"""Test-support shims (no runtime dependencies beyond pytest at test time).

``hypothesis`` is an optional test extra; when it is absent the property-based
tests import ``given``/``st`` from here instead, which turns each ``@given``
test into a single skipped test rather than a collection error.
"""
from __future__ import annotations


def given(*_args, **_kwargs):
    """Drop-in for ``hypothesis.given`` that skips the test at call time."""

    def decorate(fn):
        def skipped():
            import pytest

            pytest.skip("hypothesis not installed (pip install .[test])")

        skipped.__name__ = fn.__name__
        skipped.__doc__ = fn.__doc__
        return skipped

    return decorate


class _Strategy:
    """Inert stand-in for a hypothesis strategy (never drawn from)."""

    def __call__(self, *args, **kwargs):
        return self

    def __getattr__(self, name):
        return self


class _StrategiesModule:
    """Duck-types ``hypothesis.strategies``: every attribute is a no-op
    strategy factory, so module-level ``st.integers(...)`` etc. still build."""

    def __getattr__(self, name):
        return _Strategy()


st = _StrategiesModule()
