"""Public jit'd wrappers over the Pallas kernels, with explicit backend mode.

The kernel backend is resolved **once at import** from the ``REPRO_KERNELS``
environment variable, so a CI run is deterministic end to end instead of
depending on a per-call backend probe:

* ``interpret`` — run every kernel through the Pallas interpreter (the CPU
  CI mode: same kernel code path as TPU, emulated);
* ``native``    — compile kernels for the accelerator (TPU);
* ``off``       — disable kernel *selection*: every call site that gates on
  :func:`kernels_enabled` (the fused lowering rules, the Encoded payload
  decode) takes its plain-XLA fallback instead.  This is what makes A/B
  bit-identity checks forceable from the outside;
* ``auto`` (default) — ``native`` on TPU, ``interpret`` elsewhere.

:func:`override_mode` temporarily rebinds the mode in-process — the fused
bit-identity tests run each cell once per mode and compare.  Anything that
caches a traced program across mode changes must key on
:func:`kernel_mode` (the engine's cache keys do).
"""
from __future__ import annotations

import contextlib
import os

import jax

from . import bitpack as _bitpack
from . import block_stats as _block_stats
from . import prefix_stats as _prefix_stats
from . import quant_lorenzo as _quant_lorenzo
from . import stencil_dq as _stencil_dq

_MODES = ("auto", "interpret", "native", "off")


def _resolve(raw: str) -> str:
    mode = raw.strip().lower() or "auto"
    if mode not in _MODES:
        raise ValueError(
            f"REPRO_KERNELS={raw!r}: expected one of {_MODES}")
    if mode == "auto":
        return "native" if jax.default_backend() == "tpu" else "interpret"
    return mode


#: resolved once at import (env), rebound only by :func:`override_mode`.
_MODE = _resolve(os.environ.get("REPRO_KERNELS", "auto"))


def kernel_mode() -> str:
    """The resolved backend mode: ``interpret`` | ``native`` | ``off``."""
    return _MODE


def kernels_enabled() -> bool:
    """Should kernel-capable call sites select the Pallas path?"""
    return _MODE != "off"


@contextlib.contextmanager
def override_mode(mode: str):
    """Temporarily force the backend mode (A/B bit-identity checks)."""
    global _MODE
    prev = _MODE
    _MODE = _resolve(mode)
    try:
        yield _MODE
    finally:
        _MODE = prev


def _interpret() -> bool:
    # "off" still runs the kernel when a wrapper is called directly (the
    # wrappers *are* the kernels); selection happens at the call sites.
    return _MODE != "native"


def quant_lorenzo2d(x: jax.Array, eps) -> jax.Array:
    """Fused quantize + 2-D Lorenzo decorrelation (compression hot path)."""
    return _quant_lorenzo.quant_lorenzo2d(x, eps, interpret=_interpret())


def pack(u: jax.Array, bits: int) -> jax.Array:
    return _bitpack.pack(u, bits, interpret=_interpret())


def unpack(words: jax.Array, n: int, bits: int) -> jax.Array:
    return _bitpack.unpack(words, n, bits, interpret=_interpret())


def grad2d(q: jax.Array, eps):
    """Fused stage-③ central differences (both axes, one pass)."""
    return _stencil_dq.grad2d(q, eps, interpret=_interpret())


def laplacian2d(q: jax.Array, eps):
    return _stencil_dq.laplacian2d(q, eps, interpret=_interpret())


def block_stats(q_blocked: jax.Array):
    """Per-block (integer mean, zigzag max) metadata reduction."""
    return _block_stats.block_stats(q_blocked, interpret=_interpret())


def prefix_stats2d(p: jax.Array):
    """Algorithm-4 (sum q, sum q^2) from residuals, no reconstruction."""
    return _prefix_stats.prefix_stats2d(p, interpret=_interpret())
