"""Public jit'd wrappers over the Pallas kernels.

On CPU (this container) every wrapper runs the kernel in ``interpret=True``
mode; on TPU the compiled kernel runs natively.  The dispatch is a backend
check, so framework code calls one API either way.
"""
from __future__ import annotations

import jax

from . import bitpack as _bitpack
from . import block_stats as _block_stats
from . import prefix_stats as _prefix_stats
from . import quant_lorenzo as _quant_lorenzo
from . import stencil_dq as _stencil_dq


def _interpret() -> bool:
    return jax.default_backend() != "tpu"


def quant_lorenzo2d(x: jax.Array, eps) -> jax.Array:
    """Fused quantize + 2-D Lorenzo decorrelation (compression hot path)."""
    return _quant_lorenzo.quant_lorenzo2d(x, eps, interpret=_interpret())


def pack(u: jax.Array, bits: int) -> jax.Array:
    return _bitpack.pack(u, bits, interpret=_interpret())


def unpack(words: jax.Array, n: int, bits: int) -> jax.Array:
    return _bitpack.unpack(words, n, bits, interpret=_interpret())


def grad2d(q: jax.Array, eps):
    """Fused stage-③ central differences (both axes, one pass)."""
    return _stencil_dq.grad2d(q, eps, interpret=_interpret())


def laplacian2d(q: jax.Array, eps):
    return _stencil_dq.laplacian2d(q, eps, interpret=_interpret())


def block_stats(q_blocked: jax.Array):
    """Per-block (integer mean, zigzag max) metadata reduction."""
    return _block_stats.block_stats(q_blocked, interpret=_interpret())


def prefix_stats2d(p: jax.Array):
    """Algorithm-4 (sum q, sum q^2) from residuals, no reconstruction."""
    return _prefix_stats.prefix_stats2d(p, interpret=_interpret())
