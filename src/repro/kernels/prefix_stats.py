"""Paper Algorithm 4 as a Pallas kernel: stage-② std without reconstruction.

Computes (sum q, sum q^2) where q is the 2-D Lorenzo reconstruction of the
residuals — *without materializing q in HBM*.  The paper's CPU algorithm
carries a ``colSum`` row buffer (the previously reconstructed row) and a
scalar prefix accumulator; the TPU adaptation (DESIGN.md §3) carries the
``colSum`` row as a VMEM scratch buffer that persists across the sequential
TPU grid, and replaces the scalar column loop with a vectorized ``cumsum``
over the row band.

Grid step i processes a (R, n2) row band:
    rowcum  = cumsum(p_band, axis=1)              # prefix within each row
    q_band  = colsum_carry + cumsum(rowcum, 0)    # Lorenzo reconstruction
    s1/s2  += sum(q_band), sum(q_band^2)          # VMEM accumulators
    colsum_carry = q_band[-1]                     # carried to band i+1
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

ROWS = 64


def _kernel(p_ref, s_ref, col_ref, acc_ref):
    i = pl.program_id(0)

    @pl.when(i == 0)
    def _init():
        col_ref[...] = jnp.zeros_like(col_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    rowcum = jnp.cumsum(p_ref[...], axis=1, dtype=jnp.int32)
    q = col_ref[...][None, :] + jnp.cumsum(rowcum, axis=0, dtype=jnp.int32)
    qf = q.astype(jnp.float32)
    acc_ref[0] += jnp.sum(qf)
    acc_ref[1] += jnp.sum(qf * qf)
    col_ref[...] = q[-1, :]

    @pl.when(i == pl.num_programs(0) - 1)
    def _emit():
        s_ref[...] = acc_ref[...]


@functools.partial(jax.jit, static_argnames=("interpret",))
def prefix_stats2d(p: jax.Array, *, interpret: bool = False):
    """(sum q, sum q^2) for q = unlorenzo(p); p int32 (n0, n1), n0 % ROWS == 0."""
    n0, n1 = p.shape
    rows = min(ROWS, n0)
    if n0 % rows:
        raise ValueError(f"n0={n0} not a multiple of {rows}")
    out = pl.pallas_call(
        _kernel,
        grid=(n0 // rows,),
        in_specs=[pl.BlockSpec((rows, n1), lambda i: (i, 0))],
        out_specs=pl.BlockSpec((2,), lambda i: (0,)),
        out_shape=jax.ShapeDtypeStruct((2,), jnp.float32),
        scratch_shapes=[pltpu.VMEM((n1,), jnp.int32), pltpu.VMEM((2,), jnp.float32)],
        interpret=interpret,
    )(p)
    return out[0], out[1]
