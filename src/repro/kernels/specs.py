"""Declarative symbolic specs for every Pallas kernel call site.

``repro.audit.kernelspec`` proves, per kernel, that (a) every block /
halo index map stays in bounds for *all* grid sizes, (b) the grid writes
every output element exactly once, and (c) the per-cell VMEM footprint
fits the budget.  Those proofs need a symbolic description of each
``pl.pallas_call`` site — the grid symbols, the block shapes and index
maps as expressions over those symbols, the host-side halo gathers, and
the algebraic facts tying the sizes together (``n0 == nb*r``).  This
module is that description, kept next to the kernels it describes; the
analyzer cross-checks it against the AST of the call sites
(``undeclared-kernel`` / ``stale-kernel-spec``), so a new kernel cannot
ship unspecified and a spec cannot outlive its kernel.

Expression language: integer arithmetic (``+ - *`` and integer
literals) over the spec's symbols, with parentheses.  Symbol bounds are
inclusive and may reference other symbols (``b`` ranges over
``0 .. nb - 1``); ``None`` means unbounded above.  ``facts`` are
equalities ``"lhs == rhs"`` where ``lhs`` is a single symbol the
analyzer eliminates by rewriting (``n0 == nb*r`` substitutes ``nb*r``
for every ``n0``).  The special symbol ``F`` in ``vmem_elems`` denotes
the audit envelope's ``max_field_elems``.
"""
from __future__ import annotations

from dataclasses import dataclass, field

#: payload-word window slack of :func:`repro.kernels.fused.band_payload`:
#: +1 word for the in-word bit offset, +1 for the carry word.  The audit's
#: bounded-exhaustive unpack lemma proves this is exactly enough for every
#: (bits, offset) combination — see ``kernelspec.check_unpack_lemma``.
WPB_EXTRA = 2


@dataclass(frozen=True)
class TileSpec:
    """One ``pl.BlockSpec``-governed operand of a kernel call site.

    ``block`` / ``index`` / ``extent`` are per-dimension expressions:
    the operand's block shape, the *block* index map (what the BlockSpec
    lambda returns for the grid symbols), and the full array extent.
    """

    name: str
    block: tuple[str, ...]
    index: tuple[str, ...]
    extent: tuple[str, ...]
    dtype_bytes: int = 4


@dataclass(frozen=True)
class HaloRead:
    """A host-side ±1-row halo gather feeding a kernel input.

    ``index`` is the symbolic row read from an array of row-extent
    ``extent``; ``guard`` (optional) is the predicate under which the
    read is live — reads outside the guard are zero-filled, never
    performed (``"b >= 1"`` / ``"b <= nb - 2"``).
    """

    array: str
    index: str
    extent: str
    guard: str = ""


@dataclass(frozen=True)
class KernelSpec:
    """Symbolic contract of one ``pl.pallas_call`` site.

    ``site``    — (module, wrapper function, ordinal) locating the call.
    ``grid``    — grid symbols, one per grid dimension.
    ``bounds``  — inclusive symbol ranges ``{sym: (lo, hi)}``; ``hi=None``
    is unbounded (the analyzer substitutes the lower bound only).
    Declaration order matters: a symbol's bound expressions may only
    reference symbols declared *after* it.
    ``facts``   — ``"sym == expr"`` size equalities (rewrites).
    ``vmem_elems`` — worst-case 4-byte elements resident in VMEM per grid
    cell (inputs + outputs + temporaries), over the symbols plus ``F``.
    ``unpack_words`` — the kernel runs the in-VMEM bitplane unpack
    (``_unpack_span``); the word-window carry lemma applies.
    ``sequential_revisit`` — the output index map is deliberately
    constant across the grid (TPU sequential-grid accumulator pattern);
    exactly-once coverage is waived, and the kernel must never be
    vmapped (Pallas batching prepends a grid axis, breaking the carry).
    """

    name: str
    site: tuple[str, str, int]
    grid: tuple[str, ...]
    bounds: dict[str, tuple[str, str | None]]
    inputs: tuple[TileSpec, ...]
    outputs: tuple[TileSpec, ...]
    facts: tuple[str, ...] = ()
    halos: tuple[HaloRead, ...] = ()
    vmem_elems: str = "0"
    unpack_words: bool = False
    sequential_revisit: bool = False
    notes: str = ""


def _band_bounds(**extra) -> dict:
    """Common band-kernel symbol ranges: grid step ``b`` over ``nb``
    bands of ``r`` rows (``r <= MAX_BAND``), ``n1`` columns."""
    out = {"b": ("0", "nb - 1"), "nb": ("1", None), "r": ("1", "256"),
           "n1": ("1", None)}
    out.update(extra)
    return out


_BAND = TileSpec("band", ("r", "n1"), ("b", "0"), ("n0", "n1"))
_ROW = TileSpec("halo_row", ("1", "n1"), ("b", "0"), ("nb", "n1"))
_BASE = TileSpec("base_row", ("1", "n1"), ("b", "0"), ("nb", "n1"))
_WBAND = TileSpec("words", ("1", "wpb"), ("b", "0"), ("nb", "wpb"))
_SROW = TileSpec("s0", ("1", "1"), ("b", "0"), ("nb", "1"))


KERNEL_SPECS: tuple[KernelSpec, ...] = (
    # -- fused Lorenzo family ------------------------------------------------
    KernelSpec(
        name="fused.lorenzo2d",
        site=("fused", "lorenzo2d", 0),
        grid=("b",),
        bounds=_band_bounds(),
        facts=("n0 == nb*r",),
        inputs=(_BAND, _ROW, _BASE),
        outputs=(TileSpec("plane", ("r", "n1"), ("b", "0"), ("n0", "n1")),),
        halos=(
            # _row_halo(p, r, "next"): next[b] = p[(b+1)*r], zero last band
            HaloRead("p", "(b + 1)*r", "n0", guard="b <= nb - 2"),
        ),
        # p + da/db (+next shifts) + base/halo rows + <=2 output planes
        vmem_elems="9*F",
        notes="grad emits two planes through the same output tile spec",
    ),
    KernelSpec(
        name="fused.lorenzo_enc2d.colsum",
        site=("fused", "lorenzo_enc2d", 0),
        grid=("b",),
        bounds=_band_bounds(wpb=("2", None)),
        inputs=(_WBAND, _SROW),
        outputs=(TileSpec("colsums", ("1", "n1"), ("b", "0"), ("nb", "n1")),),
        vmem_elems="3*F + 8",
        unpack_words=True,
    ),
    KernelSpec(
        name="fused.lorenzo_enc2d.stencil",
        site=("fused", "lorenzo_enc2d", 1),
        grid=("b",),
        bounds=_band_bounds(wpb=("2", None)),
        facts=("n0 == nb*r",),
        inputs=(_WBAND, _SROW, _ROW, _BASE),
        outputs=(TileSpec("plane", ("r", "n1"), ("b", "0"), ("n0", "n1")),),
        halos=(
            # unpack_rows(payload, arange(1, nb)*r, ...): rows b*r, b >= 1
            HaloRead("plane", "b*r", "n0", guard="b >= 1"),
        ),
        vmem_elems="10*F",
        unpack_words=True,
    ),
    # -- fused block-mean family ---------------------------------------------
    KernelSpec(
        name="fused.blockmean2d",
        site=("fused", "blockmean2d", 0),
        grid=("b",),
        bounds=_band_bounds(rb=("1", "256"), b0=("1", "4096"),
                            ng1=("1", None)),
        facts=("n0 == nb*r", "r == rb*b0", "g0 == nb*rb"),
        inputs=(
            _BAND,
            TileSpec("p_prev", ("1", "n1"), ("b", "0"), ("nb", "n1")),
            TileSpec("p_next", ("1", "n1"), ("b", "0"), ("nb", "n1")),
            TileSpec("meta", ("rb", "ng1"), ("b", "0"), ("g0", "ng1")),
            TileSpec("m_prev", ("1", "ng1"), ("b", "0"), ("nb", "ng1")),
            TileSpec("m_next", ("1", "ng1"), ("b", "0"), ("nb", "ng1")),
        ),
        outputs=(TileSpec("plane", ("r", "n1"), ("b", "0"), ("n0", "n1")),),
        halos=(
            HaloRead("p", "b*r - 1", "n0", guard="b >= 1"),
            HaloRead("p", "(b + 1)*r", "n0", guard="b <= nb - 2"),
            HaloRead("meta", "b*rb - 1", "g0", guard="b >= 1"),
            HaloRead("meta", "(b + 1)*rb", "g0", guard="b <= nb - 2"),
        ),
        # p, upsampled m, 4 shifted planes, 2 col shifts, <=2 outputs, rows
        vmem_elems="14*F",
    ),
    KernelSpec(
        name="fused.blockmean_enc2d",
        site=("fused", "blockmean_enc2d", 0),
        grid=("b",),
        bounds=_band_bounds(rb=("1", "256"), b0=("1", "4096"),
                            ng1=("1", None), wpb=("2", None)),
        facts=("n0 == nb*r", "r == rb*b0", "g0 == nb*rb"),
        inputs=(
            _WBAND, _SROW,
            TileSpec("p_prev", ("1", "n1"), ("b", "0"), ("nb", "n1")),
            TileSpec("p_next", ("1", "n1"), ("b", "0"), ("nb", "n1")),
            TileSpec("meta", ("rb", "ng1"), ("b", "0"), ("g0", "ng1")),
            TileSpec("m_prev", ("1", "ng1"), ("b", "0"), ("nb", "ng1")),
            TileSpec("m_next", ("1", "ng1"), ("b", "0"), ("nb", "ng1")),
        ),
        outputs=(TileSpec("plane", ("r", "n1"), ("b", "0"), ("n0", "n1")),),
        halos=(
            # unpack_rows at arange(1, nb)*r - 1 and arange(1, nb)*r
            HaloRead("plane", "b*r - 1", "n0", guard="b >= 1"),
            HaloRead("plane", "b*r", "n0", guard="b >= 1"),
            HaloRead("meta", "b*rb - 1", "g0", guard="b >= 1"),
            HaloRead("meta", "(b + 1)*rb", "g0", guard="b <= nb - 2"),
        ),
        vmem_elems="15*F",
        unpack_words=True,
    ),
    # -- bitplane pack / unpack ----------------------------------------------
    KernelSpec(
        name="bitpack.pack",
        site=("bitpack", "pack", 0),
        grid=("i",),
        bounds={"i": ("0", "g - 1"), "g": ("1", None),
                "wp": ("1", "4096")},
        facts=("npad == g*4096", "nw == g*wp"),
        inputs=(TileSpec("u", ("4096",), ("i",), ("npad",)),),
        outputs=(TileSpec("words", ("wp",), ("i",), ("nw",)),),
        # u + (V, bits<=32) bit matrix + word stream + powers
        vmem_elems="4096 + 4096*32 + 4096 + 64",
    ),
    KernelSpec(
        name="bitpack.unpack",
        site=("bitpack", "unpack", 0),
        grid=("i",),
        bounds={"i": ("0", "g - 1"), "g": ("1", None),
                "wp": ("1", "4096")},
        facts=("npad == g*4096", "nw == g*wp"),
        inputs=(TileSpec("words", ("wp",), ("i",), ("nw",)),),
        outputs=(TileSpec("u", ("4096",), ("i",), ("npad",)),),
        vmem_elems="4096 + 4096*32 + 4096 + 64",
    ),
    # -- fused quantize + Lorenzo --------------------------------------------
    KernelSpec(
        name="quant_lorenzo.quant_lorenzo2d",
        site=("quant_lorenzo", "quant_lorenzo2d", 0),
        grid=("i", "j"),
        bounds={"i": ("0", "g0 - 1"), "j": ("0", "g1 - 1"),
                "g0": ("1", None), "g1": ("1", None),
                "t0": ("1", "128"), "t1": ("1", "256")},
        facts=("n0 == g0*t0", "n1 == g1*t1"),
        inputs=(
            TileSpec("x", ("t0", "t1"), ("i", "j"), ("n0", "n1")),
            TileSpec("xr", ("t0", "t1"), ("i", "j"), ("n0", "n1")),
            TileSpec("xc", ("t0", "t1"), ("i", "j"), ("n0", "n1")),
            TileSpec("xrc", ("t0", "t1"), ("i", "j"), ("n0", "n1")),
            TileSpec("eps", ("1",), ("0",), ("1",)),
        ),
        outputs=(TileSpec("p", ("t0", "t1"), ("i", "j"), ("n0", "n1")),),
        # halos are same-shape pre-shifted *views*; no out-of-tile reads
        vmem_elems="9*128*256 + 8",
    ),
    # -- dequantized finite-difference stencils ------------------------------
    KernelSpec(
        name="stencil_dq.grad2d",
        site=("stencil_dq", "grad2d", 0),
        grid=("i", "j"),
        bounds={"i": ("0", "g0 - 1"), "j": ("0", "g1 - 1"),
                "g0": ("1", None), "g1": ("1", None),
                "t0": ("1", "128"), "t1": ("1", "256")},
        facts=("m0 == g0*t0", "m1 == g1*t1"),
        inputs=(
            TileSpec("qn", ("t0", "t1"), ("i", "j"), ("m0", "m1")),
            TileSpec("qs", ("t0", "t1"), ("i", "j"), ("m0", "m1")),
            TileSpec("qw", ("t0", "t1"), ("i", "j"), ("m0", "m1")),
            TileSpec("qe", ("t0", "t1"), ("i", "j"), ("m0", "m1")),
        ),
        outputs=(
            TileSpec("d0", ("t0", "t1"), ("i", "j"), ("m0", "m1")),
            TileSpec("d1", ("t0", "t1"), ("i", "j"), ("m0", "m1")),
        ),
        vmem_elems="6*128*256",
    ),
    KernelSpec(
        name="stencil_dq.laplacian2d",
        site=("stencil_dq", "laplacian2d", 0),
        grid=("i", "j"),
        bounds={"i": ("0", "g0 - 1"), "j": ("0", "g1 - 1"),
                "g0": ("1", None), "g1": ("1", None),
                "t0": ("1", "128"), "t1": ("1", "256")},
        facts=("m0 == g0*t0", "m1 == g1*t1"),
        inputs=(
            TileSpec("qc", ("t0", "t1"), ("i", "j"), ("m0", "m1")),
            TileSpec("qn", ("t0", "t1"), ("i", "j"), ("m0", "m1")),
            TileSpec("qs", ("t0", "t1"), ("i", "j"), ("m0", "m1")),
            TileSpec("qw", ("t0", "t1"), ("i", "j"), ("m0", "m1")),
            TileSpec("qe", ("t0", "t1"), ("i", "j"), ("m0", "m1")),
        ),
        outputs=(TileSpec("lap", ("t0", "t1"), ("i", "j"), ("m0", "m1")),),
        vmem_elems="7*128*256",
    ),
    # -- blockwise metadata reduction ----------------------------------------
    KernelSpec(
        name="block_stats.block_stats",
        site=("block_stats", "block_stats", 0),
        grid=("i",),
        bounds={"i": ("0", "g - 1"), "g": ("1", None),
                "rows": ("1", "256"), "s": ("1", "4096")},
        facts=("nb == g*rows",),
        inputs=(TileSpec("q", ("rows", "s"), ("i", "0"), ("nb", "s")),),
        outputs=(
            TileSpec("mean", ("rows",), ("i",), ("nb",)),
            TileSpec("maxu", ("rows",), ("i",), ("nb",)),
        ),
        vmem_elems="2*256*4096 + 2*256",
    ),
    # -- sequential prefix stats (deliberately unwired) ----------------------
    KernelSpec(
        name="prefix_stats.prefix_stats2d",
        site=("prefix_stats", "prefix_stats2d", 0),
        grid=("i",),
        bounds={"i": ("0", "g - 1"), "g": ("1", None),
                "rows": ("1", "64"), "n1": ("1", None)},
        facts=("n0 == g*rows",),
        inputs=(TileSpec("p", ("rows", "n1"), ("i", "0"), ("n0", "n1")),),
        outputs=(TileSpec("s", ("2",), ("0",), ("2",)),),
        # band + rowcum + q + qf + colsum scratch
        vmem_elems="4*F + 4",
        sequential_revisit=True,
        notes="pl.program_id-keyed carry: every grid step revisits output "
              "block 0 (legal under TPU sequential grid semantics); must "
              "never run under vmap — which is why it stays unwired",
    ),
)
