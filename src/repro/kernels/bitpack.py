"""Fixed-rate bitplane pack/unpack Pallas kernels.

The encode/decode hot loop of the paper's fixed-rate coder (§IV "Encoding").
Each grid step packs ``VALS`` zigzag values at a static width ``bits`` into
``VALS*bits/32`` uint32 words entirely in VMEM via a bit-matrix contraction:

    values (V,)  ->  bits (V, bits)  ->  reshape (V*bits/32, 32)  ->  · 2^j

``VALS`` is chosen so V*bits is a multiple of 32 for every bits in 1..32
(V = multiple of 32) and the bit matrix fits VMEM comfortably.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

VALS = 4096  # values per grid step; V*bits <= 128K int32 = 512 KiB VMEM


def _words_for(n: int, bits: int) -> int:
    """uint32 words holding ``n`` values at ``bits`` (= encode.words_for)."""
    return -(-(n * bits) // 32) if bits > 0 else 0


def _pack_kernel(u_ref, o_ref, *, bits: int):
    u = u_ref[...].astype(jnp.uint32)
    shifts = jnp.arange(bits, dtype=jnp.uint32)
    bitmat = (u[:, None] >> shifts[None, :]) & jnp.uint32(1)   # (V, bits)
    stream = bitmat.reshape(-1, 32)                            # (V*bits/32, 32)
    powers = (jnp.uint32(1) << jnp.arange(32, dtype=jnp.uint32))
    o_ref[...] = jnp.sum(stream * powers[None, :], axis=1, dtype=jnp.uint32)


def _unpack_kernel(w_ref, o_ref, *, bits: int):
    w = w_ref[...].astype(jnp.uint32)
    shifts = jnp.arange(32, dtype=jnp.uint32)
    bitmat = ((w[:, None] >> shifts[None, :]) & jnp.uint32(1)).reshape(-1, bits)
    powers = (jnp.uint32(1) << jnp.arange(bits, dtype=jnp.uint32))
    o_ref[...] = jnp.sum(bitmat * powers[None, :], axis=1, dtype=jnp.uint32)


@functools.partial(jax.jit, static_argnames=("bits", "interpret"))
def pack(u: jax.Array, bits: int, *, interpret: bool = False) -> jax.Array:
    """Pack flat zigzag uint32 values at static width ``bits``.

    Matches ``repro.core.encode.pack_uniform`` bit-exactly for any length:
    a non-multiple-of-``VALS`` tail is zero-padded to the next grid step —
    zero values contribute zero bits, and fixed-rate bit ranges are
    disjoint, so slicing the word stream back to ``words_for(n, bits)``
    words is word-identical to packing the unpadded input.
    """
    if bits == 0:
        return jnp.zeros((0,), jnp.uint32)
    if bits == 32:
        return u.astype(jnp.uint32)
    n = u.shape[0]
    pad = -n % VALS
    if pad:
        u = jnp.concatenate(
            [u.astype(jnp.uint32), jnp.zeros((pad,), jnp.uint32)])
    n_pad = n + pad
    words_per = VALS * bits // 32
    grid = (n_pad // VALS,)
    out = pl.pallas_call(
        functools.partial(_pack_kernel, bits=bits),
        grid=grid,
        in_specs=[pl.BlockSpec((VALS,), lambda i: (i,))],
        out_specs=pl.BlockSpec((words_per,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((n_pad * bits // 32,), jnp.uint32),
        interpret=interpret,
    )(u.astype(jnp.uint32))
    return out[:_words_for(n, bits)]


@functools.partial(jax.jit, static_argnames=("n", "bits", "interpret"))
def unpack(words: jax.Array, n: int, bits: int, *, interpret: bool = False) -> jax.Array:
    """Inverse of :func:`pack` for any ``n`` (tail words zero-padded)."""
    if bits == 0:
        return jnp.zeros((n,), jnp.uint32)
    if bits == 32:
        return words[:n].astype(jnp.uint32)
    pad = -n % VALS
    n_pad = n + pad
    words_per = VALS * bits // 32
    nw_pad = n_pad * bits // 32
    words = words.astype(jnp.uint32)
    if words.shape[0] < nw_pad:
        words = jnp.concatenate(
            [words, jnp.zeros((nw_pad - words.shape[0],), jnp.uint32)])
    grid = (n_pad // VALS,)
    out = pl.pallas_call(
        functools.partial(_unpack_kernel, bits=bits),
        grid=grid,
        in_specs=[pl.BlockSpec((words_per,), lambda i: (i,))],
        out_specs=pl.BlockSpec((VALS,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((n_pad,), jnp.uint32),
        interpret=interpret,
    )(words[:nw_pad])
    return out[:n]
