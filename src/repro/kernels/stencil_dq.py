"""Fused dequantize + finite-difference stencils on stage-③ integers.

The paper's fastest differentiation path computes stencils on D_q and scales
once by eps (Eq. V-B.2/V-B.4).  Fusing the integer stencil in VMEM avoids
materializing the int32 difference array in HBM — one read of q per output.

The eps scaling deliberately lives *outside* the kernel: a trailing float
multiply feeding an output ref is the FMA-contraction hazard (XLA CPU
fusion can duplicate it into downstream consumers and contract it
shape-dependently, breaking bit-identity — the PR 8 bug).  The kernels
emit exact int32 stencil planes and the wrappers apply the float tail as a
separate XLA op, which is the structure ``repro.audit``'s kernelspec
analyzer enforces.

Halo handling: shifted HBM views (see quant_lorenzo.py).  Both central
differences and the 5-point Laplacian are emitted by one kernel invocation
each; ``grad2d`` returns both axis derivatives from a single pass over q
(the multivariate operators in §V-C are compositions of these outputs).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

DEFAULT_TILE = (128, 256)


def _grad_kernel(qn_ref, qs_ref, qw_ref, qe_ref, d0_ref, d1_ref):
    d0_ref[...] = qs_ref[...] - qn_ref[...]
    d1_ref[...] = qe_ref[...] - qw_ref[...]


def _lap_kernel(qc_ref, qn_ref, qs_ref, qw_ref, qe_ref, o_ref):
    o_ref[...] = (qn_ref[...] + qs_ref[...] + qw_ref[...] + qe_ref[...]
                  - 4 * qc_ref[...])


def _interior_views(q: jax.Array):
    """(north, south, west, east, center) interior-aligned views of q."""
    qn = q[:-2, 1:-1]
    qs = q[2:, 1:-1]
    qw = q[1:-1, :-2]
    qe = q[1:-1, 2:]
    qc = q[1:-1, 1:-1]
    return qn, qs, qw, qe, qc


def _tiles(shape, tile):
    t0 = min(tile[0], shape[0])
    t1 = min(tile[1], shape[1])
    if shape[0] % t0 or shape[1] % t1:
        raise ValueError(f"interior {shape} not a multiple of tile ({t0},{t1})")
    return t0, t1


@functools.partial(jax.jit, static_argnames=("tile", "interpret"))
def grad2d(q: jax.Array, eps: jax.Array, *, tile=DEFAULT_TILE, interpret: bool = False):
    """(d/dx0, d/dx1) on the common interior; both from one pass over q."""
    qn, qs, qw, qe, _ = _interior_views(q)
    m0, m1 = qn.shape
    t0, t1 = _tiles((m0, m1), tile)
    spec = pl.BlockSpec((t0, t1), lambda i, j: (i, j))
    d0, d1 = pl.pallas_call(
        _grad_kernel,
        grid=(m0 // t0, m1 // t1),
        in_specs=[spec] * 4,
        out_specs=[spec, spec],
        out_shape=[jax.ShapeDtypeStruct((m0, m1), jnp.int32)] * 2,
        interpret=interpret,
    )(qn, qs, qw, qe)
    eps = jnp.asarray(eps, jnp.float32)
    return d0.astype(jnp.float32) * eps, d1.astype(jnp.float32) * eps


@functools.partial(jax.jit, static_argnames=("tile", "interpret"))
def laplacian2d(q: jax.Array, eps: jax.Array, *, tile=DEFAULT_TILE, interpret: bool = False):
    """5-point Laplacian on the common interior (Eq. V-B.4 fused with 2eps)."""
    qn, qs, qw, qe, qc = _interior_views(q)
    m0, m1 = qn.shape
    t0, t1 = _tiles((m0, m1), tile)
    spec = pl.BlockSpec((t0, t1), lambda i, j: (i, j))
    acc = pl.pallas_call(
        _lap_kernel,
        grid=(m0 // t0, m1 // t1),
        in_specs=[spec] * 5,
        out_specs=spec,
        out_shape=jax.ShapeDtypeStruct((m0, m1), jnp.int32),
        interpret=interpret,
    )(qc, qn, qs, qw, qe)
    return acc.astype(jnp.float32) * (2.0 * jnp.asarray(eps, jnp.float32))
