"""Fused recorrelation + op-postlude Pallas kernels (2-D stage hot path).

The paper's multi-stage design exists to avoid paying full decompression per
analytical operation; these kernels take the argument one level lower: a
stage reconstruction feeding a stencil never materializes its *integer
intermediate* (the Lorenzo cumsum planes, the upsampled block means, the
stage-③ q array) in HBM at all.  One pass reads the residual band into
VMEM, recorrelates in registers, and writes only the stencil plane.

Each family has two kernel variants sharing one band body: the
*residual-plane* kernels (``lorenzo2d`` / ``blockmean2d``) read a decoded
``(r, n1)`` residual band, and the *payload-input* kernels
(``lorenzo_enc2d`` / ``blockmean_enc2d``) go one step further for
:class:`~repro.core.stages.Encoded` fields — each grid cell takes its
band's *gathered payload words*, bitplane-unpacks them in VMEM
(``_unpack_span``, the same word/shift/mask arithmetic as
``encode.unpack_uniform``, hence bit-identical integers), recorrelates,
and writes only the stencil plane: decode + op in a single pass, with the
residual plane never existing in HBM either.  Cross-band state stays
tiny: halo rows are unpacked host-side at row cost, and the Lorenzo
cross-band ``base`` prefix comes from a payload-input column-sum pass
(int32 modular, so any summation order is exact).

Design constraints (why these kernels look the way they do):

* **Carry-free / vmap-safe.**  The batched analytics engine runs every
  lowering under ``jax.vmap``; Pallas batching prepends a grid dimension,
  which silently breaks ``pl.program_id``-keyed sequential carries (see
  ``prefix_stats.py``, which is why *that* kernel stays unwired).  Here
  every grid cell is independent: cross-band prefix state enters as a tiny
  precomputed ``base`` input (exclusive band prefix of per-band column
  sums, ``n_bands x n1`` — R× smaller than the D-plane it replaces), and
  ±1-row halos enter as strided ``(n_bands, n1)`` row gathers.

* **Bit-identity via integer outputs.**  Each kernel emits the *exact
  integer* stencil plane (int32, modular — associative, so any in-kernel
  regrouping is exact); the float tail (cast + eps multiply) is applied by
  the lowering rules in ``repro.core.fused`` with the identical operations
  the XLA rules use.  Keeping the float tail outside the kernel is what
  makes composition bit-stable: a trailing in-kernel multiply can be
  duplicated into a downstream consumer and FMA-contracted by XLA's CPU
  fusion *shape-dependently* (the interpret-mode grid loop unrolls for
  small fields), which broke batched-vs-per-field bit-identity for
  divergence.  The block-mean laplacians are the one exception — their
  contract is a specific f32 accumulation *sequence* — so they emit that
  f32 sum (final op an add, same producer pattern as the XLA rule) and
  leave only the eps multiply outside.

* **Full-shape outputs, window slicing outside.**  Stencil-then-slice
  equals slice-then-stencil for every interior element, so kernels emit
  full padded-shape planes (boundary rows/columns are don't-care) and the
  lowering rule applies the same window/interior slices the XLA rules use.
  That keeps one kernel per (family, op) serving full-field, cropped, and
  region-windowed queries alike.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

MAX_BAND = 256  # target rows per grid step (VMEM residency, f32 min-tile ok)
_WORD_BITS = 32


def band_rows(n0: int, mult: int = 1) -> int:
    """Largest divisor of ``n0`` that is a multiple of ``mult`` and at most
    ``MAX_BAND`` (falls back to ``mult``, which always divides ``n0``)."""
    g = n0 // mult
    best = mult
    for d in range(1, g + 1):
        if g % d == 0 and mult * d <= MAX_BAND:
            best = mult * d
    return best


def _row_halo(x: jax.Array, r: int, side: str) -> jax.Array:
    """Per-band ±1 halo rows of ``x``: ``prev[b] = x[b*r - 1]`` (zeros for
    band 0), ``next[b] = x[(b+1)*r]`` (zeros for the last band)."""
    zero = jnp.zeros((1, x.shape[1]), x.dtype)
    if side == "prev":
        return jnp.concatenate([zero, x[r - 1::r][:-1]], axis=0)
    return jnp.concatenate([x[r::r], zero], axis=0)


def _shift_rows(x, prev, nxt):
    """(x_{i-1}, x_{i+1}) with cross-band halo rows."""
    up = jnp.concatenate([prev, x[:-1]], axis=0)
    dn = jnp.concatenate([x[1:], nxt], axis=0)
    return up, dn


def _shift_cols(x):
    """(x_{j-1}, x_{j+1}); boundary columns are don't-care (sliced off)."""
    zero = jnp.zeros((x.shape[0], 1), x.dtype)
    left = jnp.concatenate([zero, x[:, :-1]], axis=1)
    right = jnp.concatenate([x[:, 1:], zero], axis=1)
    return left, right


# ---------------------------------------------------------------------------
# in-kernel bitplane unpack (payload-input kernel variants)
# ---------------------------------------------------------------------------

def _unpack_span(words: jax.Array, bit0: jax.Array, nv: int,
                 bits: int) -> jax.Array:
    """Unpack ``nv`` zigzag values starting ``bit0`` bits into ``words``.

    Identical arithmetic to ``encode.unpack_uniform`` with the global bit
    offset split into a word base (resolved by the caller's band gather)
    and the residual in-word offset ``bit0`` — same words, same shifts,
    same masks, so the recovered integers are bit-identical.
    """
    mask = jnp.uint32((1 << bits) - 1)
    offs = (bit0.astype(jnp.uint32)
            + jnp.arange(nv, dtype=jnp.uint32) * jnp.uint32(bits))
    widx = (offs >> 5).astype(jnp.int32)
    shift = offs & jnp.uint32(31)
    lo = words[widx] >> shift
    carry = shift > jnp.uint32(_WORD_BITS - bits)
    hi_shift = jnp.where(carry, jnp.uint32(_WORD_BITS) - shift,
                         jnp.uint32(31))
    hi = jnp.where(carry, words[widx + 1] << hi_shift, jnp.uint32(0))
    return (lo | hi) & mask


def _unzigzag(u: jax.Array) -> jax.Array:
    """signed residuals from zigzag words — ``encode.unzigzag`` verbatim."""
    ui = u.astype(jnp.int32)
    return (ui >> 1) ^ -(ui & 1)


def band_payload(payload: jax.Array, nv: int, bits: int,
                 nb: int) -> tuple[jax.Array, jax.Array]:
    """Per-band payload word windows for in-kernel unpacking.

    Band ``b`` covers values ``[b*nv, (b+1)*nv)`` of the flat packed order;
    its bits span at most ``nv*bits//32 + WPB_EXTRA`` words (+1 for the
    in-word offset, +1 for the carry word — the width
    ``repro.audit.kernelspec`` proves sufficient by exhaustive sweep).
    Returns the ``(nb, wpb)`` word matrix and the ``(nb, 1)`` in-word bit
    offsets — the only payload-sized transfer of the fused-decode path.
    """
    from repro.kernels.specs import WPB_EXTRA
    wpb = (nv * bits) // _WORD_BITS + WPB_EXTRA
    bit0 = jnp.arange(nb, dtype=jnp.int32) * jnp.int32(nv * bits)
    w0 = bit0 >> 5
    s0 = bit0 & 31
    pad = jnp.concatenate([payload, jnp.zeros((wpb,), jnp.uint32)])
    words = pad[w0[:, None] + jnp.arange(wpb, dtype=jnp.int32)[None, :]]
    return words, s0.reshape(nb, 1)


def unpack_rows(payload: jax.Array, rows: jax.Array, n1: int,
                bits: int) -> jax.Array:
    """Unpack whole rows of the padded plane (halo rows for the payload
    kernels) — ``unpack_uniform``'s gather arithmetic restricted to the
    requested rows, cost proportional to the rows, not the field."""
    mask = jnp.uint32((1 << bits) - 1)
    offs = ((rows[:, None].astype(jnp.uint32) * jnp.uint32(n1)
             + jnp.arange(n1, dtype=jnp.uint32)[None, :])
            * jnp.uint32(bits))
    widx = (offs >> 5).astype(jnp.int32)
    shift = offs & jnp.uint32(31)
    pad = jnp.concatenate([payload, jnp.zeros((1,), jnp.uint32)])
    lo = pad[widx] >> shift
    carry = shift > jnp.uint32(_WORD_BITS - bits)
    hi_shift = jnp.where(carry, jnp.uint32(_WORD_BITS) - shift,
                         jnp.uint32(31))
    hi = jnp.where(carry, pad[widx + 1] << hi_shift, jnp.uint32(0))
    return _unzigzag((lo | hi) & mask)


# ---------------------------------------------------------------------------
# Lorenzo family: residual band -> cumsum planes -> stencil, all in VMEM
# ---------------------------------------------------------------------------

def _lorenzo_core(p, ph_row, base_row, out_refs, what: str):
    """Shared band body: D0 = cumsum(p, axis=1) (+1-row halo ``ph_row``),
    D1 = base + cumsum(p, axis=0); emit the requested integer planes.

    Derivative planes are ``D[+1] + D[0]`` — identical integers at stages
    ②③④ (q[i+1]-q[i-1] telescopes to D[i+1]+D[i]); the laplacian plane is
    ``sum_a (D_a[+1] - D_a[0])``, Eq. V-B.3.
    """
    outs = iter(out_refs)
    if what in ("deriv0", "grad", "lap"):
        da = jnp.cumsum(p, axis=1)
        da_next = jnp.concatenate([da[1:], jnp.cumsum(ph_row, axis=1)],
                                  axis=0)
    if what in ("deriv1", "grad", "lap"):
        db = base_row + jnp.cumsum(p, axis=0)
        db_next = jnp.concatenate(
            [db[:, 1:], jnp.zeros((p.shape[0], 1), db.dtype)], axis=1)
    if what in ("deriv0", "grad"):
        next(outs)[...] = da_next + da
    if what in ("deriv1", "grad"):
        next(outs)[...] = db_next + db
    if what == "lap":
        next(outs)[...] = (da_next - da) + (db_next - db)


def _lorenzo_kernel(p_ref, ph_ref, base_ref, *out_refs, what: str):
    _lorenzo_core(p_ref[...], ph_ref[...], base_ref[...], out_refs, what)


def _lorenzo_enc_kernel(w_ref, s0_ref, ph_ref, base_ref, *out_refs,
                        what: str, r: int, n1: int, bits: int):
    """Payload-input variant: gathered band words -> in-kernel bitplane
    unpack -> the same Lorenzo band body.  The residual plane exists only
    in VMEM."""
    p = _unzigzag(_unpack_span(w_ref[0], s0_ref[0, 0], r * n1,
                               bits)).reshape(r, n1)
    _lorenzo_core(p, ph_ref[...], base_ref[...], out_refs, what)


def _colsum_enc_kernel(w_ref, s0_ref, o_ref, *, r: int, n1: int, bits: int):
    """Payload-input band column sums (the cross-band ``base`` prefix
    input) — int32 modular, so any summation order is exact."""
    p = _unzigzag(_unpack_span(w_ref[0], s0_ref[0, 0], r * n1,
                               bits)).reshape(r, n1)
    o_ref[...] = jnp.sum(p, axis=0, keepdims=True)


@functools.partial(jax.jit, static_argnames=("what", "interpret"))
def lorenzo2d(p: jax.Array, *, what: str, interpret: bool = False):
    """Fused Lorenzo recorrelation + integer stencil over a 2-D residual
    plane.

    ``what``: ``deriv0`` / ``deriv1`` (one full-shape int32 plane), ``grad``
    (both planes from one pass), ``lap`` (V-B.3 int32 plane).  Boundary
    rows/columns of each output are don't-care; callers slice the same
    window the XLA lowering rules slice, then apply the float tail.
    """
    n0, n1 = p.shape
    r = band_rows(n0)
    nb = n0 // r
    halo = _row_halo(p, r, "next")
    band_sums = jnp.sum(p.reshape(nb, r, n1), axis=1)
    base = jnp.concatenate(
        [jnp.zeros((1, n1), p.dtype), jnp.cumsum(band_sums, axis=0)[:-1]],
        axis=0)
    band = pl.BlockSpec((r, n1), lambda b: (b, 0))
    row = pl.BlockSpec((1, n1), lambda b: (b, 0))
    n_out = 2 if what == "grad" else 1
    out_spec = [band] * n_out
    out_shape = [jax.ShapeDtypeStruct((n0, n1), p.dtype)] * n_out
    out = pl.pallas_call(
        functools.partial(_lorenzo_kernel, what=what),
        grid=(nb,),
        in_specs=[band, row, row],
        out_specs=out_spec if n_out > 1 else out_spec[0],
        out_shape=out_shape if n_out > 1 else out_shape[0],
        interpret=interpret,
    )(p, halo, base)
    return out


@functools.partial(jax.jit,
                   static_argnames=("shape", "bits", "what", "interpret"))
def lorenzo_enc2d(payload: jax.Array, shape: tuple, bits: int, *,
                  what: str, interpret: bool = False):
    """Single-pass decode + Lorenzo stencil from the packed payload.

    Two payload-input kernel passes, neither of which materializes the
    residual plane in HBM: a band column-sum pass (for the tiny cross-band
    ``base`` prefix), then the stencil pass — each unpacks its band's
    gathered payload words in VMEM.  Halo rows are unpacked host-side at
    row cost.  The recovered integers are bit-identical to
    ``decode_device`` + :func:`lorenzo2d` (same unpack arithmetic), so the
    output planes are too.
    """
    n0, n1 = shape
    r = band_rows(n0)
    nb = n0 // r
    words, s0 = band_payload(payload, r * n1, bits, nb)
    wpb = words.shape[1]
    halo = jnp.concatenate(
        [unpack_rows(payload, jnp.arange(1, nb, dtype=jnp.int32) * r,
                     n1, bits),
         jnp.zeros((1, n1), jnp.int32)], axis=0)
    wband = pl.BlockSpec((1, wpb), lambda b: (b, 0))
    srow = pl.BlockSpec((1, 1), lambda b: (b, 0))
    row = pl.BlockSpec((1, n1), lambda b: (b, 0))
    band = pl.BlockSpec((r, n1), lambda b: (b, 0))
    colsums = pl.pallas_call(
        functools.partial(_colsum_enc_kernel, r=r, n1=n1, bits=bits),
        grid=(nb,),
        in_specs=[wband, srow],
        out_specs=row,
        out_shape=jax.ShapeDtypeStruct((nb, n1), jnp.int32),
        interpret=interpret,
    )(words, s0)
    base = jnp.concatenate(
        [jnp.zeros((1, n1), jnp.int32), jnp.cumsum(colsums, axis=0)[:-1]],
        axis=0)
    n_out = 2 if what == "grad" else 1
    out_spec = [band] * n_out
    out_shape = [jax.ShapeDtypeStruct((n0, n1), jnp.int32)] * n_out
    out = pl.pallas_call(
        functools.partial(_lorenzo_enc_kernel, what=what, r=r, n1=n1,
                          bits=bits),
        grid=(nb,),
        in_specs=[wband, srow, row, row],
        out_specs=out_spec if n_out > 1 else out_spec[0],
        out_shape=out_shape if n_out > 1 else out_shape[0],
        interpret=interpret,
    )(words, s0, halo, base)
    return out


# ---------------------------------------------------------------------------
# block-mean family: residual band + metadata grid band -> stencil
# ---------------------------------------------------------------------------

def _blockmean_core(p, pp_row, pn_row, mg, mp_row, mn_row, out_refs,
                    what: str, block: tuple):
    """Shared band body: upsample the metadata grid band in VMEM (never in
    HBM) and emit the requested stencil planes.

    Derivative planes serve stages ②③④ alike: with q = p + m elementwise,
    q[+1]-q[-1] and (p[+1]-p[-1]) + (m[+1]-m[-1]) are the same int32 value.
    The two laplacian variants replicate the XLA rules' distinct f32
    accumulation orders (②: stencil(p) + stencil(m); ③④: stencil(p + m)),
    minus the trailing eps multiply, which the lowering rule applies.
    """
    b0, b1 = block
    m = jnp.repeat(jnp.repeat(mg, b0, axis=0), b1, axis=1)
    m_prev = jnp.repeat(mp_row, b1, axis=1)
    m_next = jnp.repeat(mn_row, b1, axis=1)
    p_up, p_dn = _shift_rows(p, pp_row, pn_row)
    m_up, m_dn = _shift_rows(m, m_prev, m_next)
    outs = iter(out_refs)

    def lap5(c, dn, up, right, left):
        # exact oplib._laplacian_stencil order: -2*nd*c, +hi, +lo per axis
        acc = c.astype(jnp.float32) * -4.0
        acc = acc + dn.astype(jnp.float32)
        acc = acc + up.astype(jnp.float32)
        acc = acc + right.astype(jnp.float32)
        acc = acc + left.astype(jnp.float32)
        return acc

    if what in ("deriv0", "grad"):
        next(outs)[...] = (p_dn - p_up) + (m_dn - m_up)
    if what in ("deriv1", "grad"):
        p_l, p_r = _shift_cols(p)
        m_l, m_r = _shift_cols(m)
        next(outs)[...] = (p_r - p_l) + (m_r - m_l)
    if what == "lap_p":
        p_l, p_r = _shift_cols(p)
        m_l, m_r = _shift_cols(m)
        lp = lap5(p, p_dn, p_up, p_r, p_l)
        lm = lap5(m, m_dn, m_up, m_r, m_l)
        next(outs)[...] = lp + lm
    if what == "lap_q":
        p_l, p_r = _shift_cols(p)
        m_l, m_r = _shift_cols(m)
        next(outs)[...] = lap5(p + m, p_dn + m_dn, p_up + m_up,
                               p_r + m_r, p_l + m_l)


def _blockmean_kernel(p_ref, pp_ref, pn_ref, mg_ref, mp_ref, mn_ref,
                      *out_refs, what: str, block: tuple):
    _blockmean_core(p_ref[...], pp_ref[...], pn_ref[...], mg_ref[...],
                    mp_ref[...], mn_ref[...], out_refs, what, block)


def _blockmean_enc_kernel(w_ref, s0_ref, pp_ref, pn_ref, mg_ref, mp_ref,
                          mn_ref, *out_refs, what: str, block: tuple,
                          r: int, n1: int, bits: int):
    """Payload-input variant: gathered band words -> in-kernel bitplane
    unpack -> the same block-mean band body.  Only the ±1 halo rows of the
    residual plane are unpacked host-side; the band itself exists only in
    VMEM."""
    p = _unzigzag(_unpack_span(w_ref[0], s0_ref[0, 0], r * n1,
                               bits)).reshape(r, n1)
    _blockmean_core(p, pp_ref[...], pn_ref[...], mg_ref[...], mp_ref[...],
                    mn_ref[...], out_refs, what, block)


@functools.partial(jax.jit, static_argnames=("block", "what", "interpret"))
def blockmean2d(p: jax.Array, meta: jax.Array, block: tuple, *,
                what: str, interpret: bool = False):
    """Fused block-mean upsample + stencil over a 2-D residual plane.

    ``meta`` is the block-grid metadata (``n0//b0 x n1//b1``); ``what``:
    ``deriv0`` / ``deriv1`` / ``grad`` (int32 planes) / ``lap_p`` (stage-②
    f32 accumulation) / ``lap_q`` (stage-③④ f32 accumulation).  Boundary
    rows/columns of each output are don't-care, as in :func:`lorenzo2d`.
    """
    n0, n1 = p.shape
    b0, b1 = block
    r = band_rows(n0, b0)
    nb = n0 // r
    rb = r // b0
    p_prev = _row_halo(p, r, "prev")
    p_next = _row_halo(p, r, "next")
    m_prev = _row_halo(meta, rb, "prev")
    m_next = _row_halo(meta, rb, "next")
    ng1 = meta.shape[1]
    band = pl.BlockSpec((r, n1), lambda b: (b, 0))
    row = pl.BlockSpec((1, n1), lambda b: (b, 0))
    gband = pl.BlockSpec((rb, ng1), lambda b: (b, 0))
    grow = pl.BlockSpec((1, ng1), lambda b: (b, 0))
    n_out = 2 if what == "grad" else 1
    dtype = jnp.float32 if what in ("lap_p", "lap_q") else p.dtype
    out_spec = [band] * n_out
    out_shape = [jax.ShapeDtypeStruct((n0, n1), dtype)] * n_out
    out = pl.pallas_call(
        functools.partial(_blockmean_kernel, what=what, block=(b0, b1)),
        grid=(nb,),
        in_specs=[band, row, row, gband, grow, grow],
        out_specs=out_spec if n_out > 1 else out_spec[0],
        out_shape=out_shape if n_out > 1 else out_shape[0],
        interpret=interpret,
    )(p, p_prev, p_next, meta, m_prev, m_next)
    return out


@functools.partial(jax.jit, static_argnames=("shape", "block", "bits",
                                             "what", "interpret"))
def blockmean_enc2d(payload: jax.Array, meta: jax.Array, shape: tuple,
                    block: tuple, bits: int, *, what: str,
                    interpret: bool = False):
    """Single-pass decode + block-mean stencil from the packed payload.

    One payload-input kernel pass: each grid cell unpacks its band's
    gathered payload words in VMEM, upsamples the metadata grid band, and
    writes only the stencil plane — the residual plane never exists in
    HBM.  Halo rows (±1 row per band) are unpacked host-side at row cost.
    Bit-identical to ``decode_device`` + :func:`blockmean2d`.
    """
    n0, n1 = shape
    b0, b1 = block
    r = band_rows(n0, b0)
    nb = n0 // r
    rb = r // b0
    words, s0 = band_payload(payload, r * n1, bits, nb)
    wpb = words.shape[1]
    p_prev = jnp.concatenate(
        [jnp.zeros((1, n1), jnp.int32),
         unpack_rows(payload, jnp.arange(1, nb, dtype=jnp.int32) * r - 1,
                     n1, bits)], axis=0)
    p_next = jnp.concatenate(
        [unpack_rows(payload, jnp.arange(1, nb, dtype=jnp.int32) * r,
                     n1, bits),
         jnp.zeros((1, n1), jnp.int32)], axis=0)
    m_prev = _row_halo(meta, rb, "prev")
    m_next = _row_halo(meta, rb, "next")
    ng1 = meta.shape[1]
    wband = pl.BlockSpec((1, wpb), lambda b: (b, 0))
    srow = pl.BlockSpec((1, 1), lambda b: (b, 0))
    band = pl.BlockSpec((r, n1), lambda b: (b, 0))
    row = pl.BlockSpec((1, n1), lambda b: (b, 0))
    gband = pl.BlockSpec((rb, ng1), lambda b: (b, 0))
    grow = pl.BlockSpec((1, ng1), lambda b: (b, 0))
    n_out = 2 if what == "grad" else 1
    dtype = jnp.float32 if what in ("lap_p", "lap_q") else jnp.int32
    out_spec = [band] * n_out
    out_shape = [jax.ShapeDtypeStruct((n0, n1), dtype)] * n_out
    out = pl.pallas_call(
        functools.partial(_blockmean_enc_kernel, what=what, block=(b0, b1),
                          r=r, n1=n1, bits=bits),
        grid=(nb,),
        in_specs=[wband, srow, row, row, gband, grow, grow],
        out_specs=out_spec if n_out > 1 else out_spec[0],
        out_shape=out_shape if n_out > 1 else out_shape[0],
        interpret=interpret,
    )(words, s0, p_prev, p_next, meta, m_prev, m_next)
    return out
