"""Pure-jnp oracles for every Pallas kernel in this package.

Each ``<name>.py`` kernel must match its oracle bit-exactly (integer paths)
or to float tolerance (dequantized paths) across the shape/dtype sweeps in
``tests/test_kernels.py``.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


# --- quant_lorenzo ---------------------------------------------------------

def quant_lorenzo2d(x: jax.Array, eps: jax.Array) -> jax.Array:
    """round(x/2eps) followed by the 2-D Lorenzo transform (zero boundary)."""
    inv = 1.0 / (2.0 * eps)
    q = jnp.round(x.astype(jnp.float32) * inv).astype(jnp.int32)
    z = jnp.zeros_like
    qr = jnp.pad(q, ((1, 0), (0, 0)))[:-1, :]
    qc = jnp.pad(q, ((0, 0), (1, 0)))[:, :-1]
    qrc = jnp.pad(q, ((1, 0), (1, 0)))[:-1, :-1]
    return q - qr - qc + qrc


# --- bitpack ---------------------------------------------------------------

def pack_uniform(u: jax.Array, bits: int) -> jax.Array:
    """Bit-exact mirror of repro.core.encode.pack_uniform (oracle copy)."""
    from repro.core import encode

    return encode.pack_uniform(u, bits)


def unpack_uniform(words: jax.Array, n: int, bits: int) -> jax.Array:
    from repro.core import encode

    return encode.unpack_uniform(words, n, bits)


# --- stencil_dq ------------------------------------------------------------

def stencil_dq_grad2d(q: jax.Array, eps: jax.Array) -> tuple[jax.Array, jax.Array]:
    """Fused dequantize+central-difference on quantized ints (stage ③)."""
    d0 = (q[2:, 1:-1] - q[:-2, 1:-1]).astype(jnp.float32) * eps
    d1 = (q[1:-1, 2:] - q[1:-1, :-2]).astype(jnp.float32) * eps
    return d0, d1


def stencil_dq_laplacian2d(q: jax.Array, eps: jax.Array) -> jax.Array:
    acc = (q[2:, 1:-1] + q[:-2, 1:-1] + q[1:-1, 2:] + q[1:-1, :-2]
           - 4 * q[1:-1, 1:-1])
    return acc.astype(jnp.float32) * (2.0 * eps)


# --- block_stats -----------------------------------------------------------

def block_stats(q_blocked: jax.Array) -> tuple[jax.Array, jax.Array]:
    """Per-block (rounded integer mean, zigzag max) for metadata collection.

    ``q_blocked``: (n_blocks, S) int32.  Mean uses round-half-up in exact
    integer arithmetic (matches repro.core.decorrelate.block_means).
    """
    s = jnp.sum(q_blocked, axis=1, dtype=jnp.int32)
    cnt = q_blocked.shape[1]
    means = (2 * s + cnt) // (2 * cnt)
    u = ((q_blocked << 1) ^ (q_blocked >> 31)).astype(jnp.uint32)
    return means.astype(jnp.int32), jnp.max(u, axis=1)


# --- prefix_stats (paper Algorithm 4) ---------------------------------------

def prefix_stats2d(p: jax.Array) -> tuple[jax.Array, jax.Array]:
    """(sum q, sum q^2) where q = 2-D Lorenzo reconstruction of residuals p.

    The oracle materializes q; the kernel must not (it carries the paper's
    ``colSum`` row buffer across grid steps in VMEM scratch).
    """
    q = jnp.cumsum(jnp.cumsum(p, axis=0, dtype=jnp.int32), axis=1, dtype=jnp.int32)
    qf = q.astype(jnp.float32)
    return jnp.sum(qf), jnp.sum(qf * qf)
