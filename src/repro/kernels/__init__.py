"""Pallas TPU kernels for HSZ compute hot-spots (validated vs ref.py)."""

from . import fused, ops, ref
from .ops import (
    block_stats,
    grad2d,
    laplacian2d,
    pack,
    prefix_stats2d,
    quant_lorenzo2d,
    unpack,
)
