"""Pallas TPU kernels for HSZ compute hot-spots (validated vs ref.py)."""

from . import fused, ops, ref, specs
from .ops import (
    block_stats,
    grad2d,
    laplacian2d,
    pack,
    prefix_stats2d,
    quant_lorenzo2d,
    unpack,
)
from .specs import KERNEL_SPECS, WPB_EXTRA, HaloRead, KernelSpec, TileSpec
