"""Fused quantize + 2-D Lorenzo decorrelation Pallas kernel.

Compression's bandwidth hot-spot (paper Alg. 1 lines 1-9): a naive pipeline
materializes the int32 quantization array in HBM between the quantize and
decorrelate passes (2 reads + 2 writes per element).  This kernel streams an
f32 tile into VMEM and emits the decorrelated int32 residual tile in one pass
(1 read + 1 write).  The one-row/one-column halo needed by the Lorenzo
stencil is supplied as pre-shifted *views* of the same HBM buffer (XLA
aliases them; no copy), keeping BlockSpecs disjoint as TPU requires.

Tile = (ROWS, 128·k): minor dim is a lane multiple; f32 sublane tiling (8)
divides ROWS.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

DEFAULT_TILE = (128, 256)


def _kernel(x_ref, xr_ref, xc_ref, xrc_ref, eps_ref, o_ref):
    inv = 1.0 / (2.0 * eps_ref[0])
    q = jnp.round(x_ref[...] * inv).astype(jnp.int32)
    qr = jnp.round(xr_ref[...] * inv).astype(jnp.int32)
    qc = jnp.round(xc_ref[...] * inv).astype(jnp.int32)
    qrc = jnp.round(xrc_ref[...] * inv).astype(jnp.int32)
    o_ref[...] = q - qr - qc + qrc


@functools.partial(jax.jit, static_argnames=("tile", "interpret"))
def quant_lorenzo2d(x: jax.Array, eps: jax.Array, *, tile=DEFAULT_TILE,
                    interpret: bool = False) -> jax.Array:
    """Fused ``lorenzo(round(x / 2 eps))`` for 2-D f32 ``x``.

    Shapes must be tile multiples (callers pad via ``repro.core.blocking``).
    """
    n0, n1 = x.shape
    t0 = min(tile[0], n0)
    t1 = min(tile[1], n1)
    if n0 % t0 or n1 % t1:
        raise ValueError(f"shape {x.shape} not a multiple of tile ({t0},{t1})")
    # pre-shifted halo views (zero-filled at the leading boundary)
    pad_r = jnp.pad(x, ((1, 0), (0, 0)))[:-1, :]
    pad_c = jnp.pad(x, ((0, 0), (1, 0)))[:, :-1]
    pad_rc = jnp.pad(x, ((1, 0), (1, 0)))[:-1, :-1]
    eps_arr = jnp.asarray(eps, jnp.float32).reshape(1)

    grid = (n0 // t0, n1 // t1)
    spec = pl.BlockSpec((t0, t1), lambda i, j: (i, j))
    return pl.pallas_call(
        _kernel,
        grid=grid,
        in_specs=[spec, spec, spec, spec,
                  pl.BlockSpec((1,), lambda i, j: (0,))],
        out_specs=spec,
        out_shape=jax.ShapeDtypeStruct((n0, n1), jnp.int32),
        interpret=interpret,
    )(x, pad_r, pad_c, pad_rc, eps_arr)
