"""Blockwise metadata reduction Pallas kernel (paper Alg. 1 line 6 + encode).

One pass over the quantized blocks produces, per block:
  * the rounded integer mean (HSZx-family metadata, exact int arithmetic), and
  * the zigzag max (the fixed-rate bitwidth determinant, paper §IV Encoding).

Fusing both reductions halves metadata-collection bandwidth vs. two passes.
Layout: blocks are rows of a (n_blocks, S) int32 matrix; the grid tiles rows.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

ROWS = 256  # blocks per grid step


def _kernel(q_ref, mean_ref, maxu_ref):
    q = q_ref[...]
    cnt = q.shape[1]
    s = jnp.sum(q, axis=1, dtype=jnp.int32)
    mean_ref[...] = (2 * s + cnt) // (2 * cnt)
    u = ((q << 1) ^ (q >> 31)).astype(jnp.uint32)
    maxu_ref[...] = jnp.max(u, axis=1)


@functools.partial(jax.jit, static_argnames=("interpret",))
def block_stats(q_blocked: jax.Array, *, interpret: bool = False):
    """Per-block (integer mean, zigzag max) for (n_blocks, S) int32 input."""
    nb, s = q_blocked.shape
    rows = min(ROWS, nb)
    if nb % rows:
        raise ValueError(f"n_blocks={nb} not a multiple of {rows}")
    return pl.pallas_call(
        _kernel,
        grid=(nb // rows,),
        in_specs=[pl.BlockSpec((rows, s), lambda i: (i, 0))],
        out_specs=[pl.BlockSpec((rows,), lambda i: (i,)),
                   pl.BlockSpec((rows,), lambda i: (i,))],
        out_shape=[jax.ShapeDtypeStruct((nb,), jnp.int32),
                   jax.ShapeDtypeStruct((nb,), jnp.uint32)],
        interpret=interpret,
    )(q_blocked)
