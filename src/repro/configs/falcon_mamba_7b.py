"""falcon-mamba-7b [ssm]: 64L d_model=4096 (attn-free) d_ff=0 vocab=65024,
ssm_state=16, mamba-1 arch.  [arXiv:2410.05355; unverified]"""
from .base import ArchConfig

CONFIG = ArchConfig(
    name="falcon-mamba-7b", family="ssm",
    n_layers=64, d_model=4096, n_heads=1, n_kv=1, d_ff=0, vocab=65024,
    head_dim=64, ssm_state=16, d_conv=4, expand=2, subquadratic=True,
)
