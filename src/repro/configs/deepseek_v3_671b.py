"""deepseek-v3-671b [moe]: 61L d_model=7168 128H (MLA) vocab=129280,
MoE 1 shared + 256 routed top-8 (moe d_ff=2048), first 3 layers dense
(d_ff=18432), sigmoid router.  MTP head omitted (DESIGN.md §4).
[arXiv:2412.19437; hf]"""
from .base import ArchConfig

CONFIG = ArchConfig(
    name="deepseek-v3-671b", family="moe",
    n_layers=61, d_model=7168, n_heads=128, n_kv=128, d_ff=18432,
    vocab=129280, head_dim=128,
    moe=True, n_experts=256, top_k=8, first_k_dense=3, n_shared=1,
    moe_d_ff=2048, router_softmax=False,
    mla=True, q_lora=1536, kv_lora=512, qk_nope=128, qk_rope=64, v_head=128,
)
