"""whisper-base [audio]: 6L (decoder) + 6L encoder, d_model=512 8H d_ff=2048
vocab=51865; enc-dec with conv frontend STUB (input_specs provides
precomputed frame embeddings).  [arXiv:2212.04356; unverified]"""
from .base import ArchConfig

CONFIG = ArchConfig(
    name="whisper-base", family="audio",
    n_layers=6, d_model=512, n_heads=8, n_kv=8, d_ff=2048, vocab=51865,
    head_dim=64, enc_layers=6, enc_frames=1500,
)
