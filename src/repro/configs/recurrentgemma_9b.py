"""recurrentgemma-9b [hybrid]: 38L d_model=4096 16H (MQA kv=1) d_ff=12288
vocab=256000; RG-LRU + local attention 1:2 (window 2048).
[arXiv:2402.19427; unverified]"""
from .base import ArchConfig

CONFIG = ArchConfig(
    name="recurrentgemma-9b", family="hybrid",
    n_layers=38, d_model=4096, n_heads=16, n_kv=1, d_ff=12288, vocab=256000,
    head_dim=256, act="gelu", window=2048, lru_width=4096, subquadratic=True,
)
