"""paligemma-3b [vlm]: 18L d_model=2048 8H (MQA kv=1) d_ff=16384
vocab=257216; SigLIP frontend STUB (input_specs provides 256 precomputed
patch embeddings) + gemma decoder.  [arXiv:2407.07726; hf]"""
from .base import ArchConfig

CONFIG = ArchConfig(
    name="paligemma-3b", family="vlm",
    n_layers=18, d_model=2048, n_heads=8, n_kv=1, d_ff=16384, vocab=257216,
    head_dim=256, act="gelu", prefix_tokens=256,
)
