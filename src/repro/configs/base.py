"""Architecture + shape configuration registry.

Every assigned architecture is a frozen :class:`ArchConfig`; the four
assigned input shapes are :class:`ShapeConfig` entries.  ``reduced()``
derives the CPU smoke-test variant of any config (same family/topology,
small dims) — full configs are only ever lowered via the dry-run
(ShapeDtypeStruct, no allocation).
"""
from __future__ import annotations

import dataclasses

import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class ArchConfig:
    name: str
    family: str                 # dense | moe | ssm | hybrid | audio | vlm
    n_layers: int
    d_model: int
    n_heads: int
    n_kv: int
    d_ff: int
    vocab: int
    head_dim: int | None = None
    qk_norm: bool = False
    act: str = "silu"
    rope_theta: float = 10000.0
    norm_eps: float = 1e-6
    # --- MoE ---
    moe: bool = False
    n_experts: int = 0
    top_k: int = 0
    first_k_dense: int = 0
    n_shared: int = 0
    moe_d_ff: int = 0
    router_softmax: bool = True
    capacity_factor: float = 1.25
    # --- MLA (deepseek) ---
    mla: bool = False
    q_lora: int = 0
    kv_lora: int = 0
    qk_nope: int = 0
    qk_rope: int = 0
    v_head: int = 0
    # --- SSM ---
    ssm_state: int = 16
    d_conv: int = 4
    expand: int = 2
    # --- hybrid (recurrentgemma) ---
    window: int | None = None
    lru_width: int = 0
    # --- enc-dec (whisper) ---
    enc_layers: int = 0
    enc_frames: int = 1500
    # --- vlm (paligemma) ---
    prefix_tokens: int = 0
    # --- runtime ---
    kv_quant: bool = False      # HSZ stage-③ KV residency
    fsdp_bf16_gather: bool = False  # cast params to bf16 BEFORE the FSDP gather
    remat: str = "full"         # none | full | dots
    param_dtype: jnp.dtype = jnp.float32
    compute_dtype: jnp.dtype = jnp.bfloat16
    # sub-quadratic context path (SSM / hybrid): eligible for long_500k
    subquadratic: bool = False

    def __post_init__(self):
        if self.head_dim is None:
            object.__setattr__(self, "head_dim", self.d_model // self.n_heads)

    @property
    def param_count(self) -> int:
        """Approximate parameter count (reported in the roofline table)."""
        d, v, L = self.d_model, self.vocab, self.n_layers
        total = 2 * v * d  # embed + head
        hd = self.head_dim
        if self.family == "ssm":
            di = self.expand * d
            per = d * 2 * di + self.d_conv * di + di * (max(1, d // 16) + 2 * self.ssm_state) \
                + max(1, d // 16) * di + di * self.ssm_state + di * d
            return total + L * per
        if self.mla:
            attn = (d * self.q_lora + self.q_lora * self.n_heads * (self.qk_nope + self.qk_rope)
                    + d * (self.kv_lora + self.qk_rope)
                    + self.kv_lora * self.n_heads * (self.qk_nope + self.v_head)
                    + self.n_heads * self.v_head * d)
        else:
            attn = d * self.n_heads * hd + 2 * d * self.n_kv * hd + self.n_heads * hd * d
        if self.family == "hybrid":
            w = self.lru_width or d
            rec = 2 * d * w + self.d_conv * w + 2 * w * w + w + w * d
            n_attn = L // 3
            n_rec = L - n_attn
            per_mlp = 3 * d * self.d_ff
            return total + n_rec * (rec + per_mlp) + n_attn * (attn + per_mlp)
        if self.moe:
            f = self.moe_d_ff or self.d_ff
            moe_per = d * self.n_experts + 3 * self.n_experts * d * f \
                + (3 * d * f * self.n_shared if self.n_shared else 0)
            dense_per = 3 * d * self.d_ff
            return total + self.first_k_dense * (attn + dense_per) \
                + (L - self.first_k_dense) * (attn + moe_per)
        return total + L * (attn + 3 * d * self.d_ff)

    @property
    def active_param_count(self) -> int:
        """Active params per token (= param_count for dense models)."""
        if not self.moe:
            return self.param_count
        f = self.moe_d_ff or self.d_ff
        d, L = self.d_model, self.n_layers
        inactive = (L - self.first_k_dense) * 3 * (self.n_experts - self.top_k) * d * f
        return self.param_count - inactive


@dataclasses.dataclass(frozen=True)
class ShapeConfig:
    name: str
    kind: str        # train | prefill | decode
    seq_len: int
    global_batch: int


SHAPES = {
    "train_4k": ShapeConfig("train_4k", "train", 4096, 256),
    "prefill_32k": ShapeConfig("prefill_32k", "prefill", 32768, 32),
    "decode_32k": ShapeConfig("decode_32k", "decode", 32768, 128),
    "long_500k": ShapeConfig("long_500k", "decode", 524288, 1),
}


def cell_supported(arch: ArchConfig, shape: ShapeConfig) -> tuple[bool, str]:
    """Whether an (arch × shape) cell is defined (DESIGN.md §4)."""
    if shape.name == "long_500k" and not arch.subquadratic:
        return False, "pure full-attention arch: O(S^2) at 524288 has no sub-quadratic path"
    return True, ""


def reduced(cfg: ArchConfig) -> ArchConfig:
    """Same-family smoke-test variant: small dims, few layers, tiny vocab."""
    repl = dict(
        n_layers=min(cfg.n_layers, 3 if cfg.family == "hybrid" else 2),
        d_model=64, n_heads=4, n_kv=max(1, min(cfg.n_kv, 2)), head_dim=16,
        d_ff=128, vocab=256,
    )
    if cfg.family == "hybrid":
        repl.update(n_layers=4, lru_width=64, window=16)
    if cfg.moe:
        repl.update(n_experts=min(cfg.n_experts, 8), top_k=min(cfg.top_k, 2),
                    moe_d_ff=32, first_k_dense=min(cfg.first_k_dense, 1),
                    capacity_factor=8.0)  # no token drops: decode parity
    if cfg.mla:
        repl.update(q_lora=32, kv_lora=16, qk_nope=16, qk_rope=8, v_head=16)
    if cfg.family == "audio":
        repl.update(enc_layers=2, enc_frames=8)
    if cfg.family == "vlm":
        repl.update(prefix_tokens=4)
    if cfg.family == "ssm":
        repl.update(ssm_state=4, expand=2)
    return dataclasses.replace(cfg, **repl)
