"""Assigned-architecture registry: one module per architecture."""
from .base import SHAPES, ArchConfig, ShapeConfig, cell_supported, reduced

from . import (
    deepseek_v3_671b, falcon_mamba_7b, granite_3_2b, granite_moe_3b,
    minitron_4b, paligemma_3b, qwen3_4b, recurrentgemma_9b, smollm_360m,
    whisper_base,
)

ARCHS = {
    m.CONFIG.name: m.CONFIG
    for m in (
        qwen3_4b, granite_3_2b, smollm_360m, minitron_4b, falcon_mamba_7b,
        whisper_base, granite_moe_3b, deepseek_v3_671b, recurrentgemma_9b,
        paligemma_3b,
    )
}

__all__ = ["ARCHS", "SHAPES", "ArchConfig", "ShapeConfig", "cell_supported", "reduced"]
