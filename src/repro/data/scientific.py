"""Scientific-field data pipeline (the paper's own domain, §VI-A).

Synthesizes deterministic analogues of the paper's five benchmark datasets
(multi-scale smooth structure + noise, matching dims up to a scale factor),
stores them as HSZ-compressed shards, and serves analytics/training
consumers through *homomorphic* accessors: normalization statistics come
from stage-① metadata, derivative/divergence feature channels from stage-③
integers — full decompression only when a consumer asks for raw floats.
"""
from __future__ import annotations
from collections.abc import Iterator

import dataclasses
import os

import numpy as np
import jax
import jax.numpy as jnp

from repro.core import (Stage, by_name, encode as hsz_encode, homomorphic)

# name -> (fields, full dims); scale divides each dim for CI-sized runs
DATASETS = {
    "Ocean": (2, (2400, 3600)),
    "Miranda": (7, (256, 384, 384)),
    "Hurricane": (13, (100, 500, 500)),
    "NYX": (6, (512, 512, 512)),
    "JHTDB": (3, (2580, 2580, 2580)),
}


def synth_field(name: str, field: int, dims: tuple[int, ...], seed: int = 0) -> np.ndarray:
    """Multi-scale smooth field + noise (compression behaviour like real data)."""
    rng = np.random.default_rng(hash((name, field, seed)) % (2 ** 32))
    grids = np.meshgrid(*[np.linspace(0, 1, d, dtype=np.float32) for d in dims],
                        indexing="ij")
    out = np.zeros(dims, np.float32)
    for k in range(1, 5):  # superposed octaves
        phase = rng.uniform(0, 2 * np.pi, size=len(dims))
        freq = rng.uniform(1.5, 4.0) * (2.0 ** k)
        wave = np.zeros(dims, np.float32)
        for g, ph in zip(grids, phase):
            wave = wave + np.sin(2 * np.pi * freq * g + ph).astype(np.float32)
        out += wave / (2.0 ** k)
    out += rng.normal(0, 0.02, dims).astype(np.float32)
    return out


def dataset_dims(name: str, scale: int = 1) -> tuple[int, ...]:
    _, dims = DATASETS[name]
    return tuple(max(8, d // scale) for d in dims)


@dataclasses.dataclass
class CompressedShard:
    dataset: str
    field: int
    blob: bytes

    def open(self):
        return hsz_encode.deserialize(self.blob)


class ScientificStore:
    """In-memory/on-disk store of HSZ-compressed field shards."""

    def __init__(self, compressor_name: str = "hszp_nd", rel_eb: float = 1e-3,
                 scale: int = 8, seed: int = 0, root: str | None = None):
        self.comp_name = compressor_name
        self.rel_eb = rel_eb
        self.scale = scale
        self.seed = seed
        self.root = root
        self._cache: dict[tuple[str, int], CompressedShard] = {}

    def _compressor(self, ndim: int):
        name = self.comp_name
        if name.endswith("_nd"):
            return by_name(name)
        return by_name(name)

    def put_all(self, datasets: list[str] | None = None):
        for name in datasets or DATASETS:
            fields, _ = DATASETS[name]
            for f in range(fields):
                self.get(name, f)

    def get(self, dataset: str, field: int) -> CompressedShard:
        key = (dataset, field)
        if key in self._cache:
            return self._cache[key]
        if self.root:
            path = os.path.join(self.root, f"{dataset}_{field}.hsz")
            if os.path.exists(path):
                with open(path, "rb") as fh:
                    shard = CompressedShard(dataset, field, fh.read())
                self._cache[key] = shard
                return shard
        dims = dataset_dims(dataset, self.scale)
        data = synth_field(dataset, field, dims, self.seed)
        comp = self._compressor(len(dims))
        c = comp.compress(jnp.asarray(data), rel_eb=self.rel_eb)
        blob = hsz_encode.serialize(c)
        shard = CompressedShard(dataset, field, blob)
        if self.root:
            os.makedirs(self.root, exist_ok=True)
            with open(os.path.join(self.root, f"{dataset}_{field}.hsz"), "wb") as fh:
                fh.write(blob)
        self._cache[key] = shard
        return shard

    # -- homomorphic accessors (never decompress further than needed) -------
    def stats(self, dataset: str, field: int) -> dict[str, float]:
        c = self.get(dataset, field).open()
        stage = Stage.M if c.scheme.is_blockmean else Stage.P
        return {"mean": float(homomorphic.mean(c, stage)),
                "std": float(homomorphic.std(c, Stage.P))}

    def derivative_features(self, dataset: str, field: int, stage: Stage = Stage.Q):
        c = self.get(dataset, field).open()
        return homomorphic.gradient(c, stage)

    def raw(self, dataset: str, field: int) -> jax.Array:
        c = self.get(dataset, field).open()
        comp = self._compressor(len(c.shape))
        return comp.decompress(c, Stage.F)

    def normalized_batches(self, dataset: str, field: int, batch: int,
                           patch: tuple[int, ...] = (64, 64)) -> Iterator[np.ndarray]:
        """Training-style consumer: patches normalized by homomorphic stats."""
        st = self.stats(dataset, field)
        arr = np.asarray(self.raw(dataset, field))
        arr = (arr - st["mean"]) / max(st["std"], 1e-9)
        flat_dims = arr.shape[:2] if arr.ndim >= 2 else arr.shape
        rng = np.random.default_rng(0)
        while True:
            coords = [rng.integers(0, max(1, s - p), size=batch)
                      for s, p in zip(arr.shape, patch)]
            out = np.stack([
                arr[tuple(slice(c[i], c[i] + p) for c, p in zip(coords, patch))]
                for i in range(batch)])
            yield out
