"""Data pipelines: synthetic LM tokens + compressed scientific fields."""
from . import scientific, tokens
