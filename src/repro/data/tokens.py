"""Deterministic, resumable, sharded synthetic LM token pipeline.

Batches are pure functions of (seed, step, shard) — a stateless design that
makes the pipeline trivially resumable (state == step counter), elastic
(re-sharding changes only the shard index arithmetic), and reproducible
across restarts, which the fault-tolerance tests rely on.  Tokens follow a
Zipf-like marginal with short-range Markov structure so losses decrease
meaningfully during the example runs (pure-uniform tokens give constant
loss and hide optimizer bugs).
"""
from __future__ import annotations
from collections.abc import Iterator

import dataclasses

import numpy as np


@dataclasses.dataclass(frozen=True)
class TokenPipelineConfig:
    vocab: int
    seq_len: int
    global_batch: int
    seed: int = 0
    n_shards: int = 1
    shard: int = 0


class TokenPipeline:
    """Iterator with explicit integer state (= next step index)."""

    def __init__(self, cfg: TokenPipelineConfig, start_step: int = 0):
        self.cfg = cfg
        self.step = start_step
        if cfg.global_batch % cfg.n_shards:
            raise ValueError("global_batch must divide by n_shards")

    # -- stateless batch function ------------------------------------------
    def batch_at(self, step: int) -> dict[str, np.ndarray]:
        cfg = self.cfg
        per_shard = cfg.global_batch // cfg.n_shards
        rng = np.random.default_rng(
            np.uint64(cfg.seed) * np.uint64(1_000_003)
            + np.uint64(step) * np.uint64(65_537) + np.uint64(cfg.shard))
        # Zipf-ish unigram + first-order Markov "phrases"
        base = rng.zipf(1.3, size=(per_shard, cfg.seq_len)).astype(np.int64)
        tokens = base % max(cfg.vocab - 2, 1) + 1
        # repeat structure: with p=0.35 copy the previous token (learnable)
        copy = rng.random((per_shard, cfg.seq_len)) < 0.35
        for j in range(1, cfg.seq_len):
            tokens[:, j] = np.where(copy[:, j], tokens[:, j - 1], tokens[:, j])
        return {"tokens": tokens.astype(np.int32)}

    def __iter__(self) -> Iterator[dict[str, np.ndarray]]:
        return self

    def __next__(self) -> dict[str, np.ndarray]:
        b = self.batch_at(self.step)
        self.step += 1
        return b

    # -- checkpointable state ----------------------------------------------
    def state_dict(self) -> dict[str, int]:
        return {"step": self.step}

    def load_state_dict(self, state: dict[str, int]):
        self.step = int(state["step"])
