"""Batch executor: stack same-layout fields, run one jitted vmap per op.

Many timesteps/variables of a scientific dataset share one compression
layout, so their homomorphic analytics compile to a *single* XLA program
with a leading batch axis instead of one dispatch per field.  The jit cache
is keyed on ``(scheme, block, shape, op, stage, container, axis, batch)`` —
the full static signature of the compiled program — so repeated queries over
rolling data reuse the compiled executable.
"""
from __future__ import annotations

from collections import OrderedDict
from typing import Sequence, Tuple, Union

import jax

from repro.core import (Compressed, Encoded, Stage, batch_stack, layout_key,
                        homomorphic as H)
from repro.core import region as region_mod

from .planner import MULTIVARIATE, OPS, CostModel, plan_stage

Field = Union[Compressed, Encoded]

#: univariate ops: field -> array; ``derivative`` additionally takes an axis.
_UNIVARIATE_OPS = {
    "mean": lambda c, stage, axis, region: H.mean(c, stage, region=region),
    "std": lambda c, stage, axis, region: H.std(c, stage, region=region),
    "derivative": lambda c, stage, axis, region: H.derivative(c, stage, axis,
                                                             region=region),
    "laplacian": lambda c, stage, axis, region: H.laplacian(c, stage,
                                                            region=region),
}
_MULTIVARIATE_OPS = {
    "divergence": lambda comps, stage, region: H.divergence(comps, stage,
                                                            region=region),
    "curl": lambda comps, stage, region: H.curl(comps, stage, region=region),
}


def batch_key(first: Field, op: str, stage: Stage, axis: int = 0,
              n_components: int = 1, batch: int = 1, region=None) -> Tuple:
    """Static signature of one compiled batched-analytics program.

    The batch size is part of the key: stacking happens *inside* the jitted
    program (one dispatch for stack + op, and XLA elides copies the op never
    reads — e.g. residuals under a stage-① metadata mean), so the program
    arity depends on it.  The (normalized) region is static too: it decides
    the gathered block set and every output shape.
    """
    if region is not None:
        region = region_mod.normalize_region(region, first.shape)
    return layout_key(first) + (op, Stage(stage), axis, n_components, batch,
                                region)


class BatchedAnalytics:
    """Executes one homomorphic op over a batch of same-layout fields.

    One instance owns one jit cache; module-level :data:`default_engine`
    is shared by :func:`repro.analytics.query.query` and the serve frontend.

    ``bucket_batches`` pads each batch to the next power of two (repeating
    the last field; padded results are sliced off) so a serving queue with
    fluctuating depth compiles O(log max_batch) programs per op instead of
    one per distinct length.  The cache is LRU-bounded by ``cache_limit``.
    """

    def __init__(self, cost_model: CostModel | None = None, *,
                 bucket_batches: bool = True, cache_limit: int = 128):
        self.cost_model = cost_model
        self.bucket_batches = bucket_batches
        self.cache_limit = cache_limit
        self._jitted: OrderedDict[Tuple, object] = OrderedDict()

    @staticmethod
    def _bucket(n: int) -> int:
        return 1 << (n - 1).bit_length()

    # -- compiled-program cache -------------------------------------------
    def _compiled(self, key: Tuple, op: str, stage: Stage, axis: int,
                  n_components: int, batch: int, region=None):
        fn = self._jitted.get(key)
        if fn is not None:
            self._jitted.move_to_end(key)
        else:
            if op in MULTIVARIATE:
                base = _MULTIVARIATE_OPS[op]

                def run(*flat, _base=base, _stage=stage, _b=batch,
                        _nc=n_components, _r=region):
                    comps = [batch_stack(flat[i * _b:(i + 1) * _b])
                             for i in range(_nc)]
                    return jax.vmap(lambda *cs: _base(list(cs), _stage, _r))(*comps)
            else:
                base = _UNIVARIATE_OPS[op]

                def run(*fields, _base=base, _stage=stage, _axis=axis,
                        _r=region):
                    stacked = batch_stack(fields)
                    return jax.vmap(lambda c: _base(c, _stage, _axis, _r))(stacked)

            fn = jax.jit(run)
            self._jitted[key] = fn
            while len(self._jitted) > self.cache_limit:
                self._jitted.popitem(last=False)
        return fn

    @property
    def cache_size(self) -> int:
        return len(self._jitted)

    # -- execution ---------------------------------------------------------
    def run(self, fields: Sequence, op: str,
            stage: Union[Stage, str, int] = "auto", *, axis: int = 0,
            region=None):
        """Run ``op`` over ``fields`` in one jitted, vmapped call.

        ``fields`` is a sequence of same-layout :class:`Compressed` /
        :class:`Encoded` fields — or, for ``divergence``/``curl``, a sequence
        of equal-length component tuples.  Returns the batched result (leading
        axis = ``len(fields)``); ``curl`` in 3-D returns a tuple of three
        batched components, matching the unbatched op.  ``region`` restricts
        every field to the same window (same-layout fields share the block
        geometry, so one static region plan serves the whole batch).
        """
        if op not in OPS:
            raise ValueError(f"unknown operation {op!r}; expected one of {OPS}")
        if not fields:
            raise ValueError("empty batch")

        b = len(fields)
        padded = list(fields)
        if self.bucket_batches:
            padded += [fields[-1]] * (self._bucket(b) - b)

        if op in MULTIVARIATE:
            n_comp = len(fields[0])
            if any(len(f) != n_comp for f in fields):
                raise ValueError("all vector fields must have the same number "
                                 "of components")
            first = fields[0][0]
            stage = plan_stage(first.scheme, op, stage, self.cost_model,
                               region=region, field=first)
            key = batch_key(first, op, stage, 0, n_comp, len(padded), region)
            # component-major flat args: (f0[c], f1[c], ...) for each c
            flat = tuple(f[i] for i in range(n_comp) for f in padded)
            out = self._compiled(key, op, stage, 0, n_comp, len(padded),
                                 region)(*flat)
        else:
            first = fields[0]
            d_axis = axis if op == "derivative" else 0
            stage = plan_stage(first.scheme, op, stage, self.cost_model,
                               region=region, field=first, axis=d_axis)
            key = batch_key(first, op, stage, d_axis, 1, len(padded), region)
            out = self._compiled(key, op, stage, d_axis, 1, len(padded),
                                 region)(*padded)
        if len(padded) == b:
            return out
        return jax.tree.map(lambda x: x[:b], out)


#: process-wide engine (shared jit cache) used by the query front-end.
default_engine = BatchedAnalytics()
