"""Batch executor: stack same-layout fields, run one jitted vmap per op set.

Many timesteps/variables of a scientific dataset share one compression
layout, so their homomorphic analytics compile to a *single* XLA program
with a leading batch axis instead of one dispatch per field.  Op *sets* fuse
further: ``run(fields, ["mean", "std", "laplacian"])`` compiles one program
whose shared stage-reconstruction prelude (``repro.core.oplib``) feeds every
postlude — one decode pass, a dict of batched results.  The jit cache is
keyed on ``(scheme, block, shape, frozen op-set, stage, region, axis,
batch, seed signature)`` — the full static signature of the compiled
program — and the op-set component is canonically ordered, so
``["std", "mean"]`` and ``["mean", "std"]`` hit the same entry.
Store-seeded programs (``run(..., seeds=)``) take the fields' materialized
intermediates as extra inputs and contain no stage reconstruction; they
compile separately from their cold twins.

Stage resolution is layered, not repeated: the engine plans only when given
``stage="auto"`` (or another directive string).  A resolved :class:`Stage`
or :class:`StageSetPlan` — e.g. from :func:`repro.analytics.query.query`,
which already planned the group — is executed as-is; infeasible explicit
stages still raise at trace time from the ops themselves.
"""
from __future__ import annotations
from collections.abc import Mapping, Sequence

from collections import OrderedDict

import jax
import jax.numpy as jnp

from repro.core import (Compressed, Encoded, Stage, batch_stack, layout_key,
                        oplib)
from repro.core import region as region_mod

from .planner import CostModel, StageSetPlan, plan_stages

Field = Compressed | Encoded

StageLike = Stage | str | int | StageSetPlan | Mapping[str, Stage]


def batch_key(first: Field, ops: str | Sequence[str], stage: Stage,
              axis: int = 0, n_components: int = 1, batch: int = 1,
              region=None, seed_sig: tuple | None = None) -> tuple:
    """Static signature of one compiled batched-analytics program.

    The batch size is part of the key: stacking happens *inside* the jitted
    program (one dispatch for stack + op set, and XLA elides copies the ops
    never read — e.g. residuals under a stage-① metadata mean), so the
    program arity depends on it.  The (normalized) region is static too: it
    decides the gathered block set and every output shape.  The op set is
    canonically ordered — the key is order-insensitive.  ``seed_sig``
    (:meth:`repro.store.MaterializedStage.sig`) distinguishes store-seeded
    programs — they take the resident intermediates as *inputs* and contain
    no reconstruction — from cold ones.
    """
    if region is not None:
        region = region_mod.normalize_region(region, first.shape)
    names = oplib.canonical_ops(ops)
    # the kernel backend mode is a trace-time input: fused-vs-XLA selection
    # (and the Encoded payload decode path) happens while tracing, so a
    # program compiled under one mode must not serve another
    return layout_key(first) + (names, Stage(stage), axis, n_components,
                                batch, region, seed_sig, oplib.kernel_sig())


class BatchedAnalytics:
    """Executes one homomorphic op set over a batch of same-layout fields.

    One instance owns one jit cache; module-level :data:`default_engine`
    is shared by :func:`repro.analytics.query.query` and the serve frontend.

    ``bucket_batches`` pads each batch to the next power of two (repeating
    the last field; padded results are sliced off) so a serving queue with
    fluctuating depth compiles O(log max_batch) programs per op set instead
    of one per distinct length.  The cache is LRU-bounded by ``cache_limit``.
    """

    def __init__(self, cost_model: CostModel | None = None, *,
                 bucket_batches: bool = True, cache_limit: int = 128):
        self.cost_model = cost_model
        self.bucket_batches = bucket_batches
        self.cache_limit = cache_limit
        self._jitted: OrderedDict[tuple, object] = OrderedDict()

    @staticmethod
    def _bucket(n: int) -> int:
        return 1 << (n - 1).bit_length()

    # -- compiled-program cache -------------------------------------------
    def _compiled(self, key: tuple, ops: tuple[str, ...], stage: Stage,
                  axis: int, n_components: int, batch: int, region=None,
                  seeded: bool = False):
        fn = self._jitted.get(key)
        if fn is not None:
            self._jitted.move_to_end(key)
            return fn

        def stack_seeds(seeds):
            return jax.tree.map(lambda *xs: jnp.stack(xs), *seeds)

        if oplib.is_vector_ops(ops):
            def run(*flat, _ops=ops, _stage=stage, _b=batch,
                    _nc=n_components, _r=region, _axis=axis):
                comps = [batch_stack(flat[i * _b:(i + 1) * _b])
                         for i in range(_nc)]
                if seeded:  # trailing args: seeds, component-major like fields
                    sc = [stack_seeds(flat[(_nc + i) * _b:(_nc + i + 1) * _b])
                          for i in range(_nc)]
                    return jax.vmap(lambda *args: oplib.compute(
                        list(args[:_nc]), _ops, _stage, axis=_axis, region=_r,
                        seed=list(args[_nc:])))(*comps, *sc)
                return jax.vmap(lambda *cs: oplib.compute(
                    list(cs), _ops, _stage, axis=_axis, region=_r))(*comps)
        else:
            def run(*flat, _ops=ops, _stage=stage, _b=batch, _r=region,
                    _axis=axis):
                stacked = batch_stack(flat[:_b])
                if seeded:
                    sstack = stack_seeds(flat[_b:])
                    return jax.vmap(lambda c, m: oplib.compute(
                        c, _ops, _stage, axis=_axis, region=_r,
                        seed=m))(stacked, sstack)
                return jax.vmap(lambda c: oplib.compute(
                    c, _ops, _stage, axis=_axis, region=_r))(stacked)

        fn = jax.jit(run)
        self._jitted[key] = fn
        while len(self._jitted) > self.cache_limit:
            self._jitted.popitem(last=False)
        return fn

    @property
    def cache_size(self) -> int:
        return len(self._jitted)

    def _cache_put(self, key: tuple, fn) -> None:
        self._jitted[key] = fn
        while len(self._jitted) > self.cache_limit:
            self._jitted.popitem(last=False)

    # -- temporal (streaming) programs --------------------------------------
    def summarize(self, slabs: Sequence[Field], stage: Stage, *,
                  region=None):
        """Per-slab temporal summaries, batched: one compiled program per
        ``(slab layout, stage, region, padded batch)``.

        The key never includes the stream's total slab count or the slab
        index — every append of a same-layout slab reuses the same program,
        which is what keeps streaming ingest retrace-free
        (``repro.stream``, DESIGN.md §9).  Returns a
        :class:`~repro.core.oplib.TemporalSummary` whose leaves carry a
        leading batch axis (``len(slabs)``); merging is the caller's job —
        summaries are order-sensitive (``last2``), and padding repeats the
        last slab, so a blind in-program reduce would double-count it.
        """
        if not slabs:
            raise ValueError("empty slab batch")
        first = slabs[0]
        stage = Stage(stage)
        norm = (region_mod.normalize_region(region, first.shape[1:])
                if region is not None else None)
        b = len(slabs)
        padded = list(slabs)
        if self.bucket_batches:
            padded += [slabs[-1]] * (self._bucket(b) - b)
        key = layout_key(first) + ("__temporal_summary__", stage, norm,
                                   len(padded), oplib.kernel_sig())
        fn = self._jitted.get(key)
        fresh = fn is None
        if fn is None:
            def run(*flat, _stage=stage, _r=norm, _b=len(padded)):
                stacked = batch_stack(flat[:_b])
                return jax.vmap(lambda c: oplib.summarize_slab(
                    c, _stage, region=_r))(stacked)

            fn = jax.jit(run)
            self._cache_put(key, fn)
        else:
            self._jitted.move_to_end(key)
        try:
            out = fn(*padded)
        except Exception:
            if fresh:  # infeasible stage raises at trace: don't cache it
                self._jitted.pop(key, None)
            raise
        if len(padded) != b:
            out = jax.tree.map(lambda x: x[:b], out)
        return out

    def merge_summaries(self, a, b):
        """Jitted pairwise summary merge — ONE program per summary
        signature, reused for every append and every fold step, so merging
        a K-slab stream never retraces as K grows."""
        key = ("__temporal_merge__", a.sig(), b.sig())
        fn = self._jitted.get(key)
        if fn is None:
            fn = jax.jit(oplib.merge_summaries)
            self._cache_put(key, fn)
        else:
            self._jitted.move_to_end(key)
        return fn(a, b)

    def run_temporal(self, ops: str | Sequence[str], summary, eps):
        """Temporal op postludes on one merged summary: one compiled
        program per (canonical op set, summary signature) — independent of
        how many slabs the summary merged, so querying a growing stream
        compiles exactly once."""
        names = oplib.canonical_ops(ops)
        if not oplib.is_temporal_ops(names):
            raise ValueError(f"{names} is not a temporal op set")
        key = ("__temporal_post__", names, summary.sig())
        fn = self._jitted.get(key)
        if fn is None:
            fn = jax.jit(lambda s, e, _names=names:
                         oplib.temporal_postlude(_names, s, e))
            self._cache_put(key, fn)
        else:
            self._jitted.move_to_end(key)
        return fn(summary, eps)

    # -- expression DAGs ----------------------------------------------------
    def run_expr(self, program, bindings: Sequence, stages: Sequence[Stage],
                 *, region=None, seeds: Sequence | None = None,
                 precomputed: Mapping[str, object] | None = None):
        """Execute one analyzed expression DAG as a single compiled program.

        ``bindings`` holds one entry per leaf slot — a field, a component
        tuple (vector bundles), or ``None`` for temporal slots whose op
        values arrive through ``precomputed`` (keyed by canonical node
        serial; computed outside the trace so streams never enter the jit).
        ``stages`` is the joint per-component plan
        (:class:`~repro.analytics.planner.ExprPlan`); ``seeds`` optionally
        store-seeds individual slots.  The cache key is the program's
        structural hash plus every static input signature, so two
        structurally-identical DAGs over same-layout fields share one
        compiled program regardless of which concrete arrays they bind.
        """
        from repro.core import expr as expr_mod

        precomputed = dict(precomputed or {})
        seeds = list(seeds) if seeds is not None else [None] * len(bindings)
        if len(seeds) != len(bindings):
            raise ValueError(f"{len(seeds)} seeds for {len(bindings)} slots")

        def slot_layout(b):
            if b is None:
                return None
            if isinstance(b, tuple):
                return tuple(layout_key(c) for c in b)
            return layout_key(b)

        def slot_region(b):
            if b is None or region is None:
                return None
            f = b[0] if isinstance(b, tuple) else b
            return region_mod.normalize_region(region, f.shape)

        def slot_seed_sig(s):
            if s is None:
                return None
            if isinstance(s, tuple):
                return tuple(x.sig() for x in s)
            return s.sig()

        pre_keys = tuple(sorted(precomputed))
        pre_sig = tuple((k, jnp.shape(precomputed[k]),
                         str(jnp.result_type(precomputed[k])))
                        for k in pre_keys)
        key = ("__expr__", program.key,
               tuple(slot_layout(b) for b in bindings),
               tuple(Stage(s) for s in stages),
               tuple(slot_region(b) for b in bindings),
               tuple(slot_seed_sig(s) for s in seeds), pre_sig,
               oplib.kernel_sig())
        fn = self._jitted.get(key)
        fresh = fn is None
        if fn is None:
            def run(binds, sds, pre_vals, _stages=tuple(stages), _r=region):
                return expr_mod.lower(program, binds, _stages, region=_r,
                                      seeds=sds,
                                      precomputed=dict(zip(pre_keys,
                                                           pre_vals)))

            fn = jax.jit(run)
            self._cache_put(key, fn)
        else:
            self._jitted.move_to_end(key)
        try:
            return fn(list(bindings), seeds,
                      [precomputed[k] for k in pre_keys])
        except Exception:
            if fresh:  # infeasible stage raises at trace: don't cache it
                self._jitted.pop(key, None)
            raise

    # -- stage resolution ---------------------------------------------------
    def _resolve(self, scheme, names: tuple[str, ...], stage: StageLike,
                 region, field, axis: int) -> StageSetPlan:
        """Plan only when asked to: a resolved Stage / StageSetPlan / per-op
        mapping from an upper layer is executed as-is (no double planning)."""
        if isinstance(stage, StageSetPlan):
            return stage
        if isinstance(stage, Stage):
            return StageSetPlan(names, tuple((op, stage) for op in names),
                                stage)
        if isinstance(stage, Mapping):
            stages = tuple((op, Stage(stage[op])) for op in names)
            resolved = {s for _, s in stages}
            fused = resolved.pop() if len(resolved) == 1 else None
            return StageSetPlan(names, stages, fused)
        return plan_stages(scheme, names, stage, self.cost_model,
                           region=region, field=field, axis=axis)

    # -- execution ---------------------------------------------------------
    def run(self, fields: Sequence, ops: str | Sequence[str],
            stage: StageLike = "auto", *, axis: int = 0, region=None,
            seeds: Sequence | None = None):
        """Run an op (or fused op set) over ``fields`` in jitted vmapped calls.

        ``fields`` is a sequence of same-layout :class:`Compressed` /
        :class:`Encoded` fields — or, for vector op sets
        (``divergence``/``curl``), a sequence of equal-length component
        tuples.  A single op name returns the batched result (leading axis =
        ``len(fields)``); an op *set* returns ``{op: batched result}`` from
        one compiled program per fused plan (falling back to one program per
        op when the plan is unfused).  ``curl`` in 3-D and ``gradient``
        return a tuple of batched components, matching the unbatched ops.
        ``region`` restricts every field to the same window (same-layout
        fields share the block geometry, so one static region plan serves
        the whole batch).

        ``seeds`` optionally supplies one store-resident
        :class:`~repro.store.MaterializedStage` per field (per component
        tuple for vector sets) matching the resolved fused stage: the
        compiled program then takes the intermediates as inputs and skips
        the stage reconstruction entirely.  Seeds require a fused plan (an
        unfused fallback re-plans per op at stages the seeds don't match).
        """
        single = isinstance(ops, str)
        names = oplib.canonical_ops(ops)
        if not fields:
            raise ValueError("empty batch")

        vector = oplib.is_vector_ops(names)
        if vector:
            n_comp = len(fields[0])
            if any(len(f) != n_comp for f in fields):
                raise ValueError("all vector fields must have the same number "
                                 "of components")
            first = fields[0][0]
        else:
            n_comp = 1
            first = fields[0]
        d_axis = axis if any(oplib.OPS[n].needs_axis for n in names) else 0

        plan = self._resolve(first.scheme, names, stage, region, first, d_axis)
        if plan.fused is None:
            out = {op: self.run(fields, op, plan.stage_of(op),
                                axis=axis, region=region)
                   for op in names}
            return out[names[0]] if single else out

        seed_sig = None
        if seeds is not None:
            if len(seeds) != len(fields):
                raise ValueError(
                    f"{len(seeds)} seeds for {len(fields)} fields")
            # per-component signatures may differ (per-axis band closures);
            # across the batch each component's seeds must agree to stack
            per_comp = (tuple(zip(*seeds)) if vector else (tuple(seeds),))
            comp_sigs = []
            for comp_seeds in per_comp:
                sigs = {s.sig() for s in comp_seeds}
                if len(sigs) != 1:
                    raise ValueError(
                        f"seeds must share one layout signature per "
                        f"component, got {sigs}")
                comp_sigs.append(sigs.pop())
                # the seed owns the stage-serving rule (③ serves ④, ...)
                if not comp_seeds[0].serves(plan.fused):
                    raise ValueError(
                        f"seeds materialized at stage "
                        f"{Stage(comp_seeds[0].stage).name} cannot seed a "
                        f"stage-{plan.fused.name} plan")
            seed_sig = tuple(comp_sigs)

        b = len(fields)
        padded = list(fields)
        padded_seeds = list(seeds) if seeds is not None else None
        if self.bucket_batches:
            pad = self._bucket(b) - b
            padded += [fields[-1]] * pad
            if padded_seeds is not None:
                padded_seeds += [padded_seeds[-1]] * pad
        key = batch_key(first, names, plan.fused, d_axis, n_comp,
                        len(padded), region, seed_sig)
        fresh = key not in self._jitted
        fn = self._compiled(key, names, plan.fused, d_axis, n_comp,
                            len(padded), region, seeded=seeds is not None)
        if vector:
            # component-major flat args: (f0[c], f1[c], ...) for each c
            flat = tuple(f[i] for i in range(n_comp) for f in padded)
            if padded_seeds is not None:
                flat += tuple(s[i] for i in range(n_comp)
                              for s in padded_seeds)
        else:
            flat = tuple(padded)
            if padded_seeds is not None:
                flat += tuple(padded_seeds)
        try:
            out = fn(*flat)
        except Exception:
            # an infeasible explicit stage raises at first trace; don't leave
            # a permanently-raising program in the cache (but keep warm
            # entries through transient runtime failures)
            if fresh:
                self._jitted.pop(key, None)
            raise
        if len(padded) != b:
            out = jax.tree.map(lambda x: x[:b], out)
        return out[names[0]] if single else out


#: process-wide engine (shared jit cache) used by the query front-end.
default_engine = BatchedAnalytics()
