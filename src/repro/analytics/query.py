"""Query front-end: analytics over arbitrary collections of compressed fields.

``query`` accepts any mix of layouts (different datasets, shapes, schemes)
and a single op or an op *set*, groups the fields by their static layout
signature, plans the execution stage(s) per group — ``stage="auto"`` fuses
the set onto one shared stage over the feasible intersection
(:func:`repro.analytics.planner.plan_stages`) — runs one batched vmap call
per (group, fused plan) through the shared :class:`BatchedAnalytics` engine,
and scatters results back into input order.  The engine receives the
*resolved* plan, so stages are planned exactly once per group.

With a :class:`repro.store.FieldStore` attached (``store=``), entries of
``fields`` may be string ids (components too, for vector ops).  Id-resolved
fields are served *through the store*: planning sees which stages are
already materialized (their reconstruction term drops, so ``stage="auto"``
can flip to a resident stage), and the group's compiled program is seeded
with the resident intermediates — a cache hit pays only the op postludes.
A miss materializes through the store (one reconstruction per field
lifetime, LRU/byte-budget permitting).  Results are bit-identical to the
storeless path at the same stage.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Sequence, Tuple, Union

from repro.core import Compressed, Encoded, Stage, layout_key, oplib

from .engine import BatchedAnalytics, default_engine
from .planner import CostModel, plan_stages

Field = Union[Compressed, Encoded]
FieldOrVector = Union[Field, Sequence[Field]]


@dataclasses.dataclass
class QueryResult:
    """Per-field results in input order, plus the plan that produced them.

    For a single op, ``values[i]`` is that field's result and ``stages[i]``
    its execution stage; for an op set, both are dicts keyed by op name.
    ``store_hits``/``store_misses`` count materialization-cache lookups the
    query made (0 when no store was involved).
    """

    values: List                   # result (or {op: result}) per input
    stages: List                   # execution stage(s) per input
    op: Union[str, Tuple[str, ...]]
    n_batches: int                 # number of field groups (layout batches)
    n_dispatches: int              # jitted compiled calls actually issued
    store_hits: int = 0            # materializations served from cache
    store_misses: int = 0          # materializations built on demand

    def __iter__(self):
        return iter(self.values)

    def __len__(self):
        return len(self.values)


def _group_signature(item: FieldOrVector, vector: bool) -> Tuple:
    if vector:
        return tuple(layout_key(c) for c in item)
    if hasattr(item, "layout_sig"):  # TemporalField (repro.stream)
        return item.layout_sig()
    return layout_key(item)


def _unbatch(batched, i: int):
    """Extract item ``i`` of a batched result (dicts per op-set results,
    tuples per component results)."""
    if isinstance(batched, dict):
        return {k: _unbatch(v, i) for k, v in batched.items()}
    if isinstance(batched, tuple):
        return tuple(b[i] for b in batched)
    return batched[i]


def _store_get(store, fid: str) -> Field:
    if store is None:
        raise ValueError(
            f"field id {fid!r} given but no store= attached to the query")
    return store.get(fid)


def _resolve_item(item, store, vector):
    """Resolve one ``fields`` entry: string ids -> store fields.

    Returns ``(resolved_item, ids)`` where ``ids`` is the field id (or the
    per-component id tuple) when the *whole* item is store-backed, else
    ``None`` — only fully id-resolved items are seedable (a raw array has no
    cache identity).
    """
    if vector:
        if isinstance(item, str):
            raise TypeError(
                f"vector ops take one field (or id) per component; got the "
                f"bare id {item!r} — pass a tuple of component ids instead")
        comps, ids = [], []
        for c in item:
            if isinstance(c, str):
                comps.append(_store_get(store, c))
                ids.append(c)
            else:
                comps.append(c)
                ids.append(None)
        named = [i for i in ids if i is not None]
        if len(set(named)) != len(named):
            # a vector field's components are distinct physical quantities;
            # repeating an id is a malformed request, and rejecting it here
            # keeps serve-side isolation (only this request errors)
            raise ValueError(
                f"duplicate field ids in vector components: {tuple(ids)}")
        all_ids = all(i is not None for i in ids)
        return tuple(comps), (tuple(ids) if all_ids else None)
    if isinstance(item, str):
        return _store_get(store, item), item
    return item, None


def query(fields: Sequence[FieldOrVector], op: Union[str, Sequence[str]],
          stage: Union[Stage, str, int] = "auto", *, axis: int = 0,
          region=None,
          cost_model: Optional[CostModel] = None,
          engine: Optional[BatchedAnalytics] = None,
          store=None) -> QueryResult:
    """Run one analytical operation — or a fused op set — over many fields.

    Parameters
    ----------
    fields:
        For single-field ops (``mean``/``std``/``derivative``/``gradient``/
        ``laplacian``): a sequence of :class:`Compressed`/:class:`Encoded`
        fields.  For vector ops (``divergence``/``curl``): a sequence of
        vector fields, each a tuple of component fields (one per axis).
        With ``store=``, any field (or component) may instead be a string
        id registered in the store.
    op:
        One op name from :data:`repro.analytics.OPS`, or a sequence of names
        (single arity per set).  An op set shares one stage reconstruction:
        ``query(fields, ["mean", "std", "laplacian"])`` issues one batched
        compiled call per layout group and yields ``{op: value}`` per field,
        each value bit-identical to the corresponding single-op query.
    stage:
        ``"auto"`` (joint cheapest feasible stage per group, never one that
        raises :class:`~repro.core.UnsupportedStageError`), or an explicit
        :class:`Stage` / stage name validated against the feasibility matrix
        for every op in the set.
    axis:
        Differentiation axis for ``op="derivative"``.
    region:
        Optional per-axis window (``None`` / ``slice`` / ``(start, stop)``
        per axis) applied to every field: only the covering blocks are
        decoded and the result is the op over the window
        (``repro.core.region``).  Region geometry feeds stage planning —
        stage ① needs block-aligned windows, and calibrated costs scale by
        each stage's closure size.
    store:
        Optional :class:`repro.store.FieldStore`.  Resolves string field
        ids, makes planning cache-aware (a store-resident stage is priced
        without its reconstruction term), and seeds the engine's compiled
        programs from resident materializations — building them on a miss
        so the next query hits.
    """
    single = isinstance(op, str)
    names = oplib.canonical_ops(op)
    if oplib.is_temporal_ops(names):
        # temporal op sets run over appended streams: same query() surface,
        # streaming execution path (slab-count-stable compiled programs)
        from repro.stream.query import query_temporal
        return query_temporal(fields, op, stage, axis=axis, region=region,
                              cost_model=cost_model, engine=engine,
                              store=store)
    vector = oplib.is_vector_ops(names)
    if engine is None:
        engine = default_engine
    d_axis = axis if any(oplib.OPS[n].needs_axis for n in names) else 0

    resolved: List = []
    ids: List = []
    for item in fields:
        r, fid = _resolve_item(item, store, vector)
        for c in (r if vector else (r,)):
            if hasattr(c, "layout_sig"):  # TemporalField (repro.stream)
                raise TypeError(
                    f"spatial op set {names} takes Compressed/Encoded "
                    "fields; a temporal field answers temporal ops "
                    f"({', '.join(oplib.TEMPORAL_OPS)}) instead")
        resolved.append(r)
        ids.append(fid)

    hits0, misses0 = ((store.stats.hits, store.stats.misses)
                      if store is not None else (0, 0))

    # group by static layout signature (store-backed items separately: only
    # they carry the cache identity seeding needs), preserving input order
    groups: Dict[Tuple, List[int]] = {}
    for i, item in enumerate(resolved):
        sig = (_group_signature(item, vector), ids[i] is not None)
        groups.setdefault(sig, []).append(i)

    values: List = [None] * len(fields)
    stages: List = [None] * len(fields)
    n_dispatches = 0
    for (_, store_backed), indices in groups.items():
        group = [resolved[i] for i in indices]
        first = group[0][0] if vector else group[0]
        cached = None
        if store_backed:
            sets = [store.cached_stages(ids[i], names, region=region,
                                        axis=d_axis) for i in indices]
            cached = frozenset.intersection(*sets)
        plan = plan_stages(first.scheme, names, stage,
                           cost_model or engine.cost_model,
                           region=region, field=first, axis=d_axis,
                           cached=cached)
        seeds = None
        if (store_backed and plan.fused is not None
                and plan.fused != Stage.M):
            s = plan.fused
            if vector:
                closures = oplib.component_closures(
                    names, [c.scheme for c in group[0]], s)
                seeds = [tuple(store.seed(fid, s, region=region, closure=cl)
                               for fid, cl in zip(ids[i], closures))
                         for i in indices]
                flat = [m for item in seeds for m in item]
            else:
                cl = oplib.set_closure(names, first.scheme, s, d_axis)
                seeds = [store.seed(ids[i], s, region=region, closure=cl)
                         for i in indices]
                flat = seeds
            if any(m is None for m in flat):
                # some cell can never be retained under the byte budget:
                # re-materializing it every call would make the store a
                # net loss, so the whole group runs unseeded
                seeds = None
        batched = engine.run(group, op if single else names, plan,
                             axis=axis, region=region, seeds=seeds)
        n_dispatches += plan.n_dispatches
        for j, i in enumerate(indices):
            values[i] = _unbatch(batched, j)
            # fresh dict per field: callers may hold/mutate their own copy
            stages[i] = (plan.stage_of(names[0]) if single
                         else dict(plan.stages))
    store_hits = store_misses = 0
    if store is not None:
        store_hits = store.stats.hits - hits0
        store_misses = store.stats.misses - misses0
    return QueryResult(values=values, stages=stages,
                       op=op if single else names,
                       n_batches=len(groups), n_dispatches=n_dispatches,
                       store_hits=store_hits, store_misses=store_misses)
