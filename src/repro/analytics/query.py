"""Query front-end: analytics over arbitrary collections of compressed fields.

``query`` accepts any mix of layouts (different datasets, shapes, schemes)
and a single op or an op *set*, groups the fields by their static layout
signature, plans the execution stage(s) per group — ``stage="auto"`` fuses
the set onto one shared stage over the feasible intersection
(:func:`repro.analytics.planner.plan_stages`) — runs one batched vmap call
per (group, fused plan) through the shared :class:`BatchedAnalytics` engine,
and scatters results back into input order.  The engine receives the
*resolved* plan, so stages are planned exactly once per group.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Sequence, Tuple, Union

from repro.core import Compressed, Encoded, Stage, layout_key, oplib

from .engine import BatchedAnalytics, default_engine
from .planner import CostModel, plan_stages

Field = Union[Compressed, Encoded]
FieldOrVector = Union[Field, Sequence[Field]]


@dataclasses.dataclass
class QueryResult:
    """Per-field results in input order, plus the plan that produced them.

    For a single op, ``values[i]`` is that field's result and ``stages[i]``
    its execution stage; for an op set, both are dicts keyed by op name.
    """

    values: List                   # result (or {op: result}) per input
    stages: List                   # execution stage(s) per input
    op: Union[str, Tuple[str, ...]]
    n_batches: int                 # number of field groups (layout batches)
    n_dispatches: int              # jitted compiled calls actually issued

    def __iter__(self):
        return iter(self.values)

    def __len__(self):
        return len(self.values)


def _group_signature(item: FieldOrVector, vector: bool) -> Tuple:
    if vector:
        return tuple(layout_key(c) for c in item)
    return layout_key(item)


def _unbatch(batched, i: int):
    """Extract item ``i`` of a batched result (dicts per op-set results,
    tuples per component results)."""
    if isinstance(batched, dict):
        return {k: _unbatch(v, i) for k, v in batched.items()}
    if isinstance(batched, tuple):
        return tuple(b[i] for b in batched)
    return batched[i]


def query(fields: Sequence[FieldOrVector], op: Union[str, Sequence[str]],
          stage: Union[Stage, str, int] = "auto", *, axis: int = 0,
          region=None,
          cost_model: Optional[CostModel] = None,
          engine: Optional[BatchedAnalytics] = None) -> QueryResult:
    """Run one analytical operation — or a fused op set — over many fields.

    Parameters
    ----------
    fields:
        For single-field ops (``mean``/``std``/``derivative``/``gradient``/
        ``laplacian``): a sequence of :class:`Compressed`/:class:`Encoded`
        fields.  For vector ops (``divergence``/``curl``): a sequence of
        vector fields, each a tuple of component fields (one per axis).
    op:
        One op name from :data:`repro.analytics.OPS`, or a sequence of names
        (single arity per set).  An op set shares one stage reconstruction:
        ``query(fields, ["mean", "std", "laplacian"])`` issues one batched
        compiled call per layout group and yields ``{op: value}`` per field,
        each value bit-identical to the corresponding single-op query.
    stage:
        ``"auto"`` (joint cheapest feasible stage per group, never one that
        raises :class:`~repro.core.UnsupportedStageError`), or an explicit
        :class:`Stage` / stage name validated against the feasibility matrix
        for every op in the set.
    axis:
        Differentiation axis for ``op="derivative"``.
    region:
        Optional per-axis window (``None`` / ``slice`` / ``(start, stop)``
        per axis) applied to every field: only the covering blocks are
        decoded and the result is the op over the window
        (``repro.core.region``).  Region geometry feeds stage planning —
        stage ① needs block-aligned windows, and calibrated costs scale by
        each stage's closure size.
    """
    single = isinstance(op, str)
    names = oplib.canonical_ops(op)
    vector = oplib.is_vector_ops(names)
    if engine is None:
        engine = default_engine
    d_axis = axis if any(oplib.OPS[n].needs_axis for n in names) else 0

    # group by static layout signature, preserving input order within groups
    groups: Dict[Tuple, List[int]] = {}
    for i, item in enumerate(fields):
        groups.setdefault(_group_signature(item, vector), []).append(i)

    values: List = [None] * len(fields)
    stages: List = [None] * len(fields)
    n_dispatches = 0
    for indices in groups.values():
        group = [fields[i] for i in indices]
        first = group[0][0] if vector else group[0]
        plan = plan_stages(first.scheme, names, stage,
                           cost_model or engine.cost_model,
                           region=region, field=first, axis=d_axis)
        batched = engine.run(group, op if single else names, plan,
                             axis=axis, region=region)
        n_dispatches += plan.n_dispatches
        for j, i in enumerate(indices):
            values[i] = _unbatch(batched, j)
            # fresh dict per field: callers may hold/mutate their own copy
            stages[i] = (plan.stage_of(names[0]) if single
                         else dict(plan.stages))
    return QueryResult(values=values, stages=stages,
                       op=op if single else names,
                       n_batches=len(groups), n_dispatches=n_dispatches)
