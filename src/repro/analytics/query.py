"""Query front-end: analytics over arbitrary collections of compressed fields.

``query`` accepts any mix of layouts (different datasets, shapes, schemes)
and a single op or an op *set*, groups the fields by their static layout
signature, plans the execution stage(s) per group — ``stage="auto"`` fuses
the set onto one shared stage over the feasible intersection
(:func:`repro.analytics.planner.plan_stages`) — runs one batched vmap call
per (group, fused plan) through the shared :class:`BatchedAnalytics` engine,
and scatters results back into input order.  The engine receives the
*resolved* plan, so stages are planned exactly once per group.

With a :class:`repro.store.FieldStore` attached (``store=``), entries of
``fields`` may be string ids (components too, for vector ops).  Id-resolved
fields are served *through the store*: planning sees which stages are
already materialized (their reconstruction term drops, so ``stage="auto"``
can flip to a resident stage), and the group's compiled program is seeded
with the resident intermediates — a cache hit pays only the op postludes.
A miss materializes through the store (one reconstruction per field
lifetime, LRU/byte-budget permitting).  Results are bit-identical to the
storeless path at the same stage.
"""
from __future__ import annotations
from collections.abc import Sequence

import dataclasses
import warnings

from repro.core import Compressed, Encoded, Stage, layout_key, oplib
from repro.core import expr as expr_mod

from .engine import BatchedAnalytics, default_engine
from .planner import CostModel, plan_expr, plan_stages

Field = Compressed | Encoded
FieldOrVector = Field | Sequence[Field]


@dataclasses.dataclass
class QueryResult:
    """Per-field results in input order, plus the plan that produced them.

    For a single op, ``values[i]`` is that field's result and ``stages[i]``
    its execution stage; for an op set, both are dicts keyed by op name.
    ``store_hits``/``store_misses`` count materialization-cache lookups the
    query made (0 when no store was involved).
    """

    values: list                   # result (or {op: result}) per input
    stages: list                   # execution stage(s) per input
    op: str | tuple[str, ...]
    n_batches: int                 # number of field groups (layout batches)
    n_dispatches: int              # jitted compiled calls actually issued
    store_hits: int = 0            # materializations served from cache
    store_misses: int = 0          # materializations built on demand
    exprs: tuple | None = None  # root expressions (expression queries)

    def __iter__(self):
        return iter(self.values)

    def __len__(self):
        return len(self.values)


def _group_signature(item: FieldOrVector, vector: bool) -> tuple:
    if vector:
        return tuple(layout_key(c) for c in item)
    if hasattr(item, "layout_sig"):  # TemporalField (repro.stream)
        return item.layout_sig()
    return layout_key(item)


def _unbatch(batched, i: int):
    """Extract item ``i`` of a batched result (dicts per op-set results,
    tuples per component results)."""
    if isinstance(batched, dict):
        return {k: _unbatch(v, i) for k, v in batched.items()}
    if isinstance(batched, tuple):
        return tuple(b[i] for b in batched)
    return batched[i]


def _store_get(store, fid: str) -> Field:
    if store is None:
        raise ValueError(
            f"field id {fid!r} given but no store= attached to the query")
    return store.get(fid)


def _resolve_item(item, store, vector):
    """Resolve one ``fields`` entry: string ids -> store fields.

    Returns ``(resolved_item, ids)`` where ``ids`` is the field id (or the
    per-component id tuple) when the *whole* item is store-backed, else
    ``None`` — only fully id-resolved items are seedable (a raw array has no
    cache identity).
    """
    if vector:
        if isinstance(item, str):
            raise TypeError(
                f"vector ops take one field (or id) per component; got the "
                f"bare id {item!r} — pass a tuple of component ids instead")
        comps, ids = [], []
        for c in item:
            if isinstance(c, str):
                comps.append(_store_get(store, c))
                ids.append(c)
            else:
                comps.append(c)
                ids.append(None)
        named = [i for i in ids if i is not None]
        if len(set(named)) != len(named):
            # a vector field's components are distinct physical quantities;
            # repeating an id is a malformed request, and rejecting it here
            # keeps serve-side isolation (only this request errors)
            raise ValueError(
                f"duplicate field ids in vector components: {tuple(ids)}")
        all_ids = all(i is not None for i in ids)
        return tuple(comps), (tuple(ids) if all_ids else None)
    if isinstance(item, str):
        return _store_get(store, item), item
    return item, None


def query(fields: Sequence[FieldOrVector] | None = None,
          op: str | Sequence[str] | None = None,
          stage: Stage | str | int = "auto", *, axis: int = 0,
          region=None,
          cost_model: CostModel | None = None,
          engine: BatchedAnalytics | None = None,
          store=None, exprs=None, ops=None) -> QueryResult:
    """Run analytics: expression DAGs (``exprs=``) or a flat op set.

    The expression form is the primary surface: ``exprs`` is one
    :class:`repro.core.expr.Expr` or a sequence of them — cross-field
    derived quantities (vorticity from u and v, ensemble deltas, ...) whose
    leaves are raw fields, component bundles, ``TemporalField`` streams, or
    (with ``store=``) string field ids.  The whole batch compiles into one
    program with exactly one stage-reconstruction prelude per distinct
    leaf; stages are planned jointly per connected component
    (:func:`repro.analytics.planner.plan_expr`), cache-aware when a store
    is attached.  See :func:`_query_exprs` for the result layout.

    The flat spellings — ``query(fields, op="mean")``, ``op=[...]``, and
    the ``ops=[...]`` alias — are **deprecated** shims over the same
    machinery: they stay bit-identical (and keep their grouped-batch
    dispatch accounting) but emit a :class:`DeprecationWarning` pointing at
    the expression form.  Migration: ``query([f1, f2], "mean")`` becomes
    ``query(exprs=[expr.mean(f1), expr.mean(f2)])``.
    """
    if exprs is not None:
        if fields is not None or op is not None or ops is not None:
            raise TypeError(
                "query(exprs=...) is the expression form; do not also pass "
                "fields/op/ops — put the fields inside the expressions")
        return _query_exprs(exprs, stage, region=region,
                            cost_model=cost_model, engine=engine,
                            store=store)
    if op is not None and ops is not None:
        raise TypeError("pass op= or ops=, not both")
    if ops is not None:
        op = ops
    if fields is None or op is None:
        raise TypeError("query() needs exprs=, or the deprecated "
                        "(fields, op) pair")
    warnings.warn(
        "query(fields, op=...) / query(fields, ops=[...]) are deprecated; "
        "build expressions instead: query(exprs=[expr.op_name(f) for f in "
        "fields]) (see repro.core.expr)",
        DeprecationWarning, stacklevel=2)
    return _query_opset(fields, op, stage, axis=axis, region=region,
                        cost_model=cost_model, engine=engine, store=store)


def _query_opset(fields: Sequence[FieldOrVector],
                 op: str | Sequence[str],
                 stage: Stage | str | int = "auto", *, axis: int = 0,
                 region=None,
                 cost_model: CostModel | None = None,
                 engine: BatchedAnalytics | None = None,
                 store=None) -> QueryResult:
    """Run one analytical operation — or a fused op set — over many fields.

    Parameters
    ----------
    fields:
        For single-field ops (``mean``/``std``/``derivative``/``gradient``/
        ``laplacian``): a sequence of :class:`Compressed`/:class:`Encoded`
        fields.  For vector ops (``divergence``/``curl``): a sequence of
        vector fields, each a tuple of component fields (one per axis).
        With ``store=``, any field (or component) may instead be a string
        id registered in the store.
    op:
        One op name from :data:`repro.analytics.OPS`, or a sequence of names
        (single arity per set).  An op set shares one stage reconstruction:
        ``query(fields, ["mean", "std", "laplacian"])`` issues one batched
        compiled call per layout group and yields ``{op: value}`` per field,
        each value bit-identical to the corresponding single-op query.
    stage:
        ``"auto"`` (joint cheapest feasible stage per group, never one that
        raises :class:`~repro.core.UnsupportedStageError`), or an explicit
        :class:`Stage` / stage name validated against the feasibility matrix
        for every op in the set.
    axis:
        Differentiation axis for ``op="derivative"``.
    region:
        Optional per-axis window (``None`` / ``slice`` / ``(start, stop)``
        per axis) applied to every field: only the covering blocks are
        decoded and the result is the op over the window
        (``repro.core.region``).  Region geometry feeds stage planning —
        stage ① needs block-aligned windows, and calibrated costs scale by
        each stage's closure size.
    store:
        Optional :class:`repro.store.FieldStore`.  Resolves string field
        ids, makes planning cache-aware (a store-resident stage is priced
        without its reconstruction term), and seeds the engine's compiled
        programs from resident materializations — building them on a miss
        so the next query hits.
    """
    single = isinstance(op, str)
    names = oplib.canonical_ops(op)
    if oplib.is_temporal_ops(names):
        # temporal op sets run over appended streams: same query() surface,
        # streaming execution path (slab-count-stable compiled programs)
        from repro.stream.query import query_temporal
        return query_temporal(fields, op, stage, axis=axis, region=region,
                              cost_model=cost_model, engine=engine,
                              store=store)
    vector = oplib.is_vector_ops(names)
    if engine is None:
        engine = default_engine
    d_axis = axis if any(oplib.OPS[n].needs_axis for n in names) else 0

    resolved: list = []
    ids: list = []
    for item in fields:
        r, fid = _resolve_item(item, store, vector)
        for c in (r if vector else (r,)):
            if hasattr(c, "layout_sig"):  # TemporalField (repro.stream)
                raise TypeError(
                    f"spatial op set {names} takes Compressed/Encoded "
                    "fields; a temporal field answers temporal ops "
                    f"({', '.join(oplib.TEMPORAL_OPS)}) instead")
        resolved.append(r)
        ids.append(fid)

    hits0, misses0 = ((store.stats.hits, store.stats.misses)
                      if store is not None else (0, 0))

    # group by static layout signature (store-backed items separately: only
    # they carry the cache identity seeding needs), preserving input order
    groups: dict[tuple, list[int]] = {}
    for i, item in enumerate(resolved):
        sig = (_group_signature(item, vector), ids[i] is not None)
        groups.setdefault(sig, []).append(i)

    values: list = [None] * len(fields)
    stages: list = [None] * len(fields)
    n_dispatches = 0
    for (_, store_backed), indices in groups.items():
        group = [resolved[i] for i in indices]
        first = group[0][0] if vector else group[0]
        cached = None
        placement = None
        if store_backed:
            sets = [store.cached_stages(ids[i], names, region=region,
                                        axis=d_axis) for i in indices]
            cached = frozenset.intersection(*sets)
            # a sharded store prices reconstruction as the max over
            # participating shards (repro.shard); single-device stores
            # don't expose placement_of and keep the spatial fraction
            placement_of = getattr(store, "placement_of", None)
            if placement_of is not None:
                fid0 = ids[indices[0]]
                placement = placement_of(fid0 if isinstance(fid0, str)
                                         else fid0[0])
        plan = plan_stages(first.scheme, names, stage,
                           cost_model or engine.cost_model,
                           region=region, field=first, axis=d_axis,
                           cached=cached, placement=placement)
        seeds = None
        if (store_backed and plan.fused is not None
                and plan.fused != Stage.M):
            s = plan.fused
            if vector:
                closures = oplib.component_closures(
                    names, [c.scheme for c in group[0]], s)
                seeds = [tuple(store.seed(fid, s, region=region, closure=cl)
                               for fid, cl in zip(ids[i], closures))
                         for i in indices]
                flat = [m for item in seeds for m in item]
            else:
                cl = oplib.set_closure(names, first.scheme, s, d_axis)
                seeds = [store.seed(ids[i], s, region=region, closure=cl)
                         for i in indices]
                flat = seeds
            if any(m is None for m in flat):
                # some cell can never be retained under the byte budget:
                # re-materializing it every call would make the store a
                # net loss, so the whole group runs unseeded
                seeds = None
        batched = engine.run(group, op if single else names, plan,
                             axis=axis, region=region, seeds=seeds)
        n_dispatches += plan.n_dispatches
        for j, i in enumerate(indices):
            values[i] = _unbatch(batched, j)
            # fresh dict per field: callers may hold/mutate their own copy
            stages[i] = (plan.stage_of(names[0]) if single
                         else dict(plan.stages))
    store_hits = store_misses = 0
    if store is not None:
        store_hits = store.stats.hits - hits0
        store_misses = store.stats.misses - misses0
    return QueryResult(values=values, stages=stages,
                       op=op if single else names,
                       n_batches=len(groups), n_dispatches=n_dispatches,
                       store_hits=store_hits, store_misses=store_misses)


def _resolve_leaf(lf, store):
    """Resolve one leaf slot's source: string ids -> store entries.

    Returns ``(binding, fid)`` where ``fid`` is the slot's cache identity
    (id or per-component id tuple) when *fully* store-backed, else None."""
    src = lf.source
    if isinstance(src, tuple):
        comps, fids = [], []
        for c in src:
            if isinstance(c, str):
                comps.append(_store_get(store, c))
                fids.append(c)
            else:
                comps.append(c)
                fids.append(None)
        all_ids = all(f is not None for f in fids)
        return tuple(comps), (tuple(fids) if all_ids else None)
    if isinstance(src, str):
        return _store_get(store, src), src
    return src, None


def _query_exprs(exprs, stage="auto", *, region=None,
                 cost_model: CostModel | None = None,
                 engine: BatchedAnalytics | None = None,
                 store=None) -> QueryResult:
    """Execute a batch of expression DAGs as one compiled program.

    ``values[i]`` is root ``i``'s result and ``stages[i]`` its component's
    jointly-planned stage; ``op`` is ``"expr"`` and ``exprs`` carries the
    roots.  ``n_dispatches`` counts compiled calls actually issued — one
    for the spatial DAG program (skipped when every root is purely
    temporal), plus the temporal summarize/merge/postlude calls; store
    counters mirror the flat path.  Results are bit-identical to composing
    the corresponding single-op queries at the same stage.
    """
    if engine is None:
        engine = default_engine
    single = isinstance(exprs, expr_mod.Expr)
    program = expr_mod.analyze([exprs] if single else list(exprs))

    stats = getattr(store, "stats", None) if store is not None else None
    hits0, misses0 = (stats.hits, stats.misses) if stats else (0, 0)

    bindings: list = []
    slot_ids: list = []
    for slot, lf in enumerate(program.leaves):
        b, fid = _resolve_leaf(lf, store)
        temporal = program.leaf_is_temporal(slot)
        for c in (b if isinstance(b, tuple) else (b,)):
            if hasattr(c, "layout_sig") != temporal:
                consumers = ", ".join(n for n, _ in
                                      program.leaf_consumers(slot))
                raise TypeError(
                    f"leaf {lf.key} binds a {type(c).__name__} but its "
                    f"consumers ({consumers}) are "
                    f"{'temporal' if temporal else 'spatial'} ops")
        if temporal and not b.slabs:
            raise ValueError("temporal field has no appended slabs"
                             + (f" (id {fid!r})" if fid else ""))
        bindings.append(b)
        slot_ids.append(fid)
    expr_mod.validate_bound(program, bindings, region=region)

    def slot_cached(slot: int) -> frozenset:
        fid = slot_ids[slot]
        if (fid is None or program.leaf_is_temporal(slot)
                or not hasattr(store, "is_resident")):
            return frozenset()
        b = bindings[slot]
        out = set()
        for s in (Stage.P, Stage.Q, Stage.F):
            try:
                if isinstance(b, tuple):
                    cls = expr_mod.vector_closures(
                        program, slot, [c.scheme for c in b], s)
                    ok = all(store.is_resident(f, s, region=region,
                                               closure=cl)
                             for f, cl in zip(fid, cls))
                else:
                    cl = expr_mod.leaf_closure(program, slot, b.scheme, s)
                    ok = store.is_resident(fid, s, region=region, closure=cl)
            except Exception:  # closure undefined at an infeasible stage
                continue
            if ok:
                out.add(s)
        return frozenset(out)

    cached = [slot_cached(s) for s in range(len(program.leaves))]
    plan = plan_expr(program, bindings, stage,
                     cost_model or engine.cost_model,
                     region=region, cached=cached)

    # temporal op nodes: summaries reduce outside the spatial trace (one
    # shared summary per stream slot), values join the DAG via `precomputed`
    n_dispatches = 0
    precomputed: dict[str, object] = {}
    summaries: dict[int, object] = {}
    for node in program.temporal_nodes:
        slot = program.slot_of(node.operand)
        tf = bindings[slot]
        s = plan.stages[program.leaf_component[slot]]
        if slot not in summaries:
            fid = slot_ids[slot]
            if fid is not None:
                if not hasattr(store, "temporal_summary"):
                    raise TypeError(
                        "temporal ids need a StreamFieldStore "
                        "(repro.stream.StreamFieldStore)")
                summaries[slot] = store.temporal_summary(fid, region=region,
                                                         stage=s)
            else:
                from repro.stream.query import _cold_summary
                summaries[slot], n_cold = _cold_summary(tf, s, region,
                                                        engine)
                n_dispatches += n_cold
        out = engine.run_temporal((node.name,), summaries[slot], tf.eps)
        n_dispatches += 1
        precomputed[program.serial(node)] = out[node.name]

    seeds: list = [None] * len(bindings)
    if store is not None and hasattr(store, "seed"):
        for slot in range(len(program.leaves)):
            fid = slot_ids[slot]
            if fid is None or program.leaf_is_temporal(slot):
                continue
            s = plan.stages[program.leaf_component[slot]]
            if s == Stage.M:
                continue  # metadata is always resident in the container
            b = bindings[slot]
            if isinstance(b, tuple):
                cls = expr_mod.vector_closures(
                    program, slot, [c.scheme for c in b], s)
                ms = tuple(store.seed(f, s, region=region, closure=cl)
                           for f, cl in zip(fid, cls))
                seeds[slot] = ms if all(m is not None for m in ms) else None
            else:
                cl = expr_mod.leaf_closure(program, slot, b.scheme, s)
                seeds[slot] = store.seed(fid, s, region=region, closure=cl)

    if all(program.serial(r) in precomputed for r in program.roots):
        out = tuple(precomputed[program.serial(r)] for r in program.roots)
    else:
        jit_bindings = [None if program.leaf_is_temporal(sl) else b
                        for sl, b in enumerate(bindings)]
        out = engine.run_expr(program, jit_bindings, plan.stages,
                              region=region, seeds=seeds,
                              precomputed=precomputed)
        n_dispatches += 1

    store_hits = store_misses = 0
    if stats is not None:
        store_hits = stats.hits - hits0
        store_misses = stats.misses - misses0
    stages = [plan.stages[program.root_component[i]]
              for i in range(len(program.roots))]
    return QueryResult(values=list(out), stages=stages, op="expr",
                       n_batches=1, n_dispatches=n_dispatches,
                       store_hits=store_hits, store_misses=store_misses,
                       exprs=program.roots)
