"""Query front-end: analytics over arbitrary collections of compressed fields.

``query`` accepts any mix of layouts (different datasets, shapes, schemes),
groups the fields by their static layout signature, plans the execution
stage per group (``stage="auto"`` → cheapest feasible per Table I), runs one
batched vmap call per group through the shared :class:`BatchedAnalytics`
engine, and scatters results back into input order.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Sequence, Tuple, Union

import jax

from repro.core import Compressed, Encoded, Stage, layout_key

from .engine import BatchedAnalytics, default_engine
from .planner import MULTIVARIATE, OPS, CostModel, plan_stage

Field = Union[Compressed, Encoded]
FieldOrVector = Union[Field, Sequence[Field]]


@dataclasses.dataclass
class QueryResult:
    """Per-field results in input order, plus the plan that produced them."""

    values: List[jax.Array]        # result per input field / vector tuple
    stages: List[Stage]            # execution stage per input
    op: str
    n_batches: int                 # number of jitted batched calls issued

    def __iter__(self):
        return iter(self.values)

    def __len__(self):
        return len(self.values)


def _group_signature(item: FieldOrVector, op: str) -> Tuple:
    if op in MULTIVARIATE:
        return tuple(layout_key(c) for c in item)
    return layout_key(item)


def _unbatch(batched, i: int):
    """Extract item ``i`` of a batched result (tuple results per component)."""
    if isinstance(batched, tuple):
        return tuple(b[i] for b in batched)
    return batched[i]


def query(fields: Sequence[FieldOrVector], op: str,
          stage: Union[Stage, str, int] = "auto", *, axis: int = 0,
          region=None,
          cost_model: Optional[CostModel] = None,
          engine: Optional[BatchedAnalytics] = None) -> QueryResult:
    """Run one analytical operation over many compressed fields.

    Parameters
    ----------
    fields:
        For ``mean``/``std``/``derivative``/``laplacian``: a sequence of
        :class:`Compressed`/:class:`Encoded` fields.  For ``divergence``/
        ``curl``: a sequence of vector fields, each a tuple of component
        fields (one per spatial axis).
    op:
        One of :data:`repro.analytics.OPS`.
    stage:
        ``"auto"`` (cheapest feasible stage per group, never one that raises
        :class:`~repro.core.UnsupportedStageError`), or an explicit
        :class:`Stage` / stage name validated against the feasibility matrix.
    axis:
        Differentiation axis for ``op="derivative"``.
    region:
        Optional per-axis window (``None`` / ``slice`` / ``(start, stop)``
        per axis) applied to every field: only the covering blocks are
        decoded and the result is the op over the window
        (``repro.core.region``).  Region geometry feeds stage planning —
        stage ① needs block-aligned windows, and calibrated costs scale by
        each stage's closure size.
    """
    if op not in OPS:
        raise ValueError(f"unknown operation {op!r}; expected one of {OPS}")
    if engine is None:
        engine = default_engine

    # group by static layout signature, preserving input order within groups
    groups: Dict[Tuple, List[int]] = {}
    for i, item in enumerate(fields):
        groups.setdefault(_group_signature(item, op), []).append(i)

    values: List = [None] * len(fields)
    stages: List = [None] * len(fields)
    for indices in groups.values():
        group = [fields[i] for i in indices]
        first = group[0][0] if op in MULTIVARIATE else group[0]
        planned = plan_stage(first.scheme, op, stage,
                             cost_model or engine.cost_model,
                             region=region, field=first,
                             axis=axis if op == "derivative" else 0)
        batched = engine.run(group, op, planned, axis=axis, region=region)
        for j, i in enumerate(indices):
            values[i] = _unbatch(batched, j)
            stages[i] = planned
    return QueryResult(values=values, stages=stages, op=op,
                       n_batches=len(groups))
