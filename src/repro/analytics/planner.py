"""Stage planner: the paper's Table I feasibility matrix + cost-based choice.

``FEASIBILITY[(scheme, op)]`` lists the stages the operation is defined at,
cheapest first.  The matrix mirrors — and is pinned by tests to — the actual
raise/no-raise behavior of :mod:`repro.core.homomorphic`:

* ``mean``: stage ① only for the HSZx (block-mean) family, ②③④ for all;
* ``std``: ②③④ (① carries no pointwise information);
* stencils (``derivative``/``laplacian``/``divergence``/``curl``): stage ②
  only for nd schemes (1-D partitioning destroys the spatial layout, §V-B),
  ③④ for all.

``plan_stage`` resolves ``stage="auto"`` to the cheapest feasible stage.  By
default "cheapest" is stage order (①<②<③<④ — monotone in decompression work,
which matches the paper's measurements); a :class:`CostModel` calibrated from
``benchmarks/run.py`` CSV output refines the choice with measured
microseconds per call.

``plan_stages`` plans an *op set* jointly: it picks one shared stage
minimizing the **total** cost over the feasible intersection, so a fused
query pays a single stage reconstruction for every op (DESIGN.md §6).  When
the intersection is empty — or a calibrated model says independent per-op
stages are strictly cheaper even without the shared-decode saving — it falls
back to per-op planning (``StageSetPlan.fused is None``).

Region queries change the plan twice over.  Feasibility: the stage-① mean is
only eps-exact over block-aligned windows, so unaligned regions drop ① from
the feasible set.  Cost: each stage's measured full-field cost scales by the
fraction of the field its region closure touches
(:func:`repro.core.region.closure_fraction`) — per-stage closures differ for
Lorenzo schemes (stage-② derivative bands vs stage-③ prefix hulls), so
``stage="auto"`` can genuinely pick a different stage for a 1% window than
for the full field.
"""
from __future__ import annotations
from collections.abc import Iterable, Mapping, Sequence, Set as AbstractSet

import dataclasses
import json
import os

from repro.core import Scheme, Stage, UnsupportedStageError, oplib
from repro.core import region as region_mod

#: planned operations, in the op registry's canonical order.
OPS: tuple[str, ...] = tuple(oplib.OPS)
#: temporal (time-axis) operations over appended streams (repro.stream).
TEMPORAL: tuple[str, ...] = tuple(oplib.TEMPORAL_OPS)
#: ops that take a sequence of component fields instead of a single field
MULTIVARIATE = frozenset(
    name for name, spec in oplib.OPS.items() if spec.arity == "vector")


def _build_matrix() -> dict[tuple[Scheme, str], tuple[Stage, ...]]:
    """Table I as data, derived from the op registries' own feasibility rows
    (one source of truth: :data:`repro.core.oplib.OPS` plus the temporal
    registry :data:`repro.core.oplib.TEMPORAL_OPS`)."""
    return {(scheme, name): spec.feasible(scheme)
            for scheme in Scheme
            for name, spec in oplib._ALL_OPS.items()}


#: Table I: (scheme, op) -> stages the op is defined at, cheapest first.
FEASIBILITY: dict[tuple[Scheme, str], tuple[Stage, ...]] = _build_matrix()


def as_stage(stage: Stage | str | int) -> Stage:
    """Coerce ``Stage`` / int / name ("M", "p", ...) to a :class:`Stage`."""
    if isinstance(stage, str):
        try:
            return Stage[stage.upper()]
        except KeyError:
            raise ValueError(f"unknown stage {stage!r}; expected one of "
                             f"{[s.name for s in Stage]} or 'auto'") from None
    return Stage(stage)


def feasible_stages(scheme: Scheme, op: str) -> tuple[Stage, ...]:
    """Stages ``op`` is defined at for ``scheme``, cheapest first."""
    try:
        return FEASIBILITY[(Scheme(scheme), op)]
    except KeyError:
        spec = oplib._ALL_OPS.get(op)
        if spec is None:
            raise ValueError(
                f"unknown operation {op!r}; expected one of "
                f"{tuple(oplib._ALL_OPS)}") from None
        # registered after the matrix was derived (oplib.register_op):
        # resolve straight from the spec — same source of truth
        return spec.feasible(Scheme(scheme))


def is_feasible(scheme: Scheme, op: str, stage: Stage) -> bool:
    return Stage(stage) in feasible_stages(scheme, op)


def check_feasible(scheme: Scheme, op: str, stage: Stage) -> Stage:
    """Validate an explicit stage choice with the ops' own error semantics."""
    stage = as_stage(stage)
    if not is_feasible(scheme, op, stage):
        raise UnsupportedStageError(
            f"{op} is not defined at stage {stage.name} for scheme "
            f"{Scheme(scheme).value}; feasible stages: "
            f"{[s.name for s in feasible_stages(scheme, op)]}")
    return stage


def _resident_rank(cached: AbstractSet[Stage]):
    """Stage ranking when costs are unmeasured but residency is known:
    stages needing no reconstruction (cached, or ① — metadata is always
    resident in the container) beat stages that must reconstruct; ties go
    to stage order."""
    resident = set(cached) | {Stage.M}
    return lambda s: (0 if s in resident else 1, int(s))


class CostModel:
    """Per-``(scheme, op, stage)`` cost estimates in microseconds per call,
    plus per-``(scheme, stage)`` *reconstruction* costs used to price
    cache-resident stages.

    Uncalibrated cells fall back to a stage-ordered default (stage index
    scaled to rank *below* any measured cost is wrong — instead the default
    is only used when the whole ``(scheme, op)`` row is unmeasured, so mixed
    calibration never compares measured against made-up numbers).

    A *cached* stage (its materialized intermediate is resident in a
    :class:`repro.store.FieldStore`) drops the reconstruction term: its
    effective cost is ``max(measured - reconstruction, 0)``, with the
    reconstruction calibrated from the ``fig34`` decompression rows.  An
    unmeasured reconstruction falls back to the largest one measured at a
    *lower* stage — reconstruction work is monotone in stage (paper §V),
    so the discount stays conservative and a cached stage never beats a
    measured rival on made-up numbers.
    """

    def __init__(self, table: dict[tuple[Scheme, str, Stage], float] | None = None,
                 recon: dict[tuple[Scheme, Stage], float] | None = None):
        self.table: dict[tuple[Scheme, str, Stage], float] = dict(table or {})
        self._counts: dict[tuple[Scheme, str, Stage], int] = {
            k: 1 for k in self.table}
        self.recon: dict[tuple[Scheme, Stage], float] = dict(recon or {})
        self._recon_counts: dict[tuple[Scheme, Stage], int] = {
            k: 1 for k in self.recon}

    # -- calibration -------------------------------------------------------
    _BENCH_OP_ALIASES = {"deriv": "derivative", "div": "divergence"}
    _BENCH_STAGE_TAGS = {"m": Stage.M, "p": Stage.P, "q": Stage.Q, "f": Stage.F}

    def record(self, scheme: Scheme, op: str, stage: Stage, us: float) -> None:
        key = (Scheme(scheme), op, Stage(stage))
        # true running mean over repeated observations (multiple datasets):
        # order-independent, every observation weighted equally
        n = self._counts.get(key, 0)
        prev = self.table.get(key, 0.0)
        self.table[key] = (prev * n + us) / (n + 1)
        self._counts[key] = n + 1

    def record_reconstruction(self, scheme: Scheme, stage: Stage, us: float) -> None:
        """Record a measured stage-reconstruction (decompression) cost."""
        key = (Scheme(scheme), Stage(stage))
        n = self._recon_counts.get(key, 0)
        prev = self.recon.get(key, 0.0)
        self.recon[key] = (prev * n + us) / (n + 1)
        self._recon_counts[key] = n + 1

    @classmethod
    def from_benchmark_csv(cls, rows: str | Iterable[str]) -> "CostModel":
        """Calibrate from ``benchmarks/run.py`` output.

        Parses the op-throughput rows (``fig58/…``, ``fig910/…``,
        ``fig1112/…``), whose names encode ``…/<op>/<scheme>-<stage_tag>``,
        and the per-stage decompression rows (``fig34/<ds>/<scheme>-<tag>``)
        into the reconstruction table; other rows are ignored.
        """
        model = cls()
        if isinstance(rows, str):
            rows = rows.splitlines()
        for line in rows:
            line = line.strip()
            if not line or line.startswith(("#", "name,")):
                continue
            name, _, rest = line.partition(",")
            us_text = rest.partition(",")[0]
            parts = name.split("/")
            if len(parts) == 3 and parts[0] == "fig34":
                scheme_name, _, tag = parts[2].rpartition("-")
                if tag not in cls._BENCH_STAGE_TAGS:
                    continue
                try:
                    model.record_reconstruction(Scheme(scheme_name),
                                                cls._BENCH_STAGE_TAGS[tag],
                                                float(us_text))
                except ValueError:
                    continue
                continue
            if len(parts) != 4 or parts[0] not in ("fig58", "fig910", "fig1112"):
                continue
            op = cls._BENCH_OP_ALIASES.get(parts[2], parts[2])
            scheme_name, _, tag = parts[3].rpartition("-")
            if op not in OPS or tag not in cls._BENCH_STAGE_TAGS:
                continue
            try:
                scheme = Scheme(scheme_name)
                us = float(us_text)
            except ValueError:
                continue
            model.record(scheme, op, cls._BENCH_STAGE_TAGS[tag], us)
        return model

    # -- persistence (satellite: calibrations must survive the process) ----
    _FORMAT = "hsz-cost-model"

    def save(self, path: str | os.PathLike) -> None:
        """JSON-serialize the full calibration state (cells, reconstruction
        table, observation counts) so CI and serving reuse measured models."""
        def skey(k):
            return (k[0].value,) + tuple(str(p) for p in k[1:])

        payload = {
            "format": self._FORMAT,
            "version": 1,
            "cells": [
                {"scheme": sch.value, "op": op, "stage": st.name,
                 "us": self.table[(sch, op, st)],
                 "count": self._counts.get((sch, op, st), 1)}
                for sch, op, st in sorted(self.table, key=skey)],
            "recon": [
                {"scheme": sch.value, "stage": st.name,
                 "us": self.recon[(sch, st)],
                 "count": self._recon_counts.get((sch, st), 1)}
                for sch, st in sorted(self.recon, key=skey)],
        }
        with open(path, "w") as f:
            json.dump(payload, f, indent=2)
            f.write("\n")

    @classmethod
    def load(cls, path: str | os.PathLike) -> "CostModel":
        """Inverse of :meth:`save`: an exact round-trip, including the
        observation counts, so post-load :meth:`record` calls continue the
        same running means.

        Tolerates JSON written by older versions: entries missing required
        keys (stage, microseconds, ...) — and a missing reconstruction
        table entirely — are skipped with a warning, so the affected cells
        simply fall back to the uncalibrated planning path instead of the
        whole load dying with a ``KeyError``.
        """
        import warnings

        with open(path) as f:
            data = json.load(f)
        if data.get("format") != cls._FORMAT:
            raise ValueError(f"{path}: not a {cls._FORMAT} file")
        if data.get("version") != 1:
            raise ValueError(f"{path}: unsupported version {data.get('version')!r}")
        model = cls()
        skipped = 0
        for cell in data.get("cells", ()):
            try:
                key = (Scheme(cell["scheme"]), str(cell["op"]),
                       Stage[cell["stage"]])
                us = float(cell["us"])
            except (KeyError, ValueError, TypeError):
                skipped += 1
                continue
            model.table[key] = us
            model._counts[key] = int(cell.get("count", 1))
        for cell in data.get("recon", ()):
            try:
                key = (Scheme(cell["scheme"]), Stage[cell["stage"]])
                us = float(cell["us"])
            except (KeyError, ValueError, TypeError):
                skipped += 1
                continue
            model.recon[key] = us
            model._recon_counts[key] = int(cell.get("count", 1))
        if skipped:
            warnings.warn(
                f"{path}: skipped {skipped} malformed cost-model cell(s) "
                "(older save format?); the affected cells plan uncalibrated",
                stacklevel=2)
        return model

    # -- lookup ------------------------------------------------------------
    def reconstruction(self, scheme: Scheme, stage: Stage) -> float | None:
        """Measured reconstruction microseconds for a stage (① is free —
        metadata is always resident)."""
        if Stage(stage) == Stage.M:
            return 0.0
        return self.recon.get((Scheme(scheme), Stage(stage)))

    def cost(self, scheme: Scheme, op: str, stage: Stage, *,
             cached: bool = False) -> float | None:
        base = self.table.get((Scheme(scheme), op, Stage(stage)))
        if base is None or not cached:
            return base
        rec = self.reconstruction(scheme, stage)
        if rec is None:
            # monotone fallback: reconstruction work grows with stage
            # (paper §V), so the largest measurement at a lower stage
            # *under*-estimates this stage's — a conservative discount
            lower = [v for s in Stage if s < Stage(stage)
                     for v in [self.recon.get((Scheme(scheme), s))]
                     if v is not None]
            rec = max(lower) if lower else 0.0
        return max(base - rec, 0.0)

    def cheapest(self, scheme: Scheme, op: str, stages: Sequence[Stage],
                 fractions: Mapping[Stage, float] | None = None,
                 cached: AbstractSet[Stage] | None = None) -> Stage:
        """Cheapest stage; ``fractions`` scale each stage's measured cost by
        the share of the field its region closure touches (1.0 = full
        field); stages in ``cached`` are priced without their reconstruction
        term."""
        cached = frozenset(cached or ())
        costs = {s: self.cost(scheme, op, s, cached=s in cached)
                 for s in stages}
        if any(c is None for c in costs.values()):
            # incomplete row: fall back to stage order rather than mixing
            # measured numbers with fabricated defaults — but residency is
            # hard knowledge, so cached stages still rank first
            return min(stages, key=_resident_rank(cached))
        if fractions is not None:
            costs = {s: c * fractions.get(s, 1.0) for s, c in costs.items()}
        return min(stages, key=lambda s: (costs[s], int(s)))


def plan_stage(scheme: Scheme, op: str,
               stage: Stage | str | int = "auto",
               cost_model: CostModel | None = None, *,
               region=None, field=None, axis: int = 0,
               cached: AbstractSet[Stage] | None = None) -> Stage:
    """Resolve the execution stage for ``op`` on ``scheme``.

    ``stage="auto"`` picks the cheapest feasible stage (never one that would
    raise :class:`UnsupportedStageError`); an explicit stage is validated
    against the feasibility matrix.  With ``region`` (and the queried
    ``field`` for its geometry), stage ① is dropped/rejected for windows that
    are not block-aligned, and calibrated costs scale with each stage's
    region-closure size.  ``cached`` names the stages whose materialized
    intermediates are store-resident: their reconstruction term is dropped,
    so auto planning can pick a *higher* stage than it would cold.
    """
    cached = frozenset(cached or ())
    if stage != "auto":
        stage = check_feasible(scheme, op, stage)
        if (stage == Stage.M and region is not None and field is not None
                and not region_mod.region_aligned(field, region)):
            raise UnsupportedStageError(
                f"stage-1 {op} over a region needs a block-aligned window")
        return stage
    stages = feasible_stages(scheme, op)
    if region is not None and Stage.M in stages:
        aligned = (field is not None
                   and region_mod.region_aligned(field, region))
        if not aligned:
            stages = tuple(s for s in stages if s != Stage.M)
    if cost_model is not None:
        fractions = None
        if region is not None and field is not None:
            fractions = {s: region_mod.closure_fraction(field, op, s, region,
                                                        axis=axis)
                         for s in stages}
        return cost_model.cheapest(scheme, op, stages, fractions, cached)
    if cached:
        # no measured costs, but residency is hard knowledge: a resident
        # stage pays no reconstruction, which is the dominant term (§V)
        return min(stages, key=_resident_rank(cached))
    return stages[0]


@dataclasses.dataclass(frozen=True)
class StageSetPlan:
    """Resolved execution plan for one op set.

    ``fused`` is the single shared stage every op runs at (one stage
    reconstruction for the whole set), or ``None`` when the planner fell
    back to independent per-op stages; ``stages`` maps each op to its
    resolved stage either way.
    """

    ops: tuple[str, ...]
    stages: tuple[tuple[str, Stage], ...]
    fused: Stage | None

    def stage_of(self, op: str) -> Stage:
        return dict(self.stages)[op]

    @property
    def n_dispatches(self) -> int:
        """Compiled calls one engine dispatch of this plan issues."""
        return 1 if self.fused is not None else len(self.ops)


def _max_shard_fraction(field, op: str, stage: Stage, region, axis: int,
                        placement) -> float:
    """Sharded replacement for :func:`repro.core.region.closure_fraction`:
    the *max* over participating shards of each shard's share of the
    stage's decode work — shards reconstruct their owned blocks
    concurrently, so the critical path is the busiest shard, never the sum
    (DESIGN.md §13).  Stage ① touches metadata only (no payload decode), so
    it keeps the spatial fraction."""
    stage = Stage(stage)
    if stage == Stage.M:
        return (1.0 if region is None or field is None
                else region_mod.closure_fraction(field, op, stage, region,
                                                 axis=axis))
    if op in ("divergence", "curl"):
        nd = len(field.shape) if field is not None else 1
        fr = [_max_shard_fraction(field, "derivative", stage, region, a,
                                  placement) for a in range(nd)]
        return sum(fr) / len(fr)
    if region is None or field is None:
        return placement.max_fraction(None)
    closure = region_mod.op_closure(field.scheme, op, stage, axis)
    plan = region_mod.plan_region(field, region, closure)
    return placement.max_fraction(plan)


def plan_stages(scheme: Scheme, ops: str | Sequence[str],
                stage: Stage | str | int = "auto",
                cost_model: CostModel | None = None, *,
                region=None, field=None, axis: int = 0,
                cached: AbstractSet[Stage] | None = None,
                placement=None) -> StageSetPlan:
    """Jointly resolve the execution stage(s) for an op *set*.

    An explicit stage is validated against every op in the set.  With
    ``stage="auto"`` the planner picks the shared stage minimizing the
    *total* (region-closure-scaled) cost over the feasible intersection —
    fusing the set onto one stage reconstruction — and falls back to
    independent per-op stages only when the intersection is empty, or when a
    fully calibrated cost model prices the per-op optima strictly below the
    best shared stage (conservative: measured per-op costs each include
    their own decode, so this comparison understates the fusion saving).
    ``cached`` stages (store-resident materializations) are priced without
    their reconstruction term, which can flip the shared stage to a higher
    one that is already resident.

    ``placement`` (a :class:`repro.shard.BlockPlacement`, duck-typed) turns
    on the sharded cost rule: each op's reconstruction cost scales by the
    **max** per-shard share of its closure instead of the whole-field (or
    region) fraction — participating shards decode concurrently
    (:func:`_max_shard_fraction`).  Only the calibrated totals change; the
    feasibility and residency logic is placement-blind.

    ``plan_stages(scheme, [op])`` always agrees with ``plan_stage``.
    """
    cached = frozenset(cached or ())
    names = oplib.canonical_ops(ops)
    if stage != "auto":
        resolved = as_stage(stage)
        for op in names:
            check_feasible(scheme, op, resolved)
        if (resolved == Stage.M and region is not None and field is not None
                and not region_mod.region_aligned(field, region)):
            raise UnsupportedStageError(
                f"stage-1 {names[0]} over a region needs a block-aligned window")
        return StageSetPlan(names, tuple((op, resolved) for op in names),
                            resolved)

    feas: dict[str, tuple[Stage, ...]] = {}
    for op in names:
        stages = feasible_stages(scheme, op)
        if region is not None and Stage.M in stages:
            aligned = (field is not None
                       and region_mod.region_aligned(field, region))
            if not aligned:
                stages = tuple(s for s in stages if s != Stage.M)
        feas[op] = stages

    def per_op_plan() -> tuple[tuple[str, Stage], ...]:
        return tuple(
            (op, plan_stage(scheme, op, "auto", cost_model,
                            region=region, field=field, axis=axis,
                            cached=cached))
            for op in names)

    inter = tuple(s for s in Stage if all(s in f for f in feas.values()))
    if not inter:
        return StageSetPlan(names, per_op_plan(), None)

    # residency only ever discounts stages the candidate can actually run
    # at: for the *shared* choice that is the feasible intersection, so a
    # cached stage outside it (e.g. a resident stage-② materialization
    # under a gradient-bearing set on a 1-D scheme) is neither priced nor
    # raises — the shared stage falls back to cold planning over the
    # remaining feasible stages, while per-op fallbacks keep their own
    # (per-op-feasible) residency discounts
    shared_cached = cached & frozenset(inter)

    calibrated = cost_model is not None and all(
        cost_model.cost(scheme, op, s) is not None
        for op in names for s in feas[op])
    if calibrated:
        fractions: dict[tuple[str, Stage], float] = {}

        def cost(op: str, s: Stage) -> float:
            key = (op, s)
            if key not in fractions:
                if placement is not None:
                    fractions[key] = _max_shard_fraction(
                        field, op, s, region, axis, placement)
                else:
                    fractions[key] = (
                        1.0 if region is None or field is None
                        else region_mod.closure_fraction(field, op, s, region,
                                                         axis=axis))
            return (cost_model.cost(scheme, op, s, cached=s in cached)
                    * fractions[key])

        totals = {s: sum(cost(op, s) for op in names) for s in inter}
        shared = min(inter, key=lambda s: (totals[s], int(s)))
        per_op = per_op_plan()
        per_total = sum(cost(op, s) for op, s in per_op)
        if per_total < totals[shared]:
            return StageSetPlan(names, per_op, None)
    elif shared_cached:
        # uncalibrated but residency is known: a resident shared stage pays
        # no reconstruction at all — prefer it over any cold stage
        shared = min(inter, key=_resident_rank(shared_cached))
    else:
        # stage order is monotone in decompression work (paper §V): the
        # lowest shared stage is the cheapest joint reconstruction
        shared = inter[0]
    return StageSetPlan(names, tuple((op, shared) for op in names), shared)


# ===========================================================================
# expression DAGs: joint stage planning per connected component
# ===========================================================================

@dataclasses.dataclass(frozen=True)
class ExprPlan:
    """Resolved joint stages for an analyzed expression DAG
    (``repro.core.expr.ExprProgram``): one :class:`Stage` per connected
    component, indexed by the program's ``leaf_component`` /
    ``root_component`` maps.  The whole DAG lowers into a single compiled
    program, so the plan itself contributes one dispatch."""

    stages: tuple[Stage, ...]


def plan_expr(program, bindings: Sequence, stage="auto",
              cost_model: CostModel | None = None, *, region=None,
              cached: Sequence[AbstractSet[Stage]] | None = None) -> ExprPlan:
    """Jointly plan the execution stage of each DAG component.

    Every ``(op application, leaf scheme)`` pair in a component contributes
    its feasible-stage row; the component runs at one stage from the
    intersection (never empty — stages ③④ are universally feasible), so all
    preludes a combinator joins are stage-compatible.  An explicit ``stage``
    is validated against every pair (op error semantics preserved).  With
    ``stage="auto"``: a fully calibrated cost model minimizes the total
    (region-closure-scaled, residency-discounted) cost; otherwise stages at
    which *every* leaf of the component is store-resident (``cached``, per
    leaf slot) rank first, falling back to stage order.  An unaligned
    ``region`` drops stage ① exactly as in :func:`plan_stages`.
    """
    cached = (list(cached) if cached is not None
              else [frozenset()] * len(bindings))

    def slot_field(slot: int):
        b = bindings[slot]
        return b[0] if isinstance(b, tuple) else b

    out = []
    for comp in range(program.n_components):
        pairs = []  # (op name, scheme, leaf slot, axis)
        for name, axis, slot in program.component_ops(comp):
            b = bindings[slot]
            schemes = ([c.scheme for c in b] if isinstance(b, tuple)
                       else [b.scheme])
            pairs.extend((name, sch, slot, axis) for sch in schemes)
        if stage != "auto":
            resolved = as_stage(stage)
            for name, sch, slot, _axis in pairs:
                check_feasible(sch, name, resolved)
                if (resolved == Stage.M and region is not None
                        and not region_mod.region_aligned(slot_field(slot),
                                                          region)):
                    raise UnsupportedStageError(
                        f"stage-1 {name} over a region needs a "
                        "block-aligned window")
            out.append(resolved)
            continue

        feas_sets = []
        for name, sch, slot, _axis in pairs:
            stages = feasible_stages(sch, name)
            if region is not None and Stage.M in stages:
                if not region_mod.region_aligned(slot_field(slot), region):
                    stages = tuple(s for s in stages if s != Stage.M)
            feas_sets.append(stages)
        inter = tuple(s for s in Stage if all(s in f for f in feas_sets))

        comp_slots = sorted({slot for _, _, slot, _ in pairs})
        resident = frozenset(
            s for s in inter
            if all(s in cached[sl] for sl in comp_slots))
        calibrated = cost_model is not None and all(
            cost_model.cost(sch, name, s) is not None
            for name, sch, slot, axis in pairs for s in inter)
        if calibrated:
            def pair_cost(name, sch, slot, axis, s):
                frac = 1.0
                if region is not None:
                    frac = region_mod.closure_fraction(
                        slot_field(slot), name, s, region, axis=axis)
                return cost_model.cost(sch, name, s,
                                       cached=s in cached[slot]) * frac

            totals = {s: sum(pair_cost(*p, s) for p in pairs) for s in inter}
            out.append(min(inter, key=lambda s: (totals[s], int(s))))
        elif resident:
            out.append(min(inter, key=_resident_rank(resident)))
        else:
            out.append(inter[0])
    return ExprPlan(tuple(out))


# ===========================================================================
# streaming appends: incremental-update vs full-recompute costing
# ===========================================================================

@dataclasses.dataclass(frozen=True)
class RefreshPlan:
    """How to bring a temporal field's resident summary up to date after an
    append (``repro.stream``, DESIGN.md §9).

    ``mode`` is ``"incremental"`` (reconstruct only the appended slab and
    merge it into the resident summary) or ``"recompute"`` (reconstruct
    every slab — the only option when no summary is resident).  The costs
    are reconstruction microseconds from the calibrated fig3/4 table
    (``None`` when uncalibrated: the decision then rests on slab counts
    alone, which is exact — merge work is O(extent), reconstruction is the
    whole cost).
    """

    mode: str                            # "incremental" | "recompute"
    incremental_us: float | None      # one-slab reconstruction cost
    recompute_us: float | None        # all-slab reconstruction cost


def plan_refresh(scheme: Scheme, stage: Stage, n_slabs: int,
                 cost_model: CostModel | None = None, *,
                 summary_resident: bool = True) -> RefreshPlan:
    """Cost an append's summary refresh: incremental merge vs full rebuild.

    Incremental pays one slab's stage reconstruction; a recompute pays
    ``n_slabs`` of them.  With a resident summary the incremental path is
    never dearer (reconstruction cost is nonnegative and the integer merge
    is exact, so there is no accuracy argument for recomputing); without
    one there is nothing to merge into and the plan is a recompute — which
    the store then defers to the next query rather than paying eagerly.
    """
    if n_slabs < 1:
        raise ValueError(f"n_slabs must be >= 1, got {n_slabs}")
    rec = (cost_model.reconstruction(scheme, Stage(stage))
           if cost_model is not None else None)
    inc = rec
    full = rec * n_slabs if rec is not None else None
    if not summary_resident:
        return RefreshPlan("recompute", inc, full)
    return RefreshPlan("incremental", inc, full)
