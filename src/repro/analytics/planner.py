"""Stage planner: the paper's Table I feasibility matrix + cost-based choice.

``FEASIBILITY[(scheme, op)]`` lists the stages the operation is defined at,
cheapest first.  The matrix mirrors — and is pinned by tests to — the actual
raise/no-raise behavior of :mod:`repro.core.homomorphic`:

* ``mean``: stage ① only for the HSZx (block-mean) family, ②③④ for all;
* ``std``: ②③④ (① carries no pointwise information);
* stencils (``derivative``/``laplacian``/``divergence``/``curl``): stage ②
  only for nd schemes (1-D partitioning destroys the spatial layout, §V-B),
  ③④ for all.

``plan_stage`` resolves ``stage="auto"`` to the cheapest feasible stage.  By
default "cheapest" is stage order (①<②<③<④ — monotone in decompression work,
which matches the paper's measurements); a :class:`CostModel` calibrated from
``benchmarks/run.py`` CSV output refines the choice with measured
microseconds per call.

Region queries change the plan twice over.  Feasibility: the stage-① mean is
only eps-exact over block-aligned windows, so unaligned regions drop ① from
the feasible set.  Cost: each stage's measured full-field cost scales by the
fraction of the field its region closure touches
(:func:`repro.core.region.closure_fraction`) — per-stage closures differ for
Lorenzo schemes (stage-② derivative bands vs stage-③ prefix hulls), so
``stage="auto"`` can genuinely pick a different stage for a 1% window than
for the full field.
"""
from __future__ import annotations

from typing import Dict, Iterable, Mapping, Optional, Sequence, Tuple, Union

from repro.core import Scheme, Stage, UnsupportedStageError
from repro.core import region as region_mod

OPS: Tuple[str, ...] = ("mean", "std", "derivative", "laplacian",
                        "divergence", "curl")
#: ops that take a sequence of component fields instead of a single field
MULTIVARIATE = frozenset({"divergence", "curl"})

_STENCILS = ("derivative", "laplacian", "divergence", "curl")


def _build_matrix() -> Dict[Tuple[Scheme, str], Tuple[Stage, ...]]:
    matrix: Dict[Tuple[Scheme, str], Tuple[Stage, ...]] = {}
    for scheme in Scheme:
        matrix[(scheme, "mean")] = tuple(
            ([Stage.M] if scheme.is_blockmean else [])
            + [Stage.P, Stage.Q, Stage.F])
        matrix[(scheme, "std")] = (Stage.P, Stage.Q, Stage.F)
        stencil = tuple(([Stage.P] if scheme.is_nd else [])
                        + [Stage.Q, Stage.F])
        for op in _STENCILS:
            matrix[(scheme, op)] = stencil
    return matrix


#: Table I: (scheme, op) -> stages the op is defined at, cheapest first.
FEASIBILITY: Dict[Tuple[Scheme, str], Tuple[Stage, ...]] = _build_matrix()


def as_stage(stage: Union[Stage, str, int]) -> Stage:
    """Coerce ``Stage`` / int / name ("M", "p", ...) to a :class:`Stage`."""
    if isinstance(stage, str):
        try:
            return Stage[stage.upper()]
        except KeyError:
            raise ValueError(f"unknown stage {stage!r}; expected one of "
                             f"{[s.name for s in Stage]} or 'auto'")
    return Stage(stage)


def feasible_stages(scheme: Scheme, op: str) -> Tuple[Stage, ...]:
    """Stages ``op`` is defined at for ``scheme``, cheapest first."""
    try:
        return FEASIBILITY[(Scheme(scheme), op)]
    except KeyError:
        raise ValueError(f"unknown operation {op!r}; expected one of {OPS}")


def is_feasible(scheme: Scheme, op: str, stage: Stage) -> bool:
    return Stage(stage) in feasible_stages(scheme, op)


def check_feasible(scheme: Scheme, op: str, stage: Stage) -> Stage:
    """Validate an explicit stage choice with the ops' own error semantics."""
    stage = as_stage(stage)
    if not is_feasible(scheme, op, stage):
        raise UnsupportedStageError(
            f"{op} is not defined at stage {stage.name} for scheme "
            f"{Scheme(scheme).value}; feasible stages: "
            f"{[s.name for s in feasible_stages(scheme, op)]}")
    return stage


class CostModel:
    """Per-``(scheme, op, stage)`` cost estimates in microseconds per call.

    Uncalibrated cells fall back to a stage-ordered default (stage index
    scaled to rank *below* any measured cost is wrong — instead the default
    is only used when the whole ``(scheme, op)`` row is unmeasured, so mixed
    calibration never compares measured against made-up numbers).
    """

    def __init__(self, table: Optional[Dict[Tuple[Scheme, str, Stage], float]] = None):
        self.table: Dict[Tuple[Scheme, str, Stage], float] = dict(table or {})
        self._counts: Dict[Tuple[Scheme, str, Stage], int] = {
            k: 1 for k in self.table}

    # -- calibration -------------------------------------------------------
    _BENCH_OP_ALIASES = {"deriv": "derivative", "div": "divergence"}
    _BENCH_STAGE_TAGS = {"m": Stage.M, "p": Stage.P, "q": Stage.Q, "f": Stage.F}

    def record(self, scheme: Scheme, op: str, stage: Stage, us: float) -> None:
        key = (Scheme(scheme), op, Stage(stage))
        # true running mean over repeated observations (multiple datasets):
        # order-independent, every observation weighted equally
        n = self._counts.get(key, 0)
        prev = self.table.get(key, 0.0)
        self.table[key] = (prev * n + us) / (n + 1)
        self._counts[key] = n + 1

    @classmethod
    def from_benchmark_csv(cls, rows: Union[str, Iterable[str]]) -> "CostModel":
        """Calibrate from ``benchmarks/run.py`` output.

        Parses the op-throughput rows (``fig58/…``, ``fig910/…``,
        ``fig1112/…``), whose names encode ``…/<op>/<scheme>-<stage_tag>``;
        other rows are ignored.
        """
        model = cls()
        if isinstance(rows, str):
            rows = rows.splitlines()
        for line in rows:
            line = line.strip()
            if not line or line.startswith(("#", "name,")):
                continue
            name, _, rest = line.partition(",")
            us_text = rest.partition(",")[0]
            parts = name.split("/")
            if len(parts) != 4 or parts[0] not in ("fig58", "fig910", "fig1112"):
                continue
            op = cls._BENCH_OP_ALIASES.get(parts[2], parts[2])
            scheme_name, _, tag = parts[3].rpartition("-")
            if op not in OPS or tag not in cls._BENCH_STAGE_TAGS:
                continue
            try:
                scheme = Scheme(scheme_name)
                us = float(us_text)
            except ValueError:
                continue
            model.record(scheme, op, cls._BENCH_STAGE_TAGS[tag], us)
        return model

    # -- lookup ------------------------------------------------------------
    def cost(self, scheme: Scheme, op: str, stage: Stage) -> Optional[float]:
        return self.table.get((Scheme(scheme), op, Stage(stage)))

    def cheapest(self, scheme: Scheme, op: str, stages: Sequence[Stage],
                 fractions: Optional[Mapping[Stage, float]] = None) -> Stage:
        """Cheapest stage; ``fractions`` scale each stage's measured cost by
        the share of the field its region closure touches (1.0 = full field)."""
        costs = {s: self.cost(scheme, op, s) for s in stages}
        if any(c is None for c in costs.values()):
            # incomplete row: fall back to stage order rather than mixing
            # measured numbers with fabricated defaults
            return min(stages, key=int)
        if fractions is not None:
            costs = {s: c * fractions.get(s, 1.0) for s, c in costs.items()}
        return min(stages, key=lambda s: (costs[s], int(s)))


def plan_stage(scheme: Scheme, op: str,
               stage: Union[Stage, str, int] = "auto",
               cost_model: Optional[CostModel] = None, *,
               region=None, field=None, axis: int = 0) -> Stage:
    """Resolve the execution stage for ``op`` on ``scheme``.

    ``stage="auto"`` picks the cheapest feasible stage (never one that would
    raise :class:`UnsupportedStageError`); an explicit stage is validated
    against the feasibility matrix.  With ``region`` (and the queried
    ``field`` for its geometry), stage ① is dropped/rejected for windows that
    are not block-aligned, and calibrated costs scale with each stage's
    region-closure size.
    """
    if stage != "auto":
        stage = check_feasible(scheme, op, stage)
        if (stage == Stage.M and region is not None and field is not None
                and not region_mod.region_aligned(field, region)):
            raise UnsupportedStageError(
                f"stage-1 {op} over a region needs a block-aligned window")
        return stage
    stages = feasible_stages(scheme, op)
    if region is not None and Stage.M in stages:
        aligned = (field is not None
                   and region_mod.region_aligned(field, region))
        if not aligned:
            stages = tuple(s for s in stages if s != Stage.M)
    if cost_model is not None:
        fractions = None
        if region is not None and field is not None:
            fractions = {s: region_mod.closure_fraction(field, op, s, region,
                                                        axis=axis)
                         for s in stages}
        return cost_model.cheapest(scheme, op, stages, fractions)
    return stages[0]
