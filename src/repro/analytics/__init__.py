"""Batched homomorphic analytics: automatic stage planning + vmap execution.

The paper's Table I says *which* decompression stage each analytical
operation can run at; its §V timings say stage choice is where the speedups
live.  This package turns that into an engine:

* :mod:`repro.analytics.planner` — the feasibility matrix as data, plus a
  cost model (optionally calibrated from ``benchmarks/run.py`` CSV) that
  picks the cheapest feasible stage automatically;
* :mod:`repro.analytics.engine` — stacks same-layout compressed fields into
  a leading batch axis (``repro.core.batch_stack``) and runs the homomorphic
  op once, ``vmap``-ed and ``jit``-ed, with a compilation cache keyed on
  ``(scheme, block, shape, op, stage)``;
* :mod:`repro.analytics.query` — ``query(fields, op=..., stage="auto")``:
  groups arbitrary field collections by layout, plans each group, executes
  batched, and returns results in input order.
"""
from .planner import (CostModel, FEASIBILITY, MULTIVARIATE, OPS, as_stage,
                      check_feasible, feasible_stages, is_feasible, plan_stage)
from .engine import BatchedAnalytics, batch_key
from .query import QueryResult, query

__all__ = [
    "OPS", "MULTIVARIATE", "FEASIBILITY", "as_stage",
    "feasible_stages", "is_feasible", "check_feasible", "plan_stage",
    "CostModel", "BatchedAnalytics", "batch_key", "QueryResult", "query",
]
