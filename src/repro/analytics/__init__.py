"""Batched homomorphic analytics: automatic stage planning + vmap execution.

The paper's Table I says *which* decompression stage each analytical
operation can run at; its §V timings say stage choice is where the speedups
live.  This package turns that into an engine:

* :mod:`repro.analytics.planner` — the feasibility matrix as data (derived
  from the declarative op registry in :mod:`repro.core.oplib`), plus a cost
  model (optionally calibrated from ``benchmarks/run.py`` CSV) that picks
  the cheapest feasible stage automatically — jointly over an op *set* via
  ``plan_stages`` (one shared stage minimizing total cost);
* :mod:`repro.analytics.engine` — stacks same-layout compressed fields into
  a leading batch axis (``repro.core.batch_stack``) and runs the homomorphic
  op set once, ``vmap``-ed and ``jit``-ed, with a compilation cache keyed on
  ``(scheme, block, shape, frozen op-set, stage, region)``;
* :mod:`repro.analytics.query` — ``query(fields, op_or_ops, stage="auto")``:
  groups arbitrary field collections by layout, plans each group once,
  executes batched — one compiled call per layout group for a fused op set —
  and returns results in input order.  With ``store=`` (a
  :class:`repro.store.FieldStore`) fields may be string ids, planning is
  cache-aware (resident stages drop their reconstruction term), and the
  compiled programs are seeded from resident materialized stages.
"""
from .planner import (CostModel, FEASIBILITY, MULTIVARIATE, OPS, RefreshPlan,
                      StageSetPlan, TEMPORAL, as_stage, check_feasible,
                      feasible_stages, is_feasible, plan_refresh, plan_stage,
                      plan_stages)
from .engine import BatchedAnalytics, batch_key
from .query import QueryResult, query

__all__ = [
    "OPS", "TEMPORAL", "MULTIVARIATE", "FEASIBILITY", "as_stage",
    "feasible_stages", "is_feasible", "check_feasible", "plan_stage",
    "plan_stages", "StageSetPlan", "plan_refresh", "RefreshPlan",
    "CostModel", "BatchedAnalytics", "batch_key", "QueryResult", "query",
]
