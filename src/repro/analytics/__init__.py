"""Batched homomorphic analytics: automatic stage planning + vmap execution.

The paper's Table I says *which* decompression stage each analytical
operation can run at; its §V timings say stage choice is where the speedups
live.  This package turns that into an engine:

* :mod:`repro.analytics.planner` — the feasibility matrix as data (derived
  from the declarative op registry in :mod:`repro.core.oplib`), plus a cost
  model (optionally calibrated from ``benchmarks/run.py`` CSV) that picks
  the cheapest feasible stage automatically — jointly over an op *set* via
  ``plan_stages`` (one shared stage minimizing total cost);
* :mod:`repro.analytics.engine` — stacks same-layout compressed fields into
  a leading batch axis (``repro.core.batch_stack``) and runs the homomorphic
  op set once, ``vmap``-ed and ``jit``-ed, with a compilation cache keyed on
  ``(scheme, block, shape, frozen op-set, stage, region)``;
* :mod:`repro.analytics.query` — ``query(exprs=[...], store=...)``: the
  expression front-end.  Roots are ``repro.core.expr`` DAGs (cross-field
  derived operators — vorticity from u and v, ensemble deltas); the whole
  batch compiles to one program with exactly one stage-reconstruction
  prelude per distinct leaf, planned jointly per connected component
  (``plan_expr``).  With ``store=`` (a :class:`repro.store.FieldStore`)
  leaves may be string ids, planning is cache-aware (resident stages drop
  their reconstruction term), and the compiled program is seeded from
  resident materialized stages.  The flat op-set spelling
  ``query(fields, op_or_ops)`` remains as a deprecated bit-identical shim.
"""
from .planner import (CostModel, ExprPlan, FEASIBILITY, MULTIVARIATE, OPS,
                      RefreshPlan, StageSetPlan, TEMPORAL, as_stage,
                      check_feasible, feasible_stages, is_feasible,
                      plan_expr, plan_refresh, plan_stage, plan_stages)
from .engine import BatchedAnalytics, batch_key
from .query import QueryResult, query

__all__ = [
    "OPS", "TEMPORAL", "MULTIVARIATE", "FEASIBILITY", "as_stage",
    "feasible_stages", "is_feasible", "check_feasible", "plan_stage",
    "plan_stages", "StageSetPlan", "plan_expr", "ExprPlan", "plan_refresh",
    "RefreshPlan", "CostModel", "BatchedAnalytics", "batch_key",
    "QueryResult", "query",
]
