"""Materialized-stage field store: intermediate representations as values.

``repro.store`` turns the stage reconstruction — the cost the paper says
dominates analytics — into a first-class, cacheable artifact:

* :class:`MaterializedStage` / :func:`materialize` — pytree containers for
  one ``(field, stage, region, closure)`` intermediate (stage-② residual
  sub-field, stage-③ integers, stage-④ floats);
* :class:`FieldStore` — string-id registry of encoded fields plus a
  byte-budgeted LRU cache of their materializations with hit / miss /
  eviction accounting (:class:`StoreStats`).

The analytics layers consume it end to end: ``query(..., store=)`` resolves
ids, plans cache-aware (a resident stage prices at postlude-only cost), and
seeds the batched engine's compiled programs from the resident
intermediates; ``serve.AnalyticsFrontend(store=)`` lets requests name field
ids so clients stop shipping arrays.  See DESIGN.md §7.
"""
from .field_store import FieldStore, MATERIALIZABLE, StoreStats
from .materialized import (MaterializedStage, materialize,
                           materialized_nbytes, serves, storage_stage)

__all__ = ["FieldStore", "MATERIALIZABLE", "MaterializedStage", "StoreStats",
           "materialize", "materialized_nbytes", "serves", "storage_stage"]
