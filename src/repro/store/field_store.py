"""Byte-budgeted store of encoded fields and their materialized stages.

A :class:`FieldStore` is the serving-side registry that turns "one
reconstruction per call" into "one reconstruction per field lifetime":

* **fields** — encoded/compressed containers registered under string ids,
  so analytics clients (``repro.serve.AnalyticsRequest``) name data instead
  of shipping arrays;
* **materializations** — an LRU cache of :class:`MaterializedStage`
  intermediates keyed by ``(field id, stage, region, closure)``, bounded by
  a device-byte budget, with hit / miss / eviction accounting
  (:class:`StoreStats`);
* **planner input** — :meth:`cached_stages` reports which stages of a
  field are resident for a given op set, so the cache-aware cost model
  (``repro.analytics.planner``) can drop the reconstruction term and route
  ``stage="auto"`` to an already-materialized stage.

Invalidation rules (DESIGN.md §7): re-registering or removing a field id
drops every materialization derived from it; materializations are immutable
otherwise (fields are, too — compression is content-addressed by the
caller's id discipline).
"""
from __future__ import annotations
from collections.abc import Iterable, Sequence

import dataclasses
from collections import OrderedDict

from repro.core import Compressed, Encoded, Stage, oplib
from repro.core import region as region_mod
from repro.core.region import Closure

from .materialized import (MaterializedStage, materialize,
                           materialized_nbytes, storage_stage)

Field = Compressed | Encoded

#: stages a materialization serves (① is always resident in the container;
#: ④ is served by the stage-③ integer intermediate — see ``storage_stage``)
MATERIALIZABLE = (Stage.P, Stage.Q, Stage.F)


@dataclasses.dataclass
class StoreStats:
    """Cumulative cache accounting (monotone counters).

    ``evictions`` counts entries dropped from the cache for any reason —
    budget pressure *and* id invalidation — so it tracks resident-set
    churn; ``rejected`` counts cells that never became resident (larger
    than the whole budget), so it flags fields the budget cannot serve.
    """

    hits: int = 0
    misses: int = 0
    evictions: int = 0
    rejected: int = 0


class FieldStore:
    """Registry of encoded fields + byte-budgeted LRU cache of their
    materialized stages.

    ``cache_bytes`` bounds the *device* bytes of resident intermediates
    (fields themselves are not counted — they are the store's contents, not
    its cache).  An entry larger than the whole budget is never retained
    (counted as a *rejection*, :attr:`StoreStats.rejected` — it was never
    resident, so it is not an eviction), so one huge field cannot starve
    the cache into thrash; :meth:`seed` declines such cells without even
    computing them.
    """

    def __init__(self, cache_bytes: int = 256 << 20):
        if cache_bytes < 0:
            raise ValueError("cache_bytes must be >= 0")
        self.cache_bytes = cache_bytes
        self._fields: dict[str, Field] = {}
        self._cache: "OrderedDict[Tuple, MaterializedStage]" = OrderedDict()
        self._bytes = 0
        self.stats = StoreStats()

    # -- field registry -----------------------------------------------------
    def put(self, field_id: str, field: Field, *, replace: bool = False) -> str:
        """Register ``field`` under ``field_id``.

        Replacing an existing id requires ``replace=True`` and invalidates
        every materialization derived from the old field.
        """
        if not isinstance(field_id, str) or not field_id:
            raise ValueError(f"field id must be a non-empty string, got {field_id!r}")
        if not isinstance(field, (Compressed, Encoded)):
            raise TypeError(
                f"expected a Compressed/Encoded field, got {type(field).__name__}")
        if field_id in self._fields:
            if not replace:
                raise ValueError(
                    f"field id {field_id!r} already registered "
                    "(pass replace=True to overwrite)")
            self.invalidate(field_id)
        self._fields[field_id] = field
        return field_id

    def get(self, field_id: str) -> Field:
        try:
            return self._fields[field_id]
        except KeyError:
            raise KeyError(
                f"unknown field id {field_id!r}; registered ids: "
                f"{sorted(self._fields) or '(none)'}") from None

    def remove(self, field_id: str) -> None:
        """Unregister a field and drop its materializations."""
        self.get(field_id)  # uniform unknown-id error
        self.invalidate(field_id)
        del self._fields[field_id]

    def __contains__(self, field_id: str) -> bool:
        return field_id in self._fields

    def __len__(self) -> int:
        return len(self._fields)

    def ids(self) -> tuple[str, ...]:
        return tuple(self._fields)

    # -- materialization cache ---------------------------------------------
    @staticmethod
    def _key(field_id: str, stage: Stage, region, closure: Closure) -> tuple:
        return (field_id, storage_stage(stage), region, closure)

    def _canonical(self, field: Field, stage: Stage, region, closure: Closure):
        norm = (region_mod.normalize_region(region, field.shape)
                if region is not None else None)
        return norm, region_mod.canonical_closure(field.scheme, closure, norm)

    @property
    def cache_bytes_in_use(self) -> int:
        return self._bytes

    @property
    def cache_entries(self) -> int:
        return len(self._cache)

    def _peek_hit(self, key: tuple) -> MaterializedStage | None:
        """Resident entry for ``key`` (bumping LRU order and the hit
        counter), or ``None`` without counting anything."""
        m = self._cache.get(key)
        if m is not None:
            self._cache.move_to_end(key)
            self.stats.hits += 1
        return m

    def lookup(self, field_id: str, stage: Stage, *, region=None,
               closure: Closure = "cover") -> MaterializedStage | None:
        """Cache lookup (counts a hit or a miss; hits refresh LRU order)."""
        field = self.get(field_id)
        norm, closure = self._canonical(field, stage, region, closure)
        m = self._peek_hit(self._key(field_id, stage, norm, closure))
        if m is None:
            self.stats.misses += 1
        return m

    def ensure(self, field_id: str, stage: Stage, *, region=None,
               closure: Closure = "cover") -> MaterializedStage:
        """Resident materialization for one cache cell: a hit returns it, a
        miss builds it (the *one* reconstruction of the field's lifetime,
        budget permitting) and inserts it."""
        m = self.lookup(field_id, stage, region=region, closure=closure)
        if m is not None:
            return m
        field = self.get(field_id)
        norm, closure = self._canonical(field, stage, region, closure)
        m = materialize(field, stage, region=region, closure=closure)
        self._insert(self._key(field_id, stage, norm, closure), m)
        return m

    def seed(self, field_id: str, stage: Stage, *, region=None,
             closure: Closure = "cover") -> MaterializedStage | None:
        """:meth:`ensure`, but declining cells that could never be retained.

        A materialization larger than the whole budget would be rebuilt on
        *every* query — strictly worse than running storeless — so a miss
        first checks the exact predicted size (:func:`materialized_nbytes`,
        static geometry only) and returns ``None``, signalling the caller
        to fall back to unseeded execution.  A hit skips the size check:
        residency already proved the fit."""
        field = self.get(field_id)
        norm, closure = self._canonical(field, stage, region, closure)
        key = self._key(field_id, stage, norm, closure)
        m = self._peek_hit(key)
        if m is not None:
            return m
        if materialized_nbytes(field, stage, region=region,
                               closure=closure) > self.cache_bytes:
            self.stats.rejected += 1
            return None
        self.stats.misses += 1
        m = materialize(field, stage, region=region, closure=closure)
        self._insert(key, m)
        return m

    def _insert(self, key: tuple, m) -> None:
        """Insert (or replace) one cache entry, keeping ``_bytes`` equal to
        the sum of resident ``nbytes`` through every path.

        The replace path subtracts the old entry's bytes exactly once (the
        ``pop`` removes it before the eviction loop can see it, so it can
        never be double-subtracted as both replacement and victim), and the
        eviction loop walks from the LRU end but never touches ``key``
        itself — the just-inserted entry must not be its own victim even if
        a future refactor changes its position in the order.
        """
        nb = m.nbytes
        old = self._cache.pop(key, None)
        if old is not None:
            self._bytes -= old.nbytes
        if nb > self.cache_bytes:
            # never retained: computed for this call, dropped immediately.
            # A *replaced* entry stays dropped — keeping the stale value
            # would serve outdated intermediates (fatal for streaming
            # summaries, which are replaced on every append).
            self.stats.rejected += 1
            if old is not None:
                self.stats.evictions += 1
            return
        self._cache[key] = m
        self._bytes += nb
        while self._bytes > self.cache_bytes:
            victim_key = next(iter(self._cache))
            if victim_key == key:  # never evict the entry just inserted
                break
            self._bytes -= self._cache.pop(victim_key).nbytes
            self.stats.evictions += 1

    def invalidate(self, field_id: str) -> int:
        """Drop every materialization of ``field_id`` (counted as
        evictions — resident-set churn an operator should see); returns
        the count."""
        victims = [k for k in self._cache if k[0] == field_id]
        for k in victims:
            self._bytes -= self._cache.pop(k).nbytes
        self.stats.evictions += len(victims)
        return len(victims)

    # -- planner input ------------------------------------------------------
    def is_resident(self, field_id: str, stage: Stage, *, region=None,
                    closure: Closure = "cover") -> bool:
        """Pure residency peek for one exact ``(stage, region, closure)``
        cell — the expression planner's cache-awareness probe (expression
        closures join over a DAG's consumer set, so they don't reduce to an
        op-set's :meth:`cached_stages` row).  Neither the LRU order nor the
        hit/miss counters move."""
        field = self.get(field_id)
        norm, closure = self._canonical(field, stage, region, closure)
        return self._key(field_id, stage, norm, closure) in self._cache

    def cached_stages(self, field_ids: str | Sequence[str],
                      ops: str | Iterable[str], *, region=None,
                      axis: int = 0) -> frozenset[Stage]:
        """Stages at which ``ops`` over ``field_ids`` would be served from
        resident materializations.

        For a field-arity op set pass one id; for a vector-arity set
        (``divergence``/``curl``) pass the component ids — a stage counts
        only when *every* component's cell is resident.  Pure peek: neither
        the LRU order nor the hit/miss counters move (planning must not
        distort serving statistics).
        """
        names = oplib.canonical_ops(ops)
        vector = oplib.is_vector_ops(names)
        fids = list(field_ids) if vector else [field_ids]
        if isinstance(field_ids, str) and vector:
            raise ValueError("vector op sets need one field id per component")
        fields = [self.get(f) for f in fids]
        out = set()
        for stage in MATERIALIZABLE:
            if vector:
                closures = oplib.component_closures(
                    names, [f.scheme for f in fields], stage)
            else:
                closures = (oplib.set_closure(names, fields[0].scheme, stage,
                                              axis),)
            resident = True
            for fid, field, cl in zip(fids, fields, closures):
                norm, cl = self._canonical(field, stage, region, cl)
                if self._key(fid, stage, norm, cl) not in self._cache:
                    resident = False
                    break
            if resident:
                out.add(stage)
        return frozenset(out)
