"""First-class materialized stage reconstructions (DESIGN.md §7).

The paper's premise is that decompression dominates analytics cost; the
operator-lowering core (``repro.core.oplib``) already shares one stage
reconstruction across an op *set*, but the reconstruction itself was
ephemeral — rebuilt inside every ``compute()`` call and thrown away.  A
:class:`MaterializedStage` turns it into a value: the intermediate
representation of one ``(field, stage, region, closure)`` cell, held as a
pytree so it stacks, ``vmap``-s, and enters jitted programs exactly like the
compressed containers themselves.

What each stage keeps resident is exactly the *last integer-exact*
intermediate its postludes consume:

* stage ② — the decoded sub-field (``sub``): residuals + restricted
  metadata, i.e. the honest :class:`~repro.core.stages.Compressed` that
  ``StageContext.sub`` would have decoded;
* stage ③ *and* stage ④ — ``q_spatial``: recorrelated quantization
  integers, cropped or windowed to the queried extent.  Stage ④ is the
  stage-③ intermediate plus a dequantize multiply, which stays in the op
  postlude: one cache entry serves both stages.

Stage ① has nothing to materialize — its metadata is already resident in
the compressed container — so :func:`materialize` rejects it.

Materializations stop at integer intermediates *by design*: integer
reconstruction is exact under any compilation, so a program seeded from a
resident intermediate and a program reconstructing inline share their
entire floating-point expression tail — which is what makes store-backed
results **bit-identical** to storeless ones.  (Caching dequantized floats
instead would hand XLA different float graphs to reassociate, producing
ulp-level drift between hot and cold answers.)
"""
from __future__ import annotations

from dataclasses import dataclass
from functools import partial

import jax

from repro.core import Compressed, Encoded, Stage, layout_key, oplib
from repro.core import region as region_mod
from repro.core.region import Closure
from repro.core.stages import _dataclass_pytree

Field = Compressed | Encoded


def serves(seed_stage: Stage, ctx_stage: Stage) -> bool:
    """Can a materialization at ``seed_stage`` seed a ``ctx_stage`` prelude?
    Exact stage match, plus the one derived case: the stage-③ integers serve
    stage-④ (dequantize is an op-postlude multiply, not a reconstruction)."""
    seed_stage, ctx_stage = Stage(seed_stage), Stage(ctx_stage)
    return seed_stage == ctx_stage or (seed_stage == Stage.Q
                                       and ctx_stage == Stage.F)


def storage_stage(stage: Stage) -> Stage:
    """The stage a materialization is stored at: ④ canonicalizes to ③ (one
    resident integer intermediate serves both)."""
    stage = Stage(stage)
    return Stage.Q if stage == Stage.F else stage


@partial(
    _dataclass_pytree,
    data_fields=("sub", "q_spatial"),
    meta_fields=("stage", "closure", "region"),
)
@dataclass(frozen=True)
class MaterializedStage:
    """One resident intermediate representation.

    Exactly one of ``sub`` / ``q_spatial`` is populated (stage ② / ③); the
    other is ``None`` (an empty pytree subtree, so same-key containers
    always share a treedef and stack cleanly).  The meta triple is the
    cache key the seed must match: the (storage) stage, the *canonical*
    region closure (:func:`repro.core.region.canonical_closure`), and the
    normalized region (``None`` for full-field).
    """

    sub: Compressed | None        # stage ②: decoded sub-field
    q_spatial: jax.Array | None   # stage ③ (and ④): recorrelated integers

    stage: Stage
    closure: Closure
    region: tuple[tuple[int, int], ...] | None

    @property
    def nbytes(self) -> int:
        """Device bytes this materialization keeps resident (LRU accounting)."""
        if self.sub is not None:
            return self.sub.device_bytes()
        q = self.q_spatial
        return int(q.size * q.dtype.itemsize)

    def serves(self, ctx_stage: Stage) -> bool:
        """Can this materialization seed a ``ctx_stage`` prelude?  The one
        authoritative copy of the stage-serving rule — the duck-typed seed
        consumers (`oplib.StageContext`, the engine) call this, so core
        never needs a store dependency."""
        return serves(self.stage, ctx_stage)

    def sig(self) -> tuple:
        """Hashable static signature: part of the engine's jit-cache key, and
        the stacking-compatibility check across a batch of seeds."""
        q = self.q_spatial
        return (self.stage, self.closure, self.region,
                layout_key(self.sub) if self.sub is not None else None,
                (tuple(q.shape), str(q.dtype)) if q is not None else None)


def materialized_nbytes(field: Field, stage: Stage, *, region=None,
                        closure: Closure = "cover") -> int:
    """Exact device bytes :func:`materialize` would keep resident, from
    static geometry alone (no device work) — the store consults this to
    decline cells that could never fit its budget *before* paying the
    reconstruction."""
    stage = storage_stage(stage)
    if stage == Stage.M:
        raise ValueError("stage-1 metadata is never materialized")
    int32 = 4
    if region is not None:
        plan = region_mod.plan_region(field, region, closure)
        if stage == Stage.P:
            meta = (plan.n_sub_blocks if field.scheme.is_blockmean
                    else int(field.metadata.size))
            return int32 * (plan.gathered_elems + meta
                            + 2 * plan.n_sub_blocks) + 4  # + f32 eps
        return int32 * plan.n_window
    if stage == Stage.P:
        n = 1
        for s in field.padded_shape:
            n *= s
        meta = int(field.metadata.size)
        return int32 * (n + meta + 2 * field.n_blocks) + 4
    return int32 * field.n


def materialize(field: Field, stage: Stage, *,
                region=None, closure: Closure = "cover") -> MaterializedStage:
    """Build the intermediate representation of one cache cell.

    Runs the exact shared prelude the op lowerings use
    (:class:`repro.core.oplib.StageContext`), forces the stage's resident
    intermediate, and wraps it.  Stage ④ requests return the stage-③
    container (see :func:`storage_stage`).  ``closure`` matters only with
    ``region`` (it decides the gathered block set); full-field
    materializations share the canonical ``"cover"`` key regardless of the
    op set that asked.
    """
    stage = storage_stage(stage)
    if stage == Stage.M:
        raise ValueError(
            "stage-1 metadata is already resident in the compressed "
            "container; there is nothing to materialize")
    norm = (region_mod.normalize_region(region, field.shape)
            if region is not None else None)
    closure = region_mod.canonical_closure(field.scheme, closure, norm)
    ctx = oplib.StageContext(field, stage, region, closure)
    sub = q = None
    if stage == Stage.P:
        sub = ctx.sub
    else:
        q = ctx.q_spatial
    return MaterializedStage(sub=sub, q_spatial=q,
                             stage=stage, closure=closure, region=norm)
