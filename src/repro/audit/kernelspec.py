"""Analyzer (5): Pallas kernel grid/bounds/race verification (DESIGN.md §11).

Every kernel in ``repro.kernels`` declares a symbolic spec
(:mod:`repro.kernels.specs`); this pass *proves*, for all grid sizes the
spec's symbol bounds admit:

* **bounds** — every BlockSpec index map and every host-side ±1-row halo
  gather stays inside its array, including the guard predicates that make
  boundary reads zero-filled instead of out-of-bounds;
* **coverage** — the grid writes every output element exactly once: block
  strides match block shapes (no gaps), the first/last blocks land exactly
  on the array edges, and every grid symbol distinguishes the output index
  map (no write races between grid cells) — except where a spec declares
  the sequential-accumulator pattern (``sequential_revisit``);
* **VMEM** — the declared worst-case per-cell footprint fits the budget
  (default 16 MiB, the per-core VMEM size) under the audit envelope;
* **unpack lemma** — the in-kernel bitplane unpack's guarded carry read
  (``words[widx + 1]``) never escapes the ``WPB_EXTRA``-padded word
  window, by bounded-exhaustive sweep over every (bits, in-word offset,
  band-length residue) combination;
* **no output multiply** — no float multiply is the final op feeding an
  output ref (the FMA-contraction hazard PR 8 debugged bitwise: XLA's CPU
  fusion duplicates a trailing kernel multiply into downstream consumers
  and FMA-contracts it *shape-dependently*; the float tail must live in
  the XLA lowering rule).  ``# audit: waive(output-multiply)`` on the
  store line (or the line above) exempts a deliberate exception.

Abstract domain: polynomials over the spec symbols with interval bounds.
``e >= 0`` is proven by substituting each bounded symbol ``s`` with
``lo + δ`` or ``hi − δ`` (fresh ``δ >= 0``) and checking that some branch
expands to a polynomial with only non-negative coefficients — sound
(never accepts a violable bound), conservative (may reject a true one,
which surfaces as a finding to fix or respecify, never silence).
"""
from __future__ import annotations

import ast
import math
import re
from pathlib import Path

from .findings import Finding
from .intwidth import DEFAULT_ENVELOPE, Envelope

_ANALYZER = "kernelspec"

#: per-core VMEM (see the TPU architecture table in the Pallas guide).
VMEM_BUDGET_BYTES = 16 * 2**20

_WAIVE_RE = re.compile(r"#\s*audit:\s*waive\(([a-z\-,\s]+)\)")
_FRESH = "δ"  # δ — reserved prefix for nonneg slack variables
_GUARD_RE = re.compile(r"^\s*(\w+)\s*(<=|>=)\s*(.+?)\s*$")
_FACT_RE = re.compile(r"^\s*(\w+)\s*==\s*(.+?)\s*$")


# ---------------------------------------------------------------------------
# polynomial domain
# ---------------------------------------------------------------------------

class Poly:
    """Integer polynomial over named symbols (dict monomial -> coeff).

    A monomial is a sorted tuple of ``(symbol, power)`` pairs; the empty
    tuple is the constant term.  Supports +, -, *, substitution, and
    exact equality — everything the bounds/coverage proofs need.
    """

    __slots__ = ("terms",)

    def __init__(self, terms: dict | None = None):
        self.terms = {m: c for m, c in (terms or {}).items() if c != 0}

    @classmethod
    def const(cls, n: int) -> "Poly":
        return cls({(): int(n)})

    @classmethod
    def var(cls, name: str) -> "Poly":
        return cls({((name, 1),): 1})

    def vars(self) -> set[str]:
        return {s for m in self.terms for s, _ in m}

    def is_zero(self) -> bool:
        return not self.terms

    def const_value(self) -> int | None:
        if not self.terms:
            return 0
        if set(self.terms) == {()}:
            return self.terms[()]
        return None

    def __eq__(self, other) -> bool:
        return isinstance(other, Poly) and self.terms == other.terms

    def __hash__(self):
        return hash(frozenset(self.terms.items()))

    def __add__(self, other: "Poly") -> "Poly":
        out = dict(self.terms)
        for m, c in other.terms.items():
            out[m] = out.get(m, 0) + c
        return Poly(out)

    def __neg__(self) -> "Poly":
        return Poly({m: -c for m, c in self.terms.items()})

    def __sub__(self, other: "Poly") -> "Poly":
        return self + (-other)

    def __mul__(self, other: "Poly") -> "Poly":
        out: dict = {}
        for m1, c1 in self.terms.items():
            for m2, c2 in other.terms.items():
                powers: dict[str, int] = {}
                for s, p in m1 + m2:
                    powers[s] = powers.get(s, 0) + p
                m = tuple(sorted(powers.items()))
                out[m] = out.get(m, 0) + c1 * c2
        return Poly(out)

    def subst(self, name: str, repl: "Poly") -> "Poly":
        """Replace every occurrence of ``name`` by the polynomial ``repl``."""
        out = Poly()
        for m, c in self.terms.items():
            power = 0
            rest = []
            for s, p in m:
                if s == name:
                    power = p
                else:
                    rest.append((s, p))
            term = Poly({tuple(rest): c})
            for _ in range(power):
                term = term * repl
            out = out + term
        return out

    def render(self) -> str:
        if not self.terms:
            return "0"
        parts = []
        for m, c in sorted(self.terms.items()):
            sym = "*".join(s if p == 1 else f"{s}^{p}" for s, p in m)
            parts.append(f"{c}" if not sym else
                         (sym if c == 1 else f"{c}*{sym}"))
        return " + ".join(parts)


def parse_expr(expr: str) -> Poly:
    """Parse an integer arithmetic expression (``+ - *``, parentheses,
    names, literals) into a :class:`Poly`."""
    def rec(node: ast.AST) -> Poly:
        if isinstance(node, ast.Constant) and isinstance(node.value, int):
            return Poly.const(node.value)
        if isinstance(node, ast.Name):
            return Poly.var(node.id)
        if isinstance(node, ast.BinOp):
            left, right = rec(node.left), rec(node.right)
            if isinstance(node.op, ast.Add):
                return left + right
            if isinstance(node.op, ast.Sub):
                return left - right
            if isinstance(node.op, ast.Mult):
                return left * right
        if isinstance(node, ast.UnaryOp) and isinstance(node.op, ast.USub):
            return -rec(node.operand)
        raise ValueError(f"unsupported spec expression: {expr!r}")

    return rec(ast.parse(expr, mode="eval").body)


# ---------------------------------------------------------------------------
# the nonnegativity prover
# ---------------------------------------------------------------------------

def prove_nonneg(p: Poly, order: list[str],
                 bounds: dict[str, tuple[Poly, Poly | None]]) -> bool:
    """Prove ``p >= 0`` for every assignment inside the bound box.

    Substitutes the first bounded symbol present by ``lo + δ`` (valid for
    the whole domain above ``lo``) or, when an upper bound exists, by
    ``hi − δ`` (valid below ``hi``); a branch succeeds when the fully
    substituted polynomial has only non-negative coefficients over the
    remaining δ's.  Bound expressions may only reference symbols *later*
    in ``order`` (the specs declare grid symbols first).
    """
    for k, sym in enumerate(order):
        if sym not in p.vars():
            continue
        lo, hi = bounds[sym]
        slack = Poly.var(f"{_FRESH}{k}")
        cands = [p.subst(sym, lo + slack)]
        if hi is not None:
            cands.append(p.subst(sym, hi - slack))
        return any(prove_nonneg(c, order[k + 1:], bounds) for c in cands)
    if any(not v.startswith(_FRESH) for v in p.vars()):
        return False  # a symbol with no declared bound survived
    return all(c >= 0 for c in p.terms.values())


# ---------------------------------------------------------------------------
# spec checks
# ---------------------------------------------------------------------------

class _SpecCtx:
    """One spec's parsed bounds, facts, and prover entry points."""

    def __init__(self, spec):
        self.spec = spec
        self.order = list(spec.bounds.keys())
        self.bounds = {
            s: (parse_expr(lo), parse_expr(hi) if hi is not None else None)
            for s, (lo, hi) in spec.bounds.items()}
        self.facts: list[tuple[str, Poly]] = []
        for fact in spec.facts:
            m = _FACT_RE.match(fact)
            if not m:
                raise ValueError(f"{spec.name}: malformed fact {fact!r}")
            self.facts.append((m.group(1), parse_expr(m.group(2))))

    def rw(self, p: Poly) -> Poly:
        """Eliminate fact-defined symbols (``n0 == nb*r`` rewrites)."""
        for _ in range(len(self.facts) + 1):
            q = p
            for sym, rhs in self.facts:
                q = q.subst(sym, rhs)
            if q == p:
                return p
            p = q
        return p

    def poly(self, expr: str) -> Poly:
        return self.rw(parse_expr(expr))

    def nonneg(self, p: Poly, guard: str = "") -> bool:
        bounds = self.bounds
        if guard:
            g = _GUARD_RE.match(guard)
            if not g:
                raise ValueError(
                    f"{self.spec.name}: malformed guard {guard!r}")
            sym, op, rhs = g.group(1), g.group(2), self.rw(
                parse_expr(g.group(3)))
            lo, hi = bounds[sym]
            bounds = dict(bounds)
            bounds[sym] = (rhs, hi) if op == ">=" else (lo, rhs)
        return prove_nonneg(self.rw(p), self.order, bounds)


def _finding(invariant: str, spec, message: str, suggestion: str = "",
             subject: str = "") -> Finding:
    return Finding(_ANALYZER, invariant, message,
                   subject=subject or spec.name,
                   file=f"src/repro/kernels/{spec.site[0]}.py",
                   suggestion=suggestion)


def _check_halos(ctx: _SpecCtx) -> list[Finding]:
    out = []
    for halo in ctx.spec.halos:
        idx = ctx.poly(halo.index)
        ext = ctx.poly(halo.extent)
        ok_lo = ctx.nonneg(idx, halo.guard)
        ok_hi = ctx.nonneg(ext - Poly.const(1) - idx, halo.guard)
        if not (ok_lo and ok_hi):
            side = "below 0" if not ok_lo else "past the extent"
            out.append(_finding(
                "halo-out-of-bounds", ctx.spec,
                f"halo read {halo.array}[{halo.index}] "
                f"(guard {halo.guard or 'none'!s}) can index {side} of "
                f"extent {halo.extent} for some admissible grid size",
                suggestion="tighten the halo guard to the zero-filled "
                           "boundary bands, or shrink the read row "
                           "expression"))
    return out


def _check_input_tiles(ctx: _SpecCtx) -> list[Finding]:
    out = []
    for tile in ctx.spec.inputs:
        bad_dim = None
        for d in range(len(tile.block)):
            idx = ctx.poly(tile.index[d])
            blk = ctx.poly(tile.block[d])
            ext = ctx.poly(tile.extent[d])
            lo = idx * blk
            hi = ext - idx * blk - blk
            if not (ctx.nonneg(lo) and ctx.nonneg(hi)):
                bad_dim = d
                break
        if bad_dim is not None:
            out.append(_finding(
                "tile-out-of-bounds", ctx.spec,
                f"input {tile.name!r} dim {bad_dim}: block "
                f"{tile.block[bad_dim]} at index {tile.index[bad_dim]} "
                f"escapes extent {tile.extent[bad_dim]} for some "
                "admissible grid size",
                subject=f"{ctx.spec.name}.{tile.name}",
                suggestion="fix the BlockSpec index map or the declared "
                           "extent fact"))
    return out


def _check_coverage(ctx: _SpecCtx) -> list[Finding]:
    """Exactly-once output coverage: per-dim stride/edge proofs plus the
    no-unused-grid-symbol race condition."""
    spec = ctx.spec
    out: list[Finding] = []
    grid_syms = set(spec.grid)
    for tile in spec.outputs:
        used: set[str] = set()
        dim_findings: list[Finding] = []
        for d in range(len(tile.block)):
            idx = ctx.poly(tile.index[d])
            blk = ctx.poly(tile.block[d])
            ext = ctx.poly(tile.extent[d])
            syms = idx.vars() & grid_syms
            if not syms:
                if not (idx.is_zero() and blk == ext):
                    dim_findings.append(_finding(
                        "grid-write-gap", spec,
                        f"output {tile.name!r} dim {d}: constant index "
                        f"{tile.index[d]} with block {tile.block[d]} does "
                        f"not span extent {tile.extent[d]}",
                        subject=f"{spec.name}.{tile.name}"))
                continue
            if len(syms) > 1:
                dim_findings.append(_finding(
                    "grid-write-gap", spec,
                    f"output {tile.name!r} dim {d}: index map "
                    f"{tile.index[d]} mixes grid symbols "
                    f"{sorted(syms)}; coverage is unprovable",
                    subject=f"{spec.name}.{tile.name}"))
                continue
            (g,) = syms
            used.add(g)
            g_lo, g_hi = ctx.bounds[g]
            step = (idx.subst(g, Poly.var(g) + Poly.const(1)) - idx) * blk
            start = idx.subst(g, ctx.rw(g_lo)) * blk
            end = (idx.subst(g, ctx.rw(g_hi)) * blk + blk
                   if g_hi is not None else None)
            if step != blk:
                kind = ("grid-write-gap"
                        if prove_nonneg(ctx.rw(step - blk - Poly.const(1)),
                                        ctx.order, ctx.bounds)
                        else "grid-write-overlap")
                dim_findings.append(_finding(
                    kind, spec,
                    f"output {tile.name!r} dim {d}: grid stride "
                    f"({step.render()}) != block ({blk.render()}) — "
                    "adjacent grid steps "
                    + ("leave uncovered elements" if kind == "grid-write-gap"
                       else "write overlapping blocks"),
                    subject=f"{spec.name}.{tile.name}"))
            elif not ctx.rw(start).is_zero():
                dim_findings.append(_finding(
                    "grid-write-gap", spec,
                    f"output {tile.name!r} dim {d}: first block starts at "
                    f"{ctx.rw(start).render()}, not 0",
                    subject=f"{spec.name}.{tile.name}"))
            elif end is not None and ctx.rw(end - ext) != Poly.const(0):
                over = ctx.rw(end - ext)
                kind = ("grid-write-gap"
                        if prove_nonneg(ctx.rw(ext - end - Poly.const(1)),
                                        ctx.order, ctx.bounds)
                        else "tile-out-of-bounds")
                dim_findings.append(_finding(
                    kind, spec,
                    f"output {tile.name!r} dim {d}: last block ends at "
                    f"{ctx.rw(end).render()} but the extent is "
                    f"{ext.render()} (difference {over.render()})",
                    subject=f"{spec.name}.{tile.name}"))
        unused = grid_syms - used
        if unused and not spec.sequential_revisit:
            # root cause subsumes any constant-index dim findings
            out.append(_finding(
                "grid-write-overlap", spec,
                f"output {tile.name!r}: grid symbol(s) {sorted(unused)} do "
                "not appear in the output index map — every step of that "
                "grid axis rewrites the same block (write race under "
                "parallel grids, silent last-writer-wins otherwise)",
                subject=f"{spec.name}.{tile.name}",
                suggestion="index the output block by every grid symbol, "
                           "or declare sequential_revisit=True for a "
                           "deliberate TPU sequential-grid accumulator"))
        else:
            out.extend(dim_findings)
    return out


def _check_vmem(ctx: _SpecCtx, env: Envelope, budget: int) -> list[Finding]:
    p = ctx.poly(ctx.spec.vmem_elems).subst(
        "F", Poly.const(env.max_field_elems))
    val = p.const_value()
    if val is None:
        return [_finding(
            "vmem-budget", ctx.spec,
            f"vmem_elems {ctx.spec.vmem_elems!r} does not reduce to a "
            "constant under the envelope (free symbols "
            f"{sorted(p.vars())})",
            suggestion="express the footprint over F and literals")]
    dtype_bytes = max([t.dtype_bytes for t in
                       ctx.spec.inputs + ctx.spec.outputs] or [4])
    used = val * dtype_bytes
    if used > budget:
        return [_finding(
            "vmem-budget", ctx.spec,
            f"per-cell VMEM footprint {used} bytes "
            f"({ctx.spec.vmem_elems} elems at F={env.max_field_elems}) "
            f"exceeds the {budget}-byte budget",
            suggestion="shrink MAX_BAND / the tile, or lower the "
                       "envelope's max_field_elems")]
    return []


# ---------------------------------------------------------------------------
# the bounded-exhaustive unpack lemma
# ---------------------------------------------------------------------------

def check_unpack_lemma(wpb_extra: int | None = None) -> list[Finding]:
    """Prove the in-kernel unpack word window is wide enough.

    ``band_payload`` gives each band ``nv*bits // 32 + WPB_EXTRA`` words.
    Writing ``nv*bits = 32*Q + m``, the last value's bit offset is
    ``s0 + nv*bits - bits``, so its word index is ``Q + floor((s0 + m -
    bits)/32)`` and a carry read adds one more.  Sweeping every
    ``(bits, s0, m)`` in ``[1,32) x [0,32) x [0,32)`` covers all bands of
    all lengths — offsets grow monotonically in the value index, so the
    last value dominates.
    """
    if wpb_extra is None:
        from repro.kernels import specs as kspecs
        wpb_extra = kspecs.WPB_EXTRA
    for bits in range(1, 32):
        for s0 in range(32):
            for m in range(32):
                d = s0 + m - bits
                widx_rel = math.floor(d / 32)
                shift = d % 32
                carry = shift > 32 - bits
                hi_read = widx_rel + (1 if carry else 0)
                if max(widx_rel, hi_read) > wpb_extra - 1:
                    return [Finding(
                        _ANALYZER, "unpack-oob",
                        f"in-kernel unpack at bits={bits}, in-word offset "
                        f"{s0}, band-bit residue {m} reads relative word "
                        f"Q{max(widx_rel, hi_read):+d} but the window has "
                        f"only {wpb_extra} words past Q",
                        subject="fused._unpack_span",
                        file="src/repro/kernels/fused.py",
                        suggestion="restore WPB_EXTRA = 2 in "
                                   "repro.kernels.specs (offset word + "
                                   "carry word)")]
    return []


# ---------------------------------------------------------------------------
# output-multiply (FMA-contraction hazard) lint
# ---------------------------------------------------------------------------

def _waivers(source: str) -> dict[int, list[tuple[int, str]]]:
    """Line -> [(comment line, invariant)] — a waiver covers its own line
    and the one below."""
    out: dict[int, list[tuple[int, str]]] = {}
    for i, line in enumerate(source.splitlines(), start=1):
        m = _WAIVE_RE.search(line)
        if m:
            for w in m.group(1).split(","):
                w = w.strip()
                if w:
                    out.setdefault(i, []).append((i, w))
                    out.setdefault(i + 1, []).append((i, w))
    return out


def _is_ref_store(target: ast.AST) -> bool:
    """Is this subscript-assignment target an output ref?  Matches
    ``<name>_ref[...]`` and the ``next(outs)[...]`` iterator idiom."""
    if not isinstance(target, ast.Subscript):
        return False
    base = target.value
    if isinstance(base, ast.Name) and base.id.endswith("_ref"):
        return True
    return (isinstance(base, ast.Call) and isinstance(base.func, ast.Name)
            and base.func.id == "next")


def _floatish(node: ast.AST) -> bool:
    """Does the expression involve float arithmetic?  (float constants,
    any dotted name mentioning float, ``.astype(...)`` casts.)"""
    for n in ast.walk(node):
        if isinstance(n, ast.Constant) and isinstance(n.value, float):
            return True
        if isinstance(n, ast.Attribute) and ("float" in n.attr
                                             or n.attr == "astype"):
            return True
        if isinstance(n, ast.Name) and "float" in n.id:
            return True
    return False


class _KernelLint:
    """Resolve stored-expression roots through local helpers and flag
    root-level float multiplies feeding output refs."""

    def __init__(self, tree: ast.Module):
        self.defs: dict[str, ast.AST] = {}
        for n in ast.walk(tree):
            if isinstance(n, (ast.FunctionDef, ast.AsyncFunctionDef)):
                # last definition wins; nested defs shadow by name
                self.defs[n.name] = n

    def resolve_root(self, node: ast.AST, fdef: ast.AST,
                     seen: set | None = None) -> ast.AST:
        seen = seen or set()
        while True:
            if isinstance(node, ast.BinOp):
                return node
            if (isinstance(node, ast.Call)
                    and isinstance(node.func, ast.Name)
                    and node.func.id in self.defs
                    and node.func.id not in seen):
                seen.add(node.func.id)
                fdef = self.defs[node.func.id]
                rets = [r for r in ast.walk(fdef)
                        if isinstance(r, ast.Return) and r.value is not None]
                if not rets:
                    return node
                node = rets[-1].value
                continue
            if isinstance(node, ast.Name):
                key = (id(fdef), node.id)
                if key in seen:
                    return node
                seen.add(key)
                assigns = [a for a in ast.walk(fdef)
                           if isinstance(a, ast.Assign)
                           and any(isinstance(t, ast.Name) and t.id == node.id
                                   for t in a.targets)]
                if not assigns:
                    return node
                node = assigns[-1].value
                continue
            return node


def lint_kernel_source(source: str, path: str = "<string>"
                       ) -> tuple[list[Finding], list[tuple[int, str]],
                                  set[tuple[int, str]]]:
    """Output-multiply lint for one kernel module.

    Returns ``(findings, declared_waivers, used_waivers)`` so the caller
    can run stale-waiver detection across the package.
    """
    tree = ast.parse(source)
    waivers = _waivers(source)
    declared = sorted({w for ws in waivers.values() for w in ws})
    used: set[tuple[int, str]] = set()
    lint = _KernelLint(tree)
    findings: list[Finding] = []

    def flag(node: ast.AST, message: str):
        line = getattr(node, "lineno", 0)
        hits = [w for w in waivers.get(line, [])
                if w[1] == "output-multiply"]
        if hits:
            used.update(hits)
            return
        findings.append(Finding(
            _ANALYZER, "output-multiply", message,
            subject="kernel store", file=path, line=line,
            suggestion="emit the unscaled integer/accumulated plane and "
                       "apply the float tail in the XLA lowering rule "
                       "(# audit: waive(output-multiply) if deliberate)"))

    for fdef in ast.walk(tree):
        if not isinstance(fdef, (ast.FunctionDef, ast.AsyncFunctionDef)):
            continue
        for stmt in ast.walk(fdef):
            if isinstance(stmt, ast.Assign):
                targets = stmt.targets
                value = stmt.value
            elif isinstance(stmt, ast.AugAssign):
                targets = [stmt.target]
                value = None
            else:
                continue
            if not any(_is_ref_store(t) for t in targets):
                continue
            if isinstance(stmt, ast.AugAssign):
                if (isinstance(stmt.op, ast.Mult)
                        and _floatish(stmt.value)):
                    flag(stmt, "augmented float multiply into an output "
                               "ref (FMA-contraction hazard)")
                continue
            root = lint.resolve_root(value, fdef)
            if (isinstance(root, ast.BinOp)
                    and isinstance(root.op, ast.Mult)
                    and (_floatish(value) or _floatish(root))):
                flag(stmt, "float multiply is the final op feeding an "
                           "output ref — XLA CPU fusion can duplicate and "
                           "FMA-contract it shape-dependently, breaking "
                           "bit-identity (the PR 8 hazard)")
    return findings, declared, used


# ---------------------------------------------------------------------------
# spec <-> call-site sync
# ---------------------------------------------------------------------------

def _scan_sites(src_root: Path) -> dict[tuple[str, str, int], int | None]:
    """Every ``pl.pallas_call`` site under ``kernels/`` keyed by
    (module, enclosing function, ordinal); value is the literal grid
    arity when extractable."""
    sites: dict[tuple[str, str, int], int | None] = {}
    for py in sorted((src_root / "kernels").glob("*.py")):
        module = py.stem
        tree = ast.parse(py.read_text())
        for fdef in tree.body:
            if not isinstance(fdef, (ast.FunctionDef, ast.AsyncFunctionDef)):
                continue
            ordinal = 0
            for node in ast.walk(fdef):
                if not (isinstance(node, ast.Call)
                        and isinstance(node.func, ast.Attribute)
                        and node.func.attr == "pallas_call"):
                    continue
                arity = None
                for kw in node.keywords:
                    if kw.arg == "grid" and isinstance(kw.value, ast.Tuple):
                        arity = len(kw.value.elts)
                sites[(module, fdef.name, ordinal)] = arity
                ordinal += 1
    return sites


def _check_sites(specs, src_root: Path) -> list[Finding]:
    sites = _scan_sites(src_root)
    by_site = {s.site: s for s in specs}
    out: list[Finding] = []
    for site, arity in sorted(sites.items()):
        spec = by_site.get(site)
        if spec is None:
            out.append(Finding(
                _ANALYZER, "undeclared-kernel",
                f"pl.pallas_call site #{site[2]} in {site[1]}() has no "
                "KernelSpec — its grid/bounds/race invariants are "
                "unverified",
                subject=f"{site[0]}.{site[1]}",
                file=f"src/repro/kernels/{site[0]}.py",
                suggestion="declare the site in repro.kernels.specs."
                           "KERNEL_SPECS"))
        elif arity is not None and arity != len(spec.grid):
            out.append(Finding(
                _ANALYZER, "spec-grid-mismatch",
                f"{spec.name}: spec declares {len(spec.grid)} grid "
                f"dimension(s) but the call site has {arity}",
                subject=spec.name,
                file=f"src/repro/kernels/{site[0]}.py",
                suggestion="update the KernelSpec grid symbols"))
    for spec in specs:
        if spec.site not in sites:
            out.append(Finding(
                _ANALYZER, "stale-kernel-spec",
                f"KernelSpec {spec.name!r} names call site {spec.site} "
                "which no longer exists",
                subject=spec.name, file="src/repro/kernels/specs.py",
                suggestion="delete or re-point the spec"))
    return out


# ---------------------------------------------------------------------------
# entry point
# ---------------------------------------------------------------------------

def check_spec(spec, env: Envelope = DEFAULT_ENVELOPE, *,
               vmem_budget_bytes: int = VMEM_BUDGET_BYTES) -> list[Finding]:
    """All symbolic checks for one :class:`KernelSpec` (fixture entry)."""
    try:
        ctx = _SpecCtx(spec)
    except ValueError as e:
        return [Finding(_ANALYZER, "spec-unprovable", str(e),
                        subject=spec.name)]
    findings = _check_halos(ctx)
    findings += _check_input_tiles(ctx)
    findings += _check_coverage(ctx)
    findings += _check_vmem(ctx, env, vmem_budget_bytes)
    return findings


def analyze_kernel_specs(env: Envelope = DEFAULT_ENVELOPE, *,
                         specs=None, src_root: str | Path | None = None,
                         vmem_budget_bytes: int = VMEM_BUDGET_BYTES,
                         wpb_extra: int | None = None) -> list[Finding]:
    """Run the kernel verifier against the live specs and kernel sources.

    ``specs`` / ``src_root`` / ``wpb_extra`` are injectable for the
    sabotage fixtures; defaults audit the real repo.
    """
    if specs is None:
        from repro.kernels.specs import KERNEL_SPECS
        specs = KERNEL_SPECS
    if src_root is None:
        src_root = Path(__file__).resolve().parent.parent
    src_root = Path(src_root)

    findings: list[Finding] = []
    for spec in specs:
        findings.extend(check_spec(spec, env,
                                   vmem_budget_bytes=vmem_budget_bytes))
    if any(s.unpack_words for s in specs):
        findings.extend(check_unpack_lemma(wpb_extra))

    declared_all: list[tuple[str, int, str]] = []
    used_all: set[tuple[str, int, str]] = set()
    kdir = src_root / "kernels"
    if kdir.is_dir():
        for py in sorted(kdir.glob("*.py")):
            rel = str(py.relative_to(src_root.parent.parent))
            fs, declared, used = lint_kernel_source(py.read_text(), rel)
            findings.extend(fs)
            declared_all += [(rel, ln, name) for ln, name in declared
                             if name == "output-multiply"]
            used_all |= {(rel, ln, name) for ln, name in used}
        findings.extend(_check_sites(specs, src_root))
    for rel, ln, name in declared_all:
        if (rel, ln, name) not in used_all:
            findings.append(Finding(
                _ANALYZER, "stale-waiver",
                f"# audit: waive({name}) suppresses no kernelspec finding "
                "— the waived code has moved or been fixed",
                subject=name, file=rel, line=ln, severity="warning",
                suggestion="delete the stale waiver comment"))
    return findings
