"""Analyzer (2): integer-width abstract interpretation (DESIGN.md §11).

Every bit-identity guarantee in this reproduction rides on int32 arithmetic
that must not wrap *meaningfully*: quantization indices, Lorenzo /
block-mean residuals, bit-packed payload words, the stage-② integer sum
accumulators, and the streaming :class:`~repro.core.oplib.TemporalSummary`
``{count, Σq, Σq²}`` leaves.  PR 2 fixed two of these reactively (payload
bit accounting and ``compression_ratio`` past 2^26 elements); this pass
proves the rest *statically* by propagating value-range intervals through
the pipeline:

    quantize → (Lorenzo diffs | block-mean residuals) → zigzag/bitpack
             → stage-② sum accumulators → TemporalSummary {count, Σq, Σq²}

under a declared :class:`Envelope` (``|q| ≤ 2^q_bits − 1``, maximum field
size, maximum appended timesteps).  Violations — an accumulator whose
worst-case magnitude exceeds int32 under the envelope — are findings; the
per-scheme maximum safe field size / slab count is emitted as a
machine-readable table (``AUDIT.json``, ``safe_size_table()``) and is the
source of the runtime guard ``repro.stream.temporal.summary_capacity``
(checked here for presence and agreement).
"""
from __future__ import annotations

import math
from dataclasses import dataclass

from repro.core.stages import Scheme

from .findings import Finding

_ANALYZER = "intwidth"

INT32_MAX = 2**31 - 1
UINT32_MAX = 2**32 - 1
#: f32 integer-exactness horizon: sums beyond 2^24 lose exactness (not an
#: overflow — reported in the table, never as a finding).
F32_EXACT = 2**24

#: largest block configured by the pipeline (DEFAULT_BLOCKS: 256 / 16×16 /
#: 8×8×8 — all 256 elements; callers may configure up to this).
MAX_BLOCK_ELEMS = 4096


# ---------------------------------------------------------------------------
# interval domain
# ---------------------------------------------------------------------------

@dataclass(frozen=True)
class Interval:
    """Closed integer interval [lo, hi] — the abstract value domain."""

    lo: int
    hi: int

    def __post_init__(self):
        if self.lo > self.hi:
            raise ValueError(f"empty interval [{self.lo}, {self.hi}]")

    @classmethod
    def sym(cls, mag: int) -> "Interval":
        """Symmetric interval [-mag, mag]."""
        return cls(-mag, mag)

    @property
    def mag(self) -> int:
        return max(abs(self.lo), abs(self.hi))

    def __add__(self, other: "Interval") -> "Interval":
        return Interval(self.lo + other.lo, self.hi + other.hi)

    def __sub__(self, other: "Interval") -> "Interval":
        return Interval(self.lo - other.hi, self.hi - other.lo)

    def __mul__(self, other: "Interval") -> "Interval":
        ps = (self.lo * other.lo, self.lo * other.hi,
              self.hi * other.lo, self.hi * other.hi)
        return Interval(min(ps), max(ps))

    def square(self) -> "Interval":
        if self.lo <= 0 <= self.hi:
            lo = 0
        else:
            lo = min(self.lo * self.lo, self.hi * self.hi)
        return Interval(lo, max(self.lo * self.lo, self.hi * self.hi))

    def sum_n(self, n: int) -> "Interval":
        """Worst-case sum of ``n`` independent values from this interval."""
        return Interval(self.lo * n, self.hi * n)

    def fits_int32(self) -> bool:
        return -INT32_MAX - 1 <= self.lo and self.hi <= INT32_MAX

    def zigzag(self) -> "Interval":
        """u = (p << 1) ^ (p >> 31): unsigned magnitude-ordered image."""
        return Interval(0, 2 * self.mag)


# ---------------------------------------------------------------------------
# operating envelope
# ---------------------------------------------------------------------------

@dataclass(frozen=True)
class Envelope:
    """Declared operating envelope the deployment promises to stay inside.

    ``q_bits``
        magnitude bits of the quantization indices: ``|q| ≤ 2^q_bits − 1``.
        With the value-range-relative bound ``eps = rel_eb · range`` this is
        ``q_bits = ceil(log2(1 / (2 · rel_eb)))`` — e.g. ``rel_eb = 1e-4``
        gives ``q_bits = 13``.
    ``max_field_elems``
        largest spatial field (elements) queried at any stage.
    ``max_slab_steps``
        most timesteps ever appended to one temporal stream.
    """

    q_bits: int = 12
    max_field_elems: int = 2**17
    max_slab_steps: int = 128

    @property
    def q_abs(self) -> int:
        return 2**self.q_bits - 1


DEFAULT_ENVELOPE = Envelope()


def summary_capacity(q_abs: int) -> int:
    """Max timesteps an int32 :class:`TemporalSummary` holds exactly when
    every index satisfies ``|q| ≤ q_abs`` — the binding constraint is the
    ``Σq²`` leaf (``T · q_abs² ≤ 2^31 − 1``), then ``Σq``, then ``count``.

    This is THE capacity formula: ``repro.stream.temporal.summary_capacity``
    must agree (checked by :func:`analyze_int_width`), and the runtime guard
    in ``TemporalField.append`` enforces it against the *measured* per-slab
    ``|q|`` bound, so long-stream appends fail loudly instead of wrapping.
    """
    q_abs = int(q_abs)
    if q_abs < 0:
        raise ValueError(f"negative |q| bound: {q_abs}")
    if q_abs == 0:
        return INT32_MAX  # all-zero stream: only the count leaf can wrap
    return min(INT32_MAX // (q_abs * q_abs), INT32_MAX // q_abs, INT32_MAX)


# ---------------------------------------------------------------------------
# per-scheme pipeline propagation
# ---------------------------------------------------------------------------

def _ndim(scheme: Scheme) -> int:
    """Worst-case rank the scheme's decorrelation runs over (1-D schemes
    flatten; nd schemes support up to 3 axes)."""
    return 3 if Scheme(scheme).is_nd else 1


def pipeline_bounds(scheme: Scheme, env: Envelope) -> dict:
    """Propagate intervals through one scheme's pipeline; returns the named
    accumulator table ``{name: {"interval": Interval, "dtype", "limit",
    "max_field_elems"/"max_steps"}}`` the findings and the safe-size table
    both read."""
    scheme = Scheme(scheme)
    q = Interval.sym(env.q_abs)
    n = env.max_field_elems
    acc: dict[str, dict] = {}

    def int32_acc(name: str, interval: Interval, **extra):
        acc[name] = {"interval": interval, "dtype": "int32",
                     "limit": INT32_MAX, **extra}

    # quantize: int32 indices
    int32_acc("quantize.q", q)

    # decorrelate
    if scheme.is_blockmean:
        # block mean: int32 sum of <= MAX_BLOCK_ELEMS indices, then divide
        int32_acc("decorrelate.block_sum", q.sum_n(MAX_BLOCK_ELEMS))
        mean = q  # rounded mean of values in q's interval stays inside it
        p = q - mean                      # residual = q - M_b
    else:
        # Lorenzo: one first-difference per axis doubles the magnitude
        p = q
        for _ in range(_ndim(scheme)):
            p = p - q if p is q else Interval.sym(2 * p.mag)
        p = Interval.sym((2 ** _ndim(scheme)) * env.q_abs)
    int32_acc("decorrelate.residual", p)

    # zigzag / bitpack: uint32 plane; width <= 32 by construction
    u = p.zigzag()
    acc["encode.zigzag"] = {"interval": u, "dtype": "uint32",
                            "limit": UINT32_MAX}

    # recorrelation (stage ③ reconstruction) is exact by inverse identity:
    # cumsum(p) == q, so the reconstructed indices live back in q's interval
    int32_acc("recorrelate.q", q)

    # stage-②/① integer sum accumulators (repro.core.oplib lowering rules)
    if scheme.is_blockmean:
        meta_sum = q.sum_n(n)             # _mean_m: sum M_b * overlap_b
        int32_acc("oplib.mean_m.metadata_sum", meta_sum,
                  max_field_elems=INT32_MAX // max(q.mag, 1))
        res_sum = p.sum_n(n)              # _mean_p_blockmean: masked_sum(p)
        int32_acc("oplib.mean_p.residual_sum", res_sum,
                  max_field_elems=INT32_MAX // max(p.mag, 1))
        tot = meta_sum + res_sum          # _std_p_blockmean: tot = s + Σ p_win
        int32_acc("oplib.std_p.window_sum", tot,
                  max_field_elems=INT32_MAX // max(q.mag + p.mag, 1))
    # Lorenzo stage-② statistics contract through f32 (weighted dots /
    # stat_values) — no int32 field-sized accumulator; exactness horizon
    # F32_EXACT is reported in the table, not a finding.

    # temporal summaries (repro.core.oplib.summary_from_q / merge_summaries)
    t = env.max_slab_steps
    int32_acc("temporal.count", Interval(0, t), max_steps=INT32_MAX)
    int32_acc("temporal.q_sum", q.sum_n(t),
              max_steps=INT32_MAX // max(q.mag, 1))
    int32_acc("temporal.q_sumsq", q.square().sum_n(t),
              max_steps=INT32_MAX // max(q.mag * q.mag, 1))
    return acc


def safe_size_table(env: Envelope = DEFAULT_ENVELOPE) -> dict:
    """Machine-readable per-scheme safe sizes under ``env`` (the table
    DESIGN.md §11 documents and ``AUDIT.json`` carries)."""
    table: dict[str, dict] = {"envelope": {
        "q_bits": env.q_bits, "q_abs": env.q_abs,
        "max_field_elems": env.max_field_elems,
        "max_slab_steps": env.max_slab_steps,
        "f32_exact_horizon": F32_EXACT,
    }}
    for scheme in Scheme:
        acc = pipeline_bounds(scheme, env)
        field_caps = [v["max_field_elems"] for v in acc.values()
                      if "max_field_elems" in v]
        step_caps = [v["max_steps"] for v in acc.values()
                     if "max_steps" in v]
        table[scheme.value] = {
            "residual_abs_max": acc["decorrelate.residual"]["interval"].mag,
            "max_safe_field_elems": min(field_caps, default=INT32_MAX),
            "max_safe_slab_steps": min(step_caps, default=INT32_MAX),
            "summary_capacity": summary_capacity(env.q_abs),
            "accumulators": {
                name: {"lo": v["interval"].lo, "hi": v["interval"].hi,
                       "dtype": v["dtype"],
                       "headroom_bits": (
                           math.floor(math.log2(v["limit"] / v["interval"].mag))
                           if v["interval"].mag else 32)}
                for name, v in acc.items()},
        }
    return table


# ---------------------------------------------------------------------------
# checks
# ---------------------------------------------------------------------------

def _probe_payload_accounting() -> list[Finding]:
    """Semantic probe: the serialized-size accounting must accumulate in
    floating point (int32 payload-bit sums wrap past 2^31 bits — the exact
    PR 2 bug), and ``compression_ratio`` must compute ``n * 32`` in float
    (int32 wraps for fields ≥ 2^26 elements)."""
    import types

    import jax.numpy as jnp

    from repro.core import encode
    from repro.core.pipeline import hszx

    out = []
    bits = encode.serialized_bits(jnp.full((4,), 16, jnp.int32),
                                  jnp.full((4,), 256, jnp.int32),
                                  meta_bits_per_block=32)
    if not jnp.issubdtype(jnp.asarray(bits).dtype, jnp.floating):
        out.append(Finding(
            _ANALYZER, "payload-bits-overflow",
            "encode.serialized_bits accumulates payload bits in "
            f"{jnp.asarray(bits).dtype}; int accumulation wraps past 2^31 "
            "bits (~1e8 elements at 16 bits/value)",
            subject="encode.serialized_bits",
            suggestion="sum payload bits in f32 (see PR 2)"))

    # a field of 2^27 elements at 32 bits/value: int32 n*32 would wrap
    fake = types.SimpleNamespace(
        n=2**27, bitwidths=jnp.full((4,), 16, jnp.int32),
        valid_counts=jnp.full((4,), 256, jnp.int32), scheme=Scheme.HSZX)
    ratio = float(hszx.compression_ratio(fake))
    expected = (2**27 * 32.0) / float(hszx.serialized_bits(fake))
    if not (ratio > 0 and abs(ratio - expected) < 1e-3 * expected):
        out.append(Finding(
            _ANALYZER, "ratio-overflow",
            f"compression_ratio computes {ratio} for a 2^27-element field "
            f"(expected {expected:.1f}); the original-bits product is "
            "wrapping in integer arithmetic",
            subject="pipeline.compression_ratio",
            suggestion="compute original bits as float(n) * 32.0 (see PR 2)"))
    return out


def _check_runtime_guard() -> list[Finding]:
    """The streaming satellite of this analyzer: ``repro.stream.temporal``
    must expose the capacity formula and enforce it on append."""
    out = []
    try:
        from repro.stream import temporal
    except Exception as e:  # noqa: BLE001 - report, don't crash the audit
        return [Finding(
            _ANALYZER, "unguarded-accumulator",
            f"repro.stream.temporal failed to import ({e!r}); cannot verify "
            "the TemporalSummary capacity guard",
            subject="stream.temporal")]
    guard = getattr(temporal, "summary_capacity", None)
    if guard is None or getattr(temporal, "SummaryCapacityError", None) is None:
        out.append(Finding(
            _ANALYZER, "unguarded-accumulator",
            "repro.stream.temporal has no summary_capacity / "
            "SummaryCapacityError: int32 TemporalSummary accumulators can "
            "wrap silently on long streams",
            subject="stream.temporal.summary_capacity",
            suggestion="enforce the audited capacity in TemporalField.append"))
        return out
    for q_abs in (0, 1, 255, 4095, 2**15, 2**20):
        if guard(q_abs) != summary_capacity(q_abs):
            out.append(Finding(
                _ANALYZER, "guard-mismatch",
                f"stream.temporal.summary_capacity({q_abs}) = "
                f"{guard(q_abs)} but the audited bound is "
                f"{summary_capacity(q_abs)}",
                subject="stream.temporal.summary_capacity",
                suggestion="derive the runtime guard from the audited "
                           "formula (one source of truth)"))
            break
    return out


def analyze_int_width(env: Envelope = DEFAULT_ENVELOPE, *,
                      probe_runtime: bool = True) -> list[Finding]:
    """Run the int-width pass: interval propagation per scheme under
    ``env`` plus (when ``probe_runtime``) the semantic accounting probes
    and the runtime-guard presence check."""
    findings: list[Finding] = []
    for scheme in Scheme:
        for name, v in pipeline_bounds(scheme, env).items():
            iv: Interval = v["interval"]
            if iv.mag > v["limit"] or (v["dtype"] == "int32"
                                       and not iv.fits_int32()):
                invariant = ("sumsq-overflow" if name.endswith("q_sumsq")
                             else "sum-overflow" if "sum" in name
                             else "width-overflow")
                findings.append(Finding(
                    _ANALYZER, invariant,
                    f"{scheme.value}: accumulator {name} spans "
                    f"[{iv.lo}, {iv.hi}] — exceeds {v['dtype']} under the "
                    f"declared envelope (|q| ≤ {env.q_abs}, "
                    f"N ≤ {env.max_field_elems}, T ≤ {env.max_slab_steps})",
                    subject=name,
                    suggestion="shrink the envelope (max field size / slab "
                               "count / q_bits) or widen the accumulator"))
    if probe_runtime:
        findings.extend(_probe_payload_accounting())
        findings.extend(_check_runtime_guard())
    return findings
