"""Structured findings for the static invariant audit (DESIGN.md §11).

Every analyzer reports :class:`Finding` records — never free-form prints —
so the CLI can render them uniformly, ``AUDIT.json`` stays machine-readable
for CI artifacts, and tests can assert on exact (analyzer, invariant)
pairs.  A finding names the *invariant* it protects, not just the symptom:
the six families are the registry completeness matrix, the int32 width
bounds, trace safety (no host syncs / tracer branches under jit),
jit-cache-key soundness, kernel grid/bounds/race freedom, and
shard-partition exactness.

Findings carry a ``severity``: ``error`` findings fail the audit (nonzero
exit); ``warning`` findings — today only stale-waiver reports — are printed
and serialized but do not flip ``ok``.
"""
from __future__ import annotations

from dataclasses import asdict, dataclass, field

#: AUDIT.json schema version.  Bump whenever the serialized shape changes.
#: v2: added ``schema_version``, per-finding ``severity``, ``n_errors`` /
#: ``n_warnings`` counts, and the ``shard_safe_sizes`` per-world table.
SCHEMA_VERSION = 2


@dataclass(frozen=True)
class Finding:
    """One audit violation.

    ``analyzer``  — which pass produced it (``registry`` / ``intwidth`` /
    ``trace`` / ``jitkey`` / ``kernelspec`` / ``sharddisjoint``).
    ``invariant`` — short machine-stable identifier of the violated rule
    (e.g. ``missing-lowering-rule``, ``sumsq-overflow``, ``host-sync``,
    ``unkeyed-closure``, ``halo-out-of-bounds``, ``word-owner-overlap``);
    tests and CI gates key on it.
    ``file`` / ``line`` — source location when the pass is syntactic;
    semantic passes (registry, intwidth, sharddisjoint) locate by subject.
    ``subject`` — what the finding is about (op name, accumulator, symbol).
    ``message`` — human-readable statement of the violation.
    ``suggestion`` — the concrete fix (add the rule, key the variable,
    waive with the documented comment syntax, ...).
    ``severity`` — ``error`` (fails the audit) or ``warning`` (reported
    but does not affect the exit code; used for stale waivers).
    """

    analyzer: str
    invariant: str
    message: str
    subject: str = ""
    file: str | None = None
    line: int | None = None
    suggestion: str = ""
    severity: str = "error"

    @property
    def is_error(self) -> bool:
        return self.severity == "error"

    def location(self) -> str:
        if self.file is None:
            return self.subject or "<registry>"
        loc = self.file if self.line is None else f"{self.file}:{self.line}"
        return f"{loc} ({self.subject})" if self.subject else loc

    def render(self) -> str:
        tag = f"{self.analyzer}/{self.invariant}"
        if not self.is_error:
            tag += f" {self.severity}"
        out = f"[{tag}] {self.location()}: {self.message}"
        if self.suggestion:
            out += f"\n    fix: {self.suggestion}"
        return out

    def to_dict(self) -> dict:
        return asdict(self)


@dataclass
class AuditReport:
    """The full audit result: findings plus the derived safe-size tables."""

    findings: list[Finding] = field(default_factory=list)
    #: analyzer (2)'s machine-readable output: per-scheme maximum safe
    #: field sizes / slab counts under the declared operating envelope.
    safe_sizes: dict = field(default_factory=dict)
    #: analyzer (6)'s machine-readable output: per-world-size safe summary
    #: capacities and collective bit budgets (empty unless sharddisjoint
    #: ran).
    shard_safe_sizes: dict = field(default_factory=dict)

    @property
    def errors(self) -> list[Finding]:
        return [f for f in self.findings if f.is_error]

    @property
    def warnings(self) -> list[Finding]:
        return [f for f in self.findings if not f.is_error]

    @property
    def ok(self) -> bool:
        """Warnings (stale waivers) never fail the audit."""
        return not self.errors

    def extend(self, findings) -> None:
        self.findings.extend(findings)

    def to_dict(self) -> dict:
        by_analyzer: dict[str, int] = {}
        for f in self.findings:
            by_analyzer[f.analyzer] = by_analyzer.get(f.analyzer, 0) + 1
        return {
            "schema_version": SCHEMA_VERSION,
            "ok": self.ok,
            "n_findings": len(self.findings),
            "n_errors": len(self.errors),
            "n_warnings": len(self.warnings),
            "findings_by_analyzer": by_analyzer,
            "findings": [f.to_dict() for f in self.findings],
            "safe_sizes": self.safe_sizes,
            "shard_safe_sizes": self.shard_safe_sizes,
        }
