"""Structured findings for the static invariant audit (DESIGN.md §11).

Every analyzer reports :class:`Finding` records — never free-form prints —
so the CLI can render them uniformly, ``AUDIT.json`` stays machine-readable
for CI artifacts, and tests can assert on exact (analyzer, invariant)
pairs.  A finding names the *invariant* it protects, not just the symptom:
the four families are the registry completeness matrix, the int32 width
bounds, trace safety (no host syncs / tracer branches under jit), and
jit-cache-key soundness.
"""
from __future__ import annotations

from dataclasses import asdict, dataclass, field


@dataclass(frozen=True)
class Finding:
    """One audit violation.

    ``analyzer``  — which pass produced it (``registry`` / ``intwidth`` /
    ``trace`` / ``jitkey``).
    ``invariant`` — short machine-stable identifier of the violated rule
    (e.g. ``missing-lowering-rule``, ``sumsq-overflow``, ``host-sync``,
    ``unkeyed-closure``); tests and CI gates key on it.
    ``file`` / ``line`` — source location when the pass is syntactic;
    semantic passes (registry, intwidth) locate by subject instead.
    ``subject`` — what the finding is about (op name, accumulator, symbol).
    ``message`` — human-readable statement of the violation.
    ``suggestion`` — the concrete fix (add the rule, key the variable,
    waive with the documented comment syntax, ...).
    """

    analyzer: str
    invariant: str
    message: str
    subject: str = ""
    file: str | None = None
    line: int | None = None
    suggestion: str = ""

    def location(self) -> str:
        if self.file is None:
            return self.subject or "<registry>"
        loc = self.file if self.line is None else f"{self.file}:{self.line}"
        return f"{loc} ({self.subject})" if self.subject else loc

    def render(self) -> str:
        out = f"[{self.analyzer}/{self.invariant}] {self.location()}: {self.message}"
        if self.suggestion:
            out += f"\n    fix: {self.suggestion}"
        return out

    def to_dict(self) -> dict:
        return asdict(self)


@dataclass
class AuditReport:
    """The full audit result: findings plus the derived safe-size tables."""

    findings: list[Finding] = field(default_factory=list)
    #: analyzer (2)'s machine-readable output: per-scheme maximum safe
    #: field sizes / slab counts under the declared operating envelope.
    safe_sizes: dict = field(default_factory=dict)

    @property
    def ok(self) -> bool:
        return not self.findings

    def extend(self, findings) -> None:
        self.findings.extend(findings)

    def to_dict(self) -> dict:
        by_analyzer: dict[str, int] = {}
        for f in self.findings:
            by_analyzer[f.analyzer] = by_analyzer.get(f.analyzer, 0) + 1
        return {
            "ok": self.ok,
            "n_findings": len(self.findings),
            "findings_by_analyzer": by_analyzer,
            "findings": [f.to_dict() for f in self.findings],
            "safe_sizes": self.safe_sizes,
        }
