"""Analyzer (6): shard-partition exactness (DESIGN.md §11).

The shard layer's bit-identity claim — "scatter-add + psum over *disjoint*
word sets reassembles the single-device gather bitwise" — decomposes into
exactly the invariants this pass proves:

* **word-owner partition** — :meth:`BlockPlacement.word_owner` assigns
  every payload word to exactly one shard.  Symbolically: a word's owner
  is the round-robin residue of its first value's block-row, a total
  function, so ownership is a partition *by construction*; this pass
  re-derives the residue formula independently and then runs a
  bounded-exhaustive sweep over (world, layout, bits) checking that the
  per-shard stripes (:meth:`shard_word_index`) are pairwise disjoint and
  cover every word — so a refactor that breaks the construction (caching
  bug, straddle-word special case) is caught off any example the unit
  tests happen to use.
* **scatter disjointness** — the :func:`repro.shard.exec.gather_routing`
  destination index sets are pairwise disjoint across shards and cover
  the gathered word set exactly (padding rows land in the dropped slot),
  and each routed word is read from the right stripe position — the
  precondition for ``psum`` being *reassembly*, never accumulation.
* **band tiling** — :func:`repro.shard.exec.spatial_bands` tiles a query
  window's rows exactly once, which pins the summary-merge fan-in at 1.
* **world-scaled envelope** — cross-shard ``psum`` of
  :class:`TemporalSummary` leaves stays inside the ``intwidth`` envelope:
  with fan-in ``f`` (measured from the band tiling), the Σq² accumulator
  reaches ``f * max_slab_steps * q_abs**2``, which must fit int32.  The
  per-world safe-size table (:func:`shard_safe_size_table`) goes into
  AUDIT.json next to the single-device one.
* **collective container** — the int16 compressed-psum bit budget
  (:func:`repro.comm.hom_collectives.bit_budget`) keeps the worst-case
  accumulator under ``PSUM_CONTAINER_MAX`` for every supported world
  size (swept exhaustively; the ``max(2, ...)`` usability floor caps
  support below world 32768, documented at the source).

All checks are host-side numpy/arithmetic over *static* layout math — no
mesh, no devices — so an 8-fake-device CI job and a single-device run must
produce identical findings (the shard CI job diffs the tables to prove it).

Findings are deduplicated per invariant (first witness wins) and routing
checks are skipped for a layout whose partition already failed — one root
cause, one finding.
"""
from __future__ import annotations

from types import SimpleNamespace

import numpy as np

from repro.core import Scheme, encode
from .findings import Finding
from .intwidth import DEFAULT_ENVELOPE, INT32_MAX, Envelope, summary_capacity

_ANALYZER = "sharddisjoint"

#: world sizes for the layout sweeps (placements, routing, bands).
DEFAULT_WORLDS = (1, 2, 3, 4, 8)
#: upper bound of the exhaustive collective-container sweep; the
#: ``bit_budget`` floor makes larger worlds unsupported by construction.
MAX_COLLECTIVE_WORLD = 4096

#: layout sweep: (scheme, shape, padded_shape, block, bits, axis) —
#: covers nd/flat schemes, word-straddling bit widths (bits not dividing
#: 32), a non-zero shard axis, and ragged true shapes inside padding.
DEFAULT_CASES = (
    (Scheme.HSZP_ND, (100, 96), (112, 96), (16, 32), 7, 0),
    (Scheme.HSZP_ND, (100, 96), (112, 96), (16, 32), 12, 0),
    (Scheme.HSZX_ND, (12, 40, 16), (12, 48, 16), (1, 8, 16), 9, 1),
    (Scheme.HSZP, (1000,), (1024,), (256,), 5, 0),
    (Scheme.HSZX, (1000,), (1024,), (256,), 11, 0),
)


class _Collector:
    """First witness per invariant: an auditor wants the root cause, not
    every layout the same bug breaks."""

    def __init__(self):
        self.findings: list[Finding] = []
        self._seen: set[str] = set()

    def add(self, f: Finding) -> None:
        if f.invariant not in self._seen:
            self._seen.add(f.invariant)
            self.findings.append(f)


def _case_subject(scheme, padded, block, bits, world) -> str:
    return (f"BlockPlacement[{getattr(scheme, 'value', scheme)} "
            f"{padded}/{block} bits={bits} world={world}]")


def _ref_word_owner(placement, bits: int) -> np.ndarray:
    """Independent re-derivation of the round-robin residue formula: a
    word belongs to the shard owning the block-row of its first value."""
    n_values = int(np.prod(placement.padded_shape, dtype=np.int64))
    n_words = encode.words_for(n_values, bits)
    first_value = np.minimum(
        (np.arange(n_words, dtype=np.int64) * 32) // max(bits, 1),
        max(n_values - 1, 0))
    if placement.scheme.is_nd:
        stride = int(np.prod(placement.padded_shape[placement.axis + 1:],
                             dtype=np.int64))
        coord = (first_value // stride) % placement.padded_shape[
            placement.axis]
        return ((coord // placement.block[placement.axis])
                % placement.n_shards).astype(np.int32)
    return ((first_value // placement.block[0])
            % placement.n_shards).astype(np.int32)


def _check_partition(out: _Collector, placement, bits: int,
                     subject: str) -> bool:
    """Word stripes pairwise disjoint + covering; formula drift; unit
    round-robin.  Returns True when the partition holds (routing checks
    depend on it)."""
    n_values = int(np.prod(placement.padded_shape, dtype=np.int64))
    n_words = encode.words_for(n_values, bits)
    stripes = placement.shard_word_index(bits)
    allw = np.concatenate([np.asarray(s, dtype=np.int64) for s in stripes]) \
        if stripes else np.zeros((0,), np.int64)
    ok = True
    uniq, counts = np.unique(allw, return_counts=True)
    dup = uniq[counts > 1]
    if dup.size:
        ok = False
        out.add(Finding(
            _ANALYZER, "word-owner-overlap",
            f"payload word {int(dup[0])} appears in "
            f"{int(counts[counts > 1][0])} shards' word stripes — psum "
            "would accumulate it, not reassemble it",
            subject=subject,
            suggestion="word_owner must assign each word to exactly one "
                       "shard (the word's first value's block-row owner)"))
    missing = np.setdiff1d(np.arange(n_words, dtype=np.int64), uniq,
                           assume_unique=True)
    if missing.size:
        ok = False
        out.add(Finding(
            _ANALYZER, "word-owner-gap",
            f"payload word {int(missing[0])} of {n_words} belongs to no "
            "shard's word stripe — its bits vanish from the merged "
            "payload",
            subject=subject,
            suggestion="every word index in [0, words_for(n, bits)) must "
                       "appear in exactly one shard_word_index stripe"))
    ref = _ref_word_owner(placement, bits)
    live = np.asarray(placement.word_owner(bits))
    if live.shape != ref.shape or not np.array_equal(live, ref):
        ok = False
        where = (int(np.nonzero(live != ref)[0][0])
                 if live.shape == ref.shape else -1)
        out.add(Finding(
            _ANALYZER, "stripe-formula-drift",
            "word_owner no longer matches the round-robin stripe residue "
            f"formula (first divergence at word {where}); the audited "
            "partition argument no longer describes the shipped code",
            subject=subject,
            suggestion="keep owner(word) == (block_row(first_value(word)) "
                       "% n_shards) or update the audit's reference "
                       "derivation with the new construction"))
    units = np.concatenate([placement.units_of(s)
                            for s in range(placement.n_shards)])
    if not np.array_equal(np.sort(units),
                          np.arange(placement.n_units, dtype=np.int64)):
        ok = False
        out.add(Finding(
            _ANALYZER, "unit-owner-drift",
            "units_of() does not partition the stripe units "
            f"[0, {placement.n_units})",
            subject=subject))
    return ok


def _check_routing(out: _Collector, routing_fn, placement, bits: int,
                   subject: str) -> None:
    """Scatter targets partition the gathered set; sources read the words
    they claim."""
    n_values = int(np.prod(placement.padded_shape, dtype=np.int64))
    n_words = encode.words_for(n_values, bits)
    stripes = placement.shard_word_index(bits)
    for word_idx in (np.arange(n_words, dtype=np.int64),
                     np.arange(0, n_words, 2, dtype=np.int64)):
        n_out = len(word_idx)
        src, dst = routing_fn(placement.n_shards, placement, bits, word_idx)
        live = dst[dst != n_out]
        uniq, counts = np.unique(live, return_counts=True)
        if np.any(counts > 1):
            out.add(Finding(
                _ANALYZER, "scatter-overlap",
                f"gathered word slot {int(uniq[counts > 1][0])} is a "
                "scatter-add target of more than one shard — psum "
                "accumulates instead of reassembling",
                subject=subject,
                suggestion="each gathered word must be scattered by its "
                           "single owner; all other shards pad into the "
                           "dropped slot"))
            return
        missing = np.setdiff1d(np.arange(n_out, dtype=np.int64), uniq,
                               assume_unique=True)
        if missing.size:
            out.add(Finding(
                _ANALYZER, "scatter-gap",
                f"gathered word slot {int(missing[0])} of {n_out} is no "
                "shard's scatter target — it stays zero in the merged "
                "payload",
                subject=subject))
            return
        for s in range(placement.n_shards):
            live_k = np.nonzero(dst[s] != n_out)[0]
            stripe = np.asarray(stripes[s], dtype=np.int64)
            srcs = src[s, live_k].astype(np.int64)
            if srcs.size and (srcs.max(initial=-1) >= len(stripe)
                              or not np.array_equal(
                                  stripe[srcs], word_idx[dst[s, live_k]])):
                out.add(Finding(
                    _ANALYZER, "scatter-misroute",
                    f"shard {s} routes a stripe word to a slot expecting "
                    "a different global word — the merge would be "
                    "bit-wrong even though targets are disjoint",
                    subject=subject))
                return


def _check_bands(out: _Collector, bands_fn, placement, scheme, shape,
                 block, regions, subject: str) -> int:
    """Band row ranges tile each query window exactly once; returns the
    largest fan-in observed (1 when exact)."""
    field = SimpleNamespace(scheme=scheme, shape=shape, block=block)
    fanin = 1
    for region in regions:
        spatial = shape[1:]
        if region is None:
            s0, e0 = 0, spatial[0]
        else:
            s0, e0 = region[0]
        nrows = e0 - s0
        cover = np.zeros(nrows, dtype=np.int64)
        for owner, row0, _unit_row0, band_region in \
                bands_fn(field, placement, region):
            r0, r1 = band_region[0]
            cover[r0 - s0:r1 - s0] += 1
            if not (0 <= owner < placement.n_shards):
                out.add(Finding(
                    _ANALYZER, "band-overlap",
                    f"band owner {owner} outside [0, "
                    f"{placement.n_shards})", subject=subject))
                return int(cover.max(initial=1))
        fanin = max(fanin, int(cover.max(initial=1)))
        if np.any(cover > 1):
            row = int(np.nonzero(cover > 1)[0][0]) + s0
            out.add(Finding(
                _ANALYZER, "band-overlap",
                f"window row {row} is covered by {int(cover.max())} "
                "bands — the summary psum would double-count its q "
                "integers",
                subject=subject,
                suggestion="spatial_bands must tile the window rows "
                           "exactly once per shard axis"))
        elif np.any(cover == 0):
            row = int(np.nonzero(cover == 0)[0][0]) + s0
            out.add(Finding(
                _ANALYZER, "band-gap",
                f"window row {row} is covered by no band — its q "
                "integers never reach the merged summary",
                subject=subject))
    return fanin


def shard_safe_size_table(env: Envelope = DEFAULT_ENVELOPE,
                          worlds=DEFAULT_WORLDS,
                          container_bits: int = 16) -> dict:
    """Per-world safe sizes for AUDIT.json (the sharded analogue of
    ``intwidth.safe_size_table``).

    ``summary_capacity`` is world-*independent* because the band scatter
    is disjoint (fan-in 1) — that is the point the analyzer proves; the
    ``accumulating`` column shows what the capacity would shrink to if the
    psum ever became a true accumulation, which is why drift matters.
    """
    from repro.comm.hom_collectives import bit_budget, worst_case_psum

    cap = summary_capacity(env.q_abs)
    table = {}
    for w in worlds:
        bits = bit_budget(w, container_bits)
        table[str(w)] = {
            "summary_capacity_disjoint": cap,
            "summary_capacity_if_accumulating": cap // max(w, 1),
            "collective_bits": bits,
            "collective_qmax": 2 ** (bits - 1) - 1,
            "collective_worst_psum": worst_case_psum(w, container_bits),
        }
    return {
        "envelope": {"q_bits": env.q_bits, "q_abs": env.q_abs,
                     "max_slab_steps": env.max_slab_steps},
        "per_world": table,
    }


def analyze_shard_disjoint(env: Envelope = DEFAULT_ENVELOPE, *,
                           worlds=DEFAULT_WORLDS, cases=DEFAULT_CASES,
                           placement_cls=None, routing_fn=None,
                           bands_fn=None, bit_budget_fn=None,
                           max_collective_world: int = MAX_COLLECTIVE_WORLD
                           ) -> list[Finding]:
    """Run the shard-partition verifier.

    Every collaborator is injectable (``placement_cls`` / ``routing_fn`` /
    ``bands_fn`` / ``bit_budget_fn``) so the sabotage fixtures can break
    one invariant at a time; defaults audit the live shard layer.
    """
    from repro.shard import exec as exec_mod
    from repro.shard.placement import BlockPlacement
    from repro.comm import hom_collectives as hc

    placement_cls = placement_cls or BlockPlacement
    routing_fn = routing_fn or exec_mod.gather_routing
    bands_fn = bands_fn or exec_mod.spatial_bands
    bit_budget_fn = bit_budget_fn or hc.bit_budget

    out = _Collector()

    # (1) + (2): word partition, then routing over the proven partition
    for scheme, shape, padded, block, bits, axis in cases:
        for world in worlds:
            subject = _case_subject(scheme, padded, block, bits, world)
            placement = placement_cls(scheme, shape, padded, block, world,
                                      axis)
            if _check_partition(out, placement, bits, subject):
                _check_routing(out, routing_fn, placement, bits, subject)

    # (3): band tiling of slab query windows (nd slab layout: time axis 0,
    # banded spatial axis == placement axis 1; flat: contiguous split)
    fanin = 1
    slab_cases = (
        (Scheme.HSZX_ND, (12, 40, 16), (12, 48, 16), (1, 8, 16), 1,
         (None, ((5, 27), (0, 16)))),
        (Scheme.HSZP, (16, 64), (16, 64), (1, 64), 0, (None,)),
    )
    for scheme, shape, padded, block, axis, regions in slab_cases:
        for world in worlds:
            subject = (f"spatial_bands[{scheme.value} {shape} "
                       f"world={world}]")
            placement = placement_cls(scheme, shape, padded, block, world,
                                      axis)
            fanin = max(fanin, _check_bands(
                out, bands_fn, placement, scheme, shape, block, regions,
                subject))

    # (4): world-scaled Σq² envelope — the psum adds `fanin` real
    # contributions per window position, so capacity is world-independent
    # exactly when fanin == 1
    worst = fanin * env.max_slab_steps * env.q_abs * env.q_abs
    if worst > INT32_MAX:
        out.add(Finding(
            _ANALYZER, "world-sumsq-overflow",
            f"merged summary Σq² reaches {worst} (band fan-in {fanin} x "
            f"{env.max_slab_steps} steps x q_abs {env.q_abs}²), over "
            f"int32 max {INT32_MAX} — the cross-shard psum overflows "
            "where the single-device summary would not",
            subject="TemporalSummary.q_sumsq",
            suggestion="restore disjoint band tiling (fan-in 1) or "
                       "shrink the envelope's max_slab_steps / q_bits"))

    # (5): int16 collective container, exhaustive over supported worlds
    for w in range(1, max_collective_world + 1):
        bits = bit_budget_fn(w)
        worst = w * (2 ** (bits - 1) - 1)
        if worst > hc.PSUM_CONTAINER_MAX:
            out.add(Finding(
                _ANALYZER, "collective-overflow",
                f"compressed psum at world {w} can reach {worst}, over "
                f"the int16 container max {hc.PSUM_CONTAINER_MAX} "
                f"(bit budget {bits})",
                subject="comm.bit_budget",
                suggestion="bit_budget must satisfy world * (2**(b-1)-1) "
                           "<= 2**15 - 1 for every supported world size"))
            break
    return out.findings
