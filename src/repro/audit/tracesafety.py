"""Analyzer (3): trace-safety lint (DESIGN.md §11).

An AST pass over ``core/``, ``analytics/``, ``stream/``, ``store/`` that
finds *host syncs* and *Python branches on traced values* inside code that
runs under a jax trace — the two mistakes that either crash at trace time
(``TracerBoolConversionError``, far from the cause) or silently destroy
the engine's compile-once guarantee by forcing a device round-trip per
call.

What counts as trace scope
--------------------------
* functions decorated with / passed to ``jax.jit`` / ``jax.vmap`` (also
  ``lax.cond``/``scan``/``while_loop``/``fori_loop`` branches), including
  lambdas and nested ``def``\\ s inside such functions — the engine's
  compiled-program pattern;
* operator lowering rules: functions whose first parameter is ``ctx`` /
  ``ctxs`` (the :class:`~repro.core.oplib.OpSpec` rule convention) — they
  execute inside the engine's jitted programs.

What is flagged
---------------
* ``host-sync`` — ``.item()`` / ``.tolist()`` anywhere in trace scope;
  ``float()`` / ``int()`` / ``bool()`` whose argument is not provably
  static (shapes, ``len``, dtypes, constants are exempt); ``np.asarray`` /
  ``np.array`` on non-static values; *stringification* of array-derived
  values — f-string interpolation (``f"{x}"``), ``str(x)``,
  ``format(x)``, and ``"...".format(x)`` all concretize the tracer (or
  embed the abstract value in the message) exactly like ``.item()``;
  static interpolations (``f"{x.shape}"``) stay legal.
* ``tracer-branch`` — ``if`` / ``while`` / ternary tests that reference a
  value the local dataflow marks *array-derived*: produced by a
  ``jnp.*`` / ``jax.*`` call or an array-annotated parameter.  Branches on
  static structure (``ctx.plan is None``, ``scheme.is_nd``,
  ``n_components == 2``) are legal and not flagged.

Waivers
-------
The documented host-sync lifts (PR 1: ``max_bits``, padding probes) are
eager-ingest code, outside trace scope, and need no waiver.  A deliberate
exception *inside* trace scope is waived with a comment on the same line
or the line above::

    x = arr.item()  # audit: waive(host-sync) <why this is safe>

The waiver names the invariant it suppresses; unwaivable findings are a
design smell, not a lint inconvenience.  A waiver that suppresses nothing
is itself reported at *warning* severity (``stale-waiver``) so waivers
can't rot after refactors — warnings never fail the audit.  This pass
owns waivers naming ``host-sync`` / ``tracer-branch``; other analyzers'
waiver vocabularies (``output-multiply``, ``invariant(...)``) are staled
by their own passes.
"""
from __future__ import annotations

import ast
import re
from pathlib import Path

from .findings import Finding

_ANALYZER = "trace"

#: attribute names that read static structure, never traced data.
_STATIC_ATTRS = frozenset({
    "shape", "ndim", "size", "itemsize", "nbytes", "dtype", "name",
})
_CAST_CALLS = frozenset({"float", "int", "bool"})
_SYNC_METHODS = frozenset({"item", "tolist"})
_NUMPY_NAMES = frozenset({"np", "numpy", "onp"})
_ARRAY_ANNOTATIONS = re.compile(
    r"\b(jax\s*\.\s*Array|jnp\s*\.\s*ndarray|Array|ArrayLike)\b")
_TRACED_MODULES = frozenset({"jnp", "jax", "lax"})
_WAIVE_RE = re.compile(r"#\s*audit:\s*waive\(([a-z\-,\s]+)\)")

#: the invariant names this pass owns waivers for; stale-waiver detection
#: ignores other analyzers' vocabularies so a kernelspec waiver in a
#: kernels/ file is never double-reported here.
_OWNED_WAIVERS = frozenset({"host-sync", "tracer-branch"})

_DEFAULT_ROOTS = ("core", "analytics", "stream", "store", "kernels",
                  "comm", "shard")


def _waivers(source: str) -> dict[int, set[tuple[int, str]]]:
    """Line → waiver declarations ``(comment_line, invariant)``; a waiver
    covers its own line and the one below (comment-above style).  Keeping
    the declaring line in the value lets ``lint_source`` tell which
    declarations actually suppressed something (stale-waiver detection)."""
    out: dict[int, set[tuple[int, str]]] = {}
    for i, line in enumerate(source.splitlines(), start=1):
        m = _WAIVE_RE.search(line)
        if m:
            names = {w.strip() for w in m.group(1).split(",") if w.strip()}
            for name in names:
                out.setdefault(i, set()).add((i, name))
                out.setdefault(i + 1, set()).add((i, name))
    return out


def _dotted(node: ast.AST) -> str | None:
    """'a.b.c' for a pure attribute chain, else None."""
    parts = []
    while isinstance(node, ast.Attribute):
        parts.append(node.attr)
        node = node.value
    if isinstance(node, ast.Name):
        parts.append(node.id)
        return ".".join(reversed(parts))
    return None


def _is_static_expr(node: ast.AST) -> bool:
    """Provably static under a trace: constants, shapes/dtypes, len(),
    and arithmetic/subscripts thereof."""
    if isinstance(node, ast.Constant):
        return True
    if isinstance(node, ast.Attribute):
        # any chain that *passes through* a static attribute is static
        # (x.shape, x.shape[0] handled via Subscript, x.dtype.itemsize)
        n = node
        while isinstance(n, ast.Attribute):
            if n.attr in _STATIC_ATTRS:
                return True
            n = n.value
        return False
    if isinstance(node, ast.Subscript):
        return _is_static_expr(node.value)
    if isinstance(node, ast.Call):
        if isinstance(node.func, ast.Name) and node.func.id == "len":
            return True
        if isinstance(node.func, ast.Name) and node.func.id in _CAST_CALLS:
            return all(_is_static_expr(a) for a in node.args)
        return False
    if isinstance(node, ast.BinOp):
        return _is_static_expr(node.left) and _is_static_expr(node.right)
    if isinstance(node, ast.UnaryOp):
        return _is_static_expr(node.operand)
    if isinstance(node, (ast.Tuple, ast.List)):
        return all(_is_static_expr(e) for e in node.elts)
    return False


def _array_annotated(arg: ast.arg) -> bool:
    if arg.annotation is None:
        return False
    try:
        text = ast.unparse(arg.annotation)
    except Exception:  # pragma: no cover - unparse is total on 3.9+
        return False
    return bool(_ARRAY_ANNOTATIONS.search(text))


class _ScopeIndex(ast.NodeVisitor):
    """First pass: find the trace-scope root functions of a module."""

    def __init__(self):
        self.roots: set[ast.AST] = set()
        self._defs: list[dict[str, ast.AST]] = [{}]
        self._stack: list[ast.AST] = []

    # -- helpers ------------------------------------------------------------
    def _jitlike(self, func: ast.AST) -> bool:
        name = _dotted(func) or ""
        leaf = name.rsplit(".", 1)[-1]
        return (leaf in {"jit", "vmap", "pmap", "shard_map"}
                or name in {"lax.cond", "jax.lax.cond", "lax.scan",
                            "jax.lax.scan", "lax.while_loop",
                            "jax.lax.while_loop", "lax.fori_loop",
                            "jax.lax.fori_loop", "lax.switch",
                            "jax.lax.switch", "lax.map", "jax.lax.map"})

    def _mark(self, node: ast.AST) -> None:
        if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef,
                             ast.Lambda)):
            self.roots.add(node)
        elif isinstance(node, ast.Name):
            for scope in reversed(self._defs):
                if node.id in scope:
                    self.roots.add(scope[node.id])
                    return

    # -- visitors -----------------------------------------------------------
    def visit_Call(self, node: ast.Call):
        if self._jitlike(node.func):
            for arg in node.args:
                self._mark(arg)
        self.generic_visit(node)

    def _visit_def(self, node):
        self._defs[-1][node.name] = node
        args = node.args.posonlyargs + node.args.args
        first = args[0].arg if args else ""
        if first in {"ctx", "ctxs"}:
            self.roots.add(node)  # lowering-rule convention
        for dec in node.decorator_list:
            target = dec.func if isinstance(dec, ast.Call) else dec
            if self._jitlike(target) or any(
                    self._jitlike(a) for a in getattr(dec, "args", [])):
                self.roots.add(node)
        self._defs.append({})
        self._stack.append(node)
        self.generic_visit(node)
        self._stack.pop()
        self._defs.pop()

    visit_FunctionDef = _visit_def
    visit_AsyncFunctionDef = _visit_def


class _TraceLint(ast.NodeVisitor):
    """Second pass: within one trace-scope root, track array-derived names
    and flag host syncs / tracer branches."""

    def __init__(self, path: str, root_name: str,
                 waivers: dict[int, set[tuple[int, str]]],
                 used_waivers: set[tuple[int, str]] | None = None):
        self.path = path
        self.root_name = root_name
        self.waivers = waivers
        self.used_waivers = used_waivers if used_waivers is not None else set()
        self.derived: set[str] = set()
        self.findings: list[Finding] = []

    # -- array-derivation dataflow ------------------------------------------
    def _is_array_expr(self, node: ast.AST) -> bool:
        if _is_static_expr(node):
            return False
        if isinstance(node, ast.Name):
            return node.id in self.derived
        if isinstance(node, ast.Call):
            name = _dotted(node.func) or ""
            head = name.split(".", 1)[0]
            if head in _TRACED_MODULES:
                return True
            return any(self._is_array_expr(a) for a in node.args)
        if isinstance(node, ast.BinOp):
            return (self._is_array_expr(node.left)
                    or self._is_array_expr(node.right))
        if isinstance(node, ast.UnaryOp):
            return self._is_array_expr(node.operand)
        if isinstance(node, ast.Compare):
            # identity tests (`is None`) are static dispatch, not data
            if all(isinstance(op, (ast.Is, ast.IsNot)) for op in node.ops):
                return False
            return (self._is_array_expr(node.left)
                    or any(self._is_array_expr(c) for c in node.comparators))
        if isinstance(node, ast.BoolOp):
            return any(self._is_array_expr(v) for v in node.values)
        if isinstance(node, ast.Subscript):
            return self._is_array_expr(node.value)
        if isinstance(node, ast.Attribute):
            if _is_static_expr(node):
                return False
            return self._is_array_expr(node.value)
        if isinstance(node, ast.IfExp):
            return (self._is_array_expr(node.body)
                    or self._is_array_expr(node.orelse))
        return False

    def visit_Assign(self, node: ast.Assign):
        self.generic_visit(node)
        if self._is_array_expr(node.value):
            for t in node.targets:
                for n in ast.walk(t):
                    if isinstance(n, ast.Name):
                        self.derived.add(n.id)

    def visit_AugAssign(self, node: ast.AugAssign):
        self.generic_visit(node)
        if self._is_array_expr(node.value) and isinstance(node.target, ast.Name):
            self.derived.add(node.target.id)

    # -- findings -----------------------------------------------------------
    def _waived(self, line: int, invariant: str) -> bool:
        hits = {w for w in self.waivers.get(line, ()) if w[1] == invariant}
        if hits:
            self.used_waivers.update(hits)
            return True
        return False

    def _flag(self, node: ast.AST, invariant: str, message: str,
              suggestion: str) -> None:
        line = getattr(node, "lineno", 0)
        if self._waived(line, invariant):
            return
        self.findings.append(Finding(
            _ANALYZER, invariant, message,
            subject=self.root_name, file=self.path, line=line,
            suggestion=suggestion))

    def visit_Call(self, node: ast.Call):
        name = _dotted(node.func) or ""
        leaf = name.rsplit(".", 1)[-1]
        if isinstance(node.func, ast.Attribute) and leaf in _SYNC_METHODS:
            self._flag(node, "host-sync",
                       f".{leaf}() forces a device->host sync under trace",
                       "return the array and reduce on device, or lift the "
                       "sync out of the traced region "
                       "(# audit: waive(host-sync) if deliberate)")
        elif (isinstance(node.func, ast.Name)
              and node.func.id in _CAST_CALLS and node.args
              and not all(_is_static_expr(a) for a in node.args)):
            self._flag(node, "host-sync",
                       f"{node.func.id}() on a possibly-traced value "
                       "concretizes it (host sync / TracerConversionError)",
                       "cast with .astype()/jnp on device; shapes, len() "
                       "and dtypes are exempt "
                       "(# audit: waive(host-sync) if deliberate)")
        elif (name.split(".", 1)[0] in _NUMPY_NAMES
              and leaf in {"asarray", "array"} and node.args
              and not all(_is_static_expr(a) for a in node.args)):
            self._flag(node, "host-sync",
                       f"{name}() pulls a traced value to host numpy",
                       "use jnp inside traced code; numpy belongs to eager "
                       "ingest/metadata paths "
                       "(# audit: waive(host-sync) if deliberate)")
        elif (isinstance(node.func, ast.Name)
              and node.func.id in {"str", "format"} and node.args
              and any(self._is_array_expr(a) for a in node.args)):
            self._flag(node, "host-sync",
                       f"{node.func.id}() stringifies an array-derived "
                       "value under trace — it concretizes the tracer "
                       "exactly like .item()",
                       "log shapes/dtypes (static) instead, or lift the "
                       "formatting out of the traced region "
                       "(# audit: waive(host-sync) if deliberate)")
        elif (isinstance(node.func, ast.Attribute)
              and node.func.attr == "format"
              and any(self._is_array_expr(a) for a in
                      list(node.args) + [kw.value for kw in node.keywords])):
            self._flag(node, "host-sync",
                       "str.format() interpolates an array-derived value "
                       "under trace — stringification is a host sync",
                       "format only static structure inside traced code "
                       "(# audit: waive(host-sync) if deliberate)")
        self.generic_visit(node)

    def visit_JoinedStr(self, node: ast.JoinedStr):
        for part in node.values:
            if (isinstance(part, ast.FormattedValue)
                    and self._is_array_expr(part.value)):
                self._flag(node, "host-sync",
                           "f-string interpolates an array-derived value "
                           "under trace — stringification is a host sync "
                           "(static interpolations like f'{x.shape}' are "
                           "exempt)",
                           "interpolate shapes/dtypes, or move the message "
                           "outside the traced region "
                           "(# audit: waive(host-sync) if deliberate)")
                break
        self.generic_visit(node)

    def _check_branch(self, node: ast.AST, test: ast.AST, kind: str):
        if self._is_array_expr(test):
            self._flag(node, "tracer-branch",
                       f"{kind} on an array-derived value inside a traced "
                       "region (TracerBoolConversionError at trace time)",
                       "use jnp.where / lax.cond / lax.select; branch only "
                       "on static structure "
                       "(# audit: waive(tracer-branch) if deliberate)")

    def visit_If(self, node: ast.If):
        self._check_branch(node, node.test, "`if` branch")
        self.generic_visit(node)

    def visit_While(self, node: ast.While):
        self._check_branch(node, node.test, "`while` loop")
        self.generic_visit(node)

    def visit_IfExp(self, node: ast.IfExp):
        self._check_branch(node, node.test, "conditional expression")
        self.generic_visit(node)

    def visit_Assert(self, node: ast.Assert):
        self._check_branch(node, node.test, "`assert`")
        self.generic_visit(node)


def _lint_root(path: str, root: ast.AST,
               waivers: dict[int, set[tuple[int, str]]],
               used_waivers: set[tuple[int, str]]) -> list[Finding]:
    name = getattr(root, "name", "<lambda>")
    lint = _TraceLint(path, name, waivers, used_waivers)
    args = getattr(root, "args", None)
    if args is not None:
        # ctx/ctxs themselves are mixed containers (static structure +
        # traced data): branching on their structure is legal, so only
        # array-annotated params seed the derived set; traced data inside
        # ctx surfaces through jnp.* calls in the dataflow.
        for arg in args.posonlyargs + args.args + args.kwonlyargs:
            if _array_annotated(arg):
                lint.derived.add(arg.arg)
        if isinstance(root, ast.Lambda):
            # a jitted lambda's positional params are traced by definition
            for arg in args.args:
                lint.derived.add(arg.arg)
    body = root.body if isinstance(root.body, list) else [root.body]
    for stmt in body:
        lint.visit(stmt)
    return lint.findings


def lint_source(source: str, path: str = "<string>") -> list[Finding]:
    """Lint one module's source text; returns findings (used directly by
    the fixture tests)."""
    tree = ast.parse(source)
    index = _ScopeIndex()
    index.visit(tree)
    waivers = _waivers(source)
    used: set[tuple[int, str]] = set()
    findings: list[Finding] = []
    seen: set[tuple] = set()
    for root in sorted(index.roots, key=lambda r: r.lineno):
        for f in _lint_root(path, root, waivers, used):
            key = (f.file, f.line, f.invariant, f.message)
            if key not in seen:
                seen.add(key)
                findings.append(f)
    declared = sorted({w for ws in waivers.values() for w in ws
                       if w[1] in _OWNED_WAIVERS})
    for cline, name in declared:
        if (cline, name) not in used:
            findings.append(Finding(
                _ANALYZER, "stale-waiver",
                f"# audit: waive({name}) suppresses no {name} finding — "
                "the waived code has moved or been fixed",
                subject=name, file=path, line=cline, severity="warning",
                suggestion="delete the stale waiver comment"))
    return findings


def analyze_trace_safety(src_root: str | Path | None = None,
                         packages: tuple = _DEFAULT_ROOTS) -> list[Finding]:
    """Lint every module under ``src/repro/{core,analytics,stream,store}``."""
    if src_root is None:
        src_root = Path(__file__).resolve().parent.parent
    src_root = Path(src_root)
    findings: list[Finding] = []
    for pkg in packages:
        for py in sorted((src_root / pkg).rglob("*.py")):
            rel = str(py.relative_to(src_root.parent.parent))
            findings.extend(lint_source(py.read_text(), rel))
    return findings
