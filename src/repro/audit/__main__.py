"""``python -m repro.audit`` — run the static invariant audit."""
import sys

from .runner import main

sys.exit(main())
