"""Audit runner + CLI: ``python -m repro.audit [--json AUDIT.json]``.

Runs the six analyzers (registry completeness, int-width bounds,
trace-safety lint, jit-cache-key soundness, kernel grid/bounds/race
verification, shard-partition exactness), prints findings, writes the
machine-readable report (findings + safe-size tables) when asked, and
exits non-zero iff there is at least one **error** finding — warnings
(stale waivers) print but never fail the run.  That exit-code contract is
what the CI ``audit`` job gates on, pinned by a test.
"""
from __future__ import annotations

import argparse
import json
import sys

from .findings import AuditReport
from .intwidth import DEFAULT_ENVELOPE, Envelope, analyze_int_width, safe_size_table
from .jitkeys import analyze_jit_keys
from .kernelspec import analyze_kernel_specs
from .registry import analyze_registry
from .sharddisjoint import analyze_shard_disjoint, shard_safe_size_table
from .tracesafety import analyze_trace_safety

ALL_ANALYZERS = ("registry", "intwidth", "trace", "jitkey", "kernelspec",
                 "sharddisjoint")


def run_audit(env: Envelope = DEFAULT_ENVELOPE, *,
              analyzers: tuple = ALL_ANALYZERS) -> AuditReport:
    """Run the selected analyzers against the live repo; returns the full
    report (the safe-size tables are attached even when their analyzers
    are clean)."""
    report = AuditReport()
    if "registry" in analyzers:
        report.extend(analyze_registry())
    if "intwidth" in analyzers:
        report.extend(analyze_int_width(env))
        report.safe_sizes = safe_size_table(env)
    if "trace" in analyzers:
        report.extend(analyze_trace_safety())
    if "jitkey" in analyzers:
        report.extend(analyze_jit_keys())
    if "kernelspec" in analyzers:
        report.extend(analyze_kernel_specs(env))
    if "sharddisjoint" in analyzers:
        report.extend(analyze_shard_disjoint(env))
        report.shard_safe_sizes = shard_safe_size_table(env)
    return report


def _parse_only(value: str) -> tuple:
    names = tuple(n.strip() for n in value.split(",") if n.strip())
    bad = [n for n in names if n not in ALL_ANALYZERS]
    if bad or not names:
        raise argparse.ArgumentTypeError(
            f"unknown analyzer(s) {bad or [value]}; "
            f"choose from {', '.join(ALL_ANALYZERS)}")
    return names


def main(argv=None) -> int:
    parser = argparse.ArgumentParser(
        prog="python -m repro.audit",
        description="Static invariant audit for the homomorphic pipeline "
                    "(DESIGN.md §11).")
    parser.add_argument("--json", metavar="PATH", default=None,
                        help="write the full machine-readable report "
                             "(findings + safe-size tables)")
    parser.add_argument("--only", metavar="A[,B]", type=_parse_only,
                        default=None,
                        help="comma-separated analyzer selection "
                             f"({', '.join(ALL_ANALYZERS)}); default: all")
    parser.add_argument("--analyzer", action="append", default=None,
                        choices=list(ALL_ANALYZERS),
                        help="run only the named analyzer(s); repeatable "
                             "(equivalent to --only)")
    parser.add_argument("--q-bits", type=int,
                        default=DEFAULT_ENVELOPE.q_bits,
                        help="envelope: quantization index magnitude bits")
    parser.add_argument("--max-field-elems", type=int,
                        default=DEFAULT_ENVELOPE.max_field_elems,
                        help="envelope: largest spatial field (elements)")
    parser.add_argument("--max-slab-steps", type=int,
                        default=DEFAULT_ENVELOPE.max_slab_steps,
                        help="envelope: most timesteps in one stream")
    args = parser.parse_args(argv)

    env = Envelope(q_bits=args.q_bits,
                   max_field_elems=args.max_field_elems,
                   max_slab_steps=args.max_slab_steps)
    analyzers = ALL_ANALYZERS
    if args.only:
        analyzers = args.only
    if args.analyzer:
        analyzers = tuple(dict.fromkeys(
            (list(args.only) if args.only else []) + args.analyzer))
    report = run_audit(env, analyzers=analyzers)

    for f in report.findings:
        print(f.render())
    counts = report.to_dict()["findings_by_analyzer"]
    ran = ", ".join(analyzers)
    n_warn = len(report.warnings)
    if report.ok:
        tail = f" ({n_warn} warning(s))" if n_warn else ""
        print(f"audit clean: 0 errors{tail} ({ran})")
    else:
        per = ", ".join(f"{k}={v}" for k, v in sorted(counts.items()))
        print(f"audit FAILED: {len(report.errors)} error(s), "
              f"{n_warn} warning(s) [{per}]")
    if args.json:
        with open(args.json, "w") as fh:
            json.dump(report.to_dict(), fh, indent=2, sort_keys=False)
        print(f"report written to {args.json}")
    return 0 if report.ok else 1


if __name__ == "__main__":  # pragma: no cover
    sys.exit(main())
