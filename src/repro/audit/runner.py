"""Audit runner + CLI: ``python -m repro.audit [--json AUDIT.json]``.

Runs the four analyzers (registry completeness, int-width bounds,
trace-safety lint, jit-cache-key soundness), prints findings, writes the
machine-readable report (findings + per-scheme safe-size table) when asked,
and exits non-zero iff there is at least one finding — the contract the CI
``audit`` job gates on.
"""
from __future__ import annotations

import argparse
import json
import sys

from .findings import AuditReport
from .intwidth import DEFAULT_ENVELOPE, Envelope, analyze_int_width, safe_size_table
from .jitkeys import analyze_jit_keys
from .registry import analyze_registry
from .tracesafety import analyze_trace_safety


def run_audit(env: Envelope = DEFAULT_ENVELOPE, *,
              analyzers: tuple = ("registry", "intwidth", "trace",
                                  "jitkey")) -> AuditReport:
    """Run the selected analyzers against the live repo; returns the full
    report (the safe-size table is attached even when intwidth is clean)."""
    report = AuditReport()
    if "registry" in analyzers:
        report.extend(analyze_registry())
    if "intwidth" in analyzers:
        report.extend(analyze_int_width(env))
        report.safe_sizes = safe_size_table(env)
    if "trace" in analyzers:
        report.extend(analyze_trace_safety())
    if "jitkey" in analyzers:
        report.extend(analyze_jit_keys())
    return report


def main(argv=None) -> int:
    parser = argparse.ArgumentParser(
        prog="python -m repro.audit",
        description="Static invariant audit for the homomorphic pipeline "
                    "(DESIGN.md §11).")
    parser.add_argument("--json", metavar="PATH", default=None,
                        help="write the full machine-readable report "
                             "(findings + per-scheme safe-size table)")
    parser.add_argument("--analyzer", action="append", default=None,
                        choices=["registry", "intwidth", "trace", "jitkey"],
                        help="run only the named analyzer(s); default: all")
    parser.add_argument("--q-bits", type=int,
                        default=DEFAULT_ENVELOPE.q_bits,
                        help="envelope: quantization index magnitude bits")
    parser.add_argument("--max-field-elems", type=int,
                        default=DEFAULT_ENVELOPE.max_field_elems,
                        help="envelope: largest spatial field (elements)")
    parser.add_argument("--max-slab-steps", type=int,
                        default=DEFAULT_ENVELOPE.max_slab_steps,
                        help="envelope: most timesteps in one stream")
    args = parser.parse_args(argv)

    env = Envelope(q_bits=args.q_bits,
                   max_field_elems=args.max_field_elems,
                   max_slab_steps=args.max_slab_steps)
    analyzers = tuple(args.analyzer) if args.analyzer else (
        "registry", "intwidth", "trace", "jitkey")
    report = run_audit(env, analyzers=analyzers)

    for f in report.findings:
        print(f.render())
    counts = report.to_dict()["findings_by_analyzer"]
    ran = ", ".join(analyzers)
    if report.ok:
        print(f"audit clean: 0 findings ({ran})")
    else:
        per = ", ".join(f"{k}={v}" for k, v in sorted(counts.items()))
        print(f"audit FAILED: {len(report.findings)} finding(s) [{per}]")
    if args.json:
        with open(args.json, "w") as fh:
            json.dump(report.to_dict(), fh, indent=2, sort_keys=False)
        print(f"report written to {args.json}")
    return 0 if report.ok else 1


if __name__ == "__main__":  # pragma: no cover
    sys.exit(main())
