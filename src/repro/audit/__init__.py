"""Static invariant audit for the homomorphic pipeline (DESIGN.md §11).

Four analyzers, one contract: ``python -m repro.audit`` exits 0 iff every
statically checkable invariant the bit-identity guarantees rest on holds.

* :mod:`.registry` — registry / Table-I completeness: exactly one lowering
  rule per feasible (stage, scheme-family) cell, closures for every
  region-capable cell, collision-free registry merge, planner matrix in
  agreement with the declarations.
* :mod:`.intwidth` — integer-width abstract interpretation: value-range
  intervals propagated through quantize → decorrelate → bitpack →
  TemporalSummary under a declared envelope, proving no int32 overflow and
  emitting the per-scheme safe-size table.
* :mod:`.tracesafety` — trace-safety lint: host syncs and Python branches
  on traced values inside lowering rules and compiled engine programs,
  with ``# audit: waive(...)`` for deliberate exceptions.
* :mod:`.jitkeys` — jit-cache-key soundness: every free variable a traced
  callable closes over is covered by its cache key (or declared invariant
  with ``# audit: invariant(...)``).
"""
from .findings import AuditReport, Finding
from .intwidth import DEFAULT_ENVELOPE, Envelope, analyze_int_width, safe_size_table
from .jitkeys import analyze_jit_keys
from .registry import analyze_registry
from .runner import main, run_audit
from .tracesafety import analyze_trace_safety

__all__ = [
    "AuditReport", "Finding", "Envelope", "DEFAULT_ENVELOPE",
    "analyze_registry", "analyze_int_width", "safe_size_table",
    "analyze_trace_safety", "analyze_jit_keys", "run_audit", "main",
]
