"""Static invariant audit for the homomorphic pipeline (DESIGN.md §11).

Six analyzers, one contract: ``python -m repro.audit`` exits 0 iff every
statically checkable invariant the bit-identity guarantees rest on holds
(stale-waiver *warnings* print but never fail the run).

* :mod:`.registry` — registry / Table-I completeness: exactly one lowering
  rule per feasible (stage, scheme-family) cell, closures for every
  region-capable cell, collision-free registry merge, planner matrix in
  agreement with the declarations.
* :mod:`.intwidth` — integer-width abstract interpretation: value-range
  intervals propagated through quantize → decorrelate → bitpack →
  TemporalSummary under a declared envelope, proving no int32 overflow and
  emitting the per-scheme safe-size table.
* :mod:`.tracesafety` — trace-safety lint: host syncs (including f-string
  / ``str()`` / ``format()`` stringification) and Python branches on
  traced values inside lowering rules and compiled engine programs, with
  ``# audit: waive(...)`` for deliberate exceptions.
* :mod:`.jitkeys` — jit-cache-key soundness: every free variable a traced
  callable closes over is covered by its cache key (or declared invariant
  with ``# audit: invariant(...)``), every ``FusedRule.covers`` input is
  in the dispatch key, and kernel mode keys every kernel-dispatching
  program.
* :mod:`.kernelspec` — Pallas kernel verification: symbolic grid/halo
  bounds, exactly-once output coverage, VMEM budget, the bitplane-unpack
  word-window lemma, and the no-output-float-multiply (FMA-contraction)
  lint, against the declared :mod:`repro.kernels.specs`.
* :mod:`.sharddisjoint` — shard-partition exactness: word-owner and
  scatter-target disjointness (psum is reassembly, not accumulation),
  band tiling, the world-scaled Σq² envelope, and the int16 collective
  container sweep, with a per-world safe-size table.
"""
from .findings import SCHEMA_VERSION, AuditReport, Finding
from .intwidth import DEFAULT_ENVELOPE, Envelope, analyze_int_width, safe_size_table
from .jitkeys import analyze_jit_keys
from .kernelspec import analyze_kernel_specs, check_unpack_lemma
from .registry import analyze_registry
from .runner import ALL_ANALYZERS, main, run_audit
from .sharddisjoint import analyze_shard_disjoint, shard_safe_size_table
from .tracesafety import analyze_trace_safety

__all__ = [
    "AuditReport", "Finding", "SCHEMA_VERSION", "Envelope",
    "DEFAULT_ENVELOPE", "ALL_ANALYZERS",
    "analyze_registry", "analyze_int_width", "safe_size_table",
    "analyze_trace_safety", "analyze_jit_keys", "analyze_kernel_specs",
    "check_unpack_lemma", "analyze_shard_disjoint", "shard_safe_size_table",
    "run_audit", "main",
]
