"""Analyzer (4): jit-cache-key soundness (DESIGN.md §11).

The engine (`repro.analytics.engine`) keeps hand-built jit caches: a
compiled program is stored under a tuple key and the traced callable binds
its statics through default args and lexical capture.  The bug class PRs 3
and 5 fixed by hand is a *free variable the trace depends on that the key
does not distinguish* — two calls that should compile differently silently
share one cached program.

For every ``jax.jit(<callable>)`` site this pass:

1. extracts the traced callable's **free variables** — lexical captures
   (via :mod:`symtable`, i.e. CPython's own closure analysis) plus the
   free names of default-argument expressions (``_ops=ops`` binds ``ops``
   from the enclosing scope at definition time);
2. finds the **cache-key expression** governing the site — the first
   argument of ``self._jitted.get(...)`` / ``self._jitted[...] = ...`` /
   ``self._cache_put(...)`` in the enclosing function, or the enclosing
   function's parameters when it is ``lru_cache``-decorated (the
   functools key *is* the parameter tuple);
3. verifies each free variable is **covered**: it flows into the key
   (backward slice), is fully derived from key components (forward
   closure), is a module global / import / builtin / local helper
   function (recursed), or is on the declared invariant allowlist;
4. when the key is itself a parameter (the ``_compiled(key, ops, ...)``
   factoring), repeats the check at every **call site**, mapping
   arguments to parameters — the caller's key slice must cover each
   argument feeding an uncovered parameter.

Deliberate invariants are declared with a comment on the ``jax.jit`` line
or the traced callable's ``def`` line::

    fn = jax.jit(run)  # audit: invariant(cost_model) fixed per engine

Module-level ``jax.jit(module_fn)`` of an attribute/global with no
closure is sound by construction and skipped.

Two further checks ride on the same machinery:

* **kernel-mode keys** (``unkeyed-kernel-mode``) — any jit site whose
  traced body dispatches through the kernel-backed lowering layer
  (``oplib.compute`` / ``StageContext`` / ``decode_device`` /
  ``summarize_slab``) selects fused-vs-XLA paths *at trace time*, so its
  cache key must include ``oplib.kernel_sig()`` — directly in the key
  expression, or (for the ``_compiled(key, ...)`` factoring) in the key
  built at every call site (e.g. through ``batch_key``).
* **dispatch coverage** (``uncovered-dispatch-input``) — every ``ctx``
  attribute a ``FusedRule.covers`` predicate reads must be an input the
  engine's program key distinguishes (layout, region plan, seed); a
  predicate branching on an unkeyed attribute would route two
  key-identical calls to different lowerings.

A ``# audit: invariant(...)`` declaration that suppresses nothing in the
run is reported at *warning* severity (``stale-waiver``) so declarations
can't rot after refactors.
"""
from __future__ import annotations

import ast
import builtins
import re
import symtable
from pathlib import Path

from .findings import Finding

_ANALYZER = "jitkey"

_CACHE_ATTRS = frozenset({"_jitted", "_cache", "_programs"})
_CACHE_PUTS = frozenset({"_cache_put"})
_MUTATORS = frozenset({"append", "extend", "add", "update", "insert"})
_INVARIANT_RE = re.compile(r"#\s*audit:\s*invariant\(([A-Za-z0-9_,\s]+)\)")
_BUILTINS = frozenset(dir(builtins))

_DEFAULT_TARGETS = ("analytics/engine.py", "stream/temporal.py",
                    "shard/exec.py")

# Exact dotted callees that enter the REPRO_KERNELS-switched lowering layer.
# A traced body calling any of these selects fused-vs-XLA paths at trace
# time, so its cache key must fold in ``oplib.kernel_sig()``.  Deliberately
# exact names, not head-module matches: ``oplib.TemporalSummary`` (a plain
# container) must not drag mode into keys that don't dispatch.
_DISPATCH_DOTTED = frozenset({
    "oplib.compute", "oplib.StageContext", "oplib.select_rule",
    "oplib.summarize_slab", "encode.decode_device",
    "encode_mod.decode_device",
})

# ctx attributes a FusedRule.covers predicate may branch on: each maps to a
# component of the engine's batch_key (layout_key(field) covers scheme +
# field geometry; plan -> region; _seed -> seed_sig; stage is explicit).
_COVERS_KEYED_ATTRS = frozenset({"scheme", "field", "plan", "_seed",
                                 "stage"})

_DEFAULT_FUSED_TARGETS = ("core/fused.py",)


# ---------------------------------------------------------------------------
# small ast utilities
# ---------------------------------------------------------------------------

def _dotted(node: ast.AST) -> str | None:
    parts = []
    while isinstance(node, ast.Attribute):
        parts.append(node.attr)
        node = node.value
    if isinstance(node, ast.Name):
        parts.append(node.id)
        return ".".join(reversed(parts))
    return None


def _free_names(node: ast.AST) -> set[str]:
    """Names an expression reads, minus names it binds itself
    (comprehension targets, lambda params, walrus targets)."""
    loads: set[str] = set()
    bound: set[str] = set()
    for n in ast.walk(node):
        if isinstance(n, ast.Name):
            (loads if isinstance(n.ctx, ast.Load) else bound).add(n.id)
        elif isinstance(n, ast.comprehension):
            for t in ast.walk(n.target):
                if isinstance(t, ast.Name):
                    bound.add(t.id)
        elif isinstance(n, ast.Lambda):
            a = n.args
            for arg in a.posonlyargs + a.args + a.kwonlyargs:
                bound.add(arg.arg)
    return loads - bound


def _bound_targets(t: ast.AST):
    """Names an assignment target *binds* — Subscript/Attribute targets
    mutate containers, they do not bind the names inside them."""
    if isinstance(t, ast.Name):
        yield t.id
    elif isinstance(t, (ast.Tuple, ast.List)):
        for e in t.elts:
            yield from _bound_targets(e)
    elif isinstance(t, ast.Starred):
        yield from _bound_targets(t.value)


def _param_names(node: ast.AST) -> list[str]:
    a = node.args
    names = [x.arg for x in a.posonlyargs + a.args + a.kwonlyargs]
    if a.vararg:
        names.append(a.vararg.arg)
    if a.kwarg:
        names.append(a.kwarg.arg)
    return names


def _default_frees(node: ast.AST) -> set[str]:
    """Free names of default-arg expressions — evaluated in the *enclosing*
    scope at definition time (the ``_ops=ops`` static-binding idiom)."""
    a = node.args
    out: set[str] = set()
    for d in list(a.defaults) + [d for d in a.kw_defaults if d is not None]:
        out |= _free_names(d)
    return out


# ---------------------------------------------------------------------------
# per-module index
# ---------------------------------------------------------------------------

class _Module:
    def __init__(self, source: str, path: str):
        self.path = path
        self.source = source
        self.tree = ast.parse(source)
        self.lines = source.splitlines()
        # symtable: (name, lineno) -> function block (CPython closure info)
        self.blocks: dict[tuple, symtable.SymbolTable] = {}

        def walk(tb):
            for child in tb.get_children():
                if child.get_type() == "function":
                    self.blocks[(child.get_name(), child.get_lineno())] = child
                walk(child)

        walk(symtable.symtable(source, path, "exec"))
        # names bound at module level, plus every import anywhere (imports
        # bind invariant module objects regardless of scope)
        self.module_bound: set[str] = set()
        for stmt in self.tree.body:
            self.module_bound |= _stmt_bindings(stmt)
        self.import_bound: set[str] = set()
        for n in ast.walk(self.tree):
            if isinstance(n, (ast.Import, ast.ImportFrom)):
                for alias in n.names:
                    self.import_bound.add(
                        (alias.asname or alias.name).split(".", 1)[0])
        # every ``# audit: invariant(a, b)`` declaration as (line, name) —
        # the identity stale-waiver accounting is keyed on
        self.invariant_decls: list[tuple[int, str]] = []
        for i, line in enumerate(self.lines, start=1):
            m = _INVARIANT_RE.search(line)
            if m:
                for w in m.group(1).split(","):
                    w = w.strip()
                    if w:
                        self.invariant_decls.append((i, w))

    def exempt(self, name: str) -> bool:
        return (name in _BUILTINS or name in self.module_bound
                or name in self.import_bound)

    def waived_decls(self, lineno: int) -> set[tuple[int, str]]:
        """Declarations governing a site: same line or the line above."""
        return {(ln, n) for (ln, n) in self.invariant_decls
                if ln in (lineno, lineno - 1)}

    def waived(self, lineno: int) -> set[str]:
        return {n for _, n in self.waived_decls(lineno)}

    def frees_of(self, fnode: ast.AST) -> set[str]:
        """Closure frees (symtable) + default-expr frees of one def/lambda."""
        name = getattr(fnode, "name", "lambda")
        block = self.blocks.get((name, fnode.lineno))
        frees = set(block.get_frees()) if block is not None else set()
        return frees | _default_frees(fnode)


def _stmt_bindings(stmt: ast.stmt) -> set[str]:
    out: set[str] = set()
    if isinstance(stmt, (ast.FunctionDef, ast.AsyncFunctionDef, ast.ClassDef)):
        out.add(stmt.name)
    elif isinstance(stmt, (ast.Import, ast.ImportFrom)):
        for alias in stmt.names:
            out.add((alias.asname or alias.name).split(".", 1)[0])
    elif isinstance(stmt, (ast.Assign, ast.AnnAssign, ast.AugAssign)):
        targets = (stmt.targets if isinstance(stmt, ast.Assign)
                   else [stmt.target])
        for t in targets:
            out.update(_bound_targets(t))
    elif isinstance(stmt, (ast.If, ast.Try, ast.For, ast.While, ast.With)):
        for sub in ast.iter_child_nodes(stmt):
            if isinstance(sub, ast.stmt):
                out |= _stmt_bindings(sub)
    return out


# ---------------------------------------------------------------------------
# dataflow inside one function
# ---------------------------------------------------------------------------

class _Flow:
    """Assignment dataflow of one function body: ``edges[target] = frees``
    per binding (append/extend mutations included), supporting the backward
    slice (what flows *into* an expression) and the forward closure (what
    is fully *derived from* a seed set)."""

    def __init__(self, fnode: ast.AST, mod: _Module):
        self.mod = mod
        self.edges: list[tuple[str, set[str]]] = []
        self.local_defs: dict[str, ast.AST] = {}
        for stmt in ast.walk(fnode):
            if stmt is fnode:
                continue
            if isinstance(stmt, (ast.FunctionDef, ast.AsyncFunctionDef)):
                self.local_defs[stmt.name] = stmt
            elif isinstance(stmt, ast.Assign):
                frees = _free_names(stmt.value)
                for t in stmt.targets:
                    for name in _bound_targets(t):
                        self.edges.append((name, frees))
            elif isinstance(stmt, (ast.AnnAssign, ast.AugAssign)):
                if stmt.value is not None and isinstance(stmt.target, ast.Name):
                    self.edges.append((stmt.target.id,
                                       _free_names(stmt.value)))
            elif isinstance(stmt, ast.For):
                frees = _free_names(stmt.iter)
                for name in _bound_targets(stmt.target):
                    self.edges.append((name, frees))
            elif isinstance(stmt, ast.NamedExpr):
                if isinstance(stmt.target, ast.Name):
                    self.edges.append((stmt.target.id,
                                       _free_names(stmt.value)))
            elif (isinstance(stmt, ast.Call)
                  and isinstance(stmt.func, ast.Attribute)
                  and stmt.func.attr in _MUTATORS
                  and isinstance(stmt.func.value, ast.Name)):
                frees: set[str] = set()
                for a in stmt.args:
                    frees |= _free_names(a)
                self.edges.append((stmt.func.value.id, frees))

    def _expand(self, names: set[str]) -> set[str]:
        """Substitute local helper functions by their own free variables."""
        out: set[str] = set()
        seen: set[str] = set()
        work = list(names)
        while work:
            n = work.pop()
            if n in seen:
                continue
            seen.add(n)
            if n in self.local_defs:
                work.extend(self.mod.frees_of(self.local_defs[n]))
            else:
                out.add(n)
        return out

    def backward(self, roots: set[str]) -> set[str]:
        covered = set(roots)
        changed = True
        while changed:
            changed = False
            for target, frees in self.edges:
                if target in covered:
                    new = self._expand(frees) - covered
                    if new:
                        covered |= new
                        changed = True
        return covered

    def forward(self, seeds: set[str]) -> set[str]:
        covered = set(seeds)
        changed = True
        while changed:
            changed = False
            for target, frees in self.edges:
                if target not in covered and all(
                        f in covered or self.mod.exempt(f)
                        for f in self._expand(frees)):
                    covered.add(target)
                    changed = True
        return covered

    def covered(self, key_frees: set[str]) -> set[str]:
        roots = self._expand(key_frees)
        return self.backward(roots) | self.forward(roots)


# ---------------------------------------------------------------------------
# jit sites
# ---------------------------------------------------------------------------

def _is_jit_call(node: ast.Call) -> bool:
    name = _dotted(node.func) or ""
    return name.rsplit(".", 1)[-1] in {"jit", "pmap"}


def _key_expr(fnode: ast.AST) -> ast.AST | None:
    """The cache-key expression governing jit sites in ``fnode``: first arg
    of ``<cache>.get(...)`` / ``<cache>[...]`` / ``self._cache_put(...)``."""
    for n in ast.walk(fnode):
        if isinstance(n, ast.Call) and isinstance(n.func, ast.Attribute):
            base = n.func
            if (base.attr == "get" and n.args
                    and isinstance(base.value, ast.Attribute)
                    and base.value.attr in _CACHE_ATTRS):
                return n.args[0]
            if base.attr in _CACHE_PUTS and n.args:
                return n.args[0]
        if (isinstance(n, ast.Subscript)
                and isinstance(n.value, ast.Attribute)
                and n.value.attr in _CACHE_ATTRS):
            return n.slice
    return None


def _lru_cached(fnode: ast.AST) -> bool:
    for dec in getattr(fnode, "decorator_list", []):
        target = dec.func if isinstance(dec, ast.Call) else dec
        name = _dotted(target) or ""
        if name.rsplit(".", 1)[-1] == "lru_cache" or name == "cache":
            return True
    return False


def _bind_call(call: ast.Call, fnode: ast.AST,
               skip_self: bool) -> dict[str, ast.AST]:
    """Map a call's argument expressions onto ``fnode``'s parameter names
    (best-effort; *args/**kwargs splat args are left unmapped)."""
    a = fnode.args
    params = [x.arg for x in a.posonlyargs + a.args]
    if skip_self and params and params[0] in {"self", "cls"}:
        params = params[1:]
    bound: dict[str, ast.AST] = {}
    for i, arg in enumerate(call.args):
        if isinstance(arg, ast.Starred):
            break
        if i < len(params):
            bound[params[i]] = arg
    for kw in call.keywords:
        if kw.arg is not None:
            bound[kw.arg] = kw.value
    return bound


def _key_texts(kx: ast.AST, fnode: ast.AST, flow: _Flow,
               defs_by_name: dict[str, list[ast.AST]]) -> list[str]:
    """Source texts the key's value is built from: the key expression
    itself, the RHS of every assignment on its backward slice, and the
    bodies of module functions reachable from that slice (key builders
    like ``batch_key``)."""
    names = flow.backward(flow._expand(_free_names(kx)))
    texts = [ast.unparse(kx)]
    for stmt in ast.walk(fnode):
        if isinstance(stmt, ast.Assign):
            bound = {n for t in stmt.targets for n in _bound_targets(t)}
            if bound & names:
                texts.append(ast.unparse(stmt.value))
        elif isinstance(stmt, (ast.AnnAssign, ast.AugAssign)):
            if (stmt.value is not None and isinstance(stmt.target, ast.Name)
                    and stmt.target.id in names):
                texts.append(ast.unparse(stmt.value))
    texts += [ast.unparse(d) for n in sorted(names & set(defs_by_name))
              for d in defs_by_name[n]]
    return texts


def _dispatches_kernels(fnode: ast.AST,
                        local_defs: dict[str, ast.AST]) -> bool:
    """Does the traced body (or a local helper it calls) reach the
    kernel-backed lowering layer?"""
    seen: set[str] = set()
    work = [fnode]
    while work:
        f = work.pop()
        for n in ast.walk(f):
            if not isinstance(n, ast.Call):
                continue
            if (_dotted(n.func) or "") in _DISPATCH_DOTTED:
                return True
            if (isinstance(n.func, ast.Name) and n.func.id in local_defs
                    and n.func.id not in seen):
                seen.add(n.func.id)
                work.append(local_defs[n.func.id])
    return False


def _analyze_module(mod: _Module) -> list[Finding]:
    findings: list[Finding] = []
    # (line, name) of every invariant declaration that suppressed something
    used_decls: set[tuple[int, str]] = set()
    # enclosing-function map for every node
    parents: dict[ast.AST, ast.AST | None] = {}
    stack: list[ast.AST] = []

    def assign_parents(node, fn):
        parents[node] = fn
        for child in ast.iter_child_nodes(node):
            assign_parents(
                child,
                node if isinstance(node, (ast.FunctionDef,
                                          ast.AsyncFunctionDef)) else fn)

    assign_parents(mod.tree, None)

    # all function defs by name (for resolving call sites / jit args)
    defs_by_name: dict[str, list[ast.AST]] = {}
    for n in ast.walk(mod.tree):
        if isinstance(n, (ast.FunctionDef, ast.AsyncFunctionDef)):
            defs_by_name.setdefault(n.name, []).append(n)

    for node in ast.walk(mod.tree):
        if not (isinstance(node, ast.Call) and _is_jit_call(node)
                and node.args):
            continue
        target = node.args[0]
        if isinstance(target, (ast.Attribute,)):
            continue  # jax.jit(module.fn): no closure, sound
        if isinstance(target, ast.Name):
            cands = [d for d in defs_by_name.get(target.id, [])
                     if parents.get(d) is parents.get(node)]
            if not cands:
                if not mod.exempt(target.id):
                    findings.append(Finding(
                        _ANALYZER, "unkeyed-closure",
                        f"jax.jit({target.id}) traces a callable this pass "
                        "cannot resolve; its closure cannot be verified "
                        "against the cache key",
                        subject=target.id, file=mod.path, line=node.lineno,
                        suggestion="jit a local def/lambda or a module "
                                   "function"))
                continue
            traced = cands[-1]
        elif isinstance(target, ast.Lambda):
            traced = target
        else:
            continue

        enclosing = parents.get(node)
        wdecls = mod.waived_decls(node.lineno) | mod.waived_decls(
            traced.lineno)
        waived = {n for _, n in wdecls}

        def _mark_used(suppressed, decls=None):
            used_decls.update(d for d in (wdecls if decls is None else decls)
                              if d[1] in suppressed)

        frees = mod.frees_of(traced)
        if enclosing is None:
            # module-level jit: only module globals can be captured
            captured = {f for f in frees if not mod.exempt(f)}
            _mark_used(captured & waived)
            leftover = captured - waived
            for name in sorted(leftover):
                findings.append(Finding(
                    _ANALYZER, "unkeyed-closure",
                    f"module-level jax.jit callable captures {name!r} which "
                    "is not a module global",
                    subject=name, file=mod.path, line=node.lineno))
            continue

        flow = _Flow(enclosing, mod)
        if _lru_cached(enclosing):
            kx = None
            key_frees: set[str] | None = set(_param_names(enclosing))
        else:
            kx = _key_expr(enclosing)
            key_frees = None if kx is None else _free_names(kx)
        if key_frees is None:
            captured = {f for f in frees if not mod.exempt(f)
                        and f not in flow.local_defs}
            _mark_used(captured & waived)
            interesting = captured - waived
            if interesting:
                findings.append(Finding(
                    _ANALYZER, "missing-cache-key",
                    f"jit site captures {sorted(interesting)} but no cache-"
                    "key expression was found in the enclosing function "
                    f"{enclosing.name!r}",
                    subject=enclosing.name, file=mod.path, line=node.lineno,
                    suggestion="store the program in a key-addressed cache "
                               "whose key covers every captured static"))
            continue

        covered = flow.covered(key_frees)
        unkeyed = {f for f in flow._expand(frees)
                   if f not in covered and not mod.exempt(f)}
        _mark_used(unkeyed & waived)
        uncovered = unkeyed - waived
        enc_params = set(_param_names(enclosing))

        if _dispatches_kernels(traced, flow.local_defs):
            if "kernel_sig" in waived:
                _mark_used({"kernel_sig"})
                mode_ok = True
            elif kx is None:
                # lru_cache key is the parameter tuple: mode must be a param
                mode_ok = "kernel_sig" in ast.unparse(enclosing)
            else:
                texts = _key_texts(kx, enclosing, flow, defs_by_name)
                mode_ok = any("kernel_sig" in t for t in texts)
                if not mode_ok and key_frees & enc_params:
                    # ``_compiled(key, ...)`` factoring: accept iff every
                    # call site's key argument flows through something
                    # (e.g. batch_key) whose source folds in kernel_sig
                    sites = [
                        c for c in ast.walk(mod.tree)
                        if isinstance(c, ast.Call) and c is not node
                        and (_dotted(c.func) or "").rsplit(".", 1)[-1]
                        == enclosing.name]
                    site_ok = bool(sites)
                    for call in sites:
                        caller = parents.get(call)
                        if caller is None:
                            site_ok = False
                            break
                        cflow = _Flow(caller, mod)
                        bound = _bind_call(
                            call, enclosing,
                            skip_self=isinstance(call.func, ast.Attribute))
                        texts = []
                        for p in key_frees & enc_params:
                            arg = bound.get(p)
                            if arg is not None:
                                texts += _key_texts(arg, caller, cflow,
                                                    defs_by_name)
                        if not any("kernel_sig" in t for t in texts):
                            site_ok = False
                            break
                    mode_ok = site_ok
            if not mode_ok:
                findings.append(Finding(
                    _ANALYZER, "unkeyed-kernel-mode",
                    "traced callable "
                    f"{getattr(traced, 'name', '<lambda>')!r} dispatches "
                    "through the kernel lowering layer but the cache key "
                    f"of {enclosing.name!r} never folds in "
                    "oplib.kernel_sig() — toggling REPRO_KERNELS between "
                    "calls would reuse a program compiled for the other "
                    "mode",
                    subject=enclosing.name, file=mod.path, line=node.lineno,
                    suggestion="include oplib.kernel_sig() in the cache key "
                               "(directly, or in the key builder every call "
                               "site uses)"))

        via_params = uncovered & enc_params if key_frees & enc_params else set()
        direct = uncovered - via_params
        for name in sorted(direct):
            findings.append(Finding(
                _ANALYZER, "unkeyed-closure",
                f"traced callable {getattr(traced, 'name', '<lambda>')!r} "
                f"closes over {name!r}, which the cache key of "
                f"{enclosing.name!r} does not cover — two calls differing "
                f"only in {name!r} would share one compiled program",
                subject=name, file=mod.path, line=node.lineno,
                suggestion=f"include {name!r} (or a signature of it) in the "
                           "cache key, or declare it with "
                           f"# audit: invariant({name})"))

        if via_params:
            # the key is (partly) a parameter: verify every call site keys
            # the uncovered parameters through its own key argument
            key_params = key_frees & enc_params
            sites = [c for c in ast.walk(mod.tree)
                     if isinstance(c, ast.Call) and c is not node
                     and (_dotted(c.func) or "").rsplit(".", 1)[-1]
                     == enclosing.name]
            if not sites:
                for name in sorted(via_params):
                    findings.append(Finding(
                        _ANALYZER, "unkeyed-closure",
                        f"compiled-program factory {enclosing.name!r} binds "
                        f"parameter {name!r} into the trace with no call "
                        "site to verify it is covered by the key argument",
                        subject=name, file=mod.path, line=node.lineno))
                continue
            for call in sites:
                caller = parents.get(call)
                if caller is None:
                    continue
                cflow = _Flow(caller, mod)
                bound = _bind_call(call, enclosing,
                                   skip_self=isinstance(call.func,
                                                        ast.Attribute))
                kf: set[str] = set()
                for p in key_params:
                    if p in bound:
                        kf |= _free_names(bound[p])
                ccov = cflow.covered(kf) | set(_param_names(caller)) & set()
                cdecls = mod.waived_decls(call.lineno)
                cwaived = {n for _, n in cdecls}
                for name in sorted(via_params):
                    arg = bound.get(name)
                    if arg is None:
                        continue  # default value: static at def time
                    unkeyed_c = {f for f in cflow._expand(_free_names(arg))
                                 if f not in ccov and not mod.exempt(f)}
                    _mark_used(unkeyed_c & cwaived, cdecls)
                    bad = unkeyed_c - cwaived
                    for f in sorted(bad):
                        findings.append(Finding(
                            _ANALYZER, "unkeyed-closure",
                            f"call to {enclosing.name!r} at "
                            f"{mod.path}:{call.lineno} feeds {f!r} into "
                            f"traced parameter {name!r}, but the key "
                            "argument's dataflow does not cover it",
                            subject=f, file=mod.path, line=call.lineno,
                            suggestion=f"fold {f!r} (or a signature of it) "
                                       "into the cache key built at this "
                                       "call site"))

    for line, name in sorted(set(mod.invariant_decls)):
        if (line, name) not in used_decls:
            findings.append(Finding(
                _ANALYZER, "stale-waiver",
                f"# audit: invariant({name}) declaration suppresses "
                "nothing in this run — the free variable it names is "
                "covered, renamed, or gone",
                subject=name, file=mod.path, line=line,
                suggestion="delete the stale declaration (or re-attach it "
                           "to the jit site it was meant for)",
                severity="warning"))
    return findings


# ---------------------------------------------------------------------------
# FusedRule.covers predicates
# ---------------------------------------------------------------------------

def analyze_covers_source(
        source: str, path: str = "core/fused.py", *,
        covered_attrs: frozenset = _COVERS_KEYED_ATTRS) -> list[Finding]:
    """Verify every ``FusedRule`` covers predicate only branches on ctx
    attributes the engine's program key distinguishes.

    Rule selection runs at trace time; a predicate reading an attribute
    outside ``covered_attrs`` routes two key-identical calls to different
    lowerings.  The walk follows the predicate's ctx parameter through
    module helpers it forwards ctx to.
    """
    findings: list[Finding] = []
    mod = _Module(source, path)
    defs_by_name: dict[str, list[ast.AST]] = {}
    for n in ast.walk(mod.tree):
        if isinstance(n, (ast.FunctionDef, ast.AsyncFunctionDef)):
            defs_by_name.setdefault(n.name, []).append(n)

    for n in ast.walk(mod.tree):
        if not (isinstance(n, ast.Call)
                and (_dotted(n.func) or "").rsplit(".", 1)[-1]
                == "FusedRule"):
            continue
        cov: ast.AST | None = n.args[1] if len(n.args) >= 2 else None
        for kw in n.keywords:
            if kw.arg == "covers":
                cov = kw.value
        if cov is None:
            continue
        if isinstance(cov, ast.Lambda):
            fnode: ast.AST | None = cov
        elif isinstance(cov, ast.Name):
            cands = defs_by_name.get(cov.id, [])
            fnode = cands[-1] if cands else None
        else:
            fnode = None
        if fnode is None:
            findings.append(Finding(
                _ANALYZER, "uncovered-dispatch-input",
                "FusedRule covers predicate "
                f"{ast.unparse(cov)!r} cannot be resolved to a function in "
                "this module, so its dispatch inputs cannot be verified "
                "against the program key",
                subject=ast.unparse(cov), file=path, line=n.lineno,
                suggestion="use a module-level def or inline lambda as the "
                           "covers predicate"))
            continue

        waived = mod.waived(n.lineno) | mod.waived(fnode.lineno)
        # transitively collect first-level ctx-attribute reads
        reads: dict[str, int] = {}
        seen: set[int] = set()
        params = _param_names(fnode)
        work: list[tuple[ast.AST, str]] = (
            [(fnode, params[0])] if params else [])
        while work:
            f, ctxp = work.pop()
            if id(f) in seen:
                continue
            seen.add(id(f))
            for sub in ast.walk(f):
                if (isinstance(sub, ast.Attribute)
                        and isinstance(sub.value, ast.Name)
                        and sub.value.id == ctxp):
                    reads.setdefault(sub.attr, sub.lineno)
                elif (isinstance(sub, ast.Call)
                      and isinstance(sub.func, ast.Name)
                      and sub.func.id in defs_by_name):
                    callee = defs_by_name[sub.func.id][-1]
                    cps = _param_names(callee)
                    for i, a in enumerate(sub.args):
                        if (isinstance(a, ast.Name) and a.id == ctxp
                                and i < len(cps)):
                            work.append((callee, cps[i]))
        for attr in sorted(reads):
            if attr in covered_attrs or attr in waived:
                continue
            findings.append(Finding(
                _ANALYZER, "uncovered-dispatch-input",
                "FusedRule covers predicate "
                f"{getattr(fnode, 'name', '<lambda>')!r} branches on "
                f"ctx.{attr}, which the engine's program key does not "
                "distinguish — two key-identical calls could select "
                "different lowerings",
                subject=attr, file=path, line=reads[attr],
                suggestion=f"fold ctx.{attr} (or a signature of it) into "
                           "batch_key, or restrict the predicate to "
                           f"{sorted(covered_attrs)}"))
    return findings


# ---------------------------------------------------------------------------
# entry points
# ---------------------------------------------------------------------------

def analyze_source(source: str, path: str = "<string>") -> list[Finding]:
    """Analyze one module's source text (used by the fixture tests)."""
    return _analyze_module(_Module(source, path))


def analyze_jit_keys(src_root: str | Path | None = None,
                     targets: tuple = _DEFAULT_TARGETS,
                     fused_targets: tuple = _DEFAULT_FUSED_TARGETS,
                     ) -> list[Finding]:
    """Analyze the compiled-program modules (engine + streaming jit
    caches) for under-keyed traced closures, unkeyed kernel-mode
    dispatch, and covers predicates branching on unkeyed inputs."""
    if src_root is None:
        src_root = Path(__file__).resolve().parent.parent
    src_root = Path(src_root)
    findings: list[Finding] = []
    for rel in targets:
        py = src_root / rel
        if not py.exists():
            findings.append(Finding(
                _ANALYZER, "missing-target",
                f"expected compiled-program module {rel} is absent",
                subject=rel))
            continue
        path = str(py.relative_to(src_root.parent.parent))
        findings.extend(analyze_source(py.read_text(), path))
    for rel in fused_targets:
        py = src_root / rel
        if not py.exists():
            findings.append(Finding(
                _ANALYZER, "missing-target",
                f"expected fused-rule module {rel} is absent",
                subject=rel))
            continue
        path = str(py.relative_to(src_root.parent.parent))
        findings.extend(analyze_covers_source(py.read_text(), path))
    return findings
