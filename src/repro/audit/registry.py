"""Analyzer (1): registry / Table-I completeness (DESIGN.md §11).

The operator registry (`repro.core.oplib`) is the single declarative source
the planner's Table-I feasibility matrix, the fused engine, region closures,
and the store's materialization keys all derive from.  The ROADMAP's next
levers (Pallas fused kernels, sharded stores) each add lowering rules per
``(stage, scheme-family)`` cell, so this pass statically proves the
registry can't drift:

* every :class:`~repro.core.oplib.OpSpec`'s feasible cell has **exactly
  one** lowering rule (a family rule next to an ``"any"`` rule would
  silently shadow it in ``compute``; a missing rule raises ``KeyError`` at
  trace time, far from the declaration that caused it);
* region closures exist and are well-formed for every region-capable cell;
* the ``OPS`` / ``TEMPORAL_OPS`` registries merge collision-free and the
  merged view (``_ALL_OPS`` + canonical order) has not drifted;
* the planner's derived Table-I matrix agrees with the specs' own
  feasibility rows, for built-ins and user-registered ops alike.

All checks are *semantic* — they run against the live registries, so they
see exactly what ``compute`` will dispatch on, including ops added through
``oplib.register_op``.
"""
from __future__ import annotations

from collections.abc import Mapping

from repro.core import oplib
from repro.core.oplib import OpSpec
from repro.core.stages import Scheme

from .findings import Finding

_ANALYZER = "registry"


def _spec_findings(name: str, spec: OpSpec) -> list[Finding]:
    out = []
    if spec.name != name:
        out.append(Finding(
            _ANALYZER, "registry-drift",
            f"registered under {name!r} but spec.name is {spec.name!r}",
            subject=name,
            suggestion="register specs under their own name"))
    for invariant, message in oplib.spec_violations(spec):
        out.append(Finding(
            _ANALYZER, invariant, message, subject=spec.name,
            suggestion="declare exactly one lowering rule per feasible "
                       "(stage, scheme-family) cell and a closure per "
                       "region-capable cell"))
    return out


def _merge_findings(ops: Mapping[str, OpSpec],
                    temporal: Mapping[str, OpSpec]) -> list[Finding]:
    out = []
    collisions = set(ops) & set(temporal)
    for name in sorted(collisions):
        out.append(Finding(
            _ANALYZER, "registry-collision",
            f"op {name!r} is registered in both OPS and TEMPORAL_OPS",
            subject=name,
            suggestion="op names must be unique across registries "
                       "(oplib._merge_registries rejects this at import)"))
    return out


def _drift_findings(ops: Mapping[str, OpSpec],
                    temporal: Mapping[str, OpSpec]) -> list[Finding]:
    """The merged lookup and canonical order must cover exactly the union
    of the two registries — ``register_op`` keeps them in sync; anything
    else desynchronizes fused cache keys from planning."""
    out = []
    union = dict(ops)
    union.update(temporal)
    merged = set(oplib._ALL_OPS)
    for name in sorted(set(union) - merged):
        out.append(Finding(
            _ANALYZER, "registry-drift",
            f"op {name!r} is in a source registry but not in the merged "
            "_ALL_OPS lookup", subject=name,
            suggestion="register ops through oplib.register_op"))
    for name in sorted(merged - set(union)):
        out.append(Finding(
            _ANALYZER, "registry-drift",
            f"op {name!r} is in the merged _ALL_OPS lookup but in neither "
            "source registry", subject=name,
            suggestion="register ops through oplib.register_op"))
    for name in merged & set(union):
        if oplib._ALL_OPS[name] is not union[name]:
            out.append(Finding(
                _ANALYZER, "registry-drift",
                f"op {name!r}: merged lookup holds a different spec object "
                "than its source registry", subject=name,
                suggestion="never rebind registry entries in place"))
    missing_order = sorted(merged - set(oplib._ORDER))
    for name in missing_order:
        out.append(Finding(
            _ANALYZER, "registry-drift",
            f"op {name!r} has no canonical-order rank "
            "(order-insensitive fused cache keys would KeyError)",
            subject=name,
            suggestion="register ops through oplib.register_op"))
    return out


def _matrix_findings(ops: Mapping[str, OpSpec],
                     temporal: Mapping[str, OpSpec]) -> list[Finding]:
    """The planner's derived Table-I matrix must agree with the specs."""
    from repro.analytics import planner

    out = []
    union = dict(ops)
    union.update(temporal)
    for name, spec in union.items():
        for scheme in Scheme:
            declared = tuple(spec.feasible(scheme))
            derived = planner.feasible_stages(scheme, name)
            if tuple(derived) != declared:
                out.append(Finding(
                    _ANALYZER, "matrix-mismatch",
                    f"Table-I row for ({scheme.value}, {name}) is "
                    f"{tuple(s.name for s in derived)} but the spec "
                    f"declares {tuple(s.name for s in declared)}",
                    subject=name,
                    suggestion="planner.FEASIBILITY must derive from the "
                               "registry, never be edited by hand"))
    known = set(union)
    for (scheme, name) in planner.FEASIBILITY:
        if name not in known:
            out.append(Finding(
                _ANALYZER, "stale-matrix-row",
                f"Table-I matrix has a row for unknown op "
                f"({scheme.value}, {name})", subject=name,
                suggestion="drop rows for unregistered ops"))
    return out


def analyze_registry(ops: Mapping[str, OpSpec] | None = None,
                     temporal: Mapping[str, OpSpec] | None = None, *,
                     check_matrix: bool = True) -> list[Finding]:
    """Run the full registry-completeness pass.

    ``ops`` / ``temporal`` default to the live registries; tests pass
    synthetic registries with known-bad specs.  ``check_matrix=False``
    skips the planner cross-check (synthetic registries have no derived
    matrix to compare against).
    """
    ops = oplib.OPS if ops is None else ops
    temporal = oplib.TEMPORAL_OPS if temporal is None else temporal
    live = ops is oplib.OPS and temporal is oplib.TEMPORAL_OPS

    findings: list[Finding] = []
    for name, spec in ops.items():
        findings.extend(_spec_findings(name, spec))
        if spec.arity == "temporal":
            findings.append(Finding(
                _ANALYZER, "registry-drift",
                f"temporal-arity op {name!r} lives in the spatial OPS "
                "registry", subject=name,
                suggestion="register temporal ops in TEMPORAL_OPS"))
    for name, spec in temporal.items():
        findings.extend(_spec_findings(name, spec))
        if spec.arity != "temporal":
            findings.append(Finding(
                _ANALYZER, "registry-drift",
                f"{spec.arity}-arity op {name!r} lives in the temporal "
                "registry", subject=name,
                suggestion="register spatial ops in OPS"))
    findings.extend(_merge_findings(ops, temporal))
    if live:
        findings.extend(_drift_findings(ops, temporal))
    if check_matrix and live:
        findings.extend(_matrix_findings(ops, temporal))
    return findings
