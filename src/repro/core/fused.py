"""Fused Pallas lowering rules: the alternate ``OpSpec`` backend.

This module binds the VMEM-resident decode+op kernels in
``repro.kernels.fused`` to the lowering-rule registry in
:mod:`repro.core.oplib`.  Each :class:`FusedRule` pairs a rule callable
(same ``fn(ctx, axis)`` signature as the XLA rules) with a static
``covers`` predicate; ``oplib.select_rule`` picks the fused rule for a
``(stage, family)`` cell only when kernels are enabled
(``REPRO_KERNELS`` != ``off``) *and* the predicate accepts the concrete
context — otherwise the cell's XLA rule runs, unchanged.  The registry
invariant (enforced by ``spec_violations``) is that every fused cell has
an XLA rule to fall back to, so disabling kernels can never make an op
infeasible.

Coverage matrix (2-D nd schemes only — 1-D partitioning has no spatial
stencils, and rank != 2 fields fall back):

=============  ==========================  ==========================
op             lorenzo (HSZP_ND)           blockmean (HSZX_ND)
=============  ==========================  ==========================
derivative     ② ③ ④                       ② ③ ④
gradient       ② ③ ④                       ② ③ ④
laplacian      ②                           ② ③ ④
=============  ==========================  ==========================

The lorenzo ③④ laplacian cell is *deliberately* uncovered: its XLA rule
reduces over per-axis difference planes without ever forming q, and a
fused variant would have to materialize stage-③ integers to replicate
the rule's exact f32 sequence — the fallback is the honest lowering.
Statistics (mean/std) are likewise uncovered: their flat whole-extent
f32 reductions cannot be reproduced bitwise by a tile-wise kernel
accumulation.

Bit-identity contract: every covered cell's fused output equals the XLA
rule's output *bitwise* (``np.testing.assert_array_equal``), full-field
and region-windowed, Compressed and Encoded — and the identity must hold
in every *program shape* (solo jit, engine vmap, expression DAGs).  The
kernels therefore emit exact-integer stencil planes (or, for the
block-mean laplacians, the pre-eps f32 accumulation), and the rules here
apply the float tail — the same ``astype(float32)`` / eps-multiply ops
the XLA rules end with — on the already-sliced window.  With the multiply
outside the kernel, the rule's output-producing op is a small plain-HLO
multiply exactly like the XLA rules', so downstream fusion treats both
backends identically; a trailing in-kernel multiply, by contrast, gets
duplicated through the output slice into downstream adds and
FMA-contracted shape-dependently, which broke divergence bit-identity.
Stencil-then-slice equals slice-then-stencil on every interior element
(``tests/test_fused_kernels.py`` pins all cells).

Within a covered cell, each rule picks between the two kernel variants:
full-field :class:`Encoded` contexts (no region plan, no materialized
seed, 0 < bits < 32) take the *payload-input* kernels — gathered payload
words -> in-kernel bitplane unpack -> recorrelation -> stencil, one pass,
no residual plane in HBM — and everything else (Compressed containers,
region plans, seeds) takes the residual-plane kernels on ``ctx.sub``.
The in-kernel unpack is the same word arithmetic as
``encode.unpack_uniform``, so both variants produce identical integers
and the bit-identity contract is variant-independent.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Callable

import jax
import jax.numpy as jnp

from repro.kernels import fused as fk
from repro.kernels import ops as kops

from .stages import Encoded, Stage


@dataclass(frozen=True)
class FusedRule:
    """A Pallas-backed lowering rule with a static coverage predicate."""

    fn: Callable          # (ctx, axis) -> result, same signature as XLA rules
    covers: Callable      # (ctx) -> bool: can this rule serve the context?

    def __call__(self, ctx, axis: int):
        return self.fn(ctx, axis)


def _covers_2d(ctx) -> bool:
    """Rank-2 nd fields only: the kernels are 2-D band kernels, and the
    1-D schemes have no spatial stencils to fuse.  Judged on the container
    layout (not ``ctx.sub``) so coverage never forces a decode."""
    return ctx.scheme.is_nd and len(ctx.field.padded_shape) == 2


def _payload2(ctx) -> bool:
    """Can this context take the single-pass payload kernels?  Full-field
    :class:`Encoded` queries with a uniformly packed bitstream (0 < bits
    < 32 — bits==0 is the all-zero fast path, bits==32 stores raw words)
    and no materialized seed: the kernel unpacks its band's gathered
    payload words in VMEM and the residual plane never exists in HBM.
    Region plans keep the gather-then-unpack XLA path (the plan's word
    gather already reads only the window's payload)."""
    return (isinstance(ctx.field, Encoded) and ctx.plan is None
            and ctx._seed is None and 0 < ctx.field.bits < 32)


def _window2(ctx) -> tuple[slice, slice]:
    """The stencil-interior slices into the kernels' full padded-shape
    outputs: the region window (or the padding crop) shrunk by one at each
    end, so slicing after the kernel reads exactly the elements the XLA
    rules' window-then-stencil path reads."""
    if ctx.plan is not None:
        w0, w1 = ctx.plan.window
    else:
        w0, w1 = (slice(0, s) for s in ctx.field.shape)
    return slice(w0.start + 1, w0.stop - 1), slice(w1.start + 1, w1.stop - 1)


def _interpret() -> bool:
    return kops._interpret()


# -- lorenzo family ---------------------------------------------------------

def _lz(ctx, what: str):
    if _payload2(ctx):
        f = ctx.field
        return fk.lorenzo_enc2d(f.payload, tuple(f.padded_shape), f.bits,
                                what=what, interpret=_interpret())
    return fk.lorenzo2d(ctx.sub.residuals, what=what, interpret=_interpret())


def _deriv_lorenzo(ctx, axis: int) -> jax.Array:
    out = _lz(ctx, f"deriv{axis}")
    return out[_window2(ctx)].astype(jnp.float32) * ctx.eps


def _grad_lorenzo(ctx, axis: int) -> tuple[jax.Array, ...]:
    d0, d1 = _lz(ctx, "grad")
    w = _window2(ctx)
    return (d0[w].astype(jnp.float32) * ctx.eps,
            d1[w].astype(jnp.float32) * ctx.eps)


def _lap_lorenzo(ctx, axis: int) -> jax.Array:
    out = _lz(ctx, "lap")
    return out[_window2(ctx)].astype(jnp.float32) * (2.0 * ctx.eps)


# -- blockmean family -------------------------------------------------------

def _bm(ctx, what: str):
    if _payload2(ctx):
        f = ctx.field
        return fk.blockmean_enc2d(f.payload, f.metadata,
                                  tuple(f.padded_shape), tuple(f.block),
                                  f.bits, what=what, interpret=_interpret())
    sub = ctx.sub
    return fk.blockmean2d(sub.residuals, sub.metadata, tuple(sub.block),
                          what=what, interpret=_interpret())


def _deriv_blockmean(ctx, axis: int) -> jax.Array:
    out = _bm(ctx, f"deriv{axis}")
    return out[_window2(ctx)].astype(jnp.float32) * ctx.eps


def _grad_blockmean(ctx, axis: int) -> tuple[jax.Array, ...]:
    d0, d1 = _bm(ctx, "grad")
    w = _window2(ctx)
    return (d0[w].astype(jnp.float32) * ctx.eps,
            d1[w].astype(jnp.float32) * ctx.eps)


def _lap_blockmean_p(ctx, axis: int) -> jax.Array:
    return _bm(ctx, "lap_p")[_window2(ctx)] * (2.0 * ctx.eps)


def _lap_blockmean_q(ctx, axis: int) -> jax.Array:
    return _bm(ctx, "lap_q")[_window2(ctx)] * (2.0 * ctx.eps)


# -- registries wired onto the OpSpecs (oplib imports these) ----------------

def _rule(fn) -> FusedRule:
    return FusedRule(fn, _covers_2d)


#: derivative cells — also dispatched by ``oplib._derivative_at``, which
#: hands the kernels to gradient/divergence/curl compositions for free.
DERIVATIVE: dict[tuple[Stage, str], FusedRule] = {
    (Stage.P, "lorenzo"): _rule(_deriv_lorenzo),
    (Stage.Q, "lorenzo"): _rule(_deriv_lorenzo),
    (Stage.F, "lorenzo"): _rule(_deriv_lorenzo),
    (Stage.P, "blockmean"): _rule(_deriv_blockmean),
    (Stage.Q, "blockmean"): _rule(_deriv_blockmean),
    (Stage.F, "blockmean"): _rule(_deriv_blockmean),
}

#: gradient gets its own cells: one dual-output kernel pass instead of two.
GRADIENT: dict[tuple[Stage, str], FusedRule] = {
    (Stage.P, "lorenzo"): _rule(_grad_lorenzo),
    (Stage.Q, "lorenzo"): _rule(_grad_lorenzo),
    (Stage.F, "lorenzo"): _rule(_grad_lorenzo),
    (Stage.P, "blockmean"): _rule(_grad_blockmean),
    (Stage.Q, "blockmean"): _rule(_grad_blockmean),
    (Stage.F, "blockmean"): _rule(_grad_blockmean),
}

#: laplacian: lorenzo ③④ deliberately absent (see module docstring).
LAPLACIAN: dict[tuple[Stage, str], FusedRule] = {
    (Stage.P, "lorenzo"): _rule(_lap_lorenzo),
    (Stage.P, "blockmean"): _rule(_lap_blockmean_p),
    (Stage.Q, "blockmean"): _rule(_lap_blockmean_q),
    (Stage.F, "blockmean"): _rule(_lap_blockmean_q),
}
