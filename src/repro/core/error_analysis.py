"""Theoretical bias bounds for homomorphic operations (paper §V-D).

These closed forms are used as *oracles* by the property tests: every
homomorphic result must sit within its proven bound of the stage-④ result.
"""
from __future__ import annotations

import jax.numpy as jnp

from .stages import Compressed, Encoded, Stage


def mean_bias_bound(c: Compressed | Encoded, stage: Stage) -> float:
    """|mu_stage - mu_f| bound.

    §V-D.1: metadata means round each block to the nearest integer
    (|r_b| <= 1/2), so |mu_M - mu_f| <= eps.  §V-D.2: stages ②③ differ from ④
    only by float summation order — O(ulp) which we bound generously.
    """
    eps = float(jnp.asarray(c.eps))
    if stage == Stage.M:
        return eps
    return 64.0 * jnp.finfo(jnp.float32).eps * eps * max(1, c.n) ** 0.5


def std_bias_bound(c: Compressed | Encoded, stage: Stage) -> float:
    """§V-D.3: HSZx-family stage-② std uses the rounded integer mean, giving
    |sigma_p - sigma_f| <= eps; other stages are algebraically identical to
    V-A.2 (rounding only)."""
    eps = float(jnp.asarray(c.eps))
    if stage == Stage.P and c.scheme.is_blockmean:
        return eps
    return 64.0 * jnp.finfo(jnp.float32).eps * eps * max(1, c.n) ** 0.5


def stencil_bias_bound(c: Compressed | Encoded) -> float:
    """§V-D.5: finite differences are exact in the integer domain, so the
    stage-②/③ results differ from stage-④ only by float round-off."""
    eps = float(jnp.asarray(c.eps))
    return 32.0 * jnp.finfo(jnp.float32).eps * eps * 8.0


def reconstruction_bound(c: Compressed | Encoded, max_abs: float = 0.0) -> float:
    """The compressor's contract: |d - d'| <= eps (paper §III-A), plus the
    f32 round-off of the dequantize product (a few ulps of |d|)."""
    return float(jnp.asarray(c.eps)) + 4 * float(jnp.finfo(jnp.float32).eps) * max_abs
