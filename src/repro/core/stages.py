"""Data containers and stage definitions for the HSZ multi-stage pipeline.

The paper (§III-C, Table I) defines four progressive decompression stages:

    stage 1  D_m  metadata            (block anchors / block means, int)
    stage 2  D_p  decorrelated data   (prediction residuals, int)
    stage 3  D_q  quantized data      (linear-scaling quantization indices, int)
    stage 4  D_f  floating-point data (fully decompressed values)

On-device compressed arrays must be shape-stable under ``jax.jit`` (XLA has no
dynamic shapes), so the device-resident container keeps a dense residual array
plus per-block bitwidths; the *encoded* container additionally holds a
bit-packed payload at a uniform (static) bitwidth.  True per-block
variable-rate byte streams are produced only at the host serialization
boundary (``repro.core.encode.serialize``).  See DESIGN.md §3.
"""
from __future__ import annotations
from collections.abc import Sequence

import enum
from dataclasses import dataclass
from functools import partial
from typing import Any

import jax
import jax.numpy as jnp


class Stage(enum.IntEnum):
    """Decompression stages, paper Table I."""

    M = 1  # metadata
    P = 2  # decorrelated residuals
    Q = 3  # quantization integers
    F = 4  # floating point


class Scheme(str, enum.Enum):
    """The four compressor instances implemented by the paper (§IV)."""

    HSZP = "hszp"        # 1-D Lorenzo, inter-block chained (paper HSZp)
    HSZP_ND = "hszp_nd"  # n-D Lorenzo (paper HSZp-nd)
    HSZX = "hszx"        # 1-D block-mean predictor (paper HSZx)
    HSZX_ND = "hszx_nd"  # n-D block-mean predictor (paper HSZx-nd)

    @property
    def is_nd(self) -> bool:
        return self in (Scheme.HSZP_ND, Scheme.HSZX_ND)

    @property
    def is_lorenzo(self) -> bool:
        return self in (Scheme.HSZP, Scheme.HSZP_ND)

    @property
    def is_blockmean(self) -> bool:
        return self in (Scheme.HSZX, Scheme.HSZX_ND)


def _dataclass_pytree(cls=None, *, data_fields: tuple[str, ...], meta_fields: tuple[str, ...]):
    """Register a dataclass as a pytree with explicit data/meta split."""

    def wrap(c):
        return jax.tree_util.register_dataclass(
            c, data_fields=list(data_fields), meta_fields=list(meta_fields)
        )

    return wrap(cls) if cls is not None else wrap


@partial(
    _dataclass_pytree,
    data_fields=("residuals", "metadata", "bitwidths", "eps", "valid_counts"),
    meta_fields=("scheme", "shape", "padded_shape", "block", "orig_dtype"),
)
@dataclass(frozen=True)
class Compressed:
    """Device-resident compressed field (information-complete, shape-stable).

    ``residuals`` is D_p in *spatial* layout (padded to block multiples);
    ``metadata`` is D_m: block means for HSZx-family (block-grid layout) or the
    global anchor for HSZp-family (shape ``(1,)``).  ``bitwidths`` is the exact
    per-block fixed-rate code width (bits/value, sign included) used for size
    accounting and serialization; blocks in row-major grid order.
    """

    residuals: jax.Array      # int32, spatial padded layout
    metadata: jax.Array       # int32
    bitwidths: jax.Array      # int32 (n_blocks,)
    eps: jax.Array            # f32 scalar: absolute error bound
    valid_counts: jax.Array   # int32 (n_blocks,): valid elements per block (padding-aware)

    scheme: Scheme
    shape: tuple[int, ...]         # original (unpadded) data shape
    padded_shape: tuple[int, ...]  # residuals.shape
    block: tuple[int, ...]         # block shape (same rank as padded_shape)
    orig_dtype: Any

    @property
    def n(self) -> int:
        """Number of valid (original) elements."""
        size = 1
        for s in self.shape:
            size *= s
        return size

    @property
    def grid(self) -> tuple[int, ...]:
        return tuple(p // b for p, b in zip(self.padded_shape, self.block))

    @property
    def n_blocks(self) -> int:
        size = 1
        for g in self.grid:
            size *= g
        return size

    @property
    def block_elems(self) -> int:
        size = 1
        for b in self.block:
            size *= b
        return size

    def device_bytes(self) -> int:
        """Actual on-device bytes of the decoded container: every
        device-resident leaf (residuals + metadata + bitwidths +
        valid_counts + eps) — the byte cost a store pays to keep a
        stage-② materialization resident."""
        leaves = (self.residuals, self.metadata, self.bitwidths,
                  self.valid_counts, self.eps)
        return int(sum(x.size * x.dtype.itemsize for x in leaves))


@partial(
    _dataclass_pytree,
    data_fields=("payload", "metadata", "bitwidths", "eps", "valid_counts"),
    meta_fields=("scheme", "shape", "padded_shape", "block", "orig_dtype", "bits"),
)
@dataclass(frozen=True)
class Encoded:
    """Bit-packed compressed field (stage-0 on-device representation).

    ``payload`` packs zigzag-coded residuals at a *uniform* static width
    ``bits`` into ``uint32`` words.  Decoding the payload is the stage-2
    decompression step measured by the paper's throughput figures.
    """

    payload: jax.Array       # uint32 (n_words,)
    metadata: jax.Array      # int32
    bitwidths: jax.Array     # int32 (n_blocks,) exact per-block widths (accounting)
    eps: jax.Array           # f32 scalar
    valid_counts: jax.Array  # int32 (n_blocks,)

    scheme: Scheme
    shape: tuple[int, ...]
    padded_shape: tuple[int, ...]
    block: tuple[int, ...]
    orig_dtype: Any
    bits: int                # uniform packed width (zigzag bits per value)

    @property
    def n(self) -> int:
        size = 1
        for s in self.shape:
            size *= s
        return size

    @property
    def grid(self) -> tuple[int, ...]:
        return tuple(p // b for p, b in zip(self.padded_shape, self.block))

    @property
    def n_blocks(self) -> int:
        size = 1
        for g in self.grid:
            size *= g
        return size

    @property
    def block_elems(self) -> int:
        size = 1
        for b in self.block:
            size *= b
        return size

    def device_bytes(self) -> int:
        """Actual on-device compressed bytes: every device-resident leaf
        (payload + metadata + bitwidths + valid_counts + eps)."""
        leaves = (self.payload, self.metadata, self.bitwidths,
                  self.valid_counts, self.eps)
        return int(sum(x.size * x.dtype.itemsize for x in leaves))


# ===========================================================================
# batch-stackable view (substrate for `repro.analytics`)
# ===========================================================================

Field = Compressed | Encoded

#: static (pytree-meta) layout signature two fields must share to be stacked.
def layout_key(c: Field) -> tuple:
    """Hashable static layout of a field: every pytree-meta field, i.e.
    everything that must agree across batch items for the treedefs to match
    and `jax.vmap` to apply (the data leaves may differ freely)."""
    key: tuple = (type(c).__name__, c.scheme, c.shape, c.padded_shape, c.block,
                  jnp.dtype(c.orig_dtype))
    if isinstance(c, Encoded):
        key = key + (c.bits,)
    return key


def batch_stack(fields: Sequence[Field]) -> Field:
    """Stack same-layout fields into a leading batch axis on every data leaf.

    The result reuses the *unbatched* static metadata (``shape``,
    ``padded_shape``, ...), so it is **not** a valid single field — it is a
    view meant to be consumed through ``jax.vmap`` (axis 0), under which each
    program instance again sees metadata-consistent leaves.  Use
    :func:`batch_unstack` to recover the individual fields.
    """
    if not fields:
        raise ValueError("batch_stack needs at least one field")
    key0 = layout_key(fields[0])
    for i, f in enumerate(fields[1:], 1):
        if layout_key(f) != key0:
            raise ValueError(
                f"cannot stack fields with different layouts: field 0 has "
                f"{key0}, field {i} has {layout_key(f)}")
    return jax.tree.map(lambda *xs: jnp.stack(xs), *fields)


def batch_size(c: Field) -> int:
    """Leading batch-axis length of a :func:`batch_stack` view."""
    lead = c.residuals if isinstance(c, Compressed) else c.payload
    extra = lead.ndim - (len(c.padded_shape) if isinstance(c, Compressed) else 1)
    if extra != 1:
        raise ValueError("not a batch_stack view (no leading batch axis)")
    return int(lead.shape[0])


def batch_unstack(c: Field) -> list[Field]:
    """Inverse of :func:`batch_stack`: split the leading axis back into fields."""
    b = batch_size(c)
    return [jax.tree.map(lambda x: x[i], c) for i in range(b)]
