"""Blockwise fixed-rate encoding (paper §IV "Encoding").

The paper's encoder records, per block, the number of bits needed for the
largest-magnitude residual plus a sign plane.  We use the equivalent zigzag
formulation (``u = (p << 1) ^ (p >> 31)``): the zigzag width equals the
paper's (magnitude bits + 1 sign bit) and packs signs and magnitudes in one
plane — identical size accounting, branch-free SIMD decode.

Two packers are provided:

* **Device packer** (`pack_uniform` / `unpack_uniform`): packs at a *uniform*
  static width (shape-stable under jit; see DESIGN.md §3) using a
  segment-sum shift-or — O(n) memory, no per-bit materialization.  This is
  the wire/in-memory format used by compressed collectives and the KV cache.

* **Host serializer** (`serialize` / `deserialize`): exact per-block
  variable-rate byte stream (the paper's storage format) for checkpoints and
  compression-ratio benchmarks.  Vectorized numpy, no Python per-value loops.
"""
from __future__ import annotations

import struct

import numpy as np
import jax
import jax.numpy as jnp

from repro.kernels import ops as kernel_ops

from . import blocking
from .stages import Compressed, Encoded, Scheme

# v2: padding values are stored at width 0 (stream length == the valid-only
# `serialized_bits` accounting); v1 packed them at full block width, so v1
# blobs must be rejected, not misaligned-decoded.
_MAGIC = b"HSZ2"

# ---------------------------------------------------------------------------
# zigzag
# ---------------------------------------------------------------------------

def zigzag(p: jax.Array) -> jax.Array:
    """Map signed int32 -> unsigned-ordered uint32 (small |p| -> small u)."""
    return ((p << 1) ^ (p >> 31)).astype(jnp.uint32)


def unzigzag(u: jax.Array) -> jax.Array:
    ui = u.astype(jnp.int32)
    return (ui >> 1) ^ -(ui & 1)


# ---------------------------------------------------------------------------
# per-block exact bitwidths (size accounting / serialization)
# ---------------------------------------------------------------------------

def bitwidth_per_block(residuals: jax.Array, block: tuple[int, ...]) -> jax.Array:
    """Exact fixed-rate width (bits/value, sign incl.) per block, grid order."""
    u = zigzag(residuals)
    blocked = blocking.to_blocked(u, block)
    nd = len(block)
    maxu = jnp.max(blocked, axis=tuple(range(nd, 2 * nd)))
    # bits = 32 - clz(maxu); clz(0) == 32 -> width 0 (constant block fast path)
    bw = 32 - jax.lax.clz(maxu.astype(jnp.int32))
    return jnp.maximum(bw, 0).reshape(-1).astype(jnp.int32)


def serialized_bits(bitwidths: jax.Array, valid_counts: jax.Array, *,
                    meta_bits_per_block: int, global_meta_bits: int = 0) -> jax.Array:
    """Exact serialized size in bits: payload + per-block header + metadata.

    Per-block header = 6-bit width field (packed to a byte in `serialize`)
    + per-block scheme metadata (32-bit block mean for HSZx-family, 0 for
    HSZp-family).  ``global_meta_bits`` accounts metadata serialized once per
    stream (the HSZp-family 32-bit anchor slot) so Lorenzo compression ratios
    are not inflated relative to HSZx.

    The payload sum accumulates in f32 (int32 overflows past 2^31 payload
    bits — a ~1e8-element field at 16 bits/value; f32 keeps the sum exact up
    to 2^24 and within ~1e-7 relative beyond, ample for size accounting).
    """
    payload = jnp.sum(bitwidths * valid_counts, dtype=jnp.float32)
    header = bitwidths.shape[0] * (8 + meta_bits_per_block)
    return payload + header + global_meta_bits + 8 * 64  # + fixed global header


# ---------------------------------------------------------------------------
# device packer: uniform width, shape-stable
# ---------------------------------------------------------------------------

def words_for(n_values: int, bits: int) -> int:
    return -(-(n_values * bits) // 32) if bits > 0 else 0


def pack_uniform(u_flat: jax.Array, bits: int) -> jax.Array:
    """Pack ``n`` zigzag values at static width ``bits`` into uint32 words.

    Each value lands at bit offset ``i*bits``; its (<=2) word contributions are
    scatter-summed.  Fixed-rate => bit ranges are disjoint => sum == bitwise-or.
    """
    n = u_flat.shape[0]
    if bits == 0:
        return jnp.zeros((0,), jnp.uint32)
    if bits == 32:
        return u_flat.astype(jnp.uint32)
    nw = words_for(n, bits)
    mask = jnp.uint32((1 << bits) - 1)
    u = u_flat.astype(jnp.uint32) & mask
    offs = jnp.arange(n, dtype=jnp.uint32) * jnp.uint32(bits)
    widx = (offs >> 5).astype(jnp.int32)
    shift = offs & jnp.uint32(31)
    low = u << shift                      # uint32 shift drops overflow bits
    carry = shift > jnp.uint32(32 - bits)  # spills into the next word?
    high_shift = jnp.where(carry, jnp.uint32(32) - shift, jnp.uint32(31))
    high = jnp.where(carry, u >> high_shift, jnp.uint32(0))
    out = jax.ops.segment_sum(low, widx, num_segments=nw + 1)
    out = out + jax.ops.segment_sum(high, widx + 1, num_segments=nw + 1)
    return out[:nw].astype(jnp.uint32)


def unpack_uniform(payload: jax.Array, n: int, bits: int) -> jax.Array:
    """Inverse of :func:`pack_uniform`: recover ``n`` zigzag values."""
    if bits == 0:
        return jnp.zeros((n,), jnp.uint32)
    if bits == 32:
        return payload[:n].astype(jnp.uint32)
    mask = jnp.uint32((1 << bits) - 1)
    offs = jnp.arange(n, dtype=jnp.uint32) * jnp.uint32(bits)
    widx = (offs >> 5).astype(jnp.int32)
    shift = offs & jnp.uint32(31)
    pad = jnp.concatenate([payload, jnp.zeros((1,), jnp.uint32)])
    lo = pad[widx] >> shift
    carry = shift > jnp.uint32(32 - bits)
    hi_shift = jnp.where(carry, jnp.uint32(32) - shift, jnp.uint32(31))
    hi = jnp.where(carry, pad[widx + 1] << hi_shift, jnp.uint32(0))
    return (lo | hi) & mask


def encode_device(c: Compressed, bits: int) -> Encoded:
    """Bit-pack a :class:`Compressed` field at uniform static width ``bits``.

    Residuals wider than ``bits`` saturate in zigzag space, which keeps the
    error bounded by the *dequantization* of the clamp — callers choose
    ``bits`` >= max bitwidth (host-read) for losslessness, or budget bits and
    rely on error feedback (``repro.comm``).
    """
    u = zigzag(c.residuals.reshape(-1))
    if bits < 32:
        u = jnp.minimum(u, jnp.uint32((1 << bits) - 1))
    payload = pack_uniform(u, bits)
    return Encoded(
        payload=payload, metadata=c.metadata, bitwidths=c.bitwidths, eps=c.eps,
        valid_counts=c.valid_counts, scheme=c.scheme, shape=c.shape,
        padded_shape=c.padded_shape, block=c.block, orig_dtype=c.orig_dtype, bits=bits,
    )


def decode_device(e: Encoded) -> Compressed:
    """Stage-2 decode: unpack the payload back to residuals (D_p).

    Runs the Pallas bitplane-unpack kernel when kernels are enabled
    (``REPRO_KERNELS`` != ``off``), the XLA gather-shift path otherwise —
    both recover the exact packed integers, so the choice is invisible
    downstream (pinned in ``tests/test_fused_kernels.py``).  The region
    path (:func:`decode_region`) stays on the XLA word-gather: its cost
    scales with the gathered words, which a dense-grid kernel would void.
    """
    n = 1
    for s in e.padded_shape:
        n *= s
    if kernel_ops.kernels_enabled():
        u = kernel_ops.unpack(e.payload, n, e.bits)
    else:
        u = unpack_uniform(e.payload, n, e.bits)
    residuals = unzigzag(u).reshape(e.padded_shape)
    return Compressed(
        residuals=residuals, metadata=e.metadata, bitwidths=e.bitwidths, eps=e.eps,
        valid_counts=e.valid_counts, scheme=e.scheme, shape=e.shape,
        padded_shape=e.padded_shape, block=e.block, orig_dtype=e.orig_dtype,
    )


# ---------------------------------------------------------------------------
# region fast path: gather-unpack only the words covering a block subset
# ---------------------------------------------------------------------------

def unpack_gather(payload: jax.Array, *, word_idx=None, pos0, pos1, shift,
                  bits: int) -> jax.Array:
    """Unpack a *subset* of a uniform-width payload via static word gathers.

    ``word_idx`` selects the only payload words read; ``pos0``/``pos1``/
    ``shift`` (host-computed, static — see ``repro.core.region``) address each
    requested value's low/high word within that gathered set.  Cost scales
    with the gathered words, not the field.  ``word_idx=None`` means
    ``payload`` *is* the gathered word set already (the sharded store's
    scatter/psum word merge produces exactly that — ``repro.shard.exec``).
    """
    m = int(np.asarray(pos0).shape[0])
    if bits == 0:
        return jnp.zeros((m,), jnp.uint32)
    mask = jnp.uint32(0xFFFFFFFF if bits == 32 else (1 << bits) - 1)
    gathered = payload if word_idx is None else payload[jnp.asarray(word_idx)]
    words = jnp.concatenate([gathered, jnp.zeros((1,), jnp.uint32)])
    shift = jnp.asarray(shift)
    lo = words[jnp.asarray(pos0)] >> shift
    carry = shift > jnp.uint32(32 - bits)
    hi_shift = jnp.where(carry, jnp.uint32(32) - shift, jnp.uint32(31))
    hi = jnp.where(carry, words[jnp.asarray(pos1)] << hi_shift, jnp.uint32(0))
    return (lo | hi) & mask


def decode_region(e: Encoded, plan) -> Compressed:
    """Region fast path: stage-2 decode of only ``plan``'s gathered blocks.

    ``plan`` is a :class:`repro.core.region.RegionPlan`; the result is the
    honest sub-field over the gathered blocks (metadata / bitwidths / valid
    counts restricted to them), never the full residual array.
    """
    gi = plan.payload_gather(e.bits)
    u = unpack_gather(e.payload, word_idx=gi.word_idx, pos0=gi.pos0,
                      pos1=gi.pos1, shift=gi.shift, bits=e.bits)
    residuals = unzigzag(u).reshape(plan.sub_padded_shape)
    return plan.assemble(residuals, e)


# ---------------------------------------------------------------------------
# host serializer: exact per-block variable rate (the paper's storage format)
# ---------------------------------------------------------------------------

def _np_pack_bits(values: np.ndarray, widths_per_value: np.ndarray, total_bits: int) -> np.ndarray:
    """Scatter-pack uint32 ``values`` with per-value ``widths`` into a bitstream."""
    offs = np.zeros(values.shape[0], dtype=np.int64)
    np.cumsum(widths_per_value[:-1], out=offs[1:])
    nw = int(-(-total_bits // 32))
    # +2: zero-width values (padding / constant blocks) sitting at the very
    # end of the stream index up to word nw+1 with a zero contribution
    buf = np.zeros(nw + 2, dtype=np.uint64)
    widx = offs >> 5
    shift = (offs & 31).astype(np.uint64)
    v = values.astype(np.uint64)
    np.add.at(buf, widx, v << shift)          # 64-bit shift keeps spill bits
    hi = v >> (np.uint64(32) - shift.clip(max=31))
    spill = (v << shift) >> np.uint64(32)
    np.add.at(buf, widx + 1, spill)
    del hi
    # fold carries: low 32 bits of each word + nothing else (disjoint ranges)
    out = (buf & np.uint64(0xFFFFFFFF)).astype(np.uint32)
    # add spilled-in-buf-high contributions of word k into word k+1 (already
    # handled via `spill`); buf high bits beyond that are zero by construction
    return out[:nw]


def _np_unpack_bits(stream: np.ndarray, offs: np.ndarray, widths: np.ndarray) -> np.ndarray:
    """Gather per-value uint32 values with per-value bit offsets/widths."""
    pad = np.concatenate([stream, np.zeros(2, np.uint32)]).astype(np.uint64)
    widx = offs >> 5
    shift = (offs & 31).astype(np.uint64)
    raw = (pad[widx] | (pad[widx + 1] << np.uint64(32))) >> shift
    mask = (np.uint64(1) << widths.astype(np.uint64)) - np.uint64(1)
    return (raw & mask).astype(np.uint32)


_SCHEME_CODE = {Scheme.HSZP: 0, Scheme.HSZP_ND: 1, Scheme.HSZX: 2, Scheme.HSZX_ND: 3}
_CODE_SCHEME = {v: k for k, v in _SCHEME_CODE.items()}


def _valid_mask_blocked(shape, block) -> np.ndarray:
    """0/1 per-value validity in blocked (grid-major) order.

    Padding values get width 0 in the serialized stream, so the stream length
    equals the :func:`serialized_bits` accounting exactly (padding is never
    information: every valid reconstruction is independent of it).
    """
    work_shape = shape if len(shape) == len(block) else (int(np.prod(shape)),)
    mask = blocking.valid_mask(work_shape, block)
    return np.asarray(blocking.to_blocked(jnp.asarray(mask.astype(np.int64)),
                                          block)).reshape(-1)


def serialize(c: Compressed) -> bytes:
    """Exact per-block fixed-rate byte stream (paper's storage format)."""
    residuals = np.asarray(c.residuals).reshape(-1)
    bitwidths = np.asarray(c.bitwidths, dtype=np.uint8)
    metadata = np.asarray(c.metadata, dtype=np.int32)
    block_elems = c.block_elems
    vmask = _valid_mask_blocked(c.shape, c.block)
    widths_per_value_blocked = np.repeat(bitwidths.astype(np.int64), block_elems) * vmask
    # residuals are spatial; reorder to blocked (grid-major) order
    blocked = np.asarray(
        blocking.to_blocked(jnp.asarray(residuals.reshape(c.padded_shape)), c.block)
    ).reshape(-1)
    ub = np.asarray(zigzag(jnp.asarray(blocked))) * vmask.astype(np.uint32)
    total_bits = int(widths_per_value_blocked.sum())
    stream = _np_pack_bits(ub, widths_per_value_blocked, max(total_bits, 1))

    hdr = struct.pack(
        "<4sBBBdi", _MAGIC, _SCHEME_CODE[c.scheme], len(c.shape), len(c.block),
        float(np.asarray(c.eps)), int(c.n_blocks),
    )
    dims = struct.pack(f"<{len(c.shape)}q{len(c.block)}q", *c.shape, *c.block)
    return b"".join([
        hdr, dims,
        bitwidths.tobytes(), metadata.tobytes(),
        np.int64(total_bits).tobytes(), stream.tobytes(),
    ])


def deserialize(data: bytes) -> Compressed:
    magic, scheme_code, ndim, bdim, eps, n_blocks = struct.unpack_from("<4sBBBdi", data, 0)
    if magic != _MAGIC:
        raise ValueError("not an HSZ stream")
    off = struct.calcsize("<4sBBBdi")
    dims = struct.unpack_from(f"<{ndim + bdim}q", data, off)
    off += 8 * (ndim + bdim)
    shape, block = tuple(dims[:ndim]), tuple(dims[ndim:])
    scheme = _CODE_SCHEME[scheme_code]
    bitwidths = np.frombuffer(data, np.uint8, n_blocks, off).astype(np.int32)
    off += n_blocks
    meta_count = n_blocks if scheme in (Scheme.HSZX, Scheme.HSZX_ND) else 1
    metadata = np.frombuffer(data, np.int32, meta_count, off)
    off += 4 * meta_count
    total_bits = int(np.frombuffer(data, np.int64, 1, off)[0])
    off += 8
    stream = np.frombuffer(data, np.uint32, -(-max(total_bits, 1) // 32), off)

    # 1-D schemes flatten n-D data; recover the blocking work-shape
    work_shape = shape if len(block) == len(shape) else (int(np.prod(shape)),)
    pshape = blocking.padded_shape(work_shape, block)
    block_elems = int(np.prod(block))
    widths = np.repeat(bitwidths.astype(np.int64), block_elems)
    widths *= _valid_mask_blocked(shape, block)
    if total_bits != int(widths.sum()):
        raise ValueError(
            f"corrupt HSZ stream: header claims {total_bits} payload bits, "
            f"metadata implies {int(widths.sum())}")
    offs = np.zeros(widths.shape[0], dtype=np.int64)
    np.cumsum(widths[:-1], out=offs[1:])
    u = _np_unpack_bits(stream, offs, widths)
    blocked = np.asarray(unzigzag(jnp.asarray(u)))
    grid = tuple(p // b for p, b in zip(pshape, block))
    residuals = np.asarray(
        blocking.from_blocked(jnp.asarray(blocked.reshape(grid + block)), block)
    )
    vc = blocking.valid_counts(work_shape, block)
    if scheme in (Scheme.HSZX, Scheme.HSZX_ND):
        meta = jnp.asarray(metadata.reshape(grid))
    else:
        meta = jnp.asarray(metadata)
    return Compressed(
        residuals=jnp.asarray(residuals), metadata=meta,
        bitwidths=jnp.asarray(bitwidths), eps=jnp.float32(eps),
        valid_counts=jnp.asarray(vc), scheme=scheme, shape=shape,
        padded_shape=tuple(pshape), block=block, orig_dtype=jnp.float32,
    )
