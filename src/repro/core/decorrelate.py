"""Decorrelation / recorrelation transforms (paper §IV "Decorrelation").

Two predictor families, each with 1-D and n-D variants:

* **Lorenzo** (HSZp / HSZp-nd): ``p = (I - S_0)(I - S_1)...q`` where ``S_a`` is
  the unit shift along axis ``a`` (zero boundary).  The paper's HSZp chains
  predictions across block boundaries (§IV "HSZp"), so recorrelation is a
  prefix sum along every axis — a *parallel scan* on TPU rather than the
  paper's scalar CPU accumulator (DESIGN.md §3).

* **Block-mean** (HSZx / HSZx-nd): ``p_i = q_i - M_b`` with the *rounded block
  mean* ``M_b = round(mean(q | block b))`` stored as metadata — the paper's
  §IV "HSZx" modification of SZx (mean of all data rather than (min+max)/2),
  chosen precisely because it makes mean-related analytics metadata-only.

Both transforms are linear (up to metadata rounding), which is what makes the
homomorphic algorithms of §V possible — and what makes compressed-domain
gradient accumulation valid (``repro.comm``).
"""
from __future__ import annotations
from collections.abc import Sequence

import jax
import jax.numpy as jnp

from . import blocking


# ---------------------------------------------------------------------------
# Lorenzo (HSZp family)
# ---------------------------------------------------------------------------

def _shift_diff(x: jax.Array, axis: int) -> jax.Array:
    """``x - shift(x)`` along ``axis`` with zero boundary (first slice kept)."""
    shifted = jnp.concatenate(
        [jnp.zeros_like(jax.lax.slice_in_dim(x, 0, 1, axis=axis)),
         jax.lax.slice_in_dim(x, 0, x.shape[axis] - 1, axis=axis)],
        axis=axis,
    )
    return x - shifted


def lorenzo(q: jax.Array) -> jax.Array:
    """n-D Lorenzo transform: residuals ``p`` from quantized data ``q``.

    For 2-D this is ``p_ij = q_ij - q_{i,j-1} - q_{i-1,j} + q_{i-1,j-1}``; for
    3-D the paper's 8-corner alternating sum — both factor into per-axis
    first differences.
    """
    p = q
    for axis in range(q.ndim):
        p = _shift_diff(p, axis)
    return p


def unlorenzo(p: jax.Array) -> jax.Array:
    """Inverse Lorenzo: prefix-sum along every axis (parallel scan on TPU)."""
    q = p
    for axis in range(p.ndim):
        q = jnp.cumsum(q, axis=axis, dtype=q.dtype)
    return q


# ---------------------------------------------------------------------------
# Block-mean (HSZx family)
# ---------------------------------------------------------------------------

def block_means(q: jax.Array, block: Sequence[int], valid: jax.Array | None = None) -> jax.Array:
    """Rounded per-block integer means, grid layout.

    ``valid`` is an optional boolean spatial mask; means are taken over valid
    elements only so padding never biases stage-① statistics.
    """
    blocked = blocking.to_blocked(q, block)
    nd = len(block)
    reduce_axes = tuple(range(nd, 2 * nd))
    # int32 accumulation: 2*|q|*block_elems must stay < 2^31 — true for the
    # block sizes (<= 4096) and error bounds this framework configures.
    if valid is None:
        counts = 1
        for b in block:
            counts *= b
        sums = jnp.sum(blocked, axis=reduce_axes, dtype=jnp.int32)
    else:
        vb = blocking.to_blocked(valid.astype(jnp.int32), block)
        sums = jnp.sum(blocked * vb, axis=reduce_axes, dtype=jnp.int32)
        counts = jnp.maximum(jnp.sum(vb, axis=reduce_axes, dtype=jnp.int32), 1)
    # Exact integer round-half-up: round(s/c) = floor((2s + c) / (2c)); numpy
    # integer // floors, which handles negative sums correctly.
    means = (2 * sums + counts) // (2 * counts)
    return means.astype(jnp.int32)


def blockmean_decorrelate(q: jax.Array, means: jax.Array, block: Sequence[int]) -> jax.Array:
    """``p = q - upsample(M)`` (HSZx / HSZx-nd)."""
    return q - blocking.upsample_block_means(means, block)


def blockmean_recorrelate(p: jax.Array, means: jax.Array, block: Sequence[int]) -> jax.Array:
    """``q = p + upsample(M)``."""
    return p + blocking.upsample_block_means(means, block)
