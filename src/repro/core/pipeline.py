"""HSZ compression pipeline with multi-stage decompression (paper §IV, Alg. 1-2).

Four compressor instances share one pipeline::

    quantize -> partition -> metadata -> decorrelate -> encode

and decompression stops at any of the four stages (Table I).  The device
pipeline is fully jit-able; `compress` is linear-algebraic (quantize +
decorrelate are linear maps), which the homomorphic collectives in
``repro.comm`` rely on.
"""
from __future__ import annotations

from dataclasses import dataclass

import jax
import jax.numpy as jnp

from . import blocking, decorrelate, encode, quantize
from .stages import Compressed, Encoded, Scheme, Stage

DEFAULT_BLOCKS = {1: (256,), 2: (16, 16), 3: (8, 8, 8)}


class UnsupportedStageError(NotImplementedError):
    """Raised when an operation is not defined at a decompression stage

    (e.g. stage-① mean for HSZp-family, stage-② stencils for 1-D schemes —
    paper §V-A/§V-B)."""


@dataclass(frozen=True)
class HSZCompressor:
    """One of the paper's four compressors (Table II)."""

    scheme: Scheme
    block: tuple[int, ...] | None = None  # None -> per-rank default

    # -- helpers -----------------------------------------------------------
    def _layout(self, shape: tuple[int, ...]) -> tuple[tuple[int, ...], tuple[int, ...]]:
        """(logical working shape, block shape) for this scheme."""
        if self.scheme.is_nd:
            nd = len(shape)
            if nd not in (1, 2, 3):
                raise ValueError(f"nd schemes support 1-3 dims, got {nd}")
            block = self.block or DEFAULT_BLOCKS[nd]
            if len(block) != nd:
                raise ValueError("block rank != data rank")
            return shape, tuple(block)
        # 1-D schemes flatten the data (paper §IV: "treat the original data
        # as a 1D array regardless of their original dimensions")
        n = 1
        for s in shape:
            n *= s
        block = self.block or DEFAULT_BLOCKS[1]
        return (n,), tuple(block)

    # -- compression (Alg. 1) ---------------------------------------------
    def compress(self, data: jax.Array, *, abs_eb: float | None = None,
                 rel_eb: float | None = None, eps: jax.Array | None = None) -> Compressed:
        orig_shape = tuple(data.shape)
        work_shape, block = self._layout(orig_shape)
        if eps is None:
            eps = quantize.resolve_eps(data, abs_eb=abs_eb, rel_eb=rel_eb)
        eps = jnp.asarray(eps, jnp.float32)
        q = quantize.quantize(data.reshape(work_shape), eps)
        q = blocking.pad_to_blocks(q, block)

        vc = jnp.asarray(blocking.valid_counts(work_shape, block))
        if self.scheme.is_blockmean:
            # padding is a static property of (shape, block): decide it without
            # materializing the mask so compress stays vmap/jit-composable
            valid = (jnp.asarray(blocking.valid_mask(work_shape, block))
                     if blocking.has_padding(work_shape, block) else None)
            means = decorrelate.block_means(q, block, valid=valid)
            residuals = decorrelate.blockmean_decorrelate(q, means, block)
            metadata = means
        else:
            residuals = decorrelate.lorenzo(q)
            metadata = jnp.zeros((1,), jnp.int32)  # anchor q_0 lives in residuals

        bitwidths = encode.bitwidth_per_block(residuals, block)
        return Compressed(
            residuals=residuals, metadata=metadata, bitwidths=bitwidths, eps=eps,
            valid_counts=vc, scheme=self.scheme, shape=orig_shape,
            padded_shape=tuple(residuals.shape), block=block,
            orig_dtype=jnp.dtype(data.dtype),
        )

    # -- multi-stage decompression (Alg. 2) --------------------------------
    def reconstruct_q(self, c: Compressed) -> jax.Array:
        """Stage ③: recorrelate residuals back to quantization indices (padded)."""
        if c.scheme.is_blockmean:
            return decorrelate.blockmean_recorrelate(c.residuals, c.metadata, c.block)
        return decorrelate.unlorenzo(c.residuals)

    def decompress(self, c: Compressed | Encoded, stage: Stage = Stage.F, *, crop: bool = True):
        """Return the intermediate representation at ``stage`` (paper Alg. 2)."""
        if isinstance(c, Encoded) and stage != Stage.M:
            c = encode.decode_device(c)
        if stage == Stage.M:
            return c.metadata
        if stage == Stage.P:
            return c.residuals
        q = self.reconstruct_q(c)
        if stage == Stage.Q:
            return self._restore(q, c) if crop else q
        d = quantize.dequantize(q, c.eps, dtype=c.orig_dtype)
        return self._restore(d, c) if crop else d

    def _restore(self, x: jax.Array, c: Compressed) -> jax.Array:
        """Crop padding and restore the original (pre-flatten) shape."""
        if self.scheme.is_nd:
            return blocking.crop(x, c.shape)
        n = c.n
        return x.reshape(-1)[:n].reshape(c.shape)

    # -- encoding ----------------------------------------------------------
    def max_bits(self, c: Compressed) -> int:
        """Exact max per-block width as a Python int (host device sync)."""
        try:
            return int(jnp.max(c.bitwidths))
        except jax.errors.JAXTypeError as e:  # traced: no concrete value
            raise ValueError(
                "max_bits() syncs the bitwidth to host and cannot run inside "
                "jit/vmap; compute it outside the traced region and pass the "
                "static result to encode(bits=...)") from e

    def encode(self, c: Compressed, bits: int | None = None) -> Encoded:
        """Bit-pack at uniform width; ``bits=None`` reads the exact max width
        from the device (host sync) for a lossless container.  Inside traced
        code the packed width must be static: pass ``bits`` explicitly
        (``comp.max_bits(c)`` ahead of the trace gives a lossless choice)."""
        if bits is None:
            bits = self.max_bits(c)
        return encode.encode_device(c, bits)

    # -- accounting ---------------------------------------------------------
    def serialized_bits(self, c: Compressed | Encoded) -> jax.Array:
        # HSZx-family stores a 32-bit mean per block; HSZp-family serializes
        # one global 32-bit anchor slot (see `encode.serialize`) — previously
        # unaccounted, inflating Lorenzo ratios relative to HSZx.
        meta_bits = 32 if self.scheme.is_blockmean else 0
        global_bits = 0 if self.scheme.is_blockmean else 32
        return encode.serialized_bits(c.bitwidths, c.valid_counts,
                                      meta_bits_per_block=meta_bits,
                                      global_meta_bits=global_bits)

    def compression_ratio(self, c: Compressed | Encoded) -> jax.Array:
        # float: n*32 overflows int32 for fields >= 2^26 elements
        orig_bits = float(c.n) * 32.0
        return orig_bits / self.serialized_bits(c)


# the paper's four instances (Table II)
hszp = HSZCompressor(Scheme.HSZP)
hszp_nd = HSZCompressor(Scheme.HSZP_ND)
hszx = HSZCompressor(Scheme.HSZX)
hszx_nd = HSZCompressor(Scheme.HSZX_ND)

_BY_NAME = {"hszp": hszp, "hszp_nd": hszp_nd, "hszx": hszx, "hszx_nd": hszx_nd}


def by_name(name: str, block: tuple[int, ...] | None = None) -> HSZCompressor:
    base = _BY_NAME[name]
    return HSZCompressor(base.scheme, block) if block is not None else base
