"""Block-sparse region queries over compressed/encoded fields (DESIGN.md §5).

An analytical operation over a spatial sub-region should touch only the
blocks that cover it, not decode the whole field.  Because the device
container packs residuals at a *uniform* static width (``Encoded.bits``),
the payload words holding any block are statically computable host-side:
a region query gathers exactly those words (plus the per-block metadata /
bitwidths / valid counts of the covering blocks) and unpacks nothing else.

The gathered blocks always form an *honest sub-field* — a smaller
:class:`~repro.core.stages.Compressed` whose every invariant holds — so the
homomorphic operators reuse their existing stage arithmetic on it:

* **block-mean family** (HSZx/HSZx-nd): every block is self-contained, so
  the closure of a region is its geometric covering block set;
* **Lorenzo family** (HSZp/HSZp-nd): recorrelation is a prefix sum, so the
  closure is the origin-anchored *prefix hull* ``[0, stop)`` per axis — a
  prefix-rectangle restriction of a Lorenzo field is itself a valid Lorenzo
  field (the zero boundary at the origin is preserved).  Stage-② derivatives
  only prefix-sum over the non-derivative axes, so their closure narrows to
  a *band*: covering range on the derivative axis, hull on the others.

All plan geometry (block ranges, flat indices, payload word indices, window
index maps, statistic weights) is computed host-side with numpy from static
shapes, memoized, and enters traced code only as constants — region ops stay
``jit``/``vmap``-composable exactly like their full-field counterparts.
"""
from __future__ import annotations
from collections.abc import Sequence

from collections import OrderedDict

import numpy as np
import jax
import jax.numpy as jnp

from . import encode
from .stages import Compressed, Encoded, Scheme, Stage

#: one axis of a region: ``None`` (full axis), a ``slice``, or ``(start, stop)``.
AxisSpec = None | slice | tuple[int, int] | Sequence[int]
RegionSpec = Sequence[AxisSpec]

#: closure kinds: ``"cover"`` (geometric covering blocks), ``"hull"``
#: (origin-anchored prefix rectangle), ``("band", axis)`` (cover on ``axis``,
#: hull on the others — Lorenzo stage-② derivatives).
Closure = str | tuple[str, int]


def normalize_region(region: RegionSpec, shape: Sequence[int]) -> tuple[tuple[int, int], ...]:
    """Canonicalize a region to per-axis ``(start, stop)`` over ``shape``.

    Accepts ``None`` / ``slice(start, stop)`` / ``(start, stop)`` per axis;
    negative indices count from the axis end, python-style.
    """
    if len(region) != len(shape):
        raise ValueError(f"region rank {len(region)} != field rank {len(shape)}")
    out = []
    for spec, s in zip(region, shape):
        if spec is None:
            start, stop = 0, s
        elif isinstance(spec, slice):
            if spec.step not in (None, 1):
                raise ValueError("region slices must have step 1")
            start, stop, _ = spec.indices(s)
        else:
            start, stop = spec
            start = int(start) + (s if start < 0 else 0)
            stop = int(stop) + (s if stop < 0 else 0)
        if not (0 <= start < stop <= s):
            raise ValueError(f"region axis ({start}, {stop}) out of bounds for size {s}")
        out.append((int(start), int(stop)))
    return tuple(out)


class GatherIndex:
    """Static payload-gather arrays for one ``(plan, bits)`` pair.

    ``word_idx`` are the only payload words touched; ``pos0``/``pos1``/
    ``shift`` address each gathered value's (<= 2) word contributions within
    that gathered word set (``pos1`` may point at the appended zero word).
    """

    def __init__(self, word_idx: np.ndarray, pos0: np.ndarray, pos1: np.ndarray,
                 shift: np.ndarray, n_values: int):
        self.word_idx = word_idx
        self.pos0 = pos0
        self.pos1 = pos1
        self.shift = shift
        self.n_values = n_values

    @property
    def n_words(self) -> int:
        """Number of payload words a region decode gathers."""
        return int(self.word_idx.shape[0])


class RegionPlan:
    """Host-side static plan of one region query over one field layout.

    Built once per ``(layout, region, closure)`` and memoized; holds the
    gathered block set, the sub-field geometry, the window index map, and the
    lazily-built payload word-gather / statistic-weight arrays.
    """

    def __init__(self, scheme: Scheme, shape: tuple[int, ...],
                 padded_shape: tuple[int, ...], block: tuple[int, ...],
                 region: tuple[tuple[int, int], ...], closure: Closure):
        self.scheme = scheme
        self.shape = shape              # original (logical) data shape
        self.padded_shape = padded_shape
        self.block = block
        self.region = region            # normalized, original-shape coords
        self.closure = closure
        self._gather_cache: dict[int, GatherIndex] = {}
        self._weights: tuple[np.ndarray, ...] | None = None

        grid = tuple(p // b for p, b in zip(padded_shape, block))
        self.grid = grid
        if scheme.is_nd:
            self._build_nd(grid)
        else:
            self._build_flat(grid)
        self.win_shape = tuple(e - s for s, e in region)
        self.n_window = int(np.prod(self.win_shape))
        self.n_sub_blocks = int(self.block_ids.shape[0])
        self.gathered_elems = int(np.prod(self.sub_padded_shape))

    # -- construction -------------------------------------------------------
    def _axis_block_range(self, axis: int, s: int, e: int) -> tuple[int, int]:
        b = self.block[axis]
        if self.closure == "hull" or (
                isinstance(self.closure, tuple) and self.closure[1] != axis):
            return 0, -(-e // b)
        return s // b, -(-e // b)

    def _build_nd(self, grid: tuple[int, ...]) -> None:
        block = self.block
        ranges = tuple(self._axis_block_range(a, s, e)
                       for a, (s, e) in enumerate(self.region))
        self.grid_ranges = ranges
        self.sub_padded_shape = tuple((hi - lo) * b for (lo, hi), b in zip(ranges, block))
        self.sub_shape = tuple(min(hi * b, s) - lo * b
                               for (lo, hi), b, s in zip(ranges, block, self.shape))
        self.window = tuple(slice(s - lo * b, e - lo * b)
                            for (s, e), (lo, _), b in zip(self.region, ranges, block))
        self.spatial_slices = tuple(slice(lo * b, hi * b)
                                    for (lo, hi), b in zip(ranges, block))
        self.grid_slices = tuple(slice(lo, hi) for lo, hi in ranges)
        axes = [np.arange(lo, hi) for lo, hi in ranges]
        mesh = np.meshgrid(*axes, indexing="ij")
        self.block_ids = np.ravel_multi_index(tuple(mesh), grid).reshape(-1)
        self.win_pos = None
        # per-gathered-block window-overlap element counts (outer product)
        per_axis = []
        for (s, e), (lo, hi), b in zip(self.region, ranges, block):
            i = np.arange(lo, hi)
            per_axis.append(np.clip(np.minimum(e, (i + 1) * b)
                                    - np.maximum(s, i * b), 0, None))
        ov = per_axis[0]
        for a in per_axis[1:]:
            ov = np.multiply.outer(ov, a)
        self.overlap = ov.reshape(-1).astype(np.int32)
        self.aligned = all(s % b == 0 and (e % b == 0 or e == dim)
                           for (s, e), b, dim in zip(self.region, block, self.shape))

    def _build_flat(self, grid: tuple[int, ...]) -> None:
        """1-D schemes flatten the data; a spatial region becomes a union of
        row-major flat runs whose covering block *set* (not range) is gathered."""
        b = self.block[0]
        n = int(np.prod(self.shape))
        lead = [np.arange(s, e) for s, e in self.region[:-1]]
        s_last, e_last = self.region[-1]
        if lead:
            mesh = np.meshgrid(*lead, indexing="ij")
            starts = np.ravel_multi_index(
                tuple(mesh) + (np.full(mesh[0].shape, s_last),), self.shape).reshape(-1)
        else:
            starts = np.asarray([s_last], dtype=np.int64)
        win_flat = (starts[:, None] + np.arange(e_last - s_last)).reshape(-1)
        self.win_flat = win_flat  # ascending (row-major region order)
        cover_ids = np.unique(win_flat // b)
        if self.scheme.is_lorenzo:
            # prefix hull: every block up to the last one the window touches
            self.block_ids = np.arange(int(cover_ids[-1]) + 1, dtype=np.int64)
        else:
            self.block_ids = cover_ids
        nb = int(self.block_ids.shape[0])
        self.sub_padded_shape = (nb * b,)
        # only the field's final block is partial, and it sorts last — so the
        # gathered valid elements are a prefix of the gathered layout
        per_block_valid = np.minimum(b, n - self.block_ids * b)
        self.sub_shape = (int(per_block_valid.sum()),)
        self.window = None
        rank = np.searchsorted(self.block_ids, win_flat // b)
        self.win_pos = (rank * b + win_flat % b).astype(np.int32)
        self.overlap = np.bincount(rank, minlength=nb).astype(np.int32)
        cover_rank = np.searchsorted(self.block_ids, cover_ids)
        self.aligned = bool(
            np.array_equal(self.overlap[cover_rank],
                           np.minimum(b, n - cover_ids * b)))
        self.grid_ranges = None
        self.grid_slices = None
        self.spatial_slices = None

    # -- payload word gather (Encoded fast path) ----------------------------
    def payload_gather(self, bits: int) -> GatherIndex:
        """Static word-gather arrays for a uniform-width payload at ``bits``."""
        gi = self._gather_cache.get(bits)
        if gi is not None:
            return gi
        if self.scheme.is_nd:
            axes = [np.arange(lo * b, hi * b)
                    for (lo, hi), b in zip(self.grid_ranges, self.block)]
            mesh = np.meshgrid(*axes, indexing="ij")
            gflat = np.ravel_multi_index(tuple(mesh), self.padded_shape).reshape(-1)
        else:
            b = self.block[0]
            gflat = (self.block_ids[:, None] * b + np.arange(b)).reshape(-1)
        m = int(gflat.shape[0])
        if bits == 0:
            gi = GatherIndex(np.zeros((0,), np.int32), np.zeros((m,), np.int32),
                             np.zeros((m,), np.int32), np.zeros((m,), np.uint32), m)
        else:
            total_words = encode.words_for(int(np.prod(self.padded_shape)), bits)
            offs = gflat.astype(np.int64) * bits
            w0 = offs >> 5
            uniq = np.unique(np.concatenate([w0, w0 + 1]))
            uniq = uniq[uniq < total_words]
            pos0 = np.searchsorted(uniq, w0).astype(np.int32)
            w1 = w0 + 1
            pos1 = np.where(w1 < total_words, np.searchsorted(uniq, w1),
                            uniq.shape[0]).astype(np.int32)
            gi = GatherIndex(uniq.astype(np.int32), pos0, pos1,
                             (offs & 31).astype(np.uint32), m)
        self._gather_cache[bits] = gi
        return gi

    # -- sub-field assembly --------------------------------------------------
    def gather_metadata(self, c: Compressed | Encoded) -> jax.Array:
        """Metadata restricted to the gathered blocks (no payload decode)."""
        if not c.scheme.is_blockmean:
            return c.metadata  # Lorenzo: global anchor lives in the residuals
        if self.grid_slices is not None:
            return c.metadata[self.grid_slices]
        return c.metadata.reshape(-1)[jnp.asarray(self.block_ids.astype(np.int32))]

    def assemble(self, residuals: jax.Array, src: Compressed | Encoded) -> Compressed:
        """Build the honest sub-field around gathered residuals."""
        ids = jnp.asarray(self.block_ids.astype(np.int32))
        return Compressed(
            residuals=residuals, metadata=self.gather_metadata(src),
            bitwidths=src.bitwidths[ids], eps=src.eps,
            valid_counts=src.valid_counts[ids], scheme=src.scheme,
            shape=self.sub_shape, padded_shape=self.sub_padded_shape,
            block=src.block, orig_dtype=src.orig_dtype)

    # -- window access -------------------------------------------------------
    def window_of(self, arr: jax.Array) -> jax.Array:
        """Crop a sub-field spatial array to the requested window.

        nd schemes slice the gathered rectangle; 1-D schemes gather the
        window's flat positions (static index map) and restore the n-D shape.
        """
        if self.window is not None:
            return arr[self.window]
        return arr.reshape(-1)[jnp.asarray(self.win_pos)].reshape(self.win_shape)

    def lorenzo_mean_weights(self) -> tuple[np.ndarray, ...]:
        """Window-sum weights: ``sum_{i in window} q_i = <weights, residuals>``.

        Generalizes the full-field rank-1 Lorenzo mean: per-axis weights
        ``w_a[i] = #{j in window_a : j >= i}`` (separable, nd) or one flat
        weight vector counting window positions at-or-after each index (1-D).
        """
        if self._weights is not None:
            return self._weights
        if self.scheme.is_nd:
            ws = []
            for (s, e), length in zip(self.region, self.sub_padded_shape):
                i = np.arange(length)
                ws.append(np.clip(e - np.maximum(i, s), 0, None).astype(np.float32))
            self._weights = tuple(ws)
        else:
            i = np.arange(self.sub_padded_shape[0])
            w = self.n_window - np.searchsorted(self.win_flat, i, side="left")
            self._weights = (w.astype(np.float32),)
        return self._weights


# ---------------------------------------------------------------------------
# plan construction / memoization
# ---------------------------------------------------------------------------

_PLAN_CACHE: "OrderedDict[Tuple, RegionPlan]" = OrderedDict()
_PLAN_CACHE_LIMIT = 256


def canonical_closure(scheme: Scheme, closure: Closure,
                      region: object | None = None) -> Closure:
    """Canonical cache/plan-key form of a closure.

    1-D layouts have no per-axis bands (``("band", a)`` degrades to the
    prefix hull — exactly what :func:`plan_region` executes), and with no
    region the closure never enters any computation, so every full-field
    materialization shares one key (``"cover"``).
    """
    if region is None:
        return "cover"
    if not Scheme(scheme).is_nd and isinstance(closure, tuple):
        return "hull"
    return closure


def plan_region(c: Compressed | Encoded, region: RegionSpec,
                closure: Closure = "cover") -> RegionPlan:
    """Plan (and memoize) a region query over ``c``'s layout."""
    norm = normalize_region(region, c.shape)
    closure = canonical_closure(c.scheme, closure, norm)
    key = (c.scheme, c.shape, c.padded_shape, c.block, norm, closure)
    plan = _PLAN_CACHE.get(key)
    if plan is not None:
        _PLAN_CACHE.move_to_end(key)
        return plan
    plan = RegionPlan(c.scheme, c.shape, c.padded_shape, c.block, norm, closure)
    _PLAN_CACHE[key] = plan
    while len(_PLAN_CACHE) > _PLAN_CACHE_LIMIT:
        _PLAN_CACHE.popitem(last=False)
    return plan


def op_closure(scheme: Scheme, op: str, stage: Stage, axis: int = 0) -> Closure:
    """Dependency closure an op needs at a stage (see module docstring)."""
    if not Scheme(scheme).is_lorenzo:
        return "cover"
    if Scheme(scheme).is_nd and Stage(stage) == Stage.P and op == "derivative":
        return ("band", axis)
    return "hull"


def extract(c: Compressed | Encoded, plan: RegionPlan) -> Compressed:
    """The gathered sub-field; from :class:`Encoded` this unpacks only the
    payload words covering the plan's blocks (:func:`repro.core.encode.decode_region`)."""
    if isinstance(c, Encoded):
        return encode.decode_region(c, plan)
    if plan.spatial_slices is not None:
        residuals = c.residuals[plan.spatial_slices]
    else:
        b = c.block[0]
        blocked = c.residuals.reshape(-1, b)
        residuals = blocked[jnp.asarray(plan.block_ids.astype(np.int32))].reshape(-1)
    return plan.assemble(residuals, c)


def region_aligned(c: Compressed | Encoded, region: RegionSpec) -> bool:
    """Is the window block-aligned (so stage-① statistics stay eps-exact)?"""
    return plan_region(c, region, "cover").aligned


def closure_fraction(c: Compressed | Encoded, op: str, stage: Stage,
                     region: RegionSpec, axis: int = 0) -> float:
    """Fraction of the field a region query must touch at ``stage``.

    Cost-model input: measured full-field microseconds scale by this factor.
    Stage ① touches metadata only, so its fraction is in blocks; other stages
    are in elements of the gathered closure.  Multivariate ops average their
    per-axis derivative closures.
    """
    stage = Stage(stage)
    if op in ("divergence", "curl"):
        nd = len(c.shape)
        fr = [closure_fraction(c, "derivative", stage, region, axis=a)
              for a in range(nd)]
        return float(np.mean(fr))
    if stage == Stage.M:
        plan = plan_region(c, region, "cover")
        n_blocks = int(np.prod(plan.grid))
        return plan.n_sub_blocks / max(n_blocks, 1)
    plan = plan_region(c, region, op_closure(c.scheme, op, stage, axis))
    return plan.gathered_elems / max(int(np.prod(c.padded_shape)), 1)
