"""Expression DAG: cross-field derived operators over compressed data.

The op-set pipeline (:func:`repro.core.oplib.compute`) lowers *one op set
over one field* onto a shared stage reconstruction.  Real derived
quantities — vorticity from (u, v), ensemble deltas, cross-stream drift —
combine the results of ops over *several* compressed fields.  This module
generalizes the op set to a small expression language:

* **Leaves** (:class:`Leaf`) name compressed inputs: a store field id, a
  raw :class:`~repro.core.Compressed`/:class:`~repro.core.Encoded`
  container, a component bundle (tuple of fields/ids, for
  ``divergence``/``curl``), or a ``repro.stream.TemporalField``.
* **Op nodes** (:class:`Op`) apply one registered
  :class:`~repro.core.oplib.OpSpec` to a leaf.  Ops apply to leaves *only*
  — they lower against the leaf's stage prelude; derived values are
  combined, not re-compressed.
* **Combinators** (:class:`Add`/:class:`Sub`/:class:`Scale`) form pointwise
  float arithmetic between op results (``a + b``, ``a - b``, ``alpha * a``
  with a static Python scalar).

:func:`analyze` validates a batch of root expressions (arity vs leaf kind,
component-count checks, duplicate ids inside a bundle, cycle detection,
temporal/spatial consumer consistency) and compiles them into an
:class:`ExprProgram`: leaves deduplicated into *slots*, a canonical
structural hash for jit-cache keys (``add`` is canonically commuted, so
``x + y`` and ``y + x`` share one compiled program — IEEE addition
commutes bitwise), and the connected components the planner assigns joint
stages to.

:func:`lower` evaluates a bound program: every leaf slot gets exactly ONE
:class:`~repro.core.oplib.StageContext` prelude shared by all consuming
ops (the DAG-level form of the fused-op-set guarantee), op nodes are
CSE'd on their canonical serialization, and combinators are pointwise
float tails — so every root is bit-identical to composing the single-op
results at the same stage.
"""
from __future__ import annotations
from collections.abc import Callable, Sequence

import hashlib
from dataclasses import dataclass
from typing import Any

from . import oplib
from . import region as R
from .stages import Compressed, Encoded, Scheme, Stage

Field = Compressed | Encoded

__all__ = [
    "Expr", "Leaf", "Op", "Add", "Sub", "Scale", "ExprProgram",
    "leaf", "op", "add", "sub", "scale", "analyze", "lower",
    "leaf_closure", "vector_closures",
    "mean", "std", "derivative", "gradient", "laplacian",
    "divergence", "curl", "tdelta", "tmean", "tmin", "tmax", "tstd",
]


# ===========================================================================
# nodes
# ===========================================================================

class Expr:
    """Base class of expression nodes.

    Nodes are immutable after construction (so a DAG, once built, cannot be
    mutated into a cycle or out of sync with its analyzed program) and
    support operator sugar: ``a + b``, ``a - b``, ``2.0 * a``, ``-a``.
    """

    __slots__ = ("_frozen",)

    def _freeze(self) -> None:
        object.__setattr__(self, "_frozen", True)

    def __setattr__(self, name, value):
        if getattr(self, "_frozen", False):
            raise AttributeError(
                "expression nodes are immutable; build a new expression "
                "instead of mutating this one")
        object.__setattr__(self, name, value)

    def __add__(self, other):
        return Add(self, other)

    def __sub__(self, other):
        return Sub(self, other)

    def __mul__(self, alpha):
        return Scale(self, alpha)

    __rmul__ = __mul__

    def __neg__(self):
        return Scale(self, -1.0)


def _source_key(src) -> tuple:
    return ("id", src) if isinstance(src, str) else ("obj", id(src))


class Leaf(Expr):
    """A compressed input: field id, container, component bundle, or stream.

    A string id is resolved against the query's store at execution time; its
    kind (spatial field vs temporal stream) is fixed by the ops consuming
    it.  A tuple/list bundles vector components for ``divergence``/``curl``
    (each component a field or id; duplicate ids are rejected — a vector
    field's components are distinct physical quantities).
    """

    __slots__ = ("source",)

    def __init__(self, source):
        if isinstance(source, (tuple, list)):
            comps = tuple(source)
            if not comps:
                raise ValueError("empty component bundle")
            for c in comps:
                if not isinstance(c, (str, Compressed, Encoded)):
                    raise TypeError(
                        f"bundle components are Compressed/Encoded fields or "
                        f"store ids; got {type(c).__name__}")
            named = [c for c in comps if isinstance(c, str)]
            if len(set(named)) != len(named):
                raise ValueError(
                    f"duplicate field ids in component bundle: "
                    f"{tuple(c if isinstance(c, str) else '<field>' for c in comps)}")
            self.source = comps
        elif isinstance(source, (str, Compressed, Encoded)):
            self.source = source
        elif hasattr(source, "layout_sig"):  # TemporalField (repro.stream)
            self.source = source
        else:
            raise TypeError(
                f"a leaf is a field id, a Compressed/Encoded field, a "
                f"component bundle, or a TemporalField; got "
                f"{type(source).__name__}")
        self._freeze()

    @property
    def kind(self) -> str:
        """``"vector"`` | ``"field"`` | ``"temporal"`` | ``"id"`` (a bare id
        — field vs stream is decided by the consuming ops)."""
        if isinstance(self.source, tuple):
            return "vector"
        if isinstance(self.source, str):
            return "id"
        if hasattr(self.source, "layout_sig"):
            return "temporal"
        return "field"

    @property
    def key(self) -> tuple:
        """Binding key: equal keys share one slot (one prelude) in a
        program.  Ids compare by name; raw containers by object identity."""
        if self.kind == "vector":
            return ("vec",) + tuple(_source_key(c) for c in self.source)
        return _source_key(self.source)


class Op(Expr):
    """One registered operation applied to a leaf.

    ``axis`` matters only for axis-bearing ops (``derivative``); it is
    normalized to 0 otherwise so structurally identical applications share
    one canonical form.
    """

    __slots__ = ("name", "operand", "axis")

    def __init__(self, name: str, operand, axis: int = 0):
        if name not in oplib._ALL_OPS:
            raise ValueError(
                f"unknown operation {name!r}; expected one of "
                f"{tuple(oplib._ALL_OPS)}")
        if not isinstance(operand, Expr):
            operand = Leaf(operand)
        if not isinstance(operand, Leaf):
            raise TypeError(
                f"{name} lowers against a compressed leaf's stage prelude; "
                "it cannot consume a derived expression — combine op results "
                "with add/sub/scale instead")
        spec = oplib._ALL_OPS[name]
        kind = operand.kind
        if spec.arity == "vector":
            if kind != "vector":
                raise TypeError(
                    f"vector op {name!r} takes a component bundle; got a "
                    f"{kind} leaf — pass a tuple of component fields/ids")
            spec.component_axes(len(operand.source))  # validates e.g. curl
        elif spec.arity == "temporal":
            if kind not in ("temporal", "id"):
                raise TypeError(
                    f"temporal op {name!r} runs over a TemporalField stream "
                    f"(or its store id); got a {kind} leaf")
        else:  # field arity
            if kind not in ("field", "id"):
                raise TypeError(
                    f"{name} takes a single Compressed/Encoded field (or its "
                    f"id); got a {kind} leaf")
        self.name = name
        self.operand = operand
        self.axis = int(axis) if spec.needs_axis else 0
        self._freeze()

    @property
    def spec(self) -> oplib.OpSpec:
        return oplib._ALL_OPS[self.name]

    @property
    def tuple_valued(self) -> bool:
        """Does this node yield a tuple of components (``gradient``, 3-D
        ``curl``)?  Tuple-valued nodes can be roots but not combinator
        operands."""
        if self.name == "gradient":
            return True
        return self.name == "curl" and len(self.operand.source) == 3


def _value_operand(node, what: str) -> Expr:
    if not isinstance(node, Expr):
        raise TypeError(
            f"{what} combines expressions; got {type(node).__name__} "
            "(apply an op to a field first)")
    if isinstance(node, Leaf):
        raise TypeError(
            f"a leaf has no value to {what}; apply an op to it first "
            "(leaves only feed ops)")
    if isinstance(node, Op) and node.tuple_valued:
        raise TypeError(
            f"{node.name} yields a tuple of components; combinators take "
            "array-valued expressions (combine per-axis derivative nodes "
            "instead)")
    return node


class Add(Expr):
    """Pointwise sum of two expression values (canonically commuted)."""

    __slots__ = ("a", "b")

    def __init__(self, a, b):
        self.a = _value_operand(a, "add")
        self.b = _value_operand(b, "add")
        self._freeze()


class Sub(Expr):
    """Pointwise difference ``a - b`` of two expression values."""

    __slots__ = ("a", "b")

    def __init__(self, a, b):
        self.a = _value_operand(a, "sub")
        self.b = _value_operand(b, "sub")
        self._freeze()


class Scale(Expr):
    """Pointwise scaling by a *static* Python scalar (part of the program's
    structural identity, not a traced input)."""

    __slots__ = ("x", "alpha")

    def __init__(self, x, alpha):
        self.x = _value_operand(x, "scale")
        if isinstance(alpha, Expr) or isinstance(alpha, bool) \
                or not isinstance(alpha, (int, float)):
            raise TypeError(
                f"scale takes a static Python scalar, got "
                f"{type(alpha).__name__}")
        self.alpha = float(alpha)
        self._freeze()


# -- builders ---------------------------------------------------------------

def leaf(source) -> Leaf:
    """Wrap a field / id / bundle / stream as a :class:`Leaf` (idempotent)."""
    return source if isinstance(source, Leaf) else Leaf(source)


def op(name: str, operand, *, axis: int = 0) -> Op:
    """Apply registered op ``name`` to a leaf (fields auto-wrap)."""
    return Op(name, operand, axis=axis)


def add(a, b) -> Add:
    return Add(a, b)


def sub(a, b) -> Sub:
    return Sub(a, b)


def scale(x, alpha) -> Scale:
    return Scale(x, alpha)


def mean(x) -> Op:
    return Op("mean", x)


def std(x) -> Op:
    return Op("std", x)


def derivative(x, axis: int = 0) -> Op:
    return Op("derivative", x, axis=axis)


def gradient(x) -> Op:
    return Op("gradient", x)


def laplacian(x) -> Op:
    return Op("laplacian", x)


def divergence(components) -> Op:
    return Op("divergence", components)


def curl(components) -> Op:
    return Op("curl", components)


def tdelta(x) -> Op:
    return Op("tdelta", x)


def tmean(x) -> Op:
    return Op("tmean", x)


def tmin(x) -> Op:
    return Op("tmin", x)


def tmax(x) -> Op:
    return Op("tmax", x)


def tstd(x) -> Op:
    return Op("tstd", x)


# ===========================================================================
# traversal / canonicalization
# ===========================================================================

def _children(node: Expr) -> tuple[Expr, ...]:
    if isinstance(node, Op):
        return (node.operand,)
    if isinstance(node, (Add, Sub)):
        return (node.a, node.b)
    if isinstance(node, Scale):
        return (node.x,)
    return ()


def _postorder(roots: Sequence[Expr],
               child_order: Callable | None = None) -> list[Expr]:
    """Iterative post-order over the DAG (each node once), with cycle
    detection.  Nodes are immutable, so a cycle cannot normally be built —
    the check guards against ``object.__setattr__`` surgery and keeps the
    failure mode a clear error instead of an infinite trace."""
    order = child_order or _children
    state: dict[int, int] = {}  # id -> 0 visiting, 1 done
    out: list[Expr] = []
    stack: list[tuple[Expr, bool]] = [(r, False) for r in reversed(roots)]
    while stack:
        node, processed = stack.pop()
        st = state.get(id(node))
        if processed:
            state[id(node)] = 1
            out.append(node)
            continue
        if st == 1:
            continue
        if st == 0:
            raise ValueError("expression DAG contains a cycle")
        state[id(node)] = 0
        stack.append((node, True))
        for ch in reversed(order(node)):
            cst = state.get(id(ch))
            if cst == 0:
                raise ValueError("expression DAG contains a cycle")
            if cst != 1:
                stack.append((ch, False))
    return out


def _content_sigs(roots: Sequence[Expr]) -> dict[int, tuple]:
    """Binding-aware structural signature per node — used only to pick the
    canonical ``add`` child order, so ``x + y`` and ``y + x`` canonicalize
    to one slot assignment (and hence one structural hash)."""
    sigs: dict[int, tuple] = {}
    for node in _postorder(roots):
        if id(node) in sigs:
            continue
        if isinstance(node, Leaf):
            s: tuple = ("L",) + node.key
        elif isinstance(node, Op):
            s = ("O", node.name, node.axis, sigs[id(node.operand)])
        elif isinstance(node, Add):
            a, b = sigs[id(node.a)], sigs[id(node.b)]
            s = ("A",) + tuple(sorted((a, b), key=repr))
        elif isinstance(node, Sub):
            s = ("S", sigs[id(node.a)], sigs[id(node.b)])
        else:
            s = ("C", node.alpha, sigs[id(node.x)])
        sigs[id(node)] = s
    return sigs


@dataclass(frozen=True)
class ExprProgram:
    """One analyzed batch of root expressions, ready to plan and lower.

    ``leaves`` are the deduplicated input slots (equal :attr:`Leaf.key` →
    one slot → one prelude); ``key`` is the canonical structural hash (leaf
    identities abstracted to slot indices) that keys compiled programs
    together with the per-slot layout signatures.  ``leaf_component`` /
    ``root_component`` partition the DAG into connected components — the
    planner's joint-stage unit: leaves joined by a combinator must share a
    stage-compatible plan, while independent roots plan independently.
    """

    roots: tuple[Expr, ...]
    leaves: tuple[Leaf, ...]
    leaf_keys: tuple[tuple, ...]
    key: str
    serials: dict[int, str]            # id(node) -> canonical serialization
    op_nodes: tuple[Op, ...]           # unique op nodes, canonical order
    op_slots: tuple[int, ...]          # operand slot per op node
    leaf_component: tuple[int, ...]
    root_component: tuple[int, ...]
    n_components: int

    def slot_of(self, lf: Leaf) -> int:
        return self.leaf_keys.index(lf.key)

    def serial(self, node: Expr) -> str:
        return self.serials[id(node)]

    def component_ops(self, comp: int) -> tuple[tuple[str, int, int], ...]:
        """Unique ``(op name, axis, leaf slot)`` applications inside one
        connected component — the planner's feasibility/cost unit."""
        return tuple((n.name, n.axis, s)
                     for n, s in zip(self.op_nodes, self.op_slots)
                     if self.leaf_component[s] == comp)

    def leaf_consumers(self, slot: int) -> tuple[tuple[str, int], ...]:
        """Unique ``(op name, axis)`` pairs consuming one leaf slot — the
        closure-join input."""
        return tuple((n.name, n.axis)
                     for n, s in zip(self.op_nodes, self.op_slots)
                     if s == slot)

    @property
    def temporal_nodes(self) -> tuple[Op, ...]:
        return tuple(n for n in self.op_nodes if n.spec.arity == "temporal")

    def leaf_is_temporal(self, slot: int) -> bool:
        return any(oplib._ALL_OPS[n].arity == "temporal"
                   for n, _ in self.leaf_consumers(slot))


def analyze(roots: Sequence[Expr]) -> ExprProgram:
    """Validate root expressions and build their canonical program.

    Raises on: non-expression / bare-leaf roots, cycles, a leaf consumed by
    both temporal and spatial ops (a stream cannot also be a field), and
    any constructor-level violation latent in the DAG.
    """
    roots = tuple(roots)
    if not roots:
        raise ValueError("empty expression batch")
    for r in roots:
        if not isinstance(r, Expr):
            raise TypeError(
                f"expressions are Expr nodes; got {type(r).__name__}")
        if isinstance(r, Leaf):
            raise TypeError(
                "a bare leaf is not a query — apply an op to it "
                "(e.g. expr.mean(leaf))")
    sigs = _content_sigs(roots)  # also the cycle check

    def canonical_children(node: Expr) -> tuple[Expr, ...]:
        if isinstance(node, Add):
            return tuple(sorted((node.a, node.b),
                                key=lambda n: repr(sigs[id(n)])))
        return _children(node)

    order = _postorder(roots, canonical_children)

    slot_by_key: dict[tuple, int] = {}
    leaves: list[Leaf] = []
    serials: dict[int, str] = {}
    op_nodes: list[Op] = []
    op_slots: list[int] = []
    seen_ops: dict[str, int] = {}
    for node in order:
        if isinstance(node, Leaf):
            k = node.key
            if k not in slot_by_key:
                slot_by_key[k] = len(leaves)
                leaves.append(node)
            serials[id(node)] = f"L{slot_by_key[k]}"
        elif isinstance(node, Op):
            s = f"{node.name}[{node.axis}]({serials[id(node.operand)]})"
            serials[id(node)] = s
            if s not in seen_ops:  # CSE: one postlude per distinct application
                seen_ops[s] = len(op_nodes)
                op_nodes.append(node)
                op_slots.append(slot_by_key[node.operand.key])
        elif isinstance(node, (Add, Sub)):
            ca, cb = canonical_children(node)
            tag = "add" if isinstance(node, Add) else "sub"
            if isinstance(node, Sub):
                ca, cb = node.a, node.b  # sub does not commute
            serials[id(node)] = f"{tag}({serials[id(ca)]},{serials[id(cb)]})"
        else:
            serials[id(node)] = f"scale({node.alpha!r},{serials[id(node.x)]})"

    # a slot consumed by both temporal and spatial ops can never be bound
    for slot in range(len(leaves)):
        arities = {oplib._ALL_OPS[n].arity
                   for n, s in zip((n.name for n in op_nodes), op_slots)
                   if s == slot}
        if "temporal" in arities and len(arities) > 1:
            raise TypeError(
                f"leaf {leaves[slot].key} is consumed by both temporal and "
                "spatial ops; a TemporalField stream answers temporal ops "
                "only (register the concatenated field separately for "
                "spatial analytics)")

    # connected components over leaf slots: every root unions its slots
    parent = list(range(len(leaves)))

    def find(i: int) -> int:
        while parent[i] != i:
            parent[i] = parent[parent[i]]
            i = parent[i]
        return i

    root_slots: list[list[int]] = []
    for r in roots:
        slots = sorted({slot_by_key[n.key] for n in _postorder([r])
                        if isinstance(n, Leaf)})
        root_slots.append(slots)
        for s in slots[1:]:
            parent[find(slots[0])] = find(s)

    comp_ids: dict[int, int] = {}
    leaf_component = []
    for slot in range(len(leaves)):
        rep = find(slot)
        if rep not in comp_ids:
            comp_ids[rep] = len(comp_ids)
        leaf_component.append(comp_ids[rep])
    root_component = tuple(leaf_component[slots[0]] for slots in root_slots)

    digest = hashlib.sha256(
        ";".join(serials[id(r)] for r in roots).encode()).hexdigest()[:16]
    return ExprProgram(
        roots=roots, leaves=tuple(leaves),
        leaf_keys=tuple(lf.key for lf in leaves), key=digest,
        serials=serials, op_nodes=tuple(op_nodes), op_slots=tuple(op_slots),
        leaf_component=tuple(leaf_component), root_component=root_component,
        n_components=len(comp_ids))


# ===========================================================================
# closures (region dependency joins across all consumers of a leaf)
# ===========================================================================

def leaf_closure(program: ExprProgram, slot: int, scheme: Scheme,
                 stage: Stage) -> R.Closure:
    """Joined region closure over every (field-arity) consumer of a leaf —
    the one gather the slot's shared prelude reconstructs, hence the
    materialization key a store seed must match."""
    cons = program.leaf_consumers(slot)
    return oplib.join_closures(
        [oplib.OPS[n].closure(Scheme(scheme), Stage(stage), ax)
         for n, ax in cons])


def vector_closures(program: ExprProgram, slot: int,
                    schemes: Sequence[Scheme],
                    stage: Stage) -> tuple[R.Closure, ...]:
    """Per-component joined closures of a bundle leaf across every vector
    op consuming it (mirrors :func:`repro.core.oplib.component_closures`,
    but joined over the *expression's* consumer set)."""
    stage = Stage(stage)
    axes_per_comp = [set() for _ in schemes]
    for name, _ in program.leaf_consumers(slot):
        for i, axes in enumerate(
                oplib.OPS[name].component_axes(len(schemes))):
            axes_per_comp[i].update(axes)
    return tuple(
        oplib.join_closures([R.op_closure(Scheme(s), "derivative", stage, a)
                             for a in sorted(axes)])
        for s, axes in zip(schemes, axes_per_comp))


# ===========================================================================
# bound validation (shape compatibility) and evaluation
# ===========================================================================

def _window_shape(shape: tuple[int, ...], region) -> tuple[int, ...]:
    if region is None:
        return tuple(shape)
    norm = R.normalize_region(region, shape)
    return tuple(e - s for s, e in norm)


def validate_bound(program: ExprProgram, bindings: Sequence,
                   region=None) -> None:
    """Host-side layout check of a *bound* program: combinator operands must
    agree in result shape (statistics are scalars and broadcast; stencil and
    temporal results must match elementwise).  Catches e.g. vorticity from
    differently-shaped u and v before any device work."""
    shapes: dict[str, tuple[int, ...] | None] = {}

    def op_shape(node: Op) -> tuple[int, ...] | None:
        slot = program.slot_of(node.operand)
        b = bindings[slot]
        if node.spec.category == "statistic":
            return None  # scalar: broadcasts against anything
        if node.spec.arity == "temporal":
            return _window_shape(tuple(b.shape), region)
        base = b[0] if isinstance(b, tuple) else b
        w = _window_shape(tuple(base.shape), region)
        return tuple(n - 2 for n in w)  # stencils crop the interior

    for node in _postorder(program.roots):
        s = program.serial(node)
        if s in shapes:
            continue
        if isinstance(node, Leaf):
            shapes[s] = None
        elif isinstance(node, Op):
            shapes[s] = op_shape(node)
        elif isinstance(node, (Add, Sub)):
            sa = shapes[program.serial(node.a)]
            sb = shapes[program.serial(node.b)]
            if sa is not None and sb is not None and sa != sb:
                raise ValueError(
                    f"cannot combine results of shapes {sa} and {sb}; "
                    "combinator operands must agree elementwise "
                    "(statistics broadcast)")
            shapes[s] = sa if sa is not None else sb
        else:
            shapes[s] = shapes[program.serial(node.x)]


def lower(program: ExprProgram, bindings: Sequence,
          stages: Sequence[Stage], *, region=None,
          seeds: Sequence | None = None,
          precomputed: dict[str, Any] | None = None) -> tuple:
    """Evaluate a bound program: one shared prelude per leaf slot.

    ``bindings[slot]`` is the resolved field (or component tuple) for each
    leaf slot — ``None`` for temporal slots, whose op values arrive through
    ``precomputed`` (canonical serialization -> array), computed outside
    the spatial trace by the engine/store machinery.  ``stages[comp]`` is
    the joint stage of each connected component; ``seeds[slot]`` optionally
    supplies the slot's resident ``MaterializedStage`` (a tuple for bundle
    slots).  Returns one value per root, each bit-identical to composing
    the corresponding single-op results at the same stage.
    """
    seeds = list(seeds) if seeds is not None else [None] * len(bindings)
    precomputed = precomputed or {}
    ctxs: dict[int, Any] = {}

    def ctx_for(slot: int):
        if slot not in ctxs:
            lf = program.leaves[slot]
            b = bindings[slot]
            if b is None:
                raise ValueError(f"leaf slot {slot} ({lf.key}) is unbound")
            stage = Stage(stages[program.leaf_component[slot]])
            if isinstance(b, tuple):
                schemes = [c.scheme for c in b]
                cls = vector_closures(program, slot, schemes, stage)
                sd = seeds[slot] if seeds[slot] is not None else (None,) * len(b)
                ctxs[slot] = tuple(
                    oplib.StageContext(c, stage, region, cl, seed=s)
                    for c, cl, s in zip(b, cls, sd))
            else:
                cl = leaf_closure(program, slot, b.scheme, stage)
                ctxs[slot] = oplib.StageContext(b, stage, region, cl,
                                                seed=seeds[slot])
        return ctxs[slot]

    def eval_op(node: Op):
        spec = node.spec
        slot = program.slot_of(node.operand)
        stage = Stage(stages[program.leaf_component[slot]])
        if spec.arity == "temporal":
            s = program.serial(node)
            if s not in precomputed:
                raise ValueError(
                    f"temporal node {s} has no precomputed value; temporal "
                    "op results are summarized outside the spatial program "
                    "(see repro.analytics.query / oplib.compute_exprs)")
            return precomputed[s]
        if spec.arity == "vector":
            cs = ctx_for(slot)
            for c in cs:
                oplib._check_feasible(spec, c.scheme, stage)
            return spec.lower_vector(cs, node.axis)
        ctx = ctx_for(slot)
        oplib._check_feasible(spec, ctx.scheme, stage)
        family = "lorenzo" if ctx.scheme.is_lorenzo else "blockmean"
        rule = spec.lower.get((stage, family)) or spec.lower[(stage, "any")]
        return rule(ctx, node.axis)

    memo: dict[str, Any] = dict(precomputed)
    for node in _postorder(program.roots):
        s = program.serial(node)
        if s in memo or isinstance(node, Leaf):
            continue
        if isinstance(node, Op):
            memo[s] = eval_op(node)
        elif isinstance(node, Add):
            memo[s] = memo[program.serial(node.a)] + memo[program.serial(node.b)]
        elif isinstance(node, Sub):
            memo[s] = memo[program.serial(node.a)] - memo[program.serial(node.b)]
        else:
            memo[s] = memo[program.serial(node.x)] * node.alpha
    return tuple(memo[program.serial(r)] for r in program.roots)
