"""Linear-scaling quantization (paper §IV, "Quantization").

``q_i = round(d_i / (2 eps))`` with round-half-even; decompression recovers
``d'_i = 2 q_i eps`` which guarantees ``|d_i - d'_i| <= eps``.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def resolve_eps(data: jax.Array, *, abs_eb: float | None = None, rel_eb: float | None = None) -> jax.Array:
    """Resolve the absolute error bound.

    ``rel_eb`` follows the paper's value-range-based relative bound:
    ``eps = rel_eb * (max(d) - min(d))``.  Exactly one of ``abs_eb``/``rel_eb``
    must be provided.
    """
    if (abs_eb is None) == (rel_eb is None):
        raise ValueError("provide exactly one of abs_eb / rel_eb")
    if abs_eb is not None:
        return jnp.asarray(abs_eb, jnp.float32)
    value_range = (jnp.max(data) - jnp.min(data)).astype(jnp.float32)
    # Degenerate constant fields quantize to all-zero integers with any eps>0.
    return jnp.where(value_range > 0, value_range * rel_eb, jnp.float32(1.0))


def quantize(data: jax.Array, eps: jax.Array) -> jax.Array:
    """Map floating-point data to int32 quantization indices.

    Uses ``round(d * inv)`` with ``inv = 1/(2 eps)`` — the exact expression is
    part of the format contract: every implementation (core, Pallas kernels,
    collectives) must use the same one, or ulp-level tie-breaking diverges.
    """
    inv = 1.0 / (2.0 * eps)
    q = jnp.round(data.astype(jnp.float32) * inv)
    return q.astype(jnp.int32)


def dequantize(q: jax.Array, eps: jax.Array, dtype=jnp.float32) -> jax.Array:
    """Recover floating-point values: ``d' = 2 q eps``."""
    return (q.astype(jnp.float32) * (2.0 * eps)).astype(dtype)
