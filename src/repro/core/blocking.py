"""n-D data partition / unpartition (paper §IV "Data Partition").

Data is padded (edge mode keeps residual entropy low) to block multiples and
viewed either *spatially* (padded n-D layout — natural for stencils) or
*blocked* ``(grid..., block...)`` (natural for per-block metadata/encoding).
Both views are cheap reshape/transpose; XLA fuses them away.
"""
from __future__ import annotations
from collections.abc import Sequence


import numpy as np
import jax
import jax.numpy as jnp


def padded_shape(shape: Sequence[int], block: Sequence[int]) -> tuple[int, ...]:
    return tuple(-(-s // b) * b for s, b in zip(shape, block))


def has_padding(shape: Sequence[int], block: Sequence[int]) -> bool:
    """Static predicate: does blocking ``shape`` introduce padding?

    Decided from shapes alone so callers can skip building ``valid_mask``
    (and its host-side ``.all()`` reduction) inside traced code.
    """
    return any(s % b for s, b in zip(shape, block))


def pad_to_blocks(x: jax.Array, block: Sequence[int]) -> jax.Array:
    """Pad with edge values to block multiples (edge padding keeps |residual| small)."""
    tgt = padded_shape(x.shape, block)
    pads = [(0, t - s) for s, t in zip(x.shape, tgt)]
    if all(p == (0, 0) for p in pads):
        return x
    return jnp.pad(x, pads, mode="edge")


def crop(x: jax.Array, shape: Sequence[int]) -> jax.Array:
    """Inverse of :func:`pad_to_blocks`."""
    slices = tuple(slice(0, s) for s in shape)
    return x[slices]


def to_blocked(x: jax.Array, block: Sequence[int]) -> jax.Array:
    """Spatial padded layout -> ``(g0, ..., gk, b0, ..., bk)``."""
    nd = x.ndim
    grid = tuple(s // b for s, b in zip(x.shape, block))
    # interleave: (g0, b0, g1, b1, ...)
    inter = []
    for g, b in zip(grid, block):
        inter += [g, b]
    x = x.reshape(inter)
    perm = list(range(0, 2 * nd, 2)) + list(range(1, 2 * nd, 2))
    return x.transpose(perm)


def from_blocked(x: jax.Array, block: Sequence[int]) -> jax.Array:
    """Inverse of :func:`to_blocked`."""
    nd = len(block)
    grid = x.shape[:nd]
    perm = []
    for i in range(nd):
        perm += [i, nd + i]
    x = x.transpose(perm)
    return x.reshape(tuple(g * b for g, b in zip(grid, block)))


def block_grid(shape: Sequence[int], block: Sequence[int]) -> tuple[int, ...]:
    return tuple(p // b for p, b in zip(padded_shape(shape, block), block))


def valid_counts(shape: Sequence[int], block: Sequence[int]) -> np.ndarray:
    """Number of *valid* (non-padding) elements per block, row-major grid order.

    Computed host-side (shapes are static) and attached to the container so
    padding-aware homomorphic statistics stay exact.
    """
    grid = block_grid(shape, block)
    per_axis = []
    for s, b, g in zip(shape, block, grid):
        idx = np.arange(g)
        full = np.minimum((idx + 1) * b, s) - idx * b
        per_axis.append(np.maximum(full, 0))
    counts = per_axis[0]
    for a in per_axis[1:]:
        counts = np.multiply.outer(counts, a)
    return counts.reshape(-1).astype(np.int32)


def valid_mask(shape: Sequence[int], block: Sequence[int]) -> np.ndarray:
    """Boolean spatial mask of valid elements in the padded layout."""
    pshape = padded_shape(shape, block)
    mask = np.ones(pshape, dtype=bool)
    for axis, (s, p) in enumerate(zip(shape, pshape)):
        if p > s:
            idx = [slice(None)] * len(pshape)
            idx[axis] = slice(s, p)
            mask[tuple(idx)] = False
    return mask


def upsample_block_means(means: jax.Array, block: Sequence[int]) -> jax.Array:
    """Broadcast per-block values back to the spatial padded layout.

    ``means`` has grid shape ``(g0, ..., gk)``; result has shape
    ``(g0*b0, ..., gk*bk)``.  Used by HSZx-family recorrelation and the
    homomorphic border-correction stencils (paper §V-B②).
    """
    nd = means.ndim
    x = means
    for axis in range(nd):
        x = jnp.repeat(x, block[axis], axis=axis)
    return x
