"""HSZ core: error-controlled compression with multi-stage decompression and
homomorphic analytical operations (the paper's contribution, in JAX)."""

from .stages import (Compressed, Encoded, Scheme, Stage, batch_size,
                     batch_stack, batch_unstack, layout_key)
from .pipeline import (
    HSZCompressor,
    UnsupportedStageError,
    by_name,
    hszp,
    hszp_nd,
    hszx,
    hszx_nd,
)
from . import (blocking, decorrelate, encode, error_analysis, expr,
               homomorphic, oplib, quantize, region)
from .region import RegionPlan, normalize_region

__all__ = [
    "Compressed", "Encoded", "Scheme", "Stage",
    "batch_stack", "batch_unstack", "batch_size", "layout_key",
    "HSZCompressor", "UnsupportedStageError", "by_name",
    "hszp", "hszp_nd", "hszx", "hszx_nd",
    "RegionPlan", "normalize_region",
    "blocking", "decorrelate", "encode", "error_analysis", "expr",
    "homomorphic", "oplib", "quantize", "region",
]
