"""Operator-lowering core: one stage reconstruction, many homomorphic results.

The paper's premise is that *decompression dominates analytics cost*; its six
operations differ only in the small postlude applied to a shared intermediate
representation.  This module makes that structure explicit:

* :class:`OpSpec` — a declarative description of one analytical operation:
  name, arity (single field vs vector of components), per-scheme feasible
  stages (paper Table I), the region dependency-closure kind, and one
  lowering rule per ``(stage, scheme family)`` cell.
* :class:`StageContext` — the *prelude* of a lowering: everything the ops
  share for a given ``(field, stage, region)`` — payload decode, cumsum /
  block-mean-upsample recorrelation, window cropping, statistic weights —
  computed lazily and **at most once**, so an arbitrary op set reuses a
  single stage reconstruction.
* :func:`compute` — the lowering pipeline: validates the op set, joins the
  per-op region closures into one gathered sub-field, builds the context(s),
  and runs every op's postlude against them, returning ``{op: result}``.

``repro.core.homomorphic`` keeps the public single-op API as thin wrappers
(``mean(c, stage) == compute(c, ("mean",), stage)["mean"]``); the batched
analytics engine compiles ``compute`` directly so a fused
``query(fields, ops=["mean", "std", "laplacian"])`` costs one decode pass.

The full-field path is the region path with ``region=None``: every lowering
rule consumes the context's windowing helpers, which degrade to crop/mask
operations when no region is given.  Fused and single-op results are
bit-identical at a given stage because both run the same rule against
contexts that differ at most in their (integer-exact) gather closure.

A second registry, :data:`TEMPORAL_OPS`, covers streaming time-slab
analytics (``repro.stream``, DESIGN.md §9): reductions over the time axis
of an appended stream (``tdelta``, running ``tmean``/``tmin``/``tmax``/
``tstd``), lowered as postludes on an integer-exact
:class:`TemporalSummary` built per slab (:func:`summarize_slab`) and
merged homomorphically (:func:`merge_summaries`).
"""
from __future__ import annotations
from collections.abc import Callable, Mapping, Sequence

from dataclasses import dataclass, field as dc_field
from functools import cached_property, partial

import numpy as np
import jax
import jax.numpy as jnp

from repro.kernels import ops as kernel_ops

from . import blocking, quantize
from . import encode as encode_mod
from . import fused as fused_mod
from . import region as R
from .pipeline import HSZCompressor, UnsupportedStageError, by_name
from .stages import (Compressed, Encoded, Scheme, Stage, _dataclass_pytree)

Field = Compressed | Encoded


# ===========================================================================
# closure lattice
# ===========================================================================

def join_closures(closures: Sequence[R.Closure]) -> R.Closure:
    """Smallest closure containing every op's dependency closure.

    ``cover`` only ever joins with itself (block-mean family); Lorenzo
    closures are bands/hulls, and any two distinct ones join to the
    origin-anchored prefix hull (band ∪ band' ⊆ hull and hull absorbs all).
    """
    uniq = set(closures)
    if not uniq:
        raise ValueError("empty closure set")
    if len(uniq) == 1:
        return next(iter(uniq))
    if "cover" in uniq:
        # mixed families can't happen (closures are per-scheme); be safe
        raise ValueError(f"cannot join closures {sorted(map(str, uniq))}")
    return "hull"


def set_closure(ops: str | Sequence[str], scheme: Scheme, stage: Stage,
                axis: int = 0) -> R.Closure:
    """Joined region dependency closure of a *field-arity* op set — the
    closure :func:`compute` reconstructs, hence the materialization key a
    store must match to seed the set's prelude."""
    names = canonical_ops(ops)
    if is_vector_ops(names):
        raise ValueError(
            f"vector op set {names} has per-component closures; "
            "use component_closures()")
    if is_temporal_ops(names):
        raise ValueError(
            f"temporal op set {names} closes over slabs, not a spatial "
            "gather; see repro.stream")
    return join_closures(
        [OPS[n].closure(Scheme(scheme), Stage(stage), axis) for n in names])


def component_closures(ops: str | Sequence[str],
                       schemes: Sequence[Scheme],
                       stage: Stage) -> tuple[R.Closure, ...]:
    """Per-component joined closures of a *vector-arity* op set: each
    component's closure joins the derivative bands of every axis any op in
    the set differentiates it along."""
    names = canonical_ops(ops)
    if not is_vector_ops(names):
        raise ValueError(f"field op set {names} has one closure; "
                         "use set_closure()")
    stage = Stage(stage)
    axes_per_comp = [set() for _ in schemes]
    for name in names:
        for i, axes in enumerate(OPS[name].component_axes(len(schemes))):
            axes_per_comp[i].update(axes)
    return tuple(
        join_closures([_deriv_closure(Scheme(s), stage, a)
                       for a in sorted(axes)])
        for s, axes in zip(schemes, axes_per_comp))


# ===========================================================================
# the shared prelude
# ===========================================================================

class StageContext:
    """One stage reconstruction for a ``(field, stage, region, closure)``.

    Every intermediate is a cached property, so any number of op postludes
    share one decode / recorrelation / window-crop pass.  All host-side
    geometry (plans, weights) is static; the jnp work composes with
    ``jit``/``vmap`` exactly like the single-op paths always have.

    ``seed`` is an optional materialized intermediate (duck-typed as
    ``repro.store.MaterializedStage``: ``stage`` / ``closure`` / ``region``
    meta plus ``sub`` / ``q_spatial`` / ``f_spatial`` arrays).  A seed whose
    key matches this context replaces the corresponding reconstruction —
    the arrays it holds were produced by this very prelude, so every
    downstream postlude is bit-identical to the unseeded path; a mismatched
    key raises (the store guarantees matches by construction).
    """

    def __init__(self, c: Field, stage: Stage, region, closure: R.Closure,
                 seed=None, words=None):
        self.field = c
        self.stage = Stage(stage)
        self.region = region
        self.closure = closure
        self._axis_diffs: dict[int, jax.Array] = {}
        if words is not None and (region is None or not isinstance(c, Encoded)):
            raise ValueError(
                "words= supplies the region plan's gathered payload words; "
                "it requires an Encoded field and a region")
        self._words = words
        if seed is not None:
            norm = (R.normalize_region(region, c.shape)
                    if region is not None else None)
            want = R.canonical_closure(c.scheme, closure, norm)
            got = (Stage(seed.stage), seed.closure, seed.region)
            # the seed itself owns the stage-serving rule (e.g. stage-③
            # integers serve stage ④: dequantize is a postlude multiply, so
            # the float tail stays in-program and seeded == unseeded stays
            # bit-identical) — one authoritative copy, duck-typed so core
            # never depends on the store package
            if not seed.serves(self.stage) or got[1:] != (want, norm):
                raise ValueError(
                    f"materialized seed {got} does not match context "
                    f"({self.stage}, {want}, {norm})")
        self._seed = seed

    # -- static layout ------------------------------------------------------
    @property
    def scheme(self) -> Scheme:
        return self.field.scheme

    @property
    def eps(self) -> jax.Array:
        return self.field.eps

    @cached_property
    def plan(self) -> R.RegionPlan | None:
        if self.region is None:
            return None
        return R.plan_region(self.field, self.region, self.closure)

    @property
    def n(self) -> int:
        """Valid element count of the queried extent (window or field)."""
        return self.plan.n_window if self.plan is not None else self.field.n

    @cached_property
    def compressor(self) -> HSZCompressor:
        return by_name(self.scheme.value, self.field.block)

    # -- decode (once) ------------------------------------------------------
    @cached_property
    def sub(self) -> Compressed:
        """The honest sub-field the ops run on: the gathered region closure,
        or the (decoded) full field.  From :class:`Encoded` the region path
        unpacks only the plan's payload words.  A stage-② seed skips the
        decode entirely."""
        if self._seed is not None and self._seed.sub is not None:
            return self._seed.sub
        if self.plan is not None:
            if self._words is not None:
                # pre-gathered words (the sharded store's scatter/psum word
                # merge): same unpack -> unzigzag -> assemble sequence as
                # encode.decode_region, so the result is bit-identical to
                # gathering from the resident single-device payload
                e = self.field
                gi = self.plan.payload_gather(e.bits)
                u = encode_mod.unpack_gather(
                    self._words, word_idx=None, pos0=gi.pos0, pos1=gi.pos1,
                    shift=gi.shift, bits=e.bits)
                residuals = encode_mod.unzigzag(u).reshape(
                    self.plan.sub_padded_shape)
                return self.plan.assemble(residuals, e)
            return R.extract(self.field, self.plan)
        c = self.field
        return encode_mod.decode_device(c) if isinstance(c, Encoded) else c

    # -- per-block metadata views (no payload decode) -----------------------
    @cached_property
    def metadata_blocks(self) -> jax.Array:
        """Metadata restricted to the gathered blocks, without touching the
        payload — the stage-① path must never decode."""
        if self.plan is not None:
            return self.plan.gather_metadata(self.field)
        return self.field.metadata

    @cached_property
    def block_overlap(self) -> jax.Array:
        """Per-gathered-block element counts inside the queried extent:
        window-overlap counts (region) or the field's valid counts (full)."""
        if self.plan is not None:
            return jnp.asarray(self.plan.overlap)
        return self.field.valid_counts

    # -- windowing / masking helpers ----------------------------------------
    @cached_property
    def valid_weight(self) -> jax.Array | None:
        """Full-field only: spatial 0/1 mask of valid elements, or None when
        there is no padding (static decision — no mask inside traced code
        unless padding actually exists)."""
        c = self.sub
        shape = c.shape if c.scheme.is_nd else (c.n,)
        if not blocking.has_padding(shape, c.block):
            return None
        return jnp.asarray(blocking.valid_mask(shape, c.block), jnp.int32)

    def masked_sum(self, arr: jax.Array) -> jax.Array:
        """Exact (integer) sum over the queried extent: window gather
        (region) or padding-masked full array.  Reduces *flat* — multi-axis
        reduces compile to context-dependent strategies, and store-seeded
        programs must agree with cold ones bit for bit."""
        if self.plan is not None:
            return jnp.sum(self.plan.window_of(arr).reshape(-1))
        w = self.valid_weight
        return jnp.sum((arr if w is None else arr * w).reshape(-1))

    def stat_values(self, arr: jax.Array) -> jax.Array:
        """Flat f32 values a statistic reduces over: the window (region) or
        the full array with padding zeroed (full field).  Flat for the same
        seeded-vs-cold bit-identity reason as :meth:`masked_sum`."""
        if self.plan is not None:
            return self.plan.window_of(arr).astype(jnp.float32).reshape(-1)
        x = arr.astype(jnp.float32)
        w = self.valid_weight
        return (x if w is None else x * w).reshape(-1)

    def spatial_window(self, arr: jax.Array) -> jax.Array:
        """Crop a sub-field spatial array to the stencil window: the region
        window, or the original shape (padding removed) for the full field."""
        if self.plan is not None:
            return self.plan.window_of(arr)
        return blocking.crop(arr, self.sub.shape)

    # -- recorrelation intermediates (the expensive, shared part) -----------
    def lorenzo_axis_diff(self, axis: int) -> jax.Array:
        """D_a = q - shift_a(q) from residuals: cumsum over all axes != a."""
        d = self._axis_diffs.get(axis)
        if d is None:
            d = self.sub.residuals
            for a in range(d.ndim):
                if a != axis:
                    d = jnp.cumsum(d, axis=a)
            self._axis_diffs[axis] = d
        return d

    @cached_property
    def lorenzo_q(self) -> jax.Array:
        """Stage-③ integers of a Lorenzo sub-field (padded layout).  Derived
        from the axis-0 difference so a fused {derivative, std} set shares
        the non-axis cumsum passes (integer-exact in any axis order)."""
        return jnp.cumsum(self.lorenzo_axis_diff(0), axis=0)

    @cached_property
    def upsampled_means(self) -> jax.Array:
        """Block means upsampled to the spatial layout (block-mean family)."""
        return blocking.upsample_block_means(self.sub.metadata, self.sub.block)

    @cached_property
    def q_spatial(self) -> jax.Array:
        """Stage-③ integers cropped/windowed to the queried extent — the one
        recorrelation pass every stage-③ postlude consumes (skipped when a
        stage-③ seed holds it resident)."""
        if self._seed is not None and self._seed.q_spatial is not None:
            return self._seed.q_spatial
        q = self.compressor.decompress(self.sub, Stage.Q,
                                       crop=self.plan is None)
        if self.plan is not None:
            return self.plan.window_of(q)
        return q

    @cached_property
    def f_spatial(self) -> jax.Array:
        """Stage-④ floats on the queried extent (dequantize commutes with
        the crop, so this shares :attr:`q_spatial`).

        Derived from :attr:`q_spatial` even when seeded: materializations
        stop at the last integer-exact intermediate, so seeded and cold
        programs share this entire float tail — which is what keeps
        store-backed stage-④ results bit-identical to storeless ones under
        XLA's float reassociation."""
        return quantize.dequantize(self.q_spatial, self.eps,
                                   self.field.orig_dtype)

    @cached_property
    def lorenzo_mean_weights(self) -> tuple[np.ndarray, ...]:
        """Window-sum weights: ``sum_{i in extent} q_i = <weights, residuals>``
        — per-axis separable (nd) or one flat vector (1-D schemes)."""
        if self.plan is not None:
            return self.plan.lorenzo_mean_weights()
        c = self.sub
        dims = c.shape if c.scheme.is_nd else (c.n,)
        return tuple(
            np.clip(nvalid - np.arange(npad), 0, None).astype(np.float32)
            for npad, nvalid in zip(c.padded_shape, dims))


# ===========================================================================
# stencil kernels (shared by every lowering path)
# ===========================================================================

def _interior(x: jax.Array) -> jax.Array:
    """Crop one element at each end of every axis (common stencil interior)."""
    return x[tuple(slice(1, -1) for _ in range(x.ndim))]


def _shift_pair(x: jax.Array, axis: int) -> tuple[jax.Array, jax.Array]:
    """(x_{+1}, x_{-1}) views cropped to the common interior."""
    nd = x.ndim
    idx_p = [slice(1, -1)] * nd
    idx_m = [slice(1, -1)] * nd
    idx_p[axis] = slice(2, None)
    idx_m[axis] = slice(None, -2)
    return x[tuple(idx_p)], x[tuple(idx_m)]


def _central_diff(x: jax.Array, axis: int, scale) -> jax.Array:
    """(x_{+1} - x_{-1}) * scale on the common interior (V-B.2)."""
    hi, lo = _shift_pair(x, axis)
    return (hi - lo).astype(jnp.float32) * scale


def _lorenzo_deriv_stencil(d: jax.Array, axis: int) -> jax.Array:
    """q_{+1} - q_{-1} = D_a[i+1] + D_a[i] on the interior (V-B.1), with
    ``d`` the (windowed) Lorenzo axis difference."""
    sl_hi = [slice(1, -1)] * d.ndim
    sl_hi[axis] = slice(2, None)
    sl_lo = [slice(1, -1)] * d.ndim
    sl_lo[axis] = slice(1, -1)
    return (d[tuple(sl_hi)] + d[tuple(sl_lo)]).astype(jnp.float32)


def _lorenzo_lap_term(d: jax.Array, axis: int) -> jax.Array:
    """D_a[i+1] - D_a[i] on the interior — one axis term of V-B.3."""
    sl_hi = [slice(1, -1)] * d.ndim
    sl_hi[axis] = slice(2, None)
    sl_lo = [slice(1, -1)] * d.ndim
    sl_lo[axis] = slice(1, -1)
    return d[tuple(sl_hi)] - d[tuple(sl_lo)]


def _laplacian_stencil(x: jax.Array) -> jax.Array:
    """Sum of neighbors minus 2·nd·center on the common interior, f32."""
    acc = -2.0 * x.ndim * _interior(x).astype(jnp.float32)
    for a in range(x.ndim):
        hi, lo = _shift_pair(x, a)
        acc = acc + hi.astype(jnp.float32) + lo.astype(jnp.float32)
    return acc


def _blockmean_deriv_p(p: jax.Array, m: jax.Array, axis: int) -> jax.Array:
    """(p_{+1} - p_{-1}) + (m_{+1} - m_{-1}): V-B §② with the border Delta
    terms realized as a shifted upsampled-mean difference."""
    p_hi, p_lo = _shift_pair(p, axis)
    m_hi, m_lo = _shift_pair(m, axis)
    return ((p_hi - p_lo) + (m_hi - m_lo)).astype(jnp.float32)


# ===========================================================================
# lowering rules: one per (op, stage, scheme family)
# ===========================================================================
# Each rule is fn(ctx, axis) -> result; the "any" family key matches both.

def _mean_m(ctx: StageContext, axis: int) -> jax.Array:
    # ① ultra-fast metadata path: mu = (1/N) sum_b M_b S_b * 2eps  (V-A.1).
    # Partial-block windows would weight block means by fractional coverage,
    # voiding the eps bias bound (§V-D.1), hence the alignment requirement.
    if ctx.plan is not None and not ctx.plan.aligned:
        raise UnsupportedStageError(
            "stage-1 region mean needs a block-aligned window "
            f"(region {ctx.plan.region} vs block {ctx.field.block})")
    s = jnp.sum(ctx.metadata_blocks.reshape(-1) * ctx.block_overlap)
    return s / ctx.n * ctx.eps * 2.0


def _mean_p_blockmean(ctx: StageContext, axis: int) -> jax.Array:
    # ② sum q over extent = sum p over extent + sum_b M_b * overlap_b (V-A §②)
    sp = ctx.masked_sum(ctx.sub.residuals)
    sm = jnp.sum(ctx.sub.metadata.reshape(-1) * ctx.block_overlap)
    return (sp + sm) / ctx.n * ctx.eps * 2.0


def _mean_p_lorenzo(ctx: StageContext, axis: int) -> jax.Array:
    # ② Lorenzo: sum q = weighted sum of residuals; separable weights make
    # this a rank-1 contraction (w0^T P w1 ...) for nd, one dot for flat.
    acc = ctx.sub.residuals.astype(jnp.float32)
    weights = ctx.lorenzo_mean_weights
    if ctx.scheme.is_nd:
        for w in weights:
            acc = jnp.tensordot(acc, jnp.asarray(w), axes=[[0], [0]])
    else:
        acc = jnp.dot(acc.reshape(-1), jnp.asarray(weights[0]))
    return acc / ctx.n * ctx.eps * 2.0


def _mean_q(ctx: StageContext, axis: int) -> jax.Array:
    # flat reductions throughout the statistics: see StageContext.masked_sum
    q = ctx.q_spatial.astype(jnp.float32).reshape(-1)
    return jnp.mean(q) * ctx.eps * 2.0


def _mean_f(ctx: StageContext, axis: int) -> jax.Array:
    return jnp.mean(ctx.f_spatial.astype(jnp.float32).reshape(-1))


def _std_p_blockmean(ctx: StageContext, axis: int) -> jax.Array:
    # ② decompose (q - mu) = (p) + (M_b - mu~) with integer mean mu~ (V-A §②)
    n = ctx.n
    s = jnp.sum(ctx.sub.metadata.reshape(-1) * ctx.block_overlap)
    if ctx.plan is None:
        # complete blocks: per-block residual sums stay near zero, so the
        # metadata term alone anchors the integer mean
        tot = s
    else:
        # a partial block contributes a one-sided slice of its residuals, so
        # the exact integer window sum must include them
        tot = s + jnp.sum(ctx.plan.window_of(ctx.sub.residuals).reshape(-1))
    mu_int = jnp.round(tot / n).astype(jnp.int32)
    x = ctx.stat_values(ctx.sub.residuals + (ctx.upsampled_means - mu_int))
    ss = jnp.sum(x * x)
    # the integer mean mu~ differs from the anchor mean by r, |r| <= 1/2;
    # remove its first-order contribution exactly: sum (x - r)^2 over extent
    r = tot / n - mu_int
    ss = ss - 2.0 * r * jnp.sum(x) + n * r * r
    return jnp.sqrt(jnp.maximum(ss, 0.0) / (n - 1)) * ctx.eps * 2.0


def _std_p_lorenzo(ctx: StageContext, axis: int) -> jax.Array:
    qf = ctx.stat_values(ctx.lorenzo_q)
    n = ctx.n
    s1, s2 = jnp.sum(qf), jnp.sum(qf * qf)
    var = (s2 - s1 * s1 / n) / (n - 1)
    return jnp.sqrt(jnp.maximum(var, 0.0)) * ctx.eps * 2.0


def _std_q(ctx: StageContext, axis: int) -> jax.Array:
    qf = ctx.q_spatial.astype(jnp.float32).reshape(-1)
    n = ctx.n
    s1, s2 = jnp.sum(qf), jnp.sum(qf * qf)
    var = (s2 - s1 * s1 / n) / (n - 1)
    return jnp.sqrt(jnp.maximum(var, 0.0)) * ctx.eps * 2.0


def _std_f(ctx: StageContext, axis: int) -> jax.Array:
    # two-pass (mean-subtracted) like `jnp.std` — the single-pass moments
    # form of ②/③ would catastrophically cancel in f32 for mean-dominated
    # fields, and ④ is the accuracy reference the lower stages are judged
    # against — but over *flat* single-axis reductions: multi-axis reduces
    # compile to context-dependent strategies, and store-seeded and cold
    # programs must agree bit for bit
    xf = ctx.f_spatial.astype(jnp.float32).reshape(-1)
    n = ctx.n
    d = xf - jnp.sum(xf) / n
    return jnp.sqrt(jnp.maximum(jnp.sum(d * d) / (n - 1), 0.0))


def _deriv_p_lorenzo(ctx: StageContext, axis: int) -> jax.Array:
    d = ctx.spatial_window(ctx.lorenzo_axis_diff(axis))
    return _lorenzo_deriv_stencil(d, axis) * ctx.eps


def _deriv_p_blockmean(ctx: StageContext, axis: int) -> jax.Array:
    return _blockmean_deriv_p(ctx.spatial_window(ctx.sub.residuals),
                              ctx.spatial_window(ctx.upsampled_means),
                              axis) * ctx.eps


def _deriv_q(ctx: StageContext, axis: int) -> jax.Array:
    return _central_diff(ctx.q_spatial, axis, ctx.eps)


# stage ④ stencils ARE the stage-③ rules: (f_hi - f_lo)/2 with f = 2*eps*q
# is algebraically the exact integer difference scaled once — one f32
# rounding instead of three, and (single multiply) bit-stable under any XLA
# fusion, which the store's seeded-vs-cold bit-identity contract relies on
_deriv_f = _deriv_q


def _lap_p_lorenzo(ctx: StageContext, axis: int) -> jax.Array:
    # sum_a (D_a[+1] - D_a[0]) — paper Eq. V-B.3 generalized to n-D
    total = None
    for a in range(ctx.sub.residuals.ndim):
        d = ctx.spatial_window(ctx.lorenzo_axis_diff(a))
        term = _lorenzo_lap_term(d, a)
        total = term if total is None else total + term
    return total.astype(jnp.float32) * (2.0 * ctx.eps)


def _lap_p_blockmean(ctx: StageContext, axis: int) -> jax.Array:
    m = ctx.spatial_window(ctx.upsampled_means)
    p = ctx.spatial_window(ctx.sub.residuals)
    return (_laplacian_stencil(p) + _laplacian_stencil(m)) * (2.0 * ctx.eps)


def _lap_q(ctx: StageContext, axis: int) -> jax.Array:
    return _laplacian_stencil(ctx.q_spatial) * (2.0 * ctx.eps)  # (V-B.4)


# integer-stencil form of the float laplacian (see _deriv_f note)
_lap_f = _lap_q


# ===========================================================================
# op specs
# ===========================================================================

Rule = Callable[[StageContext, int], jax.Array]


@dataclass(frozen=True)
class OpSpec:
    """Declarative description of one analytical operation.

    ``lower`` maps ``(stage, family)`` — family one of ``"blockmean"``,
    ``"lorenzo"``, ``"any"`` — to the postlude rule for that cell; cells
    absent from both family and ``"any"`` keys are infeasible (Table I).
    ``fused`` optionally maps the same cells to Pallas-backed
    :class:`repro.core.fused.FusedRule` alternates; :func:`select_rule`
    prefers a fused rule when kernels are enabled and its coverage
    predicate accepts the context, and every fused cell must have an XLA
    rule to fall back to (enforced by :func:`spec_violations`).
    ``closure`` gives the region dependency closure of the op's prelude;
    vector ops instead declare ``component_axes`` (which derivative axes
    each component feeds) from which per-component closures derive.
    """

    name: str
    arity: str                    # "field" | "vector"
    category: str                 # "statistic" | "differentiation" | "multivariate"
    feasible: Callable[[Scheme], tuple[Stage, ...]]
    needs_axis: bool = False
    closure: Callable[[Scheme, Stage, int], R.Closure] | None = None
    component_axes: Callable[[int], tuple[tuple[int, ...], ...]] | None = None
    lower: Mapping[tuple[Stage, str], Rule] = dc_field(default_factory=dict)
    fused: Mapping[tuple[Stage, str], fused_mod.FusedRule] = dc_field(
        default_factory=dict)
    lower_vector: Callable | None = None
    lower_temporal: Callable | None = None  # (TemporalSummary, eps) -> result


def _mean_stages(scheme: Scheme) -> tuple[Stage, ...]:
    return tuple(([Stage.M] if scheme.is_blockmean else [])
                 + [Stage.P, Stage.Q, Stage.F])


def _std_stages(scheme: Scheme) -> tuple[Stage, ...]:
    return (Stage.P, Stage.Q, Stage.F)


def _stencil_stages(scheme: Scheme) -> tuple[Stage, ...]:
    return tuple(([Stage.P] if scheme.is_nd else []) + [Stage.Q, Stage.F])


def _deriv_closure(scheme: Scheme, stage: Stage, axis: int) -> R.Closure:
    return R.op_closure(scheme, "derivative", stage, axis)


def _stat_closure(scheme: Scheme, stage: Stage, axis: int) -> R.Closure:
    return R.op_closure(scheme, "mean", stage, axis)


def _gradient_closure(scheme: Scheme, stage: Stage, axis: int) -> R.Closure:
    # every axis' derivative band, joined — the prefix hull for nd Lorenzo
    return R.op_closure(scheme, "gradient", stage, axis)


_DERIV_RULES: dict[tuple[Stage, str], Rule] = {
    (Stage.P, "lorenzo"): _deriv_p_lorenzo,
    (Stage.P, "blockmean"): _deriv_p_blockmean,
    (Stage.Q, "any"): _deriv_q,
    (Stage.F, "any"): _deriv_f,
}


def kernel_sig() -> str:
    """The resolved kernel backend mode — a *static* lowering input: any
    cache key over a traced ``compute`` program must include it, since the
    fused-vs-XLA selection happens at trace time (the engine's keys do)."""
    return kernel_ops.kernel_mode()


def _select(fused: Mapping, lower: Mapping, stage: Stage, family: str,
            ctx: StageContext) -> Rule:
    """The one dispatch rule: the cell's fused Pallas rule when kernels are
    enabled and it covers this concrete context, else the XLA rule."""
    fr = fused.get((stage, family))
    if fr is not None and kernel_ops.kernels_enabled() and fr.covers(ctx):
        return fr
    rule = lower.get((stage, family)) or lower.get((stage, "any"))
    if rule is None:
        raise KeyError((stage, family))
    return rule


def select_rule(spec: OpSpec, stage: Stage, family: str,
                ctx: StageContext) -> Rule:
    """Resolve the lowering rule :func:`compute` runs for one op cell."""
    return _select(spec.fused, spec.lower, Stage(stage), family, ctx)


def _derivative_at(ctx: StageContext, axis: int) -> jax.Array:
    """Dispatch the derivative rule for ``ctx`` — the shared postlude every
    multivariate/gradient lowering is assembled from.  Goes through the
    fused backend too, so divergence/curl/vector compositions pick up the
    kernels without their own cells."""
    family = family_of(ctx.scheme)
    rule = _select(fused_mod.DERIVATIVE, _DERIV_RULES, ctx.stage, family, ctx)
    return rule(ctx, axis)


def _gradient_rule(ctx: StageContext, axis: int) -> tuple[jax.Array, ...]:
    nd = len(ctx.field.shape)
    return tuple(_derivative_at(ctx, a) for a in range(nd))


def _divergence_vector(ctxs: Sequence[StageContext], axis: int) -> jax.Array:
    total = None
    for a, ctx in enumerate(ctxs):
        term = _derivative_at(ctx, a)
        total = term if total is None else total + term
    return total


def _curl_vector(ctxs: Sequence[StageContext], axis: int):
    """2-D: scalar dv/dx - du/dy (paper V-C.3 with (x,y)=(axis0,axis1));
    3-D: the full vector curl.  Pinned by the rigid-rotation oracle
    (u=-y, v=x has curl exactly +2) in ``tests/test_oracle_fields.py``."""
    if len(ctxs) == 2:
        u, v = ctxs
        return _derivative_at(v, 0) - _derivative_at(u, 1)
    u, v, w = ctxs
    return (
        _derivative_at(w, 1) - _derivative_at(v, 2),
        _derivative_at(u, 2) - _derivative_at(w, 0),
        _derivative_at(v, 0) - _derivative_at(u, 1),
    )


def _div_axes(n_components: int) -> tuple[tuple[int, ...], ...]:
    return tuple((i,) for i in range(n_components))


def _curl_axes(n_components: int) -> tuple[tuple[int, ...], ...]:
    if n_components == 2:
        return ((1,), (0,))
    if n_components == 3:
        return ((1, 2), (0, 2), (0, 1))
    raise ValueError(f"curl needs 2 or 3 components, got {n_components}")


#: the registry: declaration order is the canonical op-set order (used for
#: order-insensitive fused cache keys).
OPS: dict[str, OpSpec] = {
    spec.name: spec for spec in (
        OpSpec("mean", "field", "statistic", _mean_stages,
               closure=_stat_closure,
               lower={(Stage.M, "blockmean"): _mean_m,
                      (Stage.P, "blockmean"): _mean_p_blockmean,
                      (Stage.P, "lorenzo"): _mean_p_lorenzo,
                      (Stage.Q, "any"): _mean_q,
                      (Stage.F, "any"): _mean_f}),
        OpSpec("std", "field", "statistic", _std_stages,
               closure=_stat_closure,
               lower={(Stage.P, "blockmean"): _std_p_blockmean,
                      (Stage.P, "lorenzo"): _std_p_lorenzo,
                      (Stage.Q, "any"): _std_q,
                      (Stage.F, "any"): _std_f}),
        OpSpec("derivative", "field", "differentiation", _stencil_stages,
               needs_axis=True, closure=_deriv_closure, lower=_DERIV_RULES,
               fused=fused_mod.DERIVATIVE),
        OpSpec("gradient", "field", "differentiation", _stencil_stages,
               closure=_gradient_closure,
               lower={(Stage.P, "any"): _gradient_rule,
                      (Stage.Q, "any"): _gradient_rule,
                      (Stage.F, "any"): _gradient_rule},
               fused=fused_mod.GRADIENT),
        OpSpec("laplacian", "field", "differentiation", _stencil_stages,
               closure=_stat_closure,  # hull / cover: all axes' diffs
               lower={(Stage.P, "lorenzo"): _lap_p_lorenzo,
                      (Stage.P, "blockmean"): _lap_p_blockmean,
                      (Stage.Q, "any"): _lap_q,
                      (Stage.F, "any"): _lap_f},
               fused=fused_mod.LAPLACIAN),
        OpSpec("divergence", "vector", "multivariate", _stencil_stages,
               component_axes=_div_axes, lower_vector=_divergence_vector),
        OpSpec("curl", "vector", "multivariate", _stencil_stages,
               component_axes=_curl_axes, lower_vector=_curl_vector),
    )
}

# ===========================================================================
# temporal operations (streaming time-slab analytics)
# ===========================================================================
# A *temporal field* (``repro.stream.TemporalField``) is an append-only
# sequence of error-bounded-compressed time slabs, each an ordinary
# Compressed/Encoded field of shape ``(k, *spatial)`` sharing one eps (one
# quantization grid).  Temporal ops reduce over the time axis and lower as
# homomorphic *merges* of per-slab integer summaries: every leaf of a
# :class:`TemporalSummary` is integer-exact (int32, modular), so merging
# slab summaries in any association is bit-identical to one reduction over
# the fully decompressed concatenated field — the streaming analogue of the
# store's integer-materialization contract (DESIGN.md §9).


@partial(
    _dataclass_pytree,
    data_fields=("count", "q_sum", "q_sumsq", "q_min", "q_max", "last2"),
    meta_fields=(),
)
@dataclass(frozen=True)
class TemporalSummary:
    """Integer-exact per-slab (or merged) temporal summary.

    All leaves are ``int32`` over the queried spatial extent; sums are
    modular (two's-complement wrap), which keeps merging associative and
    bit-exact in any order — results are numerically meaningful while the
    true sums fit int32 (``|q| * T < 2^31`` for ``q_sum``, ``q^2 * T < 2^31``
    for ``q_sumsq``), the same residual-bounded regime the rest of the
    integer pipeline assumes.  ``last2`` holds the quantization integers of
    the final two timesteps (duplicated while only one exists), which is
    what ``tdelta`` — the latest inter-timestep change — consumes.
    """

    count: jax.Array    # int32 scalar: timesteps summarized
    q_sum: jax.Array    # int32 (*extent,): sum over time of q
    q_sumsq: jax.Array  # int32 (*extent,): sum over time of q^2 (modular)
    q_min: jax.Array    # int32 (*extent,)
    q_max: jax.Array    # int32 (*extent,)
    last2: jax.Array    # int32 (2, *extent): q at timesteps T-2, T-1

    @property
    def nbytes(self) -> int:
        """Device bytes kept resident (store LRU accounting)."""
        leaves = (self.count, self.q_sum, self.q_sumsq, self.q_min,
                  self.q_max, self.last2)
        return int(sum(x.size * x.dtype.itemsize for x in leaves))

    def sig(self) -> tuple:
        """Hashable static signature (jit-cache key component)."""
        return tuple((tuple(x.shape), str(x.dtype))
                     for x in (self.count, self.q_sum, self.q_sumsq,
                               self.q_min, self.q_max, self.last2))


def summary_from_q(q: jax.Array) -> TemporalSummary:
    """Summarize a time-major integer block ``q`` of shape ``(k, *extent)``.

    The one reduction rule both paths share: per-slab summaries (this, per
    slab, then merged) and the full-decompression reference (this, once,
    over the concatenated field) are bit-identical because every reduction
    is int32 (modular addition / min / max — associative, order-free).
    """
    k = int(q.shape[0])
    last2 = q[-2:] if k >= 2 else jnp.concatenate([q[-1:], q[-1:]], axis=0)
    return TemporalSummary(
        count=jnp.asarray(k, jnp.int32),
        q_sum=jnp.sum(q, axis=0),
        q_sumsq=jnp.sum(q * q, axis=0),
        q_min=jnp.min(q, axis=0),
        q_max=jnp.max(q, axis=0),
        last2=last2,
    )


def merge_summaries(a: TemporalSummary, b: TemporalSummary) -> TemporalSummary:
    """Homomorphic merge of two temporally *adjacent* summaries (a before b).

    Integer-exact and associative — ``merge(s_1, merge(s_2, s_3))`` equals
    one pass over the concatenation — but not commutative: ``last2`` tracks
    the stream's final frames, so order is the append order.
    """
    last2 = jnp.where(b.count >= 2, b.last2,
                      jnp.stack([a.last2[1], b.last2[1]]))
    return TemporalSummary(
        count=a.count + b.count,
        q_sum=a.q_sum + b.q_sum,
        q_sumsq=a.q_sumsq + b.q_sumsq,
        q_min=jnp.minimum(a.q_min, b.q_min),
        q_max=jnp.maximum(a.q_max, b.q_max),
        last2=last2,
    )


def _slab_q_view(ctx: StageContext) -> jax.Array:
    """Quantization integers of one slab on the queried extent, time-major.

    Stage ③/④ read the shared ``q_spatial`` reconstruction; stage ② derives
    q from the stage-② intermediates (block-mean: residuals + upsampled
    means, elementwise; Lorenzo: the context's cumsum recorrelation — the
    same stage-② work the spatial ``std@P`` lowerings already do).  All
    paths produce the *same integers*, which is why one summary serves every
    feasible stage bit-identically.
    """
    if ctx.stage != Stage.P:
        return ctx.q_spatial
    if ctx.scheme.is_blockmean:
        return ctx.spatial_window(ctx.sub.residuals + ctx.upsampled_means)
    return ctx.spatial_window(ctx.lorenzo_q)


def temporal_region(c: Field, region) -> tuple | None:
    """Lift a *spatial* region to the slab layout (time axis 0 kept whole)."""
    if region is None:
        return None
    if len(region) != len(c.shape) - 1:
        raise ValueError(
            f"temporal region is spatial-only: rank {len(c.shape) - 1} "
            f"expected, got {len(region)}")
    return ((0, c.shape[0]),) + tuple(region)


def summarize_slab(c: Field, stage: Stage, *,
                   region=None) -> TemporalSummary:
    """One slab's integer temporal summary at ``stage`` (the per-append
    reconstruction unit: appending a slab summarizes *only* that slab).

    ``region`` is spatial (the slab's time axis is always axis 0 and always
    fully covered).  Infeasible stages raise ``UnsupportedStageError`` with
    the temporal ops' own error semantics.
    """
    stage = Stage(stage)
    _check_feasible(TEMPORAL_OPS["tmean"], c.scheme, stage)
    slab_region = temporal_region(c, region)
    closure = R.op_closure(c.scheme, "mean", stage)
    ctx = StageContext(c, stage, slab_region, closure)
    return summary_from_q(_slab_q_view(ctx))


def _temporal_cnt(s: TemporalSummary) -> jax.Array:
    return s.count.astype(jnp.float32)


def _tmean_rule(s: TemporalSummary, eps) -> jax.Array:
    return s.q_sum.astype(jnp.float32) * (2.0 * eps) / _temporal_cnt(s)


def _tstd_rule(s: TemporalSummary, eps) -> jax.Array:
    n = _temporal_cnt(s)
    s1 = s.q_sum.astype(jnp.float32)
    s2 = s.q_sumsq.astype(jnp.float32)
    # frame-at-a-time streams query after a single timestep: ddof=1 would be
    # 0/0 there, so clamp the denominator — zero spread, not NaN, until a
    # second timestep arrives
    var = (s2 - s1 * s1 / n) / jnp.maximum(n - 1.0, 1.0)
    return jnp.sqrt(jnp.maximum(var, 0.0)) * (2.0 * eps)


def _tmin_rule(s: TemporalSummary, eps) -> jax.Array:
    return s.q_min.astype(jnp.float32) * (2.0 * eps)


def _tmax_rule(s: TemporalSummary, eps) -> jax.Array:
    return s.q_max.astype(jnp.float32) * (2.0 * eps)


def _tdelta_rule(s: TemporalSummary, eps) -> jax.Array:
    # latest inter-timestep change, exact integer difference scaled once
    # (same single-rounding form as the spatial stage-④ stencils)
    return (s.last2[1] - s.last2[0]).astype(jnp.float32) * (2.0 * eps)


def _temporal_stages(scheme: Scheme) -> tuple[Stage, ...]:
    # stage ② needs the (time, *spatial) layout; 1-D partitioning flattens
    # it away, exactly like the spatial stencils (paper §V-B)
    return tuple(([Stage.P] if scheme.is_nd else []) + [Stage.Q, Stage.F])


#: temporal op registry: reductions over the time axis of an appended
#: stream, each a postlude on one merged :class:`TemporalSummary`.
TEMPORAL_OPS: dict[str, OpSpec] = {
    spec.name: spec for spec in (
        OpSpec("tdelta", "temporal", "temporal", _temporal_stages,
               lower_temporal=_tdelta_rule),
        OpSpec("tmean", "temporal", "temporal", _temporal_stages,
               lower_temporal=_tmean_rule),
        OpSpec("tmin", "temporal", "temporal", _temporal_stages,
               lower_temporal=_tmin_rule),
        OpSpec("tmax", "temporal", "temporal", _temporal_stages,
               lower_temporal=_tmax_rule),
        OpSpec("tstd", "temporal", "temporal", _temporal_stages,
               lower_temporal=_tstd_rule),
    )
}


def temporal_postlude(ops: str | Sequence[str], summary: TemporalSummary,
                      eps) -> dict[str, jax.Array]:
    """Lower a temporal op set onto one merged summary: ``{op: result}``.

    The summary already paid every reconstruction; postludes are tiny
    elementwise float tails, identical at every stage the summary serves
    (②③④ — the integers are the same, ④'s dequantize is the final multiply).
    """
    names = canonical_ops(ops)
    if not is_temporal_ops(names):
        raise ValueError(f"{names} is not a temporal op set")
    return {n: TEMPORAL_OPS[n].lower_temporal(summary, eps) for n in names}


def family_of(scheme: Scheme) -> str:
    """The lowering-rule family key of a scheme (``compute`` dispatches on
    this): ``"lorenzo"`` for the HSZp pair, ``"blockmean"`` for HSZx."""
    return "lorenzo" if Scheme(scheme).is_lorenzo else "blockmean"


def resolve_rules(spec: OpSpec, scheme: Scheme, stage: Stage) -> tuple[Rule, ...]:
    """Every lowering rule of ``spec`` matching the ``(stage, scheme)`` cell.

    The well-formed registry has exactly one match per feasible cell —
    either the scheme-family rule or the ``"any"`` rule, never both (a
    family rule next to an ``"any"`` rule at the same stage would silently
    shadow it in :func:`compute`) and never neither.  :func:`spec_violations`
    and the ``repro.audit`` registry analyzer enforce this.
    """
    stage = Stage(stage)
    rules = []
    fam = spec.lower.get((stage, family_of(scheme)))
    if fam is not None:
        rules.append(fam)
    any_rule = spec.lower.get((stage, "any"))
    if any_rule is not None:
        rules.append(any_rule)
    return tuple(rules)


#: valid string closures (tuple closures are ``("band", axis)``).
_CLOSURE_STRS = frozenset({"cover", "hull"})


def _closure_ok(value) -> bool:
    if isinstance(value, str):
        return value in _CLOSURE_STRS
    return (isinstance(value, tuple) and len(value) == 2
            and value[0] == "band" and isinstance(value[1], int))


def spec_violations(spec: OpSpec) -> list:
    """Enumerate structural violations of one :class:`OpSpec`.

    Returns ``(invariant, message)`` pairs — the single source of truth
    shared by registration-time validation (:func:`register_op`, which
    raises on the rejecting subset) and the ``repro.audit`` registry
    analyzer (which reports every violation as a structured finding).
    """
    out: list = []
    if spec.arity not in ("field", "vector", "temporal"):
        out.append(("invalid-arity",
                    f"op {spec.name!r} has arity {spec.arity!r}; expected "
                    "'field', 'vector', or 'temporal'"))
        return out

    if spec.arity == "temporal":
        if spec.lower_temporal is None:
            out.append(("missing-lowering-rule",
                        f"temporal op {spec.name!r} has no lower_temporal "
                        "rule"))
        return out

    if spec.arity == "vector":
        if spec.lower_vector is None:
            out.append(("missing-lowering-rule",
                        f"vector op {spec.name!r} has no lower_vector rule"))
        if spec.component_axes is None:
            out.append(("missing-closure",
                        f"vector op {spec.name!r} has no component_axes "
                        "(per-component region closures derive from it)"))
        else:
            for nc in (2, 3):
                try:
                    axes = spec.component_axes(nc)
                except ValueError:
                    continue  # op legitimately rejects this component count
                if len(axes) != nc or any(
                        a not in range(nc) for t in axes for a in t):
                    out.append(("invalid-closure",
                                f"vector op {spec.name!r}: component_axes"
                                f"({nc}) = {axes!r} is not {nc} in-range "
                                "axis tuples"))
        return out

    # field arity: every feasible (stage, scheme-family) cell needs exactly
    # one lowering rule, and a region closure must exist for each cell
    if spec.closure is None:
        out.append(("missing-closure",
                    f"op {spec.name!r}: field op has no closure callable "
                    "(region-capable cells need one)"))
    seen_cells: set = set()  # one report per (invariant, stage, family) cell
    for scheme in Scheme:
        fam = family_of(scheme)
        feasible = tuple(Stage(s) for s in spec.feasible(scheme))
        for stage in feasible:
            n_rules = len(resolve_rules(spec, scheme, stage))
            if n_rules == 0 and ("miss", stage, fam) not in seen_cells:
                seen_cells.add(("miss", stage, fam))
                out.append(("missing-lowering-rule",
                            f"op {spec.name!r}: feasible cell (stage "
                            f"{stage.name}, {fam}) has no lowering rule"))
            elif n_rules > 1 and ("ambig", stage, fam) not in seen_cells:
                seen_cells.add(("ambig", stage, fam))
                out.append(("ambiguous-lowering-rule",
                            f"op {spec.name!r}: cell (stage {stage.name}, "
                            f"{fam}) matches both a family rule and an "
                            "'any' rule — the family rule silently shadows"))
            if spec.closure is None:
                continue
            try:
                value = spec.closure(scheme, stage, 0)
            except Exception as e:  # noqa: BLE001 - report, don't crash
                out.append(("invalid-closure",
                            f"op {spec.name!r}: closure({scheme.value}, "
                            f"{stage.name}) raised {e!r}"))
                continue
            if not _closure_ok(value):
                out.append(("invalid-closure",
                            f"op {spec.name!r}: closure({scheme.value}, "
                            f"{stage.name}) = {value!r} is not a valid "
                            "region closure"))
    # fused cells are *alternates*: each needs an XLA rule to fall back to
    # (REPRO_KERNELS=off / an uncovered context must never lose the op),
    # and must be a well-formed FusedRule (callable with a covers predicate)
    for (stage, fam), fr in spec.fused.items():
        stage = Stage(stage)
        if not (callable(fr) and callable(getattr(fr, "covers", None))):
            out.append(("invalid-fused-rule",
                        f"op {spec.name!r}: fused cell (stage {stage.name}, "
                        f"{fam}) holds {fr!r}, not a FusedRule (callable "
                        "with a covers predicate)"))
        if (spec.lower.get((stage, fam)) is None
                and spec.lower.get((stage, "any")) is None):
            out.append(("fused-cell-without-fallback",
                        f"op {spec.name!r}: fused cell (stage {stage.name}, "
                        f"{fam}) has no XLA lowering rule to fall back to "
                        "when kernels are off or the context is uncovered"))
    # a declared rule no feasible cell can ever reach is dead weight — and
    # usually a sign the feasibility row and the rule table disagree
    for (stage, fam), _rule in spec.lower.items():
        reachable = any(
            Stage(stage) in spec.feasible(scheme)
            and fam in ("any", family_of(scheme))
            for scheme in Scheme)
        if not reachable:
            out.append(("unreachable-lowering-rule",
                        f"op {spec.name!r}: rule for cell (stage "
                        f"{Stage(stage).name}, {fam}) is unreachable from "
                        "every scheme's feasibility row"))
    return out


#: violations that reject an OpSpec at registration time (the audit-only
#: extras — unreachable rules — merely warn the static pass).
_REJECTING = frozenset({
    "invalid-arity", "missing-lowering-rule", "ambiguous-lowering-rule",
    "missing-closure", "invalid-closure",
    "invalid-fused-rule", "fused-cell-without-fallback",
})


def _merge_registries(*registries: Mapping[str, OpSpec]) -> dict[str, OpSpec]:
    """Combine op registries into the single lookup, rejecting name
    collisions: a name silently shadowed across registries would make
    ``canonical_ops`` / planning disagree about an op's arity and
    feasibility, so the merge fails loudly instead."""
    out: dict[str, OpSpec] = {}
    for reg in registries:
        for name, spec in reg.items():
            if name in out:
                raise ValueError(
                    f"op name collision: {name!r} is registered more than "
                    "once (the spatial OPS and temporal TEMPORAL_OPS "
                    "registries — and any user-registered spec — must use "
                    "unique names)")
            out[name] = spec
    return out


#: single lookup across both registries (spatial + temporal).
_ALL_OPS: dict[str, OpSpec] = _merge_registries(OPS, TEMPORAL_OPS)

_ORDER = {name: i for i, name in enumerate(_ALL_OPS)}


def register_op(spec: OpSpec) -> OpSpec:
    """Register a user-defined :class:`OpSpec` (collision-guarded).

    The spec joins the arity-appropriate registry and the canonical order;
    ``repro.analytics.planner`` resolves feasibility for unknown matrix
    cells straight from the spec, so registered ops plan like built-ins.
    """
    if spec.name in _ALL_OPS:
        raise ValueError(
            f"op name collision: {spec.name!r} is already registered")
    bad = [(inv, msg) for inv, msg in spec_violations(spec)
           if inv in _REJECTING]
    if bad:
        detail = "; ".join(msg for _, msg in bad)
        raise ValueError(
            f"malformed OpSpec {spec.name!r}: {detail} "
            "(every feasible (stage, scheme-family) cell needs exactly one "
            "lowering rule and a region closure — see repro.audit)")
    registry = TEMPORAL_OPS if spec.arity == "temporal" else OPS
    registry[spec.name] = spec
    _ALL_OPS[spec.name] = spec
    _ORDER[spec.name] = len(_ORDER)
    return spec


# ===========================================================================
# op-set canonicalization / validation
# ===========================================================================

def canonical_ops(ops: str | Sequence[str]) -> tuple[str, ...]:
    """Validate and canonicalize an op set: known names, de-duplicated,
    registry order (so ``["std", "mean"]`` and ``["mean", "std"]`` share one
    compiled program), single arity (field ops and vector ops cannot share a
    prelude — they consume different argument shapes)."""
    names = [ops] if isinstance(ops, str) else list(ops)
    if not names:
        raise ValueError("empty op set")
    out = []
    for name in names:
        if name not in _ALL_OPS:
            raise ValueError(
                f"unknown operation {name!r}; expected one of "
                f"{tuple(_ALL_OPS)}")
        if name not in out:
            out.append(name)
    out.sort(key=_ORDER.__getitem__)
    if len({_ALL_OPS[n].arity for n in out}) > 1:
        detail = ", ".join(f"{n} ({_ALL_OPS[n].arity})" for n in out)
        raise ValueError(
            f"cannot fuse ops of different arities in one set: {detail} "
            "(field, vector, and temporal ops consume different arguments)")
    return tuple(out)


def is_vector_ops(ops: Sequence[str]) -> bool:
    """True when the (canonical) op set takes vector-field arguments."""
    return _ALL_OPS[ops[0]].arity == "vector"


def is_temporal_ops(ops: Sequence[str]) -> bool:
    """True when the (canonical) op set reduces over a temporal stream."""
    return _ALL_OPS[ops[0]].arity == "temporal"


def _check_feasible(spec: OpSpec, scheme: Scheme, stage: Stage) -> None:
    """Raise with the ops' established error messages (pinned by tests)."""
    if stage in spec.feasible(scheme):
        return
    if spec.category == "statistic":
        if spec.name == "mean":
            raise UnsupportedStageError("stage-1 mean needs HSZx-family metadata")
        raise UnsupportedStageError("std needs pointwise info (stages 2-4)")
    if spec.category == "temporal":
        if stage == Stage.M:
            raise UnsupportedStageError(
                "temporal ops need pointwise info (stages 2-4)")
        # 1-D partitioning flattens the (time, spatial) layout away, like
        # the spatial stencils (paper §V-B)
        raise UnsupportedStageError("stage-2 temporal ops require nd schemes")
    if stage == Stage.M:
        raise UnsupportedStageError("stencils need pointwise info")
    # paper §V-B: 1-D partitioning destroys multidimensional layout
    raise UnsupportedStageError("stage-2 stencils require nd schemes")


# ===========================================================================
# the lowering pipeline
# ===========================================================================

def compute(target, ops: str | Sequence[str], stage: Stage, *,
            axis: int = 0, region: R.RegionSpec | None = None,
            seed=None, payload_words=None) -> dict[str, jax.Array]:
    """Lower an op set onto one shared stage reconstruction.

    ``target`` is a single :class:`Compressed`/:class:`Encoded` field for
    field-arity op sets, or a sequence of component fields for vector-arity
    sets (``divergence``/``curl``).  Returns ``{op: result}``; every value is
    bit-identical to the corresponding single-op call at the same stage.

    ``seed`` optionally supplies the materialized stage reconstruction
    (``repro.store.MaterializedStage``) — one container for field-arity
    sets, one per component for vector-arity sets — whose key must match
    this ``(stage, region, closure)``; the prelude is then served from the
    resident intermediate instead of recomputed.

    ``payload_words`` optionally supplies the region plan's gathered
    payload words directly (one uint32 array for field-arity sets, one per
    component for vector-arity sets) instead of gathering them from
    ``target.payload`` — the sharded store's scatter/psum word merge
    produces exactly this set (``repro.shard.exec``).  Requires
    ``region`` and :class:`Encoded` targets.
    """
    stage = Stage(stage)
    names = canonical_ops(ops)
    if is_temporal_ops(names):
        raise ValueError(
            f"temporal op set {names} runs over an appended stream of time "
            "slabs; use repro.stream (TemporalField / query) instead of "
            "compute()")
    specs = [OPS[n] for n in names]

    if is_vector_ops(names):
        comps = list(target)
        for spec in specs:
            for c in comps:  # mixed-scheme vectors: every component must
                _check_feasible(spec, c.scheme, stage)  # support the stage
        closures = component_closures(names, [c.scheme for c in comps], stage)
        seeds = list(seed) if seed is not None else [None] * len(comps)
        if len(seeds) != len(comps):
            raise ValueError(f"{len(seeds)} seeds for {len(comps)} components")
        words = (list(payload_words) if payload_words is not None
                 else [None] * len(comps))
        if len(words) != len(comps):
            raise ValueError(
                f"{len(words)} payload word sets for {len(comps)} components")
        ctxs = [StageContext(c, stage, region, cl, seed=s, words=w)
                for c, cl, s, w in zip(comps, closures, seeds, words)]
        return {spec.name: spec.lower_vector(ctxs, axis) for spec in specs}

    c = target
    for spec in specs:
        _check_feasible(spec, c.scheme, stage)
    closure = set_closure(names, c.scheme, stage, axis)
    ctx = StageContext(c, stage, region, closure, seed=seed,
                       words=payload_words)
    family = family_of(c.scheme)
    out = {}
    for spec in specs:
        out[spec.name] = select_rule(spec, stage, family, ctx)(ctx, axis)
    return out


def compute_exprs(exprs, stage: Stage, *,
                  region: R.RegionSpec | None = None, seeds=None):
    """Lower expression DAGs (``repro.core.expr``) at one explicit stage.

    The core-level, storeless entry: every leaf must carry its data
    directly (containers / component bundles / ``TemporalField`` streams —
    string ids need the store-aware ``repro.analytics.query(exprs=...)``).
    Each leaf gets exactly one :class:`StageContext` prelude shared by all
    consuming expressions; temporal op nodes are summarized over their
    stream's slabs (host-side reduction of the integer-exact per-slab
    summaries) and fed into the pointwise tail.  Returns one result per
    expression (a single expression returns its value directly), each
    bit-identical to composing the corresponding single-op results.

    ``seeds`` optionally maps leaf slots to resident
    ``MaterializedStage`` intermediates, as in :func:`compute`.
    """
    from functools import reduce

    from . import expr as expr_mod

    single = isinstance(exprs, expr_mod.Expr)
    program = expr_mod.analyze([exprs] if single else list(exprs))
    stage = Stage(stage)

    bindings = []
    for lf in program.leaves:
        src = lf.source
        flat = src if isinstance(src, tuple) else (src,)
        if any(isinstance(c, str) for c in flat):
            raise ValueError(
                f"leaf {lf.key} names a field id; ids resolve through a "
                "store — use repro.analytics.query(exprs=..., store=...)")
        bindings.append(src)
    expr_mod.validate_bound(program, bindings, region=region)

    precomputed = {}
    for node in program.temporal_nodes:
        slot = program.slot_of(node.operand)
        tf = bindings[slot]
        _check_feasible(node.spec, tf.scheme, stage)
        if not tf.slabs:
            raise ValueError("temporal field has no appended slabs")
        summary = reduce(merge_summaries,
                         [summarize_slab(s, stage, region=region)
                          for s in tf.slabs])
        precomputed[program.serial(node)] = node.spec.lower_temporal(
            summary, tf.eps)

    out = expr_mod.lower(program, bindings,
                         (stage,) * program.n_components,
                         region=region, seeds=seeds, precomputed=precomputed)
    return out[0] if single else list(out)
