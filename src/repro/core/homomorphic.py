"""Homomorphic analytical operations on intermediate representations (paper §V).

Six operations, three categories:

* statistics — ``mean`` (stages ①②③④, ① HSZx-family only), ``std`` (②③④);
* numerical differentiation — ``derivative``, ``laplacian`` (② nd-schemes, ③④ all);
* multivariate derivation — ``divergence``, ``curl`` (same stage support).

TPU adaptation (DESIGN.md §3): the paper's scalar accumulators become
parallel prefix sums (`jnp.cumsum`), its per-block border branches become
shifted-upsampled block-mean difference arrays, and the HSZp-2d weighted-sum
mean becomes a rank-1 bilinear form ``w0ᵀ P w1`` (two matvecs — MXU work
instead of a data-sized reduction tree).

All stencil operators return the *common interior* of the field (every axis
cropped by one at each end), matching the reference operators in
``repro.kernels.ref`` exactly.
"""
from __future__ import annotations

from typing import Sequence

import jax
import jax.numpy as jnp

from . import blocking, encode
from .pipeline import HSZCompressor, UnsupportedStageError, by_name
from .stages import Compressed, Encoded, Stage


def _comp(c: Compressed) -> HSZCompressor:
    return by_name(c.scheme.value, c.block)


def _decode(c: Compressed | Encoded) -> Compressed:
    return encode.decode_device(c) if isinstance(c, Encoded) else c


def _valid_weight(c: Compressed) -> jax.Array | None:
    """Spatial 0/1 mask of valid elements, or None when there is no padding.

    The padding decision is static (shape/block only), so no mask is built —
    let alone reduced — inside traced code unless padding actually exists.
    """
    shape = c.shape if c.scheme.is_nd else (c.n,)
    if not blocking.has_padding(shape, c.block):
        return None
    return jnp.asarray(blocking.valid_mask(shape, c.block), jnp.int32)


# ===========================================================================
# statistics (paper §V-A)
# ===========================================================================

def mean(c: Compressed | Encoded, stage: Stage) -> jax.Array:
    """Field mean at a given decompression stage."""
    n = c.n
    if stage == Stage.M:
        # ① ultra-fast metadata path: mu = (1/N) sum_b M_b S_b * 2eps  (V-A.1)
        if not c.scheme.is_blockmean:
            raise UnsupportedStageError("stage-1 mean needs HSZx-family metadata")
        s = jnp.sum(c.metadata.reshape(-1) * c.valid_counts)
        return s / n * c.eps * 2.0

    c = _decode(c)
    if stage == Stage.P:
        p = c.residuals
        if c.scheme.is_blockmean:
            # ② sum of residuals + metadata term (V-A §②)
            w = _valid_weight(c)
            sp = jnp.sum(p if w is None else p * w)
            sm = jnp.sum(c.metadata.reshape(-1) * c.valid_counts)
            return (sp + sm) / n * c.eps * 2.0
        # ② Lorenzo: sum q = weighted sum of residuals; the separable weights
        # w_a[i] = (n_a - i) make this a rank-1 contraction (w0^T P w1 ...).
        dims = c.shape if c.scheme.is_nd else (c.n,)
        acc = p.astype(jnp.float32)
        for axis, (npad, nvalid) in enumerate(zip(c.padded_shape, dims)):
            w = jnp.clip(nvalid - jnp.arange(npad), 0).astype(jnp.float32)
            acc = jnp.tensordot(acc, w, axes=[[0], [0]])  # consumes leading axis
        return acc / n * c.eps * 2.0

    comp = _comp(c)
    if stage == Stage.Q:
        q = comp.decompress(c, Stage.Q)
        return jnp.mean(q.astype(jnp.float32)) * c.eps * 2.0
    return jnp.mean(comp.decompress(c, Stage.F).astype(jnp.float32))


def _sum_q_q2(c: Compressed) -> tuple[jax.Array, jax.Array]:
    """(sum q, sum q^2) over valid elements, computed at stage ②."""
    p = c.residuals
    if c.scheme.is_blockmean:
        q = p + blocking.upsample_block_means(c.metadata, c.block)
    else:
        q = p
        for axis in range(p.ndim):
            q = jnp.cumsum(q, axis=axis)
    qf = q.astype(jnp.float32)
    w = _valid_weight(c)
    if w is not None:
        qf = qf * w
    return jnp.sum(qf), jnp.sum(qf * qf)


def std(c: Compressed | Encoded, stage: Stage) -> jax.Array:
    """Sample standard deviation at a given stage (paper §V-A.2)."""
    n = c.n
    if stage == Stage.M:
        raise UnsupportedStageError("std needs pointwise info (stages 2-4)")
    c = _decode(c)
    if stage == Stage.P and c.scheme.is_blockmean:
        # ② decompose (q - mu) = (p) + (M_b - mu~) with integer mean mu~ (V-A §②)
        s = jnp.sum(c.metadata.reshape(-1) * c.valid_counts)
        mu_int = jnp.round(s / n).astype(jnp.int32)
        mdiff = blocking.upsample_block_means(c.metadata - mu_int, c.block)
        x = (c.residuals + mdiff).astype(jnp.float32)
        w = _valid_weight(c)
        if w is not None:
            x = x * w
        ss = jnp.sum(x * x)
        # the integer mean mu~ differs from the true mean by r~, |r~| <= 1/2;
        # remove its first-order contribution exactly: sum (x - r)^2 over valid
        r = s / n - mu_int
        ss = ss - 2.0 * r * jnp.sum(x) + n * r * r
        return jnp.sqrt(jnp.maximum(ss, 0.0) / (n - 1)) * c.eps * 2.0
    if stage == Stage.P:
        s1, s2 = _sum_q_q2(c)
        var = (s2 - s1 * s1 / n) / (n - 1)
        return jnp.sqrt(jnp.maximum(var, 0.0)) * c.eps * 2.0
    comp = _comp(c)
    if stage == Stage.Q:
        q = comp.decompress(c, Stage.Q).astype(jnp.float32)
        s1, s2 = jnp.sum(q), jnp.sum(q * q)
        var = (s2 - s1 * s1 / n) / (n - 1)
        return jnp.sqrt(jnp.maximum(var, 0.0)) * c.eps * 2.0
    d = comp.decompress(c, Stage.F).astype(jnp.float32)
    return jnp.std(d, ddof=1)


# ===========================================================================
# numerical differentiation (paper §V-B)
# ===========================================================================

def _interior(x: jax.Array) -> jax.Array:
    """Crop one element at each end of every axis (common stencil interior)."""
    return x[tuple(slice(1, -1) for _ in range(x.ndim))]


def _shift_pair(x: jax.Array, axis: int) -> tuple[jax.Array, jax.Array]:
    """(x_{+1}, x_{-1}) views cropped to the common interior."""
    nd = x.ndim
    idx_p = [slice(1, -1)] * nd
    idx_m = [slice(1, -1)] * nd
    idx_p[axis] = slice(2, None)
    idx_m[axis] = slice(None, -2)
    return x[tuple(idx_p)], x[tuple(idx_m)]


def _q_spatial(c: Compressed) -> jax.Array:
    """Stage-③ integers in the original spatial shape (cropped)."""
    comp = _comp(c)
    return comp.decompress(c, Stage.Q)


def _require_stencil_stage(c: Compressed, stage: Stage) -> None:
    if stage == Stage.M:
        raise UnsupportedStageError("stencils need pointwise info")
    if stage == Stage.P and not c.scheme.is_nd:
        # paper §V-B: 1-D partitioning destroys multidimensional layout
        raise UnsupportedStageError("stage-2 stencils require nd schemes")


def _lorenzo_axis_diff(p: jax.Array, axis: int) -> jax.Array:
    """D_a = q - shift_a(q) computed from residuals: cumsum over all axes != a."""
    out = p
    for a in range(p.ndim):
        if a != axis:
            out = jnp.cumsum(out, axis=a)
    return out


def derivative(c: Compressed | Encoded, stage: Stage, axis: int) -> jax.Array:
    """Central difference along ``axis`` on the common interior (III-B.2)."""
    c = _decode(c)
    _require_stencil_stage(c, stage)
    eps = c.eps

    if stage == Stage.P:
        p = blocking.crop(c.residuals, c.shape)
        if c.scheme.is_lorenzo:
            # q_{+1} - q_{-1} = D_a[+1] + D_a[0] with D_a the axis difference
            # reconstructed by prefix sums over the other axes (V-B.1).
            d = _lorenzo_axis_diff(c.residuals, axis)
            d = blocking.crop(d, c.shape)
            # derivative = (D[i+1] + D[i]) on the interior
            sl_hi = [slice(1, -1)] * d.ndim
            sl_hi[axis] = slice(2, None)
            sl_lo = [slice(1, -1)] * d.ndim
            sl_lo[axis] = slice(1, -1)
            val = d[tuple(sl_hi)] + d[tuple(sl_lo)]
            return val.astype(jnp.float32) * eps
        # block-mean: (p_{+1} - p_{-1}) + (m_{+1} - m_{-1})  (V-B §② with the
        # border Delta terms realized as a shifted upsampled-mean difference)
        m = blocking.upsample_block_means(c.metadata, c.block)
        p_hi, p_lo = _shift_pair(blocking.crop(c.residuals, c.shape), axis)
        m_hi, m_lo = _shift_pair(blocking.crop(m, c.shape), axis)
        return ((p_hi - p_lo) + (m_hi - m_lo)).astype(jnp.float32) * eps

    if stage == Stage.Q:
        q = _q_spatial(c)
        hi, lo = _shift_pair(q, axis)
        return (hi - lo).astype(jnp.float32) * eps  # (V-B.2)
    d = _comp(c).decompress(c, Stage.F)
    hi, lo = _shift_pair(d, axis)
    return (hi - lo) * 0.5


def gradient(c: Compressed | Encoded, stage: Stage) -> tuple[jax.Array, ...]:
    nd = len(_decode(c).shape)
    return tuple(derivative(c, stage, a) for a in range(nd))


def laplacian(c: Compressed | Encoded, stage: Stage) -> jax.Array:
    """2nd-order Laplacian stencil on the common interior (III-B.3)."""
    c = _decode(c)
    _require_stencil_stage(c, stage)
    eps2 = 2.0 * c.eps

    if stage == Stage.P:
        if c.scheme.is_lorenzo:
            # sum_a (D_a[+1] - D_a[0]) — paper Eq. V-B.3 generalized to n-D
            total = None
            for a in range(c.residuals.ndim):
                d = blocking.crop(_lorenzo_axis_diff(c.residuals, a), c.shape)
                sl_hi = [slice(1, -1)] * d.ndim
                sl_hi[a] = slice(2, None)
                sl_lo = [slice(1, -1)] * d.ndim
                sl_lo[a] = slice(1, -1)
                term = d[tuple(sl_hi)] - d[tuple(sl_lo)]
                total = term if total is None else total + term
            return total.astype(jnp.float32) * eps2
        m = blocking.crop(blocking.upsample_block_means(c.metadata, c.block), c.shape)
        p = blocking.crop(c.residuals, c.shape)
        total = None
        for x in (p, m):
            acc = -2.0 * len(c.shape) * _interior(x).astype(jnp.float32)
            for a in range(x.ndim):
                hi, lo = _shift_pair(x, a)
                acc = acc + hi.astype(jnp.float32) + lo.astype(jnp.float32)
            total = acc if total is None else total + acc
        return total * eps2

    if stage == Stage.Q:
        q = _q_spatial(c)
        acc = -2.0 * len(c.shape) * _interior(q).astype(jnp.float32)
        for a in range(q.ndim):
            hi, lo = _shift_pair(q, a)
            acc = acc + hi.astype(jnp.float32) + lo.astype(jnp.float32)
        return acc * eps2  # (V-B.4)
    d = _comp(c).decompress(c, Stage.F)
    acc = -2.0 * len(c.shape) * _interior(d)
    for a in range(d.ndim):
        hi, lo = _shift_pair(d, a)
        acc = acc + hi + lo
    return acc


# ===========================================================================
# multivariate derivation (paper §V-C)
# ===========================================================================

def divergence(components: Sequence[Compressed | Encoded], stage: Stage) -> jax.Array:
    """div F = sum_a  d(F_a)/d(x_a)  on the common interior (V-C.1/2)."""
    total = None
    for axis, comp in enumerate(components):
        term = derivative(comp, stage, axis)
        total = term if total is None else total + term
    return total


def curl(components: Sequence[Compressed | Encoded], stage: Stage):
    """2-D: scalar dv/dx - du/dy (paper V-C.3 with (x,y)=(axis0,axis1));
    3-D: the full vector curl."""
    if len(components) == 2:
        u, v = components
        return derivative(u, stage, 1) - derivative(v, stage, 0)
    u, v, w = components
    return (
        derivative(w, stage, 1) - derivative(v, stage, 2),
        derivative(u, stage, 2) - derivative(w, stage, 0),
        derivative(v, stage, 0) - derivative(u, stage, 1),
    )
