"""Homomorphic analytical operations on intermediate representations (paper §V).

Six operations, three categories:

* statistics — ``mean`` (stages ①②③④, ① HSZx-family only), ``std`` (②③④);
* numerical differentiation — ``derivative``, ``laplacian`` (② nd-schemes, ③④ all);
* multivariate derivation — ``divergence``, ``curl`` (same stage support).

TPU adaptation (DESIGN.md §3): the paper's scalar accumulators become
parallel prefix sums (`jnp.cumsum`), its per-block border branches become
shifted-upsampled block-mean difference arrays, and the HSZp-2d weighted-sum
mean becomes a rank-1 bilinear form ``w0ᵀ P w1`` (two matvecs — MXU work
instead of a data-sized reduction tree).

All stencil operators return the *common interior* of the field (every axis
cropped by one at each end), matching the reference operators in
``repro.kernels.ref`` exactly.

Every operation additionally accepts ``region=`` (per-axis ``(start, stop)``
or ``slice`` over the original shape): the op then touches only the blocks
in the region's dependency closure (``repro.core.region``, DESIGN.md §5) and
returns exactly what the full-field op would return on the cropped
decompressed window — statistics over the window values, stencils on the
window interior.
"""
from __future__ import annotations

from typing import Optional, Sequence

import jax
import jax.numpy as jnp

from . import blocking, encode, quantize
from . import region as R
from .pipeline import HSZCompressor, UnsupportedStageError, by_name
from .stages import Compressed, Encoded, Stage


def _comp(c: Compressed) -> HSZCompressor:
    return by_name(c.scheme.value, c.block)


def _decode(c: Compressed | Encoded) -> Compressed:
    return encode.decode_device(c) if isinstance(c, Encoded) else c


def _valid_weight(c: Compressed) -> jax.Array | None:
    """Spatial 0/1 mask of valid elements, or None when there is no padding.

    The padding decision is static (shape/block only), so no mask is built —
    let alone reduced — inside traced code unless padding actually exists.
    """
    shape = c.shape if c.scheme.is_nd else (c.n,)
    if not blocking.has_padding(shape, c.block):
        return None
    return jnp.asarray(blocking.valid_mask(shape, c.block), jnp.int32)


# ===========================================================================
# statistics (paper §V-A)
# ===========================================================================

def mean(c: Compressed | Encoded, stage: Stage,
         *, region: Optional[R.RegionSpec] = None) -> jax.Array:
    """Field mean at a given decompression stage (optionally over a region)."""
    if region is not None:
        return _region_mean(c, Stage(stage), region)
    n = c.n
    if stage == Stage.M:
        # ① ultra-fast metadata path: mu = (1/N) sum_b M_b S_b * 2eps  (V-A.1)
        if not c.scheme.is_blockmean:
            raise UnsupportedStageError("stage-1 mean needs HSZx-family metadata")
        s = jnp.sum(c.metadata.reshape(-1) * c.valid_counts)
        return s / n * c.eps * 2.0

    c = _decode(c)
    if stage == Stage.P:
        p = c.residuals
        if c.scheme.is_blockmean:
            # ② sum of residuals + metadata term (V-A §②)
            w = _valid_weight(c)
            sp = jnp.sum(p if w is None else p * w)
            sm = jnp.sum(c.metadata.reshape(-1) * c.valid_counts)
            return (sp + sm) / n * c.eps * 2.0
        # ② Lorenzo: sum q = weighted sum of residuals; the separable weights
        # w_a[i] = (n_a - i) make this a rank-1 contraction (w0^T P w1 ...).
        dims = c.shape if c.scheme.is_nd else (c.n,)
        acc = p.astype(jnp.float32)
        for axis, (npad, nvalid) in enumerate(zip(c.padded_shape, dims)):
            w = jnp.clip(nvalid - jnp.arange(npad), 0).astype(jnp.float32)
            acc = jnp.tensordot(acc, w, axes=[[0], [0]])  # consumes leading axis
        return acc / n * c.eps * 2.0

    comp = _comp(c)
    if stage == Stage.Q:
        q = comp.decompress(c, Stage.Q)
        return jnp.mean(q.astype(jnp.float32)) * c.eps * 2.0
    return jnp.mean(comp.decompress(c, Stage.F).astype(jnp.float32))


def _sum_q_q2(c: Compressed) -> tuple[jax.Array, jax.Array]:
    """(sum q, sum q^2) over valid elements, computed at stage ②."""
    p = c.residuals
    if c.scheme.is_blockmean:
        q = p + blocking.upsample_block_means(c.metadata, c.block)
    else:
        q = p
        for axis in range(p.ndim):
            q = jnp.cumsum(q, axis=axis)
    qf = q.astype(jnp.float32)
    w = _valid_weight(c)
    if w is not None:
        qf = qf * w
    return jnp.sum(qf), jnp.sum(qf * qf)


def std(c: Compressed | Encoded, stage: Stage,
        *, region: Optional[R.RegionSpec] = None) -> jax.Array:
    """Sample standard deviation at a given stage (paper §V-A.2)."""
    if stage == Stage.M:
        raise UnsupportedStageError("std needs pointwise info (stages 2-4)")
    if region is not None:
        return _region_std(c, Stage(stage), region)
    n = c.n
    c = _decode(c)
    if stage == Stage.P and c.scheme.is_blockmean:
        # ② decompose (q - mu) = (p) + (M_b - mu~) with integer mean mu~ (V-A §②)
        s = jnp.sum(c.metadata.reshape(-1) * c.valid_counts)
        mu_int = jnp.round(s / n).astype(jnp.int32)
        mdiff = blocking.upsample_block_means(c.metadata - mu_int, c.block)
        x = (c.residuals + mdiff).astype(jnp.float32)
        w = _valid_weight(c)
        if w is not None:
            x = x * w
        ss = jnp.sum(x * x)
        # the integer mean mu~ differs from the true mean by r~, |r~| <= 1/2;
        # remove its first-order contribution exactly: sum (x - r)^2 over valid
        r = s / n - mu_int
        ss = ss - 2.0 * r * jnp.sum(x) + n * r * r
        return jnp.sqrt(jnp.maximum(ss, 0.0) / (n - 1)) * c.eps * 2.0
    if stage == Stage.P:
        s1, s2 = _sum_q_q2(c)
        var = (s2 - s1 * s1 / n) / (n - 1)
        return jnp.sqrt(jnp.maximum(var, 0.0)) * c.eps * 2.0
    comp = _comp(c)
    if stage == Stage.Q:
        q = comp.decompress(c, Stage.Q).astype(jnp.float32)
        s1, s2 = jnp.sum(q), jnp.sum(q * q)
        var = (s2 - s1 * s1 / n) / (n - 1)
        return jnp.sqrt(jnp.maximum(var, 0.0)) * c.eps * 2.0
    d = comp.decompress(c, Stage.F).astype(jnp.float32)
    return jnp.std(d, ddof=1)


# ===========================================================================
# numerical differentiation (paper §V-B)
# ===========================================================================

def _interior(x: jax.Array) -> jax.Array:
    """Crop one element at each end of every axis (common stencil interior)."""
    return x[tuple(slice(1, -1) for _ in range(x.ndim))]


def _shift_pair(x: jax.Array, axis: int) -> tuple[jax.Array, jax.Array]:
    """(x_{+1}, x_{-1}) views cropped to the common interior."""
    nd = x.ndim
    idx_p = [slice(1, -1)] * nd
    idx_m = [slice(1, -1)] * nd
    idx_p[axis] = slice(2, None)
    idx_m[axis] = slice(None, -2)
    return x[tuple(idx_p)], x[tuple(idx_m)]


def _q_spatial(c: Compressed) -> jax.Array:
    """Stage-③ integers in the original spatial shape (cropped)."""
    comp = _comp(c)
    return comp.decompress(c, Stage.Q)


def _require_stencil_stage(c: Compressed, stage: Stage) -> None:
    if stage == Stage.M:
        raise UnsupportedStageError("stencils need pointwise info")
    if stage == Stage.P and not c.scheme.is_nd:
        # paper §V-B: 1-D partitioning destroys multidimensional layout
        raise UnsupportedStageError("stage-2 stencils require nd schemes")


def _lorenzo_axis_diff(p: jax.Array, axis: int) -> jax.Array:
    """D_a = q - shift_a(q) computed from residuals: cumsum over all axes != a."""
    out = p
    for a in range(p.ndim):
        if a != axis:
            out = jnp.cumsum(out, axis=a)
    return out


# The stencil *kernels* below take an already-windowed spatial array (full
# field cropped to shape, or a region window) so the full-field and region
# paths share one implementation — a sign/scale/convention fix lands in both
# by construction.

def _central_diff(x: jax.Array, axis: int, scale) -> jax.Array:
    """(x_{+1} - x_{-1}) * scale on the common interior (V-B.2)."""
    hi, lo = _shift_pair(x, axis)
    return (hi - lo).astype(jnp.float32) * scale


def _lorenzo_deriv_stencil(d: jax.Array, axis: int) -> jax.Array:
    """q_{+1} - q_{-1} = D_a[i+1] + D_a[i] on the interior (V-B.1), with
    ``d`` the (windowed) Lorenzo axis difference."""
    sl_hi = [slice(1, -1)] * d.ndim
    sl_hi[axis] = slice(2, None)
    sl_lo = [slice(1, -1)] * d.ndim
    sl_lo[axis] = slice(1, -1)
    return (d[tuple(sl_hi)] + d[tuple(sl_lo)]).astype(jnp.float32)


def _lorenzo_lap_term(d: jax.Array, axis: int) -> jax.Array:
    """D_a[i+1] - D_a[i] on the interior — one axis term of V-B.3."""
    sl_hi = [slice(1, -1)] * d.ndim
    sl_hi[axis] = slice(2, None)
    sl_lo = [slice(1, -1)] * d.ndim
    sl_lo[axis] = slice(1, -1)
    return d[tuple(sl_hi)] - d[tuple(sl_lo)]


def _laplacian_stencil(x: jax.Array) -> jax.Array:
    """Sum of neighbors minus 2·nd·center on the common interior, f32."""
    acc = -2.0 * x.ndim * _interior(x).astype(jnp.float32)
    for a in range(x.ndim):
        hi, lo = _shift_pair(x, a)
        acc = acc + hi.astype(jnp.float32) + lo.astype(jnp.float32)
    return acc


def _blockmean_deriv_p(p: jax.Array, m: jax.Array, axis: int) -> jax.Array:
    """(p_{+1} - p_{-1}) + (m_{+1} - m_{-1}): V-B §② with the border Delta
    terms realized as a shifted upsampled-mean difference."""
    p_hi, p_lo = _shift_pair(p, axis)
    m_hi, m_lo = _shift_pair(m, axis)
    return ((p_hi - p_lo) + (m_hi - m_lo)).astype(jnp.float32)


def derivative(c: Compressed | Encoded, stage: Stage, axis: int,
               *, region: Optional[R.RegionSpec] = None) -> jax.Array:
    """Central difference along ``axis`` on the common interior (III-B.2)."""
    if region is not None:
        return _region_derivative(c, Stage(stage), axis, region)
    c = _decode(c)
    _require_stencil_stage(c, stage)
    eps = c.eps

    if stage == Stage.P:
        if c.scheme.is_lorenzo:
            d = blocking.crop(_lorenzo_axis_diff(c.residuals, axis), c.shape)
            return _lorenzo_deriv_stencil(d, axis) * eps
        m = blocking.upsample_block_means(c.metadata, c.block)
        return _blockmean_deriv_p(blocking.crop(c.residuals, c.shape),
                                  blocking.crop(m, c.shape), axis) * eps

    if stage == Stage.Q:
        return _central_diff(_q_spatial(c), axis, eps)
    return _central_diff(_comp(c).decompress(c, Stage.F), axis, 0.5)


def gradient(c: Compressed | Encoded, stage: Stage,
             *, region: Optional[R.RegionSpec] = None) -> tuple[jax.Array, ...]:
    nd = len(c.shape)
    return tuple(derivative(c, stage, a, region=region) for a in range(nd))


def laplacian(c: Compressed | Encoded, stage: Stage,
              *, region: Optional[R.RegionSpec] = None) -> jax.Array:
    """2nd-order Laplacian stencil on the common interior (III-B.3)."""
    if region is not None:
        return _region_laplacian(c, Stage(stage), region)
    c = _decode(c)
    _require_stencil_stage(c, stage)
    eps2 = 2.0 * c.eps

    if stage == Stage.P:
        if c.scheme.is_lorenzo:
            # sum_a (D_a[+1] - D_a[0]) — paper Eq. V-B.3 generalized to n-D
            total = None
            for a in range(c.residuals.ndim):
                d = blocking.crop(_lorenzo_axis_diff(c.residuals, a), c.shape)
                term = _lorenzo_lap_term(d, a)
                total = term if total is None else total + term
            return total.astype(jnp.float32) * eps2
        m = blocking.crop(blocking.upsample_block_means(c.metadata, c.block), c.shape)
        p = blocking.crop(c.residuals, c.shape)
        return (_laplacian_stencil(p) + _laplacian_stencil(m)) * eps2

    if stage == Stage.Q:
        return _laplacian_stencil(_q_spatial(c)) * eps2  # (V-B.4)
    return _laplacian_stencil(_comp(c).decompress(c, Stage.F))


# ===========================================================================
# multivariate derivation (paper §V-C)
# ===========================================================================

def divergence(components: Sequence[Compressed | Encoded], stage: Stage,
               *, region: Optional[R.RegionSpec] = None) -> jax.Array:
    """div F = sum_a  d(F_a)/d(x_a)  on the common interior (V-C.1/2)."""
    total = None
    for axis, comp in enumerate(components):
        term = derivative(comp, stage, axis, region=region)
        total = term if total is None else total + term
    return total


def curl(components: Sequence[Compressed | Encoded], stage: Stage,
         *, region: Optional[R.RegionSpec] = None):
    """2-D: scalar dv/dx - du/dy (paper V-C.3 with (x,y)=(axis0,axis1));
    3-D: the full vector curl.  Pinned by the rigid-rotation oracle
    (u=-y, v=x has curl exactly +2) in ``tests/test_oracle_fields.py``."""
    if len(components) == 2:
        u, v = components
        return (derivative(v, stage, 0, region=region)
                - derivative(u, stage, 1, region=region))
    u, v, w = components
    return (
        derivative(w, stage, 1, region=region) - derivative(v, stage, 2, region=region),
        derivative(u, stage, 2, region=region) - derivative(w, stage, 0, region=region),
        derivative(v, stage, 0, region=region) - derivative(u, stage, 1, region=region),
    )


# ===========================================================================
# region paths (block-sparse sub-field queries, DESIGN.md §5)
# ===========================================================================

def _region_sub(c: Compressed | Encoded, op: str, stage: Stage,
                region: R.RegionSpec, axis: int = 0):
    """(plan, gathered sub-field) for an op's dependency closure."""
    plan = R.plan_region(c, region, R.op_closure(c.scheme, op, stage, axis))
    return plan, R.extract(c, plan)


def _region_mean(c: Compressed | Encoded, stage: Stage,
                 region: R.RegionSpec) -> jax.Array:
    if stage == Stage.M:
        # metadata-only: no payload decode at all — but partial-block windows
        # would weight block means by fractional coverage, voiding the eps
        # bias bound (§V-D.1), so stage ① requires a block-aligned window.
        if not c.scheme.is_blockmean:
            raise UnsupportedStageError("stage-1 mean needs HSZx-family metadata")
        plan = R.plan_region(c, region, "cover")
        if not plan.aligned:
            raise UnsupportedStageError(
                "stage-1 region mean needs a block-aligned window "
                f"(region {plan.region} vs block {c.block})")
        meta = plan.gather_metadata(c)
        s = jnp.sum(meta.reshape(-1) * jnp.asarray(plan.overlap))
        return s / plan.n_window * c.eps * 2.0

    plan, sub = _region_sub(c, "mean", stage, region)
    n = plan.n_window
    if stage == Stage.P:
        if c.scheme.is_blockmean:
            # sum q over window = sum p over window + sum_b M_b * overlap_b
            sp = jnp.sum(plan.window_of(sub.residuals))
            sm = jnp.sum(sub.metadata.reshape(-1) * jnp.asarray(plan.overlap))
            return (sp + sm) / n * c.eps * 2.0
        # Lorenzo: window-sum weights over the prefix hull generalize the
        # full-field rank-1 contraction (window == field recovers it exactly)
        weights = plan.lorenzo_mean_weights()
        acc = sub.residuals.astype(jnp.float32)
        if c.scheme.is_nd:
            for w in weights:
                acc = jnp.tensordot(acc, jnp.asarray(w), axes=[[0], [0]])
        else:
            acc = jnp.dot(acc.reshape(-1), jnp.asarray(weights[0]))
        return acc / n * c.eps * 2.0

    q_win = plan.window_of(_comp(c).reconstruct_q(sub))
    if stage == Stage.Q:
        return jnp.mean(q_win.astype(jnp.float32)) * c.eps * 2.0
    return jnp.mean(quantize.dequantize(q_win, c.eps, c.orig_dtype)
                    .astype(jnp.float32))


def _region_std(c: Compressed | Encoded, stage: Stage,
                region: R.RegionSpec) -> jax.Array:
    plan, sub = _region_sub(c, "std", stage, region)
    n = plan.n_window
    if stage == Stage.P and c.scheme.is_blockmean:
        # window analogue of the integer-mean decomposition (V-A §②).  Unlike
        # the full-field path, the window's residual sum is NOT near zero (a
        # partial block can contribute a one-sided slice of its residuals),
        # so the true window mean sum includes it: the correction r is then
        # exact and the decomposition stays integer-accurate.
        s = jnp.sum(sub.metadata.reshape(-1) * jnp.asarray(plan.overlap))
        sp = jnp.sum(plan.window_of(sub.residuals))
        tot = s + sp  # exact integer sum of q over the window
        mu_int = jnp.round(tot / n).astype(jnp.int32)
        mdiff = blocking.upsample_block_means(sub.metadata - mu_int, c.block)
        x = plan.window_of(sub.residuals + mdiff).astype(jnp.float32)
        ss = jnp.sum(x * x)
        r = tot / n - mu_int
        ss = ss - 2.0 * r * jnp.sum(x) + n * r * r
        return jnp.sqrt(jnp.maximum(ss, 0.0) / (n - 1)) * c.eps * 2.0
    if stage == Stage.P:
        q = sub.residuals
        for a in range(q.ndim):
            q = jnp.cumsum(q, axis=a)
        qf = plan.window_of(q).astype(jnp.float32)
        s1, s2 = jnp.sum(qf), jnp.sum(qf * qf)
        var = (s2 - s1 * s1 / n) / (n - 1)
        return jnp.sqrt(jnp.maximum(var, 0.0)) * c.eps * 2.0
    q_win = plan.window_of(_comp(c).reconstruct_q(sub))
    if stage == Stage.Q:
        qf = q_win.astype(jnp.float32)
        s1, s2 = jnp.sum(qf), jnp.sum(qf * qf)
        var = (s2 - s1 * s1 / n) / (n - 1)
        return jnp.sqrt(jnp.maximum(var, 0.0)) * c.eps * 2.0
    d = quantize.dequantize(q_win, c.eps, c.orig_dtype).astype(jnp.float32)
    return jnp.std(d, ddof=1)


def _region_derivative(c: Compressed | Encoded, stage: Stage, axis: int,
                       region: R.RegionSpec) -> jax.Array:
    _require_stencil_stage(c, stage)
    plan, sub = _region_sub(c, "derivative", stage, region, axis)
    eps = c.eps
    if stage == Stage.P:
        if c.scheme.is_lorenzo:
            # band closure: the axis difference needs prefix sums only over
            # the non-derivative axes, which the sub-field anchors at origin
            d = plan.window_of(_lorenzo_axis_diff(sub.residuals, axis))
            return _lorenzo_deriv_stencil(d, axis) * eps
        m = blocking.upsample_block_means(sub.metadata, c.block)
        return _blockmean_deriv_p(plan.window_of(sub.residuals),
                                  plan.window_of(m), axis) * eps
    q_win = plan.window_of(_comp(c).reconstruct_q(sub))
    if stage == Stage.Q:
        return _central_diff(q_win, axis, eps)
    return _central_diff(quantize.dequantize(q_win, c.eps, c.orig_dtype),
                         axis, 0.5)


def _region_laplacian(c: Compressed | Encoded, stage: Stage,
                      region: R.RegionSpec) -> jax.Array:
    _require_stencil_stage(c, stage)
    plan, sub = _region_sub(c, "laplacian", stage, region)
    eps2 = 2.0 * c.eps
    if stage == Stage.P:
        if c.scheme.is_lorenzo:
            total = None
            for a in range(sub.residuals.ndim):
                d = plan.window_of(_lorenzo_axis_diff(sub.residuals, a))
                term = _lorenzo_lap_term(d, a)
                total = term if total is None else total + term
            return total.astype(jnp.float32) * eps2
        m = plan.window_of(blocking.upsample_block_means(sub.metadata, c.block))
        p = plan.window_of(sub.residuals)
        return (_laplacian_stencil(p) + _laplacian_stencil(m)) * eps2
    q_win = plan.window_of(_comp(c).reconstruct_q(sub))
    if stage == Stage.Q:
        return _laplacian_stencil(q_win) * eps2
    return _laplacian_stencil(quantize.dequantize(q_win, c.eps, c.orig_dtype))
