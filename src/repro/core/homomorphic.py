"""Homomorphic analytical operations on intermediate representations (paper §V).

Seven operations, three categories:

* statistics — ``mean`` (stages ①②③④, ① HSZx-family only), ``std`` (②③④);
* numerical differentiation — ``derivative``, ``gradient``, ``laplacian``
  (② nd-schemes, ③④ all);
* multivariate derivation — ``divergence``, ``curl`` (same stage support).

Every operation is a thin wrapper over :mod:`repro.core.oplib`: a declarative
:class:`~repro.core.oplib.OpSpec` names the op's per-``(scheme, stage)``
lowering rule, and one shared :class:`~repro.core.oplib.StageContext`
prelude — payload decode, cumsum / block-mean-upsample recorrelation, window
cropping — feeds any number of op postludes.  :func:`compute` exposes the
fused entry point directly: ``compute(c, ["mean", "std"], stage)`` pays one
stage reconstruction for the whole op set, and each value is bit-identical
to the corresponding single-op call.

TPU adaptation (DESIGN.md §3): the paper's scalar accumulators become
parallel prefix sums (`jnp.cumsum`), its per-block border branches become
shifted-upsampled block-mean difference arrays, and the HSZp-2d weighted-sum
mean becomes a rank-1 bilinear form ``w0ᵀ P w1`` (two matvecs — MXU work
instead of a data-sized reduction tree).

All stencil operators return the *common interior* of the field (every axis
cropped by one at each end), matching the reference operators in
``repro.kernels.ref`` exactly.

Every operation additionally accepts ``region=`` (per-axis ``(start, stop)``
or ``slice`` over the original shape): the op then touches only the blocks
in the region's dependency closure (``repro.core.region``, DESIGN.md §5) and
returns exactly what the full-field op would return on the cropped
decompressed window — statistics over the window values, stencils on the
window interior.  The full-field path *is* the region path with
``region=None``; there are no duplicate implementations.
"""
from __future__ import annotations
from collections.abc import Sequence

import jax

from . import oplib
from . import region as R
from .stages import Compressed, Encoded, Stage

Field = Compressed | Encoded

#: fused lowering entry point (see :func:`repro.core.oplib.compute`).
compute = oplib.compute


def mean(c: Field, stage: Stage,
         *, region: R.RegionSpec | None = None) -> jax.Array:
    """Field mean at a given decompression stage (optionally over a region)."""
    return oplib.compute(c, "mean", stage, region=region)["mean"]


def std(c: Field, stage: Stage,
        *, region: R.RegionSpec | None = None) -> jax.Array:
    """Sample standard deviation at a given stage (paper §V-A.2)."""
    return oplib.compute(c, "std", stage, region=region)["std"]


def derivative(c: Field, stage: Stage, axis: int,
               *, region: R.RegionSpec | None = None) -> jax.Array:
    """Central difference along ``axis`` on the common interior (III-B.2)."""
    return oplib.compute(c, "derivative", stage, axis=axis,
                         region=region)["derivative"]


def gradient(c: Field, stage: Stage,
             *, region: R.RegionSpec | None = None) -> tuple:
    """All-axis central differences sharing one stage reconstruction."""
    return oplib.compute(c, "gradient", stage, region=region)["gradient"]


def laplacian(c: Field, stage: Stage,
              *, region: R.RegionSpec | None = None) -> jax.Array:
    """2nd-order Laplacian stencil on the common interior (III-B.3)."""
    return oplib.compute(c, "laplacian", stage, region=region)["laplacian"]


def divergence(components: Sequence[Field], stage: Stage,
               *, region: R.RegionSpec | None = None) -> jax.Array:
    """div F = sum_a  d(F_a)/d(x_a)  on the common interior (V-C.1/2)."""
    return oplib.compute(list(components), "divergence", stage,
                         region=region)["divergence"]


def curl(components: Sequence[Field], stage: Stage,
         *, region: R.RegionSpec | None = None):
    """2-D: scalar dv/dx - du/dy (paper V-C.3 with (x,y)=(axis0,axis1));
    3-D: the full vector curl.  Pinned by the rigid-rotation oracle
    (u=-y, v=x has curl exactly +2) in ``tests/test_oracle_fields.py``."""
    return oplib.compute(list(components), "curl", stage,
                         region=region)["curl"]
