"""Shard-mapped execution programs: word-merge region decode and
homomorphic temporal-summary all-reduce.

Two program families, both built on one invariant — every cross-shard
combination is an **exact associative integer merge**, so the sharded
result is bit-identical to the single-device path by construction, never
by tolerance:

* **Word merge** (:meth:`ShardPrograms.region_compute`): a region query's
  :class:`~repro.core.region.RegionPlan` names the exact payload words the
  single-device path gathers (``payload_gather``).  Each word is owned by
  exactly one shard (:meth:`~repro.shard.placement.BlockPlacement.word_owner`
  — words are never split), so each shard reads its owned words from its
  *local* payload stripe, scatter-adds them into the gathered-word layout,
  and a ``psum`` over the shard axis reassembles exactly
  ``payload[word_idx]``.  From there the op set lowers through the very
  same ``unpack -> unzigzag -> assemble -> postlude`` sequence as
  ``encode.decode_region`` (``oplib.compute(payload_words=...)``), inside
  the shard-mapped program — the Pallas kernel backend composes here
  unchanged, and kernel mode stays in the program cache key via
  ``oplib.kernel_sig()``.

* **Summary merge** (:meth:`ShardPrograms.merge_band_summaries`):
  per-band partial :class:`~repro.core.oplib.TemporalSummary` leaves are
  all int32 with modular sums, so spatial reassembly is a disjoint scatter
  followed by ``psum`` / ``pmin`` / ``pmax`` — the same homomorphic
  all-reduce shape as ``comm.hom_collectives``, and associative in any
  order.  A summary's per-position leaves depend only on the q integers at
  that position (stage reconstruction is exact), so band partials scattered
  into the window equal the full-window summary bit for bit.

Programs cache in an ``_jitted`` OrderedDict keyed exactly like the
analytics engine's (layout, static geometry, placement/mesh signatures,
kernel mode) — audited by ``repro.audit`` jit-key analysis.
"""
from __future__ import annotations

import dataclasses
from collections import OrderedDict
from collections.abc import Sequence

import numpy as np
import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro import compat
from repro.core import Encoded, Stage, layout_key, oplib
from repro.core import encode as encode_mod
from repro.core import region as region_mod
from repro.launch.mesh import SHARD_AXIS
from repro.shard.placement import BlockPlacement

_INT32_MAX = np.int32(np.iinfo(np.int32).max)
_INT32_MIN = np.int32(np.iinfo(np.int32).min)


def mesh_sig(mesh) -> tuple:
    """Hashable mesh identity (program cache key component)."""
    return (tuple(mesh.axis_names),
            tuple(int(d.id) for d in mesh.devices.flat))


def gather_routing(n_shards: int, placement: BlockPlacement, bits: int,
                   word_idx: np.ndarray) -> tuple[np.ndarray, np.ndarray]:
    """Per-shard (stripe-local source, gathered-set destination) index
    arrays for merging ``word_idx``.  Padding rows scatter into the
    dropped slot ``len(word_idx)``.

    Module-level (mesh-free) so the static audit's ``sharddisjoint``
    analyzer can prove scatter-target disjointness for world sizes the
    host has no devices for; :class:`ShardPrograms` delegates here.
    """
    word_idx = np.asarray(word_idx, dtype=np.int64)
    n_out = len(word_idx)
    owners = placement.word_owner(bits)[word_idx] if n_out else \
        np.zeros((0,), np.int32)
    stripes = placement.shard_word_index(bits)
    per_shard = []
    g_max = 1
    for s in range(n_shards):
        sel = np.nonzero(owners == s)[0]
        src = np.searchsorted(stripes[s], word_idx[sel])
        per_shard.append((src, sel))
        g_max = max(g_max, len(sel))
    src_arr = np.zeros((n_shards, g_max), np.int32)
    dst_arr = np.full((n_shards, g_max), n_out, np.int32)
    for s, (src, sel) in enumerate(per_shard):
        src_arr[s, :len(src)] = src
        dst_arr[s, :len(sel)] = sel
    return src_arr, dst_arr


class ShardPrograms:
    """Compiled ``shard_map`` programs for one analytics mesh.

    Host-static routing (which words / bands belong to which shard) is
    derived from a :class:`BlockPlacement`; the traced programs see only
    uniformly-shaped per-shard arrays, so every shard runs the same SPMD
    program and only the data differs.
    """

    def __init__(self, mesh, *, cache_limit: int = 128):
        self.mesh = mesh
        self.n_shards = int(mesh.devices.size)
        self._jitted: OrderedDict = OrderedDict()
        self._limit = int(cache_limit)

    def _cache_put(self, key, fn):
        self._jitted[key] = fn
        while len(self._jitted) > self._limit:
            self._jitted.popitem(last=False)

    # -- payload striping ---------------------------------------------------
    def shard_payload(self, e: Encoded, placement: BlockPlacement) -> jax.Array:
        """Split a field's payload into per-shard word stripes.

        Returns a ``[n_shards, w_max]`` uint32 array sharded over the mesh's
        shard axis — row ``s`` holds shard ``s``'s owned words (ascending
        global order, zero-padded).  Built once when a field enters the
        sharded store; every query reads from these stripes only.
        """
        self._check(placement)
        idx = placement.shard_word_index(e.bits)
        w_max = max(max((len(i) for i in idx), default=0), 1)
        out = np.zeros((self.n_shards, w_max), np.uint32)
        pay = np.asarray(jax.device_get(e.payload))
        for s, i in enumerate(idx):
            out[s, :len(i)] = pay[i]
        return jax.device_put(
            out, NamedSharding(self.mesh, P(SHARD_AXIS)))

    def _check(self, placement: BlockPlacement):
        if placement.n_shards != self.n_shards:
            raise ValueError(
                f"placement has {placement.n_shards} shards but the mesh "
                f"has {self.n_shards} devices")

    def _gather_routing(self, placement: BlockPlacement, bits: int,
                        word_idx: np.ndarray) -> tuple[np.ndarray, np.ndarray]:
        return gather_routing(self.n_shards, placement, bits, word_idx)

    # -- region / full-field op execution -----------------------------------
    def region_compute(self, target, ops, stage: Stage, *, axis: int = 0,
                       region=None, placements=None, stripes=None) -> dict:
        """Lower an op set over shard-striped payload(s), bit-identically.

        ``target`` is one :class:`Encoded` field (field-arity op sets) or a
        sequence of component fields (vector sets); ``placements`` /
        ``stripes`` follow the same arity (``stripes=None`` re-stripes on
        the fly — the store passes its resident stripes).  Returns the same
        ``{op: value}`` dict as :func:`repro.core.oplib.compute`.
        """
        stage = Stage(stage)
        names = oplib.canonical_ops(ops)
        vector = oplib.is_vector_ops(names)
        comps = list(target) if vector else [target]
        for c in comps:
            if not isinstance(c, Encoded):
                raise TypeError(
                    "sharded execution requires Encoded fields (the payload "
                    f"is what is striped); got {type(c).__name__}")
        if placements is None:
            placements = [BlockPlacement.of(c, self.n_shards) for c in comps]
        placements = list(placements) if vector else \
            ([placements] if isinstance(placements, BlockPlacement)
             else list(placements))
        for p in placements:
            self._check(p)
        if stripes is None:
            stripes = [self.shard_payload(c, p)
                       for c, p in zip(comps, placements)]
        else:
            stripes = list(stripes) if vector else (
                [stripes] if not isinstance(stripes, (list, tuple))
                else list(stripes))

        # host-static routing: the exact words the single-device gather reads
        norm = (region_mod.normalize_region(region, comps[0].shape)
                if region is not None else None)
        if vector:
            closures = oplib.component_closures(
                names, [c.scheme for c in comps], stage)
        else:
            closures = [oplib.set_closure(names, comps[0].scheme, stage, axis)]
        routing = []
        for c, p, cl in zip(comps, placements, closures):
            if norm is not None:
                plan = region_mod.plan_region(c, norm, cl)
                word_idx = np.asarray(plan.payload_gather(c.bits).word_idx)
            else:
                word_idx = np.arange(
                    encode_mod.words_for(
                        int(np.prod(c.padded_shape, dtype=np.int64)), c.bits),
                    dtype=np.int64)
            routing.append(self._gather_routing(p, c.bits, word_idx)
                           + (len(word_idx),))

        key = (tuple(layout_key(c) for c in comps), names, stage, axis, norm,
               tuple(p.sig() for p in placements), mesh_sig(self.mesh),
               oplib.kernel_sig(), tuple(r[2] for r in routing),
               tuple(s.shape for s in stripes))
        fn = self._jitted.get(key)
        if fn is None:
            n_outs = tuple(r[2] for r in routing)

            def body(ecs, strs, srcs, dsts, _names=names, _stage=stage,
                     _axis=axis, _norm=norm, _n=n_outs, _vec=vector):
                merged = []
                for ec, st, sr, ds, n_out in zip(ecs, strs, srcs, dsts, _n):
                    vals = st[0][sr[0]]
                    buf = jnp.zeros((n_out + 1,), jnp.uint32).at[ds[0]].add(vals)
                    merged.append(jax.lax.psum(buf[:n_out], SHARD_AXIS))
                if _norm is None:
                    # full field: the merge reassembles the entire payload
                    # exactly, so the standard full decode runs unchanged
                    full = tuple(dataclasses.replace(ec, payload=m)
                                 for ec, m in zip(ecs, merged))
                    tgt = full if _vec else full[0]
                    return oplib.compute(tgt, _names, _stage, axis=_axis)
                tgt = tuple(ecs) if _vec else ecs[0]
                words = merged if _vec else merged[0]
                return oplib.compute(tgt, _names, _stage, axis=_axis,
                                     region=_norm, payload_words=words)

            fn = jax.jit(compat.shard_map(
                body, mesh=self.mesh,
                in_specs=(P(), P(SHARD_AXIS), P(SHARD_AXIS), P(SHARD_AXIS)),
                out_specs=P(), check=False))
            self._cache_put(key, fn)
        else:
            self._jitted.move_to_end(key)

        stripped = tuple(
            dataclasses.replace(c, payload=jnp.zeros((0,), jnp.uint32))
            for c in comps)
        srcs = tuple(jnp.asarray(r[0]) for r in routing)
        dsts = tuple(jnp.asarray(r[1]) for r in routing)
        return fn(stripped, tuple(stripes), srcs, dsts)

    # -- integer stage materialization ---------------------------------------
    def materialize(self, e: Encoded, stage: Stage, *, region=None,
                    closure="cover", placement: BlockPlacement | None = None,
                    stripes=None):
        """Stage-②/③ *integer* intermediate from shard-striped payload.

        Returns what ``oplib.StageContext`` keeps resident at the storage
        stage — the decoded ``sub`` container (stage ②) or the recorrelated
        ``q_spatial`` integers (stage ③) — computed from the psum-merged
        owned words inside one shard-mapped program.  Every array in either
        intermediate is int32, and integer reconstruction is exact under
        any compilation, so the result is bit-identical to the
        single-device ``repro.store.materialize`` — which is exactly what
        lets the sharded store seed the engine's standard (vmapped, jitted)
        float postludes and inherit the store's seeded == unseeded
        bit-identity guarantee.  The full-field stage-② path runs
        ``encode.decode_device`` on the merged payload, i.e. the Pallas
        bitplane-unpack kernel when kernels are enabled — the kernel
        backend composes inside the shard-mapped program, and kernel mode
        stays in the program key (``oplib.kernel_sig()``).
        """
        stage = Stage(stage)
        if stage not in (Stage.P, Stage.Q):
            raise ValueError(
                f"materializations are stage-② or -③ intermediates, got {stage}")
        if not isinstance(e, Encoded):
            raise TypeError("sharded materialization requires an Encoded field")
        if placement is None:
            placement = BlockPlacement.of(e, self.n_shards)
        self._check(placement)
        if stripes is None:
            stripes = self.shard_payload(e, placement)
        norm = (region_mod.normalize_region(region, e.shape)
                if region is not None else None)
        closure = region_mod.canonical_closure(e.scheme, closure, norm)
        if norm is not None:
            plan = region_mod.plan_region(e, norm, closure)
            word_idx = np.asarray(plan.payload_gather(e.bits).word_idx)
        else:
            word_idx = np.arange(
                encode_mod.words_for(
                    int(np.prod(e.padded_shape, dtype=np.int64)), e.bits),
                dtype=np.int64)
        src, dst = self._gather_routing(placement, e.bits, word_idx)
        n_out = len(word_idx)

        key = ("__shard_materialize__", layout_key(e), stage, norm, closure,
               placement.sig(), mesh_sig(self.mesh), oplib.kernel_sig(),
               n_out, tuple(stripes.shape))
        fn = self._jitted.get(key)
        if fn is None:
            def body(ec, st, sr, ds, _stage=stage, _norm=norm, _cl=closure,
                     _n=n_out):
                vals = st[0][sr[0]]
                buf = jnp.zeros((_n + 1,), jnp.uint32).at[ds[0]].add(vals)
                merged = jax.lax.psum(buf[:_n], SHARD_AXIS)
                if _norm is None:
                    full = dataclasses.replace(ec, payload=merged)
                    ctx = oplib.StageContext(full, _stage, None, _cl)
                else:
                    ctx = oplib.StageContext(ec, _stage, _norm, _cl,
                                             words=merged)
                return ctx.sub if _stage == Stage.P else ctx.q_spatial

            fn = jax.jit(compat.shard_map(
                body, mesh=self.mesh,
                in_specs=(P(), P(SHARD_AXIS), P(SHARD_AXIS), P(SHARD_AXIS)),
                out_specs=P(), check=False))
            self._cache_put(key, fn)
        else:
            self._jitted.move_to_end(key)

        stripped = dataclasses.replace(
            e, payload=jnp.zeros((0,), jnp.uint32))
        return fn(stripped, stripes, jnp.asarray(src), jnp.asarray(dst))

    # -- temporal summary merge ---------------------------------------------
    def merge_band_summaries(self, bands, win_rows: int,
                             rest: tuple[int, ...]):
        """Homomorphic all-reduce of per-band partial summaries.

        ``bands`` is a list of ``(owner_shard, row0, summary)`` where each
        summary covers rows ``[row0, row0 + rows)`` of a ``(win_rows,
        *rest)`` spatial window (leaves WITHOUT a batch axis).  Each shard
        scatters its bands into the window layout with merge-neutral
        padding (0 for modular sums and ``last2``, INT32_MAX/MIN for
        min/max) and a ``psum``/``pmin``/``pmax`` over the shard axis
        reassembles the full-window summary — int32-exact, so bit-identical
        to summarizing the whole window at once.
        """
        by_shard: list[list] = [[] for _ in range(self.n_shards)]
        for owner, row0, summ in bands:
            by_shard[int(owner) % self.n_shards].append((int(row0), summ))
        b_max = max(max((len(g) for g in by_shard), default=0), 1)
        r_max = max((int(s.q_sum.shape[0]) for _, _, s in bands), default=1)

        def stacked(leaf, neutral, lead=()):
            # [n_shards, b_max, *lead, r_max, *rest] with neutral padding
            full = jnp.full((*lead, r_max, *rest), neutral, jnp.int32)
            rows = []
            for g in by_shard:
                slots = []
                for _, s in g:
                    x = leaf(s)
                    pad = [(0, 0)] * len(lead) + \
                        [(0, r_max - x.shape[len(lead)])] + \
                        [(0, 0)] * len(rest)
                    slots.append(jnp.pad(x, pad, constant_values=neutral))
                slots += [full] * (b_max - len(slots))
                rows.append(jnp.stack(slots))
            return jnp.stack(rows)

        q_sum = stacked(lambda s: s.q_sum, 0)
        q_sumsq = stacked(lambda s: s.q_sumsq, 0)
        q_min = stacked(lambda s: s.q_min, _INT32_MAX)
        q_max = stacked(lambda s: s.q_max, _INT32_MIN)
        last2 = stacked(lambda s: s.last2, 0, lead=(2,))
        count = jnp.stack([
            jnp.stack([s.count for _, s in g] +
                      [jnp.zeros((), jnp.int32)] * (b_max - len(g)))
            for g in by_shard])
        offs = np.zeros((self.n_shards, b_max), np.int32)
        nrows = np.zeros((self.n_shards, b_max), np.int32)
        for s, g in enumerate(by_shard):
            for b, (row0, summ) in enumerate(g):
                offs[s, b] = row0
                nrows[s, b] = int(summ.q_sum.shape[0])

        key = ("__shard_summary_merge__", self.n_shards, b_max, r_max,
               win_rows, rest, mesh_sig(self.mesh))
        fn = self._jitted.get(key)
        if fn is None:
            def body(qs, qq, qn, qx, l2, ct, of, nr, _b=b_max, _r=r_max,
                     _w=win_rows, _rest=rest):
                sbuf = jnp.zeros((_w + 1, *_rest), jnp.int32)
                qbuf = jnp.zeros((_w + 1, *_rest), jnp.int32)
                nbuf = jnp.full((_w + 1, *_rest), _INT32_MAX, jnp.int32)
                xbuf = jnp.full((_w + 1, *_rest), _INT32_MIN, jnp.int32)
                lbuf = jnp.zeros((2, _w + 1, *_rest), jnp.int32)
                r = jnp.arange(_r)
                okx_shape = (_r,) + (1,) * len(_rest)
                for b in range(_b):
                    ok = r < nr[0, b]
                    idx = jnp.where(ok, of[0, b] + r, _w)
                    okx = ok.reshape(okx_shape)
                    sbuf = sbuf.at[idx].add(jnp.where(okx, qs[0, b], 0))
                    qbuf = qbuf.at[idx].add(jnp.where(okx, qq[0, b], 0))
                    nbuf = nbuf.at[idx].min(
                        jnp.where(okx, qn[0, b], _INT32_MAX))
                    xbuf = xbuf.at[idx].max(
                        jnp.where(okx, qx[0, b], _INT32_MIN))
                    lbuf = lbuf.at[:, idx].add(
                        jnp.where(okx[None], l2[0, b], 0))
                return oplib.TemporalSummary(
                    count=jax.lax.pmax(jnp.max(ct[0]), SHARD_AXIS),
                    q_sum=jax.lax.psum(sbuf[:_w], SHARD_AXIS),
                    q_sumsq=jax.lax.psum(qbuf[:_w], SHARD_AXIS),
                    q_min=jax.lax.pmin(nbuf[:_w], SHARD_AXIS),
                    q_max=jax.lax.pmax(xbuf[:_w], SHARD_AXIS),
                    last2=jax.lax.psum(lbuf[:, :_w], SHARD_AXIS))

            fn = jax.jit(compat.shard_map(
                body, mesh=self.mesh,
                in_specs=(P(SHARD_AXIS),) * 8, out_specs=P(), check=False))
            self._cache_put(key, fn)
        else:
            self._jitted.move_to_end(key)
        return fn(q_sum, q_sumsq, q_min, q_max, last2, count,
                  jnp.asarray(offs), jnp.asarray(nrows))


def spatial_bands(field, placement: BlockPlacement, region=None
                  ) -> list[tuple[int, int, int, tuple]]:
    """Owner-assigned spatial bands of a slab field's query window.

    Returns ``(owner, row0_in_window, unit_row0, band_region)`` per band,
    where ``band_region`` is the spatial sub-window the owning shard
    summarizes (rows of spatial axis 0, full extent elsewhere).  nd slab
    layouts band by the compressor's block-rows along slab axis 1 — exactly
    the placement's stripe units, so each band's q reconstruction is
    shard-local; flat layouts split the window into ``n_shards`` contiguous
    bands (block ownership interleaves timesteps there, so banding is a
    grouping heuristic — the merge stays exact either way).
    """
    spatial = field.shape[1:]
    win = (region_mod.normalize_region(region, spatial) if region is not None
           else tuple((0, s) for s in spatial))
    s0, e0 = win[0]
    rest = tuple(win[1:])
    bands = []
    if field.scheme.is_nd:
        h = field.block[1]
        for u in range(s0 // h, -(-e0 // h)):
            r0, r1 = max(s0, u * h), min(e0, (u + 1) * h)
            if r1 <= r0:
                continue
            bands.append((u % placement.n_shards, r0 - s0, r0,
                          ((r0, r1),) + rest))
    else:
        n = placement.n_shards
        h = max(1, -(-(e0 - s0) // n))
        for b in range(-(-(e0 - s0) // h)):
            r0, r1 = s0 + b * h, min(s0 + (b + 1) * h, e0)
            bands.append((b % n, r0 - s0, r0, ((r0, r1),) + rest))
    return bands
