"""Sharded field store: block-wise placement over the analytics mesh.

A :class:`ShardedFieldStore` holds one :class:`~repro.stream.StreamFieldStore`
**per shard** — each with its own byte budget, LRU order, and stats — over a
single shared field registry.  Every cache cell (a materialized stage or a
temporal summary) lives in exactly one shard's store, chosen by the cell's
*home shard* (the majority owner of its region's covering blocks,
:meth:`~repro.shard.placement.BlockPlacement.home`), so eviction pressure is
per-shard: a hot region on shard 3 never evicts shard 5's materializations.

Serving stays bit-identical to the single-device :class:`~repro.store
.FieldStore` by construction, not by tolerance:

* a cache miss materializes the cell's *integer* intermediate (stage-②
  ``sub`` / stage-③ ``q_spatial``) through the shard-mapped word-merge
  program (:meth:`~repro.shard.exec.ShardPrograms.materialize`) — integer
  reconstruction is exact under any compilation, so the intermediate equals
  the single-device ``repro.store.materialize`` bit for bit;
* queries then seed the analytics engine's **standard** jitted programs
  with that intermediate, inheriting the store layer's existing
  seeded == unseeded bit-identity guarantee (DESIGN.md §7) — the float
  postludes are literally the same compiled expressions;
* temporal summaries reduce shard-locally per block-row band and merge via
  ``psum``/``pmin``/``pmax`` (:meth:`~repro.shard.exec.ShardPrograms
  .merge_band_summaries`) — all-int32, associative, exact.

``retain_payload=False`` additionally drops the registered container's
payload (only the per-shard word stripes stay device-resident), unlocking
fields larger than one device's memory; the default keeps it, so op sets
the planner declines to seed (or cells over every budget) can still fall
back to the ordinary unseeded path.
"""
from __future__ import annotations

import dataclasses
from collections.abc import Iterable, Sequence
from functools import reduce

import numpy as np
import jax
import jax.numpy as jnp

from repro.core import Encoded, Stage, oplib
from repro.core import region as region_mod
from repro.core.oplib import TemporalSummary
from repro.store import FieldStore, MATERIALIZABLE, StoreStats
from repro.store.materialized import (MaterializedStage, materialized_nbytes,
                                      storage_stage)
from repro.stream import StreamFieldStore, TemporalField
from repro.stream.store import TEMPORAL_TAG

from .exec import ShardPrograms, spatial_bands
from .placement import BlockPlacement


class ShardedFieldStore:
    """Block-sharded analytics store over a ``("shard",)`` mesh.

    Duck-types the query/serve store surface (``get`` / ``seed`` /
    ``cached_stages`` / ``is_resident`` / ``stats`` / ``temporal_summary``
    / ``append`` / ...), so ``repro.analytics.query`` and the serve
    frontend use it unchanged.  ``cache_bytes_per_shard`` budgets each
    shard's LRU independently; ``mesh`` comes from
    :func:`repro.launch.mesh.make_analytics_mesh`.
    """

    def __init__(self, mesh, cache_bytes_per_shard: int = 256 << 20, *,
                 engine=None, cost_model=None, retain_payload: bool = True,
                 shard_axis: int = 0):
        self.mesh = mesh
        self.progs = ShardPrograms(mesh)
        self.n_shards = self.progs.n_shards
        self.cost_model = cost_model
        self.retain_payload = bool(retain_payload)
        self.shard_axis = int(shard_axis)
        self._fields: dict = {}
        self._shards = [StreamFieldStore(cache_bytes_per_shard,
                                         engine=engine, cost_model=cost_model)
                        for _ in range(self.n_shards)]
        for s in self._shards:
            s._fields = self._fields  # one registry, n_shards cache budgets
        self._placements: dict[str, BlockPlacement] = {}
        self._stripes: dict[str, jax.Array] = {}
        #: monotone counters of streaming refresh work (parent-level: the
        #: children only account bytes/LRU, never compute)
        self.incremental_merges = 0
        self.summary_rebuilds = 0

    @property
    def engine(self):
        return self._shards[0].engine

    # -- aggregated accounting ----------------------------------------------
    @property
    def stats(self) -> StoreStats:
        """Aggregate accounting across shards (fresh snapshot; per-shard
        figures live on ``shard_stats``)."""
        agg = StoreStats()
        for c in self._shards:
            agg.hits += c.stats.hits
            agg.misses += c.stats.misses
            agg.evictions += c.stats.evictions
            agg.rejected += c.stats.rejected
        return agg

    @property
    def shard_stats(self) -> tuple[StoreStats, ...]:
        return tuple(c.stats for c in self._shards)

    @property
    def cache_bytes_in_use(self) -> int:
        return sum(c.cache_bytes_in_use for c in self._shards)

    @property
    def cache_entries(self) -> int:
        return sum(c.cache_entries for c in self._shards)

    # -- field registry -----------------------------------------------------
    def put(self, field_id: str, field, *, replace: bool = False) -> str:
        """Register an :class:`Encoded` field, striping its payload words
        over the shard axis (placement is static layout math — see
        :class:`BlockPlacement`)."""
        if not isinstance(field_id, str) or not field_id:
            raise ValueError(
                f"field id must be a non-empty string, got {field_id!r}")
        if not isinstance(field, Encoded):
            raise TypeError(
                "the sharded store places packed payload words; encode the "
                f"field first (Encoded), got {type(field).__name__}")
        if field_id in self._fields:
            if not replace:
                raise ValueError(
                    f"field id {field_id!r} already registered "
                    "(pass replace=True to overwrite)")
            self.invalidate(field_id)
        placement = BlockPlacement.of(field, self.n_shards,
                                      axis=self.shard_axis)
        self._stripes[field_id] = self.progs.shard_payload(field, placement)
        self._placements[field_id] = placement
        if not self.retain_payload:
            field = dataclasses.replace(
                field, payload=jnp.zeros((0,), jnp.uint32))
        self._fields[field_id] = field
        return field_id

    def put_temporal(self, field_id: str, tf: TemporalField, *,
                     replace: bool = False) -> str:
        """Register an append-only temporal field; its summaries shard by
        block-rows of the first *spatial* axis (slab axis 1 — the time axis
        stays whole, so per-shard partial summaries merge exactly)."""
        if not isinstance(field_id, str) or not field_id:
            raise ValueError(
                f"field id must be a non-empty string, got {field_id!r}")
        if not isinstance(tf, TemporalField):
            raise TypeError(
                f"expected a TemporalField, got {type(tf).__name__}")
        if field_id in self._fields:
            if not replace:
                raise ValueError(
                    f"field id {field_id!r} already registered "
                    "(pass replace=True to overwrite)")
            self.invalidate(field_id)
        self._fields[field_id] = tf
        return field_id

    def get(self, field_id: str):
        try:
            return self._fields[field_id]
        except KeyError:
            raise KeyError(
                f"unknown field id {field_id!r}; registered ids: "
                f"{sorted(self._fields) or '(none)'}") from None

    def remove(self, field_id: str) -> None:
        self.get(field_id)
        self.invalidate(field_id)
        del self._fields[field_id]
        self._placements.pop(field_id, None)
        self._stripes.pop(field_id, None)

    def invalidate(self, field_id: str) -> int:
        """Drop every shard's materializations of ``field_id``."""
        return sum(c.invalidate(field_id) for c in self._shards)

    def __contains__(self, field_id: str) -> bool:
        return field_id in self._fields

    def __len__(self) -> int:
        return len(self._fields)

    def ids(self) -> tuple[str, ...]:
        return tuple(self._fields)

    def is_temporal(self, field_id: str) -> bool:
        return isinstance(self.get(field_id), TemporalField)

    def _temporal(self, field_id: str) -> TemporalField:
        tf = self.get(field_id)
        if not isinstance(tf, TemporalField):
            raise TypeError(
                f"field id {field_id!r} is not a temporal field; append() "
                "and temporal ops need a TemporalField (see put_temporal)")
        return tf

    # -- placement ----------------------------------------------------------
    def placement_of(self, field_id: str) -> BlockPlacement | None:
        """The id's placement (spatial fields; the planner's max-cost rule
        consumes this).  ``None`` for temporal ids — their cells are
        summaries, not stage decodes."""
        return self._placements.get(field_id)

    def _temporal_placement(self, field_id: str,
                            tf: TemporalField) -> BlockPlacement:
        pl = self._placements.get(field_id)
        if pl is None:
            if not tf.slabs:
                raise ValueError(
                    f"temporal field {field_id!r} has no appended slabs")
            pl = BlockPlacement.of(tf.slabs[0], self.n_shards, axis=1)
            self._placements[field_id] = pl
        return pl

    def shard_of(self, field_id: str, stage: Stage | None = None, *,
                 region=None, closure="cover") -> int:
        """Home shard of one cache cell (tests / ops introspection)."""
        field = self.get(field_id)
        if isinstance(field, TemporalField):
            norm = (region_mod.normalize_region(region, field.shape)
                    if region is not None else None)
            return self._temporal_home(field_id, field, norm)
        norm, cl = self._canonical(field, Stage(stage), region, closure)
        return self._home(field, norm, cl)

    def payload_accounting(self, field_id: str, ops, stage: Stage, *,
                           region, axis: int = 0) -> dict:
        """Per-shard payload bytes one region query touches (bench/CI gate
        input — see :meth:`BlockPlacement.payload_bytes`)."""
        field = self.get(field_id)
        names = oplib.canonical_ops(ops)
        cl = oplib.set_closure(names, field.scheme, Stage(stage), axis)
        norm, cl = self._canonical(field, Stage(stage), region, cl)
        plan = region_mod.plan_region(field, norm, cl)
        return self._placements[field_id].payload_bytes(plan, field.bits)

    # -- cell routing ---------------------------------------------------------
    def _canonical(self, field, stage: Stage, region, closure):
        norm = (region_mod.normalize_region(region, field.shape)
                if region is not None else None)
        return norm, region_mod.canonical_closure(field.scheme, closure, norm)

    def _home(self, field, norm, closure) -> int:
        placement = BlockPlacement.of(field, self.n_shards,
                                      axis=self.shard_axis)
        if norm is None:
            return placement.home(None)
        return placement.home(region_mod.plan_region(field, norm, closure))

    def _cell(self, field_id: str, stage: Stage, region, closure):
        field = self.get(field_id)
        norm, cl = self._canonical(field, stage, region, closure)
        key = FieldStore._key(field_id, stage, norm, cl)
        return field, norm, cl, key, self._shards[self._home(field, norm, cl)]

    # -- materialization cache ------------------------------------------------
    def _materialize(self, field_id: str, field: Encoded, stage: Stage,
                     norm, closure) -> MaterializedStage:
        st = storage_stage(stage)
        inter = self.progs.materialize(
            field, st, region=norm, closure=closure,
            placement=self._placements[field_id],
            stripes=self._stripes[field_id])
        return MaterializedStage(
            sub=inter if st == Stage.P else None,
            q_spatial=None if st == Stage.P else inter,
            stage=st, closure=closure, region=norm)

    def lookup(self, field_id: str, stage: Stage, *, region=None,
               closure="cover") -> MaterializedStage | None:
        _, _, _, key, child = self._cell(field_id, Stage(stage), region,
                                         closure)
        m = child._peek_hit(key)
        if m is None:
            child.stats.misses += 1
        return m

    def ensure(self, field_id: str, stage: Stage, *, region=None,
               closure="cover") -> MaterializedStage:
        m = self.lookup(field_id, stage, region=region, closure=closure)
        if m is not None:
            return m
        field, norm, cl, key, child = self._cell(field_id, Stage(stage),
                                                 region, closure)
        m = self._materialize(field_id, field, Stage(stage), norm, cl)
        child._insert(key, m)
        return m

    def seed(self, field_id: str, stage: Stage, *, region=None,
             closure="cover") -> MaterializedStage | None:
        """Single-device :meth:`FieldStore.seed` semantics, per home shard.

        A cell larger than its home shard's whole budget is declined
        (``None`` — the engine falls back to the retained payload) when the
        payload is retained; in capacity mode (``retain_payload=False``)
        there is no fallback payload, so the cell is computed through the
        sharded program anyway and returned *without* being retained — the
        rejection is still counted on the home shard.
        """
        field, norm, cl, key, child = self._cell(field_id, Stage(stage),
                                                 region, closure)
        m = child._peek_hit(key)
        if m is not None:
            return m
        if materialized_nbytes(field, stage, region=region,
                               closure=cl) > child.cache_bytes:
            child.stats.rejected += 1
            if self.retain_payload:
                return None
            return self._materialize(field_id, field, Stage(stage), norm, cl)
        child.stats.misses += 1
        m = self._materialize(field_id, field, Stage(stage), norm, cl)
        child._insert(key, m)
        return m

    # -- planner input --------------------------------------------------------
    def is_resident(self, field_id: str, stage: Stage, *, region=None,
                    closure="cover") -> bool:
        field, norm, cl, key, child = self._cell(field_id, Stage(stage),
                                                 region, closure)
        return key in child._cache

    def cached_stages(self, field_ids, ops, *, region=None,
                      axis: int = 0) -> frozenset[Stage]:
        """:meth:`FieldStore.cached_stages`, with each cell checked in its
        home shard's cache (pure peek)."""
        names = oplib.canonical_ops(ops)
        vector = oplib.is_vector_ops(names)
        fids = list(field_ids) if vector else [field_ids]
        if isinstance(field_ids, str) and vector:
            raise ValueError("vector op sets need one field id per component")
        fields = [self.get(f) for f in fids]
        out = set()
        for stage in MATERIALIZABLE:
            if vector:
                closures = oplib.component_closures(
                    names, [f.scheme for f in fields], stage)
            else:
                closures = (oplib.set_closure(names, fields[0].scheme, stage,
                                              axis),)
            resident = True
            for fid, field, cl in zip(fids, fields, closures):
                norm, cl = self._canonical(field, stage, region, cl)
                key = FieldStore._key(fid, stage, norm, cl)
                if key not in self._shards[self._home(field, norm, cl)]._cache:
                    resident = False
                    break
            if resident:
                out.add(stage)
        return frozenset(out)

    # -- temporal serving ------------------------------------------------------
    def _temporal_home(self, field_id: str, tf: TemporalField, norm) -> int:
        pl = self._temporal_placement(field_id, tf)
        owners = [o for o, _, _, _ in spatial_bands(tf.slabs[0], pl, norm)]
        return int(np.bincount(np.asarray(owners, dtype=np.int64),
                               minlength=self.n_shards).argmax())

    def _summary_stage(self, tf: TemporalField, region=None) -> Stage:
        return self._shards[0]._summary_stage(tf, region)

    def _banded_summaries(self, field_id: str, tf: TemporalField,
                          slabs: Sequence, stage: Stage, norm
                          ) -> list[TemporalSummary]:
        """Per-slab full-window summaries via shard-local band partials +
        homomorphic merge — bit-identical to ``engine.summarize`` over the
        whole window (int32 leaves, positionwise)."""
        pl = self._temporal_placement(field_id, tf)
        engine = self.engine
        spatial = slabs[0].shape[1:]
        win = norm if norm is not None else tuple((0, s) for s in spatial)
        win_rows = win[0][1] - win[0][0]
        rest = tuple(hi - lo for lo, hi in win[1:])
        bands = spatial_bands(slabs[0], pl, norm)
        # one batched summarize per (band, slab layout): programs stay
        # independent of the stream's length, like the single-device path
        from repro.core import layout_key
        groups: dict[tuple, list[int]] = {}
        for i, slab in enumerate(slabs):
            groups.setdefault(layout_key(slab), []).append(i)
        per_slab: list[list] = [[] for _ in slabs]
        for owner, row0, _, breg in bands:
            for indices in groups.values():
                stacked = engine.summarize([slabs[i] for i in indices], stage,
                                           region=breg)
                for j, i in enumerate(indices):
                    part = jax.tree.map(lambda x, _j=j: x[_j], stacked)
                    per_slab[i].append((owner, row0, part))
        return [self.progs.merge_band_summaries(parts, win_rows, rest)
                for parts in per_slab]

    def temporal_summary(self, field_id: str, *, region=None,
                         stage=None) -> TemporalSummary:
        """Merged summary over every appended slab — band partials reduced
        shard-locally, all-reduced, then folded in temporal order (the
        fold is the same ``engine.merge_summaries`` the single-device
        store uses, so the result is bit-identical to it)."""
        tf = self._temporal(field_id)
        if not tf.slabs:
            raise ValueError(
                f"temporal field {field_id!r} has no appended slabs")
        norm = (region_mod.normalize_region(region, tf.shape)
                if region is not None else None)
        key = (field_id, TEMPORAL_TAG, norm)
        child = self._shards[self._temporal_home(field_id, tf, norm)]
        m = child._peek_hit(key)
        if m is not None:
            return m
        child.stats.misses += 1
        if stage is None:
            stage = self._summary_stage(tf, norm)
        parts = self._banded_summaries(field_id, tf, tf.slabs, Stage(stage),
                                       norm)
        merged = reduce(self.engine.merge_summaries, parts)
        self.summary_rebuilds += 1
        child._insert(key, merged)
        return merged

    # -- streaming ingest ------------------------------------------------------
    def append(self, field_id: str, data) -> int:
        """Ingest one slab; refresh every *resident* summary cell of the id
        in whichever shard holds it — only the owning shards' bands of the
        new slab are reconstructed, and each refresh is a replace-in-place
        merge on that shard's cache (other shards' cells are untouched)."""
        from repro.analytics.planner import plan_refresh

        tf = self._temporal(field_id)
        idx = tf.append(data)
        slab = tf.slabs[idx]
        resident = [(c, k) for c in self._shards for k in list(c._cache)
                    if k[0] == field_id and k[1] == TEMPORAL_TAG]
        plan = plan_refresh(tf.scheme, self._summary_stage(tf),
                            tf.n_slabs, self.cost_model,
                            summary_resident=bool(resident))
        if plan.mode != "incremental":
            return idx
        for child, key in resident:
            old = child._cache.get(key)
            if old is None:
                continue  # evicted by an earlier refresh in this very loop
            norm = key[2]
            part = self._banded_summaries(
                field_id, tf, [slab], self._summary_stage(tf, norm), norm)[0]
            merged = self.engine.merge_summaries(old, part)
            child._insert(key, merged)
            self.incremental_merges += 1
        return idx
