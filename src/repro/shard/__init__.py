"""Block-sharded field store over the analytics device mesh.

Placement (:class:`BlockPlacement`) stripes a field's compressor blocks
over a 1-D ``("shard",)`` mesh (:func:`repro.launch.mesh
.make_analytics_mesh`); the shard-mapped execution programs
(:class:`ShardPrograms`) decode region queries from shard-local payload
stripes and all-reduce temporal summaries homomorphically; the
:class:`ShardedFieldStore` serves both through per-shard byte-budgeted
caches, bit-identical to the single-device store.  See DESIGN.md §13.
"""
from .placement import BlockPlacement
from .exec import ShardPrograms, mesh_sig, spatial_bands
from .store import ShardedFieldStore

__all__ = ["BlockPlacement", "ShardPrograms", "ShardedFieldStore",
           "mesh_sig", "spatial_bands"]
