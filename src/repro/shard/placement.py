"""Block-wise placement of one compressed field over a 1-D shard axis.

The paper's compression pipeline partitions every field into fixed-size
blocks before any transform, and a :class:`~repro.core.region.RegionPlan`
already knows exactly which blocks a query's closure touches — so placement
is a pure function of the *layout*, never of the data: a
:class:`BlockPlacement` assigns each block (via its block-row along one
spatial axis) to a shard, and everything else — participating shards of a
region, per-shard payload-byte accounting, the per-shard word stripes the
``shard_map`` gather programs consume — derives statically from it.

Placement is **striped** (block-row ``r`` belongs to shard ``r % n_shards``)
rather than sliced into contiguous slabs: a localized region then spreads
its covering rows over ``min(rows, n_shards)`` shards instead of landing on
one, which is what bounds the *max* per-shard bytes a region query touches
(the planner's max-cost rule and the ``BENCH_shard.json`` CI gate both key
on that maximum).  Striping costs nothing for full-field scans — every
shard owns ``1/n`` of the blocks either way.

All arrays here are host-side numpy: placement is static layout math, built
once per ``(layout, n_shards, axis)`` and reused by every query.
"""
from __future__ import annotations

import numpy as np

from repro.core import Compressed, Encoded, Scheme, encode
from repro.core.region import RegionPlan

Field = Compressed | Encoded


class BlockPlacement:
    """Static block -> shard assignment for one field layout.

    ``axis`` is the spatial axis whose block-rows are striped (axis 0 for
    spatial fields; temporal slab layouts stripe axis 1, keeping the time
    axis whole so summaries stay per-shard mergeable).  1-D (flat) schemes
    have no rows — individual blocks stripe directly.
    """

    def __init__(self, scheme: Scheme, shape: tuple[int, ...],
                 padded_shape: tuple[int, ...], block: tuple[int, ...],
                 n_shards: int, axis: int = 0):
        if n_shards < 1:
            raise ValueError(f"n_shards must be >= 1, got {n_shards}")
        self.scheme = Scheme(scheme)
        self.shape = tuple(shape)
        self.padded_shape = tuple(padded_shape)
        self.block = tuple(block)
        self.n_shards = int(n_shards)
        self.grid = tuple(p // b for p, b in zip(padded_shape, block))
        if self.scheme.is_nd:
            if not (0 <= axis < len(shape)):
                raise ValueError(
                    f"shard axis {axis} out of range for rank {len(shape)}")
            self.axis = int(axis)
            self.n_units = self.grid[self.axis]
        else:
            # flat layouts stripe the 1-D block sequence itself
            self.axis = 0
            self.n_units = self.grid[0]
        self._word_owner_cache: dict[int, np.ndarray] = {}

    @classmethod
    def of(cls, field: Field, n_shards: int, axis: int = 0) -> "BlockPlacement":
        return cls(field.scheme, field.shape, field.padded_shape, field.block,
                   n_shards, axis)

    def sig(self) -> tuple:
        """Hashable static signature (jit/program cache key component)."""
        return (self.scheme, self.shape, self.padded_shape, self.block,
                self.n_shards, self.axis)

    # -- ownership ----------------------------------------------------------
    def unit_of_blocks(self, block_ids: np.ndarray) -> np.ndarray:
        """Stripe unit (block-row along ``axis``) of raveled block ids."""
        bids = np.asarray(block_ids, dtype=np.int64)
        if not self.scheme.is_nd:
            return bids
        stride = int(np.prod(self.grid[self.axis + 1:], dtype=np.int64))
        return (bids // stride) % self.grid[self.axis]

    def owner_of_blocks(self, block_ids: np.ndarray) -> np.ndarray:
        """Owning shard of each raveled block id."""
        return (self.unit_of_blocks(block_ids) % self.n_shards).astype(np.int32)

    def units_of(self, shard: int) -> np.ndarray:
        """Stripe units owned by ``shard`` (ascending)."""
        if not (0 <= shard < self.n_shards):
            raise ValueError(f"shard {shard} out of range [0, {self.n_shards})")
        return np.arange(shard, self.n_units, self.n_shards, dtype=np.int64)

    def participants(self, plan: RegionPlan) -> tuple[int, ...]:
        """Shards owning at least one of the plan's covering blocks."""
        owners = self.owner_of_blocks(plan.block_ids)
        return tuple(int(s) for s in np.unique(owners))

    def home(self, plan: RegionPlan | None) -> int:
        """Home shard of one cache cell: the majority owner of its covering
        blocks (full field: shard 0 — every shard owns ``~1/n`` either way).
        Materializations live in the home shard's budget, so eviction
        pressure is per-shard, never global."""
        if plan is None:
            return 0
        owners = self.owner_of_blocks(plan.block_ids)
        return int(np.bincount(owners, minlength=self.n_shards).argmax())

    # -- value / word geometry ----------------------------------------------
    def _value_owner(self, values: np.ndarray) -> np.ndarray:
        """Owning shard of flat *padded* value indices."""
        v = np.asarray(values, dtype=np.int64)
        if not self.scheme.is_nd:
            return ((v // self.block[0]) % self.n_shards).astype(np.int32)
        stride = int(np.prod(self.padded_shape[self.axis + 1:], dtype=np.int64))
        coord = (v // stride) % self.padded_shape[self.axis]
        return ((coord // self.block[self.axis]) % self.n_shards).astype(np.int32)

    def word_owner(self, bits: int) -> np.ndarray:
        """Owning shard of every payload word (by the word's first value).

        A word straddling two stripes belongs wholly to the first value's
        owner — words are the indivisible transfer unit, so each is placed
        exactly once and the scatter/psum merge never splits bits.
        """
        owners = self._word_owner_cache.get(bits)
        if owners is not None:
            return owners
        n_values = int(np.prod(self.padded_shape, dtype=np.int64))
        n_words = encode.words_for(n_values, bits)
        first_value = np.minimum(
            (np.arange(n_words, dtype=np.int64) * 32) // max(bits, 1),
            max(n_values - 1, 0))
        owners = self._value_owner(first_value)
        self._word_owner_cache[bits] = owners
        return owners

    def shard_word_index(self, bits: int) -> list[np.ndarray]:
        """Per-shard ascending global word indices (the physical payload
        stripe each shard holds)."""
        owners = self.word_owner(bits)
        return [np.nonzero(owners == s)[0] for s in range(self.n_shards)]

    # -- accounting (CI gate input) -----------------------------------------
    def payload_bytes(self, plan: RegionPlan, bits: int) -> dict:
        """Payload bytes a region decode touches, per shard and single-device.

        The single-device path gathers every word of the plan's
        :meth:`~repro.core.region.RegionPlan.payload_gather`; the sharded
        path reads each gathered word from exactly one owning shard's local
        stripe, so the per-shard figure is that shard's share of the gather.
        """
        gi = plan.payload_gather(bits)
        owners = self.word_owner(bits)[gi.word_idx] if gi.n_words else \
            np.zeros((0,), np.int32)
        per_shard = np.bincount(owners, minlength=self.n_shards) * 4
        return {
            "single_bytes": int(gi.n_words) * 4,
            "per_shard_bytes": [int(b) for b in per_shard],
            "max_shard_bytes": int(per_shard.max()) if self.n_shards else 0,
            "participants": [int(s) for s in np.nonzero(per_shard)[0]],
        }

    def closure_fractions(self, plan: RegionPlan) -> np.ndarray:
        """Per-shard fraction of the *field* each shard decodes for the
        plan's closure (planner input: a stage's measured full-field cost
        scales by a participating shard's share, and the sharded cost of
        the stage is the **max** over shards, not the sum — shards decode
        their blocks concurrently)."""
        owners = self.owner_of_blocks(plan.block_ids)
        counts = np.bincount(owners, minlength=self.n_shards).astype(np.float64)
        block_elems = float(np.prod(self.block, dtype=np.int64))
        total = float(np.prod(self.padded_shape, dtype=np.int64))
        return counts * block_elems / total

    def max_fraction(self, plan: RegionPlan | None = None) -> float:
        """Max per-shard share of the field's decode work — the planner's
        sharded cost rule scales a stage's measured full-field cost by this
        (shards decode concurrently, so the critical path is the busiest
        shard, never the sum).  ``plan=None`` is the full-field figure."""
        if plan is not None:
            return float(self.closure_fractions(plan).max())
        units = np.arange(self.n_units, dtype=np.int64) % self.n_shards
        counts = np.bincount(units, minlength=self.n_shards)
        return float(counts.max()) / max(self.n_units, 1)
