"""Serve-layer store routing: one id namespace over sharded + local stores.

An :class:`AnalyticsFrontend` takes *one* ``store``; a deployment that
shards its biggest fields over the device mesh (``repro.shard``) while
keeping small fields on the default single-device store needs both behind
one handle.  A :class:`StoreRouter` is that handle: it duck-types the store
surface the query/serve stack consumes and routes every call by **field-id
membership** — an id registered in the sharded store is served there,
everything else falls through to the local store — so
``AnalyticsRequest`` / ``AppendRequest`` by id hit the sharded store
transparently, with no request-level opt-in.

Rejection stays per-request: an id unknown to *both* stores raises the
standard ``KeyError`` (listing both registries), which the frontend turns
into that one request's structured error — the group and the jit caches of
every other request are untouched.
"""
from __future__ import annotations

from repro.store import StoreStats


class StoreRouter:
    """Route the duck-typed store surface by field-id membership.

    ``sharded`` is a :class:`repro.shard.ShardedFieldStore`; ``local`` is
    any single-device store (:class:`repro.store.FieldStore` /
    :class:`repro.stream.StreamFieldStore`) or ``None`` for a
    sharded-only deployment.  Registration stays explicit — ``put`` /
    ``put_temporal`` go to the local store, ``sharded.put`` to the mesh —
    the router only unifies the *serving* surface.
    """

    def __init__(self, sharded, local=None):
        self.sharded = sharded
        self.local = local

    def _of(self, field_id: str):
        if field_id in self.sharded:
            return self.sharded
        if self.local is not None and field_id in self.local:
            return self.local
        known = sorted(set(self.sharded.ids())
                       | set(self.local.ids() if self.local else ()))
        raise KeyError(
            f"unknown field id {field_id!r}; registered ids: "
            f"{known or '(none)'}")

    # -- registry (explicit placement) --------------------------------------
    def put(self, field_id: str, field, *, replace: bool = False) -> str:
        if self.local is None:
            raise ValueError(
                "router has no local store; register sharded fields via "
                "router.sharded.put(...)")
        if field_id in self.sharded and not replace:
            raise ValueError(
                f"field id {field_id!r} already registered "
                "(pass replace=True to overwrite)")
        return self.local.put(field_id, field, replace=replace)

    def put_temporal(self, field_id: str, tf, *, replace: bool = False) -> str:
        if self.local is None or not hasattr(self.local, "put_temporal"):
            return self.sharded.put_temporal(field_id, tf, replace=replace)
        return self.local.put_temporal(field_id, tf, replace=replace)

    def get(self, field_id: str):
        return self._of(field_id).get(field_id)

    def __contains__(self, field_id: str) -> bool:
        return (field_id in self.sharded
                or (self.local is not None and field_id in self.local))

    def ids(self) -> tuple[str, ...]:
        return tuple(self.sharded.ids()) + tuple(
            self.local.ids() if self.local else ())

    # -- serving surface ------------------------------------------------------
    def seed(self, field_id: str, stage, *, region=None, closure="cover"):
        return self._of(field_id).seed(field_id, stage, region=region,
                                       closure=closure)

    def ensure(self, field_id: str, stage, *, region=None, closure="cover"):
        return self._of(field_id).ensure(field_id, stage, region=region,
                                         closure=closure)

    def lookup(self, field_id: str, stage, *, region=None, closure="cover"):
        return self._of(field_id).lookup(field_id, stage, region=region,
                                         closure=closure)

    def is_resident(self, field_id: str, stage, *, region=None,
                    closure="cover") -> bool:
        return self._of(field_id).is_resident(field_id, stage, region=region,
                                              closure=closure)

    def cached_stages(self, field_ids, ops, *, region=None, axis: int = 0):
        fids = [field_ids] if isinstance(field_ids, str) else list(field_ids)
        stores = {id(self._of(f)) for f in fids}
        if len(stores) > 1:
            raise ValueError(
                "vector components must live in one store (sharded or "
                f"local), got a mix for {fids}")
        return self._of(fids[0]).cached_stages(field_ids, ops, region=region,
                                               axis=axis)

    def placement_of(self, field_id: str):
        store = self._of(field_id)
        placement_of = getattr(store, "placement_of", None)
        return placement_of(field_id) if placement_of is not None else None

    def temporal_summary(self, field_id: str, *, region=None, stage=None):
        store = self._of(field_id)
        if not hasattr(store, "temporal_summary"):
            raise TypeError(
                f"field id {field_id!r} lives in a store without temporal "
                "support")
        return store.temporal_summary(field_id, region=region, stage=stage)

    def is_temporal(self, field_id: str) -> bool:
        store = self._of(field_id)
        return (hasattr(store, "is_temporal")
                and store.is_temporal(field_id))

    def append(self, field_id: str, data) -> int:
        store = self._of(field_id)
        if not hasattr(store, "append"):
            raise TypeError(
                f"field id {field_id!r} lives in a store without streaming "
                "support")
        return store.append(field_id, data)

    # -- accounting -----------------------------------------------------------
    @property
    def stats(self) -> StoreStats:
        agg = StoreStats()
        for s in (self.sharded, self.local):
            if s is None:
                continue
            st = s.stats
            agg.hits += st.hits
            agg.misses += st.misses
            agg.evictions += st.evictions
            agg.rejected += st.rejected
        return agg
