"""Batched serving engine: continuous-batching-lite over fixed decode slots.

A fixed-capacity slot array (shape-stable jit decode step) with per-slot
activity masks: requests join free slots (their prompt is prefilled into the
shared cache), every engine ``step()`` decodes one token for all active
slots, finished slots are recycled.  Greedy sampling.  KV cache can hold
HSZ stage-③ int8 residency (``kv_quant`` in the arch config).
"""
from __future__ import annotations

import dataclasses

import numpy as np
import jax
import jax.numpy as jnp

from repro.models import Model


@dataclasses.dataclass
class Request:
    uid: int
    prompt: np.ndarray            # (prompt_len,) int32
    max_new_tokens: int = 16
    out_tokens: list[int] = dataclasses.field(default_factory=list)
    done: bool = False


class Engine:
    def __init__(self, model: Model, params, *, slots: int = 4, max_len: int = 512,
                 eos_id: int | None = None):
        self.model = model
        self.params = params
        self.slots = slots
        self.max_len = max_len
        self.eos_id = eos_id
        self.cache = model.init_cache(slots, max_len)
        self.active: list[Request | None] = [None] * slots
        self.slot_pos = np.zeros(slots, np.int32)
        self._decode = jax.jit(model.decode_step)
        self._queue: list[Request] = []

    # -- request lifecycle ---------------------------------------------------
    def add_request(self, req: Request):
        self._queue.append(req)

    def _admit(self):
        for s in range(self.slots):
            if self.active[s] is None and self._queue:
                req = self._queue.pop(0)
                self.active[s] = req
                # teacher-forced prefill: feed prompt tokens one by one into
                # the shared cache (simple and exact; a chunked prefill path
                # exists for long prompts via model.prefill)
                for t in req.prompt[:-1]:
                    self._step_single_slot(s, int(t))
                self._last_token_for_slot(s, int(req.prompt[-1]))

    def _last_token_for_slot(self, slot, token):
        self.slot_pos[slot] = token

    def _step_single_slot(self, slot, token):
        # feed `token` through the decode step for cache side effects;
        # other slots receive pad token 0 and their caches also advance, so
        # positions are kept per-engine-step (single shared pos counter).
        toks = np.zeros((self.slots, 1), np.int32)
        toks[slot, 0] = token
        logits, self.cache = self._decode(self.params, jnp.asarray(toks), self.cache)

    # -- decode loop -----------------------------------------------------------
    def step(self) -> dict[int, int]:
        """One decode step for all active slots; returns {uid: token}."""
        self._admit()
        toks = np.zeros((self.slots, 1), np.int32)
        for s, req in enumerate(self.active):
            if req is not None:
                toks[s, 0] = self.slot_pos[s]
        logits, self.cache = self._decode(self.params, jnp.asarray(toks), self.cache)
        next_tokens = np.asarray(jnp.argmax(logits[:, -1, :], axis=-1), np.int32)
        emitted = {}
        for s, req in enumerate(self.active):
            if req is None:
                continue
            tok = int(next_tokens[s])
            req.out_tokens.append(tok)
            emitted[req.uid] = tok
            self.slot_pos[s] = tok
            if (self.eos_id is not None and tok == self.eos_id) or \
                    len(req.out_tokens) >= req.max_new_tokens:
                req.done = True
                self.active[s] = None
        return emitted

    def run_until_drained(self, max_steps: int = 10_000) -> list[Request]:
        finished: list[Request] = []
        seen: dict[int, Request] = {}
        steps = 0
        while (self._queue or any(self.active)) and steps < max_steps:
            for r in self.active:
                if r is not None:
                    seen[r.uid] = r
            self.step()
            steps += 1
        finished = [r for r in seen.values() if r.done]
        return finished
