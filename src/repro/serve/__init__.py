"""Batched serving engine."""
from .engine import Engine, Request
