"""Serving: batched token generation + batched homomorphic analytics."""
from .engine import Engine, Request
from .analytics import AnalyticsFrontend, AnalyticsRequest

__all__ = ["Engine", "Request", "AnalyticsFrontend", "AnalyticsRequest"]
