"""Serving: batched token generation + batched homomorphic analytics +
streaming temporal ingest."""
from .engine import Engine, Request
from .analytics import AnalyticsFrontend, AnalyticsRequest, AppendRequest
from .routing import StoreRouter

__all__ = ["Engine", "Request", "AnalyticsFrontend", "AnalyticsRequest",
           "AppendRequest", "StoreRouter"]
