"""Analytics serving: the second request type next to token generation.

Mirrors the token engine's continuous-batching contract (``add_request`` /
``step`` / ``run_until_drained``) for homomorphic analytics over compressed
fields.  Each ``step`` drains the queue, groups requests by
``(op set, stage directive, axis)`` and — via the query front-end — by field
layout, and issues one jitted vmap call per group, so N concurrent requests
over same-layout fields cost one dispatch instead of N.  A request may name
*several* ops (``op=["mean", "std"]``): the fused plan pays one stage
reconstruction for the whole set and the request resolves to a result dict.
The op-set component of the group signature is canonical (order-insensitive),
so ``["std", "mean"]`` and ``["mean", "std"]`` batch — and compile — together.

With a :class:`repro.store.FieldStore` attached, ``AnalyticsRequest.fields``
may name registered field *ids* (strings — component ids too, for
``divergence``/``curl``) instead of shipping containers: the frontend
resolves ids for grouping and serves the group through the store, so
repeated queries of a hot field reuse its materialized stage reconstruction
(``repro.analytics.query`` seeds the compiled program) and clients stop
shipping arrays entirely — the serve-millions contract.  Unknown ids reject
only their own request.

With a streaming store (:class:`repro.stream.StreamFieldStore`), the queue
also carries :class:`AppendRequest` — producers ship raw timestep batches
against a temporal field id; each serving step applies appends (in order)
*before* its analytics, and temporal ops (``tmean``/``tdelta``/...) over
the same ids answer from incrementally merged summaries.  Every request is
always either answered or rejected with a structured error; a malformed
request (unknown id, empty op set, out-of-bounds region, duplicate vector
component ids) never poisons another request's group or the jit cache.
"""
from __future__ import annotations
from collections.abc import Sequence

import dataclasses
import warnings
from typing import Any

from repro.analytics import CostModel, query
from repro.analytics.engine import BatchedAnalytics
from repro.analytics.query import _group_signature, _query_opset, _resolve_item
from repro.core import Compressed, Encoded, Stage, oplib
from repro.core import expr as expr_mod
from repro.core import region as region_mod

Field = Compressed | Encoded


def _region_signature(req: "AnalyticsRequest", resolved=None):
    """Normalized region for grouping, so equivalent specs (slices vs tuples
    vs numpy ints) batch into one dispatch.  ``resolved`` is the id-free
    view of ``req.fields`` (defaults to ``req.fields`` for id-less
    requests).  Raises on malformed regions — the caller's per-request
    guard turns that into a rejection."""
    if req.region is None:
        return None
    if resolved is None:
        resolved = req.fields
    ops = oplib.canonical_ops(req.op)
    first = resolved[0] if oplib.is_vector_ops(ops) else resolved
    return region_mod.normalize_region(req.region, first.shape)


@dataclasses.dataclass
class AnalyticsRequest:
    """One analytics request: expression DAGs, or a flat (field, op) pair.

    The expression form is primary: ``exprs`` is one
    :class:`repro.core.expr.Expr` (or a sequence) whose leaves carry the
    data — containers, component bundles, or (with a store-attached
    frontend) registered field ids.  Cross-field derived quantities
    (vorticity from u and v, ensemble deltas) are one request; same-step
    expression requests with the same stage directive and region fuse into
    one compiled program, sharing leaf preludes across requests.

    The flat form — ``fields`` + ``op`` — remains for back-compat:
    ``fields`` carries (or names) one possibly-vector field and ``op`` one
    op name.  The op-*set* spelling (``op=["mean", "std"]``) is deprecated
    in favor of expressions and warns.  With a streaming store
    (:class:`repro.stream.StreamFieldStore`), temporal ops (``tmean``,
    ``tdelta``, ...) over a temporal field id query the appended stream in
    either form.
    """

    uid: int
    fields: Field | str | Sequence[Field | str] | None = None
    op: str | Sequence[str] = "mean"  # one op, or a fused op set
    stage: Stage | str | int = "auto"
    axis: int = 0                          # derivative only
    region: Any = None                     # per-axis window, or None for full
    exprs: Any = None                      # Expr or sequence of Expr roots
    result: Any = None                     # array, or {op: array} for op sets
    result_stage: Any = None               # Stage, or {op: Stage} for op sets
    error: str | None = None            # set instead of result on rejection
    done: bool = False


@dataclasses.dataclass
class AppendRequest:
    """Streaming ingest: append one time slab to a registered temporal field.

    The client-side half of the streaming contract — producers ship raw
    timestep batches (``data``: shape ``(k, *spatial)``) against a field
    *id*; the frontend's :class:`repro.stream.StreamFieldStore` compresses
    the slab and incrementally refreshes the id's resident temporal
    summaries (reconstructing only the new slab).  Within one serving step
    appends are applied before analytics, so an append+query pair enqueued
    together observes the appended timesteps.
    """

    uid: int
    field_id: str
    data: Any                              # (timesteps, *spatial) raw values
    slab_index: int | None = None       # set on success
    error: str | None = None            # set instead on rejection
    done: bool = False


class AnalyticsFrontend:
    """Batching frontend for analytics requests (no model, no slots: the
    batch axis is formed per step from whatever is queued).  ``store``
    enables id-addressed requests and materialized-stage reuse."""

    def __init__(self, cost_model: CostModel | None = None,
                 max_batch: int = 256, store=None):
        self.engine = BatchedAnalytics(cost_model)
        self.max_batch = max_batch
        self.store = store
        self._queue: list[AnalyticsRequest] = []

    def _resolve_fields(self, req: AnalyticsRequest, vector: bool):
        """Id-free view of a request's fields (for grouping signatures);
        raises on unknown ids / ids without a store (-> rejection).  One
        resolver for the whole stack: this reuses the query front-end's."""
        resolved, _ = _resolve_item(req.fields, self.store, vector)
        return resolved

    def add_request(self, req: AnalyticsRequest | "AppendRequest") -> None:
        self._queue.append(req)

    # -- one serving step --------------------------------------------------
    @staticmethod
    def _reject(req, exc: Exception):
        req.error = f"{type(exc).__name__}: {exc}"
        req.done = True
        return req

    def _apply_append(self, req: AppendRequest) -> AppendRequest:
        """Ingest one slab through the streaming store (rejections are
        per-request, like analytics)."""
        try:
            if self.store is None or not hasattr(self.store, "append"):
                raise ValueError(
                    "append requests need a streaming store "
                    "(repro.stream.StreamFieldStore) attached to the frontend")
            req.slab_index = self.store.append(req.field_id, req.data)
        except Exception as e:  # unknown id / shape mismatch / no store
            return self._reject(req, e)
        req.done = True
        return req

    def step(self) -> list[AnalyticsRequest | AppendRequest]:
        """Serve up to ``max_batch`` queued requests; returns those finished.

        Appends are applied first (in arrival order — ingest precedes the
        step's analytics), then analytics requests are grouped by
        (canonical op set, stage directive, axis, region, field layout), so
        a rejection — infeasible stage, malformed fields, duplicate ids,
        out-of-bounds region — only affects its own request or group;
        everything servable in the step is served, and a rejected request
        never leaves a poisoned entry in the engine's jit cache (fresh
        failing programs are evicted by the engine itself).
        """
        batch, self._queue = self._queue[:self.max_batch], self._queue[self.max_batch:]
        finished: list[AnalyticsRequest | AppendRequest] = []
        analytics_batch: list[AnalyticsRequest] = []
        for req in batch:
            if isinstance(req, AppendRequest):
                finished.append(self._apply_append(req))
            else:
                analytics_batch.append(req)
        groups: dict[tuple, list[AnalyticsRequest]] = {}
        # expression requests: group value is [(request, its roots), ...]
        expr_groups: dict[tuple, list[tuple[AnalyticsRequest, list]]] = {}
        for req in analytics_batch:
            if req.exprs is not None:
                try:
                    if req.fields is not None:
                        raise TypeError(
                            "an expression request carries its fields inside "
                            "the expressions; do not also set .fields")
                    roots = ([req.exprs]
                             if isinstance(req.exprs, expr_mod.Expr)
                             else list(req.exprs))
                    expr_mod.analyze(roots)  # per-request validation
                    # repr-canonical region: equivalent-but-differently-
                    # spelled windows may land in separate (still correct)
                    # groups — exprs carry no single shape to normalize by
                    sig = (str(req.stage), repr(req.region))
                except Exception as e:
                    finished.append(self._reject(req, e))
                    continue
                expr_groups.setdefault(sig, []).append((req, roots))
                continue
            if req.fields is None:
                finished.append(self._reject(req, TypeError(
                    "request needs exprs= or the flat fields/op pair")))
                continue
            if not isinstance(req.op, str):
                warnings.warn(
                    "the AnalyticsRequest.op op-set form is deprecated; "
                    "send AnalyticsRequest(exprs=[...]) expressions instead "
                    "(repro.core.expr)", DeprecationWarning, stacklevel=2)
            try:
                ops = oplib.canonical_ops(req.op)
                vector = oplib.is_vector_ops(ops)
                resolved = self._resolve_fields(req, vector)
                sig = (ops, str(req.stage), req.axis,
                       _region_signature(req, resolved),
                       _group_signature(resolved, vector))
            except Exception as e:  # unknown op / id / malformed fields
                finished.append(self._reject(req, e))
                continue
            groups.setdefault(sig, []).append(req)
        for group in groups.values():
            try:
                # original (possibly id-bearing) fields go to the query:
                # ids keep their cache identity, so hot fields are served
                # from materialized stages
                res = _query_opset([r.fields for r in group], group[0].op,
                                   group[0].stage, axis=group[0].axis,
                                   region=group[0].region, engine=self.engine,
                                   store=self.store)
            except Exception as e:
                # reject only this group (bad op / infeasible stage / ...);
                # every request is always either answered or errored
                finished.extend(self._reject(r, e) for r in group)
                continue
            for req, value, stage in zip(group, res.values, res.stages):
                # a group may mix op="mean" and op=["mean"] requests (same
                # canonical signature): give each the form it asked for
                if isinstance(req.op, str) and isinstance(value, dict):
                    value, stage = value[req.op], stage[req.op]
                elif not isinstance(req.op, str) and not isinstance(value, dict):
                    (name,) = oplib.canonical_ops(req.op)
                    value, stage = {name: value}, {name: stage}
                req.result = value
                req.result_stage = stage
                req.done = True
                finished.append(req)
        for egroup in expr_groups.values():
            reqs = [r for r, _ in egroup]
            all_roots = [root for _, roots in egroup for root in roots]
            try:
                # one fused program per group: leaves shared across requests
                # dedupe into one prelude each
                res = query(exprs=all_roots, stage=reqs[0].stage,
                            region=reqs[0].region, engine=self.engine,
                            store=self.store)
            except Exception as e:
                finished.extend(self._reject(r, e) for r in reqs)
                continue
            i = 0
            for req, roots in egroup:
                vals = res.values[i:i + len(roots)]
                stgs = res.stages[i:i + len(roots)]
                i += len(roots)
                single = isinstance(req.exprs, expr_mod.Expr)
                req.result = vals[0] if single else vals
                req.result_stage = stgs[0] if single else stgs
                req.done = True
                finished.append(req)
        return finished

    def run_until_drained(self) -> list[AnalyticsRequest]:
        finished: list[AnalyticsRequest] = []
        while self._queue:
            finished.extend(self.step())
        return finished
