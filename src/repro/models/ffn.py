"""Feed-forward blocks: gated MLP (SwiGLU/GeGLU) and routed MoE.

MoE is the TPU-native sort-based dropless-with-capacity router (MaxText
style): tokens are sorted by expert, gathered into an (E, C, D) dispatch
buffer (sharded on the expert axis -> GSPMD emits the EP all-to-all), run
through batched expert einsums, and combined with top-k gate weights.
Covers granite-moe (40e top-8) and deepseek-v3 (1 shared + 256 routed
top-8 with sigmoid routing + bias-free norm-topk).
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from .common import CTX, Builder, gelu_glu, shard, swiglu


@dataclasses.dataclass(frozen=True)
class FfnCfg:
    d_model: int
    d_ff: int
    act: str = "silu"          # 'silu' -> SwiGLU, 'gelu' -> GeGLU
    # MoE
    moe: bool = False
    n_experts: int = 0
    top_k: int = 0
    n_shared: int = 0          # shared (always-on) experts, deepseek-style
    shared_d_ff: int = 0
    capacity_factor: float = 1.25
    router_softmax: bool = True  # False -> sigmoid scores (deepseek-v3)


def _glu(act: str):
    return swiglu if act == "silu" else gelu_glu


# ---------------------------------------------------------------------------
# dense MLP
# ---------------------------------------------------------------------------

def init_dense(b: Builder, cfg: FfnCfg, d_ff: int | None = None):
    d, f = cfg.d_model, d_ff or cfg.d_ff
    return {
        "gate": b.param((d, f), ("embed_w", "mlp")),
        "up": b.param((d, f), ("embed_w", "mlp")),
        "down": b.param((f, d), ("mlp", "embed_w")),
    }


def dense(p, x: jax.Array, cfg: FfnCfg) -> jax.Array:
    h = _glu(cfg.act)(x @ p["gate"], x @ p["up"])
    h = shard(h, "batch", "seq", "mlp")
    return shard(h @ p["down"], "batch", "seq", "embed")


def init_plain(b: Builder, d_model: int, d_ff: int):
    """Ungated 2-layer MLP (whisper-style fc1 -> GELU -> fc2)."""
    return {
        "fc1": b.param((d_model, d_ff), ("embed_w", "mlp")),
        "fc2": b.param((d_ff, d_model), ("mlp", "embed_w")),
    }


def plain(p, x: jax.Array) -> jax.Array:
    h = jax.nn.gelu(x @ p["fc1"], approximate=True)
    h = shard(h, "batch", "seq", "mlp")
    return shard(h @ p["fc2"], "batch", "seq", "embed")


# ---------------------------------------------------------------------------
# routed MoE
# ---------------------------------------------------------------------------

def init_moe(b: Builder, cfg: FfnCfg):
    d, f, e = cfg.d_model, cfg.d_ff, cfg.n_experts
    p = {
        "router": b.param((d, e), ("embed_w", "experts")),
        "w_gate": b.param((e, d, f), ("experts", "embed_w", "mlp")),
        "w_up": b.param((e, d, f), ("experts", "embed_w", "mlp")),
        "w_down": b.param((e, f, d), ("experts", "mlp", "embed_w")),
    }
    if cfg.n_shared:
        p["shared"] = init_dense(b, cfg, d_ff=cfg.shared_d_ff or cfg.d_ff * cfg.n_shared)
    return p


def moe(p, x: jax.Array, cfg: FfnCfg) -> jax.Array:
    """Routed mixture with capacity; returns combined output (B, S, D)."""
    B, S, D = x.shape
    E, K = cfg.n_experts, cfg.top_k
    T = B * S
    xt = shard(x.reshape(T, D), "batch", None)

    scores = (xt @ p["router"]).astype(jnp.float32)        # (T, E)
    if cfg.router_softmax:
        probs = jax.nn.softmax(scores, axis=-1)
    else:  # deepseek-v3 sigmoid routing with top-k renormalization
        probs = jax.nn.sigmoid(scores)
    gate_vals, expert_idx = jax.lax.top_k(probs, K)        # (T, K)
    gate_vals = gate_vals / jnp.maximum(
        jnp.sum(gate_vals, axis=-1, keepdims=True), 1e-9)

    # ---- routing plan in integer space (cheap: (T*K,) int32 tensors) ------
    slots_e = expert_idx.reshape(-1)                       # (T*K,)
    order = jnp.argsort(slots_e)
    sorted_e = slots_e[order]
    starts = jnp.searchsorted(sorted_e, jnp.arange(E))     # first slot per expert
    pos_in_e = jnp.arange(T * K) - starts[sorted_e]        # rank within expert
    C = int(max(1, round(T * K / E * cfg.capacity_factor)))
    dest = jnp.where(pos_in_e < C, sorted_e * C + pos_in_e, E * C)  # drop -> row E*C
    src_token = order // K

    # ---- dispatch: ONE gather straight into the (E, C, D) buffer ----------
    # (never materializes a slot-major (T*K, D) tensor; the gather crosses
    # the DP->EP sharding boundary, which GSPMD lowers to the all-to-all)
    slot_src = jnp.full((E * C + 1,), T, jnp.int32).at[dest].set(
        src_token, mode="drop")[: E * C]
    xt_pad = jnp.concatenate([xt, jnp.zeros((1, D), x.dtype)])  # row T = zeros
    dispatch = shard(xt_pad[slot_src].reshape(E, C, D),
                     "experts", "expert_cap", None)

    # ---- expert compute ----------------------------------------------------
    h = _glu(cfg.act)(
        jnp.einsum("ecd,edf->ecf", dispatch, p["w_gate"]),
        jnp.einsum("ecd,edf->ecf", dispatch, p["w_up"]),
    )
    h = shard(h, "experts", "expert_cap", None)
    out_e = jnp.einsum("ecf,efd->ecd", h, p["w_down"])     # (E, C, D)
    out_e = shard(out_e, "experts", "expert_cap", None).reshape(E * C, D)
    out_pad = jnp.concatenate([out_e, jnp.zeros((1, D), out_e.dtype)])

    # ---- combine: K gathers in token order, weighted by gates --------------
    inv = jnp.zeros_like(order).at[order].set(jnp.arange(T * K))
    dest_tok = dest[inv].reshape(T, K)                     # row per (token, k)
    combined = jnp.zeros((T, D), x.dtype)
    for j in range(K):
        rows = out_pad[dest_tok[:, j]]                     # dropped -> zeros row
        combined = combined + shard(rows, "batch", None) * gate_vals[:, j:j + 1].astype(x.dtype)
    combined = shard(combined, "batch", None)

    if cfg.n_shared:
        combined = combined + dense(p["shared"], xt[:, None, :], cfg)[:, 0, :]
    return shard(combined.reshape(B, S, D), "batch", "seq", "embed")


# ---------------------------------------------------------------------------
# manual expert parallelism (shard_map + explicit all_to_all)
# ---------------------------------------------------------------------------

def _dp_axes():
    rule = CTX.rules.get("batch")
    return rule if isinstance(rule, tuple) else (rule,)


def _can_manual_ep(cfg: FfnCfg, x: jax.Array) -> bool:
    """Manual EP needs experts % tp == 0 and tokens to split over dp x tp."""
    if CTX.mesh is None or "model" not in CTX.mesh.axis_names:
        return False
    if CTX.manual_dp:
        return False  # already inside a manual-DP shard_map: no nesting
    tp = CTX.mesh.shape["model"]
    dp = 1
    for a in _dp_axes():
        if a not in CTX.mesh.axis_names:
            return False
        dp *= CTX.mesh.shape[a]
    B, S, _ = x.shape
    T = B * S
    if tp <= 1 or B % dp or (T // dp) % tp:
        return False
    return (T // dp // tp) * cfg.top_k >= tp  # at least one slot per peer
def moe_manual_ep(p, x: jax.Array, cfg: FfnCfg) -> jax.Array:
    """Deepseek-scale MoE with explicit EP (DESIGN.md §8).

    GSPMD cannot shard the irregular dispatch gathers of 256-expert MoE — it
    materializes slot-major (T*K, D) buffers (hundreds of GiB/device at 1M
    tokens).  This path does what production EP systems do: a partial-manual
    ``shard_map`` over (dp..., model) where each device routes its local
    token slice, exchanges expert-bound rows with ``lax.all_to_all`` over the
    ``model`` axis (the EP group), runs its local experts, and reverses the
    exchange.  Per-device buffers are O(T_local * K / tp * D).
    """
    mesh = CTX.mesh
    tp = mesh.shape["model"]
    dp_axes = _dp_axes()
    B, S, D = x.shape
    E, K = cfg.n_experts, cfg.top_k
    # experts that don't divide the EP group count are padded with dead
    # experts (zero weights, never routed to) — granite-moe's 40e on tp=16
    E_pad = -(-E // tp) * tp
    E_loc = E_pad // tp
    cf = cfg.capacity_factor

    from jax.sharding import PartitionSpec as P

    def body(xb, router, w_gate, w_up, w_down):
        # xb: (B_loc, S, D) local tokens; weights: local expert slices
        Bl = xb.shape[0]
        T_loc = Bl * S
        Ts = T_loc // tp                         # tokens routed by this device
        g_idx = jax.lax.axis_index("model")
        xt = xb.reshape(T_loc, D)
        xs = jax.lax.dynamic_slice_in_dim(xt, g_idx * Ts, Ts, axis=0)

        scores = (xs @ router).astype(jnp.float32)
        probs = jax.nn.softmax(scores, -1) if cfg.router_softmax else jax.nn.sigmoid(scores)
        gate_vals, expert_idx = jax.lax.top_k(probs, K)    # (Ts, K)
        gate_vals = gate_vals / jnp.maximum(gate_vals.sum(-1, keepdims=True), 1e-9)

        # ---- stage 1: route token-slots to expert groups (peers) ----------
        flat_e = expert_idx.reshape(-1)                    # (Ts*K,)
        dest_g = flat_e // E_loc
        order1 = jnp.argsort(dest_g)
        sorted_g = dest_g[order1]
        starts = jnp.searchsorted(sorted_g, jnp.arange(tp))
        pos1 = jnp.arange(Ts * K) - starts[sorted_g]
        cap1 = int(max(1, round(Ts * K / tp * cf)))
        slot1 = jnp.where(pos1 < cap1, sorted_g * cap1 + pos1, tp * cap1)
        # send buffers: rows + local-expert ids (E_loc marks an empty slot)
        src_tok = order1 // K
        send_src = jnp.full((tp * cap1 + 1,), Ts, jnp.int32).at[slot1].set(
            src_tok, mode="drop")[: tp * cap1]
        xs_pad = jnp.concatenate([xs, jnp.zeros((1, D), xs.dtype)])
        send_rows = xs_pad[send_src]                       # (tp*cap1, D)
        send_le = jnp.full((tp * cap1 + 1,), E_loc, jnp.int32).at[slot1].set(
            flat_e[order1] % E_loc, mode="drop")[: tp * cap1]
        send_le = jnp.where(send_src == Ts, E_loc, send_le)

        recv_rows = jax.lax.all_to_all(send_rows, "model", 0, 0, tiled=True)
        recv_le = jax.lax.all_to_all(send_le, "model", 0, 0, tiled=True)

        # ---- stage 2: local dispatch to this group's experts ---------------
        R = tp * cap1
        order2 = jnp.argsort(recv_le)                      # empties sort last
        sorted_le = recv_le[order2]
        starts2 = jnp.searchsorted(sorted_le, jnp.arange(E_loc))
        pos2 = jnp.arange(R) - starts2[jnp.clip(sorted_le, 0, E_loc - 1)]
        cap2 = int(max(1, round(R / E_loc * cf)))
        slot2_sorted = jnp.where(
            (sorted_le < E_loc) & (pos2 < cap2),
            sorted_le * cap2 + pos2, E_loc * cap2)
        slot2 = jnp.zeros((R,), jnp.int32).at[order2].set(slot2_sorted)
        disp_src = jnp.full((E_loc * cap2 + 1,), R, jnp.int32).at[slot2].set(
            jnp.arange(R), mode="drop")[: E_loc * cap2]
        recv_pad = jnp.concatenate([recv_rows, jnp.zeros((1, D), recv_rows.dtype)])
        disp = recv_pad[disp_src].reshape(E_loc, cap2, D)

        h = _glu(cfg.act)(
            jnp.einsum("ecd,edf->ecf", disp, w_gate),
            jnp.einsum("ecd,edf->ecf", disp, w_up),
        )
        out_e = jnp.einsum("ecf,efd->ecd", h, w_down).reshape(E_loc * cap2, D)
        out_pad = jnp.concatenate([out_e, jnp.zeros((1, D), out_e.dtype)])
        y_rows = out_pad[slot2]                            # (R, D) recv order

        # ---- return path + combine -----------------------------------------
        y_back = jax.lax.all_to_all(y_rows, "model", 0, 0, tiled=True)
        y_back = jnp.concatenate([y_back, jnp.zeros((1, D), y_back.dtype)])
        slot1_tok = jnp.zeros((Ts * K,), jnp.int32).at[order1].set(
            jnp.where(pos1 < cap1, slot1, tp * cap1)).reshape(Ts, K)
        acc = jnp.zeros((Ts, D), xb.dtype)
        for j in range(K):
            acc = acc + y_back[slot1_tok[:, j]] * gate_vals[:, j:j + 1].astype(xb.dtype)
        # reassemble the full local token set from the tp routing peers
        y_full = jax.lax.all_gather(acc, "model", axis=0, tiled=True)  # (T_loc, D)
        return y_full.reshape(Bl, S, D)

    axis_names = set(a for a in dp_axes if a in mesh.axis_names) | {"model"}
    mapped = jax.shard_map(
        body, mesh=mesh,
        in_specs=(P(dp_axes if len(dp_axes) > 1 else dp_axes[0], None, None),
                  P(), P("model"), P("model"), P("model")),
        out_specs=P(dp_axes if len(dp_axes) > 1 else dp_axes[0], None, None),
        axis_names=axis_names,
        check_vma=False,  # the final all_gather replicates over 'model'
    )

    def pad_e(w):
        if E_pad == E:
            return w
        return jnp.concatenate(
            [w, jnp.zeros((E_pad - E,) + w.shape[1:], w.dtype)], axis=0)

    out = mapped(x, p["router"].astype(x.dtype),
                 pad_e(p["w_gate"].astype(x.dtype)),
                 pad_e(p["w_up"].astype(x.dtype)),
                 pad_e(p["w_down"].astype(x.dtype)))
    if cfg.n_shared:
        out = out + dense(p["shared"], x, cfg)
    return shard(out, "batch", "seq", "embed")


def init(b: Builder, cfg: FfnCfg):
    return init_moe(b, cfg) if cfg.moe else init_dense(b, cfg)


def forward(p, x: jax.Array, cfg: FfnCfg) -> jax.Array:
    if not cfg.moe:
        return dense(p, x, cfg)
    if _can_manual_ep(cfg, x):
        return moe_manual_ep(p, x, cfg)
    return moe(p, x, cfg)
