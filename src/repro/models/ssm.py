"""State-space blocks: Mamba-1 selective scan and RG-LRU (RecurrentGemma).

Both recurrences are evaluated with a *chunked associative scan*: the
sequence is split into chunks; within a chunk the linear recurrence runs as
``jax.lax.associative_scan`` (parallel, depth log C), and a ``lax.scan``
carries the state across chunks.  This bounds the scan workspace to one
chunk (VMEM-friendly) while keeping the sequential depth at S/C — the
standard TPU adaptation of CUDA selective-scan kernels (DESIGN.md §3).

Decode paths carry (conv_state, ssm_state) explicitly: O(1) per token, which
is what makes the 500k-context decode shape runnable for these families.
"""
from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp

from .common import Builder, shard

CHUNK = 256


@dataclasses.dataclass(frozen=True)
class MambaCfg:
    d_model: int
    d_state: int = 16
    d_conv: int = 4
    expand: int = 2

    @property
    def d_inner(self) -> int:
        return self.expand * self.d_model

    @property
    def dt_rank(self) -> int:
        return max(1, self.d_model // 16)


@dataclasses.dataclass(frozen=True)
class RGLRUCfg:
    d_model: int
    lru_width: int
    d_conv: int = 4
    c: float = 8.0  # RG-LRU forget-rate temperature


# ---------------------------------------------------------------------------
# shared linear-recurrence machinery:  h_t = a_t * h_{t-1} + b_t
# ---------------------------------------------------------------------------

def _assoc_combine(e1, e2):
    a1, b1 = e1
    a2, b2 = e2
    return a2 * a1, a2 * b1 + b2


def chunked_linear_scan(a: jax.Array, b: jax.Array, h0: jax.Array,
                        chunk: int = CHUNK) -> tuple[jax.Array, jax.Array]:
    """Scan h_t = a_t h_{t-1} + b_t along axis 1 (seq).  Returns (h_all, h_last).

    a, b: (B, S, ...); h0: (B, ...).  S must be a chunk multiple (callers pad).
    """
    B, S = a.shape[0], a.shape[1]
    chunk = min(chunk, S)
    if S % chunk:
        raise ValueError(f"seq {S} not a multiple of chunk {chunk}")
    n_chunks = S // chunk
    a_c = a.reshape((B, n_chunks, chunk) + a.shape[2:])
    b_c = b.reshape((B, n_chunks, chunk) + b.shape[2:])

    def step(h, ab):
        a_i, b_i = ab  # (B, chunk, ...)
        acc_a, acc_b = jax.lax.associative_scan(_assoc_combine, (a_i, b_i), axis=1)
        h_all = acc_a * h[:, None] + acc_b
        return h_all[:, -1], h_all

    # scan over chunks (axis 1): move chunk axis to front for lax.scan
    a_s = jnp.moveaxis(a_c, 1, 0)
    b_s = jnp.moveaxis(b_c, 1, 0)
    h_last, h_chunks = jax.lax.scan(step, h0, (a_s, b_s))
    h_all = jnp.moveaxis(h_chunks, 0, 1).reshape((B, S) + a.shape[2:])
    return h_all, h_last


def causal_conv1d(x: jax.Array, w: jax.Array, state: jax.Array | None = None):
    """Depthwise causal conv.  x: (B, S, C), w: (K, C).  Returns (y, new_state)
    where state carries the last K-1 inputs for decode."""
    K = w.shape[0]
    if state is None:
        pad = jnp.zeros((x.shape[0], K - 1, x.shape[2]), x.dtype)
    else:
        pad = state.astype(x.dtype)
    xp = jnp.concatenate([pad, x], axis=1)
    y = sum(xp[:, i:i + x.shape[1], :] * w[i][None, None, :] for i in range(K))
    return y, xp[:, -(K - 1):, :]


# ---------------------------------------------------------------------------
# Mamba-1
# ---------------------------------------------------------------------------

def init_mamba(b: Builder, cfg: MambaCfg):
    d, di, st, r = cfg.d_model, cfg.d_inner, cfg.d_state, cfg.dt_rank
    return {
        "in_proj": b.param((d, 2 * di), ("embed_w", "mlp")),
        "conv_w": b.param((cfg.d_conv, di), ("conv", "mlp"), scale=0.5),
        "x_proj": b.param((di, r + 2 * st), ("mlp", "lora")),
        "dt_proj": b.param((r, di), ("lora", "mlp")),
        "dt_bias": b.param((di,), ("mlp",), init="zeros"),
        "A_log": b.param((di, st), ("mlp", "state"), init="ones"),
        "D": b.param((di,), ("mlp",), init="ones"),
        "out_proj": b.param((di, d), ("mlp", "embed_w")),
    }


def _mamba_core(p, xz: jax.Array, cfg: MambaCfg, conv_state, ssm_state):
    """Shared train/decode body.  xz: (B, S, 2*di).

    The (B, S, di, st) transition tensors are never materialized at full
    sequence length: each chunk computes its own a/b terms, scans them, and
    immediately contracts against C — the TPU analogue of the fused CUDA
    selective-scan (workspace = one chunk in VMEM/HBM).
    """
    di, st = cfg.d_inner, cfg.d_state
    B, S = xz.shape[0], xz.shape[1]
    x, z = xz[..., :di], xz[..., di:]
    x, new_conv = causal_conv1d(x, p["conv_w"], conv_state)
    x = jax.nn.silu(x)
    x = shard(x, "batch", "seq", "mlp")

    proj = x @ p["x_proj"]                                  # (B,S,r+2st)
    dt = jax.nn.softplus(proj[..., :cfg.dt_rank] @ p["dt_proj"] + p["dt_bias"])
    Bm = proj[..., cfg.dt_rank:cfg.dt_rank + st]            # (B,S,st)
    Cm = proj[..., cfg.dt_rank + st:]                       # (B,S,st)
    A = -jnp.exp(p["A_log"].astype(jnp.float32))            # (di,st)

    if ssm_state is None:
        ssm_state = jnp.zeros((B, di, st), jnp.float32)
    chunk = min(CHUNK, S)
    if S % chunk:
        raise ValueError(f"seq {S} not a multiple of chunk {chunk}")
    n_chunks = S // chunk

    def to_chunks(t):
        return jnp.moveaxis(t.reshape((B, n_chunks, chunk) + t.shape[2:]), 1, 0)

    def step(h, inputs):
        dt_i, x_i, b_i, c_i = inputs                        # (B, chunk, ...)
        a_i = jnp.exp(dt_i[..., None].astype(jnp.float32) * A[None, None])
        bu_i = (dt_i * x_i)[..., None].astype(jnp.float32) * b_i[:, :, None, :].astype(jnp.float32)
        acc_a, acc_b = jax.lax.associative_scan(_assoc_combine, (a_i, bu_i), axis=1)
        h_all = acc_a * h[:, None] + acc_b                  # (B, chunk, di, st)
        y_i = jnp.einsum("bsdn,bsn->bsd", h_all, c_i.astype(jnp.float32))
        return h_all[:, -1], y_i

    h_last, y_chunks = jax.lax.scan(
        step, ssm_state, (to_chunks(dt), to_chunks(x), to_chunks(Bm), to_chunks(Cm)))
    y = jnp.moveaxis(y_chunks, 0, 1).reshape(B, S, di)
    y = (y.astype(x.dtype) + x * p["D"]) * jax.nn.silu(z)
    return y @ p["out_proj"], new_conv, h_last


def mamba(p, x: jax.Array, cfg: MambaCfg) -> jax.Array:
    """Training / prefill forward.  x: (B, S, D)."""
    xz = x @ p["in_proj"]
    y, _, _ = _mamba_core(p, xz, cfg, None, None)
    return shard(y, "batch", "seq", "embed")


def mamba_decode(p, x: jax.Array, cfg: MambaCfg, state: dict[str, Any]):
    """One-token step.  x: (B, 1, D); state: {'conv': (B,K-1,di), 'ssm': (B,di,st)}."""
    xz = x @ p["in_proj"]
    y, new_conv, new_ssm = _mamba_core(p, xz, cfg, state["conv"], state["ssm"])
    return y, {"conv": new_conv, "ssm": new_ssm}


def mamba_state(cfg: MambaCfg, batch: int) -> dict[str, Any]:
    return {
        "conv": jnp.zeros((batch, cfg.d_conv - 1, cfg.d_inner), jnp.bfloat16),
        "ssm": jnp.zeros((batch, cfg.d_inner, cfg.d_state), jnp.float32),
    }


# ---------------------------------------------------------------------------
# RG-LRU (RecurrentGemma recurrent block)
# ---------------------------------------------------------------------------

def init_rglru(b: Builder, cfg: RGLRUCfg):
    d, w = cfg.d_model, cfg.lru_width
    return {
        "in_x": b.param((d, w), ("embed_w", "mlp")),
        "in_gate": b.param((d, w), ("embed_w", "mlp")),
        "conv_w": b.param((cfg.d_conv, w), ("conv", "mlp"), scale=0.5),
        "gate_a": b.param((w, w), ("mlp", "mlp"), scale=0.01),
        "gate_x": b.param((w, w), ("mlp", "mlp"), scale=0.01),
        "lambda_p": b.param((w,), ("mlp",), init="ones"),
        "out": b.param((w, d), ("mlp", "embed_w")),
    }


def _rglru_core(p, x: jax.Array, cfg: RGLRUCfg, conv_state, rnn_state):
    u = x @ p["in_x"]
    gate_branch = jax.nn.gelu(x @ p["in_gate"], approximate=True)
    u, new_conv = causal_conv1d(u, p["conv_w"], conv_state)
    u = shard(u, "batch", "seq", "mlp")

    r = jax.nn.sigmoid(u @ p["gate_a"])                 # recurrence gate
    i = jax.nn.sigmoid(u @ p["gate_x"])                 # input gate
    log_a = -cfg.c * jax.nn.softplus(p["lambda_p"]).astype(jnp.float32) * r.astype(jnp.float32)
    a = jnp.exp(log_a)
    gated = (i * u).astype(jnp.float32)
    b = jnp.sqrt(jnp.maximum(1.0 - a * a, 1e-9)) * gated
    if rnn_state is None:
        rnn_state = jnp.zeros((x.shape[0], u.shape[-1]), jnp.float32)
    h_all, h_last = chunked_linear_scan(a, b, rnn_state)
    y = (h_all.astype(x.dtype) * gate_branch) @ p["out"]
    return y, new_conv, h_last


def rglru(p, x: jax.Array, cfg: RGLRUCfg) -> jax.Array:
    y, _, _ = _rglru_core(p, x, cfg, None, None)
    return shard(y, "batch", "seq", "embed")


def rglru_decode(p, x: jax.Array, cfg: RGLRUCfg, state: dict[str, Any]):
    y, new_conv, new_rnn = _rglru_core(p, x, cfg, state["conv"], state["rnn"])
    return y, {"conv": new_conv, "rnn": new_rnn}


def rglru_state(cfg: RGLRUCfg, batch: int) -> dict[str, Any]:
    return {
        "conv": jnp.zeros((batch, cfg.d_conv - 1, cfg.lru_width), jnp.bfloat16),
        "rnn": jnp.zeros((batch, cfg.lru_width), jnp.float32),
    }
