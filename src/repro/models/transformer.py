"""Config-driven decoder-only transformer LM.

One assembly covers seven assigned architectures: qwen3-4b (GQA+qk-norm),
granite-3-2b, smollm-360m, minitron-4b (dense GQA), granite-moe-3b (routed
MoE), deepseek-v3-671b (MLA + first-k-dense + shared-expert MoE), and the
paligemma-3b decoder (MQA + prefix embeds).  Layers are *grouped* by kind
and each group runs under ``jax.lax.scan`` over stacked params (HLO size
O(groups), not O(layers)), with configurable remat.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from . import attention, ffn
from .common import (Builder, cast_tree, rms_norm, shard, stack_layers,
                     stacked_spec)

LONG_PREFILL = 2048  # query-chunk attention above this (bounds logits VMEM/HBM)


def _attn_cfg(cfg) -> attention.AttnCfg:
    return attention.AttnCfg(
        d_model=cfg.d_model, n_heads=cfg.n_heads, n_kv=cfg.n_kv,
        head_dim=cfg.head_dim, qk_norm=cfg.qk_norm, rope_theta=cfg.rope_theta,
        mla=cfg.mla, q_lora=cfg.q_lora, kv_lora=cfg.kv_lora,
        qk_nope=cfg.qk_nope, qk_rope=cfg.qk_rope, v_dim=cfg.v_head,
        kv_quant=cfg.kv_quant,
    )


def _ffn_cfg(cfg, kind: str) -> ffn.FfnCfg:
    if kind == "moe":
        return ffn.FfnCfg(
            d_model=cfg.d_model, d_ff=cfg.moe_d_ff or cfg.d_ff, act=cfg.act,
            moe=True, n_experts=cfg.n_experts, top_k=cfg.top_k,
            n_shared=cfg.n_shared, shared_d_ff=(cfg.moe_d_ff or cfg.d_ff) * max(cfg.n_shared, 1),
            router_softmax=cfg.router_softmax, capacity_factor=cfg.capacity_factor,
        )
    return ffn.FfnCfg(d_model=cfg.d_model, d_ff=cfg.d_ff, act=cfg.act)


def layer_groups(cfg) -> list[tuple[int, str]]:
    """[(n_layers, 'dense'|'moe')] — deepseek-style first-k-dense supported."""
    if cfg.moe:
        k = cfg.first_k_dense
        groups = []
        if k:
            groups.append((k, "dense"))
        groups.append((cfg.n_layers - k, "moe"))
        return groups
    return [(cfg.n_layers, "dense")]


# ---------------------------------------------------------------------------
# init
# ---------------------------------------------------------------------------

def init(cfg, key: jax.Array):
    """Returns (params, logical-spec tree)."""
    b = Builder(key, dtype=cfg.param_dtype)
    acfg = _attn_cfg(cfg)

    def one_layer(kind: str):
        return {
            "ln1": b.param((cfg.d_model,), ("embed",), init="zeros"),
            "attn": attention.init(b, acfg),
            "ln2": b.param((cfg.d_model,), ("embed",), init="zeros"),
            "ffn": ffn.init(b, _ffn_cfg(cfg, kind)),
        }

    groups_p, groups_s = [], []
    for count, kind in layer_groups(cfg):
        layers = [one_layer(kind) for _ in range(count)]
        vals = [Builder.split(l)[0] for l in layers]
        spec = Builder.split(layers[0])[1]
        groups_p.append(stack_layers(vals))
        groups_s.append(stacked_spec(spec))

    tree = {
        "embed": b.param((cfg.vocab, cfg.d_model), ("vocab", "embed"),
                         scale=1.0 / cfg.d_model ** 0.5),
        "ln_f": b.param((cfg.d_model,), ("embed",), init="zeros"),
        "lm_head": b.param((cfg.d_model, cfg.vocab), ("embed_w", "vocab")),
    }
    params, specs = Builder.split(tree)
    params["groups"] = groups_p
    specs["groups"] = groups_s
    return params, specs


# ---------------------------------------------------------------------------
# forward
# ---------------------------------------------------------------------------

def _layer_forward(cfg, kind: str, lp, x, positions, long_seq: bool):
    lp = cast_tree(lp, cfg.compute_dtype)
    acfg = _attn_cfg(cfg)
    h = rms_norm(x, lp["ln1"], cfg.norm_eps)
    if long_seq:
        h = attention.forward_chunked(lp["attn"], h, acfg, positions)
    else:
        h = attention.forward(lp["attn"], h, acfg, positions)
    x = x + h
    h = rms_norm(x, lp["ln2"], cfg.norm_eps)
    x = x + ffn.forward(lp["ffn"], h, _ffn_cfg(cfg, kind))
    return x


def _run_groups(cfg, params, x, positions, *, long_seq: bool):
    for (count, kind), gp in zip(layer_groups(cfg), params["groups"]):
        if cfg.fsdp_bf16_gather:
            # cast the sharded master weights BEFORE the scan: the FSDP
            # all-gather then moves bf16 (2x fewer collective bytes); the
            # f32 master stays the optimizer's copy (autodiff casts back)
            gp = cast_tree(gp, cfg.compute_dtype)
        body = functools.partial(_layer_forward, cfg, kind)

        def step(carry, lp):
            return body(lp, carry, positions, long_seq), None

        if cfg.remat != "none":
            policy = (jax.checkpoint_policies.dots_with_no_batch_dims_saveable
                      if cfg.remat == "dots" else None)
            step = jax.checkpoint(step, policy=policy, prevent_cse=False)
        x, _ = jax.lax.scan(step, x, gp)
    return x


def embed_tokens(cfg, params, tokens: jax.Array) -> jax.Array:
    x = params["embed"].astype(cfg.compute_dtype)[tokens]
    x = x * jnp.asarray(cfg.d_model ** 0.5, cfg.compute_dtype)
    return shard(x, "batch", "seq", "embed")


def hidden_states(cfg, params, batch: dict[str, jax.Array]) -> jax.Array:
    """Token (+ optional prefix) embedding -> final hidden states."""
    tokens = batch["tokens"]
    x = embed_tokens(cfg, params, tokens)
    if cfg.prefix_tokens:
        # VLM stub frontend: precomputed patch embeddings (assignment spec)
        prefix = batch["prefix_embeds"].astype(cfg.compute_dtype)
        x = jnp.concatenate([prefix, x], axis=1)
    B, S, _ = x.shape
    positions = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32), (B, S))
    x = _run_groups(cfg, params, x, positions, long_seq=S > LONG_PREFILL)
    return rms_norm(x, params["ln_f"], cfg.norm_eps)


def logits_fn(cfg, params, x: jax.Array) -> jax.Array:
    logits = x @ params["lm_head"].astype(cfg.compute_dtype)
    return shard(logits, "batch", "seq", "vocab")


def full_logits(cfg, params, batch: dict[str, jax.Array]) -> jax.Array:
    """Logits at every (text) position — decode-parity tests/serving."""
    x = hidden_states(cfg, params, batch)
    if cfg.prefix_tokens:
        x = x[:, cfg.prefix_tokens:, :]
    return logits_fn(cfg, params, x).astype(jnp.float32)


def loss_fn(cfg, params, batch: dict[str, jax.Array]) -> jax.Array:
    """Next-token cross entropy (mean over tokens)."""
    x = hidden_states(cfg, params, batch)
    if cfg.prefix_tokens:
        x = x[:, cfg.prefix_tokens:, :]  # loss only on text positions
    logits = logits_fn(cfg, params, x[:, :-1, :]).astype(jnp.float32)
    targets = batch["tokens"][:, 1:]
    logz = jax.nn.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, targets[..., None], axis=-1)[..., 0]
    return jnp.mean(logz - gold)


# ---------------------------------------------------------------------------
# serving
# ---------------------------------------------------------------------------

def init_cache(cfg, batch: int, max_len: int):
    """Per-group stacked KV caches (+ scalar position)."""
    acfg = _attn_cfg(cfg)
    caches = []
    for count, _ in layer_groups(cfg):
        one = attention.init_cache(acfg, batch, max_len, dtype=cfg.compute_dtype)
        caches.append(jax.tree.map(
            lambda l, _c=count: jnp.tile(l[None], (_c,) + (1,) * l.ndim), one))
    return {"layers": caches, "pos": jnp.zeros((), jnp.int32)}


def cache_specs(cfg, batch: int, max_len: int):
    """Logical sharding specs matching init_cache output."""
    def spec_of(name, leaf):
        if leaf.ndim >= 4:   # (L, B, S, kv, hd)
            return ("layers", "batch", "kv_seq", "kv_heads", None)
        if leaf.ndim == 3:   # (L, B, S) scales or latent w/o head dim
            return ("layers", "batch", "kv_seq")
        return tuple(None for _ in leaf.shape)
    cache = jax.eval_shape(lambda: init_cache(cfg, batch, max_len))
    return jax.tree.map(lambda l: spec_of("", l), cache)


def decode_step(cfg, params, tokens: jax.Array, cache):
    """One decode step for the whole stack.  tokens: (B, 1) int32."""
    acfg = _attn_cfg(cfg)
    x = embed_tokens(cfg, params, tokens)
    pos = cache["pos"]
    new_layers = []
    for (_count, kind), gp, gc in zip(layer_groups(cfg), params["groups"], cache["layers"]):

        def step(carry, scanned, _kind=kind):
            lp, lc = scanned
            lp = cast_tree(lp, cfg.compute_dtype)
            h = rms_norm(carry, lp["ln1"], cfg.norm_eps)
            h, lc = attention.decode_step(lp["attn"], h, acfg, lc, pos)
            carry = carry + h
            h = rms_norm(carry, lp["ln2"], cfg.norm_eps)
            carry = carry + ffn.forward(lp["ffn"], h, _ffn_cfg(cfg, _kind))
            return carry, lc

        x, new_gc = jax.lax.scan(step, x, (gp, gc))
        new_layers.append(new_gc)
    x = rms_norm(x, params["ln_f"], cfg.norm_eps)
    logits = logits_fn(cfg, params, x)
    return logits, {"layers": new_layers, "pos": pos + 1}


def prefill(cfg, params, batch: dict[str, jax.Array], max_len: int):
    """Full-sequence forward that also builds the decode cache.

    Returns (last-position logits, cache).  KV entries are produced by a
    second pass over the hidden states (prefill is compute-dominated by the
    main pass; the extra projections are O(S·D·kv·hd)).
    """
    acfg = _attn_cfg(cfg)
    tokens = batch["tokens"]
    x = embed_tokens(cfg, params, tokens)
    if cfg.prefix_tokens:
        prefix = batch["prefix_embeds"].astype(cfg.compute_dtype)
        x = jnp.concatenate([prefix, x], axis=1)
    B, S, _ = x.shape
    positions = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32), (B, S))
    long_seq = S > LONG_PREFILL

    caches = []
    for (_count, kind), gp in zip(layer_groups(cfg), params["groups"]):

        def step(carry, lp, _kind=kind):
            lp = cast_tree(lp, cfg.compute_dtype)
            kv_in = rms_norm(carry, lp["ln1"], cfg.norm_eps)
            kv = attention.project_kv(lp["attn"], kv_in, acfg, positions)
            out = _layer_forward(cfg, _kind, lp, carry, positions, long_seq)
            return out, kv

        if cfg.remat != "none":
            step = jax.checkpoint(step, prevent_cse=False)
        x, kv_stack = jax.lax.scan(step, x, gp)   # kv leaves: (L, B, S, ...)
        pad = max_len - S
        kv_stack = jax.tree.map(
            lambda l: jnp.pad(l, ((0, 0), (0, 0), (0, pad)) + ((0, 0),) * (l.ndim - 3)),
            kv_stack)
        caches.append(kv_stack)
    x = rms_norm(x, params["ln_f"], cfg.norm_eps)
    logits = logits_fn(cfg, params, x[:, -1:, :])
    return logits, {"layers": caches, "pos": jnp.asarray(S, jnp.int32)}
