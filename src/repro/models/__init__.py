"""Model zoo: 10 assigned architectures behind one functional facade."""
from .zoo import Model, get_model, input_specs, make_batch
