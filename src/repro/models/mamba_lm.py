"""Falcon-Mamba-style attention-free LM (mamba-1 blocks, no MLP).

64 identical blocks: ``x += mamba(rms_norm(x))``; pure SSM (d_ff = 0 in the
assignment spec).  Decode carries (conv, ssm) states — O(1) per token, so
the 500k-context decode cell is runnable (DESIGN.md §4).
"""
from __future__ import annotations


import jax
import jax.numpy as jnp

from . import ssm
from .common import (Builder, cast_tree, rms_norm, shard, stack_layers,
                     stacked_spec)


def _mcfg(cfg) -> ssm.MambaCfg:
    return ssm.MambaCfg(d_model=cfg.d_model, d_state=cfg.ssm_state,
                        d_conv=cfg.d_conv, expand=cfg.expand)


def init(cfg, key: jax.Array):
    b = Builder(key, dtype=cfg.param_dtype)
    mcfg = _mcfg(cfg)

    def one_layer():
        return {"ln": b.param((cfg.d_model,), ("embed",), init="zeros"),
                "mixer": ssm.init_mamba(b, mcfg)}

    layers = [one_layer() for _ in range(cfg.n_layers)]
    vals = [Builder.split(l)[0] for l in layers]
    spec = stacked_spec(Builder.split(layers[0])[1])
    tree = {
        "embed": b.param((cfg.vocab, cfg.d_model), ("vocab", "embed"),
                         scale=1.0 / cfg.d_model ** 0.5),
        "ln_f": b.param((cfg.d_model,), ("embed",), init="zeros"),
        "lm_head": b.param((cfg.d_model, cfg.vocab), ("embed_w", "vocab")),
    }
    params, specs = Builder.split(tree)
    params["layers"] = stack_layers(vals)
    specs["layers"] = spec
    return params, specs


def _embed(cfg, params, tokens):
    x = params["embed"].astype(cfg.compute_dtype)[tokens]
    return shard(x, "batch", "seq", "embed")


def hidden_states(cfg, params, batch: dict[str, jax.Array]) -> jax.Array:
    x = _embed(cfg, params, batch["tokens"])
    mcfg = _mcfg(cfg)

    def step(carry, lp):
        lp = cast_tree(lp, cfg.compute_dtype)
        h = rms_norm(carry, lp["ln"], cfg.norm_eps)
        return carry + ssm.mamba(lp["mixer"], h, mcfg), None

    if cfg.remat != "none":
        step = jax.checkpoint(step, prevent_cse=False)
    x, _ = jax.lax.scan(step, x, params["layers"])
    return rms_norm(x, params["ln_f"], cfg.norm_eps)


def full_logits(cfg, params, batch: dict[str, jax.Array]) -> jax.Array:
    x = hidden_states(cfg, params, batch)
    return (x @ params["lm_head"].astype(cfg.compute_dtype)).astype(jnp.float32)


def loss_fn(cfg, params, batch: dict[str, jax.Array]) -> jax.Array:
    x = hidden_states(cfg, params, batch)
    logits = (x[:, :-1, :] @ params["lm_head"].astype(cfg.compute_dtype)
              ).astype(jnp.float32)
    logits = shard(logits, "batch", "seq", "vocab")
    targets = batch["tokens"][:, 1:]
    logz = jax.nn.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, targets[..., None], axis=-1)[..., 0]
    return jnp.mean(logz - gold)


def init_cache(cfg, batch: int, max_len: int):
    """State cache is O(1) in context length — max_len unused by design."""
    one = ssm.mamba_state(_mcfg(cfg), batch)
    layers = jax.tree.map(lambda l: jnp.tile(l[None], (cfg.n_layers,) + (1,) * l.ndim), one)
    return {"layers": layers, "pos": jnp.zeros((), jnp.int32)}


def cache_specs(cfg, batch: int, max_len: int):
    cache = jax.eval_shape(lambda: init_cache(cfg, batch, max_len))
    return jax.tree.map(
        lambda l: ("layers", "batch", "mlp") if l.ndim == 3
        else (("layers", "batch", None, "mlp") if l.ndim == 4 else
              tuple(None for _ in l.shape)),
        cache)


def decode_step(cfg, params, tokens: jax.Array, cache):
    x = _embed(cfg, params, tokens)
    mcfg = _mcfg(cfg)

    def step(carry, scanned):
        lp, lc = scanned
        lp = cast_tree(lp, cfg.compute_dtype)
        h = rms_norm(carry, lp["ln"], cfg.norm_eps)
        h, lc = ssm.mamba_decode(lp["mixer"], h, mcfg, lc)
        return carry + h, lc

    x, new_layers = jax.lax.scan(step, x, (params["layers"], cache["layers"]))
    x = rms_norm(x, params["ln_f"], cfg.norm_eps)
    logits = x @ params["lm_head"].astype(cfg.compute_dtype)
    return logits, {"layers": new_layers, "pos": cache["pos"] + 1}


def prefill(cfg, params, batch: dict[str, jax.Array], max_len: int):
    """Run the sequence through, carrying final states into the cache."""
    x = _embed(cfg, params, batch["tokens"])
    mcfg = _mcfg(cfg)
    B, S, _ = x.shape

    def step(carry, lp):
        lp = cast_tree(lp, cfg.compute_dtype)
        h = rms_norm(carry, lp["ln"], cfg.norm_eps)
        xz = h @ lp["mixer"]["in_proj"]
        y, conv_s, ssm_s = ssm._mamba_core(lp["mixer"], xz, mcfg, None, None)
        return carry + y, {"conv": conv_s.astype(jnp.bfloat16), "ssm": ssm_s}

    if cfg.remat != "none":
        step = jax.checkpoint(step, prevent_cse=False)
    x, states = jax.lax.scan(step, x, params["layers"])
    x = rms_norm(x, params["ln_f"], cfg.norm_eps)
    logits = x[:, -1:, :] @ params["lm_head"].astype(cfg.compute_dtype)
    return logits, {"layers": states, "pos": jnp.asarray(S, jnp.int32)}
