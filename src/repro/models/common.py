"""Shared model-building blocks: params-with-sharding builder, norms, RoPE.

Design goals (MaxText-style, no external NN library):

* **Functional params**: nested dicts of arrays.  A :class:`Builder` creates
  each parameter together with its *logical sharding spec*; ``init`` returns
  ``(params, specs)`` trees of identical structure, so the launcher can map
  specs -> ``NamedSharding`` for any mesh (with divisibility fallback).
* **Scan-friendly**: per-layer params are stacked on a leading ``layers``
  axis and consumed by ``jax.lax.scan`` — keeps HLO size O(1) in depth,
  which keeps 61-layer 671B configs compilable in seconds.
* **Logical axes**: ``batch, seq, embed, heads, kv_heads, head_dim, mlp,
  vocab, experts, layers, conv, state`` — resolved per-mesh by
  ``repro.launch.mesh.logical_rules``.
"""
from __future__ import annotations
from collections.abc import Sequence

import dataclasses
import math
from typing import Any

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

Params = dict[str, Any]
Specs = dict[str, Any]

# ---------------------------------------------------------------------------
# logical sharding
# ---------------------------------------------------------------------------

# resolved by launch.mesh: logical name -> mesh axis (or None)
DEFAULT_RULES: dict[str, str | None] = {
    "batch": ("pod", "data"),
    "seq": None,
    "seq_tp": "model",      # sequence-parallel fallback (heads % tp != 0)
    "embed": None,          # replicated activations on embed dim
    "embed_w": "data",      # FSDP: weight embed dim sharded over data
    "heads": "model",
    "kv_heads": "model",
    "head_dim": None,
    "mlp": "model",
    "vocab": "model",
    "experts": "model",
    "expert_cap": "data",   # MoE dispatch capacity rows over the DP axes
    "layers": None,
    "conv": None,
    "state": None,
    "kv_seq": None,
    "lora": None,
}


class ShardingCtx:
    """Trace-time context mapping logical axes to mesh axes (or no-op)."""

    def __init__(self):
        self.mesh = None
        self.rules: dict[str, str | None] = dict(DEFAULT_RULES)
        self.manual_dp = False  # True inside a shard_map manual-DP body

    def activate(self, mesh, rules: dict[str, str | None]):
        self.mesh = mesh
        self.rules = rules

    def deactivate(self):
        self.mesh = None
        self.rules = dict(DEFAULT_RULES)

    def resolve(self, logical: Sequence[str | None], shape: tuple[int, ...]) -> P:
        """Logical axes -> PartitionSpec, dropping non-divisible axes and
        duplicate mesh-axis uses (first dim wins)."""
        axes = []
        used = set()
        for dim, name in zip(shape, logical):
            mesh_axis = self.rules.get(name) if name else None
            if mesh_axis is None or self.mesh is None:
                axes.append(None)
                continue
            ax_tuple = mesh_axis if isinstance(mesh_axis, tuple) else (mesh_axis,)
            if any(a in used for a in ax_tuple):
                axes.append(None)
                continue
            size = 1
            for a in ax_tuple:
                size *= self.mesh.shape[a]
            if dim % size == 0:
                axes.append(mesh_axis)
                used.update(ax_tuple)
            else:
                axes.append(None)
        return P(*axes)


CTX = ShardingCtx()


def axis_size(logical: str) -> int:
    """Mesh extent behind a logical axis (1 when no mesh is active)."""
    if CTX.mesh is None:
        return 1
    mesh_axis = CTX.rules.get(logical)
    if mesh_axis is None:
        return 1
    size = 1
    for a in (mesh_axis if isinstance(mesh_axis, tuple) else (mesh_axis,)):
        size *= CTX.mesh.shape[a]
    return size


def shard(x: jax.Array, *logical: str | None) -> jax.Array:
    """Activation sharding constraint by logical axes (no-op without mesh).

    Inside a partial-manual shard_map body (``CTX.manual_dp``) constraints
    are skipped entirely: values there carry a manual-axis vma that
    with_sharding_constraint rejects; GSPMD still propagates the auto
    (model) axis shardings from the parameter shardings.
    """
    if CTX.mesh is None or CTX.manual_dp:
        return x
    spec = CTX.resolve(logical, x.shape)
    return jax.lax.with_sharding_constraint(
        x, jax.sharding.NamedSharding(CTX.mesh, spec)
    )


# ---------------------------------------------------------------------------
# parameter builder
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class Builder:
    """Creates params and records their logical sharding specs.

    ``key=None`` puts the builder in *abstract* mode: params are
    ShapeDtypeStruct stand-ins (no allocation, no RNG) — the dry-run path.
    """

    key: jax.Array | None
    dtype: Any = jnp.float32

    def _next(self) -> jax.Array:
        self.key, sub = jax.random.split(self.key)
        return sub

    def param(self, shape: tuple[int, ...], logical: tuple[str | None, ...],
              *, scale: float | None = None, init: str = "normal"):
        if len(shape) != len(logical):
            raise ValueError(f"shape {shape} vs logical {logical}")
        if self.key is None:
            return jax.ShapeDtypeStruct(shape, self.dtype), logical
        if init == "zeros":
            value = jnp.zeros(shape, self.dtype)
        elif init == "ones":
            value = jnp.ones(shape, self.dtype)
        else:
            if scale is None:
                fan_in = shape[0] if len(shape) > 1 else shape[-1]
                scale = 1.0 / math.sqrt(max(fan_in, 1))
            value = (jax.random.normal(self._next(), shape, jnp.float32) * scale
                     ).astype(self.dtype)
        return value, logical

    @staticmethod
    def split(tree):
        """(value, logical) leaf tree -> (params, specs)."""
        is_leaf = lambda x: isinstance(x, tuple) and len(x) == 2 and isinstance(x[1], tuple) and (
            len(x[1]) == 0 or isinstance(x[1][0], (str, type(None))))
        params = jax.tree.map(lambda l: l[0], tree, is_leaf=is_leaf)
        specs = jax.tree.map(lambda l: l[1], tree, is_leaf=is_leaf)
        return params, specs


def stack_layers(layer_trees: Sequence[Params]) -> Params:
    """Stack identical per-layer trees on a new leading ``layers`` axis."""
    def stack(*xs):
        if isinstance(xs[0], jax.ShapeDtypeStruct):  # abstract mode
            return jax.ShapeDtypeStruct((len(xs),) + xs[0].shape, xs[0].dtype)
        return jnp.stack(xs, axis=0)
    return jax.tree.map(stack, *layer_trees)


def stacked_spec(spec_tree: Specs) -> Specs:
    """Prepend the ``layers`` logical axis to every spec in a layer tree."""
    is_leaf = lambda x: isinstance(x, tuple) and (len(x) == 0 or isinstance(x[0], (str, type(None))))
    return jax.tree.map(lambda s: ("layers",) + s, spec_tree, is_leaf=is_leaf)


# ---------------------------------------------------------------------------
# numerics
# ---------------------------------------------------------------------------

def cast_tree(tree, dtype):
    """Cast float params to the compute dtype (master copies stay f32)."""
    return jax.tree.map(
        lambda w: w.astype(dtype) if jnp.issubdtype(w.dtype, jnp.floating) else w,
        tree)


def rms_norm(x: jax.Array, gamma: jax.Array, eps: float = 1e-6) -> jax.Array:
    dt = x.dtype
    x = x.astype(jnp.float32)
    var = jnp.mean(x * x, axis=-1, keepdims=True)
    return (x * jax.lax.rsqrt(var + eps) * (1.0 + gamma.astype(jnp.float32))).astype(dt)


def rope(x: jax.Array, positions: jax.Array, theta: float = 10000.0) -> jax.Array:
    """Rotary embedding; x: (..., seq, heads, head_dim), positions: (..., seq)."""
    hd = x.shape[-1]
    half = hd // 2
    freqs = theta ** (-jnp.arange(0, half, dtype=jnp.float32) / half)
    angles = positions[..., :, None].astype(jnp.float32) * freqs  # (..., seq, half)
    cos = jnp.cos(angles)[..., None, :]  # broadcast over heads
    sin = jnp.sin(angles)[..., None, :]
    x1, x2 = x[..., :half], x[..., half:]
    out = jnp.concatenate(
        [x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


def swiglu(gate: jax.Array, up: jax.Array) -> jax.Array:
    return jax.nn.silu(gate) * up


def gelu_glu(gate: jax.Array, up: jax.Array) -> jax.Array:
    return jax.nn.gelu(gate, approximate=True) * up


def causal_mask(q_len: int, kv_len: int, *, window: int | None = None,
                q_offset: jax.Array | int = 0) -> jax.Array:
    """Boolean (q_len, kv_len) mask; True = attend.  ``window`` gives local
    (sliding) attention; ``q_offset`` positions queries inside a longer KV."""
    q_pos = jnp.arange(q_len)[:, None] + q_offset
    kv_pos = jnp.arange(kv_len)[None, :]
    mask = kv_pos <= q_pos
    if window is not None:
        mask &= kv_pos > q_pos - window
    return mask


def sinusoidal_positions(n: int, d: int) -> jax.Array:
    pos = jnp.arange(n, dtype=jnp.float32)[:, None]
    dim = jnp.arange(0, d, 2, dtype=jnp.float32)[None, :]
    angle = pos / jnp.power(10000.0, dim / d)
    out = jnp.zeros((n, d), jnp.float32)
    out = out.at[:, 0::2].set(jnp.sin(angle))
    out = out.at[:, 1::2].set(jnp.cos(angle))
    return out
