"""Whisper-style encoder-decoder backbone (audio frontend stubbed).

Per the assignment, the conv frontend is a STUB: ``input_specs()`` provides
precomputed frame embeddings (B, n_frames, d_model) — the backbone is the
deliverable.  Encoder: bidirectional self-attention; decoder: causal
self-attention + cross-attention.  Sinusoidal positions (whisper uses
absolute positions, not RoPE).
"""
from __future__ import annotations


import jax
import jax.numpy as jnp

from . import attention, ffn
from .common import (Builder, cast_tree, rms_norm, shard,
                     sinusoidal_positions, stack_layers, stacked_spec)


def _acfg(cfg) -> attention.AttnCfg:
    return attention.AttnCfg(
        d_model=cfg.d_model, n_heads=cfg.n_heads, n_kv=cfg.n_kv,
        head_dim=cfg.head_dim, use_rope=False, kv_quant=cfg.kv_quant)


def init(cfg, key: jax.Array):
    b = Builder(key, dtype=cfg.param_dtype)
    acfg = _acfg(cfg)

    def enc_layer():
        return {"ln1": b.param((cfg.d_model,), ("embed",), init="zeros"),
                "attn": attention.init(b, acfg),
                "ln2": b.param((cfg.d_model,), ("embed",), init="zeros"),
                "mlp": ffn.init_plain(b, cfg.d_model, cfg.d_ff)}

    def dec_layer():
        return {"ln1": b.param((cfg.d_model,), ("embed",), init="zeros"),
                "attn": attention.init(b, acfg),
                "ln_x": b.param((cfg.d_model,), ("embed",), init="zeros"),
                "xattn": attention.init(b, acfg),
                "ln2": b.param((cfg.d_model,), ("embed",), init="zeros"),
                "mlp": ffn.init_plain(b, cfg.d_model, cfg.d_ff)}

    enc = [enc_layer() for _ in range(cfg.enc_layers)]
    dec = [dec_layer() for _ in range(cfg.n_layers)]
    tree = {
        "embed": b.param((cfg.vocab, cfg.d_model), ("vocab", "embed"),
                         scale=1.0 / cfg.d_model ** 0.5),
        "ln_enc": b.param((cfg.d_model,), ("embed",), init="zeros"),
        "ln_f": b.param((cfg.d_model,), ("embed",), init="zeros"),
        "lm_head": b.param((cfg.d_model, cfg.vocab), ("embed_w", "vocab")),
    }
    params, specs = Builder.split(tree)
    params["enc"] = stack_layers([Builder.split(l)[0] for l in enc])
    specs["enc"] = stacked_spec(Builder.split(enc[0])[1])
    params["dec"] = stack_layers([Builder.split(l)[0] for l in dec])
    specs["dec"] = stacked_spec(Builder.split(dec[0])[1])
    return params, specs


def encode(cfg, params, frames: jax.Array) -> jax.Array:
    """frames: (B, F, d_model) stub embeddings -> encoder output."""
    acfg = _acfg(cfg)
    B, F, _ = frames.shape
    x = frames.astype(cfg.compute_dtype) + sinusoidal_positions(F, cfg.d_model
                                                                ).astype(cfg.compute_dtype)
    x = shard(x, "batch", "seq", "embed")
    positions = jnp.broadcast_to(jnp.arange(F, dtype=jnp.int32), (B, F))
    bidir = attention.AttnCfg(**{**acfg.__dict__, "causal": False})

    def step(carry, lp):
        lp = cast_tree(lp, cfg.compute_dtype)
        h = rms_norm(carry, lp["ln1"], cfg.norm_eps)
        carry = carry + attention.forward(lp["attn"], h, bidir, positions)
        h = rms_norm(carry, lp["ln2"], cfg.norm_eps)
        return carry + ffn.plain(lp["mlp"], h), None

    if cfg.remat != "none":
        step = jax.checkpoint(step, prevent_cse=False)
    x, _ = jax.lax.scan(step, x, params["enc"])
    return rms_norm(x, params["ln_enc"], cfg.norm_eps)


def _dec_embed(cfg, params, tokens, pos0=0):
    B, S = tokens.shape
    x = params["embed"].astype(cfg.compute_dtype)[tokens]
    pe = sinusoidal_positions(pos0 + S, cfg.d_model)[pos0:].astype(cfg.compute_dtype)
    return shard(x + pe, "batch", "seq", "embed")


def decode_train(cfg, params, tokens: jax.Array, enc_out: jax.Array) -> jax.Array:
    acfg = _acfg(cfg)
    B, S = tokens.shape
    x = _dec_embed(cfg, params, tokens)
    positions = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32), (B, S))

    def step(carry, lp):
        lp = cast_tree(lp, cfg.compute_dtype)
        h = rms_norm(carry, lp["ln1"], cfg.norm_eps)
        if S > 2048:
            carry = carry + attention.forward_chunked(lp["attn"], h, acfg, positions)
        else:
            carry = carry + attention.forward(lp["attn"], h, acfg, positions)
        h = rms_norm(carry, lp["ln_x"], cfg.norm_eps)
        carry = carry + attention.cross_forward(lp["xattn"], h, enc_out, acfg)
        h = rms_norm(carry, lp["ln2"], cfg.norm_eps)
        return carry + ffn.plain(lp["mlp"], h), None

    if cfg.remat != "none":
        step = jax.checkpoint(step, prevent_cse=False)
    x, _ = jax.lax.scan(step, x, params["dec"])
    return rms_norm(x, params["ln_f"], cfg.norm_eps)


def full_logits(cfg, params, batch: dict[str, jax.Array]) -> jax.Array:
    enc_out = encode(cfg, params, batch["frames"])
    x = decode_train(cfg, params, batch["tokens"], enc_out)
    return (x @ params["lm_head"].astype(cfg.compute_dtype)).astype(jnp.float32)


def loss_fn(cfg, params, batch: dict[str, jax.Array]) -> jax.Array:
    enc_out = encode(cfg, params, batch["frames"])
    x = decode_train(cfg, params, batch["tokens"], enc_out)
    logits = (x[:, :-1, :] @ params["lm_head"].astype(cfg.compute_dtype)
              ).astype(jnp.float32)
    logits = shard(logits, "batch", "seq", "vocab")
    targets = batch["tokens"][:, 1:]
    logz = jax.nn.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, targets[..., None], axis=-1)[..., 0]
    return jnp.mean(logz - gold)


# ---------------------------------------------------------------------------
# serving: decoder self cache + precomputed cross KV
# ---------------------------------------------------------------------------

def init_cache(cfg, batch: int, max_len: int):
    acfg = _acfg(cfg)
    self_c = attention.init_cache(acfg, batch, max_len, dtype=cfg.compute_dtype)
    cross = {"k": jnp.zeros((batch, cfg.enc_frames, cfg.n_kv, cfg.head_dim), cfg.compute_dtype),
             "v": jnp.zeros((batch, cfg.enc_frames, cfg.n_kv, cfg.head_dim), cfg.compute_dtype)}
    one = {"self": self_c, "cross": cross}
    layers = jax.tree.map(lambda l: jnp.tile(l[None], (cfg.n_layers,) + (1,) * l.ndim), one)
    return {"layers": layers, "pos": jnp.zeros((), jnp.int32)}


def cache_specs(cfg, batch: int, max_len: int):
    cache = jax.eval_shape(lambda: init_cache(cfg, batch, max_len))
    return jax.tree.map(
        lambda l: ("layers", "batch", "kv_seq", "kv_heads", None) if l.ndim == 5
        else tuple(None for _ in l.shape), cache)


def decode_step(cfg, params, tokens: jax.Array, cache):
    acfg = _acfg(cfg)
    pos = cache["pos"]
    max_len = cache["layers"]["self"]["k"].shape[2]
    pe = sinusoidal_positions(max_len, cfg.d_model)
    x = (params["embed"].astype(cfg.compute_dtype)[tokens]
         + jax.lax.dynamic_slice_in_dim(pe, pos, 1, axis=0)[None].astype(cfg.compute_dtype))

    def step(carry, scanned):
        lp, lc = scanned
        lp = cast_tree(lp, cfg.compute_dtype)
        h = rms_norm(carry, lp["ln1"], cfg.norm_eps)
        h, new_self = attention.decode_step(lp["attn"], h, acfg, lc["self"], pos)
        carry = carry + h
        h = rms_norm(carry, lp["ln_x"], cfg.norm_eps)
        q = (h @ lp["xattn"]["wq"]).reshape(h.shape[0], 1, cfg.n_heads, cfg.head_dim)
        ctx = attention.sdpa(q, lc["cross"]["k"].astype(h.dtype),
                             lc["cross"]["v"].astype(h.dtype), None,
                             1.0 / cfg.head_dim ** 0.5)
        ctx = ctx.reshape(h.shape[0], 1, cfg.n_heads * cfg.head_dim)
        carry = carry + ctx @ lp["xattn"]["wo"]
        h = rms_norm(carry, lp["ln2"], cfg.norm_eps)
        carry = carry + ffn.plain(lp["mlp"], h)
        return carry, {"self": new_self, "cross": lc["cross"]}

    x, new_layers = jax.lax.scan(step, x, (params["dec"], cache["layers"]))
    x = rms_norm(x, params["ln_f"], cfg.norm_eps)
    logits = x @ params["lm_head"].astype(cfg.compute_dtype)
    return logits, {"layers": new_layers, "pos": pos + 1}
