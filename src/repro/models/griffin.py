"""RecurrentGemma-style hybrid LM (Griffin): RG-LRU + local attention, 1:2.

Layer pattern: (recurrent, recurrent, local-attention) repeated; each layer
is temporal-mix + GeGLU MLP with pre-norms.  38 layers = 12 full periods +
2 trailing recurrent layers (scanned periods keep the HLO small; the
remainder runs unscanned).  Local attention uses a *ring buffer* KV cache of
exactly ``window`` slots, so decode memory is O(window) — with the RG-LRU's
O(1) state this is what makes the 500k-context decode cell runnable.
"""
from __future__ import annotations


import jax
import jax.numpy as jnp

from . import attention, ffn, ssm
from .common import (Builder, cast_tree, rms_norm, shard, stack_layers,
                     stacked_spec)

PERIOD = ("rec", "rec", "attn")


def _acfg(cfg) -> attention.AttnCfg:
    return attention.AttnCfg(
        d_model=cfg.d_model, n_heads=cfg.n_heads, n_kv=cfg.n_kv,
        head_dim=cfg.head_dim, rope_theta=cfg.rope_theta,
        window=cfg.window, kv_quant=cfg.kv_quant)


def _rcfg(cfg) -> ssm.RGLRUCfg:
    return ssm.RGLRUCfg(d_model=cfg.d_model, lru_width=cfg.lru_width or cfg.d_model)


def _pattern(cfg):
    n_periods = cfg.n_layers // len(PERIOD)
    remainder = tuple(PERIOD[: cfg.n_layers % len(PERIOD)])
    return n_periods, remainder


def init(cfg, key: jax.Array):
    b = Builder(key, dtype=cfg.param_dtype)

    def mix_layer(kind: str):
        mixer = (ssm.init_rglru(b, _rcfg(cfg)) if kind == "rec"
                 else attention.init(b, _acfg(cfg)))
        return {
            "ln1": b.param((cfg.d_model,), ("embed",), init="zeros"),
            "mixer": mixer,
            "ln2": b.param((cfg.d_model,), ("embed",), init="zeros"),
            "mlp": ffn.init_dense(b, ffn.FfnCfg(cfg.d_model, cfg.d_ff, act="gelu")),
        }

    n_periods, remainder = _pattern(cfg)
    periods = [{k: mix_layer(k2) for k, k2 in zip("abc", PERIOD)} for _ in range(n_periods)]
    vals = [Builder.split(p)[0] for p in periods]
    spec = stacked_spec(Builder.split(periods[0])[1])

    tree = {
        "embed": b.param((cfg.vocab, cfg.d_model), ("vocab", "embed"),
                         scale=1.0 / cfg.d_model ** 0.5),
        "ln_f": b.param((cfg.d_model,), ("embed",), init="zeros"),
        "lm_head": b.param((cfg.d_model, cfg.vocab), ("embed_w", "vocab")),
    }
    params, specs = Builder.split(tree)
    params["periods"] = stack_layers(vals)
    specs["periods"] = spec
    tail = [Builder.split(mix_layer(k)) for k in remainder]
    params["tail"] = [t[0] for t in tail]
    specs["tail"] = [t[1] for t in tail]
    return params, specs


def _mix_forward(cfg, kind: str, lp, x, positions, long_seq: bool):
    lp = cast_tree(lp, cfg.compute_dtype)
    h = rms_norm(x, lp["ln1"], cfg.norm_eps)
    if kind == "rec":
        h = ssm.rglru(lp["mixer"], h, _rcfg(cfg))
    elif long_seq:
        h = attention.forward_chunked(lp["mixer"], h, _acfg(cfg), positions)
    else:
        h = attention.forward(lp["mixer"], h, _acfg(cfg), positions)
    x = x + h
    h = rms_norm(x, lp["ln2"], cfg.norm_eps)
    return x + ffn.dense(lp["mlp"], h, ffn.FfnCfg(cfg.d_model, cfg.d_ff, act="gelu"))


def hidden_states(cfg, params, batch: dict[str, jax.Array]) -> jax.Array:
    x = params["embed"].astype(cfg.compute_dtype)[batch["tokens"]]
    x = shard(x * jnp.asarray(cfg.d_model ** 0.5, cfg.compute_dtype),
              "batch", "seq", "embed")
    B, S, _ = x.shape
    positions = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32), (B, S))
    long_seq = S > 2048

    def period_step(carry, pp):
        for key, kind in zip("abc", PERIOD):
            carry = _mix_forward(cfg, kind, pp[key], carry, positions, long_seq)
        return carry, None

    if cfg.remat != "none":
        period_step = jax.checkpoint(period_step, prevent_cse=False)
    x, _ = jax.lax.scan(period_step, x, params["periods"])
    _, remainder = _pattern(cfg)
    for lp, kind in zip(params["tail"], remainder):
        x = _mix_forward(cfg, kind, lp, x, positions, long_seq)
    return rms_norm(x, params["ln_f"], cfg.norm_eps)


def full_logits(cfg, params, batch: dict[str, jax.Array]) -> jax.Array:
    x = hidden_states(cfg, params, batch)
    return (x @ params["lm_head"].astype(cfg.compute_dtype)).astype(jnp.float32)


def loss_fn(cfg, params, batch: dict[str, jax.Array]) -> jax.Array:
    x = hidden_states(cfg, params, batch)
    logits = (x[:, :-1, :] @ params["lm_head"].astype(cfg.compute_dtype)
              ).astype(jnp.float32)
    logits = shard(logits, "batch", "seq", "vocab")
    targets = batch["tokens"][:, 1:]
    logz = jax.nn.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, targets[..., None], axis=-1)[..., 0]
    return jnp.mean(logz - gold)


# ---------------------------------------------------------------------------
# decode: ring-buffer local KV + RG-LRU state
# ---------------------------------------------------------------------------

def _ring_cache(cfg, batch: int):
    acfg = _acfg(cfg)
    w = cfg.window
    return {"k": jnp.zeros((batch, w, acfg.n_kv, acfg.head_dim), cfg.compute_dtype),
            "v": jnp.zeros((batch, w, acfg.n_kv, acfg.head_dim), cfg.compute_dtype)}


def _attn_ring_decode(cfg, lp, x, lc, pos):
    """One-token local attention over a ``window``-slot ring buffer."""
    acfg = _acfg(cfg)
    B = x.shape[0]
    w = cfg.window
    positions = jnp.full((B, 1), pos, jnp.int32)
    q, k_new, v_new = attention._project_qkv(lp, x, acfg, positions)
    slot = pos % w
    k = jax.lax.dynamic_update_slice_in_dim(lc["k"], k_new.astype(lc["k"].dtype), slot, axis=1)
    v = jax.lax.dynamic_update_slice_in_dim(lc["v"], v_new.astype(lc["v"].dtype), slot, axis=1)
    # slot s holds absolute position: the largest t <= pos with t % w == s
    slots = jnp.arange(w)
    abs_pos = pos - ((pos - slots) % w)
    valid = (abs_pos >= 0) & (abs_pos <= pos) & (abs_pos > pos - w)
    out = attention.sdpa(q, k.astype(q.dtype), v.astype(q.dtype),
                         valid[None, None, :], 1.0 / acfg.head_dim ** 0.5)
    out = out.reshape(B, 1, acfg.n_heads * acfg.head_dim)
    return out @ lp["wo"], {"k": k, "v": v}


def _mix_decode(cfg, kind: str, lp, x, lc, pos):
    lp = cast_tree(lp, cfg.compute_dtype)
    h = rms_norm(x, lp["ln1"], cfg.norm_eps)
    if kind == "rec":
        h, lc = ssm.rglru_decode(lp["mixer"], h, _rcfg(cfg), lc)
    else:
        h, lc = _attn_ring_decode(cfg, lp["mixer"], h, lc, pos)
    x = x + h
    h = rms_norm(x, lp["ln2"], cfg.norm_eps)
    x = x + ffn.dense(lp["mlp"], h, ffn.FfnCfg(cfg.d_model, cfg.d_ff, act="gelu"))
    return x, lc


def init_cache(cfg, batch: int, max_len: int):
    """max_len only bounds the ring window (decode memory is O(window))."""
    n_periods, remainder = _pattern(cfg)
    rec = ssm.rglru_state(_rcfg(cfg), batch)
    ring = _ring_cache(cfg, batch)
    one = {"a": rec, "b": rec, "c": ring}
    periods = jax.tree.map(lambda l: jnp.tile(l[None], (n_periods,) + (1,) * l.ndim), one)
    tail = [dict(rec) if k == "rec" else dict(ring) for k in remainder]
    return {"periods": periods, "tail": tail, "pos": jnp.zeros((), jnp.int32)}


def cache_specs(cfg, batch: int, max_len: int):
    cache = jax.eval_shape(lambda: init_cache(cfg, batch, max_len))

    def spec_of(l):
        if l.ndim == 5:      # (P, B, W, kv, hd) ring
            return ("layers", "batch", None, "kv_heads", None)
        if l.ndim == 4:      # (P, B, W, kv, hd) tail ring / (P,B,K-1,width) conv
            return (None, "batch", None, "mlp")
        if l.ndim == 3:      # (P, B, width) rnn state
            return ("layers", "batch", "mlp")
        if l.ndim == 2:      # tail rnn (B, width)
            return ("batch", "mlp")
        return tuple(None for _ in l.shape)

    return jax.tree.map(spec_of, cache)


def prefill(cfg, params, batch: dict[str, jax.Array], max_len: int):
    """Full-sequence forward that also builds the decode state: RG-LRU final
    states + ring KV buffers holding the last ``window`` positions."""
    x = params["embed"].astype(cfg.compute_dtype)[batch["tokens"]]
    x = shard(x * jnp.asarray(cfg.d_model ** 0.5, cfg.compute_dtype),
              "batch", "seq", "embed")
    B, S, _ = x.shape
    positions = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32), (B, S))
    long_seq = S > 2048
    w = cfg.window

    def mix_prefill(kind, lp, carry):
        lp_c = cast_tree(lp, cfg.compute_dtype)
        h = rms_norm(carry, lp_c["ln1"], cfg.norm_eps)
        if kind == "rec":
            y, conv_s, rnn_s = ssm._rglru_core(lp_c["mixer"], h, _rcfg(cfg), None, None)
            state = {"conv": conv_s.astype(jnp.bfloat16), "rnn": rnn_s}
        else:
            acfg = _acfg(cfg)
            if long_seq:
                y = attention.forward_chunked(lp_c["mixer"], h, acfg, positions)
            else:
                y = attention.forward(lp_c["mixer"], h, acfg, positions)
            kv = attention.project_kv(lp_c["mixer"], h, acfg, positions)
            # last `window` positions land at slot = pos % window (ring)
            take = min(w, S)
            ks = kv["k"][:, S - take:, :, :]
            vs = kv["v"][:, S - take:, :, :]
            slots = jnp.arange(S - take, S) % w
            ring_k = jnp.zeros((B, w) + ks.shape[2:], cfg.compute_dtype
                               ).at[:, slots].set(ks.astype(cfg.compute_dtype))
            ring_v = jnp.zeros((B, w) + vs.shape[2:], cfg.compute_dtype
                               ).at[:, slots].set(vs.astype(cfg.compute_dtype))
            state = {"k": ring_k, "v": ring_v}
        carry = carry + y
        h = rms_norm(carry, lp_c["ln2"], cfg.norm_eps)
        carry = carry + ffn.dense(lp_c["mlp"], h, ffn.FfnCfg(cfg.d_model, cfg.d_ff, act="gelu"))
        return carry, state

    def period_step(carry, pp):
        states = {}
        for key, kind in zip("abc", PERIOD):
            carry, states[key] = mix_prefill(kind, pp[key], carry)
        return carry, states

    if cfg.remat != "none":
        period_step = jax.checkpoint(period_step, prevent_cse=False)
    x, period_states = jax.lax.scan(period_step, x, params["periods"])
    _, remainder = _pattern(cfg)
    tail_states = []
    for lp, kind in zip(params["tail"], remainder):
        x, st = mix_prefill(kind, lp, x)
        tail_states.append(st)
    x = rms_norm(x, params["ln_f"], cfg.norm_eps)
    logits = x[:, -1:, :] @ params["lm_head"].astype(cfg.compute_dtype)
    return logits, {"periods": period_states, "tail": tail_states,
                    "pos": jnp.asarray(S, jnp.int32)}


def decode_step(cfg, params, tokens: jax.Array, cache):
    x = params["embed"].astype(cfg.compute_dtype)[tokens]
    x = x * jnp.asarray(cfg.d_model ** 0.5, cfg.compute_dtype)
    pos = cache["pos"]

    def period_step(carry, scanned):
        pp, pc = scanned
        new_pc = {}
        for key, kind in zip("abc", PERIOD):
            carry, new_pc[key] = _mix_decode(cfg, kind, pp[key], carry, pc[key], pos)
        return carry, new_pc

    x, new_periods = jax.lax.scan(period_step, x, (params["periods"], cache["periods"]))
    _, remainder = _pattern(cfg)
    new_tail = []
    for lp, lc, kind in zip(params["tail"], cache["tail"], remainder):
        x, lc = _mix_decode(cfg, kind, lp, x, lc, pos)
        new_tail.append(lc)
    x = rms_norm(x, params["ln_f"], cfg.norm_eps)
    logits = x @ params["lm_head"].astype(cfg.compute_dtype)
    return logits, {"periods": new_periods, "tail": new_tail, "pos": pos + 1}
