"""Model factory: ArchConfig -> uniform Model facade.

Every architecture exposes the same five entry points, so the launcher,
dry-run, trainer and server are architecture-agnostic:

    init(key) -> (params, logical_specs)
    loss_fn(params, batch) -> scalar            (train_* shapes)
    prefill(params, batch, max_len) -> (logits, cache)   (prefill_* shapes)
    decode_step(params, tokens, cache) -> (logits, cache) (decode_* shapes)
    init_cache(batch, max_len) / cache_specs(batch, max_len)

``input_specs`` produces ShapeDtypeStruct stand-ins for every model input of
an (arch × shape) cell — the dry-run lowers against these (no allocation).
"""
from __future__ import annotations
from collections.abc import Callable

import dataclasses
import functools
from typing import Any

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig, ShapeConfig
from . import griffin, mamba_lm, transformer, whisper


@dataclasses.dataclass(frozen=True)
class Model:
    cfg: ArchConfig
    init: Callable[[jax.Array], Any]
    loss_fn: Callable[[Any, dict[str, jax.Array]], jax.Array]
    full_logits: Callable[[Any, dict[str, jax.Array]], jax.Array]
    decode_step: Callable[[Any, jax.Array, Any], Any]
    prefill: Callable[[Any, dict[str, jax.Array], int], Any]
    init_cache: Callable[[int, int], Any]
    cache_specs: Callable[[int, int], Any]


_FAMILY_MODULES = {
    "dense": transformer,
    "moe": transformer,
    "vlm": transformer,
    "ssm": mamba_lm,
    "hybrid": griffin,
    "audio": whisper,
}


def get_model(cfg: ArchConfig) -> Model:
    mod = _FAMILY_MODULES[cfg.family]
    if cfg.family == "audio":
        return Model(
            cfg=cfg,
            init=functools.partial(mod.init, cfg),
            loss_fn=functools.partial(mod.loss_fn, cfg),
            full_logits=functools.partial(mod.full_logits, cfg),
            decode_step=functools.partial(mod.decode_step, cfg),
            prefill=functools.partial(_whisper_prefill, cfg),
            init_cache=functools.partial(mod.init_cache, cfg),
            cache_specs=functools.partial(mod.cache_specs, cfg),
        )
    prefill = getattr(mod, "prefill", None)
    return Model(
        cfg=cfg,
        init=functools.partial(mod.init, cfg),
        loss_fn=functools.partial(mod.loss_fn, cfg),
        full_logits=functools.partial(mod.full_logits, cfg),
        decode_step=functools.partial(mod.decode_step, cfg),
        prefill=functools.partial(prefill, cfg) if prefill else None,
        init_cache=functools.partial(mod.init_cache, cfg),
        cache_specs=functools.partial(mod.cache_specs, cfg),
    )


def _whisper_prefill(cfg, params, batch, max_len):
    """Whisper prefill: encode frames, then run the decoder prefix through
    decode_train and build the cross cache from encoder output."""
    enc_out = whisper.encode(cfg, params, batch["frames"])
    x = whisper.decode_train(cfg, params, batch["tokens"], enc_out)
    logits = x[:, -1:, :] @ params["lm_head"].astype(cfg.compute_dtype)
    cache = whisper.init_cache(cfg, batch["tokens"].shape[0], max_len)
    return logits, cache


# ---------------------------------------------------------------------------
# input specs (dry-run stand-ins)
# ---------------------------------------------------------------------------

def input_specs(cfg: ArchConfig, shape: ShapeConfig) -> dict[str, jax.ShapeDtypeStruct]:
    """ShapeDtypeStruct stand-ins for the model inputs of one cell."""
    B = shape.global_batch
    if shape.kind == "decode":
        specs = {"tokens": jax.ShapeDtypeStruct((B, 1), jnp.int32)}
        return specs
    S = shape.seq_len
    specs = {"tokens": jax.ShapeDtypeStruct((B, S), jnp.int32)}
    if cfg.family == "audio":
        specs["frames"] = jax.ShapeDtypeStruct(
            (B, cfg.enc_frames, cfg.d_model), jnp.float32)
    if cfg.family == "vlm":
        specs["prefix_embeds"] = jax.ShapeDtypeStruct(
            (B, cfg.prefix_tokens, cfg.d_model), jnp.float32)
    return specs


def make_batch(cfg: ArchConfig, shape: ShapeConfig, key: jax.Array) -> dict[str, jax.Array]:
    """Concrete random batch matching input_specs (smoke tests / examples)."""
    specs = input_specs(cfg, shape)
    out = {}
    for name, sds in specs.items():
        key, sub = jax.random.split(key)
        if jnp.issubdtype(sds.dtype, jnp.integer):
            out[name] = jax.random.randint(sub, sds.shape, 0, cfg.vocab, sds.dtype)
        else:
            out[name] = jax.random.normal(sub, sds.shape, sds.dtype)
    return out
