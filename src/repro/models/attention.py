"""Attention variants: GQA (+qk-norm, RoPE, sliding window), MLA, KV caches.

Covers every assigned architecture's attention: qwen3 (GQA + per-head
qk-norm), granite/minitron (GQA), smollm (GQA kv=5), whisper (MHA + cross),
recurrentgemma (local MQA), paligemma (MQA), deepseek-v3 (MLA with latent KV
cache).  Decode paths read/write a preallocated cache (shape-stable); the
cache optionally holds stage-③ quantized integers (HSZ residency, int8 +
per-head scale) — the framework-level analogue of the paper's "operate on
D_q instead of D_f".
"""
from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp

from .common import Builder, axis_size, causal_mask, rms_norm, rope, shard


@dataclasses.dataclass(frozen=True)
class AttnCfg:
    d_model: int
    n_heads: int
    n_kv: int
    head_dim: int
    qk_norm: bool = False
    rope_theta: float = 10000.0
    window: int | None = None      # sliding-window (local) attention
    use_rope: bool = True
    causal: bool = True
    # MLA (deepseek-v3)
    mla: bool = False
    q_lora: int = 0
    kv_lora: int = 0
    qk_nope: int = 0
    qk_rope: int = 0
    v_dim: int = 0
    # KV-cache quantization (HSZ stage-③ residency)
    kv_quant: bool = False


# ---------------------------------------------------------------------------
# init
# ---------------------------------------------------------------------------

def init(b: Builder, cfg: AttnCfg):
    d, h, kv, hd = cfg.d_model, cfg.n_heads, cfg.n_kv, cfg.head_dim
    if cfg.mla:
        p = {
            "q_a": b.param((d, cfg.q_lora), ("embed_w", "lora")),
            "q_a_norm": b.param((cfg.q_lora,), ("lora",), init="zeros"),
            "q_b": b.param((cfg.q_lora, h * (cfg.qk_nope + cfg.qk_rope)), ("lora", "heads")),
            "kv_a": b.param((d, cfg.kv_lora + cfg.qk_rope), ("embed_w", "lora")),
            "kv_a_norm": b.param((cfg.kv_lora,), ("lora",), init="zeros"),
            "kv_b": b.param((cfg.kv_lora, h * (cfg.qk_nope + cfg.v_dim)), ("lora", "heads")),
            "o": b.param((h * cfg.v_dim, d), ("heads", "embed_w")),
        }
        return p
    p = {
        "wq": b.param((d, h * hd), ("embed_w", "heads")),
        "wk": b.param((d, kv * hd), ("embed_w", "kv_heads")),
        "wv": b.param((d, kv * hd), ("embed_w", "kv_heads")),
        "wo": b.param((h * hd, d), ("heads", "embed_w")),
    }
    if cfg.qk_norm:
        p["q_norm"] = b.param((hd,), ("head_dim",), init="zeros")
        p["k_norm"] = b.param((hd,), ("head_dim",), init="zeros")
    return p


# ---------------------------------------------------------------------------
# core scaled-dot-product with GQA head grouping
# ---------------------------------------------------------------------------

def sdpa(q: jax.Array, k: jax.Array, v: jax.Array, mask: jax.Array | None,
         scale: float) -> jax.Array:
    """q: (B,S,H,hd)  k/v: (B,T,Kh,hd or vd)  -> (B,S,H,vd).

    Head grouping: H = Kh * G; computed grouped to avoid materializing
    repeated K/V (the GQA memory win).
    """
    B, S, H, hd = q.shape
    Kh = k.shape[2]
    G = H // Kh
    qg = q.reshape(B, S, Kh, G, hd)
    logits = jnp.einsum("bskgd,btkd->bkgst", qg, k).astype(jnp.float32) * scale
    if mask is not None:
        logits = jnp.where(mask[:, None, None, :, :] if mask.ndim == 3 else mask,
                           logits, jnp.float32(-1e30))
    w = jax.nn.softmax(logits, axis=-1).astype(v.dtype)
    out = jnp.einsum("bkgst,btkd->bskgd", w, v)
    return out.reshape(B, S, H, v.shape[-1])


# ---------------------------------------------------------------------------
# GQA forward (train / prefill)
# ---------------------------------------------------------------------------

def _project_qkv(p, x, cfg: AttnCfg, positions):
    B, S, _ = x.shape
    h, kv, hd = cfg.n_heads, cfg.n_kv, cfg.head_dim
    q = (x @ p["wq"]).reshape(B, S, h, hd)
    k = (x @ p["wk"]).reshape(B, S, kv, hd)
    v = (x @ p["wv"]).reshape(B, S, kv, hd)
    if cfg.qk_norm:
        q = rms_norm(q, p["q_norm"])
        k = rms_norm(k, p["k_norm"])
    if cfg.use_rope:
        q = rope(q, positions, cfg.rope_theta)
        k = rope(k, positions, cfg.rope_theta)
    if h % max(axis_size("heads"), 1) == 0:
        q = shard(q, "batch", "seq", "heads", None)
    else:
        # sequence-parallel fallback: head count (e.g. 15, 8) does not divide
        # the TP extent — shard attention over the query-sequence dim instead
        # (Megatron-style context parallelism for the logits buffer).
        q = shard(q, "batch", "seq_tp", "heads", None)
    k = shard(k, "batch", "seq", "kv_heads", None)
    v = shard(v, "batch", "seq", "kv_heads", None)
    return q, k, v


def forward(p, x: jax.Array, cfg: AttnCfg, positions: jax.Array,
            mask: jax.Array | None = None) -> jax.Array:
    """Self-attention over a full sequence (training / prefill)."""
    if cfg.mla:
        return _mla_forward(p, x, cfg, positions, mask)
    B, S, _ = x.shape
    q, k, v = _project_qkv(p, x, cfg, positions)
    if mask is None and cfg.causal:
        mask = causal_mask(S, S, window=cfg.window)
    out = sdpa(q, k, v, mask, 1.0 / (cfg.head_dim ** 0.5))
    out = out.reshape(B, S, cfg.n_heads * cfg.head_dim)
    return shard(out @ p["wo"], "batch", "seq", "embed")


def forward_chunked(p, x: jax.Array, cfg: AttnCfg, positions: jax.Array,
                    q_chunk: int = 2048) -> jax.Array:
    """Query-chunked attention for long prefill: bounds the live logits
    buffer to (B, H, q_chunk, kv_len) — the XLA-level analogue of
    flash-attention tiling (full fusion is a Pallas-kernel hillclimb lever).

    For sliding-window configs, keys are pre-shifted so each query chunk
    attends to a static (q_chunk + window) key band instead of the full
    sequence — O(S·W) instead of O(S²).
    """
    B, S, _ = x.shape
    if S <= q_chunk:
        return forward(p, x, cfg, positions)
    if S % q_chunk:  # prefix-LM shapes (e.g. 256+4096): largest divisor wins
        q_chunk = next(d for d in range(q_chunk, 0, -1) if S % d == 0)
        if q_chunk < 64:
            return forward(p, x, cfg, positions)
    if cfg.mla:
        q, k, v, _, _ = _mla_qkv(p, x, cfg, positions)
        scale = 1.0 / ((cfg.qk_nope + cfg.qk_rope) ** 0.5)
        o_name, o_dim = "o", cfg.n_heads * cfg.v_dim
    else:
        q, k, v = _project_qkv(p, x, cfg, positions)
        scale = 1.0 / (cfg.head_dim ** 0.5)
        o_name, o_dim = "wo", cfg.n_heads * cfg.head_dim
    w = cfg.window
    H = q.shape[2]
    nc = S // q_chunk
    # chunk axis leads so lax.scan slices it statically (keeps the seq-dim
    # sharding of each chunk intact — a traced dynamic_slice would force
    # GSPMD to materialize the full unsharded buffer)
    q_chunks = jnp.moveaxis(q.reshape(B, nc, q_chunk, H, -1), 1, 0)
    head_ok = H % max(axis_size("heads"), 1) == 0
    q_axes = ("batch", "seq", "heads", None) if head_ok else \
             ("batch", "seq_tp", "heads", None)

    if w is not None:
        band = ((w + q_chunk - 1) // q_chunk) * q_chunk  # static key look-back
        kp = jnp.pad(k, ((0, 0), (band, 0), (0, 0), (0, 0)))
        vp = jnp.pad(v, ((0, 0), (band, 0), (0, 0), (0, 0)))

        def body(_, inputs):
            qi, idx = inputs
            qi = shard(qi, *q_axes)
            s0 = idx * q_chunk
            ki = jax.lax.dynamic_slice_in_dim(kp, s0, band + q_chunk, axis=1)
            vi = jax.lax.dynamic_slice_in_dim(vp, s0, band + q_chunk, axis=1)
            q_pos = s0 + jnp.arange(q_chunk)[:, None]
            kv_pos = s0 - band + jnp.arange(band + q_chunk)[None, :]
            mask = (kv_pos <= q_pos) & (kv_pos > q_pos - w) & (kv_pos >= 0)
            return _, sdpa(qi, ki, vi, mask, scale)
    else:
        def body(_, inputs):
            qi, idx = inputs
            qi = shard(qi, *q_axes)
            q_pos = idx * q_chunk + jnp.arange(q_chunk)[:, None]
            mask = jnp.arange(S)[None, :] <= q_pos
            return _, sdpa(qi, k, v, mask, scale)

    _, out = jax.lax.scan(body, 0, (q_chunks, jnp.arange(nc)))
    out = jnp.moveaxis(out, 0, 1).reshape(B, S, o_dim)
    return shard(out @ p[o_name], "batch", "seq", "embed")


def cross_forward(p, x: jax.Array, kv_src: jax.Array, cfg: AttnCfg) -> jax.Array:
    """Cross-attention (whisper decoder): queries from x, keys/values from
    encoder output; no RoPE, no causal mask."""
    B, S, _ = x.shape
    T = kv_src.shape[1]
    h, kv, hd = cfg.n_heads, cfg.n_kv, cfg.head_dim
    q = (x @ p["wq"]).reshape(B, S, h, hd)
    k = (kv_src @ p["wk"]).reshape(B, T, kv, hd)
    v = (kv_src @ p["wv"]).reshape(B, T, kv, hd)
    out = sdpa(q, k, v, None, 1.0 / hd ** 0.5).reshape(B, S, h * hd)
    return out @ p["wo"]


def project_kv(p, x: jax.Array, cfg: AttnCfg, positions: jax.Array):
    """KV-cache entries for a full sequence (prefill cache construction).

    GQA -> {'k','v'}: (B,S,kv,hd); MLA -> {'latent'}: (B,S,kv_lora+rope).
    """
    if cfg.mla:
        kv_a = x @ p["kv_a"]
        c_kv = rms_norm(kv_a[..., :cfg.kv_lora], p["kv_a_norm"])
        k_rope = rope(kv_a[..., None, cfg.kv_lora:], positions, cfg.rope_theta)[:, :, 0]
        return {"latent": jnp.concatenate([c_kv, k_rope], axis=-1)}
    _, k, v = _project_qkv(p, x, cfg, positions)
    return {"k": k, "v": v}


# ---------------------------------------------------------------------------
# KV cache (decode)
# ---------------------------------------------------------------------------

def init_cache(cfg: AttnCfg, batch: int, max_len: int, dtype=jnp.bfloat16) -> dict[str, Any]:
    """Preallocated cache; int8 payload + f32 scale when kv_quant is set."""
    if cfg.mla:
        width = cfg.kv_lora + cfg.qk_rope
        if cfg.kv_quant:
            return {"latent": jnp.zeros((batch, max_len, width), jnp.int8),
                    "scale": jnp.ones((), jnp.float32)}
        return {"latent": jnp.zeros((batch, max_len, width), dtype)}
    kv_shape = (batch, max_len, cfg.n_kv, cfg.head_dim)
    if cfg.kv_quant:
        return {"k": jnp.zeros(kv_shape, jnp.int8), "v": jnp.zeros(kv_shape, jnp.int8),
                "k_scale": jnp.ones((cfg.n_kv,), jnp.float32),
                "v_scale": jnp.ones((cfg.n_kv,), jnp.float32)}
    return {"k": jnp.zeros(kv_shape, dtype), "v": jnp.zeros(kv_shape, dtype)}


def _scale_for(cache, name, buf):
    """Broadcast the per-head (or scalar) scale against (B, S, kv, hd)."""
    scale = cache.get(f"{name}_scale", cache.get("scale"))
    if scale.ndim == 1 and buf.ndim == 4:   # (kv,) -> (1, 1, kv, 1)
        scale = scale[None, None, :, None]
    return scale


def _cache_write(cache, name, val, pos):
    """Write (B, 1, ...) value at time pos (quantizing if the cache is int8)."""
    buf = cache[name]
    if buf.dtype == jnp.int8:
        scale = _scale_for(cache, name, buf)
        val = jnp.clip(jnp.round(val.astype(jnp.float32) / scale), -127, 127
                       ).astype(jnp.int8)
    return jax.lax.dynamic_update_slice_in_dim(buf, val.astype(buf.dtype), pos, axis=1)


def _cache_read(cache, name):
    buf = cache[name]
    if buf.dtype == jnp.int8:
        return buf.astype(jnp.float32) * _scale_for(cache, name, buf)
    return buf


def decode_step(p, x: jax.Array, cfg: AttnCfg, cache: dict[str, Any],
                pos: jax.Array) -> tuple[jax.Array, dict[str, Any]]:
    """One-token self-attention against the cache.  x: (B, 1, D)."""
    if cfg.mla:
        return _mla_decode(p, x, cfg, cache, pos)
    B = x.shape[0]
    h, kv, hd = cfg.n_heads, cfg.n_kv, cfg.head_dim
    positions = jnp.full((B, 1), pos, jnp.int32)
    q, k_new, v_new = _project_qkv(p, x, cfg, positions)
    cache = dict(cache)
    cache["k"] = _cache_write(cache, "k", k_new, pos)
    cache["v"] = _cache_write(cache, "v", v_new, pos)
    k = _cache_read(cache, "k").astype(q.dtype)
    v = _cache_read(cache, "v").astype(q.dtype)
    T = k.shape[1]
    valid = jnp.arange(T)[None, :] <= pos
    if cfg.window is not None:
        valid &= jnp.arange(T)[None, :] > pos - cfg.window
    out = sdpa(q, k, v, valid[None, :, :], 1.0 / hd ** 0.5)
    out = out.reshape(B, 1, h * hd)
    return out @ p["wo"], cache


# ---------------------------------------------------------------------------
# MLA (deepseek-v3)
# ---------------------------------------------------------------------------

def _mla_qkv(p, x, cfg: AttnCfg, positions):
    """Project to per-head q/k/v from the latent (training path)."""
    B, S, _ = x.shape
    h = cfg.n_heads
    qa = rms_norm(x @ p["q_a"], p["q_a_norm"])
    q = (qa @ p["q_b"]).reshape(B, S, h, cfg.qk_nope + cfg.qk_rope)
    q_nope, q_rope = q[..., :cfg.qk_nope], q[..., cfg.qk_nope:]
    q_rope = rope(q_rope, positions, cfg.rope_theta)

    kv_a = x @ p["kv_a"]
    c_kv = rms_norm(kv_a[..., :cfg.kv_lora], p["kv_a_norm"])
    k_rope = rope(kv_a[..., None, cfg.kv_lora:], positions, cfg.rope_theta)  # 1 shared head
    kvb = (c_kv @ p["kv_b"]).reshape(B, S, h, cfg.qk_nope + cfg.v_dim)
    k_nope, v = kvb[..., :cfg.qk_nope], kvb[..., cfg.qk_nope:]

    q_full = jnp.concatenate([q_nope, q_rope], axis=-1)
    k_full = jnp.concatenate([k_nope, jnp.broadcast_to(
        k_rope, k_nope.shape[:-1] + (cfg.qk_rope,))], axis=-1)
    q_full = shard(q_full, "batch", "seq", "heads", None)
    k_full = shard(k_full, "batch", "seq", "heads", None)
    v = shard(v, "batch", "seq", "heads", None)
    return q_full, k_full, v, c_kv, kv_a[..., cfg.kv_lora:]


def _mla_forward(p, x, cfg: AttnCfg, positions, mask):
    B, S, _ = x.shape
    q, k, v, _, _ = _mla_qkv(p, x, cfg, positions)
    if mask is None and cfg.causal:
        mask = causal_mask(S, S)
    scale = 1.0 / ((cfg.qk_nope + cfg.qk_rope) ** 0.5)
    out = sdpa(q, k, v, mask, scale).reshape(B, S, cfg.n_heads * cfg.v_dim)
    return shard(out @ p["o"], "batch", "seq", "embed")


def _mla_decode(p, x, cfg: AttnCfg, cache, pos):
    """Latent-cache decode: cache holds (c_kv ++ rope_k) = 576 f/token —
    MLA's compressed KV (itself a learned compression; composes with HSZ
    int8 residency when kv_quant is on)."""
    B = x.shape[0]
    h = cfg.n_heads
    positions = jnp.full((B, 1), pos, jnp.int32)
    qa = rms_norm(x @ p["q_a"], p["q_a_norm"])
    q = (qa @ p["q_b"]).reshape(B, 1, h, cfg.qk_nope + cfg.qk_rope)
    q_nope, q_rope = q[..., :cfg.qk_nope], q[..., cfg.qk_nope:]
    q_rope = rope(q_rope, positions, cfg.rope_theta)

    kv_a = x @ p["kv_a"]
    c_kv = rms_norm(kv_a[..., :cfg.kv_lora], p["kv_a_norm"])
    k_rope_new = rope(kv_a[..., None, cfg.kv_lora:], positions, cfg.rope_theta)[:, :, 0]
    latent_new = jnp.concatenate([c_kv, k_rope_new], axis=-1)
    cache = dict(cache)
    cache["latent"] = _cache_write(cache, "latent", latent_new, pos)
    latent = _cache_read(cache, "latent")
    c_all = latent[..., :cfg.kv_lora].astype(x.dtype)      # (B, T, kv_lora)
    kr_all = latent[..., cfg.kv_lora:].astype(x.dtype)     # (B, T, rope)

    # absorbed attention: score = q_nope^T (W_kb c) + q_rope^T k_rope
    wkb = p["kv_b"].reshape(cfg.kv_lora, h, cfg.qk_nope + cfg.v_dim)
    wk_nope = wkb[..., :cfg.qk_nope]      # (kv_lora, h, nope)
    wv = wkb[..., cfg.qk_nope:]           # (kv_lora, h, vd)
    q_abs = jnp.einsum("bqhn,lhn->bqhl", q_nope, wk_nope)  # project q into latent
    T = c_all.shape[1]
    scale = 1.0 / ((cfg.qk_nope + cfg.qk_rope) ** 0.5)
    logits = (jnp.einsum("bqhl,btl->bhqt", q_abs, c_all)
              + jnp.einsum("bqhr,btr->bhqt", q_rope, kr_all)).astype(jnp.float32) * scale
    valid = jnp.arange(T)[None, None, None, :] <= pos
    logits = jnp.where(valid, logits, jnp.float32(-1e30))
    w = jax.nn.softmax(logits, axis=-1).astype(x.dtype)
    ctx = jnp.einsum("bhqt,btl->bqhl", w, c_all)           # (B,1,h,kv_lora)
    out = jnp.einsum("bqhl,lhv->bqhv", ctx, wv).reshape(B, 1, h * cfg.v_dim)
    return out @ p["o"], cache
