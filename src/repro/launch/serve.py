"""Serving launcher: batched decode over the slot engine.

    PYTHONPATH=src python -m repro.launch.serve --arch qwen3-4b --reduced \
        --requests 8 [--kv-quant]
"""
from __future__ import annotations

import argparse
import dataclasses
import time

import numpy as np
import jax

from repro.configs import ARCHS, reduced as reduce_cfg
from repro.models import get_model
from repro.serve import Engine, Request


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen3-4b", choices=list(ARCHS))
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--max-len", type=int, default=128)
    ap.add_argument("--max-new-tokens", type=int, default=16)
    ap.add_argument("--kv-quant", action="store_true",
                    help="HSZ stage-3 int8 KV-cache residency")
    args = ap.parse_args()

    cfg = ARCHS[args.arch]
    if args.reduced:
        cfg = reduce_cfg(cfg)
    if args.kv_quant:
        cfg = dataclasses.replace(cfg, kv_quant=True)
    model = get_model(cfg)
    params, _ = model.init(jax.random.PRNGKey(0))
    eng = Engine(model, params, slots=args.slots, max_len=args.max_len)

    rng = np.random.default_rng(0)
    for i in range(args.requests):
        eng.add_request(Request(
            uid=i, prompt=rng.integers(1, cfg.vocab, 8).astype(np.int32),
            max_new_tokens=args.max_new_tokens))
    t0 = time.time()
    done = eng.run_until_drained()
    dt = time.time() - t0
    toks = sum(len(r.out_tokens) for r in done)
    print(f"[serve] {len(done)} requests, {toks} tokens in {dt:.1f}s "
          f"({toks/dt:.1f} tok/s) kv_quant={args.kv_quant}")


if __name__ == "__main__":
    main()
