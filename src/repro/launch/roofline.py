"""Roofline analysis from dry-run artifacts (EXPERIMENTS.md §Roofline).

Three terms per (arch x shape x mesh) cell, all per-device:

    compute    = HLO_dot_FLOPs / peak_FLOPs        (197 TF/s bf16, TPU v5e)
    memory     = HLO_bytes_proxy / HBM_bw          (819 GB/s)
    collective = wire_bytes / ICI_bw               (~50 GB/s/link; 2 links/axis
                                                    usable per collective step)

The dominant term is the bottleneck; roofline fraction = compute_term /
max(all terms) (how close the cell is to being compute-bound, the ideal).
MODEL_FLOPS uses 6·N·D (dense) or 6·N_active·D (MoE) for training; 2·N(_act)
per generated/prefilled token for inference.

    PYTHONPATH=src python -m repro.launch.roofline [--dir experiments/dryrun]
"""
from __future__ import annotations

import argparse
import glob
import json
import os

from repro.configs import ARCHS, SHAPES

PEAK_FLOPS = 197e12          # bf16 / chip
HBM_BW = 819e9               # bytes/s
ICI_BW = 50e9                # bytes/s/link (~2 usable links per collective)
ICI_EFF = 2 * ICI_BW


def model_flops_per_device(arch: str, shape_name: str, devices: int) -> float:
    cfg = ARCHS[arch]
    shape = SHAPES[shape_name]
    n = cfg.active_param_count if cfg.moe else cfg.param_count
    if shape.kind == "train":
        tokens = shape.global_batch * shape.seq_len
        return 6.0 * n * tokens / devices
    if shape.kind == "prefill":
        tokens = shape.global_batch * shape.seq_len
        return 2.0 * n * tokens / devices
    tokens = shape.global_batch  # one token per sequence per step
    return 2.0 * n * tokens / devices


def analyze_record(rec: dict) -> dict:
    hlo = rec["hlo"]
    flops = hlo["dot_flops"]
    t_compute = flops / PEAK_FLOPS
    t_memory = hlo["bytes_proxy"] / HBM_BW
    t_coll = hlo["wire_bytes_total"] / ICI_EFF
    terms = {"compute_s": t_compute, "memory_s": t_memory, "collective_s": t_coll}
    dominant = max(terms, key=terms.get)
    mf = model_flops_per_device(rec["arch"], rec["shape"], rec["devices"])
    return {
        **terms,
        "dominant": dominant.replace("_s", ""),
        "roofline_fraction": t_compute / max(max(terms.values()), 1e-30),
        "model_flops": mf,
        "useful_ratio": mf / max(flops, 1e-30),
        "peak_gib": rec["memory"]["peak_per_device_gib"],
        "fits_16g": rec["memory"]["peak_per_device_gib"] <= 16.0,
    }


def load_all(dir_: str) -> list[dict]:
    out = []
    for f in sorted(glob.glob(os.path.join(dir_, "*.json"))):
        rec = json.load(open(f))
        rec["_file"] = os.path.basename(f)
        out.append(rec)
    return out


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--dir", default="experiments/dryrun")
    ap.add_argument("--mesh", default="16x16",
                    help="mesh filter for the table (roofline is single-pod)")
    ap.add_argument("--markdown", action="store_true")
    args = ap.parse_args()

    recs = [r for r in load_all(args.dir)
            if r.get("mesh") == args.mesh and not r.get("hom_grads")]
    header = (f"{'arch':22s} {'shape':12s} {'st':4s} {'comp_ms':>8s} {'mem_ms':>8s} "
              f"{'coll_ms':>8s} {'domin':>7s} {'roofl%':>7s} {'useful%':>8s} "
              f"{'GiB/dev':>8s}")
    sep = "-" * len(header)
    if args.markdown:
        print("| arch | shape | status | compute ms | memory ms | collective ms "
              "| dominant | roofline | useful | GiB/dev |")
        print("|---|---|---|---|---|---|---|---|---|---|")
    else:
        print(header)
        print(sep)
    for rec in recs:
        arch, shape = rec["arch"], rec["shape"]
        if rec["status"] == "skipped":
            line = (f"{arch:22s} {shape:12s} skip  ({rec['reason'][:60]})")
            if args.markdown:
                print(f"| {arch} | {shape} | skipped | — | — | — | — | — | — | — |")
            else:
                print(line)
            continue
        if rec["status"] != "ok":
            print(f"{arch:22s} {shape:12s} FAIL  {rec.get('error','')[:60]}")
            continue
        a = analyze_record(rec)
        if args.markdown:
            print(f"| {arch} | {shape} | ok | {a['compute_s']*1e3:.1f} | "
                  f"{a['memory_s']*1e3:.1f} | {a['collective_s']*1e3:.2f} | "
                  f"{a['dominant']} | {a['roofline_fraction']*100:.0f}% | "
                  f"{min(a['useful_ratio'],9.99)*100:.0f}% | {a['peak_gib']:.1f} |")
        else:
            print(f"{arch:22s} {shape:12s} ok   {a['compute_s']*1e3:8.1f} "
                  f"{a['memory_s']*1e3:8.1f} {a['collective_s']*1e3:8.2f} "
                  f"{a['dominant']:>7s} {a['roofline_fraction']*100:6.0f}% "
                  f"{min(a['useful_ratio'],9.99)*100:7.0f}% {a['peak_gib']:8.1f}")


if __name__ == "__main__":
    main()
