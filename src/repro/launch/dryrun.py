import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
# The two lines above MUST run before any other import (jax locks the device
# count at first init).  Do not move them.

"""Multi-pod dry-run: lower + compile every (arch x shape x mesh) cell.

For each cell this driver builds the production mesh (16x16 single-pod /
2x16x16 multi-pod placeholder devices), lowers the appropriate step function
against ShapeDtypeStruct inputs (no allocation), compiles it, and records:

  * memory_analysis()  — per-device bytes (proves the cell fits / flags it),
  * cost_analysis()    — per-device HLO FLOPs + bytes for §Roofline,
  * the collective table parsed from the post-SPMD HLO (op kind, dtype,
    per-device bytes) — the collective roofline term,
  * lower/compile wall time and any failure, per cell, to JSON.

Usage:
  python -m repro.launch.dryrun --arch qwen3-4b --shape train_4k [--multi-pod]
  python -m repro.launch.dryrun --all [--out experiments/dryrun]
"""
import argparse
import json
import time
import traceback
from typing import Any

import numpy as np
import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs import ARCHS, SHAPES, cell_supported
from repro.models import get_model, input_specs as model_input_specs
from repro.launch import hlo_analysis
from repro.launch import mesh as mesh_lib
from repro.train import optimizer as opt_lib
from repro.train import train_step as ts_lib

def _tokens_sharding(mesh, specs):
    return mesh_lib.batch_shardings(mesh, specs)


# activation-memory lever: grad-accumulation microbatches per train step
# (the saved scan carries scale with per-device microbatch size)
MICROBATCH = {
    "deepseek-v3-671b": 8, "falcon-mamba-7b": 4, "recurrentgemma-9b": 4,
    "minitron-4b": 2, "qwen3-4b": 2, "granite-3-2b": 2,
    "granite-moe-3b-a800m": 2, "paligemma-3b": 2,
}


def build_cell(arch: str, shape_name: str, *, multi_pod: bool,
               hom_grads: bool = False, remat: str | None = None,
               seq_shard: bool = False, microbatch: int | None = None,
               kv_quant: bool = False, fsdp_bf16: bool = False) -> dict[str, Any]:
    """Lower + compile one cell; returns the result record."""
    import dataclasses as dc

    cfg = ARCHS[arch]
    if remat is not None:
        cfg = dc.replace(cfg, remat=remat)
    if kv_quant:
        cfg = dc.replace(cfg, kv_quant=True)
    if fsdp_bf16:
        cfg = dc.replace(cfg, fsdp_bf16_gather=True)
    shape = SHAPES[shape_name]
    rec: dict[str, Any] = {
        "arch": arch, "shape": shape_name,
        "mesh": "2x16x16" if multi_pod else "16x16",
        "mode": shape.kind, "hom_grads": hom_grads,
        "kv_quant": kv_quant, "seq_shard": seq_shard,
    }
    ok, reason = cell_supported(cfg, shape)
    if not ok:
        rec["status"] = "skipped"
        rec["reason"] = reason
        return rec

    mesh = mesh_lib.make_production_mesh(multi_pod=multi_pod)
    n_dev = int(np.prod(list(mesh.shape.values())))
    rec["devices"] = n_dev
    mesh_lib.activate(mesh, seq_shard=seq_shard)
    try:
        model = get_model(cfg)
        params_sds, specs = model.init(None)      # abstract init: no allocation
        rules = mesh_lib.logical_rules(mesh, seq_shard=seq_shard)
        param_sh = mesh_lib.tree_shardings(mesh, specs, params_sds, seq_shard=seq_shard)
        in_specs = model_input_specs(cfg, shape)
        batch_sh = mesh_lib.batch_shardings(mesh, in_specs)

        t0 = time.time()
        if shape.kind == "train":
            opt_cfg = opt_lib.AdamWConfig()
            opt_sds = jax.eval_shape(opt_lib.init, params_sds)
            opt_sh = ts_lib.TrainState(
                params=param_sh,
                opt=opt_lib.OptState(m=param_sh, v=param_sh,
                                     count=NamedSharding(mesh, P())),
                step=NamedSharding(mesh, P()),
                ef_residual=param_sh if hom_grads else None,
            )
            state_sds = ts_lib.TrainState(
                params=params_sds, opt=opt_sds, step=jax.ShapeDtypeStruct((), jnp.int32),
                ef_residual=params_sds if hom_grads else None)
            mode = "hom" if hom_grads else "gspmd"
            dp_axes = ("pod", "data") if multi_pod else ("data",)
            mb = microbatch if microbatch is not None else MICROBATCH.get(arch)
            rec["microbatch"] = mb
            step_fn = ts_lib.make_train_step(model, opt_cfg, mode=mode,
                                             mesh=mesh, dp_axes=dp_axes,
                                             microbatch=mb)
            # donation + partial-manual shard_map trips an XLA copy-opcode
            # CHECK in the CPU partitioner; donate only in the gspmd path
            jitted = jax.jit(step_fn, in_shardings=(opt_sh, batch_sh),
                             donate_argnums=(0,) if mode == "gspmd" else ())
            lowered = jitted.lower(state_sds, in_specs)
        elif shape.kind == "prefill":
            # cache must cover prefix tokens (VLM) + prompt + a little headroom
            max_len = shape.seq_len + cfg.prefix_tokens + 8

            def prefill_fn(params, batch):
                return model.prefill(params, batch, max_len)

            jitted = jax.jit(prefill_fn, in_shardings=(param_sh, batch_sh))
            lowered = jitted.lower(params_sds, in_specs)
        else:  # decode
            B = shape.global_batch
            cache_sds = jax.eval_shape(lambda: model.init_cache(B, shape.seq_len))
            cache_logical = model.cache_specs(B, shape.seq_len)
            cache_sh = mesh_lib.tree_shardings(mesh, cache_logical, cache_sds,
                                               seq_shard=seq_shard)
            tok_sds = jax.ShapeDtypeStruct((B, 1), jnp.int32)
            tok_sh = mesh_lib.batch_shardings(mesh, tok_sds)
            jitted = jax.jit(model.decode_step,
                             in_shardings=(param_sh, tok_sh, cache_sh),
                             donate_argnums=(2,))
            lowered = jitted.lower(params_sds, tok_sds, cache_sds)
        rec["lower_s"] = round(time.time() - t0, 2)

        t0 = time.time()
        compiled = lowered.compile()
        rec["compile_s"] = round(time.time() - t0, 2)

        ma = compiled.memory_analysis()
        rec["memory"] = {
            "argument_bytes": int(ma.argument_size_in_bytes),
            "output_bytes": int(ma.output_size_in_bytes),
            "temp_bytes": int(ma.temp_size_in_bytes),
            "alias_bytes": int(ma.alias_size_in_bytes),
            "peak_per_device_gib": round(
                (ma.argument_size_in_bytes + ma.output_size_in_bytes
                 + ma.temp_size_in_bytes - ma.alias_size_in_bytes) / 2**30, 3),
        }
        ca = compiled.cost_analysis() or {}
        if isinstance(ca, (list, tuple)):  # older jax: one dict per device
            ca = ca[0] if ca else {}
        rec["cost"] = {"flops_raw": float(ca.get("flops", 0.0)),
                       "bytes_accessed_raw": float(ca.get("bytes accessed", 0.0))}
        # trip-count-aware analysis (scan bodies weighted by L) — see
        # hlo_analysis.py; cost_analysis() counts each computation once.
        rec["hlo"] = hlo_analysis.analyze(compiled.as_text())
        rec["status"] = "ok"
        print(f"[dryrun] {arch} x {shape_name} x {rec['mesh']}"
              f"{' hom' if hom_grads else ''}: ok "
              f"(lower {rec['lower_s']}s, compile {rec['compile_s']}s, "
              f"peak {rec['memory']['peak_per_device_gib']} GiB/dev)")
    except Exception as e:  # noqa: BLE001 — record per-cell failures
        rec["status"] = "failed"
        rec["error"] = f"{type(e).__name__}: {e}"
        rec["traceback"] = traceback.format_exc()[-4000:]
        print(f"[dryrun] {arch} x {shape_name} x {rec['mesh']}: FAILED {rec['error']}")
    finally:
        mesh_lib.deactivate()
    return rec


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--both-meshes", action="store_true")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--hom-grads", action="store_true",
                    help="compressed (int16) homomorphic gradient all-reduce")
    ap.add_argument("--remat", default=None)
    ap.add_argument("--seq-shard", action="store_true")
    ap.add_argument("--microbatch", type=int, default=None)
    ap.add_argument("--kv-quant", action="store_true")
    ap.add_argument("--fsdp-bf16", action="store_true")
    ap.add_argument("--tag", default="", help="suffix for the output json")
    ap.add_argument("--out", default="experiments/dryrun")
    args = ap.parse_args()

    os.makedirs(args.out, exist_ok=True)
    cells = []
    if args.all:
        for arch in ARCHS:
            for shape in SHAPES:
                cells.append((arch, shape))
    else:
        cells.append((args.arch, args.shape))

    meshes = [False, True] if (args.both_meshes or args.all) else [args.multi_pod]
    for arch, shape in cells:
        for mp in meshes:
            tag = f"{arch}_{shape}_{'2x16x16' if mp else '16x16'}" \
                  + ("_hom" if args.hom_grads else "") \
                  + ("_kvq" if args.kv_quant else "") \
                  + (f"_{args.tag}" if args.tag else "")
            out_path = os.path.join(args.out, tag + ".json")
            if os.path.exists(out_path):
                print(f"[dryrun] {tag}: cached")
                continue
            rec = build_cell(arch, shape, multi_pod=mp, hom_grads=args.hom_grads,
                             remat=args.remat, seq_shard=args.seq_shard,
                             microbatch=args.microbatch, kv_quant=args.kv_quant,
                             fsdp_bf16=args.fsdp_bf16)
            with open(out_path, "w") as f:
                json.dump(rec, f, indent=1)


if __name__ == "__main__":
    main()
