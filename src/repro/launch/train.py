"""Training launcher: mesh-aware driver with checkpoint/restart.

On real hardware this runs under ``jax.distributed`` (one process per host);
on this container it drives the host mesh.  The dry-run (``dryrun.py``) is
the multi-pod compile proof; this driver is the runnable small-scale path.

    PYTHONPATH=src python -m repro.launch.train --arch smollm-360m \
        --steps 50 --seq-len 64 --batch 4 --reduced
"""
from __future__ import annotations

import argparse
import os
import time

import numpy as np
import jax
import jax.numpy as jnp

from repro.configs import ARCHS, reduced as reduce_cfg
from repro.data.tokens import TokenPipeline, TokenPipelineConfig
from repro.models import get_model
from repro.train import checkpoint as ckpt
from repro.train import optimizer as opt_lib
from repro.train import train_step as ts_lib


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="smollm-360m", choices=list(ARCHS))
    ap.add_argument("--reduced", action="store_true",
                    help="reduced config (CPU-runnable)")
    ap.add_argument("--steps", type=int, default=50)
    ap.add_argument("--seq-len", type=int, default=64)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--lr", type=float, default=1e-3)
    ap.add_argument("--microbatch", type=int, default=None)
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--ckpt-every", type=int, default=25)
    ap.add_argument("--ckpt-mode", default="lossless", choices=["lossless", "hsz"])
    args = ap.parse_args()

    cfg = ARCHS[args.arch]
    if args.reduced:
        cfg = reduce_cfg(cfg)
    model = get_model(cfg)
    params, _ = model.init(jax.random.PRNGKey(0))
    n = sum(int(np.prod(p.shape)) for p in jax.tree.leaves(params))
    print(f"[train] {cfg.name}{' (reduced)' if args.reduced else ''}: "
          f"{n/1e6:.1f}M params on {len(jax.devices())} device(s)")

    opt_cfg = opt_lib.AdamWConfig(lr=args.lr, warmup_steps=10,
                                  total_steps=max(args.steps, 100))
    step = jax.jit(ts_lib.make_train_step(model, opt_cfg,
                                          microbatch=args.microbatch),
                   donate_argnums=(0,))
    state = ts_lib.init_state(params)
    pipe = TokenPipeline(TokenPipelineConfig(
        vocab=cfg.vocab, seq_len=args.seq_len, global_batch=args.batch))

    if args.ckpt_dir:
        os.makedirs(args.ckpt_dir, exist_ok=True)
        last = ckpt.latest_step(args.ckpt_dir)
        if last is not None:
            restored = ckpt.restore(args.ckpt_dir, last,
                                    state._asdict() | {"data": pipe.state_dict()})
            pipe.load_state_dict(restored.pop("data"))
            state = ts_lib.TrainState(**restored)
            print(f"[train] resumed from step {last}")

    t0 = time.time()
    while int(state.step) < args.steps:
        batch = {k: jnp.asarray(v) for k, v in next(pipe).items()}
        state, metrics = step(state, batch)
        s = int(state.step)
        if s % 10 == 0 or s == 1:
            print(f"[train] step {s:5d} loss {float(metrics['loss']):.4f} "
                  f"lr {float(metrics['lr']):.2e} "
                  f"gnorm {float(metrics['grad_norm']):.3f} "
                  f"({(time.time()-t0):.0f}s)")
        if args.ckpt_dir and s % args.ckpt_every == 0:
            ckpt.save(args.ckpt_dir, s,
                      state._asdict() | {"data": pipe.state_dict()},
                      mode=args.ckpt_mode, keep=3)
    print(f"[train] finished {args.steps} steps in {time.time()-t0:.0f}s")


if __name__ == "__main__":
    main()
