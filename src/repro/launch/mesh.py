"""Production mesh construction and logical-axis sharding rules.

``make_production_mesh`` is a FUNCTION (importing this module never touches
jax device state).  Single pod: 16x16 = 256 chips (data x model); multi-pod:
2x16x16 = 512 chips (pod x data x model).  The ``pod`` axis extends data
parallelism across pods (gradient reduction crosses the inter-pod links —
exactly the collective the homomorphic compressed all-reduce targets).
"""
from __future__ import annotations


import numpy as np
import jax
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.models import common as model_common


def auto_axis_types(n_axes: int) -> dict[str, tuple]:
    """``axis_types`` kwargs for ``jax.make_mesh``, portable across jax
    versions (older releases predate ``jax.sharding.AxisType``; their meshes
    are implicitly Auto)."""
    axis_type = getattr(jax.sharding, "AxisType", None)
    if axis_type is None:
        return {}
    return {"axis_types": (axis_type.Auto,) * n_axes}


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes, **auto_axis_types(len(axes)))


#: mesh axis name of the analytics block-shard dimension.
SHARD_AXIS = "shard"


def make_analytics_mesh(n_shards: int | None = None):
    """1-D ``("shard",)`` mesh for block-sharded analytics field stores.

    The production mesh's ``(data, model)`` axes partition batches and
    weights; a :class:`repro.shard.ShardedFieldStore` partitions the
    *blocks* of one encoded field, which wants a single flat axis.  The
    mesh is host-count aware: devices are ordered by ``process_index``
    first, so consecutive shards land on co-located devices and a block
    stripe's scatter/psum merge crosses hosts as few times as the device
    topology allows.  ``n_shards`` caps the axis (default: every
    addressable device); asking for more shards than devices is an error —
    placement is physical, never oversubscribed.
    """
    devices = sorted(jax.devices(), key=lambda d: (d.process_index, d.id))
    n = len(devices) if n_shards is None else int(n_shards)
    if not (1 <= n <= len(devices)):
        raise ValueError(
            f"n_shards must be in [1, {len(devices)}] "
            f"(addressable devices), got {n_shards}")
    mesh_devices = np.asarray(devices[:n])
    axis_type = getattr(jax.sharding, "AxisType", None)
    if axis_type is None:
        return jax.sharding.Mesh(mesh_devices, (SHARD_AXIS,))
    return jax.sharding.Mesh(mesh_devices, (SHARD_AXIS,),
                             axis_types=(axis_type.Auto,))


def make_host_mesh(shape: tuple[int, ...] = (1, 1), axes=("data", "model")):
    """Tiny mesh over however many (CPU) devices exist — smoke tests."""
    n = len(jax.devices())
    shape = (n, 1)
    return jax.make_mesh(shape, axes, **auto_axis_types(len(axes)))


def logical_rules(mesh, *, seq_shard: bool = False) -> dict[str, str | None]:
    """Logical axis -> mesh axis mapping for the current mesh.

    ``seq_shard`` additionally maps kv_seq -> model (sequence parallelism
    for very long KV caches / states).
    """
    has_pod = "pod" in mesh.axis_names
    batch_axes = ("pod", "data") if has_pod else ("data",)
    rules = dict(model_common.DEFAULT_RULES)
    rules.update({
        "batch": batch_axes if len(batch_axes) > 1 else batch_axes[0],
        "embed_w": "data",      # FSDP weight shard over data
        "heads": "model",
        "kv_heads": "model",
        "mlp": "model",
        "vocab": "model",
        "experts": "model",
        "expert_cap": batch_axes if len(batch_axes) > 1 else batch_axes[0],
        "lora": None,
        "kv_seq": "model" if seq_shard else None,
    })
    return rules


def activate(mesh, *, seq_shard: bool = False):
    """Install the mesh + rules into the model sharding context."""
    model_common.CTX.activate(mesh, logical_rules(mesh, seq_shard=seq_shard))


def deactivate():
    model_common.CTX.deactivate()


def spec_to_sharding(mesh, logical_spec: tuple[str | None, ...],
                     shape: tuple[int, ...], rules: dict[str, str | None]
                     ) -> NamedSharding:
    """One logical spec -> NamedSharding with divisibility fallback."""
    axes = []
    used = set()
    for dim, name in zip(shape, logical_spec):
        mesh_axis = rules.get(name) if name else None
        if mesh_axis is None:
            axes.append(None)
            continue
        ax_tuple = mesh_axis if isinstance(mesh_axis, tuple) else (mesh_axis,)
        if any(a in used for a in ax_tuple):
            axes.append(None)  # an axis may shard only one dim
            continue
        size = int(np.prod([mesh.shape[a] for a in ax_tuple]))
        if dim % size:
            axes.append(None)  # fallback: replicate non-divisible dims
        else:
            axes.append(mesh_axis)
            used.update(ax_tuple)
    return NamedSharding(mesh, P(*axes))


def tree_shardings(mesh, spec_tree, shape_tree, *, seq_shard: bool = False):
    """Map a logical-spec tree + shape tree -> NamedSharding tree."""
    rules = logical_rules(mesh, seq_shard=seq_shard)
    is_spec = lambda x: isinstance(x, tuple) and (
        len(x) == 0 or isinstance(x[0], (str, type(None))))
    return jax.tree.map(
        lambda spec, leaf: spec_to_sharding(mesh, spec, leaf.shape, rules),
        spec_tree, shape_tree, is_leaf=is_spec)


def batch_shardings(mesh, batch_specs):
    """Batch inputs: leading dim over (pod,)data, rest replicated."""
    has_pod = "pod" in mesh.axis_names
    baxes = ("pod", "data") if has_pod else "data"

    def of(leaf):
        if leaf.ndim == 0:
            return NamedSharding(mesh, P())
        b = leaf.shape[0]
        size = int(np.prod([mesh.shape[a] for a in (baxes if isinstance(baxes, tuple) else (baxes,))]))
        if b % size == 0:
            return NamedSharding(mesh, P(baxes, *([None] * (leaf.ndim - 1))))
        if not isinstance(baxes, tuple) or b % mesh.shape["data"] != 0:
            return NamedSharding(mesh, P(*([None] * leaf.ndim)))
        return NamedSharding(mesh, P("data", *([None] * (leaf.ndim - 1))))

    return jax.tree.map(of, batch_specs)
