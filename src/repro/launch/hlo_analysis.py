"""Post-SPMD HLO cost analyzer with while-loop trip-count propagation.

``Compiled.cost_analysis()`` counts each computation once, but ``lax.scan``
lowers to a ``while`` whose body runs L times — so for scan-over-layers
models it undercounts FLOPs/bytes/collectives by ~L.  This analyzer parses
``compiled.as_text()`` (the per-device, post-partitioning module):

  1. split the module into computation blocks;
  2. recover each while loop's trip count from its condition block
     (the loop-bound constant — exact for lax.scan lowerings);
  3. propagate multipliers through the call graph (while bodies x trip,
     fusions/calls x callsite multiplier);
  4. per block, account dot/conv FLOPs (operand shapes resolved from local
     SSA defs), elementwise/copy output bytes (HBM-traffic proxy for
     non-fusion-internal ops), and collective payload bytes by kind.

Numbers are per-device (the module is the per-device SPMD program).
"""
from __future__ import annotations

import re
from collections import defaultdict

DTYPE_BYTES = {
    "f64": 8, "f32": 4, "bf16": 2, "f16": 2, "f8e4m3fn": 1, "f8e5m2": 1,
    "s64": 8, "u64": 8, "s32": 4, "u32": 4, "s16": 2, "u16": 2,
    "s8": 1, "u8": 1, "s4": 1, "u4": 1, "pred": 1, "c64": 8, "c128": 16,
}
COLLECTIVES = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all",
               "collective-permute")

# Ops whose outputs stay in VMEM/registers under TPU fusion — excluded from
# the HBM-traffic proxy.  Structural estimate: real fusion decisions differ,
# but counting every elementwise temp would overstate traffic ~10-30x.
ELEMENTWISE = {
    "add", "subtract", "multiply", "divide", "maximum", "minimum", "negate",
    "abs", "exponential", "exponential-minus-one", "log", "log-plus-one",
    "tanh", "logistic", "rsqrt", "sqrt", "power", "select", "compare", "and",
    "or", "xor", "not", "shift-left", "shift-right-logical",
    "shift-right-arithmetic", "convert", "broadcast", "iota", "reshape",
    "round-nearest-even", "round-nearest-afz", "floor", "ceil", "sign",
    "clamp", "is-finite", "reduce-precision", "sine", "cosine", "expm1",
    "log1p", "rem", "atan2", "pad", "slice", "concatenate", "rev",
}

_BLOCK_START = re.compile(r"^\s*(?:ENTRY\s+)?%?([\w\.\-]+)\s*\((.*)\)\s*->")
_OP_LINE = re.compile(r"^\s*(?:ROOT\s+)?%?([\w\.\-]+)\s*=\s*(\(?[a-z0-9]+\[.*?)\s*([a-z][\w\-]*)\(")
_TYPE_RE = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]")
_PARAM_RE = re.compile(r"%?([\w\.\-]+):\s*(\(?[a-z0-9]+\[[^)]*?\]?\)?)(?:,|$)")


def _type_bytes_and_shapes(type_str: str) -> tuple[float, list[tuple[str, list[int]]]]:
    shapes = []
    total = 0.0
    for dt, dims in _TYPE_RE.findall(type_str):
        if dt not in DTYPE_BYTES:
            continue
        shape = [int(d) for d in dims.split(",") if d] if dims else []
        numel = 1
        for d in shape:
            numel *= d
        total += numel * DTYPE_BYTES[dt]
        shapes.append((dt, shape))
    return total, shapes


class Block:
    def __init__(self, name: str):
        self.name = name
        self.lines: list[str] = []
        self.defs: dict[str, str] = {}      # ssa name -> type string
        self.whiles: list[tuple[str, str]] = []  # (body, cond)
        self.calls: list[str] = []          # fusion/call targets
        self.dot_flops = 0.0
        self.bytes = 0.0
        self.collectives: dict[str, tuple[int, float]] = defaultdict(lambda: (0, 0.0))


def _parse_blocks(text: str) -> dict[str, Block]:
    blocks: dict[str, Block] = {}
    cur: Block | None = None
    for line in text.splitlines():
        if cur is None:
            m = _BLOCK_START.match(line)
            if m and "{" in line:
                cur = Block(m.group(1))
                for pname, ptype in _PARAM_RE.findall(m.group(2)):
                    cur.defs[pname] = ptype
            continue
        if line.strip() == "}" or line.rstrip().endswith("} // " + cur.name):
            blocks[cur.name] = cur
            cur = None
            continue
        if line.strip().startswith("}"):
            blocks[cur.name] = cur
            cur = None
            continue
        cur.lines.append(line)
    if cur is not None:
        blocks[cur.name] = cur
    return blocks


def _analyze_block(b: Block):
    for line in b.lines:
        m = _OP_LINE.match(line)
        if not m:
            continue
        name, type_str, op = m.groups()
        b.defs[name] = type_str
        out_bytes, out_shapes = _type_bytes_and_shapes(type_str)

        if op == "while":
            mb = re.search(r"body=%?([\w\.\-]+)", line)
            mc = re.search(r"condition=%?([\w\.\-]+)", line)
            mt = re.search(r'"known_trip_count":\{"n":"(\d+)"\}', line)
            if mb and mc:
                b.whiles.append((mb.group(1), mc.group(1),
                                 int(mt.group(1)) if mt else None))
            continue
        if op in ("fusion", "call", "conditional"):
            for target in re.findall(r"(?:calls|to_apply)=%?([\w\.\-]+)", line):
                b.calls.append(target)
            b.bytes += out_bytes
            continue
        if op in COLLECTIVES or op.rstrip("-start") in COLLECTIVES:
            kind = op.replace("-start", "")
            if kind in COLLECTIVES:
                cnt, byt = b.collectives[kind]
                b.collectives[kind] = (cnt + 1, byt + out_bytes)
            continue
        if op == "dot":
            ops_m = re.search(r"dot\(([^)]*)\)", line)
            contract = re.search(r"lhs_contracting_dims=\{([0-9,]*)\}", line)
            if ops_m:
                operands = [o.strip().lstrip("%") for o in ops_m.group(1).split(",")]
                lhs_type = b.defs.get(operands[0], "")
                rhs_type = b.defs.get(operands[1], "") if len(operands) > 1 else ""
                lhs_bytes, lhs_shapes = _type_bytes_and_shapes(lhs_type)
                rhs_bytes, _ = _type_bytes_and_shapes(rhs_type)
                k = 1
                if lhs_shapes and contract:
                    lshape = lhs_shapes[0][1]
                    for ci in contract.group(1).split(","):
                        if ci:
                            idx = int(ci)
                            if idx < len(lshape):
                                k *= lshape[idx]
                out_numel = 1
                for _, shp in out_shapes:
                    for d in shp:
                        out_numel *= d
                b.dot_flops += 2.0 * out_numel * k
                b.bytes += lhs_bytes + rhs_bytes  # both operands stream from HBM
            b.bytes += out_bytes
            continue
        if op == "convolution":
            # rough: 2 * out_numel * (kernel elems) — rare in these models
            b.dot_flops += 2.0 * out_bytes  # conservative placeholder
            b.bytes += out_bytes
            continue
        if op in ("parameter", "constant", "tuple", "get-tuple-element",
                  "bitcast", "after-all", "partition-id") or op in ELEMENTWISE:
            continue
        if op in ("dynamic-update-slice", "scatter"):
            # in-place update: traffic = the written slice, not the buffer
            # (XLA aliases the operand; counting the output would charge the
            # whole KV cache per decode step)
            ops_m = re.search(r"\(([^)]*)\)", line)
            if ops_m:
                operands = [o.strip().lstrip("%") for o in ops_m.group(1).split(",")]
                if len(operands) > 1:
                    upd_bytes, _ = _type_bytes_and_shapes(b.defs.get(operands[1], ""))
                    b.bytes += upd_bytes
                    continue
        b.bytes += out_bytes


def _trip_count(cond: Block) -> int:
    """Loop bound from the condition block: the largest s32 constant."""
    best = 1
    for line in cond.lines:
        for m in re.finditer(r"constant\((\d+)\)", line):
            best = max(best, int(m.group(1)))
    return best


def analyze(text: str, entry_hint: str = "main") -> dict:
    blocks = _parse_blocks(text)
    for b in blocks.values():
        _analyze_block(b)

    entry_name = None
    for name in blocks:
        if name.startswith(entry_hint):
            entry_name = name
    if entry_name is None:  # fall back: the block with most whiles/lines
        entry_name = max(blocks, key=lambda n: len(blocks[n].lines))

    # execution multiplier = sum over call paths of the product of loop trip
    # counts along the path (the call graph is a DAG; memoized recursion)
    parents: dict[str, list[tuple[str, float]]] = defaultdict(list)
    for name, b in blocks.items():
        for body, cond, known in b.whiles:
            trips = known if known is not None else (
                _trip_count(blocks[cond]) if cond in blocks else 1)
            parents[body].append((name, float(trips)))
            parents[cond].append((name, float(trips) + 1.0))
        for callee in b.calls:
            parents[callee].append((name, 1.0))

    memo: dict[str, float] = {}

    def mult_of(name: str, _depth=0) -> float:
        if name == entry_name:
            return 1.0
        if name in memo:
            return memo[name]
        if _depth > len(blocks) + 2:  # cycle guard
            return 0.0
        memo[name] = 0.0  # break accidental cycles
        memo[name] = sum(mult_of(p, _depth + 1) * w for p, w in parents[name])
        return memo[name]

    mult = {name: mult_of(name) for name in blocks}

    total = {"dot_flops": 0.0, "bytes": 0.0,
             "collectives": defaultdict(lambda: {"count": 0.0, "bytes": 0.0})}
    for name, b in blocks.items():
        m = mult.get(name, 0.0)
        if m == 0.0:
            continue
        total["dot_flops"] += m * b.dot_flops
        total["bytes"] += m * b.bytes
        for kind, (cnt, byt) in b.collectives.items():
            total["collectives"][kind]["count"] += m * cnt
            total["collectives"][kind]["bytes"] += m * byt

    wire = 0.0
    for kind, rec in total["collectives"].items():
        factor = 2.0 if kind == "all-reduce" else 1.0
        rec["wire_bytes"] = rec["bytes"] * factor
        wire += rec["wire_bytes"]
    return {
        "dot_flops": total["dot_flops"],
        "bytes_proxy": total["bytes"],
        "collectives": {k: dict(v) for k, v in total["collectives"].items()},
        "wire_bytes_total": wire,
    }
