"""Homomorphic compressed collectives (paper technique on the wire)."""
from . import hom_collectives
from .hom_collectives import (bit_budget, compressed_psum_tree, init_residuals,
                              packed_allgather, stage1_stats)
