"""Homomorphic compressed collectives (the paper's technique on the wire).

The paper's stage-②/③ homomorphism — *sums commute with quantization and
linear decorrelation* — is exactly what a gradient all-reduce needs: each
worker quantizes once, the ring adds **integer residuals** hop by hop, and
dequantization happens once at the end.  This is the HSZ analogue of
hZCCL [21] realized in JAX collectives:

* wire dtype int16 (2x fewer collective bytes than f32; the dominant
  roofline term for DP-bound cells — see EXPERIMENTS.md §Perf);
* a *shared* error bound (pmax of local maxima) keeps every worker's
  quantizer identical, so ``psum(q_i) == quantize(sum(v_i))`` up to the
  per-worker rounding absorbed by error feedback;
* bit budget ``b = 15 - ceil(log2(world))`` guarantees the int16
  accumulator cannot overflow across the reduction tree;
* error feedback (Seide et al.) carries each worker's quantization residual
  into the next step, preserving convergence.

``stage1_stats`` mirrors the paper's metadata-only analytics: per-tensor
mean/second-moment telemetry read from block sums of the *quantized*
gradients — O(n_blocks) work, no decompression.
"""
from __future__ import annotations

import math
from typing import Any

import jax
import jax.numpy as jnp


#: largest magnitude an int16 psum accumulator may reach (the wire dtype's
#: positive range).  The audit's ``sharddisjoint`` collective sweep proves
#: :func:`worst_case_psum` stays under this for every supported world size.
PSUM_CONTAINER_MAX = 2**15 - 1


def bit_budget(world: int, container_bits: int = 16) -> int:
    """Per-worker magnitude bits so the psum cannot overflow the container.

    The ``max(2, ...)`` floor keeps the quantizer usable at absurd world
    sizes — which also means the overflow-freedom guarantee holds only up
    to ``world < 2**(container_bits - 3)`` (32768 for int16); the audit
    sweeps the supported range and documents the cliff.
    """
    return max(2, container_bits - 1 - math.ceil(math.log2(max(world, 1))))


def worst_case_psum(world: int, container_bits: int = 16) -> int:
    """Largest magnitude the compressed psum accumulator can reach: every
    worker contributing the clipping bound of its bit budget."""
    return world * (2 ** (bit_budget(world, container_bits) - 1) - 1)


def _leaf_compressed_psum(v: jax.Array, axis: str, bits: int):
    """One leaf: shared-eps quantize -> int16 psum -> dequantize.

    Returns (summed value, local quantization residual).
    """
    qmax = float(2 ** (bits - 1) - 1)
    vmax = jax.lax.pmax(jnp.max(jnp.abs(v)), axis)          # shared across workers
    eps = jnp.maximum(vmax / qmax, 1e-30) * 0.5             # |v| <= 2*eps*qmax
    q = jnp.clip(jnp.round(v / (2.0 * eps)), -qmax, qmax).astype(jnp.int16)
    qsum = jax.lax.psum(q, axis)                            # int16 on the wire
    summed = qsum.astype(jnp.float32) * (2.0 * eps)
    residual = v - q.astype(jnp.float32) * (2.0 * eps)
    return summed, residual


def compressed_psum_tree(grads, residuals, axis: str, world: int,
                         container_bits: int = 16):
    """Error-feedback compressed all-reduce over a gradient pytree.

    Must be called inside a ``shard_map`` body where ``axis`` is a manual
    mesh axis.  Returns (mean gradients, new residuals).
    """
    bits = bit_budget(world, container_bits)
    flat, treedef = jax.tree.flatten(grads)
    res_flat = jax.tree.leaves(residuals) if residuals is not None else [
        jnp.zeros_like(l) for l in flat]
    out, new_res = [], []
    for g, r in zip(flat, res_flat):
        v = g.astype(jnp.float32) + r
        s, nr = _leaf_compressed_psum(v, axis, bits)
        out.append((s / world).astype(g.dtype))
        new_res.append(nr)
    return jax.tree.unflatten(treedef, out), jax.tree.unflatten(treedef, new_res)


def init_residuals(params) -> Any:
    """Zero error-feedback state matching the parameter tree."""
    return jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)


# ---------------------------------------------------------------------------
# bit-packed all-gather (weight/activation broadcast path)
# ---------------------------------------------------------------------------

def packed_allgather(x: jax.Array, axis: str, bits: int) -> jax.Array:
    """All-gather a tensor in HSZ fixed-rate packed form.

    Quantizes with a shared eps, zigzag bit-packs to ``bits``/value (real
    wire-byte reduction: bits/32 uint32 words per value), gathers, unpacks.
    """
    from repro.core import encode

    qmax = float(2 ** (bits - 1) - 1)
    vmax = jax.lax.pmax(jnp.max(jnp.abs(x)), axis)
    eps = jnp.maximum(vmax / qmax, 1e-30) * 0.5
    q = jnp.clip(jnp.round(x.reshape(-1) / (2.0 * eps)), -qmax, qmax).astype(jnp.int32)
    n = q.shape[0]
    pad = (-n) % 32
    u = encode.zigzag(jnp.pad(q, (0, pad)))
    words = encode.pack_uniform(u, bits)
    gathered = jax.lax.all_gather(words, axis)              # packed on the wire
    world = gathered.shape[0]
    vals = jax.vmap(lambda w: encode.unpack_uniform(w, n + pad, bits))(gathered)
    out = encode.unzigzag(vals)[:, :n].astype(jnp.float32) * (2.0 * eps)
    return out.reshape((world,) + x.shape)


# ---------------------------------------------------------------------------
# stage-① telemetry (paper §V-A.1 applied to gradients)
# ---------------------------------------------------------------------------

def stage1_stats(grads, block: int = 4096) -> dict[str, jax.Array]:
    """Metadata-only gradient statistics: global mean and 2nd moment derived
    from per-block sums (the paper's D_m), never touching full precision."""
    total, total_sq, count = 0.0, 0.0, 0
    for g in jax.tree.leaves(grads):
        v = g.reshape(-1).astype(jnp.float32)
        n = v.shape[0]
        pad = (-n) % block
        vb = jnp.pad(v, (0, pad)).reshape(-1, block)
        bsum = jnp.sum(vb, axis=1)       # block metadata (D_m)
        bsq = jnp.sum(vb * vb, axis=1)   # second-moment metadata
        total = total + jnp.sum(bsum)
        total_sq = total_sq + jnp.sum(bsq)
        count += n
    mean = total / count
    var = jnp.maximum(total_sq / count - mean * mean, 0.0)
    return {"mean": mean, "rms": jnp.sqrt(total_sq / count),
            "std": jnp.sqrt(var), "norm": jnp.sqrt(total_sq)}
