"""Streaming time-slab ingestion with incremental homomorphic temporal
analytics (DESIGN.md §9).

Real scientific producers emit data as an append-only stream of timesteps.
``repro.stream`` turns the repo's serving stack into a system that absorbs
continuous writes:

* :class:`TemporalField` — an append-only sequence of error-bounded-
  compressed time slabs sharing one quantization grid; history is never
  re-encoded.
* :class:`StreamFieldStore` — a :class:`~repro.store.FieldStore` whose
  ``append(id, data)`` reconstructs **only the new slab** and merges its
  integer-exact summary into each resident
  :class:`~repro.core.oplib.TemporalSummary` (replace-in-place, never
  invalidate-and-rebuild).
* :func:`query_temporal` — the temporal half of ``repro.analytics.query``:
  ``tdelta`` and running ``tmean``/``tmin``/``tmax``/``tstd`` over the
  time axis, lowered as homomorphic merges of per-slab summaries,
  bit-identical to the same reduction over the full decompression of the
  concatenated field, with slab-count-stable compiled programs (appends
  never retrace).
"""
from repro.core.oplib import (TEMPORAL_OPS, TemporalSummary,
                              merge_summaries, summarize_slab,
                              summary_from_q, temporal_postlude)

from .query import query_temporal
from .store import TEMPORAL_TAG, StreamFieldStore
from .temporal import TemporalField

__all__ = [
    "TemporalField", "StreamFieldStore", "TemporalSummary", "TEMPORAL_OPS",
    "TEMPORAL_TAG", "merge_summaries", "summarize_slab", "summary_from_q",
    "temporal_postlude", "query_temporal",
]
