"""Streaming field store: continuous ingest into resident temporal summaries.

A :class:`StreamFieldStore` is a :class:`~repro.store.FieldStore` that also
registers :class:`~repro.stream.TemporalField` streams and keeps their
merged :class:`~repro.core.oplib.TemporalSummary` intermediates resident in
the same byte-budgeted LRU.  The streaming contract (DESIGN.md §9):

* **append is incremental** — ``append(id, data)`` compresses the new slab
  and, for every *resident* summary cell of that id (full-field and each
  cached region window), reconstructs **only the new slab** and merges its
  integer summary into the resident one (``oplib.merge_summaries``) — a
  replace-in-place of the cache entry, never an invalidate-and-rebuild.
  The incremental-vs-recompute decision is costed through the planner
  (:func:`repro.analytics.planner.plan_refresh`) against the calibrated
  reconstruction table; with a resident summary the incremental path is
  never dearer, and without one the rebuild is deferred to the next query.
* **appends never invalidate unrelated materializations** — entries of
  other ids (and the spatial materializations of ordinary fields) are
  untouched.
* **eviction degrades to recompute, not to wrong answers** — a summary the
  budget rejects is simply rebuilt from all slabs on the next query, and
  the rebuilt summary is bit-identical to the incrementally maintained one
  (integer merges are associative).
"""
from __future__ import annotations


import jax

from repro.core import Stage, oplib
from repro.core import region as region_mod
from repro.core.oplib import TemporalSummary
from repro.store import FieldStore

from .temporal import TemporalField

#: cache-key tag of temporal summary cells: one summary per (id, region)
#: serves every stage its feasibility row allows (the integers are the same).
TEMPORAL_TAG = "__temporal__"


class StreamFieldStore(FieldStore):
    """Field store with streaming ingest (see module docstring).

    ``engine`` (a :class:`~repro.analytics.BatchedAnalytics`, defaulting to
    the process-wide one) compiles the per-slab summarizer and merge
    programs; ``cost_model`` feeds the planner's summarize-stage choice and
    the incremental-vs-recompute costing.
    """

    def __init__(self, cache_bytes: int = 256 << 20, *, engine=None,
                 cost_model=None):
        super().__init__(cache_bytes)
        self._engine_override = engine
        self.cost_model = cost_model
        #: monotone counters of streaming refresh work
        self.incremental_merges = 0
        self.summary_rebuilds = 0

    @property
    def engine(self):
        if self._engine_override is not None:
            return self._engine_override
        from repro.analytics.engine import default_engine
        return default_engine

    # -- temporal registry --------------------------------------------------
    def put(self, field_id, field, *, replace=False):
        if isinstance(field, TemporalField):
            raise TypeError(
                "TemporalField streams register via put_temporal(), not put()")
        return super().put(field_id, field, replace=replace)

    def put_temporal(self, field_id: str, tf: TemporalField, *,
                     replace: bool = False) -> str:
        """Register an append-only temporal field under ``field_id``."""
        if not isinstance(field_id, str) or not field_id:
            raise ValueError(
                f"field id must be a non-empty string, got {field_id!r}")
        if not isinstance(tf, TemporalField):
            raise TypeError(
                f"expected a TemporalField, got {type(tf).__name__}")
        if field_id in self._fields:
            if not replace:
                raise ValueError(
                    f"field id {field_id!r} already registered "
                    "(pass replace=True to overwrite)")
            self.invalidate(field_id)
        self._fields[field_id] = tf
        return field_id

    def is_temporal(self, field_id: str) -> bool:
        return isinstance(self.get(field_id), TemporalField)

    def _temporal(self, field_id: str) -> TemporalField:
        tf = self.get(field_id)
        if not isinstance(tf, TemporalField):
            raise TypeError(
                f"field id {field_id!r} is not a temporal field; append() "
                "and temporal ops need a TemporalField (see put_temporal)")
        return tf

    def _temporal_key(self, field_id: str, tf: TemporalField,
                      region) -> tuple:
        norm = (region_mod.normalize_region(region, tf.shape)
                if region is not None else None)
        return (field_id, TEMPORAL_TAG, norm)

    def _summary_stage(self, tf: TemporalField, region=None) -> Stage:
        """Cheapest feasible stage to reconstruct a slab summary at (the
        summary itself is stage-independent — only the route is costed)."""
        from repro.analytics.planner import plan_stage
        slab0 = tf.slabs[0] if tf.slabs else None
        lifted = (oplib.temporal_region(slab0, region)
                  if region is not None and slab0 is not None else None)
        return plan_stage(tf.scheme, "tmean", "auto", self.cost_model,
                          region=lifted, field=slab0)

    # -- streaming ingest ---------------------------------------------------
    def append(self, field_id: str, data) -> int:
        """Ingest one time slab and incrementally refresh every resident
        summary of ``field_id`` (reconstructing only the new slab); returns
        the slab index.  Cells evicted or never built stay absent — the
        next query rebuilds them."""
        from repro.analytics.planner import plan_refresh

        tf = self._temporal(field_id)
        idx = tf.append(data)
        slab = tf.slabs[idx]
        resident = self._resident_summary_keys(field_id)
        plan = plan_refresh(tf.scheme, self._summary_stage(tf),
                            tf.n_slabs, self.cost_model,
                            summary_resident=bool(resident))
        if plan.mode != "incremental":
            return idx  # nothing to merge into: rebuild on the next query
        for key in resident:
            self._refresh_resident(key, slab, tf)
        return idx

    def _resident_summary_keys(self, field_id: str) -> list[tuple]:
        """Resident temporal-summary cache keys of one id (full-field and
        each cached region window)."""
        return [k for k in self._cache
                if k[0] == field_id and k[1] == TEMPORAL_TAG]

    def _slab_summary(self, tf: TemporalField, slab, region) -> TemporalSummary:
        """One slab's summary over ``region``'s window — the per-append
        reconstruction unit (the sharded store overrides the route with its
        band-partial all-reduce; the integers are identical either way)."""
        part = self.engine.summarize(
            [slab], self._summary_stage(tf, region), region=region)
        return jax.tree.map(lambda x: x[0], part)

    def _refresh_resident(self, key: tuple, slab, tf: TemporalField) -> None:
        """Merge one new slab into one resident summary cell,
        replace-in-place (LRU-refreshing)."""
        old = self._cache.get(key)
        if old is None:
            # refreshing an earlier cell evicted this one under budget
            # pressure — it is no longer resident, so there is nothing
            # to merge into; the next query rebuilds it
            return
        merged = self.engine.merge_summaries(
            old, self._slab_summary(tf, slab, key[2]))
        self._insert(key, merged)
        self.incremental_merges += 1

    # -- serving ------------------------------------------------------------
    def temporal_summary(self, field_id: str, *, region=None,
                         stage=None) -> TemporalSummary:
        """Merged summary over every appended slab of ``field_id``.

        A resident cell is a hit (any stage — the integers are identical);
        a miss rebuilds from all slabs at ``stage`` (or the planner's
        cheapest feasible) and inserts the result, budget permitting.
        """
        tf = self._temporal(field_id)
        if not tf.slabs:
            raise ValueError(
                f"temporal field {field_id!r} has no appended slabs")
        key = self._temporal_key(field_id, tf, region)
        m = self._peek_hit(key)
        if m is not None:
            return m
        self.stats.misses += 1
        if stage is None:
            stage = self._summary_stage(tf, region)
        merged = self._build_summary(tf, Stage(stage), region)
        self.summary_rebuilds += 1
        self._insert(key, merged)
        return merged

    def _build_summary(self, tf: TemporalField, stage: Stage,
                       region) -> TemporalSummary:
        """Summarize every slab and merge in temporal order — one algorithm
        for the storeless and store-miss paths (`query._cold_summary`)."""
        from .query import _cold_summary

        return _cold_summary(tf, stage, region, self.engine)[0]
