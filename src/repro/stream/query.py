"""Temporal query front-end: incremental analytics over appended streams.

``repro.analytics.query`` delegates here whenever the op set is temporal
(``tdelta`` / ``tmean`` / ``tmin`` / ``tmax`` / ``tstd``), so clients use
one ``query()`` for both workloads.  Execution is three slab-count-stable
compiled programs (DESIGN.md §9): the per-slab summarizer (only on store
misses — a hot stream serves straight from its resident merged summary),
the pairwise merge, and the op-set postlude, each keyed on layout and
summary signature but never on how many slabs the stream holds — so a
stream queried after its K-th append compiles nothing new.
"""
from __future__ import annotations
from collections.abc import Sequence

from functools import reduce

import jax

from repro.core import Stage, oplib

from .temporal import TemporalField


def _cold_summary(tf: TemporalField, stage: Stage, region, engine):
    """Storeless path: summarize every slab (batched per layout) and merge
    in temporal order.  Returns ``(summary, n_calls)`` where ``n_calls``
    counts the compiled calls issued (one batched summarize per layout
    group plus one merge per fold step) so callers report dispatch
    accounting uniformly with the spatial path."""
    from repro.core import layout_key

    groups = {}
    for i, slab in enumerate(tf.slabs):
        groups.setdefault(layout_key(slab), []).append(i)
    parts = [None] * len(tf.slabs)
    for indices in groups.values():
        stacked = engine.summarize([tf.slabs[i] for i in indices], stage,
                                   region=region)
        for j, i in enumerate(indices):
            parts[i] = jax.tree.map(lambda x, _j=j: x[_j], stacked)
    return (reduce(engine.merge_summaries, parts),
            len(groups) + max(0, len(parts) - 1))


def query_temporal(fields: Sequence, op: str | Sequence[str],
                   stage: Stage | str | int = "auto", *,
                   axis: int = 0, region=None, cost_model=None,
                   engine=None, store=None):
    """Run a temporal op set over one or more temporal fields (or store ids).

    Mirrors :func:`repro.analytics.query.query`: returns a ``QueryResult``
    with per-field values (a dict per field for op sets) in input order.
    ``region`` is spatial; ``stage`` validates against the temporal
    feasibility rows (explicit infeasible stages raise before any work) and
    routes the *reconstruction* on cold summaries — results are
    bit-identical at every feasible stage because the summaries are
    integer-exact.
    """
    from repro.analytics.engine import default_engine
    from repro.analytics.planner import plan_stages
    from repro.analytics.query import QueryResult

    single = isinstance(op, str)
    names = oplib.canonical_ops(op)
    if not oplib.is_temporal_ops(names):
        raise ValueError(f"{names} is not a temporal op set")
    if engine is None:
        engine = default_engine
    del axis  # temporal reductions are always over the time axis

    hits0, misses0 = ((store.stats.hits, store.stats.misses)
                      if store is not None else (0, 0))
    values, stages = [], []
    n_dispatches = 0
    group_sigs = set()  # layout batches, mirroring the spatial n_batches
    for item in fields:
        fid: str | None = None
        if isinstance(item, str):
            if store is None:
                raise ValueError(
                    f"field id {item!r} given but no store= attached to "
                    "the query")
            tf = store.get(item)
            fid = item
        else:
            tf = item
        if not isinstance(tf, TemporalField):
            raise TypeError(
                f"temporal ops {names} run over TemporalField streams; got "
                f"{type(tf).__name__}" + (f" for id {fid!r}" if fid else ""))
        if not tf.slabs:
            raise ValueError(
                "temporal field has no appended slabs"
                + (f" (id {fid!r})" if fid else ""))
        slab0 = tf.slabs[0]
        lifted = (oplib.temporal_region(slab0, region)
                  if region is not None else None)
        plan = plan_stages(tf.scheme, names, stage,
                           cost_model or engine.cost_model,
                           region=lifted, field=slab0)
        # temporal op sets always share one summary, so a fused stage always
        # exists — but a calibrated cost model may still price per-op stages
        # cheaper (plan.fused None).  Per-op stages would reconstruct the
        # same integers several times for identical results, so collapse to
        # one shared feasible stage: the set's cheapest per-op choice.
        s = plan.fused
        if s is None:
            s = min((st for _, st in plan.stages), key=int)
        group_sigs.add((tf.layout_sig(), fid is not None))
        if fid is not None:
            if not hasattr(store, "temporal_summary"):
                raise TypeError(
                    "temporal ids need a StreamFieldStore "
                    "(repro.stream.StreamFieldStore)")
            summary = store.temporal_summary(fid, region=region, stage=s)
        else:
            summary, n_cold = _cold_summary(tf, s, region, engine)
            n_dispatches += n_cold
        out = engine.run_temporal(names, summary, tf.eps)
        n_dispatches += 1
        values.append(out[names[0]] if single else out)
        stages.append(s if single else {n: s for n in names})
    store_hits = store_misses = 0
    if store is not None:
        store_hits = store.stats.hits - hits0
        store_misses = store.stats.misses - misses0
    return QueryResult(values=values, stages=stages,
                       op=op if single else names,
                       n_batches=len(group_sigs), n_dispatches=n_dispatches,
                       store_hits=store_hits, store_misses=store_misses)
