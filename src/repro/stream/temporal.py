"""Append-only temporal fields: streaming time-slab ingestion (DESIGN.md §9).

Scientific producers (simulations, instruments) emit data as an append-only
stream of timesteps; the paper's framework assumes fields arrive whole.  A
:class:`TemporalField` closes that gap: each ``append`` error-bound-
compresses one *time slab* — a batch of timesteps, shape ``(k, *spatial)``
— as an ordinary field of any of the four schemes, **without re-encoding
history**.  All slabs share one quantization grid (``eps`` is resolved at
the first append and pinned), so their stage-③ integers concatenate into
one coherent field, and the temporal operations registered in
``repro.core.oplib`` (``tdelta``, ``tmean``/``tmin``/``tmax``/``tstd``
over the time axis) lower as homomorphic merges of per-slab integer
summaries — bit-identical to the same reduction over the full
decompression of the concatenated field, because every summary leaf is
int32 (modular, associative, order-free).

Layout discipline: slabs appended with the same timestep count encode to
the same static layout, so the engine's per-slab summarizer program
(``BatchedAnalytics.summarize``) is compiled once and reused by every
append — streaming ingest never retraces as the stream grows.
"""
from __future__ import annotations
from collections.abc import Sequence

from functools import lru_cache

import jax
import jax.numpy as jnp

from repro.core import Compressed, Encoded, HSZCompressor, Stage, by_name, oplib
from repro.core import quantize

Field = Compressed | Encoded

_INT32_MAX = 2**31 - 1


class SummaryCapacityError(RuntimeError):
    """Appending this slab would overflow an int32 TemporalSummary leaf.

    The temporal merges are exact *because* every summary leaf is int32 and
    modular sums stay in range; past the capacity the Σq² (then Σq) leaf
    wraps silently and every downstream ``tstd``/``tmean`` is corrupt.
    Raised *before* the stream is mutated, so the caller can re-shard the
    stream, loosen the error bound (smaller ``|q|``), or open a new
    :class:`TemporalField`.
    """


def summary_capacity(q_abs: int) -> int:
    """Maximum total timesteps an int32 summary holds exactly when every
    quantization index in the stream satisfies ``|q| <= q_abs``.

    The binding leaf is ``Σq²`` (``T * q_abs**2 <= 2**31 - 1``), then
    ``Σq``, then ``count``.  This formula is cross-checked against the
    static int-width analysis (``repro.audit.intwidth.summary_capacity``)
    by the audit, so the runtime guard and the audited bound cannot drift.
    """
    q_abs = int(q_abs)
    if q_abs < 0:
        raise ValueError(f"negative |q| bound: {q_abs}")
    if q_abs == 0:
        return _INT32_MAX  # all-zero stream: only the count leaf can wrap
    return min(_INT32_MAX // (q_abs * q_abs), _INT32_MAX // q_abs, _INT32_MAX)


@lru_cache(maxsize=64)
def _jit_qabs(scheme, block):
    """One compiled |q| reducer per (scheme, block): max |stage-③ integer|
    of a slab — the measured bound the capacity guard runs against."""
    comp = HSZCompressor(scheme, block)
    return jax.jit(
        lambda c: jnp.max(jnp.abs(comp.decompress(c, Stage.Q))))


@lru_cache(maxsize=64)
def _jit_compress(scheme, block):
    """One compiled slab compressor per (scheme, block) — shared across
    streams, so steady-state ingest pays device work, not per-op dispatch."""
    comp = HSZCompressor(scheme, block)
    return jax.jit(lambda data, eps: comp.compress(data, eps=eps))


@lru_cache(maxsize=64)
def _jit_encode(scheme, block, bits: int):
    """One compiled bit-packer per (scheme, block, width)."""
    comp = HSZCompressor(scheme, block)
    return jax.jit(lambda c: comp.encode(c, bits=bits))


class TemporalField:
    """An append-only stream of error-bounded-compressed time slabs.

    Parameters
    ----------
    compressor:
        An :class:`~repro.core.HSZCompressor` (or scheme name) used for
        every slab.
    rel_eb / abs_eb / eps:
        Error-bound policy.  ``eps`` (the absolute quantization step) is
        resolved from the *first* appended slab and then pinned, so every
        slab shares one quantization grid — the precondition for merging
        per-slab integer summaries exactly.
    bits:
        Payload policy: ``"auto"`` (default) bit-packs each slab at the
        first slab's exact max width plus ``headroom`` spare bits; an int
        pins the width; ``None`` keeps slabs as decoded
        :class:`~repro.core.Compressed` containers (no packing).  A slab
        whose residuals exceed the pinned width is encoded at its own
        exact width instead — correctness first; only the retrace-free
        layout guarantee narrows to the conforming slabs.
    """

    def __init__(self, compressor: HSZCompressor | str, *,
                 rel_eb: float | None = None,
                 abs_eb: float | None = None,
                 eps=None, bits: str | int | None = "auto",
                 headroom: int = 2):
        self.compressor = (by_name(compressor)
                           if isinstance(compressor, str) else compressor)
        self._rel_eb = rel_eb
        self._abs_eb = abs_eb
        self._eps = None if eps is None else jnp.asarray(eps, jnp.float32)
        if not (bits is None or bits == "auto" or isinstance(bits, int)):
            raise ValueError(f"bits must be 'auto', an int, or None; got {bits!r}")
        self._bits = bits
        self._headroom = int(headroom)
        self.slabs: list[Field] = []
        self._spatial_shape: tuple[int, ...] | None = None
        self._dtype = None
        self._q_abs_max = 0

    # -- static identity ----------------------------------------------------
    @property
    def scheme(self):
        return self.compressor.scheme

    @property
    def eps(self) -> jax.Array:
        if self._eps is None:
            raise ValueError("eps is resolved at the first append; "
                             "no slab has been appended yet")
        return self._eps

    @property
    def shape(self) -> tuple[int, ...]:
        """The *spatial* shape (regions and results live here; time grows)."""
        if self._spatial_shape is None:
            raise ValueError("no slab has been appended yet")
        return self._spatial_shape

    @property
    def n_slabs(self) -> int:
        return len(self.slabs)

    @property
    def n_steps(self) -> int:
        """Total appended timesteps across all slabs."""
        return sum(s.shape[0] for s in self.slabs)

    def layout_sig(self) -> tuple:
        """Hashable grouping signature (the serve frontend batches requests
        whose temporal fields share compression identity)."""
        eps = None if self._eps is None else float(self._eps)
        return ("temporal", self.scheme, self._spatial_shape, eps,
                None if self._dtype is None else str(self._dtype))

    # -- ingestion ----------------------------------------------------------
    def append(self, data) -> int:
        """Compress (and encode) one time slab; returns its index.

        ``data`` has shape ``(k, *spatial)`` — ``k`` timesteps of the
        field.  History is never touched: the slab is compressed alone,
        against the stream's pinned ``eps``.
        """
        data = jnp.asarray(data)
        if data.ndim < 2:
            raise ValueError(
                f"a time slab is (timesteps, *spatial); got shape {data.shape}")
        spatial = tuple(data.shape[1:])
        if self._spatial_shape is None:
            self._spatial_shape = spatial
            self._dtype = data.dtype
        elif spatial != self._spatial_shape:
            raise ValueError(
                f"slab spatial shape {spatial} != stream spatial shape "
                f"{self._spatial_shape}")
        if self._eps is None:
            self._eps = quantize.resolve_eps(data, abs_eb=self._abs_eb,
                                             rel_eb=self._rel_eb)
            self._eps = jnp.asarray(self._eps, jnp.float32)
        comp = self.compressor
        c = _jit_compress(comp.scheme, comp.block)(data, self._eps)
        # capacity guard: the merged summary's Σq² leaf is int32; refuse an
        # append that could wrap it, *before* any state is mutated.  The
        # host sync here is eager ingest code (like max_bits below), not a
        # traced region.
        q_abs = max(self._q_abs_max, int(_jit_qabs(comp.scheme, comp.block)(c)))
        steps = self.n_steps + int(data.shape[0])
        capacity = summary_capacity(q_abs)
        if steps > capacity:
            raise SummaryCapacityError(
                f"appending {int(data.shape[0])} timesteps would take the "
                f"stream to {steps} total steps, past the exact int32 "
                f"summary capacity of {capacity} for |q| <= {q_abs}; "
                "re-shard the stream, loosen the error bound, or open a "
                "new TemporalField")
        slab: Field = c
        if self._bits is not None:
            width = comp.max_bits(c)
            if self._bits == "auto":
                if not self.slabs:
                    self._bits = min(32, width + self._headroom)
            if isinstance(self._bits, int):
                # a pinned width narrower than the slab's residuals would
                # corrupt the payload: encode such a slab at its own width
                slab = _jit_encode(comp.scheme, comp.block,
                                   max(self._bits, width))(c)
        self.slabs.append(slab)
        self._q_abs_max = q_abs
        return len(self.slabs) - 1

    # -- reference path (full decompression of the concatenated field) ------
    def decompress_q(self, region=None) -> jax.Array:
        """Stage-③ integers of the *concatenated* field, ``(T, *spatial)``
        (optionally cropped to a spatial ``region``) — the full
        multi-stage decompression the homomorphic merges are pinned
        against."""
        if not self.slabs:
            raise ValueError("no slab has been appended yet")
        qs = [self.compressor.decompress(s, Stage.Q) for s in self.slabs]
        q = jnp.concatenate(qs, axis=0)
        if region is not None:
            from repro.core.region import normalize_region
            norm = normalize_region(region, self.shape)
            q = q[(slice(None),) + tuple(slice(s, e) for s, e in norm)]
        return q

    def decompress(self, stage: Stage = Stage.F) -> jax.Array:
        """Fully decompress the concatenated stream at ``stage``."""
        stage = Stage(stage)
        if stage == Stage.Q:
            return self.decompress_q()
        return jnp.concatenate(
            [self.compressor.decompress(s, stage) for s in self.slabs], axis=0)

    def reference(self, ops: str | Sequence[str],
                  region=None) -> dict[str, jax.Array]:
        """Temporal ops evaluated on the full decompression of the
        concatenated field: one direct reduction over the stage-③ integers
        of the whole stream, then the shared op postludes.

        This is the oracle the incremental (per-slab merged) path is pinned
        bit-identical to in ``tests/test_stream.py``.
        """
        names = oplib.canonical_ops(ops)
        summary = _REF_SUMMARIZE(self.decompress_q(region=region))
        return _REF_POSTLUDE(names, summary, self.eps)


#: jitted reference programs — the same formulas the engine compiles, so
#: reference and served results share their entire float tails.
_REF_SUMMARIZE = jax.jit(oplib.summary_from_q)
_REF_POSTLUDE = jax.jit(oplib.temporal_postlude, static_argnums=0)
