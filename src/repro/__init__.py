"""HSZ: homomorphic analytical operations on compressed scientific data,
integrated as a first-class feature of a multi-pod JAX LM framework.

Public entry points:

    repro.core       — the paper: 4 compressors, 4 stages, 6 homomorphic ops
    repro.kernels    — Pallas TPU kernels (ops.py wrappers / ref.py oracles)
    repro.models     — 10-architecture zoo behind one functional facade
    repro.comm       — homomorphic compressed collectives (int16 grad sync)
    repro.train      — optimizer / train-step builder / HSZ checkpoints
    repro.serve      — batched decode engine (int8 KV residency)
    repro.store      — materialized-stage field store (id-addressed serving)
    repro.stream     — streaming time-slab ingest + incremental temporal analytics
    repro.data       — resumable token pipeline + compressed field store
    repro.configs    — assigned architectures x shapes registry
    repro.launch     — mesh rules, multi-pod dry-run, roofline, drivers
"""

__version__ = "1.0.0"
