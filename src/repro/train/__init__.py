"""Training substrate: optimizer, step construction, checkpointing."""
from . import checkpoint, optimizer, train_step
