"""Train step construction: GSPMD baseline and homomorphic-compressed DP.

Two gradient-synchronization modes:

* ``gspmd`` (baseline): one ``jax.jit`` over the global batch; the data-
  parallel gradient all-reduce is implicit (f32 wire) — this is the
  paper-faithful baseline recorded in EXPERIMENTS.md §Perf.

* ``hom`` (the paper's technique on the wire): a *partial-manual*
  ``shard_map`` over the DP axes computes unreduced per-shard gradients
  (TP stays GSPMD-auto on the ``model`` axis), then
  ``comm.compressed_psum_tree`` performs the all-reduce in the quantized
  integer domain (int16 wire, shared-eps, error feedback).  The collective
  bytes drop ~2x — measured by the dry-run roofline.
"""
from __future__ import annotations

import functools
from typing import Any, NamedTuple

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro import compat
from repro.comm import hom_collectives as hom
from . import optimizer as opt_lib


class TrainState(NamedTuple):
    params: Any
    opt: opt_lib.OptState
    step: jax.Array
    ef_residual: Any | None = None   # error-feedback state (hom mode)


def init_state(params, *, hom_mode: bool = False) -> TrainState:
    return TrainState(
        params=params, opt=opt_lib.init(params), step=jnp.zeros((), jnp.int32),
        ef_residual=hom.init_residuals(params) if hom_mode else None)


def make_train_step(model, opt_cfg: opt_lib.AdamWConfig, *,
                    mode: str = "gspmd", mesh=None,
                    dp_axes: tuple = ("data",), microbatch: int | None = None):
    """Returns train_step(state, batch) -> (state, metrics)."""

    def loss_of(params, batch):
        return model.loss_fn(params, batch)

    def grads_of(params, batch):
        if microbatch is None:
            return jax.value_and_grad(loss_of)(params, batch)
        # gradient accumulation over leading-dim microbatch splits
        def split(x):
            return x.reshape((microbatch, x.shape[0] // microbatch) + x.shape[1:])
        mb = jax.tree.map(split, batch)

        def acc_step(carry, b):
            loss, g = jax.value_and_grad(loss_of)(params, b)
            return carry, (loss, g)

        _, (losses, gs) = jax.lax.scan(acc_step, 0.0, mb)
        g = jax.tree.map(lambda x: jnp.mean(x, axis=0), gs)
        return jnp.mean(losses), g

    if mode == "gspmd":
        def train_step(state: TrainState, batch):
            loss, grads = grads_of(state.params, batch)
            new_params, new_opt, stats = opt_lib.update(
                opt_cfg, grads, state.opt, state.params)
            metrics = {"loss": loss, **stats}
            return TrainState(new_params, new_opt, state.step + 1,
                              state.ef_residual), metrics
        return train_step

    if mode != "hom":
        raise ValueError(f"unknown mode {mode}")
    if mesh is None:
        raise ValueError("hom mode needs the mesh")
    world = 1
    for a in dp_axes:
        world *= mesh.shape[a]
    axis = dp_axes[0] if len(dp_axes) == 1 else dp_axes

    def local_grads(params, residual, batch):
        """shard_map body: manual over DP axes, auto over 'model'.

        Inside the body the DP axes are manual, so model-internal sharding
        constraints must not mention them: the logical rules are rebased
        (batch/expert_cap -> None) for the duration of the trace.
        """
        from repro.models.common import CTX
        old_rules = dict(CTX.rules)
        old_manual = CTX.manual_dp
        CTX.rules = {**old_rules, "batch": None, "expert_cap": None}
        CTX.manual_dp = True
        try:
            loss, grads = grads_of(params, batch)
        finally:
            CTX.rules = old_rules
            CTX.manual_dp = old_manual
        # the paper's homomorphism: add in the quantized domain
        grads, new_residual = hom.compressed_psum_tree(
            grads, residual, axis, world)
        loss = jax.lax.pmean(loss, axis)
        return loss, grads, new_residual

    def batch_spec(x):
        return P(axis)

    def train_step(state: TrainState, batch):
        shmapped = compat.shard_map(
            functools.partial(local_grads),
            mesh=mesh,
            in_specs=(P(), P(), jax.tree.map(batch_spec, batch)),
            out_specs=(P(), P(), P()),
            axis_names=set(dp_axes),
        )
        loss, grads, new_residual = shmapped(state.params, state.ef_residual, batch)
        new_params, new_opt, stats = opt_lib.update(
            opt_cfg, grads, state.opt, state.params)
        metrics = {"loss": loss, **stats}
        return TrainState(new_params, new_opt, state.step + 1, new_residual), metrics

    return train_step
