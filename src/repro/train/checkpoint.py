"""Fault-tolerant sharded checkpointing with HSZ integration.

Layout (one directory per step, atomic rename commit):

    ckpt_dir/
      step_000123.tmp/ -> step_000123/
        manifest.json        # tree structure, shapes, dtypes, stats, mode
        arrays/<idx>.bin     # zstd(raw) | HSZ stream per leaf

Features mapped to the 1000-node requirements:

* **atomic commit + retention** — a crash mid-write never corrupts the
  latest checkpoint; keep-last-k pruning;
* **async save** — serialization runs on a background thread (training
  continues; ``wait()`` joins before the next save);
* **elastic restore** — leaves are loaded host-side and ``device_put`` with
  the *current* mesh sharding: restart on a different pod count/mesh shape
  re-shards transparently;
* **HSZ mode** (the paper): float leaves stored as error-bounded HSZ
  streams; the manifest records stage-① homomorphic validation stats
  (mean/std from metadata) so restore can verify integrity *without
  decompression* — the paper's regional-statistics use case at the
  checkpoint layer.  Lossless mode (zstd) is the default for bit-exact
  resume.
"""
from __future__ import annotations

import json
import os
import shutil
import threading
import time
import zlib
from typing import Any

import numpy as np

try:
    import zstandard
except ImportError:  # optional dep: fall back to stdlib zlib lossless codec
    zstandard = None

import jax
import jax.numpy as jnp

from repro.core import Stage, encode as hsz_encode, hszp, homomorphic

_FLOAT_KINDS = ("f",)


def _lossless_codec():
    """(codec name, compress fn) — zstd when available, else stdlib zlib."""
    if zstandard is not None:
        return "zstd", zstandard.ZstdCompressor(level=3).compress
    return "zlib", lambda raw: zlib.compress(raw, 6)


def _lossless_decompress(codec: str, blob: bytes) -> bytes:
    if codec == "zstd":
        if zstandard is None:
            raise RuntimeError(
                "checkpoint was written with zstd but zstandard is not installed")
        return zstandard.ZstdDecompressor().decompress(blob)
    return zlib.decompress(blob)


def _flatten_with_paths(tree):
    flat, treedef = jax.tree_util.tree_flatten_with_path(tree)
    paths = ["/".join(str(getattr(k, "key", getattr(k, "idx", k))) for k in path)
             for path, _ in flat]
    leaves = [leaf for _, leaf in flat]
    return paths, leaves, treedef


def save(ckpt_dir: str, step: int, tree: Any, *, mode: str = "lossless",
         rel_eb: float = 1e-4, keep: int = 3, blocking: bool = True,
         extra_meta: dict | None = None) -> threading.Thread | None:
    """Serialize ``tree`` to ``ckpt_dir/step_{step:08d}`` atomically."""
    paths, leaves, _ = _flatten_with_paths(tree)
    # pull to host before handing to the writer thread
    host_leaves = [np.asarray(l) for l in leaves]

    def _write():
        final = os.path.join(ckpt_dir, f"step_{step:08d}")
        tmp = final + ".tmp"
        os.makedirs(os.path.join(tmp, "arrays"), exist_ok=True)
        manifest = {"step": step, "mode": mode, "rel_eb": rel_eb,
                    "time": time.time(), "leaves": [],
                    "extra": extra_meta or {}}
        lossless_codec, lossless_compress = _lossless_codec()
        for i, (path, arr) in enumerate(zip(paths, host_leaves)):
            entry = {"path": path, "shape": list(arr.shape),
                     "dtype": str(arr.dtype), "file": f"arrays/{i}.bin"}
            use_hsz = (mode == "hsz" and arr.dtype.kind in _FLOAT_KINDS
                       and arr.size >= 1024)
            if use_hsz:
                c = hszp.compress(jnp.asarray(arr, jnp.float32), rel_eb=rel_eb)
                blob = hsz_encode.serialize(c)
                # stage-① homomorphic validation stats (no decompression at load)
                entry["codec"] = "hsz"
                entry["stats"] = {
                    "mean": float(homomorphic.mean(c, Stage.P)),
                    "std": float(homomorphic.std(c, Stage.P)),
                }
                entry["ratio"] = float(arr.nbytes * 8) / float(hszp.serialized_bits(c))
            else:
                blob = lossless_compress(arr.tobytes())
                entry["codec"] = lossless_codec
            with open(os.path.join(tmp, entry["file"]), "wb") as f:
                f.write(blob)
            manifest["leaves"].append(entry)
        with open(os.path.join(tmp, "manifest.json"), "w") as f:
            json.dump(manifest, f)
        if os.path.exists(final):
            shutil.rmtree(final)
        os.rename(tmp, final)  # atomic commit
        _prune(ckpt_dir, keep)

    if blocking:
        _write()
        return None
    t = threading.Thread(target=_write, daemon=True)
    t.start()
    return t


def _prune(ckpt_dir: str, keep: int):
    steps = sorted(d for d in os.listdir(ckpt_dir)
                   if d.startswith("step_") and not d.endswith(".tmp"))
    for d in steps[:-keep]:
        shutil.rmtree(os.path.join(ckpt_dir, d), ignore_errors=True)


def latest_step(ckpt_dir: str) -> int | None:
    if not os.path.isdir(ckpt_dir):
        return None
    steps = [int(d.split("_")[1]) for d in os.listdir(ckpt_dir)
             if d.startswith("step_") and not d.endswith(".tmp")]
    return max(steps) if steps else None


def restore(ckpt_dir: str, step: int, target_tree: Any, *,
            shardings: Any = None, verify: bool = True) -> Any:
    """Load into the structure of ``target_tree`` (elastic re-shard via
    ``shardings`` — a matching tree of NamedSharding or None)."""
    final = os.path.join(ckpt_dir, f"step_{step:08d}")
    with open(os.path.join(final, "manifest.json")) as f:
        manifest = json.load(f)
    paths, leaves, treedef = _flatten_with_paths(target_tree)
    by_path = {e["path"]: e for e in manifest["leaves"]}
    shard_leaves = (jax.tree.leaves(shardings) if shardings is not None
                    else [None] * len(leaves))
    out = []
    for path, ref, shd in zip(paths, leaves, shard_leaves):
        entry = by_path[path]
        with open(os.path.join(final, entry["file"]), "rb") as f:
            blob = f.read()
        if entry["codec"] == "hsz":
            c = hsz_encode.deserialize(blob)
            if verify and "stats" in entry:
                mu = float(homomorphic.mean(c, Stage.M)) if c.scheme.is_blockmean \
                    else float(homomorphic.mean(c, Stage.P))
                ref_mu = entry["stats"]["mean"]
                eps = float(np.asarray(c.eps))
                if abs(mu - ref_mu) > max(2 * eps, 1e-6 * max(abs(ref_mu), 1)):
                    raise ValueError(
                        f"homomorphic integrity check failed for {path}: "
                        f"mean {mu} vs manifest {ref_mu}")
            arr = np.asarray(hszp.decompress(c, Stage.F)).reshape(entry["shape"])
            arr = arr.astype(entry["dtype"])
        else:
            arr = np.frombuffer(_lossless_decompress(entry["codec"], blob),
                                dtype=entry["dtype"]).reshape(entry["shape"])
        if tuple(arr.shape) != tuple(np.shape(ref)):
            raise ValueError(f"shape mismatch for {path}")
        arr = arr.astype(ref.dtype) if hasattr(ref, "dtype") else arr
        out.append(jax.device_put(arr, shd) if shd is not None else jnp.asarray(arr))
    return jax.tree.unflatten(treedef, out)
