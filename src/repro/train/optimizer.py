"""AdamW + schedules (pure-pytree, no external optimizer library)."""
from __future__ import annotations

import dataclasses
from typing import Any, NamedTuple

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    grad_clip: float = 1.0
    warmup_steps: int = 100
    total_steps: int = 10_000
    min_lr_ratio: float = 0.1


class OptState(NamedTuple):
    m: Any
    v: Any
    count: jax.Array


def schedule(cfg: AdamWConfig, step: jax.Array) -> jax.Array:
    """Linear warmup -> cosine decay to min_lr_ratio."""
    step = step.astype(jnp.float32)
    warm = step / jnp.maximum(cfg.warmup_steps, 1)
    t = (step - cfg.warmup_steps) / jnp.maximum(cfg.total_steps - cfg.warmup_steps, 1)
    t = jnp.clip(t, 0.0, 1.0)
    cos = cfg.min_lr_ratio + (1 - cfg.min_lr_ratio) * 0.5 * (1 + jnp.cos(jnp.pi * t))
    return cfg.lr * jnp.where(step < cfg.warmup_steps, warm, cos)


def init(params) -> OptState:
    zeros = lambda t: jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), t)
    return OptState(m=zeros(params), v=zeros(params), count=jnp.zeros((), jnp.int32))


def global_norm(tree) -> jax.Array:
    return jnp.sqrt(sum(jnp.sum(jnp.square(l.astype(jnp.float32)))
                        for l in jax.tree.leaves(tree)))


def update(cfg: AdamWConfig, grads, state: OptState, params):
    """Returns (new_params, new_state, stats)."""
    count = state.count + 1
    gnorm = global_norm(grads)
    scale = jnp.minimum(1.0, cfg.grad_clip / jnp.maximum(gnorm, 1e-9))
    grads = jax.tree.map(lambda g: g.astype(jnp.float32) * scale, grads)

    m = jax.tree.map(lambda m_, g: cfg.b1 * m_ + (1 - cfg.b1) * g, state.m, grads)
    v = jax.tree.map(lambda v_, g: cfg.b2 * v_ + (1 - cfg.b2) * g * g, state.v, grads)
    c = count.astype(jnp.float32)
    bc1 = 1 - cfg.b1 ** c
    bc2 = 1 - cfg.b2 ** c
    lr = schedule(cfg, count)

    def upd(p, m_, v_):
        step_ = (m_ / bc1) / (jnp.sqrt(v_ / bc2) + cfg.eps)
        return (p.astype(jnp.float32)
                - lr * (step_ + cfg.weight_decay * p.astype(jnp.float32))
                ).astype(p.dtype)

    new_params = jax.tree.map(upd, params, m, v)
    return new_params, OptState(m=m, v=v, count=count), {
        "grad_norm": gnorm, "lr": lr}
