"""Portability shims across the jax versions this repo supports.

The code targets the current jax API (``jax.shard_map``,
``jax.sharding.AxisType``); these wrappers degrade gracefully on older
releases (>= 0.4.3x) where the same functionality lives under
``jax.experimental.shard_map`` with ``check_rep``/``auto`` spellings.
"""
from __future__ import annotations


import jax


def shard_map(f, *, mesh, in_specs, out_specs,
              axis_names: set[str] | None = None,
              check: bool | None = None):
    """``jax.shard_map`` with manual axes ``axis_names`` (all axes if None).

    ``check=None`` keeps the upstream default (replication checking ON) —
    callers opt *out* explicitly, never silently.  On older jax this maps to
    ``jax.experimental.shard_map.shard_map`` whose ``auto`` parameter is the
    complement of ``axis_names`` and whose ``check_rep`` corresponds to
    ``check_vma`` — except that old partial-auto shard_map cannot
    replication-check, so ``auto`` forces ``check_rep=False`` there.
    """
    if hasattr(jax, "shard_map"):
        kwargs = {} if axis_names is None else {"axis_names": set(axis_names)}
        if check is not None:
            kwargs["check_vma"] = check
        return jax.shard_map(f, mesh=mesh, in_specs=in_specs,
                             out_specs=out_specs, **kwargs)
    from jax.experimental.shard_map import shard_map as _shard_map

    kwargs = {}
    if check is not None:
        kwargs["check_rep"] = check
    if axis_names is not None:
        auto = frozenset(mesh.axis_names) - set(axis_names)
        if auto:
            kwargs["auto"] = auto
            kwargs.setdefault("check_rep", False)  # unsupported with auto
    return _shard_map(f, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
                      **kwargs)
