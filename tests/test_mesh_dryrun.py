"""Mesh sharding rules + a reduced dry-run compile in a subprocess."""
import json
import os
import subprocess
import sys

import pytest
import jax

from repro.configs import ARCHS, SHAPES, cell_supported


def test_cell_support_matrix():
    """long_500k runs only for sub-quadratic families (DESIGN.md §4)."""
    runnable = {a for a in ARCHS
                if cell_supported(ARCHS[a], SHAPES["long_500k"])[0]}
    assert runnable == {"falcon-mamba-7b", "recurrentgemma-9b"}
    for a in ARCHS:
        for s in ("train_4k", "prefill_32k", "decode_32k"):
            assert cell_supported(ARCHS[a], SHAPES[s])[0]


def test_divisibility_fallback_rules():
    """Non-divisible dims fall back to replication, never error."""
    from repro.launch import mesh as mesh_lib
    # host mesh: 1 device -> every rule resolves without touching fake devices
    mesh = jax.make_mesh((1, 1), ("data", "model"),
                         **mesh_lib.auto_axis_types(2))
    rules = mesh_lib.logical_rules(mesh)
    s = mesh_lib.spec_to_sharding(mesh, ("vocab", "embed"), (15, 7), rules)
    assert s.spec is not None  # resolved without exception


SUBPROCESS = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
import json
from repro.launch.dryrun import build_cell
rec = build_cell("smollm-360m", "decode_32k", multi_pod=True)
print(json.dumps({"status": rec["status"],
                  "devices": rec.get("devices"),
                  "has_cost": "hlo" in rec}))
"""


@pytest.mark.slow
def test_multipod_compile_subprocess():
    """One real multi-pod (512-device) lower+compile as part of the suite."""
    env = dict(os.environ, PYTHONPATH="src")
    r = subprocess.run([sys.executable, "-c", SUBPROCESS], capture_output=True,
                       text=True, env=env,
                       cwd=os.path.dirname(os.path.dirname(__file__)),
                       timeout=480)
    assert r.returncode == 0, r.stderr[-3000:]
    rec = json.loads(r.stdout.strip().splitlines()[-1])
    assert rec["status"] == "ok"
    assert rec["devices"] == 512
    assert rec["has_cost"]


def test_dryrun_artifacts_complete():
    """The committed dry-run sweep covers every defined cell on both meshes."""
    out = os.path.join(os.path.dirname(os.path.dirname(__file__)),
                       "experiments", "dryrun")
    if not os.path.isdir(out):
        pytest.skip("dry-run artifacts not generated yet")
    recs = {}
    for f in os.listdir(out):
        if f.endswith(".json"):
            r = json.load(open(os.path.join(out, f)))
            recs[(r["arch"], r["shape"], r["mesh"], r.get("hom_grads", False))] = r["status"]
    for arch in ARCHS:
        for shape in SHAPES:
            for mesh in ("16x16", "2x16x16"):
                key = (arch, shape, mesh, False)
                if key not in recs:
                    continue  # sweep may still be running
                ok, _ = cell_supported(ARCHS[arch], SHAPES[shape])
                want = "ok" if ok else "skipped"
                assert recs[key] == want, (key, recs[key])
