"""Fused multi-op analytics: bit-exactness, joint planning, cache identity.

The contract under test (ISSUE 3 acceptance):

* every fused op-set result is bit-exact vs the corresponding single-op call
  at the same stage — all four schemes, with and without ``region=``;
* the jit-cache key is order-insensitive in the op set (``["std", "mean"]``
  and ``["mean", "std"]`` hit one compiled program), and a fused query
  issues one batched compiled call per layout group;
* ``plan_stages`` picks one shared stage over the feasible intersection and
  falls back to per-op stages only when a calibrated cost model prices the
  per-op optima strictly cheaper;
* ``gradient`` is a first-class planned op: feasibility matrix, engine,
  query, and serve all accept it.
"""
import numpy as np
import pytest
import jax
import jax.numpy as jnp

from repro import analytics
from repro.core import (Stage, UnsupportedStageError, homomorphic as H,
                        hszp, hszp_nd, hszx, hszx_nd, oplib)
from repro.serve import AnalyticsFrontend, AnalyticsRequest

ALL = [hszp, hszx, hszp_nd, hszx_nd]
REGION = ((30, 75), (10, 52))  # unaligned window of the 181x97 field_2d

FUSED_SETS = [("mean", "std"), ("mean", "std", "laplacian"),
              ("std", "derivative"), ("mean", "gradient")]


def _c(comp, data, rel_eb=1e-3):
    return comp.compress(jnp.asarray(data), rel_eb=rel_eb)


def _compress_many(comp, n, shape=(64, 48), rel_eb=1e-3, seed=0):
    rng = np.random.default_rng(seed)
    return [comp.compress(jnp.asarray(rng.normal(0, 1, shape).astype(np.float32)),
                          rel_eb=rel_eb) for _ in range(n)]


def _single(op, c, stage, axis=0, region=None):
    fn = {"mean": lambda: H.mean(c, stage, region=region),
          "std": lambda: H.std(c, stage, region=region),
          "derivative": lambda: H.derivative(c, stage, axis, region=region),
          "gradient": lambda: H.gradient(c, stage, region=region),
          "laplacian": lambda: H.laplacian(c, stage, region=region)}[op]
    return fn()


def _assert_same(got, ref):
    if isinstance(ref, tuple):
        assert isinstance(got, tuple) and len(got) == len(ref)
        for g, r in zip(got, ref):
            np.testing.assert_array_equal(np.asarray(g), np.asarray(r))
    else:
        np.testing.assert_array_equal(np.asarray(got), np.asarray(ref))


def _shared_stages(scheme, ops):
    return [s for s in Stage
            if all(s in analytics.feasible_stages(scheme, op) for op in ops)]


# -- fused == single-op, bit for bit ------------------------------------------

@pytest.mark.parametrize("comp", ALL, ids=lambda c: c.scheme.value)
@pytest.mark.parametrize("ops", FUSED_SETS, ids="+".join)
def test_fused_bit_exact_vs_single_op(comp, ops, field_2d):
    c = _c(comp, field_2d)
    e = comp.encode(c)
    for field in (c, e):
        for stage in _shared_stages(comp.scheme, ops):
            out = H.compute(field, ops, stage, axis=1)
            assert set(out) == set(ops)
            for op in ops:
                _assert_same(out[op], _single(op, field, stage, axis=1))


@pytest.mark.parametrize("comp", ALL, ids=lambda c: c.scheme.value)
@pytest.mark.parametrize("ops", FUSED_SETS, ids="+".join)
def test_fused_region_bit_exact_vs_single_op(comp, ops, field_2d):
    c = _c(comp, field_2d)
    e = comp.encode(c)
    for field in (c, e):
        for stage in _shared_stages(comp.scheme, ops):
            if stage == Stage.M:
                continue  # unaligned window: stage 1 infeasible by design
            out = H.compute(field, ops, stage, axis=1, region=REGION)
            for op in ops:
                _assert_same(out[op],
                             _single(op, field, stage, axis=1, region=REGION))


@pytest.mark.parametrize("comp", [hszp_nd, hszx_nd], ids=lambda c: c.scheme.value)
def test_fused_multivariate_bit_exact(comp, vector_field_2d):
    u, v = vector_field_2d
    cu, cv = _c(comp, u), _c(comp, v)
    region = ((20, 60), (40, 90))
    for stage in _shared_stages(comp.scheme, ("divergence", "curl")):
        for r in (None, region):
            out = H.compute([cu, cv], ["curl", "divergence"], stage, region=r)
            _assert_same(out["divergence"], H.divergence([cu, cv], stage, region=r))
            _assert_same(out["curl"], H.curl([cu, cv], stage, region=r))


def test_mixed_arity_op_set_rejected(field_2d):
    c = _c(hszp_nd, field_2d)
    with pytest.raises(ValueError):
        H.compute(c, ["mean", "curl"], Stage.Q)
    with pytest.raises(ValueError):
        oplib.canonical_ops([])
    with pytest.raises(ValueError):
        oplib.canonical_ops(["bogus"])


def test_fused_infeasible_stage_raises(field_2d):
    c = _c(hszx_nd, field_2d)
    with pytest.raises(UnsupportedStageError):
        H.compute(c, ["mean", "std"], Stage.M)  # std has no stage-1 form


def test_vector_op_validates_every_component(field_2d):
    """A 1-D-scheme component makes a stage-② stencil infeasible even when
    the first component is an nd scheme (per-component guard)."""
    u_nd, v_1d = _c(hszp_nd, field_2d), _c(hszp, field_2d)
    with pytest.raises(UnsupportedStageError):
        H.divergence([u_nd, v_1d], Stage.P)
    with pytest.raises(UnsupportedStageError):
        H.compute([u_nd, v_1d], ["curl"], Stage.P)


# -- joint stage planning -----------------------------------------------------

def test_plan_stages_shared_stage_over_intersection():
    # hszx mean alone runs at ① but std forces the set to the ② intersection
    plan = analytics.plan_stages(hszx_nd.scheme, ["mean", "std"])
    assert plan.fused == Stage.P
    assert dict(plan.stages) == {"mean": Stage.P, "std": Stage.P}
    assert plan.n_dispatches == 1
    # 1-D Lorenzo stencils only exist from ③ on: the set fuses there
    plan = analytics.plan_stages(hszp.scheme, ["mean", "laplacian"])
    assert plan.fused == Stage.Q


@pytest.mark.parametrize("comp", ALL, ids=lambda c: c.scheme.value)
@pytest.mark.parametrize("op", analytics.OPS)
def test_plan_stages_singleton_matches_plan_stage(comp, op):
    plan = analytics.plan_stages(comp.scheme, [op])
    assert plan.fused == analytics.plan_stage(comp.scheme, op)
    assert plan.stage_of(op) == plan.fused


def test_plan_stages_cost_model_can_unfuse():
    """When measured per-op optima beat every shared stage, fall back."""
    cm = analytics.CostModel()
    scheme = hszx_nd.scheme
    for s in (Stage.M, Stage.P, Stage.Q, Stage.F):
        cm.record(scheme, "mean", s, 1.0 if s == Stage.M else 500.0)
    for s in (Stage.P, Stage.Q, Stage.F):
        cm.record(scheme, "std", s, 1.0 if s == Stage.P else 500.0)
    plan = analytics.plan_stages(scheme, ["mean", "std"], cost_model=cm)
    assert plan.fused is None
    assert dict(plan.stages) == {"mean": Stage.M, "std": Stage.P}
    assert plan.n_dispatches == 2
    # a flat cost surface keeps the set fused (ties prefer one decode)
    flat = analytics.CostModel()
    for op in ("mean", "std"):
        for s in analytics.feasible_stages(scheme, op):
            flat.record(scheme, op, s, 10.0)
    assert analytics.plan_stages(scheme, ["mean", "std"], cost_model=flat).fused is not None


def test_plan_stages_explicit_stage_validates_every_op():
    plan = analytics.plan_stages(hszx_nd.scheme, ["mean", "std"], Stage.P)
    assert plan.fused == Stage.P
    with pytest.raises(UnsupportedStageError):
        analytics.plan_stages(hszx_nd.scheme, ["mean", "std"], Stage.M)


# -- engine: order-insensitive op-set cache, one compiled call ----------------

def test_op_set_cache_key_order_insensitive():
    eng = analytics.BatchedAnalytics()
    cs = _compress_many(hszp_nd, 3)
    r1 = eng.run(cs, ["std", "mean"], Stage.P)
    assert eng.cache_size == 1
    r2 = eng.run(cs, ["mean", "std"], Stage.P)
    assert eng.cache_size == 1          # same canonical op set -> cache hit
    for op in ("mean", "std"):
        np.testing.assert_array_equal(np.asarray(r1[op]), np.asarray(r2[op]))
    # a singleton set and the plain single-op call share one entry too
    eng.run(cs, "mean", Stage.P)
    assert eng.cache_size == 2
    eng.run(cs, ["mean"], Stage.P)
    assert eng.cache_size == 2


def test_engine_accepts_resolved_stage_without_replanning():
    """A resolved Stage is executed as-is (planning happens in query)."""
    eng = analytics.BatchedAnalytics()
    cs = _compress_many(hszx_nd, 2)
    out = eng.run(cs, "mean", Stage.Q)   # auto would have picked M
    ref = H.mean(cs[0], Stage.Q)
    np.testing.assert_array_equal(np.asarray(out[0]), np.asarray(ref))


def test_engine_infeasible_trace_not_cached():
    eng = analytics.BatchedAnalytics()
    cs = _compress_many(hszp, 2, shape=(300,))
    with pytest.raises(UnsupportedStageError):
        eng.run(cs, "derivative", Stage.P)  # 1-D scheme: no stage-2 stencil
    assert eng.cache_size == 0


def test_fused_query_one_dispatch_per_layout_group():
    eng = analytics.BatchedAnalytics()
    a = _compress_many(hszp_nd, 3, seed=1)
    b = _compress_many(hszp_nd, 2, shape=(32, 32), seed=2)
    res = analytics.query(a + b, ["mean", "std", "laplacian"], engine=eng)
    assert res.n_batches == 2
    assert res.n_dispatches == 2         # one compiled call per layout group
    assert eng.cache_size == 2
    stage = res.stages[0]["mean"]
    refs = {op: jax.jit(lambda c, o=op: _single(o, c, stage))
            for op in ("mean", "std", "laplacian")}
    for got, c in zip(res.values, a + b):
        for op, ref in refs.items():
            _assert_same(got[op], ref(c))


def test_fused_query_region(field_2d):
    cs = [_c(hszx_nd, field_2d), _c(hszx_nd, field_2d * 0.5)]
    res = analytics.query(cs, ["mean", "std"], region=REGION)
    assert res.n_dispatches == 1
    stage = res.stages[0]["mean"]
    refs = {op: jax.jit(lambda c, o=op: _single(o, c, stage, region=REGION))
            for op in ("mean", "std")}
    for got, c in zip(res.values, cs):
        for op, ref in refs.items():
            _assert_same(got[op], ref(c))


# -- gradient as a first-class planned op -------------------------------------

def test_gradient_in_planner_matrix():
    assert "gradient" in analytics.OPS
    assert analytics.plan_stage(hszp_nd.scheme, "gradient") == Stage.P
    assert analytics.plan_stage(hszp.scheme, "gradient") == Stage.Q
    assert not analytics.is_feasible(hszp.scheme, "gradient", Stage.P)
    with pytest.raises(UnsupportedStageError):
        analytics.plan_stage(hszp.scheme, "gradient", Stage.P)


def test_gradient_through_engine_and_query():
    eng = analytics.BatchedAnalytics()
    cs = _compress_many(hszp_nd, 3)
    res = analytics.query(cs, "gradient", engine=eng)
    assert eng.cache_size == 1
    for got, c in zip(res.values, cs):
        _assert_same(got, H.gradient(c, res.stages[0]))
    # gradient shares the jit cache like any planned op
    analytics.query(_compress_many(hszp_nd, 3, seed=5), "gradient", engine=eng)
    assert eng.cache_size == 1


def test_gradient_shares_prelude_with_stats(field_2d):
    c = _c(hszp_nd, field_2d)
    out = H.compute(c, ["mean", "gradient"], Stage.P)
    _assert_same(out["gradient"], H.gradient(c, Stage.P))
    _assert_same(out["mean"], H.mean(c, Stage.P))


# -- serving: multi-op requests -----------------------------------------------

def test_serve_multi_op_request(field_2d):
    c = _c(hszx_nd, field_2d)
    fe = AnalyticsFrontend()
    fe.add_request(AnalyticsRequest(uid=0, fields=c, op=["mean", "std"]))
    fe.add_request(AnalyticsRequest(uid=1, fields=c, op=["std", "mean"]))
    fe.add_request(AnalyticsRequest(uid=2, fields=c, op="gradient"))
    done = {r.uid: r for r in fe.run_until_drained()}
    assert all(r.error is None for r in done.values())
    # order-insensitive op sets batch and compile together
    assert fe.engine.cache_size == 2
    stage = done[0].result_stage["mean"]
    refs = {op: jax.jit(lambda f, o=op: _single(o, f, stage))
            for op in ("mean", "std")}
    for uid in (0, 1):
        assert set(done[uid].result) == {"mean", "std"}
        assert done[uid].result_stage["mean"] == stage
        for op, ref in refs.items():
            _assert_same(done[uid].result[op], ref(c))
    _assert_same(done[2].result,
                 jax.jit(lambda f: H.gradient(f, done[2].result_stage))(c))


def test_serve_multi_op_isolates_bad_sets(field_2d):
    c = _c(hszx_nd, field_2d)
    fe = AnalyticsFrontend()
    fe.add_request(AnalyticsRequest(uid=0, fields=c, op=["mean", "std"]))
    fe.add_request(AnalyticsRequest(uid=1, fields=c, op=["mean", "bogus"]))
    done = {r.uid: r for r in fe.run_until_drained()}
    assert done[0].error is None and set(done[0].result) == {"mean", "std"}
    assert done[1].error is not None and "bogus" in done[1].error
