"""Sharded field store: placement, mesh helper, bit-identity, semantics.

Placement and planner logic is pure host code and runs in-process (the
main test process stays single-device — XLA's device count is locked at
first jax init).  Everything that needs real shard_map collectives runs in
a subprocess with 8 fake devices, mirroring ``tests/test_comm.py``: the
subprocess executes the full (scheme x op-set x stage x region) matrix
against the single-device reference and prints one JSON verdict dict the
in-process tests assert on.  The matrix runs once per kernel mode
(``REPRO_KERNELS=off`` / ``interpret``) — the Pallas backend must compose
inside the shard-mapped program.
"""
import json
import os
import subprocess
import sys

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.core import Stage, by_name
from repro.core import region as region_mod
from repro.launch.mesh import SHARD_AXIS, make_analytics_mesh
from repro.shard import BlockPlacement, ShardedFieldStore, spatial_bands
from repro.store import FieldStore

SCHEMES = ("hszp", "hszx", "hszp_nd", "hszx_nd")


def _field(scheme, shape=(256, 192), rel_eb=1e-2, seed=0):
    rng = np.random.default_rng(seed)
    data = jnp.asarray(np.cumsum(rng.normal(size=shape), axis=0), jnp.float32)
    comp = by_name(scheme)
    return comp.encode(comp.compress(data, rel_eb=rel_eb))


# ---------------------------------------------------------------------------
# mesh helper
# ---------------------------------------------------------------------------

def test_make_analytics_mesh_defaults_to_all_devices():
    mesh = make_analytics_mesh()
    assert mesh.axis_names == (SHARD_AXIS,)
    assert mesh.devices.size == len(jax.devices())


def test_make_analytics_mesh_validates_count():
    with pytest.raises(ValueError, match="devices"):
        make_analytics_mesh(len(jax.devices()) + 1)
    with pytest.raises(ValueError):
        make_analytics_mesh(0)


# ---------------------------------------------------------------------------
# placement (pure host logic)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("scheme", SCHEMES)
def test_word_partition_is_exact(scheme):
    """Every payload word has exactly one owner; the per-shard word index
    lists are a disjoint, ascending partition of all words."""
    e = _field(scheme)
    p = BlockPlacement.of(e, 8)
    owners = p.word_owner(e.bits)
    n_words = int(e.payload.size)
    assert owners.shape == (n_words,)
    assert owners.min() >= 0 and owners.max() < 8
    stripes = p.shard_word_index(e.bits)
    seen = np.concatenate(stripes)
    assert len(seen) == n_words
    assert sorted(seen.tolist()) == list(range(n_words))
    for s, idx in enumerate(stripes):
        assert (owners[idx] == s).all()
        if len(idx) > 1:
            assert (np.diff(idx) > 0).all()


def test_striping_cycles_over_shards():
    e = _field("hszx_nd")          # (256, 192), block (16, 16): 16 stripe units
    p = BlockPlacement.of(e, 8)
    assert p.n_units == 16
    # consecutive stripe units cycle round-robin over the shards, so every
    # shard owns the same number of units and they interleave
    for s in range(8):
        assert (p.units_of(s) % 8 == s).all()
        assert len(p.units_of(s)) == 2
    cols = p.grid[1]
    block_ids = np.arange(p.n_units * cols)
    assert (p.owner_of_blocks(block_ids)
            == (block_ids // cols) % 8).all()


@pytest.mark.parametrize("scheme", SCHEMES)
def test_payload_bytes_partition_and_locality(scheme):
    """Per-shard region bytes partition the single-device gather bytes, and
    a quarter-row window keeps the busiest shard under the 0.5x CI gate."""
    from repro.core import oplib

    e = _field(scheme)
    region = ((64, 128), (0, 192))     # 1/4 of the rows, off the origin
    cl = oplib.set_closure(("mean",), e.scheme, Stage.Q, 0)
    plan = region_mod.plan_region(
        e, region_mod.normalize_region(region, e.shape), cl)
    p = BlockPlacement.of(e, 8)
    acct = p.payload_bytes(plan, e.bits)
    assert sum(acct["per_shard_bytes"]) == acct["single_bytes"]
    assert acct["max_shard_bytes"] == max(acct["per_shard_bytes"])
    assert set(acct["participants"]) <= set(range(8))
    assert acct["max_shard_bytes"] < 0.5 * acct["single_bytes"], acct


@pytest.mark.parametrize("scheme", SCHEMES)
def test_max_fraction_full_field(scheme):
    e = _field(scheme)
    p = BlockPlacement.of(e, 8)
    # striped placement: no shard owns much more than 1/8 of the blocks
    assert 1 / 8 <= p.max_fraction(None) <= 1 / 8 + 8 / max(p.n_units, 1)


def test_spatial_bands_cover_window():
    e = _field("hszx_nd", shape=(3, 96, 64))
    p = BlockPlacement.of(e, 8, axis=1)
    for region in (None, ((10, 60), (8, 56))):
        bands = spatial_bands(e, p, region)
        win = (region_mod.normalize_region(region, e.shape[1:])
               if region is not None else tuple((0, s) for s in e.shape[1:]))
        rows = sorted((b[3][0][0], b[3][0][1]) for b in bands)
        assert rows[0][0] == win[0][0] and rows[-1][1] == win[0][1]
        for (a, b), (c, d) in zip(rows, rows[1:]):
            assert b == c          # contiguous, non-overlapping
        assert all(0 <= b[0] < 8 for b in bands)


# ---------------------------------------------------------------------------
# planner max-over-shards rule
# ---------------------------------------------------------------------------

def test_planner_max_shard_fraction_bounds():
    from repro.analytics.planner import _max_shard_fraction

    e = _field("hszx_nd")
    p = BlockPlacement.of(e, 8)
    region = region_mod.normalize_region(((64, 128), (0, 192)), e.shape)
    single = region_mod.closure_fraction(e, "mean", Stage.Q, region, axis=0)
    sharded = _max_shard_fraction(e, "mean", Stage.Q, region, 0, p)
    assert 0 < sharded <= single
    # full field: the busiest shard decodes ~1/8 of the blocks, not all
    assert _max_shard_fraction(e, "mean", Stage.Q, None, 0, p) < 0.2
    # stage (1) touches metadata only -> placement-blind spatial fraction
    m = _max_shard_fraction(e, "mean", Stage.M, region, 0, p)
    assert m == region_mod.closure_fraction(e, "mean", Stage.M, region, axis=0)


def test_plan_stages_accepts_placement():
    from repro.analytics.planner import plan_stages

    e = _field("hszx_nd")
    p = BlockPlacement.of(e, 8)
    plan = plan_stages(e.scheme, ("mean", "std"), "auto", None,
                       region=((64, 128), (0, 192)), field=e, placement=p)
    assert plan.fused is not None or len(plan.stages) == 2


# ---------------------------------------------------------------------------
# sharded store semantics reachable on one device
# ---------------------------------------------------------------------------

def test_sharded_store_requires_encoded():
    comp = by_name("hszx_nd")
    c = comp.compress(jnp.ones((32, 32), jnp.float32), rel_eb=1e-2)
    store = ShardedFieldStore(make_analytics_mesh(1))
    with pytest.raises(TypeError, match="encode"):
        store.put("f", c)


def test_router_membership_and_rejection():
    from repro.serve import StoreRouter

    sh = ShardedFieldStore(make_analytics_mesh(1))
    local = FieldStore()
    e = _field("hszx_nd", shape=(64, 48))
    sh.put("big", e)
    local.put("small", e)
    r = StoreRouter(sh, local)
    assert "big" in r and "small" in r and "nope" not in r
    assert r.get("big") is sh.get("big")
    assert r.get("small") is local.get("small")
    assert set(r.ids()) == {"big", "small"}
    with pytest.raises(KeyError, match="big.*small|small.*big"):
        r.get("nope")
    with pytest.raises(ValueError, match="already registered"):
        r.put("big", e)          # id lives in the sharded store
    assert r.placement_of("big") is not None
    assert r.placement_of("small") is None
    with pytest.raises(TypeError, match="streaming"):
        r.append("small", jnp.ones((1, 64, 48)))


def test_router_without_local_store():
    from repro.serve import StoreRouter

    sh = ShardedFieldStore(make_analytics_mesh(1))
    sh.put("only", _field("hszp", shape=(64, 48)))
    r = StoreRouter(sh)
    assert "only" in r and r.get("only") is sh.get("only")
    with pytest.raises(ValueError, match="no local store"):
        r.put("x", _field("hszp", shape=(64, 48)))


# ---------------------------------------------------------------------------
# 8-device matrix (subprocess: collectives need a multi-device mesh)
# ---------------------------------------------------------------------------

SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import json
from functools import reduce

import numpy as np
import jax, jax.numpy as jnp

from repro.analytics.engine import BatchedAnalytics
from repro.analytics.query import query
from repro.core import Stage, by_name, oplib
from repro.launch.mesh import make_analytics_mesh
from repro.serve import AnalyticsFrontend, AnalyticsRequest, AppendRequest, \
    StoreRouter
from repro.shard import BlockPlacement, ShardPrograms, ShardedFieldStore
from repro.store import FieldStore, materialize, materialized_nbytes
from repro.stream import StreamFieldStore, TemporalField, query_temporal

out = {"failures": []}

def check(name, ok):
    out[name] = bool(ok)
    if not ok:
        out["failures"].append(name)

def eq_tree(a, b):
    fa, fb = jax.tree.leaves(a), jax.tree.leaves(b)
    return len(fa) == len(fb) and all(
        np.asarray(x).dtype == np.asarray(y).dtype
        and np.array_equal(np.asarray(x), np.asarray(y), equal_nan=True)
        for x, y in zip(fa, fb))

rng = np.random.default_rng(0)
data = jnp.asarray(np.cumsum(rng.normal(size=(128, 96)), axis=0), jnp.float32)
mesh = make_analytics_mesh(8)
progs = ShardPrograms(mesh)
REGION = ((16, 80), (8, 72))

# --- (scheme x op-set x stage x region) bit-identity, ops inside shard_map --
for scheme in ("hszp", "hszx", "hszp_nd", "hszx_nd"):
    comp = by_name(scheme)
    e = comp.encode(comp.compress(data, rel_eb=1e-2))
    cells = [(("mean", "std"), Stage.Q), (("mean",), Stage.P),
             (("mean",), Stage.F)]
    if comp.scheme.is_blockmean:
        cells.append((("mean",), Stage.M))
    for ops, stage in cells:
        for region in (None, REGION):
            tag = f"exec/{scheme}/{'+'.join(ops)}/{stage.name}/" \
                  f"{'region' if region else 'full'}"
            try:
                ref = jax.jit(lambda enc, _o=ops, _s=stage, _r=region:
                              oplib.compute(enc, _o, _s, region=_r))(e)
            except Exception as ex:
                try:
                    progs.region_compute(e, ops, stage, region=region)
                    check(tag + "/raises", False)
                except Exception:
                    check(tag + "/raises", True)
                continue
            got = progs.region_compute(e, ops, stage, region=region)
            check(tag, eq_tree(ref, got))

# --- shard-map materialize == single-device materialize ---------------------
for scheme in ("hszp", "hszx_nd"):
    comp = by_name(scheme)
    e = comp.encode(comp.compress(data, rel_eb=1e-2))
    for stage in (Stage.P, Stage.Q):
        for region in (None, REGION):
            ref = materialize(e, stage, region=region)
            got = progs.materialize(e, stage, region=region)
            leaf = ref.sub if stage == Stage.P else ref.q_spatial
            check(f"mat/{scheme}/{stage.name}/"
                  f"{'region' if region else 'full'}", eq_tree(leaf, got))

# --- store-vs-store query bit-identity (seeded engine programs) -------------
for scheme in ("hszp", "hszx", "hszp_nd", "hszx_nd"):
    comp = by_name(scheme)
    e = comp.encode(comp.compress(data, rel_eb=1e-2))
    ref_store, sh_store = StreamFieldStore(), ShardedFieldStore(mesh)
    ref_store.put("f", e); sh_store.put("f", e)
    for region in (None, REGION):
        for ops, stage in ((["mean", "std"], Stage.Q), ("mean", "auto"),
                           ("laplacian", Stage.F)):
            r1 = query(["f"], ops, stage, region=region, store=ref_store)
            r2 = query(["f"], ops, stage, region=region, store=sh_store)
            r3 = query(["f"], ops, stage, region=region, store=sh_store)
            tag = f"store/{scheme}/{ops if isinstance(ops, str) else '+'.join(ops)}/" \
                  f"{'region' if region else 'full'}"
            check(tag, eq_tree(r1.values[0], r2.values[0])
                  and eq_tree(r2.values[0], r3.values[0]))
    st = sh_store.stats
    check(f"store/{scheme}/hits", st.hits > 0)

# --- per-shard byte budgets: eviction on one shard leaves siblings ----------
comp = by_name("hszx_nd")
e = comp.encode(comp.compress(
    jnp.asarray(np.cumsum(rng.normal(size=(256, 96)), axis=0), jnp.float32),
    rel_eb=1e-2))
rA = ((0, 16), (0, 96))      # block-row 0 -> home shard 0
rB = ((16, 32), (0, 96))     # block-row 1 -> home shard 1
rC = ((128, 144), (0, 96))   # another row homed on shard 0 (unit 8)
budget = materialized_nbytes(e, Stage.Q, region=rA) + 64
sv = ShardedFieldStore(mesh, cache_bytes_per_shard=budget)
sv.put("f", e)
hA = sv.shard_of("f", Stage.Q, region=rA)
hB = sv.shard_of("f", Stage.Q, region=rB)
hC = sv.shard_of("f", Stage.Q, region=rC)
check("evict/homes-differ", hA != hB and hA == hC)
sv.ensure("f", Stage.Q, region=rA)
sv.ensure("f", Stage.Q, region=rB)
check("evict/both-resident", sv.is_resident("f", Stage.Q, region=rA)
      and sv.is_resident("f", Stage.Q, region=rB))
sv.ensure("f", Stage.Q, region=rC)   # overflows shard hA's budget only
check("evict/lru-evicted-on-home", not sv.is_resident("f", Stage.Q, region=rA))
check("evict/sibling-survives", sv.is_resident("f", Stage.Q, region=rB)
      and sv.is_resident("f", Stage.Q, region=rC))
check("evict/counted", sv.stats.evictions == 1
      and sv.shard_stats[hA].evictions == 1
      and sv.shard_stats[hB].evictions == 0)
got = query(["f"], "mean", Stage.Q, region=rA, store=sv).values[0]
ref = query(["f"], "mean", Stage.Q, region=rA,
            store=(lambda s: (s.put("f", e), s)[1])(StreamFieldStore())
            ).values[0]
check("evict/recompute-bitident", eq_tree(ref, got))

# --- temporal: banded summaries, owning-shard-only append refresh -----------
slabs = [np.cumsum(rng.normal(size=(4, 70, 64)), axis=1).astype(np.float32)
         for _ in range(3)]
for scheme in ("hszp", "hszx_nd"):
    comp = by_name(scheme)
    ref_store, sh_store = StreamFieldStore(), ShardedFieldStore(mesh)
    ref_store.put_temporal("t", TemporalField(comp, rel_eb=1e-2))
    sh_store.put_temporal("t", TemporalField(comp, rel_eb=1e-2))
    for s in slabs[:2]:
        ref_store.append("t", jnp.asarray(s))
        sh_store.append("t", jnp.asarray(s))
    regions = (None, ((8, 52), (10, 60)))
    for region in regions:
        a = query_temporal(["t"], ["tmean", "tstd"], region=region,
                           store=ref_store).values[0]
        b = query_temporal(["t"], ["tmean", "tstd"], region=region,
                           store=sh_store).values[0]
        check(f"temporal/{scheme}/{'region' if region else 'full'}",
              eq_tree(a, b))
    # both summary cells now resident; each lives on exactly one shard
    keys = [k for ch in sh_store._shards for k in ch._cache if k[0] == "t"]
    check(f"temporal/{scheme}/one-owner-per-cell", len(keys) == 2
          and len(set(keys)) == 2)
    owners = {k: [i for i, ch in enumerate(sh_store._shards)
                  if k in ch._cache] for k in keys}
    check(f"temporal/{scheme}/single-shard-cells",
          all(len(v) == 1 for v in owners.values()))
    before = {i: dict(ch._cache) for i, ch in enumerate(sh_store._shards)}
    merges0 = sh_store.incremental_merges
    ref_store.append("t", jnp.asarray(slabs[2]))
    sh_store.append("t", jnp.asarray(slabs[2]))
    check(f"temporal/{scheme}/incremental", sh_store.incremental_merges
          == merges0 + 2)
    # the refresh replaced cells in place on their owning shards only
    for i, ch in enumerate(sh_store._shards):
        owned = [k for k in before[i] if k[0] == "t"]
        foreign_ok = all(k in ch._cache for k in before[i])
        check(f"temporal/{scheme}/shard{i}-keys-stable",
              foreign_ok and set(k for k in ch._cache if k[0] == "t")
              == set(owned))
    for region in regions:
        a = query_temporal(["t"], ["tmean", "tstd", "tdelta"], region=region,
                           store=ref_store).values[0]
        b = query_temporal(["t"], ["tmean", "tstd", "tdelta"], region=region,
                           store=sh_store).values[0]
        check(f"temporal/{scheme}/post-append/"
              f"{'region' if region else 'full'}", eq_tree(a, b))

# --- serve routing: unknown ids reject per-request ---------------------------
sh_store = ShardedFieldStore(mesh)
local = StreamFieldStore()
e = by_name("hszx_nd").encode(by_name("hszx_nd").compress(data, rel_eb=1e-2))
sh_store.put("big", e)
local.put("small", e)
local.put_temporal("t", TemporalField("hszx_nd", rel_eb=1e-2))
fe = AnalyticsFrontend(store=StoreRouter(sh_store, local))
fe.add_request(AnalyticsRequest(uid=1, fields="big", op="mean",
                                region=REGION))
fe.add_request(AnalyticsRequest(uid=2, fields="small", op="mean"))
fe.add_request(AnalyticsRequest(uid=3, fields="nope", op="mean"))
fe.add_request(AppendRequest(uid=4, field_id="t", data=jnp.asarray(slabs[0])))
fe.add_request(AnalyticsRequest(uid=5, fields="t", op="tmean"))
done = {r.uid: r for r in fe.run_until_drained()}
check("serve/sharded-ok", done[1].error is None)
check("serve/local-ok", done[2].error is None)
check("serve/unknown-rejected", done[3].error is not None
      and "unknown field id" in done[3].error)
check("serve/append-ok", done[4].error is None and done[4].slab_index == 0)
check("serve/temporal-ok", done[5].error is None)
ref = query(["big"], "mean", region=REGION, store=sh_store).values[0]
check("serve/value-bitident", eq_tree(ref, done[1].result))

print(json.dumps(out))
"""


@pytest.fixture(scope="module", params=["off", "interpret"])
def shard_results(request):
    env = dict(os.environ, PYTHONPATH="src", REPRO_KERNELS=request.param)
    env.pop("XLA_FLAGS", None)
    r = subprocess.run(
        [sys.executable, "-c", SCRIPT], capture_output=True, text=True,
        env=env, cwd=os.path.dirname(os.path.dirname(__file__)))
    assert r.returncode == 0, r.stderr[-4000:]
    return json.loads(r.stdout.strip().splitlines()[-1])


def _failing(results, prefix):
    return [k for k in results["failures"] if k.startswith(prefix)]


def test_exec_bit_identity_matrix(shard_results):
    """shard_map region/full op sets == the jitted single-device compute,
    bitwise, for every (scheme, op-set, stage, +-region) cell."""
    assert not _failing(shard_results, "exec/"), shard_results["failures"]


def test_materialize_bit_identity(shard_results):
    assert not _failing(shard_results, "mat/"), shard_results["failures"]


def test_store_query_bit_identity(shard_results):
    assert not _failing(shard_results, "store/"), shard_results["failures"]


def test_eviction_is_per_shard(shard_results):
    """Evicting on one shard leaves the sibling materialization on another
    shard resident, and the evicted cell recomputes bit-identically."""
    assert not _failing(shard_results, "evict/"), shard_results["failures"]


def test_temporal_append_refreshes_owning_shard_only(shard_results):
    assert not _failing(shard_results, "temporal/"), shard_results["failures"]


def test_serve_routing_rejects_per_request(shard_results):
    assert not _failing(shard_results, "serve/"), shard_results["failures"]
