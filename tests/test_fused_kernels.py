"""Fused Pallas decode+op kernel lowering: bit-identity pins + fallback proofs.

The contract (ISSUE 8 acceptance):

* every covered (scheme-family, op, stage) cell is *bitwise* identical to
  the XLA lowering — ``np.testing.assert_array_equal``, never allclose —
  for Compressed and Encoded containers, full-field and region-windowed;
* the identity holds in every program shape that composes fused outputs:
  the engine's vmap-batched multivariate path and expression DAGs must
  match per-field / composed single-op results bit for bit (the regression
  trap: a trailing in-kernel eps multiply FMA-contracts into downstream
  adds shape-dependently — see repro.core.fused);
* uncovered cells provably fall back to the XLA rules: the lorenzo ③④
  laplacian has no registry entry, non-2-D contexts fail ``covers``, and
  ``REPRO_KERNELS=off`` deselects every fused rule — all three resolve to
  plain XLA rules through the same ``select_rule`` dispatch.
"""
import numpy as np
import pytest
import jax.numpy as jnp

from repro import analytics
from repro.core import Stage, expr, homomorphic as H, oplib
from repro.core import fused as fused_mod
from repro.core.encode import decode_device
from repro.core.pipeline import by_name
from repro.kernels import fused as fk
from repro.kernels import ops as kops

ND_SCHEMES = ["hszp_nd", "hszx_nd"]
STAGES = [Stage.P, Stage.Q, Stage.F]
REGION = ((30, 75), (10, 52))  # unaligned window of the 181x97 field

OPCALLS = {
    "deriv0": lambda f, s, r: H.derivative(f, s, 0, region=r),
    "deriv1": lambda f, s, r: H.derivative(f, s, 1, region=r),
    "gradient": lambda f, s, r: H.gradient(f, s, region=r),
    "laplacian": lambda f, s, r: H.laplacian(f, s, region=r),
}


@pytest.fixture(scope="module", params=ND_SCHEMES)
def pair_2d(request, field_2d):
    """(Compressed, Encoded) of the session 2-D field, one nd scheme."""
    comp = by_name(request.param, (8, 8))
    c = comp.compress(jnp.asarray(field_2d), abs_eb=1e-3)
    return c, comp.encode(c)


def _ab(call):
    """Run ``call`` with the fused backend (default) and with kernels off."""
    got = call()
    with kops.override_mode("off"):
        want = call()
    got = got if isinstance(got, tuple) else (got,)
    want = want if isinstance(want, tuple) else (want,)
    return got, want


# ===========================================================================
# per-cell bit-identity
# ===========================================================================

@pytest.mark.parametrize("container", ["compressed", "encoded"])
@pytest.mark.parametrize("region", [None, REGION], ids=["full", "window"])
@pytest.mark.parametrize("stage", STAGES, ids=lambda s: s.name)
@pytest.mark.parametrize("op", list(OPCALLS))
def test_cell_bit_identity(pair_2d, container, region, stage, op):
    fld = pair_2d[0] if container == "compressed" else pair_2d[1]
    got, want = _ab(lambda: OPCALLS[op](fld, stage, region))
    for g, w in zip(got, want):
        np.testing.assert_array_equal(np.asarray(g), np.asarray(w))


def test_decode_device_bit_identity(pair_2d):
    """The Encoded→Compressed device decode routes payload unpacking through
    the Pallas bitpack kernel; the residual planes must match the XLA
    unpacker bit for bit."""
    _, e = pair_2d
    got, want = _ab(lambda: decode_device(e).residuals)
    np.testing.assert_array_equal(np.asarray(got[0]), np.asarray(want[0]))


def test_payload_kernels_match_plane_kernels(pair_2d):
    """The single-pass payload kernels (in-kernel bitplane unpack) must be
    bit-identical to decode_device + the residual-plane kernels for every
    ``what`` — the unpack arithmetic is the same word/shift/mask math as
    ``encode.unpack_uniform``, so the recovered integers, and hence the
    stencil planes, are the same bits."""
    _, e = pair_2d
    d = decode_device(e)
    shape = tuple(d.residuals.shape)
    if oplib.family_of(e.scheme) == "lorenzo":
        for what in ("deriv0", "deriv1", "lap", "grad"):
            a = fk.lorenzo2d(d.residuals, what=what, interpret=True)
            b = fk.lorenzo_enc2d(e.payload, shape, e.bits, what=what,
                                 interpret=True)
            a = a if isinstance(a, (tuple, list)) else (a,)
            b = b if isinstance(b, (tuple, list)) else (b,)
            for x, y in zip(a, b):
                np.testing.assert_array_equal(np.asarray(x), np.asarray(y))
    else:
        blk = tuple(d.block)
        for what in ("deriv0", "deriv1", "lap_p", "lap_q", "grad"):
            a = fk.blockmean2d(d.residuals, d.metadata, blk, what=what,
                               interpret=True)
            b = fk.blockmean_enc2d(e.payload, e.metadata, shape, blk,
                                   e.bits, what=what, interpret=True)
            a = a if isinstance(a, (tuple, list)) else (a,)
            b = b if isinstance(b, (tuple, list)) else (b,)
            for x, y in zip(a, b):
                np.testing.assert_array_equal(np.asarray(x), np.asarray(y))


def test_payload_path_predicate(pair_2d):
    """Payload kernels serve exactly the full-field Encoded contexts; the
    Compressed container and region plans keep the residual-plane / XLA
    gather paths."""
    c, e = pair_2d
    assert fused_mod._payload2(_ctx(e, Stage.Q))
    assert not fused_mod._payload2(_ctx(c, Stage.Q))
    closure = oplib.set_closure(["derivative"], e.scheme, Stage.Q, 0)
    region_ctx = oplib.StageContext(e, Stage.Q, REGION, closure)
    assert not fused_mod._payload2(region_ctx)


# ===========================================================================
# composition shapes: engine vmap batching + expression DAGs
# ===========================================================================

def test_engine_batched_bit_identity(field_2d):
    """The batched engine path (one vmapped program over same-layout fields)
    must produce the same bits as with kernels off — and as the per-field
    jit programs, which test_analytics pins; the kernel mode is part of the
    engine's jit-cache key, so on/off compile separately."""
    rng = np.random.default_rng(5)
    for scheme in ND_SCHEMES:
        comp = by_name(scheme, (8, 8))
        fields = [comp.compress(
            jnp.asarray(field_2d + rng.normal(0, 0.01, field_2d.shape)
                        .astype(np.float32)), abs_eb=1e-3) for _ in range(3)]
        for stage in STAGES:
            got, want = _ab(lambda: tuple(
                jnp.asarray(r) for r in
                analytics.query(exprs=[expr.derivative(f, axis=0)
                                       for f in fields], stage=stage)))
            for g, w in zip(got, want):
                np.testing.assert_array_equal(np.asarray(g), np.asarray(w))


def test_expr_composition_bit_identity(field_2d):
    """Adding two fused derivative outputs inside one program (the
    divergence / vorticity shape) is the exact scenario where an in-kernel
    float tail FMA-contracts shape-dependently; pin in-program composition
    against out-of-program composition, fused on and off."""
    rng = np.random.default_rng(9)
    for scheme in ND_SCHEMES:
        comp = by_name(scheme, (8, 8))
        cu = comp.compress(jnp.asarray(field_2d), abs_eb=1e-3)
        cv = comp.compress(
            jnp.asarray(field_2d[::-1].copy()), abs_eb=1e-3)
        vort = expr.sub(expr.derivative(cv, axis=0),
                        expr.derivative(cu, axis=1))
        for stage in STAGES:
            got = np.asarray(oplib.compute_exprs(vort, stage))
            composed = (
                np.asarray(oplib.compute(cv, "derivative", stage, axis=0)
                           ["derivative"])
                - np.asarray(oplib.compute(cu, "derivative", stage, axis=1)
                             ["derivative"]))
            np.testing.assert_array_equal(got, composed)
            with kops.override_mode("off"):
                off = np.asarray(oplib.compute_exprs(vort, stage))
            np.testing.assert_array_equal(got, off)


# ===========================================================================
# fallback proofs
# ===========================================================================

def _ctx(c, stage):
    closure = oplib.set_closure(["derivative"], c.scheme, stage, 0)
    return oplib.StageContext(c, stage, None, closure)


def test_uncovered_cells_have_no_registry_entry():
    """lorenzo ③④ laplacian is deliberately uncovered (its XLA rule never
    forms q); statistics carry no fused cells at all."""
    assert (Stage.Q, "lorenzo") not in fused_mod.LAPLACIAN
    assert (Stage.F, "lorenzo") not in fused_mod.LAPLACIAN
    for name in ("mean", "std"):
        assert not oplib.OPS[name].fused
    # every fused cell has an XLA fallback (spec_violations enforces this)
    for name in ("derivative", "gradient", "laplacian"):
        assert oplib.spec_violations(oplib.OPS[name]) == []


def test_lap_lorenzo_q_selects_xla_rule(field_2d):
    comp = by_name("hszp_nd", (8, 8))
    c = comp.compress(jnp.asarray(field_2d), abs_eb=1e-3)
    for stage in (Stage.Q, Stage.F):
        rule = oplib.select_rule(oplib.OPS["laplacian"], stage, "lorenzo",
                                 _ctx(c, stage))
        assert not isinstance(rule, fused_mod.FusedRule)
    rule = oplib.select_rule(oplib.OPS["laplacian"], Stage.P, "lorenzo",
                             _ctx(c, Stage.P))
    assert isinstance(rule, fused_mod.FusedRule)


def test_1d_scheme_fails_covers_and_falls_back(field_2d):
    """The 1-D partition schemes have no spatial stencils to fuse: the
    coverage predicate rejects them and dispatch lands on the XLA rule."""
    comp = by_name("hszp", (256,))
    c = comp.compress(jnp.asarray(field_2d), abs_eb=1e-3)
    ctx = _ctx(c, Stage.Q)
    assert not fused_mod._covers_2d(ctx)
    rule = oplib.select_rule(oplib.OPS["derivative"], Stage.Q, "lorenzo", ctx)
    assert not isinstance(rule, fused_mod.FusedRule)


def test_off_mode_deselects_fused_rules(field_2d):
    comp = by_name("hszp_nd", (8, 8))
    c = comp.compress(jnp.asarray(field_2d), abs_eb=1e-3)
    ctx = _ctx(c, Stage.Q)
    on = oplib.select_rule(oplib.OPS["derivative"], Stage.Q, "lorenzo", ctx)
    assert isinstance(on, fused_mod.FusedRule)
    with kops.override_mode("off"):
        off = oplib.select_rule(oplib.OPS["derivative"], Stage.Q, "lorenzo",
                                ctx)
    assert not isinstance(off, fused_mod.FusedRule)
    assert oplib.kernel_sig() in ("auto", "interpret", "native")
    with kops.override_mode("off"):
        assert oplib.kernel_sig() == "off"
