"""Streaming time-slab ingestion + incremental temporal analytics (ISSUE 5).

The contract under test:

* for every scheme, temporal ops (``tdelta``, running ``tmean``/``tmin``/
  ``tmax``/``tstd``) over appended slabs are **bit-identical** to the same
  reduction over the full decompression of the concatenated field — ± a
  spatial region, at every feasible stage (② and ③ for nd schemes, ③ for
  1-D ones), served incrementally through a :class:`StreamFieldStore`;
* appends refresh resident summaries in place and never invalidate
  unrelated materializations;
* querying a stream in steady state compiles nothing new — appends never
  retrace (slab-count-stable jit cache keys);
* feasibility and malformed-input errors mirror the spatial ops' semantics.
"""
import numpy as np
import pytest
import jax
import jax.numpy as jnp

from repro import analytics
from repro.analytics import BatchedAnalytics, CostModel, query
from repro.core import (Scheme, Stage, UnsupportedStageError, hszp, hszp_nd,
                        hszx, hszx_nd, oplib)
from repro.serve import AnalyticsFrontend, AnalyticsRequest, AppendRequest
from repro.store import FieldStore
from repro.stream import (StreamFieldStore, TemporalField, merge_summaries,
                          query_temporal, summarize_slab, summary_from_q)

ALL = [hszp, hszx, hszp_nd, hszx_nd]
TOPS = ("tdelta", "tmean", "tmin", "tmax", "tstd")
SPATIAL = (48, 40)
REGION = ((10, 40), (5, 29))     # unaligned spatial window


def _slab(i, k=3, spatial=SPATIAL, seed=0):
    rng = np.random.default_rng(seed + 100 * i)
    t = np.arange(i * k, (i + 1) * k, dtype=np.float32)[:, None, None]
    x = (np.linspace(0, 2 * np.pi, spatial[0])[None, :, None]
         + np.linspace(0, np.pi, spatial[1])[None, None, :])
    return (np.sin(x + 0.1 * t) * 2 + 0.05 * t
            + rng.normal(0, 0.02, (k,) + spatial)).astype(np.float32)


def _stream(comp, n_slabs=4, k=3, **kw):
    tf = TemporalField(comp, rel_eb=1e-3, **kw)
    raw = [_slab(i, k=k) for i in range(n_slabs)]
    for d in raw:
        tf.append(d)
    return tf, np.concatenate(raw, axis=0)


def _feasible(scheme):
    return analytics.feasible_stages(scheme, "tmean")


def _same(got, ref):
    np.testing.assert_array_equal(np.asarray(got), np.asarray(ref))


# -- bit-identity: incremental merges == full decompression -------------------

@pytest.mark.parametrize("comp", ALL, ids=lambda c: c.scheme.value)
def test_store_served_bit_identical_to_full_decompression(comp):
    """Incrementally appended + merged summaries answer every temporal op
    bit-identically to one reduction over the concatenated decompression,
    at every feasible stage, full-field and windowed."""
    eng = BatchedAnalytics()
    store = StreamFieldStore(engine=eng)
    tf = TemporalField(comp, rel_eb=1e-3)
    store.put_temporal("sim/T", tf)
    for i in range(4):
        store.append("sim/T", _slab(i))
    for stage in _feasible(comp.scheme):
        for region in (None, REGION):
            ref = tf.reference(TOPS, region=region)
            got = query(["sim/T"], list(TOPS), stage=stage, store=store,
                        engine=eng, region=region)
            for op in TOPS:
                _same(got.values[0][op], ref[op])
                assert got.stages[0][op] == stage


@pytest.mark.parametrize("comp", ALL, ids=lambda c: c.scheme.value)
def test_storeless_and_single_op_match_fused(comp):
    tf, _ = _stream(comp)
    eng = BatchedAnalytics()
    fused = query([tf], list(TOPS), engine=eng)
    for op in TOPS:
        single = query([tf], op, engine=eng)
        _same(single.values[0], fused.values[0][op])
        assert single.stages[0] == fused.stages[0][op]


def test_summaries_identical_across_stages_and_slabs():
    """The per-slab summary is the same integers at every feasible stage,
    and merging slab summaries equals summarizing the concatenation."""
    comp = hszx_nd
    tf, _ = _stream(comp, n_slabs=3)
    per_stage = []
    for stage in _feasible(comp.scheme):
        parts = [summarize_slab(s, stage) for s in tf.slabs]
        merged = parts[0]
        for p in parts[1:]:
            merged = merge_summaries(merged, p)
        per_stage.append(merged)
    full = summary_from_q(tf.decompress_q())
    for m in per_stage:
        for leaf in ("count", "q_sum", "q_sumsq", "q_min", "q_max", "last2"):
            _same(getattr(m, leaf), getattr(full, leaf))


def test_temporal_accuracy_vs_raw_data():
    """Sanity against the uncompressed stream: every op lands within the
    error bound's reach of the raw-statistic (not just self-consistent)."""
    tf, raw = _stream(hszp_nd, n_slabs=5)
    eps = float(tf.eps)
    res = query([tf], list(TOPS))
    v = res.values[0]
    assert np.abs(np.asarray(v["tmean"]) - raw.mean(0)).max() <= 2 * eps
    assert np.abs(np.asarray(v["tmin"]) - raw.min(0)).max() <= 2 * eps
    assert np.abs(np.asarray(v["tmax"]) - raw.max(0)).max() <= 2 * eps
    assert np.abs(np.asarray(v["tdelta"]) - (raw[-1] - raw[-2])).max() <= 3 * eps
    assert np.abs(np.asarray(v["tstd"]) - raw.std(0, ddof=1)).max() <= 5e-3


# -- appends: in-place refresh, no collateral invalidation --------------------

def test_appends_never_invalidate_unrelated_materializations(field_2d):
    eng = BatchedAnalytics()
    store = StreamFieldStore(engine=eng)
    c = hszx_nd.compress(jnp.asarray(field_2d), rel_eb=1e-3)
    store.put("static/field", c)
    store.ensure("static/field", Stage.Q)
    tf = TemporalField(hszx_nd, rel_eb=1e-3)
    store.put_temporal("sim/T", tf)
    store.append("sim/T", _slab(0))
    query(["sim/T"], "tmean", store=store, engine=eng)   # summary resident
    entries0 = store.cache_entries
    ev0 = store.stats.evictions
    for i in range(1, 4):
        store.append("sim/T", _slab(i))
    # same resident set (summary replaced in place), zero evictions, and the
    # unrelated spatial materialization still serves hits
    assert store.cache_entries == entries0
    assert store.stats.evictions == ev0
    assert store.lookup("static/field", Stage.Q) is not None
    assert store.incremental_merges == 3
    # ... and the refreshed summary is still exact
    _same(query(["sim/T"], "tmean", store=store, engine=eng).values[0],
          tf.reference(["tmean"])["tmean"])


def test_append_byte_accounting_stays_exact():
    store = StreamFieldStore()
    tf = TemporalField(hszp_nd, rel_eb=1e-3)
    store.put_temporal("s", tf)
    store.append("s", _slab(0))
    for region in (None, REGION):
        store.temporal_summary("s", region=region)
    for i in range(1, 4):
        store.append("s", _slab(i))
        assert store.cache_bytes_in_use == sum(
            m.nbytes for m in store._cache.values())


def test_append_survives_cross_cell_eviction_under_budget_pressure():
    """Refreshing one resident summary can evict a sibling cell of the same
    stream under a tight budget; the append must skip the evicted cell (the
    next query rebuilds it) instead of crashing, and every survivor must
    stay exact."""
    eng = BatchedAnalytics()
    store = StreamFieldStore(engine=eng)
    tf = TemporalField(hszx_nd, rel_eb=1e-3)
    store.put_temporal("s", tf)
    store.append("s", _slab(0))
    store.temporal_summary("s")                   # full-field cell
    store.temporal_summary("s", region=REGION)    # region cell
    assert store.cache_entries == 2
    # budget holds ~one cell: every further append evicts one sibling
    store.cache_bytes = store.cache_bytes_in_use - 1
    for i in range(1, 4):
        store.append("s", _slab(i))               # must not raise
        assert store.cache_bytes_in_use <= store.cache_bytes
        assert store.cache_bytes_in_use == sum(
            m.nbytes for m in store._cache.values())
    for region in (None, REGION):
        got = query(["s"], "tmean", store=store, engine=eng, region=region)
        _same(got.values[0], tf.reference(["tmean"], region=region)["tmean"])


def test_tstd_single_timestep_is_zero_not_nan():
    """Frame-at-a-time streaming: a one-timestep stream has zero spread,
    not NaN (ddof=1 denominator is clamped until a second frame arrives)."""
    tf = TemporalField(hszx_nd, rel_eb=1e-3)
    tf.append(_slab(0, k=1))
    v = query([tf], ["tstd", "tmean", "tdelta"]).values[0]
    assert np.all(np.asarray(v["tstd"]) == 0.0)
    assert np.all(np.asarray(v["tdelta"]) == 0.0)   # duplicated last2 frame
    assert np.isfinite(np.asarray(v["tmean"])).all()
    tf.append(_slab(1, k=1))
    raw = np.concatenate([_slab(0, k=1), _slab(1, k=1)], axis=0)
    got = np.asarray(query([tf], "tstd").values[0])
    assert np.isfinite(got).all()
    # two-sample std = |a - b| / sqrt(2): each value within eps of raw
    assert np.abs(got - raw.std(0, ddof=1)).max() <= 2 * float(tf.eps)


def test_per_op_calibrated_plan_collapses_to_one_shared_stage():
    """A calibrated model pricing temporal ops cheapest at different stages
    triggers plan_stages' per-op fallback; the temporal path must collapse
    it to one shared feasible stage (one summary serves every op) instead
    of crashing on a fused=None plan."""
    scheme = hszp.scheme                  # 1-D: feasible stages Q, F
    cm = CostModel()
    for op, q_us, f_us in (("tmean", 10.0, 500.0), ("tstd", 500.0, 10.0)):
        cm.record(scheme, op, Stage.Q, q_us)
        cm.record(scheme, op, Stage.F, f_us)
    plan = analytics.plan_stages(scheme, ["tmean", "tstd"], cost_model=cm)
    assert plan.fused is None             # the fallback actually fires
    tf, _ = _stream(hszp, n_slabs=2)
    res = query([tf], ["tmean", "tstd"], cost_model=cm)
    ref = tf.reference(["tmean", "tstd"])
    for op in ("tmean", "tstd"):
        _same(res.values[0][op], ref[op])
    assert res.stages[0]["tmean"] == res.stages[0]["tstd"]


def test_summary_eviction_degrades_to_recompute_not_wrong_answers():
    """A summary the budget rejects is rebuilt from all slabs on the next
    query — bit-identical to the incrementally maintained one."""
    eng = BatchedAnalytics()
    store = StreamFieldStore(cache_bytes=16, engine=eng)  # nothing fits
    tf = TemporalField(hszx_nd, rel_eb=1e-3)
    store.put_temporal("s", tf)
    for i in range(3):
        store.append("s", _slab(i))
    res = query(["s"], ["tmean", "tstd"], store=store, engine=eng)
    assert store.cache_entries == 0 and store.stats.rejected >= 1
    ref = tf.reference(["tmean", "tstd"])
    for op in ("tmean", "tstd"):
        _same(res.values[0][op], ref[op])


# -- retrace-freedom ----------------------------------------------------------

def test_steady_state_appends_and_queries_compile_nothing_new():
    """After one warm append+query cycle, K further appends + queries reuse
    exactly the compiled programs: the summarizer is keyed on slab layout
    (never the stream length), the postlude on the summary signature."""
    eng = BatchedAnalytics()
    store = StreamFieldStore(engine=eng)
    # a pinned payload width keeps every slab on one static layout — the
    # precondition for the guarantee (auto width would split the layout,
    # and only the split slab, once, if the stream's range outgrew it)
    tf = TemporalField(hszp_nd, rel_eb=1e-3, bits=12)
    store.put_temporal("s", tf)
    store.append("s", _slab(0))
    query(["s"], list(TOPS), store=store, engine=eng)   # cold: compile
    store.append("s", _slab(1))                         # warm the append path
    query(["s"], list(TOPS), store=store, engine=eng)
    n0 = eng.cache_size
    for i in range(2, 7):
        store.append("s", _slab(i))
        res = query(["s"], list(TOPS), store=store, engine=eng)
        assert res.store_hits >= 1 and res.store_misses == 0
        assert eng.cache_size == n0   # no per-append retrace, ever
    _same(query(["s"], "tmean", store=store, engine=eng).values[0],
          tf.reference(["tmean"])["tmean"])


def test_query_uses_one_postlude_program_per_op_set():
    eng = BatchedAnalytics()
    tf, _ = _stream(hszx_nd, n_slabs=2)
    query([tf], ["tmean", "tstd"], engine=eng)
    n0 = eng.cache_size
    # order-insensitive op-set key, same program on repeat queries
    query([tf], ["tstd", "tmean"], engine=eng)
    assert eng.cache_size == n0


# -- planner / feasibility ----------------------------------------------------

@pytest.mark.parametrize("comp", ALL, ids=lambda c: c.scheme.value)
@pytest.mark.parametrize("op", TOPS)
@pytest.mark.parametrize("stage", list(Stage))
def test_temporal_feasibility_matrix_matches_ops(comp, op, stage):
    """Every temporal Table-I cell: the planner says feasible <=> the
    summarizer does not raise (drift guard, like the spatial matrix)."""
    e = comp.encode(comp.compress(jnp.asarray(_slab(0)), rel_eb=1e-3))
    feasible = analytics.is_feasible(comp.scheme, op, stage)
    if feasible:
        s = summarize_slab(e, stage)
        assert all(np.isfinite(np.asarray(x)).all() or x.dtype == np.int32
                   for x in jax.tree.leaves(s))
    else:
        with pytest.raises(UnsupportedStageError):
            summarize_slab(e, stage)


def test_explicit_infeasible_stage_rejected_before_any_work():
    tf, _ = _stream(hszp)            # 1-D scheme: no stage ②
    with pytest.raises(UnsupportedStageError):
        query([tf], "tmean", stage=Stage.P)
    with pytest.raises(UnsupportedStageError):
        query([tf], "tmean", stage=Stage.M)


def test_mixed_arity_op_sets_rejected():
    with pytest.raises(ValueError, match="different arities"):
        oplib.canonical_ops(["mean", "tmean"])
    with pytest.raises(ValueError, match="different arities"):
        oplib.canonical_ops(["tdelta", "curl"])


def test_plan_refresh_costing():
    cm = CostModel()
    cm.record_reconstruction(Scheme.HSZP_ND, Stage.Q, 80.0)
    plan = analytics.plan_refresh(Scheme.HSZP_ND, Stage.Q, 5, cm)
    assert plan.mode == "incremental"
    assert plan.incremental_us == 80.0 and plan.recompute_us == 400.0
    # no resident summary -> nothing to merge into
    cold = analytics.plan_refresh(Scheme.HSZP_ND, Stage.Q, 5, cm,
                                  summary_resident=False)
    assert cold.mode == "recompute"
    # uncalibrated: decision from residency alone
    assert analytics.plan_refresh(Scheme.HSZX, Stage.Q, 3).mode == "incremental"
    with pytest.raises(ValueError):
        analytics.plan_refresh(Scheme.HSZX, Stage.Q, 0)


# -- malformed inputs / guards ------------------------------------------------

def test_eps_pinned_across_slabs():
    tf = TemporalField(hszx_nd, rel_eb=1e-3)
    tf.append(_slab(0))
    eps0 = float(tf.eps)
    tf.append(10.0 * _slab(1))       # very different range: eps must not move
    assert float(tf.eps) == eps0
    assert float(tf.slabs[1].eps) == eps0


def test_shape_and_rank_validation():
    tf = TemporalField(hszx_nd, rel_eb=1e-3)
    tf.append(_slab(0))
    with pytest.raises(ValueError, match="spatial shape"):
        tf.append(np.zeros((3, 8, 8), np.float32))
    with pytest.raises(ValueError, match="time slab"):
        TemporalField(hszx_nd, rel_eb=1e-3).append(np.zeros((5,), np.float32))


def test_temporal_ops_reject_spatial_fields_and_vice_versa(field_2d):
    c = hszx_nd.compress(jnp.asarray(field_2d), rel_eb=1e-3)
    with pytest.raises(TypeError, match="TemporalField"):
        query([c], "tmean")
    tf, _ = _stream(hszx_nd, n_slabs=1)
    with pytest.raises(TypeError, match="temporal ops"):
        query([tf], "mean")
    with pytest.raises(ValueError, match="temporal op set"):
        oplib.compute(c, "tmean", Stage.Q)


def test_empty_stream_and_missing_store_rejected():
    tf = TemporalField(hszx_nd, rel_eb=1e-3)
    with pytest.raises(ValueError, match="no appended slabs"):
        query_temporal([tf], "tmean")
    with pytest.raises(ValueError, match="no store"):
        query_temporal(["some/id"], "tmean")
    with pytest.raises(TypeError, match="put_temporal"):
        StreamFieldStore().put("x", tf)


# -- serving end-to-end -------------------------------------------------------

def test_serve_append_then_query_end_to_end():
    eng_store = StreamFieldStore()
    tf = TemporalField(hszp_nd, rel_eb=1e-3)
    eng_store.put_temporal("sim/T", tf)
    fe = AnalyticsFrontend(store=eng_store)
    for i in range(3):
        fe.add_request(AppendRequest(uid=i, field_id="sim/T", data=_slab(i)))
    fe.add_request(AnalyticsRequest(uid=10, fields="sim/T",
                                    op=["tmean", "tdelta"]))
    fe.add_request(AnalyticsRequest(uid=11, fields="sim/T", op="tstd",
                                    region=REGION))
    done = {r.uid: r for r in fe.run_until_drained()}
    assert [done[i].slab_index for i in range(3)] == [0, 1, 2]
    assert all(done[i].error is None for i in done)
    # the same-step query saw every appended slab (ingest precedes analytics)
    ref = tf.reference(["tmean", "tdelta"])
    _same(done[10].result["tmean"], ref["tmean"])
    _same(done[10].result["tdelta"], ref["tdelta"])
    _same(done[11].result, tf.reference(["tstd"], region=REGION)["tstd"])


def test_serve_append_rejections_are_per_request():
    store = StreamFieldStore()
    tf = TemporalField(hszx_nd, rel_eb=1e-3)
    store.put_temporal("s", tf)
    store.put("plain", hszx_nd.compress(jnp.asarray(_slab(0)[0]), rel_eb=1e-3))
    fe = AnalyticsFrontend(store=store)
    fe.add_request(AppendRequest(uid=0, field_id="ghost", data=_slab(0)))
    fe.add_request(AppendRequest(uid=1, field_id="plain", data=_slab(0)))
    fe.add_request(AppendRequest(uid=2, field_id="s", data=_slab(0)))
    done = {r.uid: r for r in fe.run_until_drained()}
    assert "unknown field id" in done[0].error
    assert "not a temporal field" in done[1].error
    assert done[2].error is None and done[2].slab_index == 0
    # a frontend without a streaming store rejects appends cleanly
    fe2 = AnalyticsFrontend(store=FieldStore())
    fe2.add_request(AppendRequest(uid=0, field_id="s", data=_slab(0)))
    (r,) = fe2.run_until_drained()
    assert r.error is not None and "streaming store" in r.error


def test_temporal_field_registry_semantics():
    store = StreamFieldStore()
    tf = TemporalField(hszx_nd, rel_eb=1e-3)
    store.put_temporal("s", tf)
    assert store.is_temporal("s") and "s" in store
    with pytest.raises(ValueError, match="already registered"):
        store.put_temporal("s", tf)
    tf.append(_slab(0))
    store.temporal_summary("s")
    assert store.cache_entries == 1
    tf2 = TemporalField(hszx_nd, rel_eb=1e-3)
    store.put_temporal("s", tf2, replace=True)
    assert store.cache_entries == 0          # stale summary invalidated
    store.remove("s")
    assert "s" not in store
    with pytest.raises(TypeError, match="TemporalField"):
        StreamFieldStore().put_temporal("x", np.zeros(3))
