"""Static invariant audit (ISSUE 7): analyzers, fixtures, runtime guards.

The contract under test:

* each analyzer produces **exactly one** structured finding on its
  known-bad fixture — a missing lowering cell, an overflowing accumulator,
  a hidden host sync, an under-keyed jit cache — and none on a corrected
  twin;
* the self-audit is clean: ``python -m repro.audit`` exits 0 on this repo
  under **all six analyzers** and in every ``REPRO_KERNELS`` mode (the
  acceptance gate CI enforces with the ``AUDIT.json`` artifact);
* the kernel verifier (kernelspec) and shard-partition verifier
  (sharddisjoint) each flag their sabotage fixture with exactly one
  finding: widened halo, overlapping grid writes, in-kernel output
  multiply, double-owned payload word, world-scaled Σq² overflow;
* stale ``waive(...)`` / ``invariant(...)`` declarations surface as
  warnings (exit stays 0), and ``--only`` restricts the analyzer set;
* ``oplib.register_op`` rejects malformed OpSpecs at registration time
  with an error naming the offending (stage, scheme-family) cell, without
  mutating the registries;
* the streaming capacity guard: appends past the audited int32
  ``TemporalSummary`` bound raise :class:`SummaryCapacityError` *before*
  mutating the stream, and the runtime formula agrees with the audit's.
"""
from dataclasses import replace

import numpy as np
import pytest

from repro import audit
from repro.audit import (intwidth, jitkeys, kernelspec, registry, runner,
                         sharddisjoint, tracesafety)
from repro.audit.findings import SCHEMA_VERSION, AuditReport, Finding
from repro.comm.hom_collectives import PSUM_CONTAINER_MAX, worst_case_psum
from repro.core import oplib
from repro.core.oplib import OpSpec
from repro.core.stages import Scheme, Stage
from repro.kernels import ops as kops
from repro.kernels.specs import KERNEL_SPECS, HaloRead, TileSpec
from repro.shard import exec as shard_exec
from repro.shard.placement import BlockPlacement
from repro.stream.temporal import (SummaryCapacityError, TemporalField,
                                   summary_capacity)

INT32_MAX = 2**31 - 1


def _field_spec(name, *, feasible, lower, closure="default"):
    if closure == "default":
        closure = lambda s, st, a: "cover"  # noqa: E731
    return OpSpec(name=name, arity="field", category="statistic",
                  feasible=feasible, closure=closure, lower=lower)


def _only_hszp_at_f(scheme):
    s = Scheme(scheme)
    return (Stage.F,) if (s.is_lorenzo and not s.is_nd) else ()


# ===========================================================================
# analyzer (1): registry completeness
# ===========================================================================

class TestRegistryAnalyzer:
    def test_missing_lowering_cell_one_finding(self):
        bad = _field_spec("badop", feasible=_only_hszp_at_f, lower={})
        fs = registry.analyze_registry({"badop": bad}, {},
                                       check_matrix=False)
        assert len(fs) == 1
        (f,) = fs
        assert f.invariant == "missing-lowering-rule"
        assert "(stage F, lorenzo)" in f.message

    def test_shadowed_any_rule_one_finding(self):
        rule = lambda ctx, axis: None  # noqa: E731
        bad = _field_spec("shadow", feasible=_only_hszp_at_f,
                          lower={(Stage.F, "lorenzo"): rule,
                                 (Stage.F, "any"): rule})
        fs = registry.analyze_registry({"shadow": bad}, {},
                                       check_matrix=False)
        assert [f.invariant for f in fs] == ["ambiguous-lowering-rule"]

    def test_missing_closure_one_finding(self):
        rule = lambda ctx, axis: None  # noqa: E731
        bad = _field_spec("noclose", feasible=_only_hszp_at_f,
                          lower={(Stage.F, "any"): rule}, closure=None)
        fs = registry.analyze_registry({"noclose": bad}, {},
                                       check_matrix=False)
        assert [f.invariant for f in fs] == ["missing-closure"]

    def test_registry_collision_detected(self):
        rule = lambda ctx, axis: None  # noqa: E731
        ok = _field_spec("dup", feasible=_only_hszp_at_f,
                         lower={(Stage.F, "any"): rule})
        tok = OpSpec(name="dup", arity="temporal", category="statistic",
                     feasible=lambda s: (Stage.Q,),
                     lower_temporal=lambda s, e: None)
        fs = registry.analyze_registry({"dup": ok}, {"dup": tok},
                                       check_matrix=False)
        assert [f.invariant for f in fs] == ["registry-collision"]

    def test_live_registries_clean(self):
        assert registry.analyze_registry() == []


# ===========================================================================
# analyzer (2): integer-width abstract interpretation
# ===========================================================================

class TestIntWidthAnalyzer:
    def test_default_envelope_clean(self):
        assert intwidth.analyze_int_width(probe_runtime=False) == []

    def test_overflowing_sumsq_one_finding_per_scheme(self):
        env = intwidth.Envelope(max_slab_steps=129)  # 129 * 4095**2 > 2^31
        fs = intwidth.analyze_int_width(env, probe_runtime=False)
        assert len(fs) == len(list(Scheme))
        assert {f.invariant for f in fs} == {"sumsq-overflow"}
        assert {f.subject for f in fs} == {"temporal.q_sumsq"}

    def test_field_sum_overflow_detected(self):
        # metadata/residual sums over a 2^21-element field at |q|<=4095
        # exceed int32 only for the blockmean schemes (Lorenzo contracts
        # its stage-(2) statistics through f32)
        env = intwidth.Envelope(max_field_elems=2**21, max_slab_steps=1)
        fs = intwidth.analyze_int_width(env, probe_runtime=False)
        assert fs, "expected blockmean accumulator overflows"
        assert {f.invariant for f in fs} == {"sum-overflow"}
        assert all("hszx" in f.message for f in fs)

    def test_safe_size_table_shape(self):
        table = intwidth.safe_size_table()
        for scheme in Scheme:
            row = table[scheme.value]
            assert row["max_safe_slab_steps"] >= 128
            assert row["summary_capacity"] == summary_capacity(4095)
            assert row["accumulators"]["temporal.q_sumsq"]["dtype"] == "int32"
        # Lorenzo residuals grow 2^nd-fold; blockmean residuals 2-fold
        assert table["hszp_nd"]["residual_abs_max"] == 8 * 4095
        assert table["hszx"]["residual_abs_max"] == 2 * 4095

    def test_runtime_guard_probe_clean(self):
        assert intwidth.analyze_int_width() == []

    def test_interval_arithmetic(self):
        iv = intwidth.Interval.sym(10)
        assert (iv * iv).hi == 100
        assert iv.square().lo == 0
        assert iv.sum_n(3).mag == 30
        assert iv.zigzag() == intwidth.Interval(0, 20)
        with pytest.raises(ValueError):
            intwidth.Interval(1, 0)


# ===========================================================================
# analyzer (3): trace-safety lint
# ===========================================================================

_HOST_SYNC_FIXTURE = '''
import jax

@jax.jit
def f(x):
    return x.item()
'''

_TRACER_BRANCH_FIXTURE = '''
import jax
import jax.numpy as jnp

@jax.jit
def f(x):
    s = jnp.sum(x)
    if s > 0:
        return s
    return -s
'''

_WAIVED_FIXTURE = '''
import jax

@jax.jit
def f(x):
    return x.item()  # audit: waive(host-sync) deliberate for this test
'''

_KERNEL_HOST_SYNC_FIXTURE = '''
import functools
import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

def _kern(x_ref, o_ref):
    o_ref[...] = x_ref[...] * 2

@functools.partial(jax.jit, static_argnames=("interpret",))
def double2d(x, *, interpret=False):
    n0, n1 = x.shape
    out = pl.pallas_call(
        _kern, out_shape=jax.ShapeDtypeStruct((n0, n1), x.dtype),
        interpret=interpret)(x)
    peak = jnp.max(out)
    if peak.item() > 0:  # host sync inside the jitted wrapper
        return out
    return -out
'''

_SHARD_BODY_HOST_SYNC_FIXTURE = '''
import jax
import jax.numpy as jnp
from repro import compat

def merge(mesh, stripes):
    def body(st):
        buf = jax.lax.psum(st, "shard")
        peak = jnp.max(buf)
        if peak.item() > 0:  # host sync inside the collective body
            return buf
        return -buf
    f = jax.jit(compat.shard_map(body, mesh=mesh,
                                 in_specs=None, out_specs=None))
    return f(stripes)
'''

_CLEAN_RULE_FIXTURE = '''
import jax.numpy as jnp

def _mean_rule(ctx, axis):
    if ctx.plan is not None and not ctx.plan.aligned:
        return None
    if ctx.scheme.is_nd:
        n = int(ctx.shape[0])
        return jnp.sum(jnp.ones(n))
    return jnp.where(jnp.asarray(0) > 0, 1.0, 0.0)
'''


class TestTraceSafetyAnalyzer:
    def test_hidden_host_sync_one_finding(self):
        fs = tracesafety.lint_source(_HOST_SYNC_FIXTURE, "fix.py")
        assert len(fs) == 1
        assert fs[0].invariant == "host-sync"
        assert fs[0].file == "fix.py" and fs[0].line is not None

    def test_tracer_branch_one_finding(self):
        fs = tracesafety.lint_source(_TRACER_BRANCH_FIXTURE, "fix.py")
        assert [f.invariant for f in fs] == ["tracer-branch"]

    def test_waiver_comment_suppresses(self):
        assert tracesafety.lint_source(_WAIVED_FIXTURE, "fix.py") == []

    def test_static_branches_not_flagged(self):
        assert tracesafety.lint_source(_CLEAN_RULE_FIXTURE, "fix.py") == []

    def test_repo_is_trace_safe(self):
        assert tracesafety.analyze_trace_safety() == []

    def test_kernel_wrapper_host_sync_caught(self):
        """A host sync hidden inside a jitted Pallas-kernel wrapper is a
        finding — the analyzer must not treat kernel wrappers specially."""
        fs = tracesafety.lint_source(_KERNEL_HOST_SYNC_FIXTURE, "kern.py")
        assert [f.invariant for f in fs] == ["host-sync"]
        assert fs[0].line is not None

    def test_kernels_package_in_audit_roots(self):
        """src/repro/kernels is part of the default trace-safety sweep, so
        regressions in the fused-kernel wrappers surface in repro.audit."""
        assert "kernels" in tracesafety._DEFAULT_ROOTS

    def test_comm_and_shard_packages_in_audit_roots(self):
        """The collective (comm) and sharded-store (shard) packages run
        shard_map-traced bodies, so they are linted by default too."""
        assert "comm" in tracesafety._DEFAULT_ROOTS
        assert "shard" in tracesafety._DEFAULT_ROOTS

    def test_shard_map_body_host_sync_caught(self):
        """A host sync inside a shard_map body (the sharded store's program
        shape) is a finding — collective bodies trace like any jitted fn."""
        fs = tracesafety.lint_source(_SHARD_BODY_HOST_SYNC_FIXTURE,
                                     "shardfix.py")
        assert [f.invariant for f in fs] == ["host-sync"]
        assert fs[0].line is not None


# ===========================================================================
# analyzer (4): jit-cache-key soundness
# ===========================================================================

_UNDERKEYED_FIXTURE = '''
import jax

class Engine:
    def __init__(self):
        self._jitted = {}

    def go(self, fields, scale):
        key = (len(fields),)
        fn = self._jitted.get(key)
        if fn is None:
            def run(*flat, _s=scale):
                return [x * _s for x in flat]
            fn = jax.jit(run)
            self._jitted[key] = fn
        return fn(*fields)
'''


class TestJitKeyAnalyzer:
    def test_underkeyed_cache_one_finding(self):
        fs = jitkeys.analyze_source(_UNDERKEYED_FIXTURE, "fix.py")
        assert len(fs) == 1
        assert fs[0].invariant == "unkeyed-closure"
        assert fs[0].subject == "scale"

    def test_keyed_twin_clean(self):
        good = _UNDERKEYED_FIXTURE.replace("key = (len(fields),)",
                                           "key = (len(fields), scale)")
        assert jitkeys.analyze_source(good, "fix.py") == []

    def test_invariant_comment_waives(self):
        waived = _UNDERKEYED_FIXTURE.replace(
            "fn = jax.jit(run)",
            "fn = jax.jit(run)  # audit: invariant(scale)")
        assert jitkeys.analyze_source(waived, "fix.py") == []

    def test_sabotaged_engine_key_detected(self):
        # dropping seed_sig from the key built at the run() call site must
        # surface `seeds` as an unkeyed traced input (the PR 3/5 bug class)
        from pathlib import Path

        import repro

        engine = (Path(repro.__file__).parent / "analytics"
                  / "engine.py").read_text()
        sabotaged = engine.replace("region, seed_sig)", "region, None)")
        assert sabotaged != engine
        fs = jitkeys.analyze_source(sabotaged, "engine.py")
        assert any(f.subject == "seeds" and f.invariant == "unkeyed-closure"
                   for f in fs)

    def test_repo_cache_keys_sound(self):
        assert jitkeys.analyze_jit_keys() == []


# ===========================================================================
# runner / CLI / self-audit
# ===========================================================================

class TestRunner:
    def test_self_audit_zero_findings(self):
        report = audit.run_audit()
        assert report.ok, "\n".join(f.render() for f in report.findings)
        assert report.safe_sizes  # table attached even when clean

    def test_cli_clean_exit_and_json(self, tmp_path, capsys):
        out = tmp_path / "AUDIT.json"
        rc = runner.main(["--json", str(out)])
        assert rc == 0
        import json

        data = json.loads(out.read_text())
        assert data["ok"] and data["n_findings"] == 0
        assert set(data["safe_sizes"]) >= {s.value for s in Scheme}

    def test_cli_nonzero_on_findings(self, capsys):
        # a 129-step envelope genuinely overflows Σq² — the CLI must fail
        rc = runner.main(["--analyzer", "intwidth",
                          "--max-slab-steps", "129"])
        assert rc == 1
        assert "sumsq-overflow" in capsys.readouterr().out

    def test_report_round_trip(self):
        f = Finding("registry", "missing-lowering-rule", "msg", subject="op")
        rep = AuditReport(findings=[f])
        d = rep.to_dict()
        assert not d["ok"] and d["findings_by_analyzer"] == {"registry": 1}
        assert f.render().startswith("[registry/missing-lowering-rule]")


# ===========================================================================
# satellite: registration-time validation
# ===========================================================================

class TestRegisterOpValidation:
    def test_rejects_missing_cell_naming_it(self):
        bad = _field_spec("badreg", feasible=_only_hszp_at_f, lower={})
        with pytest.raises(ValueError, match=r"\(stage F, lorenzo\)"):
            oplib.register_op(bad)
        assert "badreg" not in oplib.OPS
        assert "badreg" not in oplib._ALL_OPS

    def test_rejects_missing_closure(self):
        rule = lambda ctx, axis: None  # noqa: E731
        bad = _field_spec("badreg2", feasible=_only_hszp_at_f,
                          lower={(Stage.F, "any"): rule}, closure=None)
        with pytest.raises(ValueError, match="closure"):
            oplib.register_op(bad)
        assert "badreg2" not in oplib.OPS

    def test_rejects_temporal_without_rule(self):
        bad = OpSpec(name="badtemp", arity="temporal", category="statistic",
                     feasible=lambda s: (Stage.Q,))
        with pytest.raises(ValueError, match="lower_temporal"):
            oplib.register_op(bad)
        assert "badtemp" not in oplib.TEMPORAL_OPS

    def test_accepts_wellformed_spec(self):
        rule = lambda ctx, axis: None  # noqa: E731
        ok = _field_spec("okreg_audit", feasible=_only_hszp_at_f,
                         lower={(Stage.F, "any"): rule})
        try:
            oplib.register_op(ok)
            assert "okreg_audit" in oplib.OPS
            assert registry.analyze_registry() == []
        finally:
            oplib.OPS.pop("okreg_audit", None)
            oplib._ALL_OPS.pop("okreg_audit", None)
            oplib._ORDER.pop("okreg_audit", None)


# ===========================================================================
# satellite: TemporalSummary capacity guard
# ===========================================================================

class TestSummaryCapacityGuard:
    def test_formula_matches_audit(self):
        for q_abs in (0, 1, 255, 4095, 4096, 2**15, 2**20):
            assert summary_capacity(q_abs) == intwidth.summary_capacity(q_abs)
        assert summary_capacity(4095) == INT32_MAX // 4095**2 == 128
        assert summary_capacity(0) == INT32_MAX
        with pytest.raises(ValueError):
            summary_capacity(-1)

    def test_append_fails_loudly_at_boundary(self):
        # a tiny eps drives |q| to ~2^15, so capacity is O(1) timesteps:
        # the guard must reject the append that crosses it, untouched state
        data = np.linspace(0.5, 1.0, 256, dtype=np.float32).reshape(1, 256)
        tf = TemporalField("hszx", eps=2**-16)
        tf.append(data)
        q_abs = tf._q_abs_max
        cap = summary_capacity(q_abs)
        assert 1 <= cap <= 8, f"fixture drifted: capacity {cap}"
        while tf.n_steps < cap:
            tf.append(data)
        steps_before = tf.n_steps
        n_slabs = tf.n_slabs
        with pytest.raises(SummaryCapacityError, match="capacity"):
            tf.append(data)
        assert tf.n_steps == steps_before  # stream not mutated
        assert tf.n_slabs == n_slabs

    def test_growing_q_tightens_capacity(self):
        # a later slab with larger |q| must tighten the bound retroactively
        small = np.full((1, 256), 0.25, dtype=np.float32)
        tf = TemporalField("hszx", eps=2**-16)
        tf.append(small)
        cap_small = summary_capacity(tf._q_abs_max)
        big = np.linspace(0.5, 4.0, 256, dtype=np.float32).reshape(1, 256)
        q_big = int(np.max(np.abs(np.round(big / 2**-16))))
        if tf.n_steps + 1 > summary_capacity(q_big):
            with pytest.raises(SummaryCapacityError):
                tf.append(big)
        else:
            tf.append(big)
            assert summary_capacity(tf._q_abs_max) <= cap_small

    def test_normal_streams_unaffected(self):
        rng = np.random.default_rng(7)
        tf = TemporalField("hszp", rel_eb=1e-3)
        for _ in range(4):
            tf.append(rng.normal(size=(3, 64)).astype(np.float32))
        assert tf.n_steps == 12


# ===========================================================================
# analyzer (3b): trace-time stringification + stale-waiver warnings
# ===========================================================================

_FSTRING_SYNC_FIXTURE = '''
import jax
import jax.numpy as jnp

@jax.jit
def f(x):
    s = jnp.sum(x)
    print(f"sum={s}")
    return s
'''

_STRINGIFY_FIXTURE = '''
import jax
import jax.numpy as jnp

@jax.jit
def f(x):
    s = jnp.sum(x)
    a = str(s)
    b = format(s, ".3f")
    c = "{}".format(s)
    return s
'''

_STATIC_FSTRING_FIXTURE = '''
import jax

@jax.jit
def f(x):
    print(f"shape={x.shape}")
    return x
'''

_STALE_WAIVE_FIXTURE = '''
import jax

@jax.jit
def f(x):
    return x + 1  # audit: waive(host-sync)
'''


class TestTraceStringification:
    def test_fstring_on_traced_value_one_finding(self):
        fs = tracesafety.lint_source(_FSTRING_SYNC_FIXTURE, "fix.py")
        assert [f.invariant for f in fs] == ["host-sync"]

    def test_str_format_builtins_flagged(self):
        fs = tracesafety.lint_source(_STRINGIFY_FIXTURE, "fix.py")
        assert [f.invariant for f in fs] == ["host-sync"] * 3

    def test_static_fstring_not_flagged(self):
        assert tracesafety.lint_source(_STATIC_FSTRING_FIXTURE,
                                       "fix.py") == []

    def test_stale_waiver_is_warning_not_error(self):
        fs = tracesafety.lint_source(_STALE_WAIVE_FIXTURE, "fix.py")
        assert [(f.invariant, f.severity) for f in fs] \
            == [("stale-waiver", "warning")]
        rep = AuditReport(findings=fs)
        assert rep.ok and rep.warnings and not rep.errors


# ===========================================================================
# analyzer (4b): kernel-mode keys, covers predicates, stale invariants
# ===========================================================================

_UNCOVERED_DISPATCH_FIXTURE = '''
class FusedRule:
    pass

def _covers_bad(ctx):
    return ctx.scheme.is_lorenzo and ctx.eps_budget > 0

RULES = {"d": FusedRule(lambda c, a: None, _covers_bad)}
'''


class TestJitKeyKernelMode:
    def _engine_source(self):
        from pathlib import Path

        import repro

        return (Path(repro.__file__).parent / "analytics"
                / "engine.py").read_text()

    def test_kernel_sig_dropped_from_batch_key_one_finding(self):
        engine = self._engine_source()
        sab = engine.replace("seed_sig, oplib.kernel_sig())", "seed_sig)")
        assert sab != engine
        fs = jitkeys.analyze_source(sab, "engine.py")
        assert [(f.invariant, f.subject) for f in fs] \
            == [("unkeyed-kernel-mode", "_compiled")]

    def test_kernel_sig_dropped_from_inline_key_detected(self):
        engine = self._engine_source()
        sab = engine.replace("len(padded), oplib.kernel_sig())",
                             "len(padded))")
        assert sab != engine
        fs = jitkeys.analyze_source(sab, "engine.py")
        assert [f.invariant for f in fs] == ["unkeyed-kernel-mode"]
        assert fs[0].subject == "summarize"

    def test_covers_predicate_unkeyed_input_one_finding(self):
        fs = jitkeys.analyze_covers_source(_UNCOVERED_DISPATCH_FIXTURE,
                                           "fused.py")
        assert [(f.invariant, f.subject) for f in fs] \
            == [("uncovered-dispatch-input", "eps_budget")]

    def test_covers_predicate_helper_forwarding_followed(self):
        src = _UNCOVERED_DISPATCH_FIXTURE.replace(
            "def _covers_bad(ctx):\n"
            "    return ctx.scheme.is_lorenzo and ctx.eps_budget > 0",
            "def _helper(c):\n"
            "    return c.eps_budget > 0\n\n"
            "def _covers_bad(ctx):\n"
            "    return ctx.scheme.is_lorenzo and _helper(ctx)")
        fs = jitkeys.analyze_covers_source(src, "fused.py")
        assert [f.subject for f in fs] == ["eps_budget"]

    def test_live_covers_predicates_clean(self):
        from pathlib import Path

        import repro

        src = (Path(repro.__file__).parent / "core" / "fused.py").read_text()
        assert jitkeys.analyze_covers_source(src, "core/fused.py") == []

    def test_stale_invariant_declaration_is_warning(self):
        stale = '''
import jax

def build(cache, key):
    def run(x):
        return x + 1
    fn = jax.jit(run)  # audit: invariant(cost_model)
    cache._jitted[key] = fn
    return fn
'''
        fs = jitkeys.analyze_source(stale, "m.py")
        assert [(f.invariant, f.subject, f.severity) for f in fs] \
            == [("stale-waiver", "cost_model", "warning")]

    def test_consumed_invariant_declaration_not_stale(self):
        used = '''
import jax

def build(cache, key, cost_model):
    def run(x):
        return x + cost_model.weight
    fn = jax.jit(run)  # audit: invariant(cost_model)
    cache._jitted[key] = fn
    return fn
'''
        assert jitkeys.analyze_source(used, "m.py") == []


# ===========================================================================
# analyzer (5): kernel symbolic verifier (kernelspec)
# ===========================================================================

_SPEC = next(s for s in KERNEL_SPECS if s.name == "fused.lorenzo2d")

_FMA_FIXTURE = '''
import jax.numpy as jnp

def _kern(q_ref, eps_ref, o_ref):
    o_ref[...] = q_ref[...].astype(jnp.float32) * eps_ref[0]
'''


class TestKernelSpecAnalyzer:
    def test_live_kernel_layer_clean(self):
        assert kernelspec.analyze_kernel_specs() == []

    def test_every_pallas_site_has_a_spec(self):
        names = {s.name for s in KERNEL_SPECS}
        assert {"fused.lorenzo2d", "bitpack.pack", "stencil_dq.grad2d",
                "stencil_dq.laplacian2d",
                "quant_lorenzo.quant_lorenzo2d"} <= names

    def test_widened_halo_one_finding(self):
        # dropping the last-band guard lets (b+1)*r run past n0
        bad = replace(_SPEC, halos=(HaloRead("p", "(b + 1)*r", "n0"),))
        fs = kernelspec.check_spec(bad)
        assert [f.invariant for f in fs] == ["halo-out-of-bounds"]

    def test_overlapping_grid_writes_one_finding(self):
        # constant output index map: every grid step rewrites block (0, 0)
        out = TileSpec("plane", ("r", "n1"), ("0", "0"), ("n0", "n1"))
        fs = kernelspec.check_spec(replace(_SPEC, outputs=(out,)))
        assert [f.invariant for f in fs] == ["grid-write-overlap"]

    def test_coverage_gap_one_finding(self):
        # one band more of rows than the grid writes
        fs = kernelspec.check_spec(replace(_SPEC, facts=("n0 == nb*r + r",)))
        assert [f.invariant for f in fs] == ["grid-write-gap"]

    def test_vmem_budget_one_finding(self):
        env = intwidth.Envelope(max_field_elems=2**23)  # 9F*4B >> 16 MiB
        fs = kernelspec.check_spec(_SPEC, env)
        assert [f.invariant for f in fs] == ["vmem-budget"]

    def test_unpack_lemma_pins_word_window_slack(self):
        assert kernelspec.check_unpack_lemma(2) == []
        fs = kernelspec.check_unpack_lemma(1)
        assert [f.invariant for f in fs] == ["unpack-oob"]

    def test_output_multiply_one_finding(self):
        fs, declared, used = kernelspec.lint_kernel_source(_FMA_FIXTURE,
                                                           "k.py")
        assert [f.invariant for f in fs] == ["output-multiply"]
        assert fs[0].line == 5 and not declared and not used

    def test_output_multiply_waiver_consumed(self):
        waived = _FMA_FIXTURE.replace(
            "* eps_ref[0]",
            "* eps_ref[0]  # audit: waive(output-multiply)")
        fs, declared, used = kernelspec.lint_kernel_source(waived, "k.py")
        assert fs == [] and declared and used

    def test_stencil_kernels_keep_eps_outside(self):
        """The dequantized stencils emit exact integers; the float eps tail
        lives in the wrapper (the PR 8 FMA-contraction hazard)."""
        from pathlib import Path

        import repro

        src = (Path(repro.__file__).parent / "kernels"
               / "stencil_dq.py").read_text()
        fs, _, _ = kernelspec.lint_kernel_source(src, "stencil_dq.py")
        assert fs == []
        sab = src.replace(
            "d0_ref[...] = qs_ref[...] - qn_ref[...]",
            "d0_ref[...] = (qs_ref[...] - qn_ref[...])"
            ".astype(jnp.float32) * 0.5")
        assert sab != src
        fs, _, _ = kernelspec.lint_kernel_source(sab, "stencil_dq.py")
        assert [f.invariant for f in fs] == ["output-multiply"]

    def test_undeclared_site_and_stale_spec(self, tmp_path):
        kdir = tmp_path / "kernels"
        kdir.mkdir()
        (kdir / "mystery.py").write_text(
            "import jax\n"
            "from jax.experimental import pallas as pl\n"
            "def go(x):\n"
            "    return pl.pallas_call(\n"
            "        lambda x_ref, o_ref: None,\n"
            "        grid=(4,),\n"
            "        out_shape=jax.ShapeDtypeStruct(x.shape, x.dtype))(x)\n")
        fs = kernelspec.analyze_kernel_specs(specs=(), src_root=tmp_path)
        assert [f.invariant for f in fs] == ["undeclared-kernel"]
        fs = kernelspec.analyze_kernel_specs(specs=(_SPEC,),
                                             src_root=tmp_path)
        assert sorted(f.invariant for f in fs) \
            == ["stale-kernel-spec", "undeclared-kernel"]

    def test_stale_kernel_waiver_warning(self, tmp_path):
        kdir = tmp_path / "kernels"
        kdir.mkdir()
        (kdir / "clean.py").write_text(
            "def _kern(q_ref, o_ref):\n"
            "    # audit: waive(output-multiply)\n"
            "    o_ref[...] = q_ref[...] + 1\n")
        fs = kernelspec.analyze_kernel_specs(specs=(), src_root=tmp_path)
        assert [(f.invariant, f.severity) for f in fs] \
            == [("stale-waiver", "warning")]


# ===========================================================================
# analyzer (6): shard-partition exactness (sharddisjoint)
# ===========================================================================

class TestShardDisjointAnalyzer:
    def test_live_shard_layer_clean(self):
        assert sharddisjoint.analyze_shard_disjoint() == []

    def test_double_owned_word_one_finding(self):
        class DoubleOwned(BlockPlacement):
            def shard_word_index(self, bits):
                stripes = super().shard_word_index(bits)
                if self.n_shards >= 2 and len(stripes[0]):
                    stripes[1] = np.unique(np.concatenate(
                        [np.asarray(stripes[1]),
                         np.asarray(stripes[0][:1])]))
                return stripes

        fs = sharddisjoint.analyze_shard_disjoint(placement_cls=DoubleOwned)
        assert [f.invariant for f in fs] == ["word-owner-overlap"]

    def test_scatter_overlap_one_finding(self):
        def overlap_routing(n_shards, placement, bits, word_idx):
            src, dst = shard_exec.gather_routing(n_shards, placement, bits,
                                                 word_idx)
            src, dst = np.array(src), np.array(dst)
            if n_shards >= 2:
                l0 = np.nonzero(dst[0] != len(word_idx))[0]
                l1 = np.nonzero(dst[1] != len(word_idx))[0]
                if l0.size and l1.size:
                    dst[1, l1[0]] = dst[0, l0[0]]
            return src, dst

        fs = sharddisjoint.analyze_shard_disjoint(routing_fn=overlap_routing)
        assert [f.invariant for f in fs] == ["scatter-overlap"]

    def test_world_scaled_sumsq_overflow_one_finding(self):
        # 129 slab steps overflow int32 Σq² once any band fans in — the
        # envelope-driven acceptance fixture for the world-size sweep
        env = intwidth.Envelope(max_slab_steps=129)
        fs = sharddisjoint.analyze_shard_disjoint(env)
        assert [f.invariant for f in fs] == ["world-sumsq-overflow"]

    def test_collective_bit_budget_overflow_one_finding(self):
        fs = sharddisjoint.analyze_shard_disjoint(
            bit_budget_fn=lambda world, container_bits=16: 15)
        assert [f.invariant for f in fs] == ["collective-overflow"]

    def test_duplicated_band_detected(self):
        def dup_bands(field, placement, region=None):
            bands = shard_exec.spatial_bands(field, placement, region)
            return bands + bands[:1] if len(bands) > 1 else bands

        fs = sharddisjoint.analyze_shard_disjoint(bands_fn=dup_bands)
        assert fs and fs[0].invariant == "band-overlap"

    def test_safe_size_table_shape(self):
        table = sharddisjoint.shard_safe_size_table()
        per = table["per_world"]
        assert per["1"]["summary_capacity_if_accumulating"] == 128
        caps = [per[str(w)]["summary_capacity_if_accumulating"]
                for w in (1, 2, 4, 8)]
        assert caps == sorted(caps, reverse=True)
        # disjoint capacity is world-independent — the proven property
        assert len({per[k]["summary_capacity_disjoint"]
                    for k in per}) == 1
        for k in per:
            assert per[k]["collective_worst_psum"] <= PSUM_CONTAINER_MAX

    def test_worst_case_psum_stays_in_container(self):
        for w in (1, 2, 3, 4, 8, 64, 1024, 4096):
            assert worst_case_psum(w) <= PSUM_CONTAINER_MAX


# ===========================================================================
# runner: --only, schema version, exit codes, both kernel modes
# ===========================================================================

class TestRunnerContract:
    def test_six_analyzers_registered(self):
        assert runner.ALL_ANALYZERS == ("registry", "intwidth", "trace",
                                        "jitkey", "kernelspec",
                                        "sharddisjoint")
        assert audit.ALL_ANALYZERS == runner.ALL_ANALYZERS

    def test_only_flag_and_schema_version(self, tmp_path, capsys):
        import json

        out = tmp_path / "AUDIT.json"
        rc = runner.main(["--only", "kernelspec,sharddisjoint",
                          "--json", str(out)])
        assert rc == 0
        data = json.loads(out.read_text())
        assert data["schema_version"] == SCHEMA_VERSION == 2
        assert data["ok"] and data["shard_safe_sizes"]["per_world"]

    def test_only_rejects_unknown_analyzer(self, capsys):
        with pytest.raises(SystemExit) as exc:
            runner.main(["--only", "nosuch"])
        assert exc.value.code == 2

    def test_exit_zero_on_warnings_only(self):
        rep = AuditReport(findings=[Finding(
            "trace", "stale-waiver", "m", severity="warning")])
        assert rep.ok and not rep.errors and len(rep.warnings) == 1
        d = rep.to_dict()
        assert d["ok"] and d["n_warnings"] == 1 and d["n_errors"] == 0

    def test_self_audit_clean_in_both_kernel_modes(self):
        for mode in ("interpret", "off"):
            with kops.override_mode(mode):
                report = audit.run_audit()
            assert report.ok, (mode, [f.render() for f in report.findings])
            assert not report.warnings
            assert report.shard_safe_sizes["per_world"]
