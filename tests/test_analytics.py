"""Batched analytics engine: planner matrix, vmap bit-exactness, serving.

The feasibility matrix test is the drift guard demanded by the planner's
contract: every (scheme, op, stage) cell is asserted against the actual
raise/no-raise behavior of ``repro.core.homomorphic``, so the planner can
never silently diverge from the ops.
"""
import numpy as np
import pytest
import jax
import jax.numpy as jnp

from repro import analytics
from repro.core import (Stage, UnsupportedStageError, batch_stack,
                        batch_unstack, by_name, homomorphic as H, hszp,
                        hszp_nd, hszx, hszx_nd)
from repro.serve import AnalyticsFrontend, AnalyticsRequest

ALL = [hszp, hszx, hszp_nd, hszx_nd]
UNIVARIATE = ["mean", "std", "derivative", "gradient", "laplacian"]


def _compress_many(comp, n, shape=(37, 53), rel_eb=1e-3, seed=0):
    rng = np.random.default_rng(seed)
    return [comp.compress(jnp.asarray(rng.normal(0, 1, shape).astype(np.float32)),
                          rel_eb=rel_eb) for _ in range(n)]


def _apply(op, c, stage, axis=0):
    if op == "mean":
        return H.mean(c, stage)
    if op == "std":
        return H.std(c, stage)
    if op == "derivative":
        return H.derivative(c, stage, axis)
    if op == "gradient":
        return H.gradient(c, stage)
    if op == "laplacian":
        return H.laplacian(c, stage)
    if op == "divergence":
        return H.divergence(list(c), stage)
    return H.curl(list(c), stage)


# -- feasibility matrix: planner pinned to op behavior ------------------------

@pytest.mark.parametrize("comp", ALL, ids=lambda c: c.scheme.value)
@pytest.mark.parametrize("op", analytics.OPS)
@pytest.mark.parametrize("stage", list(Stage))
def test_feasibility_matrix_matches_ops(comp, op, stage, field_2d):
    """Every Table I cell: planner says feasible <=> the op does not raise."""
    if op in analytics.MULTIVARIATE:
        item = (comp.compress(jnp.asarray(field_2d), rel_eb=1e-3),
                comp.compress(jnp.asarray(field_2d[::-1].copy()), rel_eb=1e-3))
    else:
        item = comp.compress(jnp.asarray(field_2d), rel_eb=1e-3)
    feasible = analytics.is_feasible(comp.scheme, op, stage)
    if feasible:
        out = _apply(op, item, stage)  # must not raise
        assert all(np.isfinite(np.asarray(x)).all()
                   for x in jax.tree.leaves(out))
    else:
        with pytest.raises(UnsupportedStageError):
            _apply(op, item, stage)


@pytest.mark.parametrize("comp", ALL, ids=lambda c: c.scheme.value)
@pytest.mark.parametrize("op", analytics.OPS)
def test_auto_stage_never_raises(comp, op, field_2d):
    """stage="auto" always resolves to a stage the op actually supports."""
    stage = analytics.plan_stage(comp.scheme, op, "auto")
    assert stage == analytics.feasible_stages(comp.scheme, op)[0]
    if op in analytics.MULTIVARIATE:
        item = (comp.compress(jnp.asarray(field_2d), rel_eb=1e-3),) * 2
    else:
        item = comp.compress(jnp.asarray(field_2d), rel_eb=1e-3)
    _apply(op, item, stage)  # must not raise


def test_explicit_infeasible_stage_raises():
    with pytest.raises(UnsupportedStageError):
        analytics.plan_stage(hszp.scheme, "mean", Stage.M)
    with pytest.raises(UnsupportedStageError):
        analytics.plan_stage(hszp.scheme, "derivative", "P")
    assert analytics.plan_stage(hszp_nd.scheme, "derivative", "p") == Stage.P


# -- batch stacking (core view) ------------------------------------------------

@pytest.mark.parametrize("comp", ALL, ids=lambda c: c.scheme.value)
def test_batch_stack_roundtrip(comp):
    cs = _compress_many(comp, 3)
    stacked = batch_stack(cs)
    back = batch_unstack(stacked)
    assert len(back) == 3
    for orig, rt in zip(cs, back):
        for a, b in zip(jax.tree.leaves(orig), jax.tree.leaves(rt)):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_batch_stack_rejects_layout_mismatch():
    a = hszp_nd.compress(jnp.zeros((32, 32)), abs_eb=1e-3)
    b = hszp_nd.compress(jnp.zeros((16, 16)), abs_eb=1e-3)
    with pytest.raises(ValueError):
        batch_stack([a, b])
    c = hszx_nd.compress(jnp.zeros((32, 32)), abs_eb=1e-3)
    with pytest.raises(ValueError):
        batch_stack([a, c])


# -- batched execution: bit-exact vs per-field loops ---------------------------

@pytest.mark.parametrize("comp", ALL, ids=lambda c: c.scheme.value)
@pytest.mark.parametrize("op", UNIVARIATE)
def test_batched_matches_per_field_all_stages(comp, op):
    """vmap-batched result == jitted per-field loop, bit for bit, at every
    feasible stage (batch of 5 also exercises bucket padding + slicing)."""
    cs = _compress_many(comp, 5)
    for stage in analytics.feasible_stages(comp.scheme, op):
        res = analytics.query(cs, op, stage=stage)
        fn = jax.jit(lambda c, s=stage, o=op: _apply(o, c, s))
        for got, c in zip(res.values, cs):
            ref = fn(c)
            np.testing.assert_array_equal(np.asarray(got), np.asarray(ref))


@pytest.mark.parametrize("comp", [hszp_nd, hszx_nd], ids=lambda c: c.scheme.value)
@pytest.mark.parametrize("op", ["divergence", "curl"])
def test_batched_multivariate_matches_per_field(comp, op):
    rng = np.random.default_rng(1)
    vecs = [tuple(comp.compress(
        jnp.asarray(rng.normal(0, 1, (40, 44)).astype(np.float32)), rel_eb=1e-3)
        for _ in range(2)) for _ in range(3)]
    for stage in analytics.feasible_stages(comp.scheme, op):
        res = analytics.query(vecs, op, stage=stage)
        fn = jax.jit(lambda u, v, s=stage, o=op: _apply(o, (u, v), s))
        for got, vec in zip(res.values, vecs):
            ref = fn(*vec)
            np.testing.assert_array_equal(np.asarray(got), np.asarray(ref))


def test_batched_encoded_fields():
    """Encoded (bit-packed) fields run batched without pre-decoding."""
    comp = by_name("hszx_nd")
    cs = _compress_many(comp, 3, shape=(48, 48))
    bits = max(comp.max_bits(c) for c in cs)
    es = [comp.encode(c, bits=bits) for c in cs]
    res = analytics.query(es, "mean", stage="auto")
    assert res.stages[0] == Stage.M  # metadata path: no decode at all
    fn = jax.jit(H.mean, static_argnums=1)
    for got, e in zip(res.values, es):
        np.testing.assert_array_equal(np.asarray(got), np.asarray(fn(e, Stage.M)))


def test_query_groups_mixed_layouts():
    """One query over heterogeneous layouts: grouped, each at its own stage,
    results in input order."""
    nd = _compress_many(hszx_nd, 2, shape=(40, 40))
    oned = _compress_many(hszp, 2, shape=(300,), seed=3)
    res = analytics.query([nd[0], oned[0], nd[1], oned[1]], "mean")
    assert res.n_batches == 2
    assert [s.name for s in res.stages] == ["M", "P", "M", "P"]
    for got, c in zip(res.values, [nd[0], oned[0], nd[1], oned[1]]):
        stage = Stage.M if c.scheme.is_blockmean else Stage.P
        np.testing.assert_array_equal(np.asarray(got),
                                      np.asarray(jax.jit(H.mean, static_argnums=1)(c, stage)))


def test_jit_cache_reused_across_queries():
    eng = analytics.BatchedAnalytics()
    cs = _compress_many(hszp_nd, 3)
    eng.run(cs, "mean", Stage.P)
    assert eng.cache_size == 1
    eng.run(_compress_many(hszp_nd, 3, seed=9), "mean", Stage.P)
    assert eng.cache_size == 1  # same (scheme, block, shape, op, stage) key
    eng.run(cs, "std", Stage.P)
    assert eng.cache_size == 2


def test_derivative_axis_in_cache_key():
    eng = analytics.BatchedAnalytics()
    cs = _compress_many(hszp_nd, 2)
    d0 = eng.run(cs, "derivative", Stage.P, axis=0)
    d1 = eng.run(cs, "derivative", Stage.P, axis=1)
    assert eng.cache_size == 2
    assert not np.allclose(np.asarray(d0), np.asarray(d1))


# -- cost model ---------------------------------------------------------------

def test_cost_model_calibration_changes_plan():
    csv = "\n".join([
        "name,us_per_call,derived",
        "fig58/Ocean/mean/hszx_nd-m,50.0,GBps=1",
        "fig58/Ocean/mean/hszx_nd-p,5.0,GBps=1",
        "fig58/Ocean/mean/hszx_nd-q,80.0,GBps=1",
        "fig58/Ocean/mean/hszx_nd-f,90.0,GBps=1",
        "# comment rows and malformed rows are ignored",
        "fig2/Ocean/hszp/eb0.01,0.0,ratio=3",
        "bogus",
    ])
    cm = analytics.CostModel.from_benchmark_csv(csv)
    assert cm.cost(hszx_nd.scheme, "mean", Stage.P) == 5.0
    # calibrated: stage P measured cheaper than the metadata stage
    assert analytics.plan_stage(hszx_nd.scheme, "mean", "auto", cm) == Stage.P
    # uncalibrated rows fall back to cheapest-stage-first
    assert analytics.plan_stage(hszx_nd.scheme, "std", "auto", cm) == Stage.P
    # a calibrated plan still never picks an infeasible stage
    assert analytics.plan_stage(hszp.scheme, "mean", "auto", cm) == Stage.P


def test_cost_model_never_selects_infeasible():
    cm = analytics.CostModel()
    for comp in ALL:
        for op in analytics.OPS:
            for s in Stage:
                cm.record(comp.scheme, op, s, 1e-6 if s == Stage.M else 1e3)
    for comp in ALL:
        for op in analytics.OPS:
            stage = analytics.plan_stage(comp.scheme, op, "auto", cm)
            assert analytics.is_feasible(comp.scheme, op, stage)


# -- serving frontend ---------------------------------------------------------

def test_analytics_frontend_drains_mixed_requests():
    rng = np.random.default_rng(5)
    comp = by_name("hszx_nd")
    fields = [comp.compress(jnp.asarray(
        rng.normal(0, 1, (40, 40)).astype(np.float32)), rel_eb=1e-3)
        for _ in range(5)]
    fe = AnalyticsFrontend()
    for i, c in enumerate(fields):
        fe.add_request(AnalyticsRequest(uid=i, fields=c, op="mean"))
    fe.add_request(AnalyticsRequest(uid=10, fields=fields[0], op="std"))
    fe.add_request(AnalyticsRequest(
        uid=11, fields=(fields[0], fields[1]), op="curl"))
    done = fe.run_until_drained()
    assert len(done) == 7 and all(r.done for r in done)
    by_uid = {r.uid: r for r in done}
    assert by_uid[0].result_stage == Stage.M
    np.testing.assert_array_equal(
        np.asarray(by_uid[0].result),
        np.asarray(jax.jit(H.mean, static_argnums=1)(fields[0], Stage.M)))
    assert by_uid[11].result.shape == (38, 38)
    # 5x mean batched into one call + std + curl = 3 compiled programs
    assert fe.engine.cache_size == 3


def test_analytics_frontend_isolates_bad_requests():
    """An infeasible request is rejected with an error; the rest of the
    queue is still served."""
    c = hszp.compress(jnp.asarray(np.linspace(0, 1, 200, dtype=np.float32)),
                      rel_eb=1e-3)
    fe = AnalyticsFrontend()
    fe.add_request(AnalyticsRequest(uid=0, fields=c, op="mean"))
    fe.add_request(AnalyticsRequest(uid=1, fields=c, op="derivative",
                                    stage=Stage.P))  # infeasible: 1-D scheme
    fe.add_request(AnalyticsRequest(uid=2, fields=c, op="std"))
    done = {r.uid: r for r in fe.run_until_drained()}
    assert len(done) == 3
    assert done[1].error is not None and "derivative" in done[1].error
    assert done[1].result is None
    assert done[0].error is None and done[0].result is not None
    assert done[2].error is None and done[2].result is not None
