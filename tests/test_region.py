"""Block-sparse region queries: sparsity, correctness, planning, serving.

The contract under test (ISSUE 2 acceptance):

* a region query over a small window decodes only the covering blocks'
  payload words (asserted via the plan's gathered word count);
* for every (scheme, op, stage) cell, the region result equals the same op
  applied to the cropped full decompression, within stage tolerance;
* region geometry feeds stage planning (stage-① alignment, closure-scaled
  cost model) and batching (region is part of the jit-cache key).
"""
import numpy as np
import pytest
import jax
import jax.numpy as jnp

from repro import analytics
from repro.core import (Stage, UnsupportedStageError, encode,
                        homomorphic as H, hszp, hszp_nd, hszx, hszx_nd)
from repro.core import region as R
from repro.serve import AnalyticsFrontend, AnalyticsRequest

ALL = [hszp, hszx, hszp_nd, hszx_nd]
ND = [hszp_nd, hszx_nd]

REGION = ((30, 75), (10, 52))  # unaligned window of the 181x97 field_2d
WIN = tuple(slice(s, e) for s, e in REGION)


def _c(comp, data, rel_eb=1e-3):
    return comp.compress(jnp.asarray(data), rel_eb=rel_eb)


def _window_ref(comp, c):
    """The acceptance reference: crop the full decompression to the region."""
    return np.asarray(comp.decompress(c, Stage.F))[WIN]


# -- the sparsity contract ----------------------------------------------------

def test_region_decodes_only_covering_blocks():
    """A <=10% window gathers exactly its covering blocks and a proportional
    share of the payload words — never the whole field."""
    rng = np.random.default_rng(7)
    d = rng.normal(0, 1, (160, 160)).astype(np.float32)
    c = hszx_nd.compress(jnp.asarray(d), rel_eb=1e-3)   # block (16, 16)
    e = hszx_nd.encode(c)
    region = ((32, 80), (48, 96))                       # 48x48 = 9% of field
    plan = R.plan_region(e, region, "cover")
    assert plan.n_sub_blocks == 9                       # 3x3 covering blocks
    gi = plan.payload_gather(e.bits)
    assert gi.n_words < 0.15 * e.payload.size           # ~9% + block-row slack
    # the gathered decode is bit-exact vs the corresponding full-decode slice
    sub = encode.decode_region(e, plan)
    np.testing.assert_array_equal(np.asarray(sub.residuals),
                                  np.asarray(c.residuals)[32:80, 48:96])


def test_region_word_count_scales_with_window():
    rng = np.random.default_rng(8)
    e = hszx_nd.encode(hszx_nd.compress(
        jnp.asarray(rng.normal(0, 1, (160, 160)).astype(np.float32)),
        rel_eb=1e-3))
    small = R.plan_region(e, ((0, 16), (0, 16)), "cover").payload_gather(e.bits)
    large = R.plan_region(e, ((0, 96), (0, 96)), "cover").payload_gather(e.bits)
    assert small.n_words < large.n_words < e.payload.size


def test_lorenzo_closure_is_prefix_hull():
    """Lorenzo recorrelation is a prefix sum: the closure anchors at origin."""
    rng = np.random.default_rng(9)
    c = hszp_nd.compress(jnp.asarray(
        rng.normal(0, 1, (160, 160)).astype(np.float32)), rel_eb=1e-3)
    hull = R.plan_region(c, ((128, 160), (128, 160)), "hull")
    assert hull.grid_ranges == ((0, 10), (0, 10))
    band0 = R.plan_region(c, ((128, 160), (128, 160)), ("band", 0))
    assert band0.grid_ranges == ((8, 10), (0, 10))  # cover on the deriv axis
    assert band0.gathered_elems < hull.gathered_elems


# -- correctness: every (scheme, op, stage) cell ------------------------------

@pytest.mark.parametrize("comp", ALL, ids=lambda c: c.scheme.value)
def test_region_statistics_match_cropped_decompression(comp, field_2d):
    c = _c(comp, field_2d)
    e = comp.encode(c)
    win = _window_ref(comp, c)
    for field in (c, e):
        for stage in (Stage.P, Stage.Q, Stage.F):
            mu = float(H.mean(field, stage, region=REGION))
            assert abs(mu - win.mean()) <= 2e-4, (stage, mu, win.mean())
            sd = float(H.std(field, stage, region=REGION))
            assert abs(sd - win.std(ddof=1)) <= float(c.eps) + 1e-4, (stage, sd)


@pytest.mark.parametrize("comp", ALL, ids=lambda c: c.scheme.value)
@pytest.mark.parametrize("op", ["derivative", "laplacian"])
def test_region_stencils_match_cropped_decompression(comp, op, field_2d):
    c = _c(comp, field_2d)
    e = comp.encode(c)
    win = _window_ref(comp, c)
    stages = [Stage.Q, Stage.F] + ([Stage.P] if comp.scheme.is_nd else [])
    for field in (c, e):
        for stage in stages:
            if op == "derivative":
                for axis in (0, 1):
                    got = np.asarray(H.derivative(field, stage, axis,
                                                  region=REGION))
                    hi = [slice(1, -1)] * 2
                    lo = [slice(1, -1)] * 2
                    hi[axis], lo[axis] = slice(2, None), slice(None, -2)
                    ref = (win[tuple(hi)] - win[tuple(lo)]) * 0.5
                    np.testing.assert_allclose(got, ref, rtol=1e-4,
                                               atol=float(c.eps) * 1e-2)
            else:
                got = np.asarray(H.laplacian(field, stage, region=REGION))
                ref = (-4 * win[1:-1, 1:-1] + win[2:, 1:-1] + win[:-2, 1:-1]
                       + win[1:-1, 2:] + win[1:-1, :-2])
                np.testing.assert_allclose(got, ref, rtol=1e-4,
                                           atol=float(c.eps) * 1e-1)


@pytest.mark.parametrize("comp", ALL, ids=lambda c: c.scheme.value)
@pytest.mark.parametrize("op", ["divergence", "curl"])
def test_region_multivariate_match_cropped_decompression(comp, op, vector_field_2d):
    u, v = vector_field_2d
    cu, cv = _c(comp, u), _c(comp, v)
    region = ((20, 60), (40, 90))
    fn = H.divergence if op == "divergence" else H.curl
    du = np.asarray(comp.decompress(cu, Stage.F))[20:60, 40:90]
    dv = np.asarray(comp.decompress(cv, Stage.F))[20:60, 40:90]
    if op == "divergence":
        ref = ((du[2:, 1:-1] - du[:-2, 1:-1]) * 0.5
               + (dv[1:-1, 2:] - dv[1:-1, :-2]) * 0.5)
    else:  # curl = dv/dx - du/dy
        ref = ((dv[2:, 1:-1] - dv[:-2, 1:-1]) * 0.5
               - (du[1:-1, 2:] - du[1:-1, :-2]) * 0.5)
    stages = [Stage.Q, Stage.F] + ([Stage.P] if comp.scheme.is_nd else [])
    for stage in stages:
        got = np.asarray(fn([cu, cv], stage, region=region))
        np.testing.assert_allclose(got, ref, rtol=1e-4,
                                   atol=float(cu.eps) * 1e-1)


@pytest.mark.parametrize("comp", ND, ids=lambda c: c.scheme.value)
def test_region_3d(comp, field_3d):
    c = _c(comp, field_3d)
    region = ((4, 20), (10, 36), (5, 29))
    win = np.asarray(comp.decompress(c, Stage.F))[4:20, 10:36, 5:29]
    for stage in (Stage.P, Stage.Q):
        assert abs(float(H.mean(c, stage, region=region)) - win.mean()) <= 2e-4
        got = np.asarray(H.derivative(c, stage, 1, region=region))
        ref = (win[1:-1, 2:, 1:-1] - win[1:-1, :-2, 1:-1]) * 0.5
        np.testing.assert_allclose(got, ref, rtol=1e-4, atol=float(c.eps) * 1e-2)


def test_region_full_window_equals_full_field(field_2d):
    """region=(full extent) must reproduce the full-field op exactly."""
    for comp in ND:
        c = _c(comp, field_2d)
        full = tuple((0, s) for s in c.shape)
        for stage in (Stage.P, Stage.Q):
            np.testing.assert_allclose(
                float(H.mean(c, stage, region=full)),
                float(H.mean(c, stage)), rtol=1e-6, atol=1e-6)
            np.testing.assert_array_equal(
                np.asarray(H.derivative(c, stage, 0, region=full)),
                np.asarray(H.derivative(c, stage, 0)))


def test_region_slice_specs(field_2d):
    """slice / (start, stop) / None axis specs are equivalent."""
    c = _c(hszx_nd, field_2d)
    a = H.mean(c, Stage.P, region=(slice(30, 75), slice(10, 52)))
    b = H.mean(c, Stage.P, region=REGION)
    np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    full_rows = H.mean(c, Stage.P, region=(None, (10, 52)))
    expect = H.mean(c, Stage.P, region=((0, 181), (10, 52)))
    np.testing.assert_array_equal(np.asarray(full_rows), np.asarray(expect))
    with pytest.raises(ValueError):
        H.mean(c, Stage.P, region=((0, 300), (0, 10)))
    with pytest.raises(ValueError):
        H.mean(c, Stage.P, region=((0, 10),))  # rank mismatch


# -- stage-1 alignment rule ---------------------------------------------------

def test_region_stage1_mean_requires_alignment():
    rng = np.random.default_rng(3)
    d = rng.normal(3.0, 1.0, (160, 160)).astype(np.float32)
    c = hszx_nd.compress(jnp.asarray(d), rel_eb=1e-3)  # block (16, 16)
    aligned = ((32, 80), (48, 96))
    mu = float(H.mean(c, Stage.M, region=aligned))
    assert abs(mu - d[32:80, 48:96].mean()) <= 2 * float(c.eps)
    with pytest.raises(UnsupportedStageError):
        H.mean(c, Stage.M, region=((33, 80), (48, 96)))
    # planner mirrors the op: auto drops stage 1 for unaligned windows
    assert analytics.plan_stage(c.scheme, "mean", "auto",
                                region=aligned, field=c) == Stage.M
    assert analytics.plan_stage(c.scheme, "mean", "auto",
                                region=((33, 80), (48, 96)), field=c) == Stage.P
    with pytest.raises(UnsupportedStageError):
        analytics.plan_stage(c.scheme, "mean", Stage.M,
                             region=((33, 80), (48, 96)), field=c)


# -- region-aware cost model --------------------------------------------------

def test_region_closure_fractions_flip_auto_stage():
    """Lorenzo stage-② derivative bands shrink with the window while stage-③
    prefix hulls do not: a far-corner window flips the auto plan to ②."""
    rng = np.random.default_rng(4)
    c = hszp_nd.compress(jnp.asarray(
        rng.normal(0, 1, (160, 160)).astype(np.float32)), rel_eb=1e-3)
    cm = analytics.CostModel()
    for stage, us in ((Stage.P, 100.0), (Stage.Q, 50.0), (Stage.F, 200.0)):
        cm.record(c.scheme, "derivative", stage, us)
    # full field: stage Q measured cheapest
    assert analytics.plan_stage(c.scheme, "derivative", "auto", cm) == Stage.Q
    # far-corner window: the stage-P band touches 0.2 of the field while the
    # stage-Q hull touches all of it -> 100*0.2 < 50*1.0 picks P
    region = ((128, 160), (128, 160))
    assert analytics.plan_stage(c.scheme, "derivative", "auto", cm,
                                region=region, field=c, axis=0) == Stage.P
    fr_p = R.closure_fraction(c, "derivative", Stage.P, region, axis=0)
    fr_q = R.closure_fraction(c, "derivative", Stage.Q, region, axis=0)
    assert fr_p == pytest.approx(0.2) and fr_q == pytest.approx(1.0)


def test_closure_fraction_blockmean_scales_with_window():
    rng = np.random.default_rng(5)
    c = hszx_nd.compress(jnp.asarray(
        rng.normal(0, 1, (160, 160)).astype(np.float32)), rel_eb=1e-3)
    region = ((128, 160), (128, 160))
    for stage in (Stage.P, Stage.Q, Stage.F):
        fr = R.closure_fraction(c, "mean", stage, region)
        assert fr == pytest.approx((32 * 32) / (160 * 160))
    assert R.closure_fraction(c, "mean", Stage.M, region) == pytest.approx(4 / 100)


# -- engine / query / serving -------------------------------------------------

def _compress_many(comp, n, shape=(96, 80), rel_eb=1e-3, seed=0):
    rng = np.random.default_rng(seed)
    return [comp.compress(jnp.asarray(rng.normal(0, 1, shape).astype(np.float32)),
                          rel_eb=rel_eb) for _ in range(n)]


@pytest.mark.parametrize("comp", ALL, ids=lambda c: c.scheme.value)
def test_query_region_batched_matches_per_field(comp):
    cs = _compress_many(comp, 4)
    region = ((10, 40), (20, 60))
    for op in ("mean", "std", "derivative"):
        for stage in analytics.feasible_stages(comp.scheme, op):
            if stage == Stage.M:
                continue  # unaligned window: stage 1 infeasible by design
            res = analytics.query(cs, op, stage=stage, region=region)
            if op == "mean":
                fn = jax.jit(lambda c, s=stage: H.mean(c, s, region=region))
            elif op == "std":
                fn = jax.jit(lambda c, s=stage: H.std(c, s, region=region))
            else:
                fn = jax.jit(lambda c, s=stage: H.derivative(c, s, 0,
                                                             region=region))
            for got, c in zip(res.values, cs):
                np.testing.assert_array_equal(np.asarray(got),
                                              np.asarray(fn(c)))


def test_region_part_of_jit_cache_key():
    eng = analytics.BatchedAnalytics()
    cs = _compress_many(hszx_nd, 2)
    r1, r2 = ((0, 32), (0, 32)), ((32, 64), (16, 48))
    out1 = eng.run(cs, "mean", Stage.P, region=r1)
    assert eng.cache_size == 1
    eng.run(cs, "mean", Stage.P, region=r1)
    assert eng.cache_size == 1      # same region -> cache hit
    out2 = eng.run(cs, "mean", Stage.P, region=r2)
    assert eng.cache_size == 2      # different region -> new program
    assert not np.allclose(np.asarray(out1), np.asarray(out2))


def test_serve_equivalent_region_specs_group_together(field_2d):
    """slice vs (start, stop) vs numpy-int specs of the same window must land
    in one batch group (the signature normalizes, not repr-compares)."""
    from repro.serve.analytics import _region_signature
    f = _c(hszx_nd, field_2d)
    reqs = [AnalyticsRequest(uid=0, fields=f, region=REGION),
            AnalyticsRequest(uid=1, fields=f,
                             region=(slice(30, 75), slice(10, 52))),
            AnalyticsRequest(uid=2, fields=f,
                             region=((np.int64(30), np.int64(75)), (10, 52)))]
    sigs = {_region_signature(r) for r in reqs}
    assert len(sigs) == 1
    assert _region_signature(AnalyticsRequest(uid=3, fields=f)) is None


def test_serve_region_requests(field_2d):
    fields = [_c(hszx_nd, field_2d), _c(hszx_nd, field_2d * 0.5)]
    fe = AnalyticsFrontend()
    fe.add_request(AnalyticsRequest(uid=0, fields=fields[0], op="mean",
                                    region=REGION))
    fe.add_request(AnalyticsRequest(uid=1, fields=fields[1], op="mean",
                                    region=REGION))
    fe.add_request(AnalyticsRequest(uid=2, fields=fields[0], op="mean"))
    fe.add_request(AnalyticsRequest(uid=3, fields=fields[0], op="laplacian",
                                    region=REGION))
    done = {r.uid: r for r in fe.run_until_drained()}
    assert all(r.error is None for r in done.values())
    win = _window_ref(hszx_nd, fields[0])
    assert abs(float(done[0].result) - win.mean()) <= 2e-4
    assert done[2].result_stage == Stage.M          # full field: metadata mean
    assert done[0].result_stage == Stage.P          # unaligned region: stage 2
    h, w = REGION[0][1] - REGION[0][0], REGION[1][1] - REGION[1][0]
    assert done[3].result.shape == (h - 2, w - 2)
    # region vs full-field requests compile separate programs, same-region
    # mean requests batch together: mean-region + mean-full + laplacian = 3
    assert fe.engine.cache_size == 3
