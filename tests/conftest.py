import numpy as np
import pytest

try:
    from hypothesis import HealthCheck, settings
except ImportError:  # optional dep: property-based tests self-skip without it
    settings = None
else:
    # CI-friendly hypothesis profile: jit compilation makes examples expensive
    settings.register_profile(
        "ci", max_examples=12, deadline=None,
        suppress_health_check=[HealthCheck.too_slow, HealthCheck.data_too_large],
    )
    settings.load_profile("ci")


@pytest.fixture(scope="session")
def field_2d():
    """Smooth-ish 2D scientific field (paper-style Ocean analogue)."""
    rng = np.random.default_rng(0)
    x = np.linspace(0, 4 * np.pi, 181)[:, None] + np.linspace(0, 2 * np.pi, 97)[None, :]
    return (np.sin(x) * 3 + np.cos(2 * x) + rng.normal(0, 0.05, (181, 97))
            ).astype(np.float32)


@pytest.fixture(scope="session")
def field_3d():
    rng = np.random.default_rng(1)
    d = rng.normal(0, 1, (24, 40, 33)).astype(np.float32)
    return (np.cumsum(np.cumsum(np.cumsum(d, 0), 1), 2) * 1e-2).astype(np.float32)


@pytest.fixture(scope="session")
def vector_field_2d():
    rng = np.random.default_rng(2)
    g = np.linspace(0, 2 * np.pi, 128)
    u = (np.sin(g)[:, None] * np.cos(g)[None, :]).astype(np.float32)
    v = (np.cos(g)[:, None] * np.sin(g)[None, :]).astype(np.float32)
    u += rng.normal(0, 0.01, u.shape).astype(np.float32)
    v += rng.normal(0, 0.01, v.shape).astype(np.float32)
    return u, v
