"""Stencil operators vs closed-form fields (absolute-correctness oracles).

The stage-comparison tests in ``test_homomorphic.py`` check that stages ②③
agree with stage ④ — which lets absolute errors (sign flips, scale factors,
axis swaps) hide if they affect every stage equally.  These tests pin the
operators to fields with *known exact answers* on the unit index grid:

* quadratics — central differences and the 5/7-point Laplacian are exact;
* rigid rotation ``(u, v) = (-y, x)`` — curl is exactly +2 everywhere (this
  is the oracle that catches the historical ``du/dy - dv/dx`` sign flip);
* trigonometric — the central difference of ``sin(a·i)`` is exactly
  ``sin(a) · cos(a·i)``.

Fields are integer-valued and compressed with ``abs_eb=0.25``, so
quantization is exact (``q = 2·d``) and stages ②③④ must agree to round-off.
"""
import numpy as np
import pytest
import jax.numpy as jnp

from repro.core import Stage, homomorphic as H, hszp, hszp_nd, hszx, hszx_nd

ALL = [hszp, hszx, hszp_nd, hszx_nd]
ND = [hszp_nd, hszx_nd]

N0, N1 = 48, 64


def _grid_2d():
    i = np.arange(N0, dtype=np.float32)[:, None]
    j = np.arange(N1, dtype=np.float32)[None, :]
    return i, j


def _compress(comp, data):
    # abs_eb=0.25 => q = round(d / 0.5) = 2*d exactly for integer-valued d
    return comp.compress(jnp.asarray(data, jnp.float32), abs_eb=0.25)


def _stages(comp):
    return [Stage.Q, Stage.F] + ([Stage.P] if comp.scheme.is_nd else [])


@pytest.mark.parametrize("comp", ALL, ids=lambda c: c.scheme.value)
@pytest.mark.parametrize("axis", [0, 1])
def test_derivative_quadratic_exact(comp, axis):
    """d(x^2)/dx by central difference is exactly 2x on the interior."""
    i, j = _grid_2d()
    f = ((i * i) if axis == 0 else (j * j)) + np.zeros((N0, N1), np.float32)
    c = _compress(comp, f)
    coord = (i if axis == 0 else j) + np.zeros((N0, N1), np.float32)
    expect = 2.0 * coord[1:-1, 1:-1]
    for stage in _stages(comp):
        got = np.asarray(H.derivative(c, stage, axis))
        np.testing.assert_allclose(got, expect, rtol=1e-5, atol=1e-3)


@pytest.mark.parametrize("comp", ALL, ids=lambda c: c.scheme.value)
def test_derivative_axis_order(comp):
    """f = i*N1 + j separates the axes: df/d0 == N1, df/d1 == 1 (an axis swap
    cannot produce either)."""
    i, j = _grid_2d()
    c = _compress(comp, i * N1 + j)
    for stage in _stages(comp):
        np.testing.assert_allclose(np.asarray(H.derivative(c, stage, 0)),
                                   np.full((N0 - 2, N1 - 2), N1, np.float32),
                                   rtol=1e-5, atol=1e-3)
        np.testing.assert_allclose(np.asarray(H.derivative(c, stage, 1)),
                                   np.ones((N0 - 2, N1 - 2), np.float32),
                                   rtol=1e-5, atol=1e-3)


@pytest.mark.parametrize("comp", ALL, ids=lambda c: c.scheme.value)
def test_laplacian_quadratic_exact(comp):
    """Laplacian of x^2 + y^2 is exactly 4 under the 5-point stencil (h=1)."""
    i, j = _grid_2d()
    c = _compress(comp, i * i + j * j)
    for stage in _stages(comp):
        got = np.asarray(H.laplacian(c, stage))
        np.testing.assert_allclose(got, np.full((N0 - 2, N1 - 2), 4.0, np.float32),
                                   rtol=1e-5, atol=1e-3)


@pytest.mark.parametrize("comp", ALL, ids=lambda c: c.scheme.value)
def test_divergence_radial_exact(comp):
    """div (x, y) = 2 exactly."""
    i, j = _grid_2d()
    cu = _compress(comp, i + np.zeros((N0, N1), np.float32))
    cv = _compress(comp, j + np.zeros((N0, N1), np.float32))
    for stage in _stages(comp):
        got = np.asarray(H.divergence([cu, cv], stage))
        np.testing.assert_allclose(got, np.full((N0 - 2, N1 - 2), 2.0, np.float32),
                                   rtol=1e-5, atol=1e-3)


@pytest.mark.parametrize("comp", ALL, ids=lambda c: c.scheme.value)
def test_curl_rigid_rotation_is_plus_two(comp):
    """The sign oracle: (u, v) = (-y, x) has curl dv/dx - du/dy == +2.

    The historical implementation computed du/dy - dv/dx (== -2 here); only
    stage-vs-stage comparisons could not see it.
    """
    i, j = _grid_2d()
    cu = _compress(comp, -(j + np.zeros((N0, N1), np.float32)))
    cv = _compress(comp, i + np.zeros((N0, N1), np.float32))
    for stage in _stages(comp):
        got = np.asarray(H.curl([cu, cv], stage))
        np.testing.assert_allclose(got, np.full((N0 - 2, N1 - 2), 2.0, np.float32),
                                   rtol=1e-5, atol=1e-3)


@pytest.mark.parametrize("comp", ND, ids=lambda c: c.scheme.value)
def test_curl_3d_rigid_rotation(comp):
    """3-D rotation about z: F = (-y, x, 0) has curl exactly (0, 0, 2)."""
    n = 24
    i, j, k = np.meshgrid(np.arange(n), np.arange(n), np.arange(n),
                          indexing="ij")
    z = np.zeros((n, n, n), np.float32)
    cu = _compress(comp, -(j.astype(np.float32)))
    cv = _compress(comp, i.astype(np.float32))
    cw = _compress(comp, z)
    for stage in (Stage.P, Stage.Q, Stage.F):
        cx, cy, cz = H.curl([cu, cv, cw], stage)
        interior = (n - 2, n - 2, n - 2)
        np.testing.assert_allclose(np.asarray(cx), np.zeros(interior), atol=1e-3)
        np.testing.assert_allclose(np.asarray(cy), np.zeros(interior), atol=1e-3)
        np.testing.assert_allclose(np.asarray(cz), np.full(interior, 2.0),
                                   rtol=1e-5, atol=1e-3)


@pytest.mark.parametrize("comp", ALL, ids=lambda c: c.scheme.value)
def test_derivative_trigonometric(comp):
    """Central difference of sin(a·i) is exactly sin(a)·cos(a·i); the
    compressed result must match within O(eps) at every supported stage."""
    a = 2 * np.pi * 3 / N0
    i, j = _grid_2d()
    f = np.sin(a * i) + 0.0 * j
    comp_field = comp.compress(jnp.asarray(f, jnp.float32), abs_eb=1e-4)
    eps = float(comp_field.eps)
    expect = (np.sin(a) * np.cos(a * i) + 0.0 * j)[1:-1, 1:-1]
    for stage in _stages(comp):
        got = np.asarray(H.derivative(comp_field, stage, 0))
        # central difference of d' where |d - d'| <= eps -> error <= eps
        np.testing.assert_allclose(got, expect, atol=2 * eps + 1e-6)


@pytest.mark.parametrize("comp", ALL, ids=lambda c: c.scheme.value)
def test_stats_linear_field_exact(comp):
    """mean/std of f = i*N1 + j (a permutation of 0..N-1) in closed form."""
    i, j = _grid_2d()
    n = N0 * N1
    c = _compress(comp, i * N1 + j)
    expect_mean = (n - 1) / 2.0
    expect_std = float(np.sqrt(n * (n + 1) / 12.0))  # sample std of 0..n-1
    stages = [Stage.P, Stage.Q, Stage.F] + \
        ([Stage.M] if comp.scheme.is_blockmean else [])
    for stage in stages:
        got = float(H.mean(c, stage))
        tol = 0.5 if stage == Stage.M else max(1e-4 * expect_mean, 1e-3)
        assert abs(got - expect_mean) <= tol, (stage, got)
    for stage in (Stage.P, Stage.Q, Stage.F):
        got = float(H.std(c, stage))
        assert abs(got - expect_std) <= max(1e-4 * expect_std, 1e-2), (stage, got)
