"""End-to-end training: loss decreases, checkpoint/restart, compressed DP."""
import os

import numpy as np
import jax
import jax.numpy as jnp

from repro.configs import ARCHS, reduced
from repro.data.tokens import TokenPipeline, TokenPipelineConfig
from repro.models import get_model
from repro.train import checkpoint as ckpt
from repro.train import optimizer as opt_lib
from repro.train import train_step as ts_lib

KEY = jax.random.PRNGKey(0)


def _setup(name="smollm-360m", lr=3e-3):
    cfg = reduced(ARCHS[name])
    model = get_model(cfg)
    params, _ = model.init(KEY)
    opt_cfg = opt_lib.AdamWConfig(lr=lr, warmup_steps=5, total_steps=100,
                                  weight_decay=0.0)
    step = jax.jit(ts_lib.make_train_step(model, opt_cfg))
    state = ts_lib.init_state(params)
    pipe = TokenPipeline(TokenPipelineConfig(vocab=cfg.vocab, seq_len=32,
                                             global_batch=4))
    return cfg, model, step, state, pipe


def test_loss_decreases():
    _, _, step, state, pipe = _setup()
    losses = []
    for _ in range(30):
        batch = {k: jnp.asarray(v) for k, v in next(pipe).items()}
        state, metrics = step(state, batch)
        losses.append(float(metrics["loss"]))
    assert np.isfinite(losses).all()
    assert np.mean(losses[-5:]) < np.mean(losses[:5]) - 0.1, losses


def test_checkpoint_exact_resume(tmp_path):
    """Save at step k, keep training; restart from k reproduces losses."""
    _, _, step, state, pipe = _setup()
    for _ in range(4):
        batch = {k: jnp.asarray(v) for k, v in next(pipe).items()}
        state, _ = step(state, batch)
    ckpt.save(str(tmp_path), 4, state._asdict() | {"data": pipe.state_dict()},
              mode="lossless")
    cont_losses = []
    state_a = state
    pipe_a = TokenPipeline(pipe.cfg, start_step=pipe.step)
    for _ in range(3):
        batch = {k: jnp.asarray(v) for k, v in next(pipe_a).items()}
        state_a, m = step(state_a, batch)
        cont_losses.append(float(m["loss"]))

    # fresh process-style restart
    _, _, step2, state_b, pipe_b = _setup()
    last = ckpt.latest_step(str(tmp_path))
    assert last == 4
    restored = ckpt.restore(str(tmp_path), last,
                            state_b._asdict() | {"data": pipe_b.state_dict()})
    pipe_b.load_state_dict(restored.pop("data"))
    state_b = ts_lib.TrainState(**restored)
    resume_losses = []
    for _ in range(3):
        batch = {k: jnp.asarray(v) for k, v in next(pipe_b).items()}
        state_b, m = step2(state_b, batch)
        resume_losses.append(float(m["loss"]))
    np.testing.assert_allclose(resume_losses, cont_losses, rtol=1e-6)


def test_simulated_failure_recovery(tmp_path):
    """Crash mid-run -> restart from the latest checkpoint -> losses finite
    and the atomic commit never leaves a partial directory behind."""
    _, _, step, state, pipe = _setup()
    for i in range(6):
        batch = {k: jnp.asarray(v) for k, v in next(pipe).items()}
        state, _ = step(state, batch)
        if i % 2 == 1:
            ckpt.save(str(tmp_path), i, state._asdict(), mode="lossless", keep=2)
    # simulate crash: new state from scratch, restore latest
    assert not [d for d in os.listdir(tmp_path) if d.endswith(".tmp")]
    last = ckpt.latest_step(str(tmp_path))
    assert last == 5
    _, _, step2, state2, _ = _setup()
    restored = ckpt.restore(str(tmp_path), last, state2._asdict())
    state2 = ts_lib.TrainState(**restored)
    batch = {k: jnp.asarray(v) for k, v in next(pipe).items()}
    state2, m = step2(state2, batch)
    assert np.isfinite(float(m["loss"]))
    # retention pruned old checkpoints
    kept = [d for d in os.listdir(tmp_path) if d.startswith("step_")]
    assert len(kept) <= 2


def test_hsz_checkpoint_error_bounded(tmp_path):
    """HSZ-mode checkpoints restore within the error bound and verify the
    homomorphic stage-① statistics recorded in the manifest."""
    _, _, step, state, pipe = _setup()
    batch = {k: jnp.asarray(v) for k, v in next(pipe).items()}
    state, _ = step(state, batch)
    ckpt.save(str(tmp_path), 1, {"params": state.params}, mode="hsz", rel_eb=1e-4)
    restored = ckpt.restore(str(tmp_path), 1, {"params": state.params})
    for a, b in zip(jax.tree.leaves(state.params),
                    jax.tree.leaves(restored["params"])):
        a, b = np.asarray(a, np.float32), np.asarray(b, np.float32)
        rng = a.max() - a.min()
        if a.size >= 1024:
            assert np.max(np.abs(a - b)) <= max(1e-4 * rng, 1e-7) * 1.01
        else:
            np.testing.assert_array_equal(a, b)  # small leaves stay lossless


def test_microbatched_matches_full_batch():
    cfg, model, _, state, pipe = _setup()
    opt_cfg = opt_lib.AdamWConfig(lr=1e-3, weight_decay=0.0)
    full = jax.jit(ts_lib.make_train_step(model, opt_cfg))
    micro = jax.jit(ts_lib.make_train_step(model, opt_cfg, microbatch=2))
    batch = {k: jnp.asarray(v) for k, v in next(pipe).items()}
    s1, m1 = full(state, batch)
    s2, m2 = micro(state, batch)
    # same data -> same loss; grads averaged over microbatches match closely
    np.testing.assert_allclose(float(m1["loss"]), float(m2["loss"]), rtol=1e-5)
    np.testing.assert_allclose(float(m1["grad_norm"]), float(m2["grad_norm"]),
                               rtol=1e-3)
