"""Pallas kernels vs pure-jnp oracles (interpret mode), shape/dtype sweeps."""
import numpy as np
import pytest
import jax.numpy as jnp
try:
    from hypothesis import given, strategies as st
except ImportError:  # optional dep: property-based tests self-skip
    from repro.testing import given, st

from repro.kernels import ops, ref


@pytest.mark.parametrize("shape", [(128, 256), (256, 512), (384, 256)])
@pytest.mark.parametrize("eps", [1e-1, 1e-3])
def test_quant_lorenzo2d(shape, eps):
    rng = np.random.default_rng(hash(shape) % 2**31)
    x = jnp.asarray(rng.normal(0, 3, shape).astype(np.float32))
    got = ops.quant_lorenzo2d(x, jnp.float32(eps))
    want = ref.quant_lorenzo2d(x, jnp.float32(eps))
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


@pytest.mark.parametrize("bits", list(range(0, 33)))
def test_bitpack_all_widths(bits):
    rng = np.random.default_rng(bits)
    n = 8192
    if bits == 0:
        u = jnp.zeros((n,), jnp.uint32)
    else:
        maxv = (1 << bits) - 1 if bits < 32 else 0xFFFFFFFF
        u = jnp.asarray((rng.integers(0, 2**31, n, dtype=np.uint32)
                         & np.uint32(maxv)))
    packed = ops.pack(u, bits)
    want = ref.pack_uniform(u, bits)
    np.testing.assert_array_equal(np.asarray(packed), np.asarray(want))
    out = ops.unpack(packed, n, bits)
    np.testing.assert_array_equal(np.asarray(out), np.asarray(u))


@pytest.mark.parametrize("shape", [(130, 258), (258, 514)])
def test_stencils(shape):
    rng = np.random.default_rng(7)
    q = jnp.asarray(rng.integers(-10000, 10000, shape, dtype=np.int32))
    eps = jnp.float32(5e-3)
    d0, d1 = ops.grad2d(q, eps)
    r0, r1 = ref.stencil_dq_grad2d(q, eps)
    np.testing.assert_array_equal(np.asarray(d0), np.asarray(r0))
    np.testing.assert_array_equal(np.asarray(d1), np.asarray(r1))
    lap = ops.laplacian2d(q, eps)
    rl = ref.stencil_dq_laplacian2d(q, eps)
    np.testing.assert_array_equal(np.asarray(lap), np.asarray(rl))


@pytest.mark.parametrize("bits", list(range(1, 33)))
@pytest.mark.parametrize("n", [100, 4097, 5000])
def test_bitpack_tail_shapes(bits, n):
    """Word-layout parity with the XLA packer at non-multiple-of-VALS sizes.

    The kernel packer pads to VALS-multiples internally and slices; its words
    and recovered values must match ``encode.pack_uniform`` bit for bit so
    payloads produced by either path are interchangeable (decode_device
    routes Encoded payloads through the kernel unpacker)."""
    from repro.core import encode
    rng = np.random.default_rng(bits * 101 + n)
    maxv = (1 << bits) - 1 if bits < 32 else 0xFFFFFFFF
    u = jnp.asarray(rng.integers(0, 2**31, n, dtype=np.uint32)
                    & np.uint32(maxv))
    packed = ops.pack(u, bits)
    want = encode.pack_uniform(u, bits)
    np.testing.assert_array_equal(np.asarray(packed), np.asarray(want))
    out = ops.unpack(packed, n, bits)
    np.testing.assert_array_equal(np.asarray(out), np.asarray(u))
    np.testing.assert_array_equal(
        np.asarray(encode.unpack_uniform(packed, n, bits)), np.asarray(u))


@pytest.mark.parametrize("nb,s", [(256, 128), (512, 256), (1024, 64)])
def test_block_stats(nb, s):
    rng = np.random.default_rng(nb)
    qb = jnp.asarray(rng.integers(-50000, 50000, (nb, s), dtype=np.int32))
    gm, gx = ops.block_stats(qb)
    rm, rx = ref.block_stats(qb)
    np.testing.assert_array_equal(np.asarray(gm), np.asarray(rm))
    np.testing.assert_array_equal(np.asarray(gx), np.asarray(rx))


@pytest.mark.parametrize("block", [(4, 4), (8, 8), (8, 16)])
def test_block_stats_signed_parity_with_core(block):
    """The kernel's per-block rounded mean must agree with the stage-①
    metadata the compressor actually stores (decorrelate.block_means) on
    signed data — both use exact round-half-up, floor((2s + c) / (2c)),
    where flooring (not truncating) the negative sums is the parity trap."""
    from repro.core import blocking, decorrelate
    rng = np.random.default_rng(11)
    q = jnp.asarray(rng.integers(-50000, 50000, (64, 48), dtype=np.int32))
    want = decorrelate.block_means(q, block)
    blocked = blocking.to_blocked(q, block)
    g0, g1, b0, b1 = blocked.shape
    gm, gx = ops.block_stats(blocked.reshape(g0 * g1, b0 * b1))
    np.testing.assert_array_equal(np.asarray(gm).reshape(g0, g1),
                                  np.asarray(want))
    u = np.asarray(blocked.reshape(g0 * g1, b0 * b1))
    zig = ((u << 1) ^ (u >> 31)).astype(np.uint32)
    np.testing.assert_array_equal(np.asarray(gx), zig.max(axis=1))


@pytest.mark.parametrize("shape", [(128, 256), (256, 384)])
def test_prefix_stats(shape):
    rng = np.random.default_rng(3)
    p = jnp.asarray(rng.integers(-8, 8, shape, dtype=np.int32))
    s1, s2 = ops.prefix_stats2d(p)
    r1, r2 = ref.prefix_stats2d(p)
    np.testing.assert_allclose(float(s1), float(r1), rtol=1e-5)
    np.testing.assert_allclose(float(s2), float(r2), rtol=1e-5)


@given(st.integers(1, 31), st.integers(1, 4))
def test_bitpack_roundtrip_property(bits, blocks):
    rng = np.random.default_rng(bits * 131 + blocks)
    n = 4096 * blocks
    u = jnp.asarray(rng.integers(0, 1 << bits, n, dtype=np.uint32))
    np.testing.assert_array_equal(
        np.asarray(ops.unpack(ops.pack(u, bits), n, bits)), np.asarray(u))


def test_kernel_pipeline_consistency(field_2d):
    """Fused kernels reproduce the reference pipeline end to end."""
    from repro.core import hszp_nd
    import repro.core.blocking as blocking
    x = jnp.asarray(np.ascontiguousarray(field_2d[:128, :64]))
    eps = jnp.float32(1e-3)
    p_kernel = ops.quant_lorenzo2d(x, eps)
    c = hszp_nd.compress(x, eps=eps)
    p_pipeline = blocking.crop(c.residuals, x.shape)
    np.testing.assert_array_equal(np.asarray(p_kernel), np.asarray(p_pipeline))
