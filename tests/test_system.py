"""End-to-end behaviour: the paper's pipeline (Fig. 1) on a realistic field.

Compress -> pick the cheapest stage per operation -> homomorphic results
match full decompression within eps — the whole point of the paper.
"""
import numpy as np
import jax.numpy as jnp

from repro.core import Stage, homomorphic as H, hszx_nd
from repro.data.scientific import ScientificStore


def test_paper_pipeline_end_to_end():
    store = ScientificStore(scale=24, rel_eb=1e-3)
    # statistical computation from metadata (stage 1, HSZx-family)
    xstore = ScientificStore(compressor_name="hszx_nd", scale=24, rel_eb=1e-3)
    c = xstore.get("Ocean", 0).open()
    raw = np.asarray(xstore.raw("Ocean", 0))
    eps = float(c.eps)
    assert abs(float(H.mean(c, Stage.M)) - raw.mean()) <= 2 * eps
    # numerical differentiation at stage 2/3 (HSZp-nd)
    cp = store.get("Ocean", 0).open()
    for stage in (Stage.P, Stage.Q):
        lap = np.asarray(H.laplacian(cp, stage))
        ref = np.asarray(H.laplacian(cp, Stage.F))
        assert np.abs(lap - ref).max() < 1e-4
    # multivariate derivation on the velocity pair
    cu = store.get("Ocean", 0).open()
    cv = store.get("Ocean", 1).open()
    div_q = np.asarray(H.divergence([cu, cv], Stage.Q))
    div_f = np.asarray(H.divergence([cu, cv], Stage.F))
    assert np.abs(div_q - div_f).max() < 1e-4


def test_stage_selection_economics():
    """Lower stages decode strictly less: the premise of Eq. (2) in §III-A.

    We verify the *work* ordering structurally: stage-1 touches only
    metadata (n_blocks ints), stage-2 skips recorrelation, stage-3 skips
    dequantization.
    """
    rng = np.random.default_rng(0)
    d = jnp.asarray(rng.normal(0, 1, (256, 256)).astype(np.float32))
    c = hszx_nd.compress(d, rel_eb=1e-3)
    assert c.metadata.size == c.n_blocks
    assert c.metadata.size < 0.01 * d.size          # stage-1 data is tiny
    p = hszx_nd.decompress(c, Stage.P)
    q = hszx_nd.decompress(c, Stage.Q, crop=False)
    assert p.dtype == q.dtype == jnp.int32          # integer stages
    f = hszx_nd.decompress(c, Stage.F)
    assert f.dtype == jnp.float32
