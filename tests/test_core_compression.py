"""Core HSZ invariants: error bound, roundtrips, size accounting (paper §III-IV)."""
import numpy as np
import pytest
import jax.numpy as jnp
try:
    from hypothesis import given, strategies as st
except ImportError:  # optional dep: property-based tests self-skip
    from repro.testing import given, st

from repro.core import (Stage, by_name, encode, hszp, hszp_nd, hszx,
                        hszx_nd)

ALL = [hszp, hszx, hszp_nd, hszx_nd]


@pytest.mark.parametrize("comp", ALL, ids=lambda c: c.scheme.value)
@pytest.mark.parametrize("rel_eb", [1e-1, 1e-2, 1e-3, 1e-4])
def test_error_bound_2d(comp, rel_eb, field_2d):
    c = comp.compress(jnp.asarray(field_2d), rel_eb=rel_eb)
    out = np.asarray(comp.decompress(c, Stage.F))
    assert out.shape == field_2d.shape
    # eps + f32 round-off of d' = 2*q*eps (a few ulps of |d|, paper §V-D.2)
    tol = float(c.eps) + 4 * np.finfo(np.float32).eps * np.abs(field_2d).max()
    assert np.max(np.abs(out - field_2d)) <= tol


@pytest.mark.parametrize("comp", [hszp_nd, hszx_nd], ids=lambda c: c.scheme.value)
def test_error_bound_3d(comp, field_3d):
    c = comp.compress(jnp.asarray(field_3d), rel_eb=1e-3)
    out = np.asarray(comp.decompress(c, Stage.F))
    tol = float(c.eps) + 4 * np.finfo(np.float32).eps * np.abs(field_3d).max()
    assert np.max(np.abs(out - field_3d)) <= tol


@pytest.mark.parametrize("comp", ALL, ids=lambda c: c.scheme.value)
def test_stagewise_consistency(comp, field_2d):
    """Stage Q/P/M representations reproduce stage F when completed manually."""
    c = comp.compress(jnp.asarray(field_2d), rel_eb=1e-3)
    q = comp.decompress(c, Stage.Q, crop=False)
    df = np.asarray(comp.decompress(c, Stage.F))
    manual = np.asarray(q).astype(np.float32) * 2.0 * float(c.eps)
    manual = manual.reshape(-1)[: df.size].reshape(df.shape) if not comp.scheme.is_nd \
        else manual[tuple(slice(0, s) for s in df.shape)]
    np.testing.assert_array_equal(manual.astype(np.float32), df)


@pytest.mark.parametrize("comp", ALL, ids=lambda c: c.scheme.value)
def test_encoded_roundtrip_bitexact(comp, field_2d):
    c = comp.compress(jnp.asarray(field_2d), rel_eb=1e-3)
    e = comp.encode(c)
    c2 = encode.decode_device(e)
    np.testing.assert_array_equal(np.asarray(c2.residuals), np.asarray(c.residuals))


@pytest.mark.parametrize("comp", ALL, ids=lambda c: c.scheme.value)
def test_serialize_roundtrip(comp, field_2d):
    c = comp.compress(jnp.asarray(field_2d), rel_eb=1e-3)
    blob = encode.serialize(c)
    c2 = encode.deserialize(blob)
    assert c2.scheme == c.scheme
    # padding values are not serialized (width 0: they carry no information,
    # and counting them would break the valid-only size accounting), so the
    # roundtrip contract is equality of every *valid* residual...
    if comp.scheme.is_nd:
        valid = tuple(slice(0, s) for s in c.shape)
        np.testing.assert_array_equal(np.asarray(c2.residuals)[valid],
                                      np.asarray(c.residuals)[valid])
    else:
        np.testing.assert_array_equal(
            np.asarray(c2.residuals).reshape(-1)[:c.n],
            np.asarray(c.residuals).reshape(-1)[:c.n])
    np.testing.assert_array_equal(np.asarray(c2.metadata), np.asarray(c.metadata))
    # ... and bit-identical decompressed data at every stage
    np.testing.assert_array_equal(np.asarray(comp.decompress(c2, Stage.F)),
                                  np.asarray(comp.decompress(c, Stage.F)))
    np.testing.assert_array_equal(np.asarray(comp.decompress(c2, Stage.Q)),
                                  np.asarray(comp.decompress(c, Stage.Q)))
    # exact size accounting: stream length matches serialized_bits payload
    assert len(blob) * 8 >= float(comp.serialized_bits(c)) - 64 * 8


@pytest.mark.parametrize("comp", ALL, ids=lambda c: c.scheme.value)
def test_serialized_bits_counts_all_metadata(comp, field_2d):
    """The accounting formula pinned: payload + 8-bit width field per block +
    per-block/global scheme metadata + the 64-byte global header.  The
    HSZp-family 32-bit anchor slot used to be dropped, inflating Lorenzo
    ratios relative to HSZx."""
    c = comp.compress(jnp.asarray(field_2d), rel_eb=1e-3)
    payload = int(np.sum(np.asarray(c.bitwidths) * np.asarray(c.valid_counts)))
    n_blocks = c.n_blocks
    if comp.scheme.is_blockmean:
        meta = 32 * n_blocks
    else:
        meta = 32  # global anchor slot, serialized once per stream
    expect = payload + 8 * n_blocks + meta + 8 * 64
    assert int(comp.serialized_bits(c)) == expect
    # the actual serialized stream can only be smaller than the accounted
    # bits by header-estimate slack, never by unaccounted metadata
    blob = encode.serialize(c)
    assert abs(len(blob) * 8 - expect) <= 64 * 8


def test_cross_scheme_ratio_not_inflated(field_2d):
    """Same data, same bound: the reported ratio must track actual serialized
    bytes for every scheme (no scheme gets metadata for free)."""
    for comp in ALL:
        c = comp.compress(jnp.asarray(field_2d), rel_eb=1e-3)
        reported = float(comp.compression_ratio(c))
        actual = (c.n * 4) / len(encode.serialize(c))
        assert abs(reported - actual) / actual < 0.05, (comp.scheme, reported, actual)


def test_device_bytes_counts_every_leaf(field_2d):
    for comp in ALL:
        c = comp.compress(jnp.asarray(field_2d), rel_eb=1e-3)
        e = comp.encode(c)
        leaves = (e.payload, e.metadata, e.bitwidths, e.valid_counts, e.eps)
        assert e.device_bytes() == sum(x.size * x.dtype.itemsize for x in leaves)
        assert e.device_bytes() > e.payload.size * 4  # metadata never free


def test_serialized_bits_no_int32_overflow():
    """Accounting survives >2^31 payload bits (large-field regime)."""
    n_blocks = 100_000
    bw = jnp.full((n_blocks,), 30, jnp.int32)
    vc = jnp.full((n_blocks,), 4096, jnp.int32)   # 1.2e10 payload bits
    got = float(encode.serialized_bits(bw, vc, meta_bits_per_block=32))
    expect = n_blocks * 30 * 4096 + n_blocks * 40 + 8 * 64
    assert got > 0
    assert abs(got - expect) / expect < 1e-6


def test_deserialize_rejects_stale_or_corrupt_streams():
    """v1 blobs (padding packed at full width) and length-inconsistent
    streams must fail loudly, never misalign-decode."""
    import struct
    d = jnp.asarray(np.linspace(0, 1, 600, dtype=np.float32))
    c = hszp.compress(d, rel_eb=1e-3)
    blob = encode.serialize(c)
    with pytest.raises(ValueError):
        encode.deserialize(b"HSZ1" + blob[4:])   # pre-v2 magic
    off = struct.calcsize("<4sBBBdi") + 8 * 2 + c.n_blocks + 4  # total_bits slot
    tampered = bytearray(blob)
    struct.pack_into("<q", tampered, off, 1)
    with pytest.raises(ValueError):
        encode.deserialize(bytes(tampered))


@pytest.mark.parametrize("comp", ALL, ids=lambda c: c.scheme.value)
def test_compression_ratio_sane(comp, field_2d):
    tight = comp.compress(jnp.asarray(field_2d), rel_eb=1e-4)
    loose = comp.compress(jnp.asarray(field_2d), rel_eb=1e-1)
    rt, rl = float(comp.compression_ratio(tight)), float(comp.compression_ratio(loose))
    assert 1.0 < rt < rl, (rt, rl)  # looser bound -> higher ratio


@given(st.integers(10, 2000), st.floats(1e-4, 1e-1),
       st.sampled_from(["hszp", "hszx", "hszp_nd", "hszx_nd"]))
def test_error_bound_property(n, rel_eb, name):
    """|d - d'| <= eps for arbitrary 1-D inputs (hypothesis)."""
    rng = np.random.default_rng(n)
    d = rng.normal(0, 10, n).astype(np.float32)
    comp = by_name(name)
    c = comp.compress(jnp.asarray(d), rel_eb=rel_eb)
    out = np.asarray(comp.decompress(c, Stage.F))
    tol = float(c.eps) + 4 * np.finfo(np.float32).eps * np.abs(d).max()
    assert np.max(np.abs(out - d)) <= tol


@given(st.integers(0, 32))
def test_pack_unpack_property(bits):
    rng = np.random.default_rng(bits)
    n = 256
    maxv = (1 << bits) - 1 if bits < 32 else 0xFFFFFFFF
    u = jnp.asarray(rng.integers(0, maxv + 1 if maxv < 2**63 else maxv,
                                 n, dtype=np.uint32) & np.uint32(maxv))
    packed = encode.pack_uniform(u, bits)
    out = encode.unpack_uniform(packed, n, bits)
    np.testing.assert_array_equal(np.asarray(out), np.asarray(u))


def _wire_roundtrip_check(name: str, shape, rel_eb: float, seed: int) -> None:
    """Full wire-format contract for one (scheme, shape, eps) cell.

    ``compress -> encode -> decode`` (device packer) must be residual-exact,
    and ``compress -> serialize -> deserialize`` (HSZ2 host stream) must
    reproduce the container — every valid residual, the metadata, the exact
    per-block bitwidths and valid counts, eps — and bit-identical stage-③/④
    reconstructions.
    """
    rng = np.random.default_rng(seed)
    d = rng.normal(0, 10, shape).astype(np.float32)
    comp = by_name(name)
    c = comp.compress(jnp.asarray(d), rel_eb=rel_eb)

    # device packer roundtrip at the lossless width
    e = comp.encode(c)
    np.testing.assert_array_equal(
        np.asarray(encode.decode_device(e).residuals), np.asarray(c.residuals))

    c2 = encode.deserialize(encode.serialize(c))
    assert (c2.scheme, c2.shape, c2.block, c2.padded_shape) == \
        (c.scheme, c.shape, c.block, c.padded_shape)
    assert float(c2.eps) == float(c.eps)
    np.testing.assert_array_equal(np.asarray(c2.bitwidths), np.asarray(c.bitwidths))
    np.testing.assert_array_equal(np.asarray(c2.valid_counts),
                                  np.asarray(c.valid_counts))
    np.testing.assert_array_equal(np.asarray(c2.metadata), np.asarray(c.metadata))
    if comp.scheme.is_nd:
        valid = tuple(slice(0, s) for s in c.shape)
        np.testing.assert_array_equal(np.asarray(c2.residuals)[valid],
                                      np.asarray(c.residuals)[valid])
    else:
        np.testing.assert_array_equal(
            np.asarray(c2.residuals).reshape(-1)[:c.n],
            np.asarray(c.residuals).reshape(-1)[:c.n])
    for stage in (Stage.Q, Stage.F):
        np.testing.assert_array_equal(np.asarray(comp.decompress(c2, stage)),
                                      np.asarray(comp.decompress(c, stage)))


@given(st.sampled_from(["hszp", "hszx", "hszp_nd", "hszx_nd"]),
       st.integers(1, 3),
       st.tuples(st.integers(1, 40), st.integers(1, 40), st.integers(1, 40)),
       st.floats(1e-5, 1e-1), st.integers(0, 2 ** 16))
def test_wire_roundtrip_property(name, ndim, dims, rel_eb, seed):
    """encode→serialize→deserialize→decode is exact for all four schemes at
    random shapes/eps (hypothesis) — the regression net the HSZ2 format bump
    (padding at width 0, total_bits validation) previously lacked."""
    _wire_roundtrip_check(name, dims[:ndim], rel_eb, seed)


@pytest.mark.parametrize("name", ["hszp", "hszx", "hszp_nd", "hszx_nd"])
@pytest.mark.parametrize("shape", [(1,), (7,), (300,), (17, 5), (9, 11, 13)])
def test_wire_roundtrip_smoke(name, shape):
    """Deterministic pin of the property above (runs with or without
    hypothesis): odd shapes exercise partial blocks in every rank."""
    import zlib
    seed = zlib.crc32(repr((name, shape)).encode()) % 997  # process-stable
    _wire_roundtrip_check(name, shape, rel_eb=1e-3, seed=seed)


def test_constant_field():
    """Degenerate constant input: near-zero-width blocks, bounded recovery."""
    d = jnp.full((64, 64), 3.25, jnp.float32)
    for comp in ALL:
        c = comp.compress(d, rel_eb=1e-3)
        # all blocks except (possibly) the Lorenzo anchor block are 0-width
        widths = np.asarray(c.bitwidths)
        assert np.median(widths) == 0
        assert float(comp.compression_ratio(c)) > 3.0
        out = comp.decompress(c, Stage.F)
        assert float(jnp.max(jnp.abs(out - d))) <= float(c.eps)
