"""Core HSZ invariants: error bound, roundtrips, size accounting (paper §III-IV)."""
import numpy as np
import pytest
import jax.numpy as jnp
try:
    from hypothesis import given, strategies as st
except ImportError:  # optional dep: property-based tests self-skip
    from repro.testing import given, st

from repro.core import (Stage, by_name, encode, hszp, hszp_nd, hszx,
                        hszx_nd)

ALL = [hszp, hszx, hszp_nd, hszx_nd]


@pytest.mark.parametrize("comp", ALL, ids=lambda c: c.scheme.value)
@pytest.mark.parametrize("rel_eb", [1e-1, 1e-2, 1e-3, 1e-4])
def test_error_bound_2d(comp, rel_eb, field_2d):
    c = comp.compress(jnp.asarray(field_2d), rel_eb=rel_eb)
    out = np.asarray(comp.decompress(c, Stage.F))
    assert out.shape == field_2d.shape
    # eps + f32 round-off of d' = 2*q*eps (a few ulps of |d|, paper §V-D.2)
    tol = float(c.eps) + 4 * np.finfo(np.float32).eps * np.abs(field_2d).max()
    assert np.max(np.abs(out - field_2d)) <= tol


@pytest.mark.parametrize("comp", [hszp_nd, hszx_nd], ids=lambda c: c.scheme.value)
def test_error_bound_3d(comp, field_3d):
    c = comp.compress(jnp.asarray(field_3d), rel_eb=1e-3)
    out = np.asarray(comp.decompress(c, Stage.F))
    tol = float(c.eps) + 4 * np.finfo(np.float32).eps * np.abs(field_3d).max()
    assert np.max(np.abs(out - field_3d)) <= tol


@pytest.mark.parametrize("comp", ALL, ids=lambda c: c.scheme.value)
def test_stagewise_consistency(comp, field_2d):
    """Stage Q/P/M representations reproduce stage F when completed manually."""
    c = comp.compress(jnp.asarray(field_2d), rel_eb=1e-3)
    q = comp.decompress(c, Stage.Q, crop=False)
    df = np.asarray(comp.decompress(c, Stage.F))
    manual = np.asarray(q).astype(np.float32) * 2.0 * float(c.eps)
    manual = manual.reshape(-1)[: df.size].reshape(df.shape) if not comp.scheme.is_nd \
        else manual[tuple(slice(0, s) for s in df.shape)]
    np.testing.assert_array_equal(manual.astype(np.float32), df)


@pytest.mark.parametrize("comp", ALL, ids=lambda c: c.scheme.value)
def test_encoded_roundtrip_bitexact(comp, field_2d):
    c = comp.compress(jnp.asarray(field_2d), rel_eb=1e-3)
    e = comp.encode(c)
    c2 = encode.decode_device(e)
    np.testing.assert_array_equal(np.asarray(c2.residuals), np.asarray(c.residuals))


@pytest.mark.parametrize("comp", ALL, ids=lambda c: c.scheme.value)
def test_serialize_roundtrip(comp, field_2d):
    c = comp.compress(jnp.asarray(field_2d), rel_eb=1e-3)
    blob = encode.serialize(c)
    c2 = encode.deserialize(blob)
    assert c2.scheme == c.scheme
    np.testing.assert_array_equal(np.asarray(c2.residuals), np.asarray(c.residuals))
    np.testing.assert_array_equal(np.asarray(c2.metadata), np.asarray(c.metadata))
    # exact size accounting: stream length matches serialized_bits payload
    assert len(blob) * 8 >= float(comp.serialized_bits(c)) - 64 * 8


@pytest.mark.parametrize("comp", ALL, ids=lambda c: c.scheme.value)
def test_compression_ratio_sane(comp, field_2d):
    tight = comp.compress(jnp.asarray(field_2d), rel_eb=1e-4)
    loose = comp.compress(jnp.asarray(field_2d), rel_eb=1e-1)
    rt, rl = float(comp.compression_ratio(tight)), float(comp.compression_ratio(loose))
    assert 1.0 < rt < rl, (rt, rl)  # looser bound -> higher ratio


@given(st.integers(10, 2000), st.floats(1e-4, 1e-1),
       st.sampled_from(["hszp", "hszx", "hszp_nd", "hszx_nd"]))
def test_error_bound_property(n, rel_eb, name):
    """|d - d'| <= eps for arbitrary 1-D inputs (hypothesis)."""
    rng = np.random.default_rng(n)
    d = rng.normal(0, 10, n).astype(np.float32)
    comp = by_name(name)
    c = comp.compress(jnp.asarray(d), rel_eb=rel_eb)
    out = np.asarray(comp.decompress(c, Stage.F))
    tol = float(c.eps) + 4 * np.finfo(np.float32).eps * np.abs(d).max()
    assert np.max(np.abs(out - d)) <= tol


@given(st.integers(0, 32))
def test_pack_unpack_property(bits):
    rng = np.random.default_rng(bits)
    n = 256
    maxv = (1 << bits) - 1 if bits < 32 else 0xFFFFFFFF
    u = jnp.asarray(rng.integers(0, maxv + 1 if maxv < 2**63 else maxv,
                                 n, dtype=np.uint32) & np.uint32(maxv))
    packed = encode.pack_uniform(u, bits)
    out = encode.unpack_uniform(packed, n, bits)
    np.testing.assert_array_equal(np.asarray(out), np.asarray(u))


def test_constant_field():
    """Degenerate constant input: near-zero-width blocks, bounded recovery."""
    d = jnp.full((64, 64), 3.25, jnp.float32)
    for comp in ALL:
        c = comp.compress(d, rel_eb=1e-3)
        # all blocks except (possibly) the Lorenzo anchor block are 0-width
        widths = np.asarray(c.bitwidths)
        assert np.median(widths) == 0
        assert float(comp.compression_ratio(c)) > 3.0
        out = comp.decompress(c, Stage.F)
        assert float(jnp.max(jnp.abs(out - d))) <= float(c.eps)
