"""Homomorphic compressed collectives: correctness on a multi-device mesh.

Runs in a subprocess with 8 fake devices (XLA device count is locked at
first jax init, so the main test process must stay single-device).
"""
import json
import os
import subprocess
import sys

import numpy as np
import pytest

SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import json
import jax, jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as P
from repro import compat
from repro.comm import hom_collectives as hom
from repro.launch.mesh import auto_axis_types

mesh = jax.make_mesh((8,), ("data",), **auto_axis_types(1))
world = 8

# --- compressed psum vs exact mean -----------------------------------------
rng = np.random.default_rng(0)
grads = {"a": rng.normal(0, 1e-3, (8, 64, 32)).astype(np.float32),
         "b": rng.normal(0, 3e-4, (8, 128,)).astype(np.float32)}

def body(g, r):
    local = {k: v[0] for k, v in g.items()}
    mean, new_r = hom.compressed_psum_tree(local, r, "data", world)
    return mean, new_r

res0 = {k: np.zeros(v.shape[1:], np.float32) for k, v in grads.items()}
f = compat.shard_map(body, mesh=mesh,
                     in_specs=({"a": P("data"), "b": P("data")}, {"a": P(), "b": P()}),
                     out_specs=(P(), P()), check=False)
mean, resid = jax.jit(f)(
    {k: jnp.asarray(v).reshape((8, 1) + v.shape[1:]) for k, v in grads.items()},
    {k: jnp.asarray(v) for k, v in res0.items()})

out = {}
bits = hom.bit_budget(world)
for k in grads:
    exact = grads[k].mean(axis=0)
    got = np.asarray(mean[k])
    vmax = np.abs(grads[k]).max()
    qmax = 2 ** (bits - 1) - 1
    bound = 2 * (vmax / qmax * 0.5) / 1.0  # eps per worker, worst case mean err
    out[k + "_err"] = float(np.abs(got - exact).max())
    out[k + "_bound"] = float(bound)
    out[k + "_resid_finite"] = bool(np.isfinite(np.asarray(resid[k])).all())

# --- packed allgather --------------------------------------------------------
x = rng.normal(0, 1.0, (8, 96)).astype(np.float32)
def body2(xs):
    return hom.packed_allgather(xs[0], "data", bits=12)
g = compat.shard_map(body2, mesh=mesh, in_specs=(P("data"),), out_specs=P(),
                     check=False)
gathered = np.asarray(jax.jit(g)(jnp.asarray(x).reshape(8, 1, 96)))
gathered = gathered.reshape(8, 96)   # (world, 1, 96) -> per-source rows
err = np.abs(gathered - x).max()
out["allgather_err"] = float(err)
out["allgather_bound"] = float(np.abs(x).max() / (2**11 - 1) * 1.01)
print(json.dumps(out))
"""


@pytest.fixture(scope="module")
def comm_results():
    env = dict(os.environ, PYTHONPATH="src")
    r = subprocess.run([sys.executable, "-c", SCRIPT], capture_output=True,
                       text=True, env=env, cwd=os.path.dirname(os.path.dirname(__file__)))
    assert r.returncode == 0, r.stderr[-3000:]
    return json.loads(r.stdout.strip().splitlines()[-1])


def test_compressed_psum_error_bounded(comm_results):
    for k in ("a", "b"):
        assert comm_results[f"{k}_err"] <= comm_results[f"{k}_bound"], comm_results
        assert comm_results[f"{k}_resid_finite"]


def test_packed_allgather_roundtrip(comm_results):
    assert comm_results["allgather_err"] <= comm_results["allgather_bound"]


def test_bit_budget():
    from repro.comm import bit_budget
    assert bit_budget(1) == 15
    assert bit_budget(256) == 7
    assert bit_budget(512) == 6
    # int16 container can hold 512 workers x 6-bit magnitudes: 512*31 < 2^15
    assert 512 * (2 ** (6 - 1) - 1) < 2 ** 15


def test_stage1_stats_matches_numpy():
    import jax.numpy as jnp
    from repro.comm import stage1_stats
    rng = np.random.default_rng(3)
    tree = {"w": jnp.asarray(rng.normal(0.1, 2.0, (513, 37)).astype(np.float32)),
            "b": jnp.asarray(rng.normal(-1, 0.5, (1000,)).astype(np.float32))}
    got = stage1_stats(tree, block=256)
    flat = np.concatenate([np.asarray(v).ravel() for v in tree.values()])
    np.testing.assert_allclose(float(got["mean"]), flat.mean(), rtol=1e-4)
    np.testing.assert_allclose(float(got["std"]), flat.std(), rtol=1e-3)
    np.testing.assert_allclose(float(got["norm"]),
                               np.linalg.norm(flat), rtol=1e-4)


def _world1_mesh():
    import jax
    from repro.launch.mesh import auto_axis_types
    return jax.make_mesh((1,), ("data",), **auto_axis_types(1))


@pytest.mark.parametrize("bits", [4, 8, 12, 15])
@pytest.mark.parametrize("n", [96, 97, 33])   # off-word-boundary lengths too
def test_packed_allgather_unit_roundtrip(bits, n):
    """In-process (world=1) round-trip: gather returns the quantized values
    within the bit budget's grid spacing, and exactly recovers values that
    already sit on the grid (the pack -> wire -> unpack path is lossless on
    the integers)."""
    import jax
    import jax.numpy as jnp
    from jax.sharding import PartitionSpec as P
    from repro import compat
    from repro.comm import hom_collectives as hom

    mesh = _world1_mesh()
    qmax = 2 ** (bits - 1) - 1
    rng = np.random.default_rng(bits * 100 + n)
    # on-grid values: x = q * 2*eps with eps = max|x|/qmax * 0.5, i.e. any
    # x = q * (max|q|/qmax) with q integers and max|q| == qmax
    q = rng.integers(-qmax, qmax + 1, size=n)
    q[0] = qmax
    x = q.astype(np.float32)

    f = compat.shard_map(
        lambda xs: hom.packed_allgather(xs[0], "data", bits=bits),
        mesh=mesh, in_specs=(P("data"),), out_specs=P(), check=False)
    got = np.asarray(jax.jit(f)(jnp.asarray(x).reshape(1, 1, n)))
    assert got.shape == (1, 1, n)
    np.testing.assert_array_equal(got.reshape(n), x)

    y = rng.normal(0, 1.0, n).astype(np.float32)
    got = np.asarray(jax.jit(f)(jnp.asarray(y).reshape(1, 1, n))).reshape(n)
    assert np.abs(got - y).max() <= np.abs(y).max() / qmax * 0.5 + 1e-7


@pytest.mark.parametrize("world", [1, 8, 256, 512])
def test_bit_budget_roundtrip_never_overflows(world):
    """``world`` workers' worst-case quantized magnitudes summed in the
    int16 container stay in range, and the budgeted round-trip recovers the
    exact sum of on-grid values (the homomorphism the wire relies on)."""
    from repro.comm import bit_budget

    bits = bit_budget(world)
    qmax = 2 ** (bits - 1) - 1
    assert world * qmax < 2 ** 15          # int16 accumulator safe
    acc = np.zeros((), np.int16)
    for _ in range(world):
        acc = (acc + np.int16(qmax)).astype(np.int16)
    assert int(acc) == world * qmax        # no wraparound occurred


def test_compressed_psum_tree_unit_world1():
    """In-process world=1 contract: psum is the identity, so the returned
    mean is the dequantized local value, the residual is exactly what
    quantization dropped (v == mean + residual bitwise), and the residual
    is bounded by the shared quantizer's eps."""
    import jax
    import jax.numpy as jnp
    from jax.sharding import PartitionSpec as P
    from repro import compat
    from repro.comm import hom_collectives as hom

    mesh = _world1_mesh()
    rng = np.random.default_rng(7)
    grads = {"w": rng.normal(0, 1e-3, (64, 32)).astype(np.float32),
             "b": rng.normal(0, 3e-4, (128,)).astype(np.float32)}

    def body(g, r):
        return hom.compressed_psum_tree(g, r, "data", world=1)

    f = compat.shard_map(
        body, mesh=mesh,
        in_specs=({"w": P(), "b": P()}, {"w": P(), "b": P()}),
        out_specs=({"w": P(), "b": P()}, {"w": P(), "b": P()}), check=False)
    res0 = jax.tree.map(lambda v: jnp.zeros_like(v), grads)
    mean, resid = jax.jit(f)(
        {k: jnp.asarray(v) for k, v in grads.items()}, res0)

    bits = hom.bit_budget(1)
    qmax = 2 ** (bits - 1) - 1
    for k, v in grads.items():
        m, r = np.asarray(mean[k]), np.asarray(resid[k])
        # residual is the quantization error of the reported value
        # (m + (v - m) re-rounds, so compare to one f32 ulp of v)
        np.testing.assert_allclose(m + r, v, rtol=0,
                                   atol=float(np.abs(v).max()) * 2 ** -22)
        # 1% slack: eps itself is recomputed in f32 inside the jitted body
        eps = np.abs(v).max() / qmax * 0.5
        assert np.abs(r).max() <= eps * 1.01
        # a second round with the carried residual reports a refined mean
        mean2, _ = jax.jit(f)(
            {k2: jnp.asarray(v2) for k2, v2 in grads.items()}, resid)
        assert np.isfinite(np.asarray(mean2[k])).all()


def test_error_feedback_convergence():
    """With error feedback, the accumulated mean over steps converges to the
    true mean (residual carries what quantization dropped)."""
    import jax.numpy as jnp
    from repro.comm import hom_collectives as hom
    # single-worker world: psum over a size-1 axis via vmap-like trick
    rng = np.random.default_rng(1)
    g_true = jnp.asarray(rng.normal(0, 1e-4, (256,)).astype(np.float32))

    mesh = None
    # emulate: quantize/dequantize with error feedback, no collective needed
    bits = hom.bit_budget(1)
    qmax = float(2 ** (bits - 1) - 1)
    resid = jnp.zeros_like(g_true)
    acc = jnp.zeros_like(g_true)
    for _step in range(20):
        v = g_true + resid
        eps = jnp.maximum(jnp.max(jnp.abs(v)) / qmax, 1e-30) * 0.5
        q = jnp.clip(jnp.round(v / (2 * eps)), -qmax, qmax)
        deq = q * 2 * eps
        resid = v - deq
        acc = acc + deq
    mean_est = acc / 20
    assert float(jnp.max(jnp.abs(mean_est - g_true))) < 1e-6
